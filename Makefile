# FlashBias workspace glue.
#
# Tier-1 verify: `make verify` (= cargo build --release && cargo test -q).
# The PJRT artifacts are optional: everything except the runtime-replay
# paths works without them (tests skip, examples print a notice).

CARGO ?= cargo
PYTHON ?= python3

.PHONY: all build test verify bench bench-json bench-check bench-baseline examples fmt clippy lint lint-strict lint-baseline lint-json artifacts clean

all: build

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

verify: build test

bench:
	$(CARGO) bench

# Machine-readable bench output: runs the kernel-engine bench and the
# factorstore benches (cold-vs-warm plan latency, plus plan latency by
# store tier: resident vs spill vs remote vs cold SVD), dropping
# BENCH_kernels.json, BENCH_factorstore.json and BENCH_store_tiers.json
# at the workspace root. serving_load drives a live loopback NetServer
# at three offered-load levels and records BENCH_serving_load.json
# (p50/p99 latency, throughput, continuous-vs-batch1 ratio).
bench-json:
	FLASHBIAS_BENCH_JSON_DIR=$(CURDIR) $(CARGO) bench --bench fig3_efficiency
	FLASHBIAS_BENCH_JSON_DIR=$(CURDIR) $(CARGO) bench --bench serving_overhead
	FLASHBIAS_BENCH_JSON_DIR=$(CURDIR) $(CARGO) bench --bench decode_throughput
	FLASHBIAS_BENCH_JSON_DIR=$(CURDIR) $(CARGO) bench --bench serving_load
	$(CARGO) run --release --bin bench_check -- --report

# Perf-regression gate: re-run the kernel-engine bench and fail if any
# single-thread row's ratio against the dense oracle drifted more than
# 15% above BENCH_kernels.baseline.json. `make bench-baseline`
# re-records the baseline (run on a quiet machine, then commit it).
bench-check:
	FLASHBIAS_BENCH_JSON_DIR=$(CURDIR) $(CARGO) bench --bench fig3_efficiency
	$(CARGO) run --release --bin bench_check

bench-baseline:
	FLASHBIAS_BENCH_JSON_DIR=$(CURDIR) $(CARGO) bench --bench fig3_efficiency
	$(CARGO) run --release --bin bench_check -- --write-baseline

examples:
	$(CARGO) build --release --examples

fmt:
	$(CARGO) fmt --all -- --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# flashlint: the in-repo static analyzer for the serving core's
# concurrency, determinism, and hot-path invariants (rust/src/lint/).
#
# `make lint` is the CI gate: findings recorded in the checked-in
# rust/src/lint/baseline.json are reported as known and do not fail, so
# only *new* findings block a PR. `make lint-strict` fails on any
# finding (the swept tree keeps the baseline empty, so the two agree
# today). After an intentional rule rollout, `make lint-baseline`
# regenerates the baseline deterministically (sorted); commit the diff.
# `make lint-json` drops the machine-readable report at the workspace
# root (gitignored).
lint:
	$(CARGO) run --release --bin flashlint -- \
		--baseline rust/src/lint/baseline.json rust/src

lint-strict:
	$(CARGO) run --release --bin flashlint -- rust/src

lint-baseline:
	$(CARGO) run --release --bin flashlint -- \
		--write-baseline rust/src/lint/baseline.json rust/src

lint-json:
	$(CARGO) run --release --bin flashlint -- --json rust/src > flashlint.json || \
		{ cat flashlint.json; exit 1; }
	cat flashlint.json

# AOT-compile the HLO artifacts + input/output dumps (needs the python
# jax toolchain from the accelerator image).
artifacts:
	cd python/compile && $(PYTHON) aot.py --out-dir ../../artifacts

clean:
	$(CARGO) clean
