"""L1 — Pallas tiled online-softmax attention kernels.

Five variants, mirroring the paper's Figure 2:

* ``flash_attention``                 — pure FlashAttention (upper bound).
* ``flash_attention_dense_bias``      — the baseline: reads the dense
  ``N×M`` bias tile-by-tile from HBM (``O(NM)`` IO; Figure 1c).
* ``flash_attention_factored``        — **FlashBias**: streams the rank-R
  factor strips ``φ_q (N×R)`` / ``φ_k (M×R)`` instead and reconstructs the
  bias tile with one extra MXU matmul (``O((N+M)R)`` IO; Figure 2 right).
* ``flash_attention_alibi_jit``       — Appendix C: ALiBi factor strips
  generated *inside* the kernel from the block coordinates (zero bias IO).
* ``flash_attention_mult_factored``   — Appendix I Eq. (17): multiplicative
  bias via the per-tile Hadamard of two factor matmuls.

All kernels use the FlashAttention-2 schedule: grid over query blocks, an
in-kernel loop over key blocks, and the (m, l, acc) online-softmax
recurrence. ``interpret=True`` everywhere — the CPU PJRT client cannot run
Mosaic custom-calls; real-TPU efficiency is estimated analytically
(DESIGN.md §Hardware-Adaptation).

TPU adaptation of the paper's Triton kernel: the (block_q × C+R) query
strip and (block_k × C+R) key strip live in VMEM (BlockSpec), and the bias
reconstruction φ_q φ_kᵀ is expressed as a matmul so it lands on the MXU —
the paper's core insight ("bias as part of the dot product") maps 1:1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# Perf pass (EXPERIMENTS.md §Perf L1): swept {32..512}²; 256² is 1.8x
# faster than the initial 64² at N=512 (interpret->XLA while-loop trip
# count) and its VMEM model (~0.5 MB: q/k/v/φ strips + score tile) stays
# far under a TPU core's ~16 MB VMEM; 512² gained <5% more — stopped per
# the three-strikes rule. _pick_block clamps to divisors of N for small N.
DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256


def _pick_block(n: int, preferred: int) -> int:
    """Largest divisor of n that is <= preferred (kernels assume exact tiling)."""
    b = min(preferred, n)
    while n % b != 0:
        b -= 1
    return b


def _attn_body(q, k_blk, v_blk, s_extra, m_acc, l_acc, o_acc, scale):
    """One online-softmax step over a key block.

    ``s_extra`` is an additive pre-softmax term for this tile (bias tile or
    causal mask), already in score units.
    """
    s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32) * scale
    if s_extra is not None:
        s = s + s_extra
    m_new = jnp.maximum(m_acc, s.max(axis=-1))
    alpha = jnp.exp(m_acc - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_acc * alpha + p.sum(axis=-1)
    o_new = o_acc * alpha[:, None] + jnp.dot(
        p, v_blk, preferred_element_type=jnp.float32
    )
    return m_new, l_new, o_new


def _causal_tile(q_start, k_start, block_q, block_k, n, m):
    """Additive causal-mask tile in score units (0 or NEG_INF)."""
    qi = q_start + jax.lax.iota(jnp.int32, block_q)[:, None]
    kj = k_start + jax.lax.iota(jnp.int32, block_k)[None, :]
    return jnp.where(kj - (m - n) <= qi, 0.0, NEG_INF).astype(jnp.float32)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k, causal, n, m):
    block_q, c = q_ref.shape
    scale = 1.0 / (c**0.5)
    q = q_ref[...]
    q_start = pl.program_id(0) * block_q
    m_acc = jnp.full((block_q,), NEG_INF, jnp.float32)
    l_acc = jnp.zeros((block_q,), jnp.float32)
    o_acc = jnp.zeros((block_q, v_ref.shape[-1]), jnp.float32)

    def body(i, carry):
        m_a, l_a, o_a = carry
        k_start = i * block_k
        k_blk = k_ref[pl.ds(k_start, block_k), :]
        v_blk = v_ref[pl.ds(k_start, block_k), :]
        extra = (
            _causal_tile(q_start, k_start, block_q, block_k, n, m)
            if causal
            else None
        )
        return _attn_body(q, k_blk, v_blk, extra, m_a, l_a, o_a, scale)

    m_acc, l_acc, o_acc = jax.lax.fori_loop(
        0, m // block_k, body, (m_acc, l_acc, o_acc)
    )
    o_ref[...] = (o_acc / l_acc[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=False, block_q=DEFAULT_BLOCK_Q,
                    block_k=DEFAULT_BLOCK_K):
    """Pure FlashAttention (no bias). q: (N,C), k/v: (M,C)."""
    n, c = q.shape
    m = k.shape[0]
    bq = _pick_block(n, block_q)
    bk = _pick_block(m, block_k)
    kernel = functools.partial(
        _flash_kernel, block_k=bk, causal=causal, n=n, m=m
    )
    return pl.pallas_call(
        kernel,
        grid=(n // bq,),
        in_specs=[
            pl.BlockSpec((bq, c), lambda i: (i, 0)),
            pl.BlockSpec((m, c), lambda i: (0, 0)),
            pl.BlockSpec((m, v.shape[-1]), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, v.shape[-1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, v.shape[-1]), q.dtype),
        interpret=True,
    )(q, k, v)


def _flash_dense_bias_kernel(q_ref, k_ref, v_ref, b_ref, o_ref, *, block_k,
                             causal, n, m):
    block_q, c = q_ref.shape
    scale = 1.0 / (c**0.5)
    q = q_ref[...]
    q_start = pl.program_id(0) * block_q
    m_acc = jnp.full((block_q,), NEG_INF, jnp.float32)
    l_acc = jnp.zeros((block_q,), jnp.float32)
    o_acc = jnp.zeros((block_q, v_ref.shape[-1]), jnp.float32)

    def body(i, carry):
        m_a, l_a, o_a = carry
        k_start = i * block_k
        k_blk = k_ref[pl.ds(k_start, block_k), :]
        v_blk = v_ref[pl.ds(k_start, block_k), :]
        # The quadratic HBM stream the paper eliminates: a (block_q,
        # block_k) tile of the dense bias per inner step.
        extra = b_ref[:, pl.ds(k_start, block_k)].astype(jnp.float32)
        if causal:
            extra = extra + _causal_tile(q_start, k_start, block_q, block_k, n, m)
        return _attn_body(q, k_blk, v_blk, extra, m_a, l_a, o_a, scale)

    m_acc, l_acc, o_acc = jax.lax.fori_loop(
        0, m // block_k, body, (m_acc, l_acc, o_acc)
    )
    o_ref[...] = (o_acc / l_acc[:, None]).astype(o_ref.dtype)


def flash_attention_dense_bias(q, k, v, bias, *, causal=False,
                               block_q=DEFAULT_BLOCK_Q,
                               block_k=DEFAULT_BLOCK_K):
    """Baseline: FlashAttention reading a dense (N, M) additive bias."""
    n, c = q.shape
    m = k.shape[0]
    bq = _pick_block(n, block_q)
    bk = _pick_block(m, block_k)
    kernel = functools.partial(
        _flash_dense_bias_kernel, block_k=bk, causal=causal, n=n, m=m
    )
    return pl.pallas_call(
        kernel,
        grid=(n // bq,),
        in_specs=[
            pl.BlockSpec((bq, c), lambda i: (i, 0)),
            pl.BlockSpec((m, c), lambda i: (0, 0)),
            pl.BlockSpec((m, v.shape[-1]), lambda i: (0, 0)),
            pl.BlockSpec((bq, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bq, v.shape[-1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, v.shape[-1]), q.dtype),
        interpret=True,
    )(q, k, v, bias)


def _flash_factored_kernel(q_ref, k_ref, v_ref, pq_ref, pk_ref, o_ref, *,
                           block_k, causal, n, m):
    block_q, c = q_ref.shape
    scale = 1.0 / (c**0.5)
    q = q_ref[...]
    pq = pq_ref[...].astype(jnp.float32)  # (block_q, R) — stays in VMEM
    q_start = pl.program_id(0) * block_q
    m_acc = jnp.full((block_q,), NEG_INF, jnp.float32)
    l_acc = jnp.zeros((block_q,), jnp.float32)
    o_acc = jnp.zeros((block_q, v_ref.shape[-1]), jnp.float32)

    def body(i, carry):
        m_a, l_a, o_a = carry
        k_start = i * block_k
        k_blk = k_ref[pl.ds(k_start, block_k), :]
        v_blk = v_ref[pl.ds(k_start, block_k), :]
        pk_blk = pk_ref[pl.ds(k_start, block_k), :].astype(jnp.float32)
        # FlashBias: reconstruct the bias tile with one extra matmul —
        # (block_q, R) @ (R, block_k) — instead of reading it from HBM.
        extra = jnp.dot(pq, pk_blk.T, preferred_element_type=jnp.float32)
        if causal:
            extra = extra + _causal_tile(q_start, k_start, block_q, block_k, n, m)
        return _attn_body(q, k_blk, v_blk, extra, m_a, l_a, o_a, scale)

    m_acc, l_acc, o_acc = jax.lax.fori_loop(
        0, m // block_k, body, (m_acc, l_acc, o_acc)
    )
    o_ref[...] = (o_acc / l_acc[:, None]).astype(o_ref.dtype)


def flash_attention_factored(q, k, v, phi_q, phi_k, *, causal=False,
                             block_q=DEFAULT_BLOCK_Q,
                             block_k=DEFAULT_BLOCK_K):
    """FlashBias fused kernel: bias = phi_q @ phi_k.T, never materialized."""
    n, c = q.shape
    m = k.shape[0]
    r = phi_q.shape[-1]
    bq = _pick_block(n, block_q)
    bk = _pick_block(m, block_k)
    kernel = functools.partial(
        _flash_factored_kernel, block_k=bk, causal=causal, n=n, m=m
    )
    return pl.pallas_call(
        kernel,
        grid=(n // bq,),
        in_specs=[
            pl.BlockSpec((bq, c), lambda i: (i, 0)),
            pl.BlockSpec((m, c), lambda i: (0, 0)),
            pl.BlockSpec((m, v.shape[-1]), lambda i: (0, 0)),
            pl.BlockSpec((bq, r), lambda i: (i, 0)),
            pl.BlockSpec((m, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, v.shape[-1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, v.shape[-1]), q.dtype),
        interpret=True,
    )(q, k, v, phi_q, phi_k)


def _flash_alibi_jit_kernel(q_ref, k_ref, v_ref, slope_ref, o_ref, *,
                            block_k, causal, n, m):
    """Appendix C: ALiBi factor strips created in-kernel (JIT), zero bias IO.

    ALiBi: b[i,j] = -slope * |i - j| for the bias part; with causal masking
    only j <= i survives so b = slope * (j - i). Decomposition (Ex. 3.4):
    φ_q(i) = [1, i], φ_k(j) = [-j, 1] scaled by slope.
    """
    block_q, c = q_ref.shape
    scale = 1.0 / (c**0.5)
    q = q_ref[...]
    slope = slope_ref[0]
    q_start = pl.program_id(0) * block_q
    qi = (q_start + jax.lax.iota(jnp.int32, block_q)).astype(jnp.float32)
    m_acc = jnp.full((block_q,), NEG_INF, jnp.float32)
    l_acc = jnp.zeros((block_q,), jnp.float32)
    o_acc = jnp.zeros((block_q, v_ref.shape[-1]), jnp.float32)

    def body(i, carry):
        m_a, l_a, o_a = carry
        k_start = i * block_k
        k_blk = k_ref[pl.ds(k_start, block_k), :]
        v_blk = v_ref[pl.ds(k_start, block_k), :]
        kj = (k_start + jax.lax.iota(jnp.int32, block_k)).astype(jnp.float32)
        extra = slope * (kj[None, :] - qi[:, None])
        if causal:
            extra = extra + _causal_tile(q_start, k_start, block_q, block_k, n, m)
        return _attn_body(q, k_blk, v_blk, extra, m_a, l_a, o_a, scale)

    m_acc, l_acc, o_acc = jax.lax.fori_loop(
        0, m // block_k, body, (m_acc, l_acc, o_acc)
    )
    o_ref[...] = (o_acc / l_acc[:, None]).astype(o_ref.dtype)


def flash_attention_alibi_jit(q, k, v, slope, *, causal=True,
                              block_q=DEFAULT_BLOCK_Q,
                              block_k=DEFAULT_BLOCK_K):
    """ALiBi bias generated inside the kernel (Appendix C / Table 8)."""
    n, c = q.shape
    m = k.shape[0]
    bq = _pick_block(n, block_q)
    bk = _pick_block(m, block_k)
    slope_arr = jnp.asarray(slope, jnp.float32).reshape((1,))
    kernel = functools.partial(
        _flash_alibi_jit_kernel, block_k=bk, causal=causal, n=n, m=m
    )
    return pl.pallas_call(
        kernel,
        grid=(n // bq,),
        in_specs=[
            pl.BlockSpec((bq, c), lambda i: (i, 0)),
            pl.BlockSpec((m, c), lambda i: (0, 0)),
            pl.BlockSpec((m, v.shape[-1]), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bq, v.shape[-1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, v.shape[-1]), q.dtype),
        interpret=True,
    )(q, k, v, slope_arr)


def _flash_mult_factored_kernel(q_ref, k_ref, v_ref, pq_ref, pk_ref, o_ref,
                                *, block_k, n, m):
    block_q, c = q_ref.shape
    scale = 1.0 / (c**0.5)
    q = q_ref[...]
    pq = pq_ref[...].astype(jnp.float32)
    m_acc = jnp.full((block_q,), NEG_INF, jnp.float32)
    l_acc = jnp.zeros((block_q,), jnp.float32)
    o_acc = jnp.zeros((block_q, v_ref.shape[-1]), jnp.float32)

    def body(i, carry):
        m_a, l_a, o_a = carry
        k_start = i * block_k
        k_blk = k_ref[pl.ds(k_start, block_k), :]
        v_blk = v_ref[pl.ds(k_start, block_k), :]
        pk_blk = pk_ref[pl.ds(k_start, block_k), :].astype(jnp.float32)
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32) * scale
        # Appendix I: Hadamard with the reconstructed multiplicative bias.
        s = s * jnp.dot(pq, pk_blk.T, preferred_element_type=jnp.float32)
        m_new = jnp.maximum(m_a, s.max(axis=-1))
        alpha = jnp.exp(m_a - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_a * alpha + p.sum(axis=-1)
        o_new = o_a * alpha[:, None] + jnp.dot(
            p, v_blk, preferred_element_type=jnp.float32
        )
        return m_new, l_new, o_new

    m_acc, l_acc, o_acc = jax.lax.fori_loop(
        0, m // block_k, body, (m_acc, l_acc, o_acc)
    )
    o_ref[...] = (o_acc / l_acc[:, None]).astype(o_ref.dtype)


def flash_attention_mult_factored(q, k, v, phi_q, phi_k, *,
                                  block_q=DEFAULT_BLOCK_Q,
                                  block_k=DEFAULT_BLOCK_K):
    """Multiplicative-bias FlashBias (Appendix I), bias = phi_q @ phi_k.T."""
    n, c = q.shape
    m = k.shape[0]
    r = phi_q.shape[-1]
    bq = _pick_block(n, block_q)
    bk = _pick_block(m, block_k)
    kernel = functools.partial(
        _flash_mult_factored_kernel, block_k=bk, n=n, m=m
    )
    return pl.pallas_call(
        kernel,
        grid=(n // bq,),
        in_specs=[
            pl.BlockSpec((bq, c), lambda i: (i, 0)),
            pl.BlockSpec((m, c), lambda i: (0, 0)),
            pl.BlockSpec((m, v.shape[-1]), lambda i: (0, 0)),
            pl.BlockSpec((bq, r), lambda i: (i, 0)),
            pl.BlockSpec((m, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, v.shape[-1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, v.shape[-1]), q.dtype),
        interpret=True,
    )(q, k, v, phi_q, phi_k)
