"""Pure-jnp oracles for every attention variant in the repo.

These are the correctness ground truth for the Pallas kernels (L1) and for
the rust host-side reference implementations (cross-checked through the
AOT artifacts). Everything is single-head ``(N, C)``; multi-head is vmap'd
at L2.

Equation (1) of the paper:   o = softmax(q kᵀ / √C + b) v
Equation (3) (FlashBias):    o = softmax(([q | √C φ_q][k | φ_k]ᵀ) / √C) v
Equation (15) (App. I):      o = softmax((q kᵀ / √C) ⊙ b) v
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def _causal_mask(n: int, m: int):
    """Causal mask aligned to the *end* of the key axis (decoder alignment)."""
    i = jnp.arange(n)[:, None]
    j = jnp.arange(m)[None, :]
    return j - (m - n) <= i


def attention(q, k, v, bias=None, causal: bool = False):
    """Reference attention with optional additive dense bias and causal mask."""
    c = q.shape[-1]
    s = (q @ k.T) / jnp.sqrt(jnp.asarray(c, q.dtype))
    if bias is not None:
        s = s + bias.astype(s.dtype)
    if causal:
        s = jnp.where(_causal_mask(q.shape[0], k.shape[0]), s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return p @ v


def attention_factored(q, k, v, phi_q, phi_k, causal: bool = False):
    """FlashBias Eq. (3): factored bias folded into the dot product.

    ``phi_q @ phi_k.T`` must equal the bias. Implemented exactly as the
    concat trick so it exercises the same numerics as the kernels.
    """
    c = q.shape[-1]
    sqrt_c = jnp.sqrt(jnp.asarray(c, q.dtype))
    q_ext = jnp.concatenate([q, sqrt_c * phi_q.astype(q.dtype)], axis=-1)
    k_ext = jnp.concatenate([k, phi_k.astype(k.dtype)], axis=-1)
    s = (q_ext @ k_ext.T) / sqrt_c
    if causal:
        s = jnp.where(_causal_mask(q.shape[0], k.shape[0]), s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return p @ v


def attention_multiplicative(q, k, v, bias):
    """Appendix I Eq. (15): Hadamard (multiplicative) bias."""
    c = q.shape[-1]
    s = (q @ k.T) / jnp.sqrt(jnp.asarray(c, q.dtype))
    s = s * bias.astype(s.dtype)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return p @ v


def attention_multiplicative_factored(q, k, v, phi_q, phi_k):
    """Appendix I Eq. (17): channel-repeat trick for multiplicative bias.

    q' = [q ⊙ φ_q,1, …, q ⊙ φ_q,R]  ∈ R^{N×CR}, likewise k'.
    """
    c = q.shape[-1]
    r = phi_q.shape[-1]
    # (N, R, C): broadcast each factor column over the channel dim.
    q_ext = (q[:, None, :] * phi_q[:, :, None]).reshape(q.shape[0], r * c)
    k_ext = (k[:, None, :] * phi_k[:, :, None]).reshape(k.shape[0], r * c)
    s = (q_ext @ k_ext.T) / jnp.sqrt(jnp.asarray(c, q.dtype))
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return p @ v


def online_softmax_attention(q, k, v, bias=None, block_k: int = 64):
    """Block-streamed online-softmax attention (Milakov & Gimelshein).

    Mirrors the accumulator recurrence the Pallas kernels implement, but in
    plain jnp — validates the recurrence independently of Pallas.
    """
    n, c = q.shape
    m_len = k.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(c, q.dtype))
    m_acc = jnp.full((n,), NEG_INF, q.dtype)
    l_acc = jnp.zeros((n,), q.dtype)
    o_acc = jnp.zeros((n, v.shape[-1]), q.dtype)
    for start in range(0, m_len, block_k):
        stop = min(start + block_k, m_len)
        s = (q @ k[start:stop].T) * scale
        if bias is not None:
            s = s + bias[:, start:stop].astype(s.dtype)
        m_new = jnp.maximum(m_acc, s.max(axis=-1))
        alpha = jnp.exp(m_acc - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_acc = l_acc * alpha + p.sum(axis=-1)
        o_acc = o_acc * alpha[:, None] + p @ v[start:stop]
        m_acc = m_new
    return o_acc / l_acc[:, None]
