"""Bias decomposition library (build-time, Python side).

Three instantiations of Table 1:

* **Exact** — closed-form factor functions φ_q, φ_k for ALiBi
  (Example 3.4), 3D spatial distance (Example 3.5, incl. the learnable-α
  weighted variant of §4.4), and the cos multiplicative bias
  (Example I.1).
* **SVD** — truncated SVD of a fixed (learned-parameter) bias matrix,
  with energy-targeted rank selection (Remark 3.8 / Figures 6, 8, 9).
* **Neural** — token-wise MLP factor functions φ̂_q,θ1 / φ̂_k,θ2 trained
  with Adam against Eq. (5), used for dynamic biases (AlphaFold-style
  pair bias, gravity, spherical distance — Appendix G).

The rust layer has mirrored implementations (``rust/src/bias``,
``rust/src/decompose``); the pytest suite pins both against these.
"""

from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Exact decompositions
# --------------------------------------------------------------------------


def alibi_slopes(num_heads: int) -> np.ndarray:
    """Geometric head slopes from the ALiBi paper: 2^(-8h/H)."""
    return np.asarray(
        [2.0 ** (-8.0 * (h + 1) / num_heads) for h in range(num_heads)],
        np.float32,
    )


def alibi_bias(n: int, m: int, slope: float) -> jnp.ndarray:
    """Dense ALiBi bias slope·(j − i) (pre-causal-mask, Example 3.4)."""
    i = jnp.arange(n, dtype=jnp.float32)[:, None]
    j = jnp.arange(m, dtype=jnp.float32)[None, :]
    return slope * (j - i)


def alibi_factors(n: int, m: int, slope: float):
    """Example 3.4: φ_q(i) = [slope·(−i), slope], φ_k(j) = [1, j]  (R = 2)."""
    i = jnp.arange(n, dtype=jnp.float32)
    j = jnp.arange(m, dtype=jnp.float32)
    phi_q = jnp.stack([-slope * i, jnp.full_like(i, slope)], axis=-1)
    phi_k = jnp.stack([jnp.ones_like(j), j], axis=-1)
    return phi_q, phi_k


def spatial_bias(xq: jnp.ndarray, xk: jnp.ndarray,
                 alpha: jnp.ndarray | None = None) -> jnp.ndarray:
    """Dense −α_i·‖x_i − x_j‖² bias (Example 3.5 / §4.4 PDE solver).

    The paper uses the squared distance with a per-query learnable weight
    α_i (adaptive-mesh approximation); sign convention: closer points get
    larger bias, so we negate.
    """
    d2 = ((xq[:, None, :] - xk[None, :, :]) ** 2).sum(-1)
    if alpha is not None:
        d2 = alpha[:, None] * d2
    return -d2


def spatial_factors(xq: jnp.ndarray, xk: jnp.ndarray,
                    alpha: jnp.ndarray | None = None):
    """Example 3.5 exact factorization, R = 3·dim (9 for 3D).

    −α_i‖x_i − x_j‖² = Σ_d  α_i·(−x_id²)·1 + α_i·(−1)·x_jd² + α_i·2x_id·x_jd
    φ_q rows absorb α_i so the weighted variant stays rank-9.
    """
    dim = xq.shape[-1]
    n, m = xq.shape[0], xk.shape[0]
    a = jnp.ones((n,), xq.dtype) if alpha is None else alpha
    cols_q, cols_k = [], []
    for d in range(dim):
        xd_q, xd_k = xq[:, d], xk[:, d]
        cols_q += [-a * xd_q**2, -a, 2.0 * a * xd_q]
        cols_k += [jnp.ones((m,), xk.dtype), xd_k**2, xd_k]
    return jnp.stack(cols_q, -1), jnp.stack(cols_k, -1)


def cos_mult_bias(n: int, m: int) -> jnp.ndarray:
    """Example I.1: multiplicative bias b_ij = cos(i − j)."""
    i = jnp.arange(n, dtype=jnp.float32)[:, None]
    j = jnp.arange(m, dtype=jnp.float32)[None, :]
    return jnp.cos(i - j)


def cos_mult_factors(n: int, m: int):
    """cos(i−j) = cos i cos j + sin i sin j  (R = 2)."""
    i = jnp.arange(n, dtype=jnp.float32)
    j = jnp.arange(m, dtype=jnp.float32)
    return (
        jnp.stack([jnp.cos(i), jnp.sin(i)], -1),
        jnp.stack([jnp.cos(j), jnp.sin(j)], -1),
    )


# --------------------------------------------------------------------------
# Dense generators used only as neural-decomposition targets (Appendix G)
# --------------------------------------------------------------------------


def gravity_bias(xq: jnp.ndarray, xk: jnp.ndarray,
                 eps: float = 0.01) -> jnp.ndarray:
    """Appendix G Eq. (13): 1/(‖x_i − x_j‖² + eps·diag-stabilizer)."""
    d2 = ((xq[:, None, :] - xk[None, :, :]) ** 2).sum(-1)
    return 1.0 / (d2 + eps)


def spherical_bias(xq: jnp.ndarray, xk: jnp.ndarray) -> jnp.ndarray:
    """Appendix G Eq. (14): haversine great-circle distance.

    xq/xk columns are (latitude, longitude).
    """
    lat_q, lon_q = xq[:, 0:1], xq[:, 1:2]
    lat_k, lon_k = xk[None, :, 0], xk[None, :, 1]
    s1 = jnp.sin((lat_q - lat_k) / 2.0) ** 2
    s2 = jnp.cos(lat_q) * jnp.cos(lat_k) * jnp.sin((lon_q - lon_k) / 2.0) ** 2
    return 2.0 * jnp.arcsin(jnp.sqrt(jnp.clip(s1 + s2, 0.0, 1.0)))


# --------------------------------------------------------------------------
# Synthetic "trained" relative-position bias (Swin / Pangu substitution)
# --------------------------------------------------------------------------


def swin_relative_bias(window: tuple[int, int], num_heads: int,
                       seed: int = 0, smooth_terms: int = 6,
                       noise: float = 0.02) -> np.ndarray:
    """Synthetic learned relative-position bias with realistic spectra.

    Real SwinV2 biases come from a (2w−1)×(2w−1) learned table indexed by
    relative offset — a smooth function of (Δy, Δx) plus training noise,
    which is exactly what makes them low-rank (Figure 6/8). We synthesize
    the table as a small sum of separable Gaussians (smooth, low-rank
    part) plus white noise (the full-rank tail), then gather into the
    (N, N) per-head bias, N = wy·wx.
    """
    wy, wx = window
    rng = np.random.default_rng(seed)
    n = wy * wx
    dy = np.arange(-(wy - 1), wy)[:, None].astype(np.float32)
    dx = np.arange(-(wx - 1), wx)[None, :].astype(np.float32)
    biases = np.empty((num_heads, n, n), np.float32)
    ys, xs = np.meshgrid(np.arange(wy), np.arange(wx), indexing="ij")
    coords = np.stack([ys.ravel(), xs.ravel()], -1)  # (n, 2)
    rel = coords[:, None, :] - coords[None, :, :]    # (n, n, 2)
    for h in range(num_heads):
        table = np.zeros((2 * wy - 1, 2 * wx - 1), np.float32)
        for _ in range(smooth_terms):
            cy, cx = rng.normal(0, wy / 2), rng.normal(0, wx / 2)
            sy = rng.uniform(wy / 4, wy) ; sx = rng.uniform(wx / 4, wx)
            amp = rng.normal(0, 1.0)
            table += amp * np.exp(-((dy - cy) / sy) ** 2) * np.exp(
                -((dx - cx) / sx) ** 2
            )
        table += noise * rng.normal(size=table.shape).astype(np.float32)
        biases[h] = table[rel[..., 0] + wy - 1, rel[..., 1] + wx - 1]
    return biases


# --------------------------------------------------------------------------
# SVD decomposition (Table 1b)
# --------------------------------------------------------------------------


def svd_factors(bias: jnp.ndarray, rank: int):
    """Truncated SVD: bias ≈ (U√Σ)(V√Σ)ᵀ with R columns."""
    u, s, vt = jnp.linalg.svd(bias, full_matrices=False)
    root = jnp.sqrt(s[:rank])
    return u[:, :rank] * root[None, :], vt[:rank, :].T * root[None, :]


def energy(bias: np.ndarray) -> np.ndarray:
    """Cumulative squared-singular-value energy fractions (Remark 3.8)."""
    s = np.linalg.svd(np.asarray(bias), compute_uv=False)
    e = s**2
    return np.cumsum(e) / max(e.sum(), 1e-30)


def rank_for_energy(bias: np.ndarray, target: float = 0.99) -> int:
    """Smallest R whose truncated SVD keeps ≥ target energy (Fig. 8)."""
    cum = energy(bias)
    return int(np.searchsorted(cum, target) + 1)


# --------------------------------------------------------------------------
# Neural decomposition (Table 1c, Eq. 5)
# --------------------------------------------------------------------------


class MlpParams(NamedTuple):
    w1: jnp.ndarray
    b1: jnp.ndarray
    w2: jnp.ndarray
    b2: jnp.ndarray
    w3: jnp.ndarray
    b3: jnp.ndarray


def mlp_init(key, c_in: int, hidden: int, c_out: int) -> MlpParams:
    """Three linear layers with tanh in between (Appendix H Table 12)."""
    k1, k2, k3 = jax.random.split(key, 3)

    def lin(k, fan_in, fan_out):
        scale = 1.0 / math.sqrt(fan_in)
        return (
            jax.random.uniform(k, (fan_in, fan_out), jnp.float32,
                               -scale, scale),
            jnp.zeros((fan_out,), jnp.float32),
        )

    w1, b1 = lin(k1, c_in, hidden)
    w2, b2 = lin(k2, hidden, hidden)
    w3, b3 = lin(k3, hidden, c_out)
    return MlpParams(w1, b1, w2, b2, w3, b3)


def mlp_apply(p: MlpParams, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.tanh(x @ p.w1 + p.b1)
    h = jnp.tanh(h @ p.w2 + p.b2)
    return h @ p.w3 + p.b3


def _adam_update(g, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1**step)
    vh = v / (1 - b2**step)
    return -lr * mh / (jnp.sqrt(vh) + eps), m, v


def neural_decompose(target_fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
                     xq: jnp.ndarray, xk: jnp.ndarray, rank: int,
                     hidden: int = 64, steps: int = 2000, lr: float = 1e-3,
                     seed: int = 0, lr_decay: float = 0.95,
                     lr_decay_every: int = 50):
    """Fit φ̂_q,θ1 / φ̂_k,θ2 to a dense bias via Eq. (5) with Adam.

    ``target_fn(xq, xk) -> (N, M)`` is evaluated once; the MLPs are
    token-wise (Remark 3.6). Returns (params_q, params_k, loss_history).
    """
    key = jax.random.PRNGKey(seed)
    kq, kk = jax.random.split(key)
    pq = mlp_init(kq, xq.shape[-1], hidden, rank)
    pk = mlp_init(kk, xk.shape[-1], hidden, rank)
    target = target_fn(xq, xk)

    def loss_fn(params):
        pq, pk = params
        approx = mlp_apply(pq, xq) @ mlp_apply(pk, xk).T
        return jnp.mean((approx - target) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    params = (pq, pk)
    m_state = jax.tree_util.tree_map(jnp.zeros_like, params)
    v_state = jax.tree_util.tree_map(jnp.zeros_like, params)
    losses = []
    cur_lr = lr
    for step in range(1, steps + 1):
        loss, grads = grad_fn(params)
        losses.append(float(loss))
        flat_p, tree = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_m = jax.tree_util.tree_leaves(m_state)
        flat_v = jax.tree_util.tree_leaves(v_state)
        new_p, new_m, new_v = [], [], []
        for p, g, mm, vv in zip(flat_p, flat_g, flat_m, flat_v):
            upd, mm, vv = _adam_update(g, mm, vv, step, cur_lr)
            new_p.append(p + upd)
            new_m.append(mm)
            new_v.append(vv)
        params = jax.tree_util.tree_unflatten(tree, new_p)
        m_state = jax.tree_util.tree_unflatten(tree, new_m)
        v_state = jax.tree_util.tree_unflatten(tree, new_v)
        if step % lr_decay_every == 0:
            cur_lr *= lr_decay
    return params[0], params[1], losses


def reconstruction_error(bias: jnp.ndarray, phi_q: jnp.ndarray,
                         phi_k: jnp.ndarray) -> float:
    """Relative Frobenius error of a factor pair against a dense bias."""
    diff = phi_q @ phi_k.T - bias
    return float(jnp.linalg.norm(diff) / (jnp.linalg.norm(bias) + 1e-30))
