"""L2 variant assembly: every AOT artifact is declared here.

An artifact is a named, fully-shaped computation: a python builder returns
``(fn, example_inputs, meta)`` where

* ``fn(*flat_arrays) -> tuple(outputs)`` — a jax function over a *flat*
  positional list of arrays (params, activations, bias factors, …). Flat
  signatures keep the rust loader model-agnostic: it feeds literals in
  manifest order.
* ``example_inputs`` — list of concrete np/jnp arrays; these are dumped as
  raw binaries next to the HLO so the rust side can execute any artifact
  (and overwrite activation inputs when benchmarking).
* ``meta`` — free-form dict recorded in the manifest (experiment id,
  variant, N/C/H/R, which inputs are "weights" vs "activations").

Variant families (see DESIGN.md per-experiment index):

* ``attn_*``   — multi-head attention micro-ops over the L1 Pallas kernels
  (Figures 3/4/5 measured rows, Table 8).
* ``plain_*``  — §4.1 8-layer Transformer fwd + 2-layer train step.
* ``gpt2_*``   — §4.2 causal + ALiBi decoder stack (Table 3).
* ``swin_*``   — §4.3 window attention with learned bias (Table 4).
* ``pde_*``    — §4.4 PDE solver with weighted spatial bias (Tables 5/11).
* ``pairformer_*`` — §4.4 AF3-style block (Tables 6/9/10, Figure 7).
* ``mult_*``   — Appendix I multiplicative bias.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import decomp
from .kernels import flash_attention as fa
from .models import common, gpt2_alibi, pairformer, pde, plain, swin

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, callable] = {}


def artifact(name):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def registry():
    return dict(_REGISTRY)


def _key(seed=0):
    return jax.random.PRNGKey(seed)


def _rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


def _flatten_params(params):
    flat, treedef = jax.tree_util.tree_flatten(params)
    return flat, treedef


def _meta_inputs(names):
    """Mark which flat inputs are weights vs activations (for the bench
    harness: activations may be randomized per-iteration, weights reused)."""
    return names


# ---------------------------------------------------------------------------
# attention micro-ops (Figures 3/4/5, Table 8, Appendix I)
# ---------------------------------------------------------------------------

MICRO_H = 8
MICRO_C = 64


def _micro_qkv(n, h=MICRO_H, c=MICRO_C, seed=0):
    k1, k2, k3 = jax.random.split(_key(seed), 3)
    return (_rand(k1, (h, n, c)), _rand(k2, (h, n, c)), _rand(k3, (h, n, c)))


def _register_micro(n):
    @artifact(f"attn_pure_n{n}")
    def _pure(n=n):
        q, k, v = _micro_qkv(n)

        def fn(q, k, v):
            return (common.mha_pallas(q, k, v),)

        return fn, [q, k, v], {
            "family": "attn", "variant": "pure", "n": n, "c": MICRO_C,
            "heads": MICRO_H, "inputs": ["q", "k", "v"],
            "activations": [0, 1, 2],
        }

    @artifact(f"attn_dense_n{n}")
    def _dense(n=n):
        q, k, v = _micro_qkv(n)
        bias = _rand(_key(7), (MICRO_H, n, n), 0.1)

        def fn(q, k, v, bias):
            return (common.mha_pallas_dense_bias(q, k, v, bias),)

        return fn, [q, k, v, bias], {
            "family": "attn", "variant": "dense", "n": n, "c": MICRO_C,
            "heads": MICRO_H, "inputs": ["q", "k", "v", "bias"],
            "activations": [0, 1, 2],
        }

    @artifact(f"attn_factored_n{n}")
    def _factored(n=n, r=8):
        q, k, v = _micro_qkv(n)
        kk = jax.random.split(_key(8), 2)
        pq = _rand(kk[0], (MICRO_H, n, r), 0.3)
        pk = _rand(kk[1], (MICRO_H, n, r), 0.3)

        def fn(q, k, v, pq, pk):
            return (common.mha_pallas_factored(q, k, v, pq, pk),)

        return fn, [q, k, v, pq, pk], {
            "family": "attn", "variant": "factored", "n": n, "c": MICRO_C,
            "heads": MICRO_H, "rank": r,
            "inputs": ["q", "k", "v", "phi_q", "phi_k"],
            "activations": [0, 1, 2],
        }

    @artifact(f"attn_flexlike_n{n}")
    def _flexlike(n=n):
        q, k, v = _micro_qkv(n)
        pos = jnp.arange(n, dtype=jnp.float32)

        def fn(q, k, v, pos):
            # FlexAttention stand-in: the bias is an element-wise in-graph
            # computation over (N, M) — never a matmul, never an input.
            bias = jnp.stack(
                [-0.05 * (h + 1) * jnp.abs(pos[:, None] - pos[None, :])
                 for h in range(MICRO_H)]
            )
            return (common.mha_pallas_dense_bias(q, k, v, bias),)

        return fn, [q, k, v, pos], {
            "family": "attn", "variant": "flexlike", "n": n, "c": MICRO_C,
            "heads": MICRO_H, "inputs": ["q", "k", "v", "pos"],
            "activations": [0, 1, 2],
        }


for _n in (256, 512, 1024):
    _register_micro(_n)


def _register_fig5(n):
    """Figure 5: fused Pallas kernel vs concat-SDPA graph, C=128 H=8 R=8."""
    c, h, r = 128, 8, 8

    def _qkvf(seed=0):
        ks = jax.random.split(_key(seed), 5)
        return (
            _rand(ks[0], (h, n, c)), _rand(ks[1], (h, n, c)),
            _rand(ks[2], (h, n, c)), _rand(ks[3], (h, n, r), 0.3),
            _rand(ks[4], (h, n, r), 0.3),
        )

    @artifact(f"fig5_pallas_n{n}")
    def _pallas(n=n):
        q, k, v, pq, pk = _qkvf()

        def fn(q, k, v, pq, pk):
            return (common.mha_pallas_factored(q, k, v, pq, pk),)

        return fn, [q, k, v, pq, pk], {
            "family": "fig5", "variant": "pallas", "n": n, "c": c,
            "heads": h, "rank": r,
            "inputs": ["q", "k", "v", "phi_q", "phi_k"],
            "activations": [0, 1, 2],
        }

    @artifact(f"fig5_sdpa_n{n}")
    def _sdpa(n=n):
        q, k, v, pq, pk = _qkvf()

        def fn(q, k, v, pq, pk):
            return (common.mha_sdpa_factored(q, k, v, pq, pk),)

        return fn, [q, k, v, pq, pk], {
            "family": "fig5", "variant": "sdpa", "n": n, "c": c,
            "heads": h, "rank": r,
            "inputs": ["q", "k", "v", "phi_q", "phi_k"],
            "activations": [0, 1, 2],
        }


for _n in (256, 512, 1024):
    _register_fig5(_n)


def _register_causal(n):
    """Table 3 / Table 8 micro path: causal attention + ALiBi variants."""
    h, c = MICRO_H, MICRO_C
    slopes = decomp.alibi_slopes(h)

    @artifact(f"causal_pure_n{n}")
    def _pure(n=n):
        q, k, v = _micro_qkv(n, h, c, seed=3)

        def fn(q, k, v):
            return (common.mha_pallas(q, k, v, causal=True),)

        return fn, [q, k, v], {
            "family": "causal", "variant": "pure", "n": n, "c": c,
            "heads": h, "inputs": ["q", "k", "v"], "activations": [0, 1, 2],
        }

    @artifact(f"causal_alibi_dense_n{n}")
    def _dense(n=n):
        q, k, v = _micro_qkv(n, h, c, seed=3)
        bias = jnp.stack(
            [decomp.alibi_bias(n, n, float(s)) for s in slopes]
        )

        def fn(q, k, v, bias):
            return (common.mha_pallas_dense_bias(q, k, v, bias, causal=True),)

        return fn, [q, k, v, bias], {
            "family": "causal", "variant": "dense", "n": n, "c": c,
            "heads": h, "inputs": ["q", "k", "v", "bias"],
            "activations": [0, 1, 2],
        }

    @artifact(f"causal_alibi_factored_n{n}")
    def _factored(n=n):
        q, k, v = _micro_qkv(n, h, c, seed=3)
        _, pq, pk = gpt2_alibi.alibi_inputs(n, h)

        def fn(q, k, v, pq, pk):
            return (common.mha_pallas_factored(q, k, v, pq, pk, causal=True),)

        return fn, [q, k, v, pq, pk], {
            "family": "causal", "variant": "factored", "n": n, "c": c,
            "heads": h, "rank": 2,
            "inputs": ["q", "k", "v", "phi_q", "phi_k"],
            "activations": [0, 1, 2],
        }

    @artifact(f"causal_alibi_jit_n{n}")
    def _jit(n=n):
        q, k, v = _micro_qkv(n, h, c, seed=3)
        slope_arr = jnp.asarray(slopes, jnp.float32)

        def fn(q, k, v, slope_arr):
            return (
                jax.vmap(
                    lambda a, b, cc, s: fa.flash_attention_alibi_jit(
                        a, b, cc, s, causal=True
                    )
                )(q, k, v, slope_arr),
            )

        return fn, [q, k, v, slope_arr], {
            "family": "causal", "variant": "jit", "n": n, "c": c,
            "heads": h, "inputs": ["q", "k", "v", "slopes"],
            "activations": [0, 1, 2],
        }


for _n in (256, 512):
    _register_causal(_n)


@artifact("mult_factored_n256")
def _mult_factored(n=256):
    """Appendix I: multiplicative cos(i-j) bias, R=2 fused kernel."""
    q, k, v = _micro_qkv(n, 1, MICRO_C, seed=5)
    pq, pk = decomp.cos_mult_factors(n, n)
    pq = pq[None]
    pk = pk[None]

    def fn(q, k, v, pq, pk):
        return (
            jax.vmap(fa.flash_attention_mult_factored)(q, k, v, pq, pk),
        )

    return fn, [q, k, v, pq, pk], {
        "family": "mult", "variant": "factored", "n": n, "c": MICRO_C,
        "heads": 1, "rank": 2,
        "inputs": ["q", "k", "v", "phi_q", "phi_k"], "activations": [0, 1, 2],
    }


@artifact("mult_dense_n256")
def _mult_dense(n=256):
    q, k, v = _micro_qkv(n, 1, MICRO_C, seed=5)
    bias = decomp.cos_mult_bias(n, n)[None]

    def fn(q, k, v, bias):
        from .kernels import ref as kref

        return (
            jax.vmap(kref.attention_multiplicative)(q, k, v, bias),
        )

    return fn, [q, k, v, bias], {
        "family": "mult", "variant": "dense", "n": n, "c": MICRO_C,
        "heads": 1, "inputs": ["q", "k", "v", "bias"], "activations": [0, 1, 2],
    }


# ---------------------------------------------------------------------------
# §4.1 plain Transformer (Figures 3/4)
# ---------------------------------------------------------------------------

PLAIN_D, PLAIN_FF, PLAIN_H, PLAIN_LAYERS = 512, 1024, 8, 8
PLAIN_TRAIN_LAYERS = 2


def _plain_setup(n, num_layers, seed=0):
    params = plain.init(_key(seed), num_layers, PLAIN_D, PLAIN_FF)
    x = _rand(_key(seed + 1), (n, PLAIN_D))
    flat, treedef = _flatten_params(params)
    return params, flat, treedef, x


def _register_plain(n):
    @artifact(f"plain_nobias_n{n}")
    def _nobias(n=n):
        _, flat, treedef, x = _plain_setup(n, PLAIN_LAYERS)

        def fn(*args):
            params = jax.tree_util.tree_unflatten(treedef, args[:-1])
            return (plain.forward(params, args[-1], PLAIN_H),)

        return fn, flat + [x], {
            "family": "plain", "variant": "nobias", "n": n, "c": PLAIN_D,
            "heads": PLAIN_H, "layers": PLAIN_LAYERS,
            "activations": [len(flat)],
        }

    @artifact(f"plain_dense_n{n}")
    def _dense(n=n):
        _, flat, treedef, x = _plain_setup(n, PLAIN_LAYERS)
        bias = _rand(_key(11), (PLAIN_H, n, n), 0.1)

        def fn(*args):
            params = jax.tree_util.tree_unflatten(treedef, args[:-2])
            return (plain.forward(params, args[-2], PLAIN_H, bias=args[-1]),)

        return fn, flat + [x, bias], {
            "family": "plain", "variant": "dense", "n": n, "c": PLAIN_D,
            "heads": PLAIN_H, "layers": PLAIN_LAYERS,
            "activations": [len(flat)],
        }

    @artifact(f"plain_factored_n{n}")
    def _factored(n=n, r=8):
        _, flat, treedef, x = _plain_setup(n, PLAIN_LAYERS)
        ks = jax.random.split(_key(12), 2)
        pq = _rand(ks[0], (PLAIN_H, n, r), 0.3)
        pk = _rand(ks[1], (PLAIN_H, n, r), 0.3)

        def fn(*args):
            params = jax.tree_util.tree_unflatten(treedef, args[:-3])
            return (
                plain.forward(
                    params, args[-3], PLAIN_H, phi_q=args[-2], phi_k=args[-1]
                ),
            )

        return fn, flat + [x, pq, pk], {
            "family": "plain", "variant": "factored", "n": n, "c": PLAIN_D,
            "heads": PLAIN_H, "layers": PLAIN_LAYERS, "rank": r,
            "activations": [len(flat)],
        }

    @artifact(f"plain_flexlike_n{n}")
    def _flexlike(n=n):
        _, flat, treedef, x = _plain_setup(n, PLAIN_LAYERS)
        pos = jnp.arange(n, dtype=jnp.float32)

        def fn(*args):
            params = jax.tree_util.tree_unflatten(treedef, args[:-2])
            return (plain.forward_flexlike(params, args[-2], args[-1],
                                           PLAIN_H),)

        return fn, flat + [x, pos], {
            "family": "plain", "variant": "flexlike", "n": n, "c": PLAIN_D,
            "heads": PLAIN_H, "layers": PLAIN_LAYERS,
            "activations": [len(flat)],
        }


for _n in (256, 512, 1024):
    _register_plain(_n)


def _register_plain_train(n):
    @artifact(f"plain_train_dense_n{n}")
    def _dense(n=n):
        _, flat, treedef, x = _plain_setup(n, PLAIN_TRAIN_LAYERS)
        target = _rand(_key(13), (n, PLAIN_D))
        bias = _rand(_key(14), (PLAIN_H, n, n), 0.1)

        def fn(*args):
            params = jax.tree_util.tree_unflatten(treedef, args[:-3])
            val, _new_params, new_bias = plain.train_step(
                params, args[-3], args[-2], PLAIN_H, bias=args[-1]
            )
            return (val.reshape((1,)), new_bias)

        return fn, flat + [x, target, bias], {
            "family": "plain_train", "variant": "dense", "n": n,
            "c": PLAIN_D, "heads": PLAIN_H, "layers": PLAIN_TRAIN_LAYERS,
            "activations": [len(flat), len(flat) + 1],
        }

    @artifact(f"plain_train_factored_n{n}")
    def _factored(n=n, r=8):
        _, flat, treedef, x = _plain_setup(n, PLAIN_TRAIN_LAYERS)
        target = _rand(_key(13), (n, PLAIN_D))
        ks = jax.random.split(_key(15), 2)
        pq = _rand(ks[0], (PLAIN_H, n, r), 0.3)
        pk = _rand(ks[1], (PLAIN_H, n, r), 0.3)

        def fn(*args):
            params = jax.tree_util.tree_unflatten(treedef, args[:-4])
            val, _new_params, new_pq, new_pk = plain.train_step(
                params, args[-4], args[-3], PLAIN_H, phi_q=args[-2],
                phi_k=args[-1],
            )
            return (val.reshape((1,)), new_pq, new_pk)

        return fn, flat + [x, target, pq, pk], {
            "family": "plain_train", "variant": "factored", "n": n,
            "c": PLAIN_D, "heads": PLAIN_H, "layers": PLAIN_TRAIN_LAYERS,
            "rank": r, "activations": [len(flat), len(flat) + 1],
        }


for _n in (256, 512):
    _register_plain_train(_n)


# ---------------------------------------------------------------------------
# §4.2 GPT-2 + ALiBi (Table 3)
# ---------------------------------------------------------------------------

GPT_V, GPT_LAYERS, GPT_D, GPT_FF, GPT_H = 512, 4, 256, 1024, 8


def _gpt_setup(n, seed=0):
    params = gpt2_alibi.init(_key(seed), GPT_V, GPT_LAYERS, GPT_D, GPT_FF)
    tokens = jax.random.randint(_key(seed + 1), (n,), 0, GPT_V, jnp.int32)
    flat, treedef = _flatten_params(params)
    return params, flat, treedef, tokens


def _register_gpt(n):
    @artifact(f"gpt2_pure_n{n}")
    def _pure(n=n):
        _, flat, treedef, tokens = _gpt_setup(n)

        def fn(*args):
            params = jax.tree_util.tree_unflatten(treedef, args[:-1])
            return (gpt2_alibi.forward(params, args[-1], GPT_H, mode="pure",
                                       attn="pallas"),)

        return fn, flat + [tokens], {
            "family": "gpt2", "variant": "pure", "n": n, "c": GPT_D,
            "heads": GPT_H, "layers": GPT_LAYERS, "vocab": GPT_V,
            "activations": [len(flat)],
        }

    @artifact(f"gpt2_dense_n{n}")
    def _dense(n=n):
        _, flat, treedef, tokens = _gpt_setup(n)
        dense, _, _ = gpt2_alibi.alibi_inputs(n, GPT_H)

        def fn(*args):
            params = jax.tree_util.tree_unflatten(treedef, args[:-2])
            return (
                gpt2_alibi.forward(params, args[-2], GPT_H, mode="dense",
                                   bias=args[-1], attn="pallas"),
            )

        return fn, flat + [tokens, dense], {
            "family": "gpt2", "variant": "dense", "n": n, "c": GPT_D,
            "heads": GPT_H, "layers": GPT_LAYERS, "vocab": GPT_V,
            "activations": [len(flat)],
        }

    @artifact(f"gpt2_factored_n{n}")
    def _factored(n=n):
        _, flat, treedef, tokens = _gpt_setup(n)
        _, pq, pk = gpt2_alibi.alibi_inputs(n, GPT_H)

        def fn(*args):
            params = jax.tree_util.tree_unflatten(treedef, args[:-3])
            return (
                gpt2_alibi.forward(params, args[-3], GPT_H, mode="factored",
                                   phi_q=args[-2], phi_k=args[-1],
                                   attn="pallas"),
            )

        return fn, flat + [tokens, pq, pk], {
            "family": "gpt2", "variant": "factored", "n": n, "c": GPT_D,
            "heads": GPT_H, "layers": GPT_LAYERS, "vocab": GPT_V, "rank": 2,
            "activations": [len(flat)],
        }


for _n in (256, 512):
    _register_gpt(_n)


# ---------------------------------------------------------------------------
# §4.3 Swin window attention (Table 4)
# ---------------------------------------------------------------------------

SWIN_WINDOW = (12, 12)          # N = 144 (paper: 24² = 576, scaled)
SWIN_LAYERS, SWIN_D, SWIN_FF, SWIN_H = 4, 128, 256, 4
SWIN_CLASSES, SWIN_PATCH = 10, 16
SWIN_FACTORED_FROM = 2          # paper's "last layers only" policy
SWIN_RANK = 16


def _swin_setup(seed=0):
    n = SWIN_WINDOW[0] * SWIN_WINDOW[1]
    biases = np.stack(
        [decomp.swin_relative_bias(SWIN_WINDOW, SWIN_H, seed=seed + li)
         for li in range(SWIN_LAYERS)]
    )
    params = swin.init(
        _key(seed), SWIN_LAYERS, SWIN_D, SWIN_FF, SWIN_WINDOW, SWIN_H,
        SWIN_CLASSES, SWIN_PATCH, biases=biases,
    )
    patches = _rand(_key(seed + 9), (n, SWIN_PATCH))
    flat, treedef = _flatten_params(params)
    return params, flat, treedef, patches, biases


@artifact("swin_dense")
def _swin_dense():
    _, flat, treedef, patches, _ = _swin_setup()

    def fn(*args):
        params = jax.tree_util.tree_unflatten(treedef, args[:-1])
        return (swin.forward(params, args[-1], SWIN_H),)

    return fn, flat + [patches], {
        "family": "swin", "variant": "dense",
        "n": SWIN_WINDOW[0] * SWIN_WINDOW[1], "c": SWIN_D, "heads": SWIN_H,
        "layers": SWIN_LAYERS, "activations": [len(flat)],
    }


@artifact("swin_factored")
def _swin_factored():
    params, flat, treedef, patches, biases = _swin_setup()
    fqs, fks = [], []
    for li in range(SWIN_FACTORED_FROM, SWIN_LAYERS):
        fq_h, fk_h = [], []
        for h in range(SWIN_H):
            pq, pk = decomp.svd_factors(jnp.asarray(biases[li, h]),
                                        SWIN_RANK)
            fq_h.append(pq)
            fk_h.append(pk)
        fqs.append(jnp.stack(fq_h))
        fks.append(jnp.stack(fk_h))
    fqs = jnp.stack(fqs)  # (L', H, N, R)
    fks = jnp.stack(fks)

    def fn(*args):
        params = jax.tree_util.tree_unflatten(treedef, args[:-3])
        return (
            swin.forward(params, args[-3], SWIN_H, factor_qs=args[-2],
                         factor_ks=args[-1],
                         factored_from=SWIN_FACTORED_FROM),
        )

    return fn, flat + [patches, fqs, fks], {
        "family": "swin", "variant": "factored",
        "n": SWIN_WINDOW[0] * SWIN_WINDOW[1], "c": SWIN_D, "heads": SWIN_H,
        "layers": SWIN_LAYERS, "rank": SWIN_RANK,
        "factored_from": SWIN_FACTORED_FROM, "activations": [len(flat)],
    }


# ---------------------------------------------------------------------------
# §4.4 PDE solver (Tables 5 / 11)
# ---------------------------------------------------------------------------

PDE_LAYERS, PDE_D, PDE_FF, PDE_H = 2, 128, 256, 8


def _pde_setup(n, seed=0):
    params = pde.init(_key(seed), n, PDE_LAYERS, PDE_D, PDE_FF, PDE_H)
    positions = jnp.asarray(pde.synthetic_car_cloud(n, seed))
    flat, treedef = _flatten_params(params)
    return params, flat, treedef, positions


def _register_pde(n):
    for mode in ("nobias", "dense", "factored"):
        @artifact(f"pde_{mode}_n{n}")
        def _fwd(n=n, mode=mode):
            _, flat, treedef, positions = _pde_setup(n)

            def fn(*args):
                params = jax.tree_util.tree_unflatten(treedef, args[:-1])
                return (pde.forward(params, args[-1], PDE_H, mode=mode),)

            return fn, flat + [positions], {
                "family": "pde", "variant": mode, "n": n, "c": PDE_D,
                "heads": PDE_H, "layers": PDE_LAYERS,
                "rank": 9 if mode == "factored" else None,
                "activations": [len(flat)],
            }


for _n in (512, 1024, 2048):
    _register_pde(_n)


def _register_pde_train(n):
    for mode in ("dense", "factored"):
        @artifact(f"pde_train_{mode}_n{n}")
        def _train(n=n, mode=mode):
            _, flat, treedef, positions = _pde_setup(n)
            target = jnp.asarray(pde.synthetic_fields(positions))

            def fn(*args):
                params = jax.tree_util.tree_unflatten(treedef, args[:-2])
                val, new = pde.train_step(params, args[-2], args[-1], PDE_H,
                                          mode=mode)
                # return the α gradient-updated weights (the dense-vs-
                # factored gradient traffic the paper measures)
                return (val.reshape((1,)), new.alphas)

            return fn, flat + [positions, target], {
                "family": "pde_train", "variant": mode, "n": n, "c": PDE_D,
                "heads": PDE_H, "layers": PDE_LAYERS,
                "activations": [len(flat), len(flat) + 1],
            }


for _n in (512, 1024):
    _register_pde_train(_n)


# ---------------------------------------------------------------------------
# §4.4 Pairformer (Tables 6/9/10, Figure 7)
# ---------------------------------------------------------------------------

PAIR_N, PAIR_LAYERS, PAIR_D, PAIR_FF = 128, 2, 64, 128
PAIR_CZ, PAIR_H, PAIR_RANK = 8, 4, 16
PAIR_NEURAL_STEPS = 400


def _pair_setup(seed=0):
    params = pairformer.init(_key(seed), PAIR_LAYERS, PAIR_D, PAIR_FF,
                             PAIR_CZ)
    single = _rand(_key(seed + 1), (PAIR_N, PAIR_D))
    z = pairformer.synthetic_pair_rep(_key(seed + 2), PAIR_N, PAIR_CZ)
    flat, treedef = _flatten_params(params)
    return params, flat, treedef, single, z


@artifact("pairformer_dense")
def _pair_dense():
    _, flat, treedef, single, z = _pair_setup()

    def fn(*args):
        params = jax.tree_util.tree_unflatten(treedef, args[:-2])
        return (
            pairformer.forward(params, args[-2], args[-1], PAIR_H,
                               mode="dense"),
        )

    return fn, flat + [single, z], {
        "family": "pairformer", "variant": "dense", "n": PAIR_N,
        "c": PAIR_D, "heads": PAIR_H, "layers": PAIR_LAYERS,
        "c_z": PAIR_CZ, "activations": [len(flat), len(flat) + 1],
    }


@artifact("pairformer_neural")
def _pair_neural():
    """Neural decomposition: φ̂ nets trained offline (Eq. 5) at AOT time,
    their weights becoming ordinary inputs of the lowered graph."""
    params, flat, treedef, single, z = _pair_setup()
    factor_params = pairformer.train_factor_nets(
        params, single, z, PAIR_H, PAIR_RANK, hidden=64,
        steps=PAIR_NEURAL_STEPS,
    )
    fp_flat, fp_treedef = jax.tree_util.tree_flatten(factor_params)
    n_fp = len(fp_flat)

    def fn(*args):
        params = jax.tree_util.tree_unflatten(treedef,
                                              args[:-(2 + n_fp)])
        fps = jax.tree_util.tree_unflatten(fp_treedef, args[-(2 + n_fp):-2])
        return (
            pairformer.forward(params, args[-2], args[-1], PAIR_H,
                               mode="neural", factor_params=fps,
                               rank=PAIR_RANK),
        )

    return fn, flat + list(fp_flat) + [single, z], {
        "family": "pairformer", "variant": "neural", "n": PAIR_N,
        "c": PAIR_D, "heads": PAIR_H, "layers": PAIR_LAYERS,
        "c_z": PAIR_CZ, "rank": PAIR_RANK,
        "activations": [len(flat) + n_fp, len(flat) + n_fp + 1],
    }


# ---------------------------------------------------------------------------
# default artifact set (what `make artifacts` builds)
# ---------------------------------------------------------------------------

# Keep the default build bounded: micro-ops at all sizes, model variants at
# their headline sizes. Everything else is available via --only.
DEFAULT_SET = [
    "attn_pure_n256", "attn_dense_n256", "attn_factored_n256",
    "attn_flexlike_n256",
    "attn_pure_n512", "attn_dense_n512", "attn_factored_n512",
    "attn_flexlike_n512",
    "attn_pure_n1024", "attn_dense_n1024", "attn_factored_n1024",
    "attn_flexlike_n1024",
    "fig5_pallas_n256", "fig5_sdpa_n256",
    "fig5_pallas_n512", "fig5_sdpa_n512",
    "causal_pure_n256", "causal_alibi_dense_n256",
    "causal_alibi_factored_n256", "causal_alibi_jit_n256",
    "causal_pure_n512", "causal_alibi_dense_n512",
    "causal_alibi_factored_n512", "causal_alibi_jit_n512",
    "mult_factored_n256", "mult_dense_n256",
    "plain_nobias_n256", "plain_dense_n256", "plain_factored_n256",
    "plain_flexlike_n256",
    "plain_nobias_n512", "plain_dense_n512", "plain_factored_n512",
    "plain_flexlike_n512",
    "plain_train_dense_n256", "plain_train_factored_n256",
    "gpt2_pure_n256", "gpt2_dense_n256", "gpt2_factored_n256",
    "swin_dense", "swin_factored",
    "pde_nobias_n512", "pde_dense_n512", "pde_factored_n512",
    "pde_train_dense_n512", "pde_train_factored_n512",
    "pairformer_dense", "pairformer_neural",
]
