"""AOT lowering driver: python runs ONCE here, never on the request path.

For every artifact declared in ``model.py`` this script:

1. builds the jax function + concrete example inputs,
2. lowers ``jax.jit(fn)`` to StableHLO and converts it to **HLO text**
   (the interchange format — the image's xla_extension 0.5.1 rejects
   jax≥0.5 serialized protos with 64-bit instruction ids; the text parser
   reassigns ids, see /opt/xla-example/README.md),
3. dumps every example input as a raw little-endian binary so the rust
   runtime can execute the artifact without knowing the model structure,
4. compiles + runs the lowered computation on XLA:CPU and dumps the
   outputs — the rust integration tests replay the artifact and require
   bit-identical results,
5. writes ``artifacts/manifest.json`` describing all of it.

Usage:
    python -m compile.aot --out-dir ../artifacts [--only REGEX] [--no-outputs]
"""

from __future__ import annotations

import argparse
import json
import re
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_registry

DTYPE_NAMES = {
    np.dtype(np.float32): "f32",
    np.dtype(np.int32): "i32",
}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    rust side always unwraps a tuple, see load_hlo.rs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def dump_array(arr: np.ndarray, path: Path) -> dict:
    arr = np.ascontiguousarray(arr)
    if arr.dtype not in DTYPE_NAMES:
        raise ValueError(f"unsupported dtype {arr.dtype} for {path}")
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_bytes(arr.tobytes())
    return {
        "shape": list(arr.shape),
        "dtype": DTYPE_NAMES[arr.dtype],
    }


def build_one(name: str, builder, out_dir: Path, run_outputs: bool) -> dict:
    t0 = time.time()
    fn, example_inputs, meta = builder()
    example_inputs = [np.asarray(a) for a in example_inputs]
    specs = [
        jax.ShapeDtypeStruct(a.shape, a.dtype) for a in example_inputs
    ]
    # keep_unused=True: the manifest promises one HLO parameter per input,
    # even for inputs a variant does not read (e.g. dense-bias tables in a
    # factored variant) — the rust loader feeds them all.
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    hlo_text = to_hlo_text(lowered)
    hlo_rel = f"hlo/{name}.hlo.txt"
    hlo_path = out_dir / hlo_rel
    hlo_path.parent.mkdir(parents=True, exist_ok=True)
    hlo_path.write_text(hlo_text)

    inputs_meta = []
    for i, arr in enumerate(example_inputs):
        rel = f"inputs/{name}/{i}.bin"
        info = dump_array(arr, out_dir / rel)
        info["file"] = rel
        inputs_meta.append(info)

    outputs_meta = []
    if run_outputs:
        compiled = lowered.compile()
        outs = compiled(*example_inputs)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        for i, arr in enumerate(outs):
            arr = np.asarray(arr)
            rel = f"outputs/{name}/{i}.bin"
            info = dump_array(arr, out_dir / rel)
            info["file"] = rel
            outputs_meta.append(info)

    dt = time.time() - t0
    print(f"  {name}: hlo {len(hlo_text) // 1024}KB, "
          f"{len(inputs_meta)} inputs, {len(outputs_meta)} outputs "
          f"[{dt:.1f}s]", flush=True)
    return {
        "name": name,
        "hlo": hlo_rel,
        "inputs": inputs_meta,
        "outputs": outputs_meta,
        "meta": meta,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="regex over artifact names (overrides DEFAULT_SET)")
    ap.add_argument("--no-outputs", action="store_true",
                    help="skip running the computations for expected outputs")
    args = ap.parse_args()

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    registry = model_registry.registry()
    if args.only:
        pat = re.compile(args.only)
        names = [n for n in registry if pat.search(n)]
    else:
        names = [n for n in model_registry.DEFAULT_SET if n in registry]
    missing = [n for n in model_registry.DEFAULT_SET if n not in registry]
    if missing:
        print(f"WARNING: DEFAULT_SET names missing from registry: {missing}")

    print(f"lowering {len(names)} artifacts -> {out_dir}")
    entries = []
    t0 = time.time()
    for name in names:
        entries.append(
            build_one(name, registry[name], out_dir, not args.no_outputs)
        )

    manifest = {
        "format": 1,
        "jax_version": jax.__version__,
        "artifacts": entries,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    # stamp for make dependency tracking
    (out_dir / ".stamp").write_text(str(time.time()))
    print(f"done: {len(entries)} artifacts in {time.time() - t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
