"""§4.4 AlphaFold-3-style Pairformer block with pair-representation bias
(Tables 6, 9, 10; Figure 7).

The efficiency bottleneck in AF3 is triangle self-attention: the bias is
*projected from the intermediate pair representation* z ∈ R^{N×N×Cz}, so
it varies per sample/layer/head and only the neural decomposition
(Table 1c) applies. Following Appendix H Table 12, the factor nets φ̂ take
the combination of pair-representation row/column sums and the single
representation, and emit per-head rank-R strips.

Block structure (scaled Protenix-like):
    triangle self-attention (rows)  — bias from pair rep
    triangle multiplication (outgoing) — kept dense (cubic, not attention)
    single attention with pair bias
    transition (FFN)

Variants: ``dense`` projects b = linear(z) per head (quadratic HBM
object); ``neural`` replaces it with φ̂_q(x) φ̂_k(x)ᵀ where the MLP weights
were trained offline (Eq. 5) and baked into the artifact.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import common
from .. import decomp


class PairformerParams(NamedTuple):
    layers: list              # common.LayerParams for the single track
    pair_proj: jnp.ndarray    # (L, Cz, H) bias projection from pair rep
    tri_mul_in: jnp.ndarray   # (L, Cz, Cz) triangle multiplication proj a
    tri_mul_out: jnp.ndarray  # (L, Cz, Cz)
    tri_gate: jnp.ndarray     # (L, Cz, Cz)


def init(key, num_layers=2, d_model=64, d_ff=128, c_z=8):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    layers = [
        common.layer_init(k, d_model, d_ff)
        for k in jax.random.split(k1, num_layers)
    ]
    num_heads = 4
    s = 1.0 / math.sqrt(c_z)
    return PairformerParams(
        layers=layers,
        pair_proj=jax.random.normal(k2, (num_layers, c_z, num_heads),
                                    jnp.float32) * s,
        tri_mul_in=jax.random.normal(k3, (num_layers, c_z, c_z), jnp.float32)
        * s,
        tri_mul_out=jax.random.normal(k4, (num_layers, c_z, c_z),
                                      jnp.float32) * s,
        tri_gate=jax.random.normal(k5, (num_layers, c_z, c_z), jnp.float32)
        * s,
    )


def pair_bias(z, proj):
    """b (H, N, N) = per-head linear projection of the pair rep (N,N,Cz)."""
    return jnp.einsum("nmc,ch->hnm", z, proj)


def factor_inputs(z, single):
    """Appendix H Table 12: x_q = x_k = [row-sum(z) + col-sum(z) | single]."""
    row = z.mean(axis=1)  # (N, Cz)
    col = z.mean(axis=0)  # (N, Cz)
    return jnp.concatenate([row + col, single], axis=-1)


def triangle_multiplication(z, w_in, w_out, w_gate):
    """Simplified outgoing triangle multiplication (cubic component)."""
    a = jnp.einsum("nmc,cd->nmd", z, w_in)
    b = jnp.einsum("nmc,cd->nmd", z, w_out)
    upd = jnp.einsum("nkc,mkc->nmc", a, b) / z.shape[0]
    gate = jax.nn.sigmoid(jnp.einsum("nmc,cd->nmd", z, w_gate))
    return z + gate * upd


def forward(params: PairformerParams, single, z, num_heads=4, *,
            mode="dense", factor_params=None, rank=16, attn="sdpa"):
    """single: (N, D); z: (N, N, Cz). Returns updated single rep (N, D).

    mode="dense": bias projected from z per layer (the O(N²) stream).
    mode="neural": FlashBias neural decomposition — factor_params is a
    list per layer of (MlpParams_q, MlpParams_k) emitting (N, H·R).
    """
    n = single.shape[0]
    for li, p in enumerate(params.layers):
        z = triangle_multiplication(
            z, params.tri_mul_in[li], params.tri_mul_out[li],
            params.tri_gate[li],
        )
        if mode == "dense":
            bias = pair_bias(z, params.pair_proj[li])
            single = common.transformer_layer(
                p, single, num_heads, bias=bias, attn=attn
            )
        else:
            pq_params, pk_params = factor_params[li]
            x = factor_inputs(z, single)
            fq = decomp.mlp_apply(pq_params, x).reshape(n, num_heads, rank)
            fk = decomp.mlp_apply(pk_params, x).reshape(n, num_heads, rank)
            single = common.transformer_layer(
                p, single, num_heads,
                phi_q=fq.transpose(1, 0, 2), phi_k=fk.transpose(1, 0, 2),
                attn=attn,
            )
    return single


def train_factor_nets(params: PairformerParams, single, z, num_heads=4,
                      rank=16, hidden=64, steps=600, seed=0):
    """Offline neural decomposition (Eq. 5) per layer against the dense
    pair bias actually produced on this input distribution."""
    factor_params = []
    zi = z
    for li in range(len(params.layers)):
        zi = triangle_multiplication(
            zi, params.tri_mul_in[li], params.tri_mul_out[li],
            params.tri_gate[li],
        )
        target = pair_bias(zi, params.pair_proj[li])  # (H, N, N)
        x = factor_inputs(zi, single)
        h, n, _ = target.shape

        def tgt_fn(xq, xk, target=target, h=h, n=n):
            # stack heads into one (N, H·N) problem → factor nets emit H·R
            return target.transpose(1, 0, 2).reshape(n, h * n)

        # train one net pair emitting (N, H*R) against blocked target
        pq, pk, _ = _train_multihead(x, target, rank, hidden, steps,
                                     seed + li)
        factor_params.append((pq, pk))
    return factor_params


def _train_multihead(x, target, rank, hidden, steps, seed):
    """Fit φ̂ emitting (N, H·R) such that per-head strips reconstruct the
    per-head bias. Plain Adam on Eq. (5) summed over heads."""
    h, n, _ = target.shape
    key = jax.random.PRNGKey(seed)
    kq, kk = jax.random.split(key)
    pq = decomp.mlp_init(kq, x.shape[-1], hidden, h * rank)
    pk = decomp.mlp_init(kk, x.shape[-1], hidden, h * rank)

    def loss_fn(ps):
        pq, pk = ps
        fq = decomp.mlp_apply(pq, x).reshape(n, h, rank)
        fk = decomp.mlp_apply(pk, x).reshape(n, h, rank)
        approx = jnp.einsum("nhr,mhr->hnm", fq, fk)
        return jnp.mean((approx - target) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    ps = (pq, pk)
    m_s = jax.tree_util.tree_map(jnp.zeros_like, ps)
    v_s = jax.tree_util.tree_map(jnp.zeros_like, ps)
    losses = []
    for step in range(1, steps + 1):
        val, grads = grad_fn(ps)
        losses.append(float(val))
        flat_p, tree = jax.tree_util.tree_flatten(ps)
        flat = zip(
            flat_p,
            jax.tree_util.tree_leaves(grads),
            jax.tree_util.tree_leaves(m_s),
            jax.tree_util.tree_leaves(v_s),
        )
        new_p, new_m, new_v = [], [], []
        for p, g, mm, vv in flat:
            upd, mm, vv = decomp._adam_update(g, mm, vv, step, 1e-3)
            new_p.append(p + upd)
            new_m.append(mm)
            new_v.append(vv)
        ps = jax.tree_util.tree_unflatten(tree, new_p)
        m_s = jax.tree_util.tree_unflatten(tree, new_m)
        v_s = jax.tree_util.tree_unflatten(tree, new_v)
    return ps[0], ps[1], losses


def synthetic_pair_rep(key, n, c_z=8):
    """Synthetic smooth pair representation: low-rank structure + local
    texture, mimicking Figure 7's observed bias statistics."""
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.normal(k1, (n, 4), jnp.float32)
    w = jax.random.normal(k2, (4, 4, c_z), jnp.float32) * 0.5
    smooth = jnp.einsum("na,mb,abc->nmc", u, u, w) / 4.0
    idx = jnp.arange(n, dtype=jnp.float32)
    locality = jnp.exp(-jnp.abs(idx[:, None] - idx[None, :]) / (n / 8.0))
    noise = jax.random.normal(k3, (n, n, c_z), jnp.float32) * 0.05
    return smooth + locality[:, :, None] * 0.5 + noise
