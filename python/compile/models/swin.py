"""§4.3 Swin-V2-style window attention with learned relative-position bias
(Table 4 / Figures 6, 8, 9 workload).

The real SwinV2-B has 24 layers at window 24² (N = 576); the bias of each
WindowAttention is a learned (H, 576, 576) parameter. We reproduce the
experiment's *mechanism*: a stack of window-attention layers whose biases
are synthetic "trained" tables with the paper's observed spectral decay
(decomp.swin_relative_bias), truncated by SVD at a target energy and
folded in via FlashBias.

A small classifier head on top lets Table 4's accuracy-preservation claim
be checked end-to-end on synthetic images.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import common


class SwinParams(NamedTuple):
    patch_proj: jnp.ndarray   # (P, D) patch embedding
    layers: list              # LayerParams per block
    biases: jnp.ndarray       # (L, H, N, N) learned relative-position bias
    ln_f: tuple
    head: jnp.ndarray         # (D, num_classes)


def init(key, num_layers=4, d_model=128, d_ff=256, window=(8, 8),
         num_heads=4, num_classes=10, patch_dim=16, biases=None):
    n = window[0] * window[1]
    k1, k2, k3, k4 = jax.random.split(key, 4)
    layers = [
        common.layer_init(k, d_model, d_ff)
        for k in jax.random.split(k2, num_layers)
    ]
    if biases is None:
        biases = (
            jax.random.normal(k4, (num_layers, num_heads, n, n), jnp.float32)
            * 0.1
        )
    return SwinParams(
        patch_proj=jax.random.normal(k1, (patch_dim, d_model), jnp.float32)
        / math.sqrt(patch_dim),
        layers=layers,
        biases=jnp.asarray(biases, jnp.float32),
        ln_f=(jnp.ones((d_model,)), jnp.zeros((d_model,))),
        head=jax.random.normal(k3, (d_model, num_classes), jnp.float32)
        * 0.02,
    )


def forward(params: SwinParams, patches, num_heads=4, *, factor_qs=None,
            factor_ks=None, factored_from: int = 0, attn="sdpa"):
    """patches: (N, P). When factor tensors are given, layers ≥
    ``factored_from`` use FlashBias and earlier layers keep the dense bias —
    the paper's "last 8 layers only" deployment policy (§4.3).
    """
    x = patches @ params.patch_proj
    for li, p in enumerate(params.layers):
        if factor_qs is not None and li >= factored_from:
            x = common.transformer_layer(
                p, x, num_heads,
                phi_q=factor_qs[li - factored_from],
                phi_k=factor_ks[li - factored_from],
                attn=attn,
            )
        else:
            x = common.transformer_layer(
                p, x, num_heads, bias=params.biases[li], attn=attn
            )
    x = common.layer_norm(x, *params.ln_f)
    return x.mean(axis=0) @ params.head


def window_attention(q, k, v, bias):
    """Single WindowAttention op (per-window), for micro benches."""
    return common.mha_sdpa(q, k, v, bias=bias)
