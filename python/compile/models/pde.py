"""§4.4 Transformer PDE solver with weighted 3D spatial-distance bias
(Table 5 / Table 11 workload; Example 3.5).

Input: positions of computation mesh points (N, 3); output: physics
quantities (pressure + velocity) at those points. Every head of every
layer adds the bias f(x_i, x_j) = −α_i‖x_i − x_j‖² with a *learnable*
token-wise weight α (the adaptive-mesh approximation), so the training
phase needs gradients through the bias — the paper's hardest efficiency
case (dense methods must store an N×N gradient per head).

``dense`` variants materialize the (H, N, N) bias in-graph from positions
(what OOMs in Table 5); ``factored`` uses the exact rank-9 decomposition,
keeping everything O(N·R).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import common
from .. import decomp


class PdeParams(NamedTuple):
    in_proj: jnp.ndarray      # (3, D)
    layers: list
    alphas: jnp.ndarray       # (L, H, N) learnable bias weights (token-wise)
    out_proj: jnp.ndarray     # (D, 4) pressure + 3 velocity components


def init(key, n_points, num_layers=2, d_model=128, d_ff=256, num_heads=8):
    k1, k2, k3 = jax.random.split(key, 3)
    layers = [
        common.layer_init(k, d_model, d_ff)
        for k in jax.random.split(k2, num_layers)
    ]
    return PdeParams(
        in_proj=jax.random.normal(k1, (3, d_model), jnp.float32)
        / math.sqrt(3.0),
        layers=layers,
        alphas=jnp.ones((num_layers, num_heads, n_points), jnp.float32),
        out_proj=jax.random.normal(k3, (d_model, 4), jnp.float32) * 0.02,
    )


def _head_bias_dense(positions, alphas_lh):
    """(H, N, N) dense bias from positions — the quadratic object."""
    return jnp.stack(
        [decomp.spatial_bias(positions, positions, alphas_lh[h])
         for h in range(alphas_lh.shape[0])]
    )


def _head_factors(positions, alphas_lh):
    fq, fk = [], []
    for h in range(alphas_lh.shape[0]):
        pq, pk = decomp.spatial_factors(positions, positions, alphas_lh[h])
        fq.append(pq)
        fk.append(pk)
    return jnp.stack(fq), jnp.stack(fk)


def forward(params: PdeParams, positions, num_heads=8, *, mode="factored",
            attn="sdpa"):
    """positions: (N, 3) → (N, 4) physics fields."""
    x = positions @ params.in_proj
    for li, p in enumerate(params.layers):
        if mode == "dense":
            bias = _head_bias_dense(positions, params.alphas[li])
            x = common.transformer_layer(p, x, num_heads, bias=bias,
                                          attn=attn)
        elif mode == "factored":
            pq, pk = _head_factors(positions, params.alphas[li])
            x = common.transformer_layer(p, x, num_heads, phi_q=pq,
                                          phi_k=pk, attn=attn)
        else:  # "nobias" ablation (Table 11 first row)
            x = common.transformer_layer(p, x, num_heads, attn=attn)
    return x @ params.out_proj


def loss(params, positions, target, num_heads=8, mode="factored"):
    pred = forward(params, positions, num_heads, mode=mode)
    return jnp.mean((pred - target) ** 2)


def train_step(params, positions, target, num_heads=8, lr=1e-3,
               mode="factored"):
    """One SGD step *including* the α gradient — the Table 5 training
    workload. In dense mode autodiff stores the (H, N, N) bias per layer,
    in factored mode only (N, R) strips."""
    val, grads = jax.value_and_grad(loss)(params, positions, target,
                                          num_heads, mode)
    new = jax.tree_util.tree_map(lambda w, g: w - lr * g, params, grads)
    return val, new


def synthetic_car_cloud(n: int, seed: int = 0):
    """Parametric car-like hull point cloud (DrivAer stand-in).

    Half-ellipsoid body + cabin bump + wheel clusters, with surface noise.
    Returns float32 (n, 3) in a unit-ish box.
    """
    import numpy as np

    rng = np.random.default_rng(seed)
    u = rng.uniform(0, 1, n)
    t = rng.uniform(0, 2 * np.pi, n)
    # body: elongated ellipsoid surface
    x = 4.0 * (u - 0.5)
    ry = 0.8 * np.sqrt(np.clip(1 - (2 * u - 1) ** 2, 0, 1)) + 0.05
    y = ry * np.cos(t)
    z = 0.5 * ry * np.abs(np.sin(t))
    # cabin bump over the mid-section
    cabin = np.exp(-((x - 0.2) ** 2) / 0.5)
    z = z + 0.35 * cabin * np.clip(np.sin(t), 0, 1)
    # wheels: four clusters pulled down
    for wx in (-1.2, 1.2):
        for wy in (-0.6, 0.6):
            d = (x - wx) ** 2 + (y - wy) ** 2
            z = np.where(d < 0.08, -0.2 + 0.1 * rng.uniform(size=n), z)
    pts = np.stack([x, y, z], -1) + 0.01 * rng.normal(size=(n, 3))
    return np.asarray(pts, np.float32)


def synthetic_fields(positions, seed: int = 0):
    """Smooth synthetic pressure/velocity targets over the cloud."""
    import numpy as np

    p = np.asarray(positions)
    pr = np.tanh(p[:, 0]) * np.exp(-p[:, 2] ** 2)
    vel = np.stack(
        [np.sin(p[:, 0]), np.cos(p[:, 1]) * 0.3, p[:, 2] * 0.1], -1
    )
    return np.asarray(np.concatenate([pr[:, None], vel], -1), np.float32)
