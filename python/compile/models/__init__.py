"""L2 model zoo for the FlashBias reproduction."""
