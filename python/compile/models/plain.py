"""§4.1 plain Transformer: 8 layers, 512 model channels, 8 heads, 1024 FFN,
one static (H, N, N) bias shared across layers — the overall-comparison
workload of Figures 3/4/5.

Variants lowered by aot.py:
  * ``nobias``   — "Pure FlashAttention" upper bound.
  * ``dense``    — bias passed as a dense (H, N, N) input ("FlashAttention
    with Bias": the whole quadratic tensor crosses HBM).
  * ``factored`` — FlashBias: (H, N, R) factor inputs, concat trick.
  * ``flexlike`` — FlexAttention stand-in: the bias is *computed
    element-wise inside the graph* from per-token sources (no dense input,
    but O(N·M) element-wise work that cannot use the MXU).

A 2-layer ``train`` variant lowers value_and_grad + SGD for the training
columns of Figure 3.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common


def init(key, num_layers=8, d_model=512, d_ff=1024):
    keys = jax.random.split(key, num_layers)
    return [common.layer_init(k, d_model, d_ff) for k in keys]


def forward(params, x, num_heads=8, *, bias=None, phi_q=None, phi_k=None,
            attn="sdpa"):
    for p in params:
        x = common.transformer_layer(
            p, x, num_heads, bias=bias, phi_q=phi_q, phi_k=phi_k, attn=attn
        )
    return x


def flexlike_bias(xsrc_q, xsrc_k, scale):
    """Element-wise in-graph bias: -scale * |i - j| from position inputs.

    Mirrors what FlexAttention's score_mod compiles to — a full (N, M)
    element-wise computation that is never a matmul.
    """
    return -scale * jnp.abs(xsrc_q[:, None] - xsrc_k[None, :])


def forward_flexlike(params, x, positions, num_heads=8, scale=0.05):
    h_bias = jnp.stack(
        [flexlike_bias(positions, positions, scale * (h + 1))
         for h in range(num_heads)]
    )
    return forward(params, x, num_heads, bias=h_bias)


def loss(params, x, target, num_heads=8, **kw):
    out = forward(params, x, num_heads, **kw)
    return jnp.mean((out - target) ** 2)


def train_step(params, x, target, num_heads=8, lr=1e-3, *, bias=None,
               phi_q=None, phi_k=None):
    """One SGD step; lowered as the Figure-3 training-phase workload.

    When ``bias`` is given it is treated as a *learnable* input: its
    gradient is computed and returned (the dense O(N²) gradient traffic
    the paper calls out in §4.4). With factors, only (N, R) gradients flow.
    """
    if bias is not None:
        def f(p, b):
            return loss(p, x, target, num_heads, bias=b)

        (val, (gp, gb)) = jax.value_and_grad(f, argnums=(0, 1))(params, bias)
        new_params = jax.tree_util.tree_map(lambda w, g: w - lr * g, params, gp)
        return val, new_params, bias - lr * gb
    if phi_q is not None:
        def f(p, pq, pk):
            return loss(p, x, target, num_heads, phi_q=pq, phi_k=pk)

        (val, (gp, gq, gk)) = jax.value_and_grad(f, argnums=(0, 1, 2))(
            params, phi_q, phi_k
        )
        new_params = jax.tree_util.tree_map(lambda w, g: w - lr * g, params, gp)
        return val, new_params, phi_q - lr * gq, phi_k - lr * gk

    val, gp = jax.value_and_grad(loss)(params, x, target, num_heads)
    new_params = jax.tree_util.tree_map(lambda w, g: w - lr * g, params, gp)
    return val, new_params
