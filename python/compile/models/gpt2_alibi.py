"""§4.2 GPT-2-shaped decoder-only LM with ALiBi bias (Table 3 workload).

The paper's model is 48 layers / 1600 channels / 50 heads (1.5B params);
Table 3 measures the *bias-processing overhead* Δ = time(with-bias) −
time(pure-causal), which is a property of the attention path, so we keep
the exact layer structure (causal mask + per-head ALiBi slopes + LM head)
at scaled dimensions (see DESIGN.md substitutions).

Variants:
  * ``pure``     — causal attention, no bias (the Δ baseline).
  * ``dense``    — ALiBi materialized as a dense (H, N, N) input.
  * ``factored`` — FlashBias exact decomposition (Example 3.4, R = 2).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import common
from .. import decomp


class GptParams(NamedTuple):
    embed: jnp.ndarray    # (V, D)
    pos_dummy: jnp.ndarray  # kept zero: ALiBi replaces positional embeddings
    layers: list
    ln_f: tuple
    head: jnp.ndarray     # (D, V)


def init(key, vocab=512, num_layers=4, d_model=256, d_ff=1024):
    k1, k2, k3 = jax.random.split(key, 3)
    layers = [
        common.layer_init(k, d_model, d_ff)
        for k in jax.random.split(k2, num_layers)
    ]
    return GptParams(
        embed=jax.random.normal(k1, (vocab, d_model), jnp.float32) * 0.02,
        pos_dummy=jnp.zeros((1, d_model), jnp.float32),
        layers=layers,
        ln_f=(jnp.ones((d_model,)), jnp.zeros((d_model,))),
        head=jax.random.normal(k3, (d_model, vocab), jnp.float32) * 0.02,
    )


def forward(params: GptParams, tokens, num_heads=8, *, mode="pure",
            bias=None, phi_q=None, phi_k=None, attn="sdpa"):
    """tokens: (N,) int32. Returns logits (N, V)."""
    x = params.embed[tokens]
    for p in params.layers:
        if mode == "dense":
            x = common.transformer_layer(
                p, x, num_heads, bias=bias, causal=True, attn=attn
            )
        elif mode == "factored":
            x = common.transformer_layer(
                p, x, num_heads, phi_q=phi_q, phi_k=phi_k, causal=True,
                attn=attn,
            )
        else:
            x = common.transformer_layer(p, x, num_heads, causal=True,
                                          attn=attn)
    x = common.layer_norm(x, *params.ln_f)
    return x @ params.head


def alibi_inputs(n: int, num_heads: int):
    """Per-head dense bias (H,N,N) and factor strips (H,N,2)/(H,N,2)."""
    slopes = decomp.alibi_slopes(num_heads)
    dense = jnp.stack([decomp.alibi_bias(n, n, float(s)) for s in slopes])
    fq, fk = [], []
    for s in slopes:
        pq, pk = decomp.alibi_factors(n, n, float(s))
        fq.append(pq)
        fk.append(pk)
    return dense, jnp.stack(fq), jnp.stack(fk)


def lm_loss(params, tokens, num_heads=8, **kw):
    """Next-token cross-entropy (teacher-forced)."""
    logits = forward(params, tokens[:-1], num_heads, **kw)
    targets = tokens[1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def train_step(params, tokens, num_heads=8, lr=1e-3, **kw):
    val, grads = jax.value_and_grad(lm_loss)(params, tokens, num_heads, **kw)
    new = jax.tree_util.tree_map(lambda w, g: w - lr * g, params, grads)
    return val, new
