"""Appendix B: Pangu-Weather 3D-window attention (Table 7 workload).

Pangu's backbone is a 3D Swin Transformer; each block carries a relative-
position bias of shape (#windows, H, 144, 144) with the 3D window
2×6×12 = 144, and windows at the same latitude band share biases across
longitude. Only the fine-scale biases are low-rank; the paper applies SVD
FlashBias there with R = 56 (99% energy).

We reproduce the geometry exactly (window 2×6×12, longitude sharing) with
synthetic "trained" tables generated the same way as the Swin ones but in
3D (pressure-level, latitude, longitude offsets).
"""

from __future__ import annotations

import numpy as np

WINDOW = (2, 6, 12)  # (pressure levels, lat, lon)
N = WINDOW[0] * WINDOW[1] * WINDOW[2]  # 144


def pangu_relative_bias(num_heads: int = 4, seed: int = 0,
                        smooth_terms: int = 5, noise: float = 0.02,
                        window=WINDOW) -> np.ndarray:
    """Synthetic learned 3D relative-position bias (H, 144, 144)."""
    wz, wy, wx = window
    n = wz * wy * wx
    rng = np.random.default_rng(seed)
    zz, yy, xx = np.meshgrid(
        np.arange(wz), np.arange(wy), np.arange(wx), indexing="ij"
    )
    coords = np.stack([zz.ravel(), yy.ravel(), xx.ravel()], -1)  # (n, 3)
    rel = coords[:, None, :] - coords[None, :, :]
    dz = np.arange(-(wz - 1), wz).astype(np.float32)
    dy = np.arange(-(wy - 1), wy).astype(np.float32)
    dx = np.arange(-(wx - 1), wx).astype(np.float32)
    out = np.empty((num_heads, n, n), np.float32)
    for h in range(num_heads):
        table = np.zeros((2 * wz - 1, 2 * wy - 1, 2 * wx - 1), np.float32)
        for _ in range(smooth_terms):
            cz = rng.normal(0, wz / 2)
            cy = rng.normal(0, wy / 2)
            cx = rng.normal(0, wx / 2)
            sz = rng.uniform(wz / 3, wz)
            sy = rng.uniform(wy / 3, wy)
            sx = rng.uniform(wx / 3, wx)
            amp = rng.normal(0, 1.0)
            table += amp * (
                np.exp(-((dz - cz) / sz) ** 2)[:, None, None]
                * np.exp(-((dy - cy) / sy) ** 2)[None, :, None]
                * np.exp(-((dx - cx) / sx) ** 2)[None, None, :]
            )
        table += noise * rng.normal(size=table.shape).astype(np.float32)
        out[h] = table[
            rel[..., 0] + wz - 1, rel[..., 1] + wy - 1, rel[..., 2] + wx - 1
        ]
    return out


def longitude_shared_windows(num_lat_bands: int, num_lon: int,
                             num_heads: int = 4, seed: int = 0):
    """Biases for a (lat-band × lon) grid of windows: one table per lat
    band, shared across longitude (the meteorological prior)."""
    tables = [
        pangu_relative_bias(num_heads, seed=seed + b)
        for b in range(num_lat_bands)
    ]
    return np.stack([tables[b] for b in range(num_lat_bands)
                     for _ in range(num_lon)])
