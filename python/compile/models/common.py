"""Shared L2 building blocks: multi-head attention wrappers and FFN.

Every attention entry point comes in an "sdpa" flavour (plain jnp graph —
what PyTorch SDPA corresponds to in the paper's Figure 5) and a "pallas"
flavour (the L1 streaming kernels). Multi-head is vmap over the head axis.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import flash_attention as fa
from ..kernels import ref as kref


def mha_sdpa(q, k, v, bias=None, causal=False):
    """Multi-head SDPA graph. q/k/v: (H, N, C); bias: (H, N, M) or None."""
    if bias is None:
        return jax.vmap(lambda a, b, c: kref.attention(a, b, c, causal=causal))(
            q, k, v
        )
    return jax.vmap(
        lambda a, b, c, d: kref.attention(a, b, c, bias=d, causal=causal)
    )(q, k, v, bias)


def mha_sdpa_factored(q, k, v, phi_q, phi_k, causal=False):
    """Multi-head FlashBias concat graph. phi_*: (H, N, R)."""
    return jax.vmap(
        lambda a, b, c, pq, pk: kref.attention_factored(
            a, b, c, pq, pk, causal=causal
        )
    )(q, k, v, phi_q, phi_k)


def mha_pallas(q, k, v, causal=False):
    return jax.vmap(lambda a, b, c: fa.flash_attention(a, b, c, causal=causal))(
        q, k, v
    )


def mha_pallas_dense_bias(q, k, v, bias, causal=False):
    return jax.vmap(
        lambda a, b, c, d: fa.flash_attention_dense_bias(a, b, c, d, causal=causal)
    )(q, k, v, bias)


def mha_pallas_factored(q, k, v, phi_q, phi_k, causal=False):
    return jax.vmap(
        lambda a, b, c, pq, pk: fa.flash_attention_factored(
            a, b, c, pq, pk, causal=causal
        )
    )(q, k, v, phi_q, phi_k)


# --------------------------------------------------------------------------
# Transformer layer (the §4.1 plain Transformer)
# --------------------------------------------------------------------------


class LayerParams(NamedTuple):
    wq: jnp.ndarray  # (D, D)
    wk: jnp.ndarray
    wv: jnp.ndarray
    wo: jnp.ndarray
    w1: jnp.ndarray  # (D, F)
    b1: jnp.ndarray
    w2: jnp.ndarray  # (F, D)
    b2: jnp.ndarray
    ln1: tuple
    ln2: tuple


def layer_init(key, d_model: int, d_ff: int) -> LayerParams:
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d_model)
    sf = 1.0 / math.sqrt(d_ff)
    return LayerParams(
        wq=jax.random.normal(ks[0], (d_model, d_model), jnp.float32) * s,
        wk=jax.random.normal(ks[1], (d_model, d_model), jnp.float32) * s,
        wv=jax.random.normal(ks[2], (d_model, d_model), jnp.float32) * s,
        wo=jax.random.normal(ks[3], (d_model, d_model), jnp.float32) * s,
        w1=jax.random.normal(ks[4], (d_model, d_ff), jnp.float32) * s,
        b1=jnp.zeros((d_ff,), jnp.float32),
        w2=jax.random.normal(ks[5], (d_ff, d_model), jnp.float32) * sf,
        b2=jnp.zeros((d_model,), jnp.float32),
        ln1=(jnp.ones((d_model,)), jnp.zeros((d_model,))),
        ln2=(jnp.ones((d_model,)), jnp.zeros((d_model,))),
    )


def layer_norm(x, scale, shift, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + shift


def split_heads(x, num_heads):
    n, d = x.shape
    c = d // num_heads
    return x.reshape(n, num_heads, c).transpose(1, 0, 2)


def merge_heads(x):
    h, n, c = x.shape
    return x.transpose(1, 0, 2).reshape(n, h * c)


def transformer_layer(p: LayerParams, x, num_heads, *, bias=None,
                      phi_q=None, phi_k=None, causal=False,
                      attn="sdpa"):
    """One pre-LN Transformer layer with selectable attention path.

    ``attn``: "sdpa" | "pallas". Bias path is chosen by which of
    ``bias`` / ``(phi_q, phi_k)`` is given (both None → pure attention).
    """
    h = layer_norm(x, *p.ln1)
    q = split_heads(h @ p.wq, num_heads)
    k = split_heads(h @ p.wk, num_heads)
    v = split_heads(h @ p.wv, num_heads)
    if phi_q is not None:
        o = (
            mha_pallas_factored(q, k, v, phi_q, phi_k, causal=causal)
            if attn == "pallas"
            else mha_sdpa_factored(q, k, v, phi_q, phi_k, causal=causal)
        )
    elif bias is not None:
        o = (
            mha_pallas_dense_bias(q, k, v, bias, causal=causal)
            if attn == "pallas"
            else mha_sdpa(q, k, v, bias=bias, causal=causal)
        )
    else:
        o = (
            mha_pallas(q, k, v, causal=causal)
            if attn == "pallas"
            else mha_sdpa(q, k, v, causal=causal)
        )
    x = x + merge_heads(o) @ p.wo
    h = layer_norm(x, *p.ln2)
    x = x + jnp.maximum(h @ p.w1 + p.b1, 0.0) @ p.w2 + p.b2
    return x
