"""L1 correctness: every Pallas kernel against the pure-jnp oracle.

Hypothesis sweeps shapes (N, M, C, block sizes) and dtypes; fixed-seed
numpy data keeps the sweeps reproducible. Tolerances are f32-accumulation
level (the kernels accumulate in f32 like the oracle).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import flash_attention as fa
from compile.kernels import ref

ATOL, RTOL = 2e-5, 2e-5


def _data(n, m, c, seed=0, cv=None):
    rng = np.random.default_rng(seed)
    cv = cv or c
    q = rng.normal(size=(n, c)).astype(np.float32)
    k = rng.normal(size=(m, c)).astype(np.float32)
    v = rng.normal(size=(m, cv)).astype(np.float32)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


def _factors(n, m, r, seed=1, scale=0.3):
    rng = np.random.default_rng(seed)
    pq = (scale * rng.normal(size=(n, r))).astype(np.float32)
    pk = (scale * rng.normal(size=(m, r))).astype(np.float32)
    return jnp.asarray(pq), jnp.asarray(pk)


# --------------------------------------------------------------------------
# hypothesis shape sweeps
# --------------------------------------------------------------------------

shapes = st.tuples(
    st.sampled_from([16, 24, 48, 64, 96, 128]),   # n
    st.sampled_from([16, 32, 64, 128]),           # m
    st.sampled_from([8, 16, 32, 64]),             # c
)
blocks = st.sampled_from([16, 32, 64])


@settings(max_examples=20, deadline=None)
@given(shape=shapes, bq=blocks, bk=blocks, seed=st.integers(0, 3))
def test_flash_attention_matches_ref(shape, bq, bk, seed):
    n, m, c = shape
    q, k, v = _data(n, m, c, seed)
    out = fa.flash_attention(q, k, v, block_q=bq, block_k=bk)
    expect = ref.attention(q, k, v)
    assert_allclose(np.asarray(out), np.asarray(expect), atol=ATOL, rtol=RTOL)


@settings(max_examples=20, deadline=None)
@given(shape=shapes, bq=blocks, bk=blocks, seed=st.integers(0, 3))
def test_flash_dense_bias_matches_ref(shape, bq, bk, seed):
    n, m, c = shape
    q, k, v = _data(n, m, c, seed)
    rng = np.random.default_rng(seed + 100)
    bias = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    out = fa.flash_attention_dense_bias(q, k, v, bias, block_q=bq, block_k=bk)
    expect = ref.attention(q, k, v, bias=bias)
    assert_allclose(np.asarray(out), np.asarray(expect), atol=ATOL, rtol=RTOL)


@settings(max_examples=20, deadline=None)
@given(shape=shapes, r=st.sampled_from([1, 2, 8, 16]), seed=st.integers(0, 3))
def test_flash_factored_matches_dense(shape, r, seed):
    """FlashBias fused kernel == dense-bias kernel when b = φ_q φ_kᵀ."""
    n, m, c = shape
    q, k, v = _data(n, m, c, seed)
    pq, pk = _factors(n, m, r, seed)
    bias = pq @ pk.T
    out = fa.flash_attention_factored(q, k, v, pq, pk)
    expect = ref.attention(q, k, v, bias=bias)
    assert_allclose(np.asarray(out), np.asarray(expect), atol=ATOL, rtol=RTOL)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([16, 48, 64, 128]),
    m=st.sampled_from([64, 128]),
    c=st.sampled_from([16, 64]),
    seed=st.integers(0, 3),
)
def test_flash_causal_rectangular(n, m, c, seed):
    """Causal mask with N != M (decoder alignment: mask ends at key end)."""
    if n > m:
        n = m
    q, k, v = _data(n, m, c, seed)
    out = fa.flash_attention(q, k, v, causal=True)
    expect = ref.attention(q, k, v, causal=True)
    assert_allclose(np.asarray(out), np.asarray(expect), atol=ATOL, rtol=RTOL)


@settings(max_examples=15, deadline=None)
@given(shape=shapes, r=st.sampled_from([2, 8]), seed=st.integers(0, 3))
def test_flash_factored_causal(shape, r, seed):
    n, m, c = shape
    if n > m:
        n = m
    q, k, v = _data(n, m, c, seed)
    pq, pk = _factors(n, m, r, seed)
    bias = pq @ pk.T
    out = fa.flash_attention_factored(q, k, v, pq, pk, causal=True)
    expect = ref.attention(q, k, v, bias=bias, causal=True)
    assert_allclose(np.asarray(out), np.asarray(expect), atol=ATOL, rtol=RTOL)


@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([32, 64, 128]),
    c=st.sampled_from([16, 64]),
    slope_exp=st.integers(-8, -1),
    seed=st.integers(0, 3),
)
def test_alibi_jit_kernel(n, c, slope_exp, seed):
    """Appendix C: in-kernel ALiBi == dense ALiBi bias + causal."""
    from compile import decomp

    slope = 2.0**slope_exp
    q, k, v = _data(n, n, c, seed)
    bias = decomp.alibi_bias(n, n, slope)
    out = fa.flash_attention_alibi_jit(q, k, v, slope, causal=True)
    expect = ref.attention(q, k, v, bias=bias, causal=True)
    assert_allclose(np.asarray(out), np.asarray(expect), atol=ATOL, rtol=RTOL)


@settings(max_examples=15, deadline=None)
@given(shape=shapes, r=st.sampled_from([1, 2, 4]), seed=st.integers(0, 3))
def test_mult_factored_kernel(shape, r, seed):
    """Appendix I: multiplicative factored kernel vs Hadamard oracle."""
    n, m, c = shape
    q, k, v = _data(n, m, c, seed)
    pq, pk = _factors(n, m, r, seed, scale=0.5)
    bias = pq @ pk.T
    out = fa.flash_attention_mult_factored(q, k, v, pq, pk)
    expect = ref.attention_multiplicative(q, k, v, bias)
    assert_allclose(np.asarray(out), np.asarray(expect), atol=ATOL, rtol=RTOL)


# --------------------------------------------------------------------------
# oracle self-consistency
# --------------------------------------------------------------------------


def test_online_softmax_equals_full():
    q, k, v = _data(64, 96, 32)
    rng = np.random.default_rng(9)
    bias = jnp.asarray(rng.normal(size=(64, 96)).astype(np.float32))
    out = ref.online_softmax_attention(q, k, v, bias=bias, block_k=16)
    expect = ref.attention(q, k, v, bias=bias)
    assert_allclose(np.asarray(out), np.asarray(expect), atol=ATOL, rtol=RTOL)


def test_eq3_concat_equals_additive_bias():
    """Equation (3): the concat trick is algebraically exact."""
    n, m, c, r = 48, 64, 32, 8
    q, k, v = _data(n, m, c)
    pq, pk = _factors(n, m, r)
    bias = pq @ pk.T
    out = ref.attention_factored(q, k, v, pq, pk)
    expect = ref.attention(q, k, v, bias=bias)
    assert_allclose(np.asarray(out), np.asarray(expect), atol=ATOL, rtol=RTOL)


def test_eq17_channel_repeat_equals_hadamard():
    """Appendix I Eq. (17): channel-repeat trick is exact."""
    n, m, c, r = 32, 48, 16, 2
    q, k, v = _data(n, m, c)
    pq, pk = _factors(n, m, r, scale=0.5)
    bias = pq @ pk.T
    out = ref.attention_multiplicative_factored(q, k, v, pq, pk)
    expect = ref.attention_multiplicative(q, k, v, bias)
    assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4, rtol=1e-4)


def test_extreme_scores_stable():
    """Online softmax must survive large score magnitudes (no inf/nan)."""
    n, m, c = 32, 64, 16
    q, k, v = _data(n, m, c)
    bias = jnp.full((n, m), 80.0, jnp.float32)
    out = fa.flash_attention_dense_bias(q, k, v, bias)
    expect = ref.attention(q, k, v, bias=bias)
    assert np.isfinite(np.asarray(out)).all()
    assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4, rtol=1e-4)


def test_single_block_and_multi_block_agree():
    n, m, c = 64, 64, 32
    q, k, v = _data(n, m, c)
    one = fa.flash_attention(q, k, v, block_q=64, block_k=64)
    many = fa.flash_attention(q, k, v, block_q=16, block_k=16)
    assert_allclose(np.asarray(one), np.asarray(many), atol=ATOL, rtol=RTOL)


def test_value_dim_differs_from_key_dim():
    q, k, v = _data(32, 64, 16, cv=24)
    out = fa.flash_attention(q, k, v)
    expect = ref.attention(q, k, v)
    assert_allclose(np.asarray(out), np.asarray(expect), atol=ATOL, rtol=RTOL)
