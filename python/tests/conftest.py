import os
import sys

# Run from python/ or repo root: make `compile` importable as a package.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
