"""AOT pipeline tests: the artifact registry is well-formed, the manifest
on disk (if built) is consistent with its binaries, and HLO lowering
round-trips for a sample artifact."""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np
import pytest

from compile import model as model_registry

ARTIFACTS = Path(__file__).resolve().parents[2] / "artifacts"


def test_registry_contains_default_set():
    reg = model_registry.registry()
    missing = [n for n in model_registry.DEFAULT_SET if n not in reg]
    assert not missing, f"DEFAULT_SET names missing: {missing}"


def test_registry_builders_produce_consistent_specs():
    # spot-check a few cheap builders: fn accepts the example inputs and
    # meta marks activation indices in range
    reg = model_registry.registry()
    for name in ["attn_pure_n256", "causal_alibi_factored_n256",
                 "mult_factored_n256"]:
        fn, inputs, meta = reg[name]()
        acts = meta.get("activations", [])
        assert all(0 <= i < len(inputs) for i in acts)
        out = fn(*inputs)
        assert isinstance(out, tuple)
        assert all(np.isfinite(np.asarray(o)).all() for o in out)


def test_micro_factored_matches_dense_reconstruction():
    """attn_factored's kernel output == dense kernel on φ_q φ_kᵀ."""
    reg = model_registry.registry()
    fn_f, inputs_f, _ = reg["attn_factored_n256"]()
    q, k, v, pq, pk = inputs_f
    import jax.numpy as jnp

    bias = jnp.einsum("hnr,hmr->hnm", pq, pk)
    fn_d, _, _ = reg["attn_dense_n256"]()
    out_f = np.asarray(fn_f(q, k, v, pq, pk)[0])
    out_d = np.asarray(fn_d(q, k, v, bias)[0])
    np.testing.assert_allclose(out_f, out_d, atol=2e-4, rtol=2e-4)


needs_artifacts = pytest.mark.skipif(
    not (ARTIFACTS / "manifest.json").exists(),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_files_exist_and_sizes_match():
    manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
    assert manifest["format"] == 1
    assert len(manifest["artifacts"]) >= 40
    for entry in manifest["artifacts"]:
        hlo = ARTIFACTS / entry["hlo"]
        assert hlo.exists(), f"missing {hlo}"
        assert hlo.stat().st_size > 100
        for spec in entry["inputs"] + entry["outputs"]:
            f = ARTIFACTS / spec["file"]
            expect = int(np.prod(spec["shape"] or [1])) * 4
            assert f.exists(), f"missing {f}"
            assert f.stat().st_size == expect, (
                f"{f}: {f.stat().st_size} != {expect}"
            )


@needs_artifacts
def test_manifest_activation_indices_valid():
    manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
    for entry in manifest["artifacts"]:
        acts = entry["meta"].get("activations", [])
        for i in acts:
            assert 0 <= i < len(entry["inputs"]), entry["name"]


@needs_artifacts
def test_hlo_text_is_parseable_header():
    manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
    entry = manifest["artifacts"][0]
    text = (ARTIFACTS / entry["hlo"]).read_text()
    assert text.startswith("HloModule"), "not HLO text format"
    assert "ENTRY" in text
    # every input should appear as a parameter
    assert text.count("parameter(") >= len(entry["inputs"])


def test_lowering_roundtrip_small():
    """Lower a fresh tiny artifact and execute it via XLA:CPU (the same
    path aot.py uses), checking outputs stay finite and deterministic."""
    import jax

    reg = model_registry.registry()
    fn, inputs, _ = reg["mult_dense_n256"]()
    specs = [jax.ShapeDtypeStruct(np.asarray(a).shape, np.asarray(a).dtype)
             for a in inputs]
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    from compile.aot import to_hlo_text

    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    compiled = lowered.compile()
    out1 = np.asarray(compiled(*inputs)[0])
    out2 = np.asarray(compiled(*inputs)[0])
    np.testing.assert_array_equal(out1, out2)
    assert np.isfinite(out1).all()
