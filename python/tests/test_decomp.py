"""Decomposition library tests: exact factorizations are exact, SVD hits
its energy targets, neural decomposition converges (Eq. 5)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import decomp


# --------------------------------------------------------------------------
# exact decompositions
# --------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(4, 96),
    m=st.integers(4, 96),
    slope_exp=st.integers(-8, 0),
)
def test_alibi_factors_exact(n, m, slope_exp):
    slope = 2.0**slope_exp
    dense = decomp.alibi_bias(n, m, slope)
    pq, pk = decomp.alibi_factors(n, m, slope)
    assert pq.shape == (n, 2) and pk.shape == (m, 2)
    assert_allclose(np.asarray(pq @ pk.T), np.asarray(dense),
                    atol=1e-4, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(4, 64),
    m=st.integers(4, 64),
    dim=st.sampled_from([1, 2, 3]),
    weighted=st.booleans(),
    seed=st.integers(0, 5),
)
def test_spatial_factors_exact(n, m, dim, weighted, seed):
    """Example 3.5: rank-3·dim factorization of −α‖x_i − x_j‖²."""
    rng = np.random.default_rng(seed)
    xq = jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))
    xk = jnp.asarray(rng.normal(size=(m, dim)).astype(np.float32))
    alpha = (
        jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))
        if weighted else None
    )
    dense = decomp.spatial_bias(xq, xk, alpha)
    pq, pk = decomp.spatial_factors(xq, xk, alpha)
    assert pq.shape == (n, 3 * dim)
    assert_allclose(np.asarray(pq @ pk.T), np.asarray(dense),
                    atol=1e-4, rtol=1e-4)


def test_cos_mult_factors_exact():
    dense = decomp.cos_mult_bias(37, 53)
    pq, pk = decomp.cos_mult_factors(37, 53)
    assert pq.shape == (37, 2)
    assert_allclose(np.asarray(pq @ pk.T), np.asarray(dense), atol=1e-5)


def test_alibi_slopes_geometric():
    s = decomp.alibi_slopes(8)
    assert s.shape == (8,)
    ratios = s[1:] / s[:-1]
    assert_allclose(ratios, ratios[0], rtol=1e-6)
    assert s[-1] == pytest.approx(2.0**-8)


# --------------------------------------------------------------------------
# SVD decomposition + energy accounting (Remark 3.8 / Figures 6/8)
# --------------------------------------------------------------------------


def test_svd_factors_reconstruct_lowrank_exactly():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(48, 6)).astype(np.float32)
    b = rng.normal(size=(64, 6)).astype(np.float32)
    bias = jnp.asarray(a @ b.T)
    pq, pk = decomp.svd_factors(bias, 6)
    assert_allclose(np.asarray(pq @ pk.T), np.asarray(bias),
                    atol=1e-3, rtol=1e-3)


def test_svd_rank_truncation_error_decreases():
    bias = jnp.asarray(decomp.swin_relative_bias((8, 8), 1, seed=1)[0])
    errs = [
        decomp.reconstruction_error(bias, *decomp.svd_factors(bias, r))
        for r in (1, 2, 4, 8, 16, 32)
    ]
    assert all(e1 >= e2 - 1e-6 for e1, e2 in zip(errs, errs[1:]))
    assert errs[-1] < 0.1


def test_energy_monotone_and_normalized():
    bias = np.random.default_rng(2).normal(size=(32, 32)).astype(np.float32)
    cum = decomp.energy(bias)
    assert np.all(np.diff(cum) >= -1e-7)
    assert cum[-1] == pytest.approx(1.0, abs=1e-5)


def test_rank_for_energy_consistent_with_energy():
    bias = decomp.swin_relative_bias((8, 8), 1, seed=3)[0]
    r = decomp.rank_for_energy(bias, 0.99)
    cum = decomp.energy(bias)
    assert cum[r - 1] >= 0.99
    if r > 1:
        assert cum[r - 2] < 0.99


def test_swin_synthetic_bias_is_lowrank():
    """The synthetic 'trained' tables must exhibit the paper's observed
    spectral decay (Figure 8): 99% energy well below full rank."""
    bias = decomp.swin_relative_bias((12, 12), 4, seed=0)  # N=144
    for h in range(4):
        r = decomp.rank_for_energy(bias[h], 0.99)
        assert r <= 40, f"head {h} rank@99% = {r}, not low-rank"


def test_swin_bias_shapes_and_symmetry_structure():
    wy, wx = 6, 7
    bias = decomp.swin_relative_bias((wy, wx), 3, seed=0)
    n = wy * wx
    assert bias.shape == (3, n, n)
    # relative-position structure: b[i,i] identical for all i (offset 0,0)
    diag = np.diagonal(bias[0])
    assert_allclose(diag, diag[0], atol=1e-6)


# --------------------------------------------------------------------------
# neural decomposition (Eq. 5, Appendix G)
# --------------------------------------------------------------------------


def test_neural_decompose_gravity_converges():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(0, 1, (48, 2)).astype(np.float32))
    pq, pk, losses = decomp.neural_decompose(
        decomp.gravity_bias, x, x, rank=16, hidden=32, steps=800, seed=0
    )
    # Gravity is the paper's hard case (App. G: "more difficult for
    # optimization ... still captures the locality"): require steady
    # optimization progress, not a tight fit.
    assert losses[-1] < losses[0] * 0.75
    target = decomp.gravity_bias(x, x)
    approx = decomp.mlp_apply(pq, x) @ decomp.mlp_apply(pk, x).T
    rel = float(
        jnp.linalg.norm(approx - target) / jnp.linalg.norm(target)
    )
    assert rel < 0.8


def test_neural_decompose_spherical_good_fit():
    rng = np.random.default_rng(1)
    lat = rng.uniform(-np.pi / 2, np.pi / 2, 48)
    lon = rng.uniform(0, 2 * np.pi, 48)
    x = jnp.asarray(np.stack([lat, lon], -1).astype(np.float32))
    pq, pk, losses = decomp.neural_decompose(
        decomp.spherical_bias, x, x, rank=32, hidden=48, steps=400, seed=0
    )
    target = decomp.spherical_bias(x, x)
    approx = decomp.mlp_apply(pq, x) @ decomp.mlp_apply(pk, x).T
    rel = float(jnp.linalg.norm(approx - target) / jnp.linalg.norm(target))
    assert rel < 0.25  # paper: spherical decomposes very well


def test_neural_decompose_exact_lowrank_target():
    """A target that IS rank-R must be fit to high accuracy."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(40, 3)).astype(np.float32))

    def target_fn(xq, xk):
        return -((xq[:, None, :] - xk[None, :, :]) ** 2).sum(-1)

    pq, pk, losses = decomp.neural_decompose(
        target_fn, x, x, rank=9, hidden=64, steps=800, seed=0
    )
    target = target_fn(x, x)
    approx = decomp.mlp_apply(pq, x) @ decomp.mlp_apply(pk, x).T
    rel = float(jnp.linalg.norm(approx - target) / jnp.linalg.norm(target))
    assert rel < 0.15


def test_mlp_tokenwise_property():
    """Remark 3.6: φ̂ is token-wise — permuting rows permutes outputs."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32))
    p = decomp.mlp_init(jax.random.PRNGKey(0), 4, 16, 8)
    perm = np.asarray(rng.permutation(16))
    out = decomp.mlp_apply(p, x)
    out_perm = decomp.mlp_apply(p, x[perm])
    assert_allclose(np.asarray(out[perm]), np.asarray(out_perm), atol=1e-6)


def test_gravity_and_spherical_bias_values():
    x = jnp.asarray([[0.0, 0.0], [1.0, 0.0]], jnp.float32)
    g = decomp.gravity_bias(x, x)
    assert g[0, 0] == pytest.approx(100.0)  # 1/eps at the diagonal
    assert g[0, 1] == pytest.approx(1.0 / 1.01, rel=1e-5)
    # antipodal points on the sphere: distance π
    p = jnp.asarray([[0.0, 0.0], [0.0, np.pi]], jnp.float32)
    s = decomp.spherical_bias(p, p)
    assert s[0, 1] == pytest.approx(np.pi, rel=1e-5)
    assert s[0, 0] == pytest.approx(0.0, abs=1e-6)
