"""L2 model tests: output shapes, dense↔factored equivalence where the
decomposition is exact, and training-step behaviour."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import decomp
from compile.models import common, gpt2_alibi, pairformer, pde, plain, swin


def key(seed=0):
    return jax.random.PRNGKey(seed)


# --------------------------------------------------------------------------
# plain Transformer (§4.1)
# --------------------------------------------------------------------------


def test_plain_forward_shapes_and_paths_agree():
    params = plain.init(key(), num_layers=2, d_model=64, d_ff=128)
    x = jax.random.normal(key(1), (32, 64), jnp.float32)
    pq = 0.3 * jax.random.normal(key(2), (8, 32, 4), jnp.float32)
    pk = 0.3 * jax.random.normal(key(3), (8, 32, 4), jnp.float32)
    bias = jnp.einsum("hnr,hmr->hnm", pq, pk)
    out_dense = plain.forward(params, x, 8, bias=bias)
    out_fact = plain.forward(params, x, 8, phi_q=pq, phi_k=pk)
    assert out_dense.shape == (32, 64)
    assert_allclose(np.asarray(out_fact), np.asarray(out_dense),
                    atol=1e-4, rtol=1e-4)


def test_plain_sdpa_vs_pallas_agree():
    params = plain.init(key(), num_layers=1, d_model=32, d_ff=64)
    x = jax.random.normal(key(4), (64, 32), jnp.float32)
    a = plain.forward(params, x, 4, attn="sdpa")
    b = plain.forward(params, x, 4, attn="pallas")
    assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


def test_plain_train_step_reduces_loss():
    params = plain.init(key(), num_layers=1, d_model=32, d_ff=64)
    x = jax.random.normal(key(5), (16, 32), jnp.float32)
    target = jax.random.normal(key(6), (16, 32), jnp.float32)
    pq = 0.3 * jax.random.normal(key(7), (4, 16, 2), jnp.float32)
    pk = 0.3 * jax.random.normal(key(8), (4, 16, 2), jnp.float32)
    losses = []
    for _ in range(5):
        val, params, pq, pk = plain.train_step(
            params, x, target, 4, lr=1e-2, phi_q=pq, phi_k=pk
        )
        losses.append(float(val))
    assert losses[-1] < losses[0]


def test_plain_train_dense_updates_bias():
    params = plain.init(key(), num_layers=1, d_model=32, d_ff=64)
    x = jax.random.normal(key(9), (16, 32), jnp.float32)
    target = jax.random.normal(key(10), (16, 32), jnp.float32)
    bias = 0.1 * jax.random.normal(key(11), (4, 16, 16), jnp.float32)
    _, _, new_bias = plain.train_step(params, x, target, 4, bias=bias)
    # the dense N×N gradient the paper calls out: bias must change
    assert float(jnp.abs(new_bias - bias).max()) > 0.0


# --------------------------------------------------------------------------
# GPT-2 + ALiBi (§4.2)
# --------------------------------------------------------------------------


def test_gpt2_dense_equals_factored_exactly():
    """ALiBi's decomposition is exact ⇒ logits must match."""
    params = gpt2_alibi.init(key(), vocab=64, num_layers=2, d_model=32,
                             d_ff=64)
    tokens = jax.random.randint(key(1), (24,), 0, 64, jnp.int32)
    dense, pq, pk = gpt2_alibi.alibi_inputs(24, 4)
    out_d = gpt2_alibi.forward(params, tokens, 4, mode="dense", bias=dense)
    out_f = gpt2_alibi.forward(params, tokens, 4, mode="factored",
                               phi_q=pq, phi_k=pk)
    assert out_d.shape == (24, 64)
    assert_allclose(np.asarray(out_f), np.asarray(out_d), atol=2e-4,
                    rtol=2e-4)


def test_gpt2_bias_changes_output():
    params = gpt2_alibi.init(key(), vocab=64, num_layers=2, d_model=32,
                             d_ff=64)
    tokens = jax.random.randint(key(2), (24,), 0, 64, jnp.int32)
    dense, _, _ = gpt2_alibi.alibi_inputs(24, 4)
    pure = gpt2_alibi.forward(params, tokens, 4, mode="pure")
    biased = gpt2_alibi.forward(params, tokens, 4, mode="dense", bias=dense)
    assert float(jnp.abs(pure - biased).max()) > 1e-3


def test_gpt2_lm_loss_finite_and_trains():
    params = gpt2_alibi.init(key(), vocab=64, num_layers=1, d_model=32,
                             d_ff=64)
    tokens = jax.random.randint(key(3), (16,), 0, 64, jnp.int32)
    losses = []
    for _ in range(3):
        val, params = gpt2_alibi.train_step(params, tokens, 4, lr=1e-2)
        losses.append(float(val))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


def test_gpt2_causality():
    """Changing a future token must not affect past logits."""
    params = gpt2_alibi.init(key(), vocab=64, num_layers=1, d_model=32,
                             d_ff=64)
    tokens = jax.random.randint(key(4), (16,), 0, 64, jnp.int32)
    out1 = gpt2_alibi.forward(params, tokens, 4, mode="pure")
    tokens2 = tokens.at[10].set((tokens[10] + 1) % 64)
    out2 = gpt2_alibi.forward(params, tokens2, 4, mode="pure")
    assert_allclose(np.asarray(out1[:10]), np.asarray(out2[:10]),
                    atol=1e-5)
    assert float(jnp.abs(out1[10:] - out2[10:]).max()) > 1e-4


# --------------------------------------------------------------------------
# Swin (§4.3)
# --------------------------------------------------------------------------


def test_swin_factored_from_policy():
    window = (6, 6)
    n = 36
    biases = np.stack(
        [decomp.swin_relative_bias(window, 2, seed=s) for s in range(3)]
    )
    params = swin.init(key(), num_layers=3, d_model=32, d_ff=64,
                       window=window, num_heads=2, biases=biases)
    patches = jax.random.normal(key(1), (n, 16), jnp.float32)
    out_dense = swin.forward(params, patches, 2)
    assert out_dense.shape == (10,)
    # SVD-factor the last 2 layers at generous rank
    fqs, fks = [], []
    for li in (1, 2):
        fq_h, fk_h = [], []
        for h in range(2):
            pq, pk = decomp.svd_factors(jnp.asarray(biases[li, h]), 30)
            fq_h.append(pq)
            fk_h.append(pk)
        fqs.append(jnp.stack(fq_h))
        fks.append(jnp.stack(fk_h))
    out_fact = swin.forward(params, patches, 2,
                            factor_qs=jnp.stack(fqs),
                            factor_ks=jnp.stack(fks), factored_from=1)
    rel = float(jnp.linalg.norm(out_fact - out_dense)
                / jnp.linalg.norm(out_dense))
    assert rel < 0.05, rel


# --------------------------------------------------------------------------
# PDE solver (§4.4)
# --------------------------------------------------------------------------


def test_pde_dense_equals_factored():
    n = 48
    params = pde.init(key(), n, num_layers=1, d_model=32, d_ff=64,
                      num_heads=4)
    positions = jnp.asarray(pde.synthetic_car_cloud(n))
    out_d = pde.forward(params, positions, 4, mode="dense")
    out_f = pde.forward(params, positions, 4, mode="factored")
    assert out_d.shape == (n, 4)
    assert_allclose(np.asarray(out_f), np.asarray(out_d), atol=2e-4,
                    rtol=2e-4)


def test_pde_train_step_updates_alpha():
    n = 32
    params = pde.init(key(), n, num_layers=1, d_model=32, d_ff=64,
                      num_heads=2)
    positions = jnp.asarray(pde.synthetic_car_cloud(n))
    target = jnp.asarray(pde.synthetic_fields(positions))
    val, new = pde.train_step(params, positions, target, 2, lr=1e-2,
                              mode="factored")
    assert np.isfinite(float(val))
    assert float(jnp.abs(new.alphas - params.alphas).max()) > 0.0


def test_car_cloud_properties():
    pts = pde.synthetic_car_cloud(200, seed=1)
    assert pts.shape == (200, 3)
    assert np.abs(pts[:, 0]).max() < 2.5
    fields = pde.synthetic_fields(pts)
    assert fields.shape == (200, 4)
    assert np.isfinite(fields).all()


# --------------------------------------------------------------------------
# Pairformer (§4.4)
# --------------------------------------------------------------------------


def test_pairformer_forward_and_neural_fidelity():
    n, cz, h, rank = 32, 4, 2, 8
    params = pairformer.init(key(), num_layers=1, d_model=32, d_ff=64,
                             c_z=cz)
    # num_heads fixed to 4 in init's projection; use 4
    single = jax.random.normal(key(1), (n, 32), jnp.float32)
    z = pairformer.synthetic_pair_rep(key(2), n, cz)
    out_dense = pairformer.forward(params, single, z, 4, mode="dense")
    assert out_dense.shape == (n, 32)
    factor_params = pairformer.train_factor_nets(
        params, single, z, 4, rank=rank, hidden=32, steps=200
    )
    out_neural = pairformer.forward(params, single, z, 4, mode="neural",
                                    factor_params=factor_params, rank=rank)
    rel = float(jnp.linalg.norm(out_neural - out_dense)
                / jnp.linalg.norm(out_dense))
    assert rel < 0.5, rel
    _ = h


def test_triangle_multiplication_shape_and_gate():
    n, cz = 16, 4
    z = pairformer.synthetic_pair_rep(key(3), n, cz)
    w = 0.3 * jax.random.normal(key(4), (cz, cz), jnp.float32)
    out = pairformer.triangle_multiplication(z, w, w, w)
    assert out.shape == (n, n, cz)
    # residual structure: zero weights ⇒ identity-ish (gate·0 added)
    zero = jnp.zeros((cz, cz), jnp.float32)
    out0 = pairformer.triangle_multiplication(z, zero, zero, zero)
    assert_allclose(np.asarray(out0), np.asarray(z), atol=1e-6)


def test_pair_bias_projection_shape():
    n, cz = 12, 4
    z = pairformer.synthetic_pair_rep(key(5), n, cz)
    proj = jax.random.normal(key(6), (cz, 4), jnp.float32)
    b = pairformer.pair_bias(z, proj)
    assert b.shape == (4, n, n)


# --------------------------------------------------------------------------
# multi-head plumbing
# --------------------------------------------------------------------------


def test_split_merge_heads_roundtrip():
    x = jax.random.normal(key(7), (10, 32), jnp.float32)
    h = common.split_heads(x, 4)
    assert h.shape == (4, 10, 8)
    back = common.merge_heads(h)
    assert_allclose(np.asarray(back), np.asarray(x), atol=0)


def test_layer_norm_statistics():
    x = jax.random.normal(key(8), (20, 16), jnp.float32) * 5 + 3
    out = common.layer_norm(x, jnp.ones((16,)), jnp.zeros((16,)))
    assert_allclose(np.asarray(out.mean(-1)), 0.0, atol=1e-5)
    assert_allclose(np.asarray(out.std(-1)), 1.0, atol=1e-2)
