//! Vendored minimal re-implementation of the `anyhow` error-handling API.
//!
//! The accelerator build environment has no crates.io access, so the
//! workspace vendors the subset of `anyhow` it actually uses: the
//! [`Error`] type (context chain, `{}` / `{:#}` formatting), the
//! [`Result`] alias, the [`anyhow!`] / [`bail!`] macros, and the
//! [`Context`] extension trait. The API is call-compatible with the real
//! crate for this subset, so swapping the path dependency for
//! `anyhow = "1"` is a one-line change.

use std::error::Error as StdError;
use std::fmt;

/// Error with a human-readable cause chain (outermost message first).
///
/// Deliberately does NOT implement [`std::error::Error`] — exactly like
/// the real `anyhow::Error` — so the blanket `From<E: StdError>` impl
/// cannot overlap the reflexive `From<Error>`.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a plain message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Prepend a context message (what `.context()` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause-chain messages, outermost first.
    pub fn chain_messages(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, colon-joined (anyhow's format)
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (subset of `anyhow::Context`).
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self, context: C,
    ) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self, context: C,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self, context: C,
    ) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self, context: C,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest.json".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest.json");
        let alt = format!("{e:#}");
        assert!(alt.contains("reading manifest.json"));
        assert!(alt.contains("gone"));
    }

    #[test]
    fn macros_build_errors() {
        let name = "x";
        let e = anyhow!("unknown artifact {name}");
        assert_eq!(format!("{e}"), "unknown artifact x");
        let e = anyhow!("load {}: {}", "a", "b");
        assert_eq!(format!("{e}"), "load a: b");
        fn f() -> Result<()> {
            bail!("nope {}", 3)
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e: Error =
            Err::<(), _>(io_err()).context("outer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by"));
        assert!(e.chain_messages().len() == 2);
    }
}
