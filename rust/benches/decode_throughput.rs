//! Decode-path bench (ISSUE 8 prefill/decode split), two sections,
//! both written to `BENCH_decode.json`:
//!
//! 1. **Per-step bias-strip cost**: one `run_decode_step` over a full
//!    KV cache at M ∈ {512, 2048, 4096}, with the bias supplied three
//!    ways — a dense table row (O(M) reads against an O(M²)-resident
//!    table), factored strips at r = 8 (O(r·M) FMA against O(r·M)
//!    storage), and JIT ALiBi (zero bias IO). The query position walks
//!    the table sequentially like a real decode session, so the dense
//!    path streams a fresh 4·M-byte row from the big table every step
//!    while the factor strips stay cache-resident; at M ≥ 2048 (table
//!    ≥ 16 MB) that working-set gap is what the strips win on.
//!
//! 2. **Multi-session coordinator throughput**: open S sessions,
//!    prefill each, drive a round-robin decode schedule through
//!    `Coordinator::step`, and report steps/sec as S grows — the
//!    continuous-batching path (`run_batch_decode`) end to end.
//!
//! Honors `FLASHBIAS_BENCH_ITERS` (CI smoke runs a single iteration)
//! and `FLASHBIAS_BENCH_JSON_DIR` for the JSON drop location.

use std::sync::Arc;
use std::time::Duration;

use flashbias::benchkit::{bench_fn, iters, Table};
use flashbias::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig,
};
use flashbias::iomodel::Geometry;
use flashbias::kernels::{
    run_decode_step, AlibiTile, BiasTile, DenseTile, FactoredTile,
    KernelConfig,
};
use flashbias::plan::{BiasSpec, PlanOptions, Planner};
use flashbias::runtime::Runtime;
use flashbias::tensor::Tensor;
use flashbias::util::{human_secs, Xoshiro256};

const C: usize = 64;
const RANK: usize = 8;
const SRAM: usize = 100 * 1024 / 2;

/// One decode step against a full cache of M keys, with the query
/// position advancing each call so the dense table is streamed row by
/// row (a stationary row would sit in L1 and hide the IO).
fn bench_step_at(out: &mut Table, m: usize, it: usize) {
    let mut rng = Xoshiro256::new(42 + m as u64);
    let q = Tensor::randn(&[C], 1.0, &mut rng);
    let k = Tensor::randn(&[m, C], 1.0, &mut rng);
    let v = Tensor::randn(&[m, C], 1.0, &mut rng);
    let cfg = KernelConfig::for_geometry(&Geometry::square(m, C, 0, SRAM));
    let scale = 1.0 / (C as f32).sqrt();

    // the three ways to supply the same-shaped bias strip
    let table = Tensor::randn(&[m, m], 0.02, &mut rng);
    let dense = DenseTile::new(table.view2());
    let phi_q = Tensor::randn(&[m, RANK], 0.1, &mut rng);
    let phi_k = Tensor::randn(&[m, RANK], 0.1, &mut rng);
    let factored = FactoredTile::new(&phi_q, &phi_k);
    let jit = AlibiTile { slope: 0.0625 };

    let run = |label: &str, tile: &dyn BiasTile| {
        let mut outbuf = vec![0.0f32; C];
        let mut i = 0usize;
        bench_fn(label, 2, it, || {
            let carry = run_decode_step(
                q.data(),
                k.view2(),
                v.view2(),
                tile,
                i,
                m,
                false,
                scale,
                &cfg,
                &mut outbuf,
            );
            assert!(carry.l > 0.0);
            i = (i + 1) % m;
        })
    };
    let rows = [
        run(&format!("M={m} dense row (O(M) over M\u{b2} table)"), &dense),
        run(&format!("M={m} factored strips r={RANK} (O(r\u{b7}M))"),
            &factored),
        run(&format!("M={m} jit alibi (zero bias IO)"), &jit),
    ];
    let (d, f, j) = (
        rows[0].stats.mean(),
        rows[1].stats.mean(),
        rows[2].stats.mean(),
    );
    println!(
        "  M={m}: dense {} | factored {} ({:.2}x) | jit {} ({:.2}x)",
        human_secs(d),
        human_secs(f),
        d / f.max(1e-12),
        human_secs(j),
        d / j.max(1e-12)
    );
    for row in rows {
        out.row(row);
    }
}

/// Multi-session decode throughput through the coordinator: prefill S
/// sessions, round-robin STEPS decode steps each, drain, close.
fn bench_sessions(out: &mut Table, sessions: usize, it: usize) {
    const PREFILL: usize = 16;
    const STEPS: usize = 32;
    let n = 256usize;
    let geo = Geometry::square(n, C, 0, SRAM);
    let planner = Planner::default();
    let spec = BiasSpec::alibi(n, n, 0.0625);
    let opts = PlanOptions { causal: true, ..PlanOptions::default() };

    let mut coord = Coordinator::new(
        Arc::new(Runtime::empty()),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: sessions.max(4),
                max_wait: Duration::from_millis(1),
            },
            workers: 2,
            queue_depth: 256,
        },
    );
    coord
        .plan_and_register("decode_bench", &planner, &spec, &geo, &opts)
        .expect("register host plan");

    let mut rng = Xoshiro256::new(7);
    let qp = Tensor::randn(&[PREFILL, C], 1.0, &mut rng);
    let kp = Tensor::randn(&[PREFILL, C], 1.0, &mut rng);
    let vp = Tensor::randn(&[PREFILL, C], 1.0, &mut rng);
    let row: Vec<f32> = (0..C).map(|j| (j as f32 * 0.01).sin()).collect();

    let total_steps = sessions * STEPS;
    let label = format!("coordinator decode ({sessions} sessions \u{d7} \
                         {STEPS} steps)");
    let bench_row = bench_fn(&label, 1, (it / 4).max(2), || {
        let ids: Vec<u64> = (0..sessions)
            .map(|_| {
                let id = coord.open_session("decode_bench").expect("open");
                coord
                    .prefill(id, qp.clone(), kp.clone(), vp.clone())
                    .expect("prefill");
                id
            })
            .collect();
        let mut want = sessions; // the prefill responses
        for _ in 0..STEPS {
            for &id in &ids {
                coord.step(id, &row, &row, &row).expect("step");
                want += 1;
            }
        }
        coord.flush_all().expect("flush");
        let mut got = 0usize;
        while got < want {
            let resp = coord
                .recv_timeout(Duration::from_secs(30))
                .expect("response");
            resp.outputs.expect("decode ok");
            got += 1;
        }
        for id in ids {
            coord.close_session(id);
        }
    });
    let per_step = bench_row.stats.mean() / total_steps as f64;
    println!(
        "  {sessions} session(s): {} per step -> {:.0} steps/sec",
        human_secs(per_step),
        1.0 / per_step.max(1e-12)
    );
    out.row(bench_row);
    coord.shutdown();
}

fn main() {
    let it = iters(30);
    let mut out = Table::new(
        "decode: per-step bias-strip cost + session throughput",
    );
    println!("DECODE STEP: bias-strip cost per step (C={C}, r={RANK})");
    for m in [512usize, 2048, 4096] {
        bench_step_at(&mut out, m, it);
    }
    println!("\nDECODE THROUGHPUT: continuous-batched sessions");
    for s in [1usize, 4, 8] {
        bench_sessions(&mut out, s, it);
    }
    out.write_json("decode").expect("write BENCH_decode.json");
}
