//! Appendix I: multiplicative bias — the channel-repeat trick (Eq. 17),
//! its efficiency condition R ≤ √(S/C² + 1) (Corollary I.2), and the
//! measured cos(i−j) R=2 kernel.

use flashbias::benchkit::{bench_artifact, iters, paper_reference, Table};
use flashbias::bias::{CosMultiplicative, ExactBias};
use flashbias::iomodel::{self, Geometry};
use flashbias::runtime::Runtime;

fn main() {
    println!("APPENDIX I: multiplicative bias");
    paper_reference(&[
        "Eq. 17: q' = [q⊙φ_q,1 … q⊙φ_q,R] — bias as reweighted channel",
        "repeats; Cor. I.2: speedup iff R ≤ sqrt(S/C² + 1);",
        "Example I.1: cos(i−j) has exact R = 2",
    ]);

    // exact factorization of cos(i−j)
    let cosb = CosMultiplicative { n: 256, m: 256 };
    let (pq, pk) = cosb.factors();
    let err = pq.matmul_t(&pk).rel_err(&cosb.dense());
    println!("\n  cos(i−j) factorization (R=2): rel err {err:.2e}");
    assert!(err < 1e-4);

    // Cor I.2 threshold sweep
    println!("\n  Cor I.2 thresholds (R ≤ sqrt(S/C² + 1)):");
    for (c, s_bytes) in [(64usize, 100 * 1024usize), (32, 100 * 1024),
                         (64, 1024 * 1024)] {
        let s = s_bytes / 2; // fp16 elements
        let thr = iomodel::mult_bias_rank_threshold(c, s);
        println!("    C={c:3}, S={:4}KB: R ≤ {thr:.1}", s_bytes / 1024);
    }

    // IO crossover: factored multiplicative wins below the threshold
    let s = 100 * 1024 / 2;
    let thr = iomodel::mult_bias_rank_threshold(64, s) as usize;
    for r in [1usize, 2, thr.max(2), thr + 4] {
        let g = Geometry::square(8192, 64, r, s);
        let mult = iomodel::mult_factored_io(&g);
        let dense = iomodel::flash_dense_bias_io(&g);
        println!(
            "    R={r:2}: factored IO {:.2e} vs dense {:.2e} -> {}",
            mult,
            dense,
            if mult <= dense { "factored wins" } else { "dense wins" }
        );
    }

    // measured: the R=2 fused kernel vs the dense multiplicative graph
    let rt = Runtime::open_default().expect("make artifacts");
    let it = iters(10);
    let mut table = Table::new("measured multiplicative (N=256, C=64)");
    table.row(bench_artifact(&rt, "mult_dense_n256", 2, it));
    table.row(bench_artifact(&rt, "mult_factored_n256", 2, it));

    // numerics agree between dense Hadamard and the fused factored kernel
    let a = rt
        .load("mult_dense_n256")
        .unwrap()
        .run(&rt.example_inputs("mult_dense_n256").unwrap())
        .unwrap();
    let b = rt
        .load("mult_factored_n256")
        .unwrap()
        .run(&rt.example_inputs("mult_factored_n256").unwrap())
        .unwrap();
    let rel = b[0].as_f32().unwrap().rel_err(a[0].as_f32().unwrap());
    println!("\n  dense vs fused-factored rel err: {rel:.2e}");
    assert!(rel < 1e-3);
}
