//! Table 7 (Appendix B): Pangu-Weather 3-D window attention — SVD
//! FlashBias at R=56 on the 2×6×12=144 window; output difference vs the
//! dense code must be tiny (paper: 0.0003 vs 0.0128 for no-bias).
//!
//! Host-side reproduction through the plan API: each head's synthetic
//! 3-D relative table is a `BiasSpec::static_learned`, planned at the
//! paper's pinned R = 56 (`rank_override`), and executed against the
//! dense reference and the no-bias plan.

use flashbias::attention::{self, AttnOpts};
use flashbias::benchkit::{bench_fn, iters, paper_reference, Table};
use flashbias::bias::pangu_relative_bias;
use flashbias::iomodel::Geometry;
use flashbias::plan::{self, BiasSpec, Decision, PlanOptions, Planner};
use flashbias::tensor::Tensor;
use flashbias::util::Xoshiro256;

fn main() {
    println!("TABLE 7: Pangu-Weather 3-D window bias (Appendix B)");
    paper_reference(&[
        "Table 7: output diff (z-scored L2) FlashBias 0.0003 vs no-bias",
        "0.0128; time 98.0 -> 76.8 s/100it; mem 26.5 -> 12.2 GB; R=56",
        "keeps 99% energy; biases shared across longitude",
    ]);
    let window = (2usize, 6, 12);
    let n = window.0 * window.1 * window.2; // 144
    let heads = 4;
    let biases = pangu_relative_bias(window, heads, 0, 5, 0.02);

    let planner = Planner::default();
    let geo = Geometry::square(n, 32, 0, 100 * 1024 / 2);
    let pinned = PlanOptions {
        rank_override: Some(56),
        ..PlanOptions::default()
    };

    // rank profile at the energy target vs the paper's pinned rank
    let ranks: Vec<usize> = biases
        .iter()
        .map(|b| {
            planner
                .plan(&BiasSpec::static_learned(b.clone()), &geo,
                      &PlanOptions::default())
                .expect("plan")
                .measured_rank()
        })
        .collect();
    println!("  rank@99% per head: {ranks:?} of {n} (paper sets R = 56)");

    // output difference through the executed plans
    let mut rng = Xoshiro256::new(0);
    let q = Tensor::randn(&[n, 32], 1.0, &mut rng);
    let k = Tensor::randn(&[n, 32], 1.0, &mut rng);
    let v = Tensor::randn(&[n, 32], 1.0, &mut rng);
    let opts = AttnOpts::default();
    let nobias_plan = planner
        .plan(&BiasSpec::None, &geo, &PlanOptions::default())
        .expect("plan no-bias");
    let mut diff_fb = 0.0f32;
    let mut diff_nobias = 0.0f32;
    let mut fb_plans = Vec::new();
    for b in &biases {
        let dense_out = attention::attention(&q, &k, &v, Some(b), &opts);
        let fb_plan = planner
            .plan(&BiasSpec::static_learned(b.clone()), &geo, &pinned)
            .expect("plan R=56");
        let fb_out = plan::execute(&fb_plan, &q, &k, &v).expect("execute");
        let nob_out =
            plan::execute(&nobias_plan, &q, &k, &v).expect("execute");
        diff_fb = diff_fb.max(fb_out.rel_err(&dense_out));
        diff_nobias = diff_nobias.max(nob_out.rel_err(&dense_out));
        fb_plans.push(fb_plan);
    }
    println!(
        "  output diff: FlashBias(R=56) {diff_fb:.5} vs no-bias \
         {diff_nobias:.4} ({}x smaller)",
        (diff_nobias / diff_fb.max(1e-9)) as u32
    );
    assert!(diff_fb < diff_nobias / 5.0, "Table 7 shape violated");

    // longitude sharing: one plan per lat band serves every window in it
    let num_lon = 8;
    println!(
        "  longitude sharing: 1 plan per lat band serves {num_lon} windows \
         -> {num_lon}x fewer decompositions"
    );

    // host timing of the attention path (window-sized, per window)
    let it = iters(20);
    let mut table = Table::new("host attention per 3-D window (N=144)");
    let b0 = biases[0].clone();
    table.row(bench_fn("dense-bias attention", 2, it, || {
        let _ = attention::attention(&q, &k, &v, Some(&b0), &opts);
    }));
    let p0 = &fb_plans[0];
    table.row(bench_fn("flashbias plan (R=56)", 2, it, || {
        let _ = plan::execute(p0, &q, &k, &v).expect("execute");
    }));
    println!(
        "  plan summary: {}",
        p0.summary()
    );
    println!("  (N=144 is small — the paper notes the speedup grows with N)");
}
