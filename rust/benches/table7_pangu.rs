//! Table 7 (Appendix B): Pangu-Weather 3-D window attention — SVD
//! FlashBias at R=56 on the 2×6×12=144 window; output difference vs the
//! dense code must be tiny (paper: 0.0003 vs 0.0128 for no-bias).
//!
//! Host-side reproduction: synthetic 3-D relative tables with longitude
//! sharing, SVD truncation, attention output difference + timing.

use flashbias::attention::{self, AttnOpts};
use flashbias::benchkit::{bench_fn, iters, paper_reference, Table};
use flashbias::bias::pangu_relative_bias;
use flashbias::linalg::{rank_for_energy, svd_factors};
use flashbias::tensor::Tensor;
use flashbias::util::Xoshiro256;

fn main() {
    println!("TABLE 7: Pangu-Weather 3-D window bias (Appendix B)");
    paper_reference(&[
        "Table 7: output diff (z-scored L2) FlashBias 0.0003 vs no-bias",
        "0.0128; time 98.0 -> 76.8 s/100it; mem 26.5 -> 12.2 GB; R=56",
        "keeps 99% energy; biases shared across longitude",
    ]);
    let window = (2usize, 6, 12);
    let n = window.0 * window.1 * window.2; // 144
    let heads = 4;
    let r = 56;
    let biases = pangu_relative_bias(window, heads, 0, 5, 0.02);

    // rank profile
    let ranks: Vec<usize> =
        biases.iter().map(|b| rank_for_energy(b, 0.99)).collect();
    println!("  rank@99% per head: {ranks:?} of {n} (paper sets R = 56)");

    // output difference through attention
    let mut rng = Xoshiro256::new(0);
    let q = Tensor::randn(&[n, 32], 1.0, &mut rng);
    let k = Tensor::randn(&[n, 32], 1.0, &mut rng);
    let v = Tensor::randn(&[n, 32], 1.0, &mut rng);
    let opts = AttnOpts::default();
    let mut diff_fb = 0.0f32;
    let mut diff_nobias = 0.0f32;
    for b in &biases {
        let dense_out = attention::attention(&q, &k, &v, Some(b), &opts);
        let (pq, pk) = svd_factors(b, r);
        let fb_out =
            attention::attention_factored(&q, &k, &v, &pq, &pk, &opts);
        let nob_out = attention::attention(&q, &k, &v, None, &opts);
        diff_fb = diff_fb.max(fb_out.rel_err(&dense_out));
        diff_nobias = diff_nobias.max(nob_out.rel_err(&dense_out));
    }
    println!(
        "  output diff: FlashBias(R={r}) {diff_fb:.5} vs no-bias \
         {diff_nobias:.4} ({}x smaller)",
        (diff_nobias / diff_fb.max(1e-9)) as u32
    );
    assert!(diff_fb < diff_nobias / 5.0, "Table 7 shape violated");

    // longitude sharing: one SVD serves every window in the lat band
    let num_lon = 8;
    println!(
        "  longitude sharing: 1 SVD per lat band serves {num_lon} windows \
         -> {num_lon}x fewer decompositions"
    );

    // host timing of the attention path (window-sized, per window)
    let it = iters(20);
    let mut table = Table::new("host attention per 3-D window (N=144)");
    let b0 = biases[0].clone();
    table.row(bench_fn("dense-bias attention", 2, it, || {
        let _ = attention::attention(&q, &k, &v, Some(&b0), &opts);
    }));
    let (pq, pk) = svd_factors(&b0, r);
    table.row(bench_fn("flashbias attention (R=56)", 2, it, || {
        let _ = attention::attention_factored(&q, &k, &v, &pq, &pk, &opts);
    }));
    println!("  (N=144 is small — the paper notes the speedup grows with N)");
}
