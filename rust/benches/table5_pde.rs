//! Table 5: Transformer PDE solver with *learnable* weighted 3-D distance
//! bias — training + inference memory/time across N; dense methods OOM,
//! FlashBias scales. The per-N geometry, rank and algorithm all come from
//! the planner: `BiasSpec::spatial` plans to exact rank-9 factors and the
//! simulator runs `plan.algorithm()` against the dense-bias baseline.
//!
//! Paper (per 100 iters): train N=8192 FlashAttention 12.8GB/15.4s, OOM at
//! 16384+; FlashBias 1.46GB/4.54s ... 2.97GB/51.1s at 32186. Inference
//! FlexAttention OOM ≥16384; FlashBias 1.13GB/12.7s at 32186.

use flashbias::benchkit::{bench_artifact, iters, paper_reference, Table};
use flashbias::bias::synthetic_car_cloud;
use flashbias::iomodel::Geometry;
use flashbias::plan::{BiasSpec, PlanOptions, Planner};
use flashbias::runtime::Runtime;
use flashbias::simulator::{
    simulate_fwd, simulate_train_step, Algorithm, HwModel,
};
use flashbias::util::human_bytes;

fn main() {
    println!("TABLE 5: PDE solver, learnable spatial-distance bias");
    paper_reference(&[
        "Table 5 train (GB / s-per-100it): FA 12.8/15.4 OOM OOM;",
        "  FlashBias 1.46/4.54  2.02/14.7  2.97/51.1 at N=8192/16384/32186",
        "Table 5 infer: FlexAttention 21.9GB/184s@8192, OOM beyond;",
        "  FlashBias 0.98/1.22  1.03/3.48  1.13/12.7",
    ]);

    // plan the bias at each paper N: the spatial spec always plans to the
    // exact rank-9 factors (8 heads, C=128)
    let hw = HwModel::default();
    let planner = Planner::default();
    let opts = PlanOptions::default();
    println!("\n-- plan-driven simulation (8 heads, C=128) --");
    println!(
        "  {:>8} | {:>10} | {:>24} | {:>24}",
        "N", "plan", "dense (train mem)", "flashbias (train mem)"
    );
    for n in [8192usize, 16384, 32186] {
        let cloud = synthetic_car_cloud(n, 0);
        let spec = BiasSpec::spatial(cloud.clone(), cloud, None);
        let g = Geometry::square(n, 128, 0, hw.sram_elems);
        let plan = planner.plan(&spec, &g, &opts).expect("plan spatial");
        let dense =
            simulate_train_step(Algorithm::FlashDenseBias, &plan.geometry,
                                &hw);
        let fact = simulate_train_step(plan.algorithm(), &plan.geometry,
                                       &hw);
        println!(
            "  {n:>8} | {:>7} R={} | {:>24} | {:>24}",
            plan.mode_name(),
            plan.rank(),
            human_bytes(dense.hbm_peak * 8 * 4),
            human_bytes(fact.hbm_peak * 8 * 4)
        );
    }
    println!("  (dense quadratic-gradient storage is what OOMs in Table 5)");

    println!("\n-- plan-driven inference cost --");
    for n in [8192usize, 16384, 32186] {
        let cloud = synthetic_car_cloud(n, 1);
        let spec = BiasSpec::spatial(cloud.clone(), cloud, None);
        let g = Geometry::square(n, 128, 0, hw.sram_elems);
        let plan = planner.plan(&spec, &g, &opts).expect("plan spatial");
        let dense = simulate_fwd(Algorithm::FlashDenseBias, &plan.geometry,
                                 &hw);
        let flex =
            simulate_fwd(Algorithm::FlexLike, &plan.geometry, &hw);
        let fact = simulate_fwd(plan.algorithm(), &plan.geometry, &hw);
        println!(
            "  N={n:>6}: dense {:.3e}  flex {:.3e}  flashbias {:.3e} \
             (model predicts {:.2}x; sim dense/fb {:.2}x)",
            dense.cost(&hw),
            flex.cost(&hw),
            fact.cost(&hw),
            plan.io_saving(),
            dense.cost(&hw) / fact.cost(&hw)
        );
    }

    // measured on XLA-CPU at the built sizes (requires `make artifacts`)
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            println!("\n  measured section skipped ({e})");
            return;
        }
    };
    let it = iters(6);
    let mut table = Table::new("measured fwd (N=512, H=8, 2 layers)");
    for variant in ["nobias", "dense", "factored"] {
        let name = format!("pde_{variant}_n512");
        if rt.spec(&name).is_some() {
            table.row(bench_artifact(&rt, &name, 1, it));
        }
    }
    let mut train = Table::new("measured train step (N=512)");
    for variant in ["dense", "factored"] {
        let name = format!("pde_train_{variant}_n512");
        if rt.spec(&name).is_some() {
            train.row(bench_artifact(&rt, &name, 1, it.min(4)));
        }
    }
}
