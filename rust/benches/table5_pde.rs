//! Table 5: Transformer PDE solver with *learnable* weighted 3-D distance
//! bias — training + inference memory/time across N; dense methods OOM,
//! FlashBias scales.
//!
//! Paper (per 100 iters): train N=8192 FlashAttention 12.8GB/15.4s, OOM at
//! 16384+; FlashBias 1.46GB/4.54s ... 2.97GB/51.1s at 32186. Inference
//! FlexAttention OOM ≥16384; FlashBias 1.13GB/12.7s at 32186.

use flashbias::benchkit::{bench_artifact, iters, paper_reference, Table};
use flashbias::iomodel::Geometry;
use flashbias::runtime::Runtime;
use flashbias::simulator::{
    simulate_fwd, simulate_train_step, Algorithm, HwModel,
};
use flashbias::util::human_bytes;

fn main() {
    println!("TABLE 5: PDE solver, learnable spatial-distance bias");
    paper_reference(&[
        "Table 5 train (GB / s-per-100it): FA 12.8/15.4 OOM OOM;",
        "  FlashBias 1.46/4.54  2.02/14.7  2.97/51.1 at N=8192/16384/32186",
        "Table 5 infer: FlexAttention 21.9GB/184s@8192, OOM beyond;",
        "  FlashBias 0.98/1.22  1.03/3.48  1.13/12.7",
    ]);

    // simulated at the paper's N (8 heads, C=128, R=9, per train step)
    let hw = HwModel::default();
    println!("\n-- simulated peak memory (8 heads, C=128, R=9) --");
    println!("  {:>8} | {:>24} | {:>24}", "N", "dense (train)",
             "flashbias (train)");
    for n in [8192usize, 16384, 32186] {
        let g = Geometry::square(n, 128, 9, hw.sram_elems);
        let dense = simulate_train_step(Algorithm::FlashDenseBias, &g, &hw);
        let fact = simulate_train_step(Algorithm::FlashBias(9), &g, &hw);
        println!(
            "  {n:>8} | {:>24} | {:>24}",
            human_bytes(dense.hbm_peak * 8 * 4),
            human_bytes(fact.hbm_peak * 8 * 4)
        );
    }
    println!("  (dense quadratic-gradient storage is what OOMs in Table 5)");

    println!("\n-- simulated inference cost --");
    for n in [8192usize, 16384, 32186] {
        let g = Geometry::square(n, 128, 9, hw.sram_elems);
        let dense = simulate_fwd(Algorithm::FlashDenseBias, &g, &hw);
        let flex = simulate_fwd(Algorithm::FlexLike, &g, &hw);
        let fact = simulate_fwd(Algorithm::FlashBias(9), &g, &hw);
        println!(
            "  N={n:>6}: dense {:.3e}  flex {:.3e}  flashbias {:.3e} \
             (ratio dense/fb {:.2}x)",
            dense.cost(&hw),
            flex.cost(&hw),
            fact.cost(&hw),
            dense.cost(&hw) / fact.cost(&hw)
        );
    }

    // measured on XLA-CPU at the built sizes
    let rt = Runtime::open_default().expect("make artifacts");
    let it = iters(6);
    let mut table = Table::new("measured fwd (N=512, H=8, 2 layers)");
    for variant in ["nobias", "dense", "factored"] {
        let name = format!("pde_{variant}_n512");
        if rt.spec(&name).is_some() {
            table.row(bench_artifact(&rt, &name, 1, it));
        }
    }
    let mut train = Table::new("measured train step (N=512)");
    for variant in ["dense", "factored"] {
        let name = format!("pde_train_{variant}_n512");
        if rt.spec(&name).is_some() {
            train.row(bench_artifact(&rt, &name, 1, it.min(4)));
        }
    }
}
