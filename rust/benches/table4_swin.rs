//! Table 4: SwinV2-B — SVD-decomposed relative-position bias: accuracy
//! preserved, time/memory reduced; offline SVD cost reported. The whole
//! offline pipeline goes through the plan API: every head's table is a
//! `BiasSpec::static_learned` and the planner runs the rank test + SVD.
//!
//! Paper: Acc@1 87.14→87.19 (+0.04), time 0.479→0.190 s (−60%), mem
//! 12.8→9.4 GB (−27%); offline SVD of all biases 4.79 s.

use flashbias::benchkit::{
    bench_artifact, bias_input_bytes, iters, paper_reference, time_once,
    Table,
};
use flashbias::bias::swin_relative_bias;
use flashbias::factorstore::FactorStore;
use flashbias::iomodel::Geometry;
use flashbias::plan::{BiasSpec, PlanOptions, Planner};
use flashbias::runtime::Runtime;
use flashbias::util::human_bytes;

fn main() {
    println!("TABLE 4: SwinV2 window attention with learned bias");
    paper_reference(&[
        "Table 4: Official 0.479s/12829MB -> FlashBias 0.190s/9429MB,",
        "Acc@1 87.144%->87.186%, Acc@5 98.232%->98.220% (no loss);",
        "offline SVD of all biases: 4.79s",
    ]);
    let it = iters(10);

    // offline planning cost (the Table 4 footnote): rank scan + SVD for
    // every (layer, head) table, at the paper's pinned R = 16
    let window = (12, 12);
    let n = window.0 * window.1;
    let heads = 4;
    let layers = 4;
    let planner = Planner::default();
    let geo = Geometry::square(n, 32, 0, 100 * 1024 / 2);
    let opts = PlanOptions {
        rank_override: Some(16),
        ..PlanOptions::default()
    };
    let plans = time_once("offline planning of all biases (R=16)", || {
        (0..layers)
            .flat_map(|li| {
                swin_relative_bias(window, heads, li as u64, 6, 0.02)
                    .into_iter()
                    .map(|b| {
                        planner
                            .plan(&BiasSpec::static_learned(b), &geo,
                                  &opts)
                            .expect("plan swin table")
                    })
                    .collect::<Vec<_>>()
            })
            .collect::<Vec<_>>()
    });
    let total_factor_bytes: usize =
        plans.iter().map(|p| p.bias_storage_bytes).sum();
    println!(
        "  {} plans, factor storage {} (dense would be {})",
        plans.len(),
        human_bytes(total_factor_bytes as u64),
        human_bytes((plans.len() * n * n * 4) as u64)
    );

    // store-amortized planning — the tentpole point of the Table 4
    // footnote: the offline SVD cost is paid ONCE, not per plan. The
    // first pass through an empty FactorStore pays every SVD; the
    // second pass is all hits and does zero decomposition work.
    let specs: Vec<BiasSpec> = (0..layers)
        .flat_map(|li| {
            swin_relative_bias(window, heads, li as u64, 6, 0.02)
                .into_iter()
                .map(BiasSpec::static_learned)
                .collect::<Vec<_>>()
        })
        .collect();
    let store = FactorStore::unbounded();
    for label in [
        "cold pass: plan all tables into an empty store",
        "warm pass: re-plan all tables (store hits)",
    ] {
        time_once(label, || {
            for spec in &specs {
                planner
                    .plan_with_store(spec, &geo, &opts, &store)
                    .expect("plan through store");
            }
        });
    }
    let stats = store.stats();
    assert_eq!(stats.misses as usize, specs.len());
    assert_eq!(stats.hits as usize, specs.len());
    println!("  {}", stats.summary());

    // rank profile at the energy target (Figure 8 companion)
    let measured_opts = PlanOptions::default();
    let ranks: Vec<usize> = swin_relative_bias(window, heads, 0, 6, 0.02)
        .into_iter()
        .map(|b| {
            planner
                .plan(&BiasSpec::static_learned(b), &geo, &measured_opts)
                .expect("plan")
                .measured_rank()
        })
        .collect();
    println!("  rank@99% per head: {ranks:?} of {n}");

    // measured artifacts (optional: requires `make artifacts`)
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            println!("  measured section skipped ({e})");
            return;
        }
    };
    let mut table = Table::new("Swin classifier (N=144, 4 layers, H=4)");
    for name in ["swin_dense", "swin_factored"] {
        let mut row = bench_artifact(&rt, name, 2, it);
        row.note = format!(
            "activation+factor bytes {}",
            human_bytes(bias_input_bytes(&rt, name))
        );
        table.row(row);
    }

    // accuracy preservation
    let run = |name: &str| {
        rt.load(name)
            .unwrap()
            .run(&rt.example_inputs(name).unwrap())
            .unwrap()[0]
            .as_f32()
            .unwrap()
            .clone()
    };
    let d = run("swin_dense");
    let f = run("swin_factored");
    let argmax = |t: &flashbias::tensor::Tensor| {
        t.data()
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    };
    println!(
        "  logits rel err {:.4}; top-1 preserved: {}",
        f.rel_err(&d),
        argmax(&d) == argmax(&f)
    );
    assert_eq!(argmax(&d), argmax(&f), "Table 4 accuracy claim violated");
}
