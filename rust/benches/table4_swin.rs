//! Table 4: SwinV2-B — SVD-decomposed relative-position bias: accuracy
//! preserved, time/memory reduced; offline SVD cost reported.
//!
//! Paper: Acc@1 87.14→87.19 (+0.04), time 0.479→0.190 s (−60%), mem
//! 12.8→9.4 GB (−27%); offline SVD of all biases 4.79 s.

use flashbias::benchkit::{
    bench_artifact, bias_input_bytes, iters, paper_reference, time_once,
    Table,
};
use flashbias::bias::swin_relative_bias;
use flashbias::linalg::{rank_for_energy, svd_factors};
use flashbias::runtime::Runtime;
use flashbias::util::human_bytes;

fn main() {
    println!("TABLE 4: SwinV2 window attention with learned bias");
    paper_reference(&[
        "Table 4: Official 0.479s/12829MB -> FlashBias 0.190s/9429MB,",
        "Acc@1 87.144%->87.186%, Acc@5 98.232%->98.220% (no loss);",
        "offline SVD of all biases: 4.79s",
    ]);
    let rt = Runtime::open_default().expect("make artifacts");
    let it = iters(10);

    // offline SVD cost (the Table 4 footnote)
    let window = (12, 12);
    let heads = 4;
    let layers = 4;
    time_once("offline SVD of all biases", || {
        for li in 0..layers {
            for b in swin_relative_bias(window, heads, li as u64, 6, 0.02) {
                let _ = svd_factors(&b, 16);
            }
        }
    });

    // rank profile (Figure 8 companion)
    let biases = swin_relative_bias(window, heads, 0, 6, 0.02);
    let ranks: Vec<usize> =
        biases.iter().map(|b| rank_for_energy(b, 0.99)).collect();
    println!("  rank@99% per head: {ranks:?} of {}", window.0 * window.1);

    let mut table = Table::new("Swin classifier (N=144, 4 layers, H=4)");
    for name in ["swin_dense", "swin_factored"] {
        let mut row = bench_artifact(&rt, name, 2, it);
        row.note = format!(
            "activation+factor bytes {}",
            human_bytes(bias_input_bytes(&rt, name))
        );
        table.row(row);
    }

    // accuracy preservation
    let run = |name: &str| {
        rt.load(name)
            .unwrap()
            .run(&rt.example_inputs(name).unwrap())
            .unwrap()[0]
            .as_f32()
            .unwrap()
            .clone()
    };
    let d = run("swin_dense");
    let f = run("swin_factored");
    let argmax = |t: &flashbias::tensor::Tensor| {
        t.data()
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    };
    println!(
        "  logits rel err {:.4}; top-1 preserved: {}",
        f.rel_err(&d),
        argmax(&d) == argmax(&f)
    );
    assert_eq!(argmax(&d), argmax(&f), "Table 4 accuracy claim violated");
}
