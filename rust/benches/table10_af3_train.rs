//! Table 10 (Appendix D): training AlphaFold-3 with FlashBias — replacing
//! the bias projection with factor nets at init saves ~15% step time and
//! ~18% memory. Reproduced with (a) the simulator at the paper's crop
//! N=384 and (b) the measured plain-Transformer train-step artifacts.

use flashbias::benchkit::{bench_artifact, iters, paper_reference, Table};
use flashbias::iomodel::Geometry;
use flashbias::runtime::Runtime;
use flashbias::simulator::{simulate_train_step, Algorithm, HwModel};
use flashbias::util::human_bytes;

fn main() {
    println!("TABLE 10: training with factored-from-init bias");
    paper_reference(&[
        "Table 10 (crop 384): open code 165s/23.57GB per 10 it;",
        "FA w/ bias 153s/23.57GB; FlashBias 140s/19.39GB",
        "(−15.2% time, −17.7% memory)",
    ]);

    // simulated at the paper's crop size (triangle-attention geometry:
    // N=384 rows of N-token attention, H=4, R=96 per Appendix H)
    let hw = HwModel::default();
    let g = Geometry::square(384, 64, 96, hw.sram_elems);
    let rows = 384u64; // triangle attention: one attention per pair row
    let dense = simulate_train_step(Algorithm::FlashDenseBias, &g, &hw);
    let fact = simulate_train_step(Algorithm::FlashBias(96), &g, &hw);
    println!(
        "\n  simulated train step (triangle attention, crop 384, H=4):\n  \
         dense: cost {:.3e}, peak {}\n  flashbias: cost {:.3e}, peak {}\n  \
         -> time ratio {:.2}, memory ratio {:.2}",
        dense.cost(&hw) * rows as f64 * 4.0,
        human_bytes(dense.hbm_peak * 4 * 4 * rows),
        fact.cost(&hw) * rows as f64 * 4.0,
        human_bytes(fact.hbm_peak * 4 * 4 * rows),
        fact.cost(&hw) / dense.cost(&hw),
        fact.hbm_peak as f64 / dense.hbm_peak as f64,
    );
    // The robust Table 10 signal is MEMORY (paper: −17.7%): the dense
    // N×N bias + its gradient disappear. At R = 96 ≈ 1.5·C the simulator's
    // conservative block constants price the widened q/k streams above the
    // bias stream saved, so the *time* win at crop 384 shows up in the
    // measured path below (pairformer artifacts), not in the IO model.
    assert!(fact.hbm_peak < dense.hbm_peak);
    let mem_ratio = fact.hbm_peak as f64 / dense.hbm_peak as f64;
    assert!(mem_ratio < 0.95, "memory saving too small: {mem_ratio}");

    // measured: train-step artifacts (bias gradient traffic is real here)
    let rt = Runtime::open_default().expect("make artifacts");
    let it = iters(5);
    let mut table =
        Table::new("measured train step (2-layer Transformer, N=256)");
    for variant in ["dense", "factored"] {
        let name = format!("plain_train_{variant}_n256");
        if rt.spec(&name).is_some() {
            table.row(bench_artifact(&rt, &name, 1, it));
        }
    }
    if let Some(delta) =
        table.delta("plain_train_dense_n256", "plain_train_factored_n256")
    {
        println!(
            "  factored train step saves {} per step",
            flashbias::util::human_secs(delta.max(0.0))
        );
    }
}
