//! Figure 5: implementation comparison — the fused Pallas FlashBias
//! kernel vs the PyTorch-SDPA-style concat graph (both AOT-compiled,
//! C=128, H=8, R=8), measured on XLA-CPU.
//!
//! Paper: Triton fused kernel wins the forward pass; SDPA-concat wins
//! training. On XLA-CPU both lower to the same backend so the gap is
//! smaller, but both must agree numerically and scale identically.

use flashbias::benchkit::{bench_artifact, iters, paper_reference, Table};
use flashbias::runtime::Runtime;

fn main() {
    println!("FIG5: fused-kernel vs concat-SDPA implementations");
    paper_reference(&[
        "Fig 5: Triton fused FlashBias fastest in forward; SDPA-based",
        "version better for training; vanilla SDPA OOMs at long N.",
    ]);
    let rt = Runtime::open_default().expect("make artifacts");
    let it = iters(10);
    let mut table = Table::new("Fig 5 measured (C=128, H=8, R=8)");
    for n in [256usize, 512] {
        for impl_ in ["pallas", "sdpa"] {
            let name = format!("fig5_{impl_}_n{n}");
            if rt.spec(&name).is_some() {
                table.row(bench_artifact(&rt, &name, 2, it));
            }
        }
    }
    // numeric agreement between the two implementations
    let a = rt
        .load("fig5_pallas_n256")
        .unwrap()
        .run(&rt.example_inputs("fig5_pallas_n256").unwrap())
        .unwrap();
    let b = rt
        .load("fig5_sdpa_n256")
        .unwrap()
        .run(&rt.example_inputs("fig5_sdpa_n256").unwrap())
        .unwrap();
    let rel = a[0].as_f32().unwrap().rel_err(b[0].as_f32().unwrap());
    assert!(rel < 1e-3, "implementations diverge: {rel}");
    println!("\nimplementations agree: rel err {rel:.2e}");
}
