//! Figure 4: efficiency ratio of each method over "Pure FlashAttention"
//! (method / pure-flash), training and inference, memory and time —
//! regenerated from the tiled-execution simulator.

use flashbias::benchkit::paper_reference;
use flashbias::iomodel::Geometry;
use flashbias::simulator::{
    simulate_fwd, simulate_train_step, Algorithm, HwModel,
};

fn main() {
    println!("FIG4: efficiency ratio over Pure FlashAttention");
    paper_reference(&[
        "Fig 4: FlashBias stays closest to 1.0x across N; FlexAttention is",
        "competitive on time at short N but never reduces memory; dense",
        "bias diverges quadratically in both.",
    ]);
    let hw = HwModel::default();
    let algs = [
        (Algorithm::FlashDenseBias, "flash+bias"),
        (Algorithm::FlexLike, "flex-like"),
        (Algorithm::FlashBias(16), "flashbias"),
    ];
    for phase in ["inference", "training"] {
        println!("\n  {phase}: cost ratio | memory ratio (vs pure flash)");
        print!("  {:>8}", "N");
        for (_, name) in algs {
            print!(" | {name:>22}");
        }
        println!();
        for n in [1024usize, 2048, 4096, 8192, 16384] {
            let pure_g = Geometry::square(n, 64, 0, hw.sram_elems);
            let pure = if phase == "training" {
                simulate_train_step(Algorithm::Flash, &pure_g, &hw)
            } else {
                simulate_fwd(Algorithm::Flash, &pure_g, &hw)
            };
            print!("  {n:>8}");
            for (alg, _) in algs {
                let g = Geometry::square(n, 64, 16, hw.sram_elems);
                let rep = if phase == "training" {
                    simulate_train_step(alg, &g, &hw)
                } else {
                    simulate_fwd(alg, &g, &hw)
                };
                print!(
                    " | {:>10.2}x {:>9.2}x",
                    rep.cost(&hw) / pure.cost(&hw),
                    rep.hbm_peak as f64 / pure.hbm_peak as f64
                );
            }
            println!();
        }
    }
    // sanity for the bench harness: FlashBias ratio must stay below
    // dense-bias ratio at the largest N
    let hw2 = HwModel::default();
    let g = Geometry::square(16384, 64, 16, hw2.sram_elems);
    let pure = simulate_fwd(
        Algorithm::Flash,
        &Geometry::square(16384, 64, 0, hw2.sram_elems),
        &hw2,
    )
    .cost(&hw2);
    let fb = simulate_fwd(Algorithm::FlashBias(16), &g, &hw2).cost(&hw2);
    let dense = simulate_fwd(Algorithm::FlashDenseBias, &g, &hw2).cost(&hw2);
    assert!(fb / pure < dense / pure);
    println!("\nfig4 OK (flashbias ratio {:.2}x < dense {:.2}x)",
             fb / pure, dense / pure);
}
