//! Serving-load bench (ISSUE 9 network router), written to
//! `BENCH_serving_load.json`:
//!
//! Spawns a real [`NetServer`] on loopback (empty runtime, demo ALiBi
//! host plan) and drives mixed session workloads — `open` → 32-row
//! `prefill` → 4 × `step` → `close` — through real TCP connections at
//! three offered-load levels (16 / 64 / 96 concurrent connections).
//! Per level it records:
//!
//! * `latency conns=N` — per-operation round-trip stats (mean/p50/p99
//!   seconds), the client-observed queueing + batching + execution.
//! * `throughput conns=N (op/s)` — completed operations per wall
//!   second, with the error tally in the note.
//!
//! Then the continuous-batching payoff: the 64-connection level is
//! re-run against a `ServeConfig::batch1()` server (every flush serves
//! exactly one request — the no-batching strawman) and the throughput
//! ratio is reported. Outside single-iteration CI smoke runs, the
//! continuous server must win.
//!
//! Server-side admission/flush counters (queue wait, queue depth,
//! flush reasons, batch occupancy) are fetched over the wire via the
//! `stats` op and printed for the log.
//!
//! Honors `FLASHBIAS_BENCH_ITERS` (CI smoke runs a single iteration)
//! and `FLASHBIAS_BENCH_JSON_DIR` for the JSON drop location.

use std::sync::Arc;
use std::time::Duration;

use flashbias::benchkit::{iters, Row, Table};
use flashbias::coordinator::Coordinator;
use flashbias::runtime::Runtime;
use flashbias::server::{
    demo_plan_name, fetch_stats, register_demo_plan, run_wave,
    wait_ready, NetServer, ServeConfig, WaveConfig, WaveOutcome,
};
use flashbias::util::Stats;

const PLAN_N: usize = 256;
const PREFILL_ROWS: usize = 32;
const DECODE_STEPS: usize = 4;

fn spawn_server(cfg: ServeConfig) -> NetServer {
    let coord = Coordinator::new(
        Arc::new(Runtime::empty()),
        cfg.coordinator_config(),
    );
    register_demo_plan(&coord, PLAN_N).expect("register demo plan");
    let srv = NetServer::serve(coord, cfg, "127.0.0.1:0")
        .expect("bind netserver");
    assert!(
        wait_ready(&srv.addr().to_string(), Duration::from_secs(10)),
        "server did not come up"
    );
    srv
}

fn wave_at(addr: &str, connections: usize,
           requests: usize) -> WaveOutcome {
    let out = run_wave(&WaveConfig {
        addr: addr.to_string(),
        plan: demo_plan_name(PLAN_N),
        connections,
        requests_per_conn: requests,
        prefill_rows: PREFILL_ROWS,
        decode_steps: DECODE_STEPS,
        seed: 0x5e2f,
    });
    assert_eq!(out.protocol_errors, 0, "protocol errors under load");
    assert_eq!(out.errors, 0, "typed error frames under load");
    assert!(out.completed > 0, "no requests completed");
    out
}

/// Record one level as two rows: the latency distribution and the
/// throughput scalar.
fn record(out: &mut Table, level: &str, wave: &WaveOutcome) {
    out.row(Row {
        label: format!("latency {level}"),
        stats: wave.latency.clone(),
        bytes: None,
        note: format!(
            "completed={} overloaded={}",
            wave.completed, wave.overloaded
        ),
    });
    let mut tp = Stats::new();
    tp.push(wave.throughput());
    out.row(Row {
        label: format!("throughput {level} (op/s)"),
        stats: tp,
        bytes: None,
        note: format!("wall={:.2}s", wave.wall_secs),
    });
}

fn main() {
    let it = iters(8);
    // enough interactions per connection that batching has material to
    // work with, scaled down for CI smoke
    let requests = it.clamp(2, 16);
    let mut out = Table::new(
        "serving load: latency/throughput vs offered connections",
    );

    let server = spawn_server(ServeConfig::default());
    let addr = server.addr().to_string();
    let mut continuous_64 = 0.0f64;
    for conns in [16usize, 64, 96] {
        let wave = wave_at(&addr, conns, requests);
        println!(
            "  conns={conns}: {:.1} op/s p50={:.1}ms p99={:.1}ms \
             (completed={}, overloaded={})",
            wave.throughput(),
            wave.latency.p50() * 1e3,
            wave.latency.p99() * 1e3,
            wave.completed,
            wave.overloaded,
        );
        if conns == 64 {
            continuous_64 = wave.throughput();
        }
        record(&mut out, &format!("conns={conns}"), &wave);
    }
    match fetch_stats(&addr) {
        Ok(stats) => println!("  server stats: {}", stats.dump()),
        Err(e) => println!("  server stats unavailable: {e}"),
    }
    server.shutdown();

    // the no-batching strawman: identical offered load, but every
    // flush serves exactly one request
    let baseline = spawn_server(ServeConfig::batch1());
    let addr = baseline.addr().to_string();
    let wave = wave_at(&addr, 64, requests);
    let batch1_64 = wave.throughput();
    record(&mut out, "conns=64 batch1-baseline", &wave);
    baseline.shutdown();

    let speedup = continuous_64 / batch1_64.max(1e-9);
    println!(
        "  continuous batching at 64 conns: {continuous_64:.1} op/s \
         vs batch1 {batch1_64:.1} op/s ({speedup:.2}x)"
    );
    if it > 1 {
        assert!(
            continuous_64 > batch1_64,
            "continuous batching ({continuous_64:.1} op/s) did not \
             beat the batch-size-1 baseline ({batch1_64:.1} op/s)"
        );
    }

    out.write_json("serving_load")
        .expect("write BENCH_serving_load.json");
}
