//! Table 9 (Appendix D): AlphaFold-3 component breakdown — triangle
//! self-attention (cubic) dominates inference time (53.3%), then triangle
//! multiplication (37.1%); everything else is small. Reproduced with a
//! host-side Pairformer block at reduced N.

use flashbias::attention::{self, AttnOpts};
use flashbias::benchkit::paper_reference;
use flashbias::tensor::Tensor;
use flashbias::util::{Timer, Xoshiro256};

fn main() {
    println!("TABLE 9: Pairformer component breakdown");
    paper_reference(&[
        "Table 9 (PDB 7wux): data embedding 1.91s (7.1%), triangle",
        "self-attention 14.32s (53.3%), triangle multiplication 9.97s",
        "(37.1%), single attention w/ pair bias 0.48s (1.8%), FFN 0.7%",
    ]);

    let n = 96;
    let (c, cz, d) = (64usize, 16usize, 64usize);
    let mut rng = Xoshiro256::new(0);
    let z: Vec<Tensor> = (0..n)
        .map(|_| Tensor::randn(&[n, cz], 0.5, &mut rng))
        .collect(); // pair rep as rows
    let single = Tensor::randn(&[n, d], 1.0, &mut rng);
    let w_embed = Tensor::randn(&[d, d], 0.1, &mut rng);
    let w_in = Tensor::randn(&[cz, cz], 0.3, &mut rng);
    let w_out = Tensor::randn(&[cz, cz], 0.3, &mut rng);
    let wq = Tensor::randn(&[cz, c], 0.3, &mut rng);
    let w_ff1 = Tensor::randn(&[d, 2 * d], 0.1, &mut rng);
    let w_ff2 = Tensor::randn(&[2 * d, d], 0.1, &mut rng);
    let pair_bias = Tensor::randn(&[n, n], 0.3, &mut rng);
    let opts = AttnOpts::default();

    let time = |f: &mut dyn FnMut()| -> f64 {
        let t = Timer::start();
        f();
        t.elapsed_secs()
    };

    // 1. data embedding: linear over the single rep (linear in N)
    let t_embed = time(&mut || {
        let _ = single.matmul(&w_embed);
    });

    // 2. triangle self-attention: one attention per pair-rep row, with
    //    bias — cubic in N
    let t_tri_attn = time(&mut || {
        for zi in &z {
            let q = zi.matmul(&wq);
            let _ =
                attention::attention(&q, &q, &q, Some(&pair_bias), &opts);
        }
    });

    // 3. triangle multiplication: z_nm += Σ_k a_nk ⊙ b_mk — cubic in N
    let t_tri_mul = time(&mut || {
        let a: Vec<Tensor> = z.iter().map(|zi| zi.matmul(&w_in)).collect();
        let b: Vec<Tensor> = z.iter().map(|zi| zi.matmul(&w_out)).collect();
        for an in &a {
            for bm in &b {
                // per-channel contraction over k
                let mut acc = vec![0.0f32; cz];
                for k in 0..n {
                    for ch in 0..cz {
                        acc[ch] += an.at2(k, ch) * bm.at2(k, ch);
                    }
                }
                std::hint::black_box(&acc);
            }
        }
    });

    // 4. single attention with pair bias — quadratic
    let t_single = time(&mut || {
        let q = single.slice_cols(0, c.min(d));
        let _ = attention::attention(&q, &q, &q, Some(&pair_bias), &opts);
    });

    // 5. feedforward — linear
    let t_ffn = time(&mut || {
        let h = single.matmul(&w_ff1).map(|x| x.max(0.0));
        let _ = h.matmul(&w_ff2);
    });

    let total = t_embed + t_tri_attn + t_tri_mul + t_single + t_ffn;
    println!("\n  component                      time      ratio  (paper)");
    for (name, t, paper) in [
        ("data embedding", t_embed, "7.1%"),
        ("triangle self-attention", t_tri_attn, "53.3%"),
        ("triangle multiplication", t_tri_mul, "37.1%"),
        ("single attn w/ pair bias", t_single, "1.8%"),
        ("feedforward", t_ffn, "0.7%"),
    ] {
        println!(
            "  {name:28} {:>10} {:>6.1}%  ({paper})",
            flashbias::util::human_secs(t),
            t / total * 100.0
        );
    }
    // Table 9's shape: the two cubic components dominate
    assert!(
        (t_tri_attn + t_tri_mul) / total > 0.8,
        "triangle ops should dominate"
    );
    assert!(t_tri_attn > t_single * 5.0);
    println!(
        "\n  triangle ops = {:.1}% of the block — the paper's target for \
         FlashBias",
        (t_tri_attn + t_tri_mul) / total * 100.0
    );
}
