//! Table 8 (Appendix C): ALiBi with in-kernel JIT generation — when the
//! factor strips are created inside the kernel from block coordinates
//! (zero bias IO), FlashBias matches FlashAttention's ALiBi_slopes
//! feature exactly. Through the plan API this is `prefer_jit`: the
//! planner emits `ExecMode::Jit` with zero bias storage and the same
//! numerics as the streamed-strip plan.
//!
//! Paper: w/o bias 119.3/38.77, ALiBi_slopes 119.8/38.98, FlashBias-JIT
//! 119.8/38.98 (train/test s per 100 it) — i.e. indistinguishable.

use flashbias::benchkit::{bench_artifact, iters, paper_reference, Table};
use flashbias::iomodel::Geometry;
use flashbias::plan::{self, BiasSpec, PlanOptions, Planner};
use flashbias::runtime::Runtime;
use flashbias::tensor::Tensor;
use flashbias::util::Xoshiro256;

fn main() {
    println!("TABLE 8: ALiBi factor strips generated in-kernel (JIT)");
    paper_reference(&[
        "Table 8: FlashAttention w/o bias 119.3/38.77; ALiBi_slopes",
        "119.8/38.98; FlashBias w/ JIT decomposition 119.8/38.98 —",
        "the two JIT approaches are the same speed",
    ]);

    // plan-level story: jit and factored plans agree numerically; jit
    // carries zero bias bytes
    let planner = Planner::default();
    let n = 256;
    let geo = Geometry::square(n, 64, 0, 100 * 1024 / 2);
    let spec = BiasSpec::alibi(n, n, 0.25);
    let causal = PlanOptions {
        causal: true,
        ..PlanOptions::default()
    };
    let fact = planner.plan(&spec, &geo, &causal).expect("factored plan");
    let jit = planner
        .plan(
            &spec,
            &geo,
            &PlanOptions {
                prefer_jit: true,
                ..causal
            },
        )
        .expect("jit plan");
    let mut rng = Xoshiro256::new(0);
    let q = Tensor::randn(&[n, 64], 1.0, &mut rng);
    let k = Tensor::randn(&[n, 64], 1.0, &mut rng);
    let v = Tensor::randn(&[n, 64], 1.0, &mut rng);
    let a = plan::execute(&fact, &q, &k, &v).expect("factored");
    let b = plan::execute(&jit, &q, &k, &v).expect("jit");
    println!(
        "  plans: factored carries {} bias bytes, jit {}; outputs agree \
         rel={:.2e}",
        fact.bias_storage_bytes,
        jit.bias_storage_bytes,
        b.rel_err(&a)
    );
    assert!(b.rel_err(&a) < 1e-5, "jit must equal factored");
    assert_eq!(jit.bias_storage_bytes, 0, "jit streams no bias bytes");

    // measured artifacts (optional: requires `make artifacts`)
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            println!("  measured section skipped ({e})");
            return;
        }
    };
    let it = iters(20);
    for n in [256usize, 512] {
        let mut table = Table::new(&format!("causal + ALiBi, N={n}"));
        for name in [
            format!("causal_pure_n{n}"),
            format!("causal_alibi_jit_n{n}"),
            format!("causal_alibi_factored_n{n}"),
            format!("causal_alibi_dense_n{n}"),
        ] {
            if rt.spec(&name).is_some() {
                table.row(bench_artifact(&rt, &name, 3, it));
            }
        }
        // Table 8's claim: jit ≈ pure (tiny Δ), both ≤ loaded-strip ≤ dense
        let pure = table
            .rows()
            .iter()
            .find(|r| r.label.contains("pure"))
            .unwrap()
            .stats
            .mean();
        let jit = table
            .rows()
            .iter()
            .find(|r| r.label.contains("jit"))
            .unwrap()
            .stats
            .mean();
        println!(
            "  Δ(jit − pure) = {} ({:.1}% overhead)",
            flashbias::util::human_secs((jit - pure).max(0.0)),
            ((jit / pure) - 1.0) * 100.0
        );
    }
}
