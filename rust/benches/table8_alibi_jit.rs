//! Table 8 (Appendix C): ALiBi with in-kernel JIT generation — when the
//! factor strips are created inside the kernel from block coordinates
//! (zero bias IO), FlashBias matches FlashAttention's ALiBi_slopes
//! feature exactly.
//!
//! Paper: w/o bias 119.3/38.77, ALiBi_slopes 119.8/38.98, FlashBias-JIT
//! 119.8/38.98 (train/test s per 100 it) — i.e. indistinguishable.

use flashbias::benchkit::{bench_artifact, iters, paper_reference, Table};
use flashbias::runtime::Runtime;

fn main() {
    println!("TABLE 8: ALiBi factor strips generated in-kernel (JIT)");
    paper_reference(&[
        "Table 8: FlashAttention w/o bias 119.3/38.77; ALiBi_slopes",
        "119.8/38.98; FlashBias w/ JIT decomposition 119.8/38.98 —",
        "the two JIT approaches are the same speed",
    ]);
    let rt = Runtime::open_default().expect("make artifacts");
    let it = iters(20);
    for n in [256usize, 512] {
        let mut table = Table::new(&format!("causal + ALiBi, N={n}"));
        for name in [
            format!("causal_pure_n{n}"),
            format!("causal_alibi_jit_n{n}"),
            format!("causal_alibi_factored_n{n}"),
            format!("causal_alibi_dense_n{n}"),
        ] {
            if rt.spec(&name).is_some() {
                table.row(bench_artifact(&rt, &name, 3, it));
            }
        }
        // Table 8's claim: jit ≈ pure (tiny Δ), both ≤ loaded-strip ≤ dense
        let pure = table
            .rows()
            .iter()
            .find(|r| r.label.contains("pure"))
            .unwrap()
            .stats
            .mean();
        let jit = table
            .rows()
            .iter()
            .find(|r| r.label.contains("jit"))
            .unwrap()
            .stats
            .mean();
        println!(
            "  Δ(jit − pure) = {} ({:.1}% overhead)",
            flashbias::util::human_secs((jit - pure).max(0.0)),
            ((jit / pure) - 1.0) * 100.0
        );
    }
}
