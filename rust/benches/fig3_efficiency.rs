//! Figure 3: GPU memory + running time vs sequence length, training and
//! inference, for {pure Flash, Flash w/ dense bias, FlexAttention-like,
//! FlashBias}.
//!
//! Two instruments (DESIGN.md §Hardware-Adaptation):
//!  * the tiled-execution simulator at the paper's N ∈ {1k..16k} —
//!    regenerates the *shape* (who wins, crossovers) of all four panels;
//!  * measured XLA-CPU wall-clock on the compiled artifacts at
//!    N ∈ {256, 512, 1024} — the same asymptotics on this host.

use flashbias::attention::{attention, AttnOpts};
use flashbias::benchkit::{
    bench_artifact, bench_fn, iters, paper_reference, Table,
};
use flashbias::bias::{Alibi, ExactBias};
use flashbias::iomodel::Geometry;
use flashbias::kernels::{
    self, AlibiTile, BiasTile, DenseTile, FactoredTile, KernelConfig,
};
use flashbias::runtime::Runtime;
use flashbias::simulator::{
    simulate_fwd, simulate_train_step, Algorithm, HwModel,
};
use flashbias::tensor::{Strip, StripDType, Tensor};
use flashbias::util::{human_bytes, Xoshiro256};

const ALGS: [(Algorithm, &str); 4] = [
    (Algorithm::Flash, "pure-flash"),
    (Algorithm::FlashDenseBias, "flash+bias"),
    (Algorithm::FlexLike, "flex-like"),
    (Algorithm::FlashBias(16), "flashbias"),
];

fn simulated() {
    let hw = HwModel::default();
    println!("\n-- simulated (A100-like cost model, H=8 heads, C=64) --");
    paper_reference(&[
        "Fig 3(a-b): at N=16384 FlashBias memory 5x smaller (train), 10x \
         (inference) vs dense-bias/Flex",
        "Fig 3(c-d): FlashBias 18.6% (train) / 44% (infer) faster than \
         FlashAttention w/ bias; Flex degrades at long N",
    ]);
    for phase in ["inference", "training"] {
        println!("\n  {phase}: cost (HBM-equivalents) | peak memory");
        print!("  {:>8}", "N");
        for (_, name) in ALGS {
            print!(" | {name:>24}");
        }
        println!();
        for n in [1024usize, 2048, 4096, 8192, 16384] {
            print!("  {n:>8}");
            for (alg, _) in ALGS {
                let r = if alg == Algorithm::Flash { 0 } else { 16 };
                let g = Geometry::square(n, 64, r, hw.sram_elems);
                let rep = if phase == "training" {
                    simulate_train_step(alg, &g, &hw)
                } else {
                    simulate_fwd(alg, &g, &hw)
                };
                let cost = rep.cost(&hw) * 8.0; // 8 heads
                print!(
                    " | {:>11.3e} {:>10}",
                    cost,
                    human_bytes(rep.hbm_peak * 8 * 4)
                );
            }
            println!();
        }
    }
}

/// Measured host wall-clock: the tiled multi-threaded kernel engine
/// against the dense single-threaded reference, and the factored/JIT
/// tile providers against the dense-bias tiled path. Emits
/// `BENCH_kernels.json` (label, mean, p50, bytes) for CI/tooling; the
/// bytes column is the bias HBM residency each provider streams.
fn host_engine() {
    let it = iters(5);
    let threads = kernels::default_threads();
    let mut table = Table::new(&format!(
        "kernels: host tiled engine, C=64, ALiBi bias, {threads} threads"
    ));
    paper_reference(&[
        "Fig 3(c): FlashBias beats FlashAttention w/ dense bias; the \
         bias-IO saving grows with N",
        "acceptance: tiled > reference-dense at N>=2048; factored/jit > \
         tiled-dense",
    ]);
    let c = 64;
    for n in [512usize, 2048] {
        let mut rng = Xoshiro256::new(n as u64);
        let q = Tensor::randn(&[n, c], 1.0, &mut rng);
        let k = Tensor::randn(&[n, c], 1.0, &mut rng);
        let v = Tensor::randn(&[n, c], 1.0, &mut rng);
        let alibi = Alibi::new(n, n, 0.0625);
        let dense_bias = alibi.dense();
        let (pq, pk) = alibi.factors();
        let cfg = KernelConfig::for_geometry(&Geometry::square(
            n,
            c,
            alibi.rank(),
            HwModel::default().sram_elems,
        ));
        let opts = AttnOpts::default();
        let mut row = bench_fn(&format!("reference-dense n{n}"), 1, it,
                               || {
            attention(&q, &k, &v, Some(&dense_bias), &opts);
        });
        row.bytes = Some(dense_bias.size_bytes() as u64);
        row.note = "single-thread dense oracle".into();
        table.row(row);
        let dense_tile = DenseTile::from_tensor(&dense_bias);
        let mut row = bench_fn(&format!("tiled-dense n{n}"), 1, it, || {
            kernels::attention_tiled(&q, &k, &v, &dense_tile, false,
                                     &cfg);
        });
        row.bytes = Some(4 * dense_tile.resident_elems() as u64);
        table.row(row);
        let fact_tile = FactoredTile::new(&pq, &pk);
        let mut row = bench_fn(&format!("tiled-factored n{n}"), 1, it,
                               || {
            kernels::attention_tiled(&q, &k, &v, &fact_tile, false, &cfg);
        });
        row.bytes = Some(4 * fact_tile.resident_elems() as u64);
        table.row(row);
        let jit_tile = AlibiTile { slope: 0.0625 };
        let mut row = bench_fn(&format!("tiled-jit n{n}"), 1, it, || {
            kernels::attention_tiled(&q, &k, &v, &jit_tile, false, &cfg);
        });
        row.bytes = Some(0);
        table.row(row);

        // single-thread rows: the CI perf gate (`make bench-check`)
        // compares their means as ratios against the same-n
        // reference-dense oracle, so the gated quantity is
        // machine-independent raw microkernel speed, not core count
        let cfg1 = cfg.with_threads(1);
        let mut row = bench_fn(&format!("tiled-dense-1t n{n}"), 1, it,
                               || {
            kernels::attention_tiled(&q, &k, &v, &dense_tile, false,
                                     &cfg1);
        });
        row.bytes = Some(4 * dense_tile.resident_elems() as u64);
        table.row(row);
        let mut row = bench_fn(&format!("tiled-factored-1t n{n}"), 1,
                               it, || {
            kernels::attention_tiled(&q, &k, &v, &fact_tile, false,
                                     &cfg1);
        });
        row.bytes = Some(4 * fact_tile.resident_elems() as u64);
        table.row(row);
        // reduced-precision strips: same contraction, half the bias HBM
        let (sq, sk) = (
            Strip::quantize(&pq, StripDType::Bf16),
            Strip::quantize(&pk, StripDType::Bf16),
        );
        let bf_tile = FactoredTile::from_strips(&sq, &sk);
        let cfg_bf = KernelConfig::for_geometry_dtype(
            &Geometry::square(n, c, alibi.rank(),
                              HwModel::default().sram_elems),
            StripDType::Bf16,
        )
        .with_threads(1);
        let mut row = bench_fn(
            &format!("tiled-factored-bf16-1t n{n}"), 1, it, || {
                kernels::attention_tiled(&q, &k, &v, &bf_tile, false,
                                         &cfg_bf);
            },
        );
        row.bytes = Some(bf_tile.resident_bytes() as u64);
        table.row(row);
        let mut row = bench_fn(&format!("tiled-jit-1t n{n}"), 1, it,
                               || {
            kernels::attention_tiled(&q, &k, &v, &jit_tile, false,
                                     &cfg1);
        });
        row.bytes = Some(0);
        table.row(row);
    }
    if let Err(e) = table.write_json("kernels") {
        println!("  BENCH_kernels.json not written: {e}");
    }
}

fn measured() {
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            println!("\n-- measured: SKIPPED ({e}) --");
            return;
        }
    };
    let it = iters(10);
    let mut table = Table::new(
        "Fig 3 measured (XLA-CPU, plain-Transformer attention micro-op, \
         H=8, C=64)",
    );
    for n in [256usize, 512, 1024] {
        for variant in ["pure", "dense", "factored", "flexlike"] {
            let name = format!("attn_{variant}_n{n}");
            if rt.spec(&name).is_some() {
                table.row(bench_artifact(&rt, &name, 2, it));
            }
        }
    }
    // full 8-layer model forward (the paper's actual §4.1 workload)
    let mut model = Table::new(
        "Fig 3 measured (XLA-CPU, full 8-layer Transformer fwd, D=512)",
    );
    for n in [256usize, 512] {
        for variant in ["nobias", "dense", "factored", "flexlike"] {
            let name = format!("plain_{variant}_n{n}");
            if rt.spec(&name).is_some() {
                model.row(bench_artifact(&rt, &name, 1, it.min(5)));
            }
        }
    }
    // training phase (2-layer train step)
    let mut train = Table::new("Fig 3 measured (train step, 2 layers)");
    for variant in ["dense", "factored"] {
        let name = format!("plain_train_{variant}_n256");
        if rt.spec(&name).is_some() {
            train.row(bench_artifact(&rt, &name, 1, it.min(5)));
        }
    }
}

fn main() {
    println!("FIG3: efficiency comparison (memory + time vs N)");
    simulated();
    host_engine();
    measured();
}
