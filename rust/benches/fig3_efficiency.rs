//! Figure 3: GPU memory + running time vs sequence length, training and
//! inference, for {pure Flash, Flash w/ dense bias, FlexAttention-like,
//! FlashBias}.
//!
//! Two instruments (DESIGN.md §Hardware-Adaptation):
//!  * the tiled-execution simulator at the paper's N ∈ {1k..16k} —
//!    regenerates the *shape* (who wins, crossovers) of all four panels;
//!  * measured XLA-CPU wall-clock on the compiled artifacts at
//!    N ∈ {256, 512, 1024} — the same asymptotics on this host.

use flashbias::benchkit::{bench_artifact, iters, paper_reference, Table};
use flashbias::iomodel::Geometry;
use flashbias::runtime::Runtime;
use flashbias::simulator::{
    simulate_fwd, simulate_train_step, Algorithm, HwModel,
};
use flashbias::util::human_bytes;

const ALGS: [(Algorithm, &str); 4] = [
    (Algorithm::Flash, "pure-flash"),
    (Algorithm::FlashDenseBias, "flash+bias"),
    (Algorithm::FlexLike, "flex-like"),
    (Algorithm::FlashBias(16), "flashbias"),
];

fn simulated() {
    let hw = HwModel::default();
    println!("\n-- simulated (A100-like cost model, H=8 heads, C=64) --");
    paper_reference(&[
        "Fig 3(a-b): at N=16384 FlashBias memory 5x smaller (train), 10x \
         (inference) vs dense-bias/Flex",
        "Fig 3(c-d): FlashBias 18.6% (train) / 44% (infer) faster than \
         FlashAttention w/ bias; Flex degrades at long N",
    ]);
    for phase in ["inference", "training"] {
        println!("\n  {phase}: cost (HBM-equivalents) | peak memory");
        print!("  {:>8}", "N");
        for (_, name) in ALGS {
            print!(" | {name:>24}");
        }
        println!();
        for n in [1024usize, 2048, 4096, 8192, 16384] {
            print!("  {n:>8}");
            for (alg, _) in ALGS {
                let r = if alg == Algorithm::Flash { 0 } else { 16 };
                let g = Geometry::square(n, 64, r, hw.sram_elems);
                let rep = if phase == "training" {
                    simulate_train_step(alg, &g, &hw)
                } else {
                    simulate_fwd(alg, &g, &hw)
                };
                let cost = rep.cost(&hw) * 8.0; // 8 heads
                print!(
                    " | {:>11.3e} {:>10}",
                    cost,
                    human_bytes(rep.hbm_peak * 8 * 4)
                );
            }
            println!();
        }
    }
}

fn measured() {
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            println!("\n-- measured: SKIPPED ({e}) --");
            return;
        }
    };
    let it = iters(10);
    let mut table = Table::new(
        "Fig 3 measured (XLA-CPU, plain-Transformer attention micro-op, \
         H=8, C=64)",
    );
    for n in [256usize, 512, 1024] {
        for variant in ["pure", "dense", "factored", "flexlike"] {
            let name = format!("attn_{variant}_n{n}");
            if rt.spec(&name).is_some() {
                table.row(bench_artifact(&rt, &name, 2, it));
            }
        }
    }
    // full 8-layer model forward (the paper's actual §4.1 workload)
    let mut model = Table::new(
        "Fig 3 measured (XLA-CPU, full 8-layer Transformer fwd, D=512)",
    );
    for n in [256usize, 512] {
        for variant in ["nobias", "dense", "factored", "flexlike"] {
            let name = format!("plain_{variant}_n{n}");
            if rt.spec(&name).is_some() {
                model.row(bench_artifact(&rt, &name, 1, it.min(5)));
            }
        }
    }
    // training phase (2-layer train step)
    let mut train = Table::new("Fig 3 measured (train step, 2 layers)");
    for variant in ["dense", "factored"] {
        let name = format!("plain_train_{variant}_n256");
        if rt.spec(&name).is_some() {
            train.row(bench_artifact(&rt, &name, 1, it.min(5)));
        }
    }
}

fn main() {
    println!("FIG3: efficiency comparison (memory + time vs N)");
    simulated();
    measured();
}
