//! Table 6: AlphaFold-3 Pairformer — neural-decomposed pair bias:
//! accuracy preserved (pLLDDT/pTM fluctuation within noise), ~32% time
//! reduction vs the open-source code, vs 3.2x degradation without bias.
//!
//! Here: the Pairformer-shaped block with dense pair bias vs baked neural
//! factor nets (trained at AOT time on the same pair statistics), output
//! fidelity + measured time.

use flashbias::benchkit::{bench_artifact, iters, paper_reference, Table};
use flashbias::runtime::Runtime;

fn main() {
    println!("TABLE 6: Pairformer with neural-decomposed pair bias");
    paper_reference(&[
        "Table 6 (PDB 7wux, 1218 tokens): open code 26.85s/13.62GB;",
        "  w/o bias 8.27s but pTM 0.95->0.17 (broken); FlashBias 18.19s,",
        "  pLLDDT 3.3724->3.3758, pTM 0.9500->0.9498 (within noise)",
    ]);
    let rt = Runtime::open_default().expect("make artifacts");
    let it = iters(8);

    let mut table = Table::new("Pairformer block (N=128, H=4, 2 layers)");
    table.row(bench_artifact(&rt, "pairformer_dense", 2, it));
    table.row(bench_artifact(&rt, "pairformer_neural", 2, it));

    // fidelity: the Table 6 "no loss of accuracy" claim
    let run = |name: &str| {
        rt.load(name)
            .unwrap()
            .run(&rt.example_inputs(name).unwrap())
            .unwrap()[0]
            .as_f32()
            .unwrap()
            .clone()
    };
    let dense = run("pairformer_dense");
    let neural = run("pairformer_neural");
    let rel = neural.rel_err(&dense);
    println!(
        "\n  single-rep output fidelity: rel err {rel:.3} \
         (neural decomposition approximates the dynamic pair bias)"
    );
    assert!(rel < 0.35, "fidelity broken: {rel}");

    let speedup = table
        .delta("pairformer_dense", "pairformer_neural")
        .unwrap_or(0.0);
    println!(
        "  time saved by neural decomposition: {} per forward",
        flashbias::util::human_secs(speedup.max(0.0))
    );
}
