//! Table 11 (Appendix F): the spatial-distance bias *matters* — attention
//! without it is substantially less accurate, and only FlashBias can run
//! the biased model at scale (dense OOMs).
//!
//! Reproduction: a Nadaraya–Watson-style attention surrogate over a car
//! hull — attention with the distance bias is a locality-aware kernel
//! interpolator; without the bias it over-smooths. We fit physics fields
//! at held-out points and report relative L2, pure vs biased (factored),
//! plus the memory wall that kills the dense variant at N=32186.

use flashbias::attention::{self, AttnOpts};
use flashbias::benchkit::paper_reference;
use flashbias::bias::{synthetic_car_cloud, ExactBias, SpatialDistance};
use flashbias::iomodel;
use flashbias::tensor::Tensor;
use flashbias::util::{human_bytes, Xoshiro256};

/// Smooth synthetic pressure field over the hull.
fn field(p: &Tensor) -> Tensor {
    Tensor::from_fn(&[p.shape()[0], 1], |ix| {
        let (x, _y, z) = (p.at2(ix[0], 0), p.at2(ix[0], 1), p.at2(ix[0], 2));
        x.tanh() * (-z * z).exp()
    })
}

fn main() {
    println!("TABLE 11: accuracy benefit of the spatial-distance bias");
    paper_reference(&[
        "Table 11 (N=32186): pure attention pressure err 0.0838, w/ bias",
        "0.0706 (−15.7%); C_D err 0.0173 -> 0.0113 (−65.3% rel. promo.);",
        "dense-bias methods OOM — only FlashBias trains",
    ]);

    let n_train = 2048;
    let n_test = 512;
    let cloud = synthetic_car_cloud(n_train + n_test, 0);
    let train = cloud.slice_rows(0, n_train);
    let test = cloud.slice_rows(n_train, n_train + n_test);
    let y_train = field(&train);
    let y_test = field(&test);

    // attention interpolator: q = test coords proj, k = train coords proj,
    // v = train field values; the bias adds locality
    let mut rng = Xoshiro256::new(1);
    let proj = Tensor::randn(&[3, 16], 0.6, &mut rng);
    let q = test.matmul(&proj);
    let k = train.matmul(&proj);
    let opts = AttnOpts::default();

    let pred_pure = attention::attention(&q, &k, &y_train, None, &opts);
    // weighted distance bias, exact rank-9 factorization (Example 3.5)
    let alpha: Vec<f32> = vec![8.0; n_test];
    let bias = SpatialDistance::new(test.clone(), train.clone(),
                                    Some(alpha));
    let (pq, pk) = bias.factors();
    let pred_biased =
        attention::attention_factored(&q, &k, &y_train, &pq, &pk, &opts);

    let err_pure = pred_pure.rel_err(&y_test);
    let err_biased = pred_biased.rel_err(&y_test);
    println!(
        "\n  surface-field rel L2: pure {err_pure:.4} vs w/ spatial bias \
         {err_biased:.4} ({:.1}% better)",
        (1.0 - err_biased / err_pure) * 100.0
    );
    assert!(
        err_biased < err_pure * 0.8,
        "bias must improve accuracy: {err_biased} !< 0.8*{err_pure}"
    );

    // drag-coefficient-style aggregate (mean field over the surface)
    let cd = |pred: &Tensor| pred.data().iter().sum::<f32>() / n_test as f32;
    let cd_true = cd(&y_test);
    let cd_err = |pred: &Tensor| ((cd(pred) - cd_true) / cd_true).abs();
    println!(
        "  aggregate (C_D-like) rel err: pure {:.4} vs biased {:.4}",
        cd_err(&pred_pure),
        cd_err(&pred_biased)
    );

    // the memory wall at the paper's N (why dense "OOM"s)
    println!("\n  memory wall at N=32186 (8 heads, f32):");
    let n = 32186usize;
    let dense_b = iomodel::dense_storage_elems(n, n) * 4 * 8;
    let fact_b = iomodel::factored_storage_elems(n, n, 9) * 4 * 8;
    println!(
        "    dense bias + gradient: {} | FlashBias factors: {} ({}x)",
        human_bytes(2 * dense_b as u64),
        human_bytes(2 * fact_b as u64),
        dense_b / fact_b
    );
}
