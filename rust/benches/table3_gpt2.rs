//! Table 3: GPT-2 + ALiBi — the bias-processing overhead Δ relative to
//! pure causal attention, for FlashAttention-with-bias vs FlashBias.
//!
//! Paper (N=2048, 48 layers, 1.5B): train Δ 5.0 → 2.3 s/100it (−54%),
//! inference Δ 1.55 → 0.49 (−68%). Here: scaled dims (DESIGN.md
//! substitutions), same attention path, Δ over the causal micro-op and
//! over the full decoder stack.

use flashbias::benchkit::{bench_artifact, iters, paper_reference, Table};
use flashbias::runtime::Runtime;
use flashbias::util::human_secs;

fn main() {
    println!("TABLE 3: GPT-2 + ALiBi bias-processing overhead");
    paper_reference(&[
        "Table 3 (N=2048): Train  pure 119.3  +bias 124.3 (Δ5.0)  \
         FlashBias 121.6 (Δ2.3)",
        "             Infer  pure 38.77  +bias 40.32 (Δ1.55) \
         FlashBias 39.26 (Δ0.49)",
        "claim: FlashBias cuts >50% of the bias-processing time",
    ]);
    let rt = Runtime::open_default().expect("make artifacts");
    let it = iters(20);

    for n in [256usize, 512] {
        let mut table =
            Table::new(&format!("causal attention micro-op, N={n}"));
        for variant in ["pure", "alibi_dense", "alibi_factored",
                        "alibi_jit"] {
            let name = if variant == "pure" {
                format!("causal_pure_n{n}")
            } else {
                format!("causal_{variant}_n{n}")
            };
            if rt.spec(&name).is_some() {
                table.row(bench_artifact(&rt, &name, 3, it));
            }
        }
        let base = format!("causal_pure_n{n}");
        let d_dense = table.delta(&format!("causal_alibi_dense_n{n}"), &base);
        let d_fact =
            table.delta(&format!("causal_alibi_factored_n{n}"), &base);
        if let (Some(dd), Some(df)) = (d_dense, d_fact) {
            println!(
                "  Δ(dense)={}  Δ(flashbias)={}  reduction={:.0}%",
                human_secs(dd.max(0.0)),
                human_secs(df.max(0.0)),
                (1.0 - df / dd.max(1e-12)) * 100.0
            );
        }
    }

    // full decoder stack (4 scaled layers)
    let mut table = Table::new("full GPT-2-shaped stack, N=256");
    for variant in ["pure", "dense", "factored"] {
        let name = format!("gpt2_{variant}_n256");
        if rt.spec(&name).is_some() {
            table.row(bench_artifact(&rt, &name, 2, it.min(10)));
        }
    }
    let d_dense = table.delta("gpt2_dense_n256", "gpt2_pure_n256");
    let d_fact = table.delta("gpt2_factored_n256", "gpt2_pure_n256");
    if let (Some(dd), Some(df)) = (d_dense, d_fact) {
        println!(
            "  stack Δ(dense)={} Δ(flashbias)={}",
            human_secs(dd.max(0.0)),
            human_secs(df.max(0.0))
        );
    }
}
