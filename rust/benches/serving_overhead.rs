//! L3 perf bench (EXPERIMENTS.md §Perf), three sections:
//!
//! 1. **Plan-time amortization** (no artifacts needed): per-request plan
//!    latency for a Swin-style learned bias, cold (SVD every request)
//!    vs warm (FactorStore hit), through the same planner the serving
//!    stack uses — plus a host-plan serving burst on a coordinator that
//!    shares the store. Writes `BENCH_factorstore.json`.
//! 2. **Store tiers** (no artifacts needed): plan latency by the tier
//!    that supplies the factors — resident hit vs spill-file reload vs
//!    remote fetch from a loopback `FactorService` vs a cold full SVD.
//!    Writes `BENCH_store_tiers.json`.
//! 3. **Coordinator overhead over raw PJRT execution** — router +
//!    batcher + channel + thread hop must cost <10% of execute time,
//!    per the DESIGN.md target. Skipped gracefully without artifacts.
//!
//! Perf-pass finding (section 2): on the CPU PJRT backend each execute
//! already uses the whole core pool, so 2 concurrent workers *contend*
//! (per-execute wall time ~2x) and buy nothing; 1 worker is the right
//! CPU config. On a real accelerator pool (1 device per worker) more
//! workers scale.

use std::sync::Arc;
use std::time::Duration;

use flashbias::benchkit::{bench_fn, iters, Table};
use flashbias::bias::swin_relative_bias;
use flashbias::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig,
};
use flashbias::factorstore::{FactorService, FactorStore, RemoteStore};
use flashbias::iomodel::Geometry;
use flashbias::plan::{BiasSpec, PlanOptions, Planner};
use flashbias::runtime::{HostValue, Runtime};
use flashbias::tensor::Tensor;
use flashbias::util::{human_secs, Xoshiro256};

fn bench_factorstore(it: usize) {
    println!("FACTORSTORE: per-request plan latency, cold vs warm");
    let table = swin_relative_bias((12, 12), 1, 0, 6, 0.02).remove(0);
    let spec = BiasSpec::static_learned(table);
    let geo = Geometry::square(144, 64, 0, 100 * 1024 / 2);
    let opts = PlanOptions {
        rank_override: Some(16), // the paper pins R = 16 for Swin
        ..PlanOptions::default()
    };
    let planner = Planner::default();

    let mut out =
        Table::new("factorstore: plan latency (swin 144x144, R=16)");
    out.row(bench_fn("cold plan (SVD every request)", 1, it, || {
        let plan = planner.plan(&spec, &geo, &opts).expect("plan");
        assert_eq!(plan.rank(), 16);
    }));

    let store = Arc::new(FactorStore::unbounded());
    planner
        .plan_with_store(&spec, &geo, &opts, &store)
        .expect("warm the store");
    out.row(bench_fn("warm plan (store hit)", 1, it, || {
        let plan = planner
            .plan_with_store(&spec, &geo, &opts, &store)
            .expect("plan");
        assert_eq!(plan.rank(), 16);
    }));
    let cold = out.rows()[0].stats.mean();
    let warm = out.rows()[1].stats.mean();
    println!(
        "  cold {} vs warm {} -> {:.0}x lower plan latency",
        human_secs(cold),
        human_secs(warm),
        cold / warm.max(1e-12)
    );
    println!("  {}", store.stats().summary());

    // the same store carried through a serving loop: plan_and_register
    // is a hit, and the burst runs on the host kernel engine
    let coord = Coordinator::with_store(
        Arc::new(Runtime::empty()),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            workers: 1,
            queue_depth: 64,
        },
        store.clone(),
    );
    coord
        .plan_and_register("swin_host", &planner, &spec, &geo, &opts)
        .expect("register host plan");
    let mut coord = coord;
    let mut rng = Xoshiro256::new(17);
    let q = Tensor::randn(&[144, 64], 1.0, &mut rng);
    let k = Tensor::randn(&[144, 64], 1.0, &mut rng);
    let v = Tensor::randn(&[144, 64], 1.0, &mut rng);
    let inputs = vec![
        HostValue::F32(q),
        HostValue::F32(k),
        HostValue::F32(v),
    ];
    let row = bench_fn(
        "host-plan serving burst (batch=8, warm store)",
        1,
        (it / 4).max(2),
        || {
            let reqs: Vec<_> = (0..8)
                .map(|_| ("swin_host".to_string(), inputs.clone()))
                .collect();
            let responses = coord.run_burst(reqs).expect("burst");
            assert_eq!(responses.len(), 8);
        },
    );
    out.row(row);
    println!("  {}", coord.metrics().summary());
    coord.shutdown();

    out.write_json("factorstore")
        .expect("write BENCH_factorstore.json");
}

/// Plan latency by the tier that supplies the factors (ISSUE 5
/// acceptance): a budgeted store under eviction pressure must serve
/// spill hits — never a repeated SVD — and a store pointed at a peer's
/// `FactorService` must plan with zero local SVD work.
fn bench_store_tiers(it: usize) {
    println!("\nSTORE TIERS: plan latency by serving tier");
    // two distinct swin heads so an LRU budget sized for one entry
    // alternates them through the spill tier
    let table_a = swin_relative_bias((12, 12), 1, 0, 6, 0.02).remove(0);
    let table_b = swin_relative_bias((12, 12), 1, 1, 6, 0.02).remove(0);
    let spec_a = BiasSpec::static_learned(table_a);
    let spec_b = BiasSpec::static_learned(table_b);
    let geo = Geometry::square(144, 64, 0, 100 * 1024 / 2);
    let opts = PlanOptions {
        rank_override: Some(16), // the paper pins R = 16 for Swin
        ..PlanOptions::default()
    };
    let planner = Planner::default();
    let mut out =
        Table::new("store tiers: plan latency (swin 144x144, R=16)");

    // cold: the full SVD on every plan (what a storeless fleet pays)
    out.row(bench_fn("cold plan (full SVD)", 1, it, || {
        let plan = planner.plan(&spec_a, &geo, &opts).expect("plan");
        assert_eq!(plan.rank(), 16);
    }));

    // resident hit: warm store, zero decomposition work
    let resident = FactorStore::unbounded();
    planner
        .plan_with_store(&spec_a, &geo, &opts, &resident)
        .expect("warm");
    out.row(bench_fn("resident hit", 1, it, || {
        planner
            .plan_with_store(&spec_a, &geo, &opts, &resident)
            .expect("plan");
    }));

    // spill hit: the budget holds one entry's strips, so planning A
    // and B alternately reloads each from the spill file every time —
    // one disk read per plan, and misses stays at the initial 2
    let strips_bytes: usize = (144 + 144) * 16 * 4;
    let spill_path = std::env::temp_dir().join(format!(
        "fb_bench_spill_{}.jsonl",
        std::process::id()
    ));
    let spilling = FactorStore::new(strips_bytes + 64)
        .spill_to(&spill_path)
        .expect("spill file");
    planner
        .plan_with_store(&spec_a, &geo, &opts, &spilling)
        .expect("warm a");
    planner
        .plan_with_store(&spec_b, &geo, &opts, &spilling)
        .expect("warm b");
    // warming left b resident and a spilled: start with a so every
    // sample (including the very first) crosses the spill tier
    let mut flip = true;
    out.row(bench_fn("spill hit (reload from disk)", 2, it, || {
        let spec = if flip { &spec_a } else { &spec_b };
        flip = !flip;
        planner
            .plan_with_store(spec, &geo, &opts, &spilling)
            .expect("plan");
    }));
    assert_eq!(
        spilling.misses(),
        2,
        "eviction pressure must never re-run a decomposition"
    );
    println!("  {}", spilling.stats().summary());

    // remote hit: a fresh store per plan fetches from a loopback
    // FactorService instead of decomposing (the fleet-warming path)
    let leader = Arc::new(FactorStore::unbounded());
    planner
        .plan_with_store(&spec_a, &geo, &opts, &leader)
        .expect("warm leader");
    let service = FactorService::serve(leader, "127.0.0.1:0")
        .expect("factor service");
    let addr = service.addr().to_string();
    out.row(bench_fn("remote hit (loopback fetch)", 1, it, || {
        let follower = FactorStore::unbounded()
            .with_remote(RemoteStore::new(addr.clone()));
        let plan = planner
            .plan_with_store(&spec_a, &geo, &opts, &follower)
            .expect("plan");
        assert_eq!(plan.rank(), 16);
        assert_eq!(follower.misses(), 0, "no SVD work on the follower");
        assert_eq!(follower.remote_hits(), 1);
    }));
    println!("  factor service served {} lookups", service.served());
    service.shutdown();
    let _ = std::fs::remove_file(&spill_path);

    let mean = |i: usize| out.rows()[i].stats.mean();
    let (cold, res, spill, rem) = (mean(0), mean(1), mean(2), mean(3));
    println!(
        "  cold {} | resident {} ({:.0}x) | spill {} ({:.0}x) | \
         remote {} ({:.0}x)",
        human_secs(cold),
        human_secs(res),
        cold / res.max(1e-12),
        human_secs(spill),
        cold / spill.max(1e-12),
        human_secs(rem),
        cold / rem.max(1e-12),
    );
    out.write_json("store_tiers")
        .expect("write BENCH_store_tiers.json");
}

fn bench_pjrt_overhead(it: usize) {
    println!("\nSERVING OVERHEAD: coordinator vs raw PJRT");
    let rt = match Runtime::open_default() {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            println!("  skipped ({e}); run `make artifacts`");
            return;
        }
    };
    let name = "attn_factored_n512";
    let exe = rt.load_warm(name).expect("warm");
    let inputs = rt.example_inputs(name).expect("inputs");

    let mut table = Table::new("per-request latency (attn_factored_n512)");
    table.row(bench_fn("raw PJRT execute", 3, it, || {
        exe.run(&inputs).expect("run");
    }));
    let raw = table.rows()[0].stats.mean();

    let batch = 8usize;
    for workers in [1usize, 2] {
        let mut coord = Coordinator::new(
            rt.clone(),
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_batch: batch,
                    max_wait: Duration::from_millis(1),
                },
                workers,
                queue_depth: 64,
            },
        );
        let label = format!("coordinator (batch=8, {workers} worker(s))");
        let row = bench_fn(&label, 1, (it / 4).max(3), || {
            let reqs: Vec<_> = (0..batch)
                .map(|_| (name.to_string(), inputs.clone()))
                .collect();
            let out = coord.run_burst(reqs).expect("burst");
            assert_eq!(out.len(), batch);
        });
        let per_req = row.stats.mean() / batch as f64;
        table.row(row);
        println!(
            "  workers={workers}: per-request {} vs raw {} -> overhead \
             {:+.1}%",
            human_secs(per_req),
            human_secs(raw),
            (per_req / raw - 1.0) * 100.0
        );
        println!("  {}", coord.metrics().summary());
        coord.shutdown();
    }
    println!(
        "\n  (CPU PJRT saturates all cores per execute; 1 worker avoids \
         pool contention — the <10% overhead target applies there)"
    );
}

fn main() {
    let it = iters(20);
    bench_factorstore(it);
    bench_store_tiers(it);
    bench_pjrt_overhead(it);
}
