//! L3 perf bench (EXPERIMENTS.md §Perf): coordinator overhead over raw
//! PJRT execution — router + batcher + channel + thread hop must cost
//! <10% of execute time, per the DESIGN.md target.
//!
//! Perf-pass finding: on the CPU PJRT backend each execute already uses
//! the whole core pool, so 2 concurrent workers *contend* (per-execute
//! wall time ~2x) and buy nothing; 1 worker is the right CPU config.
//! On a real accelerator pool (1 device per worker) more workers scale.

use std::sync::Arc;
use std::time::Duration;

use flashbias::benchkit::{bench_fn, iters, Table};
use flashbias::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig,
};
use flashbias::runtime::Runtime;

fn main() {
    println!("SERVING OVERHEAD: coordinator vs raw PJRT");
    let rt = Arc::new(Runtime::open_default().expect("make artifacts"));
    let name = "attn_factored_n512";
    let exe = rt.load_warm(name).expect("warm");
    let inputs = rt.example_inputs(name).expect("inputs");
    let it = iters(20);

    let mut table = Table::new("per-request latency (attn_factored_n512)");
    table.row(bench_fn("raw PJRT execute", 3, it, || {
        exe.run(&inputs).expect("run");
    }));
    let raw = table.rows()[0].stats.mean();

    let batch = 8usize;
    for workers in [1usize, 2] {
        let mut coord = Coordinator::new(
            rt.clone(),
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_batch: batch,
                    max_wait: Duration::from_millis(1),
                },
                workers,
                queue_depth: 64,
            },
        );
        let label = format!("coordinator (batch=8, {workers} worker(s))");
        let row = bench_fn(&label, 1, (it / 4).max(3), || {
            let reqs: Vec<_> = (0..batch)
                .map(|_| (name.to_string(), inputs.clone()))
                .collect();
            let out = coord.run_burst(reqs).expect("burst");
            assert_eq!(out.len(), batch);
        });
        let per_req = row.stats.mean() / batch as f64;
        table.row(row);
        println!(
            "  workers={workers}: per-request {} vs raw {} -> overhead \
             {:+.1}%",
            flashbias::util::human_secs(per_req),
            flashbias::util::human_secs(raw),
            (per_req / raw - 1.0) * 100.0
        );
        println!("  {}", coord.metrics().summary());
        coord.shutdown();
    }
    println!(
        "\n  (CPU PJRT saturates all cores per execute; 1 worker avoids \
         pool contention — the <10% overhead target applies there)"
    );
}
