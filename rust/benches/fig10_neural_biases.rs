//! Figure 10 (Appendix G): neural decomposition generalizes to diverse
//! scientific biases — gravity (hard: near-singular diagonal) and
//! spherical haversine distance (easy: smooth) — both declared as
//! `BiasSpec::dynamic` and routed through the Table 1 planner, which
//! picks the neural decomposition (Eq. 5) for data-dependent biases.

use flashbias::benchkit::paper_reference;
use flashbias::bias::{gravity_bias, spherical_bias};
use flashbias::decompose::NeuralConfig;
use flashbias::iomodel::Geometry;
use flashbias::plan::{
    BiasSpec, Decision, PlanOptions, Planner, SelectorConfig,
};
use flashbias::tensor::Tensor;
use flashbias::util::{Timer, Xoshiro256};

fn main() {
    println!("FIG 10: neural decomposition of gravity + spherical biases");
    paper_reference(&[
        "App. G: R=32, 3-layer tanh MLPs, Adam 10k steps (<30s on A100).",
        "Spherical decomposes very well; gravity is harder (numerical",
        "instability of 1/d²) but locality is captured.",
    ]);
    let n = 64;
    let mut rng = Xoshiro256::new(0);
    let planner = Planner::new(SelectorConfig {
        neural: NeuralConfig {
            rank: 32,
            hidden: 48,
            steps: 1500,
            lr: 3e-3,
            ..NeuralConfig::default()
        },
        ..SelectorConfig::default()
    });
    let geo = Geometry::square(n, 32, 0, 100 * 1024 / 2);
    let opts = PlanOptions::default();

    let fit = |sources: &Tensor, target: &Tensor| {
        let spec = BiasSpec::dynamic(
            sources.clone(),
            sources.clone(),
            target.clone(),
        );
        let t = Timer::start();
        let plan = planner.plan(&spec, &geo, &opts).expect("plan dynamic");
        let secs = t.elapsed_secs();
        let (rank, rel_err) = match &plan.decision {
            Decision::Neural { rank, rel_err } => (*rank, *rel_err),
            other => panic!("dynamic bias must plan neural: {other:?}"),
        };
        let approx = plan.materialized_bias().expect("factored bias");
        (plan, rank, rel_err, secs, approx)
    };

    // gravity: points in [0,1]², bias 1/(d² + 0.01)
    let pts_data: Vec<f32> = (0..n * 2).map(|_| rng.next_f32()).collect();
    let pts = Tensor::new(&[n, 2], pts_data);
    let grav = gravity_bias(&pts, &pts, 0.01);
    let (gplan, grank, grav_err, gsecs, gapprox) = fit(&pts, &grav);
    println!(
        "\n  gravity  (R={grank}): rel err {grav_err:.3} in {gsecs:.1}s, \
         plan {}",
        gplan.mode_name()
    );

    // spherical: (lat, lon) samples, haversine distance
    let mut rng2 = Xoshiro256::new(1);
    let sphere_data: Vec<f32> = (0..n)
        .flat_map(|_| {
            [
                (rng2.next_f32() - 0.5) * std::f32::consts::PI,
                rng2.next_f32() * 2.0 * std::f32::consts::PI,
            ]
        })
        .collect();
    let sphere_pts = Tensor::new(&[n, 2], sphere_data);
    let sph = spherical_bias(&sphere_pts, &sphere_pts);
    let (splan, srank, sph_err, ssecs, _sapprox) = fit(&sphere_pts, &sph);
    println!(
        "  spherical(R={srank}): rel err {sph_err:.3} in {ssecs:.1}s, \
         plan {} ({:.1}x predicted IO win)",
        splan.mode_name(),
        splan.io_saving()
    );

    // the paper's shape: spherical much easier than gravity
    assert!(sph_err < 0.2, "spherical should fit well: {sph_err}");
    assert!(sph_err < grav_err, "spherical should beat gravity");
    // gravity still captures locality: diagonal neighborhood correlation
    let mut num = 0.0f64;
    let mut den_a = 0.0f64;
    let mut den_b = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            let a = gapprox.at2(i, j) as f64;
            let b = grav.at2(i, j) as f64;
            num += a * b;
            den_a += a * a;
            den_b += b * b;
        }
    }
    let corr = num / (den_a.sqrt() * den_b.sqrt());
    println!("  gravity reconstruction correlation: {corr:.3}");
    assert!(corr > 0.6, "gravity locality lost: corr {corr}");
}
