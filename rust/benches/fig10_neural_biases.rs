//! Figure 10 (Appendix G): neural decomposition generalizes to diverse
//! scientific biases — gravity (hard: near-singular diagonal) and
//! spherical haversine distance (easy: smooth) — trained with the
//! rust-side Eq. (5) fitter.

use flashbias::benchkit::paper_reference;
use flashbias::bias::{gravity_bias, spherical_bias};
use flashbias::decompose::{NeuralConfig, NeuralDecomposition};
use flashbias::tensor::Tensor;
use flashbias::util::{Timer, Xoshiro256};

fn main() {
    println!("FIG 10: neural decomposition of gravity + spherical biases");
    paper_reference(&[
        "App. G: R=32, 3-layer tanh MLPs, Adam 10k steps (<30s on A100).",
        "Spherical decomposes very well; gravity is harder (numerical",
        "instability of 1/d²) but locality is captured.",
    ]);
    let n = 64;
    let mut rng = Xoshiro256::new(0);

    // gravity: points in [0,1]², bias 1/(d² + 0.01)
    let pts_data: Vec<f32> = (0..n * 2).map(|_| rng.next_f32()).collect();
    let pts = Tensor::new(&[n, 2], pts_data);
    let grav = gravity_bias(&pts, &pts, 0.01);
    let cfg = NeuralConfig {
        rank: 32,
        hidden: 48,
        steps: 1500,
        lr: 3e-3,
        ..NeuralConfig::default()
    };
    let t = Timer::start();
    let nd = NeuralDecomposition::fit(&pts, &pts, &grav, &cfg, &mut rng);
    let approx = nd.phi_q(&pts).matmul_t(&nd.phi_k(&pts));
    let grav_err = approx.rel_err(&grav);
    println!(
        "\n  gravity  (R=32): rel err {grav_err:.3} in {:.1}s, loss \
         {:.2} -> {:.2}",
        t.elapsed_secs(),
        nd.loss_history.first().unwrap(),
        nd.loss_history.last().unwrap()
    );

    // spherical: (lat, lon) samples, haversine distance
    let mut rng2 = Xoshiro256::new(1);
    let sphere_data: Vec<f32> = (0..n)
        .flat_map(|_| {
            [
                (rng2.next_f32() - 0.5) * std::f32::consts::PI,
                rng2.next_f32() * 2.0 * std::f32::consts::PI,
            ]
        })
        .collect();
    let sphere_pts = Tensor::new(&[n, 2], sphere_data);
    let sph = spherical_bias(&sphere_pts, &sphere_pts);
    let t = Timer::start();
    let nd2 = NeuralDecomposition::fit(&sphere_pts, &sphere_pts, &sph,
                                       &cfg, &mut rng2);
    let approx2 =
        nd2.phi_q(&sphere_pts).matmul_t(&nd2.phi_k(&sphere_pts));
    let sph_err = approx2.rel_err(&sph);
    println!(
        "  spherical(R=32): rel err {sph_err:.3} in {:.1}s, loss \
         {:.3} -> {:.4}",
        t.elapsed_secs(),
        nd2.loss_history.first().unwrap(),
        nd2.loss_history.last().unwrap()
    );

    // the paper's shape: spherical much easier than gravity
    assert!(sph_err < 0.2, "spherical should fit well: {sph_err}");
    assert!(sph_err < grav_err, "spherical should beat gravity");
    // gravity still captures locality: diagonal neighborhood correlation
    let mut num = 0.0f64;
    let mut den_a = 0.0f64;
    let mut den_b = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            let a = approx.at2(i, j) as f64;
            let b = grav.at2(i, j) as f64;
            num += a * b;
            den_a += a * a;
            den_b += b * b;
        }
    }
    let corr = num / (den_a.sqrt() * den_b.sqrt());
    println!("  gravity reconstruction correlation: {corr:.3}");
    assert!(corr > 0.6, "gravity locality lost: corr {corr}");
}
