//! CI perf-regression gate: compare a fresh `BENCH_kernels.json`
//! (written by `cargo bench --bench fig3_efficiency`) against the
//! checked-in `BENCH_kernels.baseline.json`.
//!
//! The gated quantity is machine-independent: each single-thread
//! (`…-1t nN`) row's mean normalized by the same-n single-thread dense
//! oracle (`reference-dense nN`). A ratio more than `slack` (default
//! 15%) above its baseline fails the gate with exit code 1.
//!
//! ```text
//! bench_check [--current F] [--baseline F] [--slack X]
//!             [--write-baseline] [--report]
//! ```
//!
//! * `--write-baseline` — re-record the baseline from the current run
//!   (run on a quiet machine with full iterations, then commit it).
//! * `--report` — print the comparison but always exit 0 (`make
//!   bench-json` uses this for the delta print).

use std::process::exit;

use flashbias::benchkit::{
    gate, ratios_from_json, ratios_to_json, speed_ratios, GATE_SLACK,
};
use flashbias::jsonlite::Json;

struct Args {
    current: String,
    baseline: String,
    slack: Option<f64>,
    write_baseline: bool,
    report_only: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        current: "BENCH_kernels.json".into(),
        baseline: "BENCH_kernels.baseline.json".into(),
        slack: None,
        write_baseline: false,
        report_only: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        let mut val = |name: &str| {
            it.next().ok_or(format!("{name} needs a value"))
        };
        match a.as_str() {
            "--current" => args.current = val("--current")?,
            "--baseline" => args.baseline = val("--baseline")?,
            "--slack" => {
                let v = val("--slack")?;
                args.slack = Some(
                    v.parse()
                        .map_err(|_| format!("bad --slack `{v}`"))?,
                );
            }
            "--write-baseline" => args.write_baseline = true,
            "--report" => args.report_only = true,
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    Ok(args)
}

fn load(path: &str) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("{path}: parse error {e:?}"))
}

fn run() -> Result<bool, String> {
    let args = parse_args()?;
    let current_doc = load(&args.current)?;
    let current = speed_ratios(&current_doc)?;

    if args.write_baseline {
        let slack = args.slack.unwrap_or(GATE_SLACK);
        let doc = ratios_to_json(
            current_doc.get("title").as_str().unwrap_or("kernels"),
            slack,
            &current,
        );
        std::fs::write(&args.baseline, doc.dump())
            .map_err(|e| format!("cannot write {}: {e}", args.baseline))?;
        println!("wrote {} ({} gated rows, slack {:.0}%)",
                 args.baseline, current.len(), slack * 100.0);
        return Ok(true);
    }

    let (file_slack, baseline) = ratios_from_json(&load(&args.baseline)?)?;
    let slack = args.slack.unwrap_or(file_slack);
    let outcomes = gate(&current, &baseline, slack)?;

    println!("perf gate: {} vs {} (slack {:.0}%)",
             args.current, args.baseline, slack * 100.0);
    println!("  {:34} {:>9} {:>9} {:>8}  status",
             "row (mean / dense oracle)", "baseline", "current", "delta");
    let mut ok = true;
    for o in &outcomes {
        let delta = (o.current / o.baseline - 1.0) * 100.0;
        println!("  {:34} {:>9.3} {:>9.3} {:>+7.1}%  {}",
                 o.label, o.baseline, o.current, delta,
                 if o.ok { "ok" } else { "REGRESSION" });
        ok &= o.ok;
    }
    if !ok {
        println!("FAIL: ratio(s) above baseline by more than {:.0}%; \
                  if intentional, re-record with --write-baseline",
                 slack * 100.0);
    }
    Ok(ok || args.report_only)
}

fn main() {
    match run() {
        Ok(true) => {}
        Ok(false) => exit(1),
        Err(e) => {
            eprintln!("bench_check: {e}");
            exit(2);
        }
    }
}
