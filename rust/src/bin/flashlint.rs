//! flashlint CLI: run the in-repo static-analysis pass over a source
//! tree and report violations of the serving-core invariants.
//!
//! ```text
//! flashlint [--json] [--hotpath FILE] [--baseline FILE]
//!           [--write-baseline FILE] [--list-rules] [PATH...]
//! ```
//!
//! PATH defaults to `rust/src` (falling back to `src` when run from
//! inside `rust/`). With `--baseline`, findings recorded in FILE are
//! reported as known and do not affect the exit code — only new
//! findings fail. `--write-baseline` regenerates FILE (sorted,
//! deterministic) from the current findings and exits 0.
//!
//! Exit codes: 0 clean (or all findings known), 1 unsuppressed new
//! findings, 2 usage or I/O error.

use flashbias::lint;
use std::path::PathBuf;
use std::process::ExitCode;

struct Args {
    json: bool,
    list_rules: bool,
    hotpath: Option<PathBuf>,
    baseline: Option<PathBuf>,
    write_baseline: Option<PathBuf>,
    paths: Vec<PathBuf>,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        json: false,
        list_rules: false,
        hotpath: None,
        baseline: None,
        write_baseline: None,
        paths: Vec::new(),
    };
    let mut it = std::env::args().skip(1);
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--json" => args.json = true,
            "--list-rules" => args.list_rules = true,
            "--hotpath" => match it.next() {
                Some(p) => args.hotpath = Some(PathBuf::from(p)),
                None => return Err("--hotpath requires a FILE".to_string()),
            },
            "--baseline" => match it.next() {
                Some(p) => args.baseline = Some(PathBuf::from(p)),
                None => return Err("--baseline requires a FILE".to_string()),
            },
            "--write-baseline" => match it.next() {
                Some(p) => args.write_baseline = Some(PathBuf::from(p)),
                None => {
                    return Err("--write-baseline requires a FILE".to_string())
                }
            },
            "-h" | "--help" => {
                return Err(
                    "usage: flashlint [--json] [--hotpath FILE] \
                     [--baseline FILE] [--write-baseline FILE] \
                     [--list-rules] [PATH...]"
                        .to_string(),
                )
            }
            other if other.starts_with('-') => {
                return Err(format!("unknown flag `{other}`"))
            }
            other => args.paths.push(PathBuf::from(other)),
        }
    }
    if args.baseline.is_some() && args.write_baseline.is_some() {
        return Err(
            "--baseline and --write-baseline are mutually exclusive"
                .to_string(),
        );
    }
    Ok(args)
}

fn default_root() -> PathBuf {
    let preferred = PathBuf::from("rust/src");
    if preferred.is_dir() {
        preferred
    } else {
        PathBuf::from("src")
    }
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("flashlint: {msg}");
            return ExitCode::from(2);
        }
    };

    if args.list_rules {
        for (name, summary, _) in lint::RULES {
            println!("{name:20} {summary}");
        }
        return ExitCode::SUCCESS;
    }

    let cfg = match &args.hotpath {
        None => lint::LintConfig::default(),
        Some(path) => match std::fs::read_to_string(path) {
            Ok(text) => lint::LintConfig::from_manifests(
                &text,
                lint::default_dispatch_manifest(),
            ),
            Err(e) => {
                eprintln!("flashlint: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        },
    };

    let roots = if args.paths.is_empty() {
        vec![default_root()]
    } else {
        args.paths.clone()
    };

    let mut sources: Vec<(String, String)> = Vec::new();
    for root in &roots {
        let files = match lint::collect_rs_files(root) {
            Ok(f) => f,
            Err(e) => {
                eprintln!("flashlint: cannot walk {}: {e}", root.display());
                return ExitCode::from(2);
            }
        };
        for path in files {
            match std::fs::read_to_string(&path) {
                Ok(src) => {
                    sources.push((path.display().to_string(), src))
                }
                Err(e) => {
                    eprintln!(
                        "flashlint: cannot read {}: {e}",
                        path.display()
                    );
                    return ExitCode::from(2);
                }
            }
        }
    }
    if sources.is_empty() {
        eprintln!("flashlint: no .rs files found under the given paths");
        return ExitCode::from(2);
    }

    let mut report = lint::lint_sources(&sources, &cfg);

    if let Some(path) = &args.write_baseline {
        let text = lint::render_baseline(&report);
        if let Err(e) = std::fs::write(path, text + "\n") {
            eprintln!(
                "flashlint: cannot write baseline {}: {e}",
                path.display()
            );
            return ExitCode::from(2);
        }
        println!(
            "flashlint: baseline {} written with {} finding(s)",
            path.display(),
            report.diagnostics.len()
        );
        return ExitCode::SUCCESS;
    }

    if let Some(path) = &args.baseline {
        let entries = match std::fs::read_to_string(path)
            .map_err(|e| e.to_string())
            .and_then(|t| lint::parse_baseline(&t))
        {
            Ok(entries) => entries,
            Err(e) => {
                eprintln!(
                    "flashlint: cannot load baseline {}: {e}",
                    path.display()
                );
                return ExitCode::from(2);
            }
        };
        lint::apply_baseline(&mut report, &entries);
    }

    if args.json {
        println!("{}", lint::render_json(&report));
    } else {
        print!("{}", lint::render_text(&report));
    }
    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
