//! flashlint CLI: run the in-repo static-analysis pass over a source
//! tree and report violations of the serving-core invariants.
//!
//! ```text
//! flashlint [--json] [--hotpath FILE] [--list-rules] [PATH...]
//! ```
//!
//! PATH defaults to `rust/src` (falling back to `src` when run from
//! inside `rust/`). Exit codes: 0 clean, 1 unsuppressed findings,
//! 2 usage or I/O error.

use flashbias::lint;
use std::path::PathBuf;
use std::process::ExitCode;

struct Args {
    json: bool,
    list_rules: bool,
    hotpath: Option<PathBuf>,
    paths: Vec<PathBuf>,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        json: false,
        list_rules: false,
        hotpath: None,
        paths: Vec::new(),
    };
    let mut it = std::env::args().skip(1);
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--json" => args.json = true,
            "--list-rules" => args.list_rules = true,
            "--hotpath" => match it.next() {
                Some(p) => args.hotpath = Some(PathBuf::from(p)),
                None => return Err("--hotpath requires a FILE".to_string()),
            },
            "-h" | "--help" => {
                return Err(
                    "usage: flashlint [--json] [--hotpath FILE] \
                     [--list-rules] [PATH...]"
                        .to_string(),
                )
            }
            other if other.starts_with('-') => {
                return Err(format!("unknown flag `{other}`"))
            }
            other => args.paths.push(PathBuf::from(other)),
        }
    }
    Ok(args)
}

fn default_root() -> PathBuf {
    let preferred = PathBuf::from("rust/src");
    if preferred.is_dir() {
        preferred
    } else {
        PathBuf::from("src")
    }
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("flashlint: {msg}");
            return ExitCode::from(2);
        }
    };

    if args.list_rules {
        for (name, summary, _) in lint::RULES {
            println!("{name:18} {summary}");
        }
        return ExitCode::SUCCESS;
    }

    let cfg = match &args.hotpath {
        None => lint::LintConfig::default(),
        Some(path) => match std::fs::read_to_string(path) {
            Ok(text) => lint::LintConfig {
                hotpath_roots: lint::parse_hotpath(&text),
            },
            Err(e) => {
                eprintln!("flashlint: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        },
    };

    let roots = if args.paths.is_empty() {
        vec![default_root()]
    } else {
        args.paths.clone()
    };

    let mut sources: Vec<(String, String)> = Vec::new();
    for root in &roots {
        let files = match lint::collect_rs_files(root) {
            Ok(f) => f,
            Err(e) => {
                eprintln!("flashlint: cannot walk {}: {e}", root.display());
                return ExitCode::from(2);
            }
        };
        for path in files {
            match std::fs::read_to_string(&path) {
                Ok(src) => {
                    sources.push((path.display().to_string(), src))
                }
                Err(e) => {
                    eprintln!(
                        "flashlint: cannot read {}: {e}",
                        path.display()
                    );
                    return ExitCode::from(2);
                }
            }
        }
    }
    if sources.is_empty() {
        eprintln!("flashlint: no .rs files found under the given paths");
        return ExitCode::from(2);
    }

    let report = lint::lint_sources(&sources, &cfg);
    if args.json {
        println!("{}", lint::render_json(&report));
    } else {
        print!("{}", lint::render_text(&report));
    }
    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
