//! `loadgen` — drive load waves against a flashbias network server.
//!
//! Point it at a live `flashbias serve --listen ADDR` with `--addr`,
//! or let it spawn a private in-process server on an ephemeral
//! loopback port with `--spawn` (no PJRT artifacts needed — the spawn
//! path serves the synthetic demo plan from an empty runtime, which is
//! what the CI smoke gate runs). `--check` turns the run into a gate:
//! nonzero completions, zero protocol errors, zero non-overload
//! errors.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use flashbias::coordinator::Coordinator;
use flashbias::jsonlite::Json;
use flashbias::runtime::Runtime;
use flashbias::server::{
    demo_plan_name, fetch_stats, register_demo_plan, run_wave,
    wait_ready, Cli, NetServer, ServeConfig, WaveConfig,
};

const USAGE: &str = "\
loadgen — load generator for the flashbias network server

USAGE: loadgen (--addr HOST:PORT | --spawn) [OPTIONS]

OPTIONS:
  --addr HOST:PORT    target a running `flashbias serve --listen`
  --spawn             serve an in-process demo server instead
  --connections N     concurrent client connections   (default 8)
  --requests N        interactions per connection     (default 4)
  --rows N            prefill rows per interaction    (default 32)
  --steps N           decode steps per interaction    (default 4;
                      0 = one-shot mode, no sessions)
  --n N               demo plan context length        (default 256;
                      must match the server's --n)
  --plan NAME         serve against NAME instead of the demo plan
  --seed S            base RNG seed                   (default 4269)
  --json              print the outcome as one JSON line
  --check             exit nonzero unless completed > 0 and
                      protocol_errors == errors == 0
";

fn main() {
    let cli = match Cli::parse(std::env::args().skip(1)) {
        Ok(cli) => cli,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if cli.command == "help" {
        print!("{USAGE}");
        return;
    }
    match run(&cli) {
        Ok(out) => print!("{out}"),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(cli: &Cli) -> Result<String> {
    let connections = cli.flag_usize("connections", 8)?;
    let requests = cli.flag_usize("requests", 4)?;
    let rows = cli.flag_usize("rows", 32)?;
    let steps = cli.flag_usize("steps", 4)?;
    let seed = cli.flag_usize("seed", 4269)? as u64;
    let n = cli.flag_usize("n", 256)?;
    if rows == 0 || rows + steps > n {
        bail!("rows={rows} + steps={steps} must fit the plan's \
               context n={n} (and rows > 0)");
    }

    let mut server = None;
    let addr = if cli.flag_bool("spawn") {
        let scfg = ServeConfig::default();
        let coord = Coordinator::new(
            Arc::new(Runtime::empty()),
            scfg.coordinator_config(),
        );
        register_demo_plan(&coord, n)?;
        let srv = NetServer::serve(coord, scfg, "127.0.0.1:0")?;
        let addr = srv.addr().to_string();
        server = Some(srv);
        addr
    } else {
        cli.flag("addr")
            .ok_or_else(|| {
                anyhow!("loadgen needs --addr HOST:PORT or --spawn\n\
                         {USAGE}")
            })?
            .to_string()
    };
    if !wait_ready(&addr, Duration::from_secs(10)) {
        bail!("server at {addr} did not answer ping");
    }

    let plan = match cli.flag("plan") {
        Some(p) => p.to_string(),
        None => demo_plan_name(n),
    };
    let wave = WaveConfig {
        addr: addr.clone(),
        plan,
        connections,
        requests_per_conn: requests,
        prefill_rows: rows,
        decode_steps: steps,
        seed,
    };
    let out = run_wave(&wave);
    // server-side counters (flush reasons, queue depth, batch sizes)
    let stats = fetch_stats(&addr).ok();
    if let Some(srv) = server {
        srv.shutdown();
    }

    let mut text = format!(
        "wave: {connections} conns x {requests} reqs \
         (rows={rows}, steps={steps}) against {addr}\n\
         completed={} overloaded={} errors={} protocol_errors={}\n\
         throughput={:.1} op/s p50={:.1}ms p99={:.1}ms wall={:.2}s\n",
        out.completed,
        out.overloaded,
        out.errors,
        out.protocol_errors,
        out.throughput(),
        out.latency.p50() * 1e3,
        out.latency.p99() * 1e3,
        out.wall_secs,
    );
    if let Some(s) = &stats {
        text.push_str(&format!("server stats: {}\n", s.dump()));
    }
    if cli.flag_bool("json") {
        let doc = Json::obj(vec![
            ("connections", Json::num(connections as f64)),
            ("requests_per_conn", Json::num(requests as f64)),
            ("completed", Json::num(out.completed as f64)),
            ("overloaded", Json::num(out.overloaded as f64)),
            ("errors", Json::num(out.errors as f64)),
            (
                "protocol_errors",
                Json::num(out.protocol_errors as f64),
            ),
            ("throughput", Json::num(out.throughput())),
            ("p50_s", Json::num(out.latency.p50())),
            ("p99_s", Json::num(out.latency.p99())),
            ("wall_secs", Json::num(out.wall_secs)),
            ("server", stats.unwrap_or(Json::Null)),
        ]);
        text.push_str(&doc.dump());
        text.push('\n');
    }
    if cli.flag_bool("check") {
        if out.completed == 0 {
            bail!("check failed: no requests completed\n{text}");
        }
        if out.protocol_errors > 0 || out.errors > 0 {
            bail!(
                "check failed: {} protocol errors, {} errors\n{text}",
                out.protocol_errors,
                out.errors
            );
        }
    }
    Ok(text)
}
