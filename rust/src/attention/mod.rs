//! Host-side reference attention — the dense ground truth the kernel
//! engine, the PJRT artifacts and the simulator are cross-checked
//! against.
//!
//! [`attention`] and its factored/multiplicative variants materialize
//! the score matrix the straightforward way (Eq. (1)/(3)/(15)); they are
//! the oracle for tests. The *streamed* paths — [`online_softmax_attention`]
//! and [`mha`] — are thin wrappers over the block-tiled multi-threaded
//! engine in [`crate::kernels`], which owns the one streaming-softmax
//! compute loop in the crate.

use crate::kernels::{self, KernelConfig};
use crate::tensor::Tensor;

pub const NEG_INF: f32 = -1e30;

/// Options for [`attention`].
#[derive(Clone, Debug, Default)]
pub struct AttnOpts {
    pub causal: bool,
}

fn causal_allowed(i: usize, j: usize, n: usize, m: usize) -> bool {
    // decoder alignment: the mask ends at the key end (j − (m−n) ≤ i)
    j as isize - (m as isize - n as isize) <= i as isize
}

/// Overwrite masked-future positions of an `(N, M)` score matrix with
/// [`NEG_INF`] (decoder-aligned causal mask).
pub fn apply_causal_mask(s: &mut Tensor) {
    let (n, m) = (s.shape()[0], s.shape()[1]);
    for i in 0..n {
        for j in 0..m {
            if !causal_allowed(i, j, n, m) {
                s.set2(i, j, NEG_INF);
            }
        }
    }
}

/// Row softmax with the fully-masked-row guard: a row whose every score
/// is masked (≤ [`kernels::MASKED`]) yields an exactly-zero output row
/// instead of a uniform distribution over masked keys — the decoder
/// alignment with N > M produces such rows.
fn softmax_rows_guarded(s: &Tensor) -> Tensor {
    let (n, m) = (s.shape()[0], s.shape()[1]);
    let mut out = vec![0.0f32; n * m];
    for i in 0..n {
        let row = s.row(i);
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        if mx <= kernels::MASKED {
            continue; // fully masked row → zero weights
        }
        let orow = &mut out[i * m..(i + 1) * m];
        let mut sum = 0.0f32;
        for (o, &x) in orow.iter_mut().zip(row) {
            let e = (x - mx).exp();
            *o = e;
            sum += e;
        }
        let inv = 1.0 / sum;
        for o in orow.iter_mut() {
            *o *= inv;
        }
    }
    Tensor::new(&[n, m], out)
}

/// Reference attention `softmax(q kᵀ/√C + b) v` with optional causal mask.
///
/// `q: (N, C)`, `k`, `v: (M, C)`, `bias: (N, M)` or `None`.
pub fn attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    bias: Option<&Tensor>,
    opts: &AttnOpts,
) -> Tensor {
    let (n, c) = (q.shape()[0], q.shape()[1]);
    let m = k.shape()[0];
    assert_eq!(k.shape()[1], c);
    assert_eq!(v.shape()[0], m);
    if let Some(b) = bias {
        assert_eq!(b.shape(), &[n, m], "bias shape");
    }
    let scale = 1.0 / (c as f32).sqrt();
    let mut s = q.matmul_t(k).scale(scale);
    if let Some(b) = bias {
        s = s.add(b);
    }
    if opts.causal {
        apply_causal_mask(&mut s);
    }
    softmax_rows_guarded(&s).matmul(v)
}

/// FlashBias Eq. (3): factored bias folded into the dot product via
/// channel concatenation. Exactly equivalent to
/// `attention(q, k, v, Some(φ_q φ_kᵀ))`.
pub fn attention_factored(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    phi_q: &Tensor,
    phi_k: &Tensor,
    opts: &AttnOpts,
) -> Tensor {
    let c = q.shape()[1];
    let sqrt_c = (c as f32).sqrt();
    // [q | √C·φ_q] [k | φ_k]ᵀ / √C  ==  q kᵀ/√C + φ_q φ_kᵀ
    let q_ext = q.concat_cols(&phi_q.scale(sqrt_c));
    let k_ext = k.concat_cols(phi_k);
    let mut s = q_ext.matmul_t(&k_ext).scale(1.0 / sqrt_c);
    if opts.causal {
        apply_causal_mask(&mut s);
    }
    softmax_rows_guarded(&s).matmul(v)
}

/// Appendix I Eq. (15): multiplicative (Hadamard) bias.
pub fn attention_multiplicative(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    bias: &Tensor,
) -> Tensor {
    let c = q.shape()[1];
    let scale = 1.0 / (c as f32).sqrt();
    let s = q.matmul_t(k).scale(scale).mul(bias);
    s.softmax_rows().matmul(v)
}

/// Appendix I Eq. (17): multiplicative factored bias via the
/// channel-repeat trick — `q' = [q⊙φ_q,1, …, q⊙φ_q,R] ∈ R^{N×CR}`.
pub fn attention_multiplicative_factored(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    phi_q: &Tensor,
    phi_k: &Tensor,
) -> Tensor {
    let (n, c) = (q.shape()[0], q.shape()[1]);
    let m = k.shape()[0];
    let r = phi_q.shape()[1];
    let expand = |x: &Tensor, phi: &Tensor, rows: usize| {
        Tensor::from_fn(&[rows, r * c], |ix| {
            let (i, col) = (ix[0], ix[1]);
            let (rr, cc) = (col / c, col % c);
            x.at2(i, cc) * phi.at2(i, rr)
        })
    };
    let q_ext = expand(q, phi_q, n);
    let k_ext = expand(k, phi_k, m);
    let scale = 1.0 / (c as f32).sqrt();
    let s = q_ext.matmul_t(&k_ext).scale(scale);
    s.softmax_rows().matmul(v)
}

/// Block-streamed online-softmax attention — a thin wrapper over the
/// tiled kernel engine, kept for its historical key-block signature.
/// Unlike its pre-engine incarnation it honors `opts.causal` instead of
/// silently ignoring masking.
pub fn online_softmax_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    bias: Option<&Tensor>,
    block_k: usize,
    opts: &AttnOpts,
) -> Tensor {
    let cfg = KernelConfig::default().with_blocks(64, block_k);
    match bias {
        Some(b) => kernels::attention_tiled(
            q,
            k,
            v,
            &kernels::DenseTile::from_tensor(b),
            opts.causal,
            &cfg,
        ),
        None => kernels::attention_tiled(
            q, k, v, &kernels::NoBias, opts.causal, &cfg,
        ),
    }
}

/// Multi-head wrapper over the tiled engine: `q/k/v: (H, N, C)`,
/// optional `bias: (H, N, M)`. Heads run data-parallel.
pub fn mha(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    bias: Option<&Tensor>,
    opts: &AttnOpts,
) -> Tensor {
    kernels::mha_tiled(q, k, v, bias, opts.causal,
                       &KernelConfig::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn data(n: usize, m: usize, c: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = Xoshiro256::new(seed);
        (
            Tensor::randn(&[n, c], 1.0, &mut rng),
            Tensor::randn(&[m, c], 1.0, &mut rng),
            Tensor::randn(&[m, c], 1.0, &mut rng),
        )
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        let (q, k, v) = data(8, 12, 4, 0);
        let out = attention(&q, &k, &v, None, &AttnOpts::default());
        // each output row lies within [min, max] of v per column
        for j in 0..4 {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for i in 0..12 {
                lo = lo.min(v.at2(i, j));
                hi = hi.max(v.at2(i, j));
            }
            for i in 0..8 {
                assert!(out.at2(i, j) >= lo - 1e-5);
                assert!(out.at2(i, j) <= hi + 1e-5);
            }
        }
    }

    #[test]
    fn factored_equals_dense_bias() {
        let (q, k, v) = data(10, 14, 8, 1);
        let mut rng = Xoshiro256::new(2);
        let pq = Tensor::randn(&[10, 3], 0.3, &mut rng);
        let pk = Tensor::randn(&[14, 3], 0.3, &mut rng);
        let bias = pq.matmul_t(&pk);
        let dense = attention(&q, &k, &v, Some(&bias), &AttnOpts::default());
        let fact =
            attention_factored(&q, &k, &v, &pq, &pk, &AttnOpts::default());
        assert!(fact.allclose(&dense, 1e-4, 1e-4));
    }

    #[test]
    fn factored_equals_dense_bias_causal() {
        let (q, k, v) = data(9, 9, 8, 3);
        let mut rng = Xoshiro256::new(4);
        let pq = Tensor::randn(&[9, 2], 0.3, &mut rng);
        let pk = Tensor::randn(&[9, 2], 0.3, &mut rng);
        let bias = pq.matmul_t(&pk);
        let opts = AttnOpts { causal: true };
        let dense = attention(&q, &k, &v, Some(&bias), &opts);
        let fact = attention_factored(&q, &k, &v, &pq, &pk, &opts);
        assert!(fact.allclose(&dense, 1e-4, 1e-4));
    }

    #[test]
    fn causal_mask_zeroes_future() {
        let (q, k, v) = data(6, 6, 4, 5);
        let out = attention(&q, &k, &v, None, &AttnOpts { causal: true });
        // first query can only attend to first key → out[0] == v[0]
        for j in 0..4 {
            assert!((out.at2(0, j) - v.at2(0, j)).abs() < 1e-5);
        }
    }

    #[test]
    fn causal_rectangular_alignment() {
        // N=2 queries vs M=4 keys: query 0 sees keys 0..=2, query 1 all 4.
        let (q, k, v) = data(2, 4, 4, 6);
        let out = attention(&q, &k, &v, None, &AttnOpts { causal: true });
        // reference: manual mask
        let scale = 1.0 / 2.0;
        let mut s = q.matmul_t(&k).scale(scale);
        s.set2(0, 3, NEG_INF); // only key 3 masked for query 0
        let expect = s.softmax_rows().matmul(&v);
        assert!(out.allclose(&expect, 1e-5, 1e-5));
    }

    #[test]
    fn fully_masked_rows_yield_zero_output() {
        // decoder alignment with N > M: rows 0..N−M see no key at all and
        // must produce zeros, not a uniform average over masked keys
        let (q, k, v) = data(7, 4, 4, 13);
        let out = attention(&q, &k, &v, None, &AttnOpts { causal: true });
        for i in 0..3 {
            assert!(out.row(i).iter().all(|&x| x == 0.0), "row {i}");
        }
        // the first live row attends exactly to key 0
        for j in 0..4 {
            assert!((out.at2(3, j) - v.at2(0, j)).abs() < 1e-5);
        }
    }

    #[test]
    fn online_softmax_matches_full() {
        let (q, k, v) = data(7, 33, 8, 7);
        let mut rng = Xoshiro256::new(8);
        let bias = Tensor::randn(&[7, 33], 1.0, &mut rng);
        let full = attention(&q, &k, &v, Some(&bias), &AttnOpts::default());
        for block_k in [1, 4, 16, 33, 64] {
            let streamed = online_softmax_attention(
                &q, &k, &v, Some(&bias), block_k, &AttnOpts::default());
            assert!(streamed.allclose(&full, 1e-4, 1e-4),
                    "block_k={block_k}");
        }
    }

    #[test]
    fn online_softmax_honors_causal_mask() {
        // the regression the engine fixes: the streamed path used to
        // silently ignore causal masking
        let (q, k, v) = data(9, 12, 4, 14);
        let opts = AttnOpts { causal: true };
        let full = attention(&q, &k, &v, None, &opts);
        for block_k in [1, 3, 5, 12, 32] {
            let streamed =
                online_softmax_attention(&q, &k, &v, None, block_k, &opts);
            assert!(streamed.allclose(&full, 1e-5, 1e-5),
                    "block_k={block_k}");
        }
    }

    #[test]
    fn multiplicative_factored_equals_dense() {
        let (q, k, v) = data(8, 10, 4, 9);
        let mut rng = Xoshiro256::new(10);
        let pq = Tensor::randn(&[8, 2], 0.5, &mut rng);
        let pk = Tensor::randn(&[10, 2], 0.5, &mut rng);
        let bias = pq.matmul_t(&pk);
        let dense = attention_multiplicative(&q, &k, &v, &bias);
        let fact = attention_multiplicative_factored(&q, &k, &v, &pq, &pk);
        assert!(fact.allclose(&dense, 1e-4, 1e-4));
    }

    #[test]
    fn extreme_bias_is_stable() {
        let (q, k, v) = data(5, 8, 4, 11);
        let bias = Tensor::full(&[5, 8], 200.0);
        let out = attention(&q, &k, &v, Some(&bias), &AttnOpts::default());
        assert!(out.data().iter().all(|x| x.is_finite()));
        let streamed = online_softmax_attention(
            &q, &k, &v, Some(&bias), 4, &AttnOpts::default());
        assert!(streamed.allclose(&out, 1e-4, 1e-4));
    }

    #[test]
    fn mha_shape_and_per_head_equivalence() {
        let mut rng = Xoshiro256::new(12);
        let q = Tensor::randn(&[3, 6, 4], 1.0, &mut rng);
        let k = Tensor::randn(&[3, 8, 4], 1.0, &mut rng);
        let v = Tensor::randn(&[3, 8, 4], 1.0, &mut rng);
        let out = mha(&q, &k, &v, None, &AttnOpts::default());
        assert_eq!(out.shape(), &[3, 6, 4]);
        let h1 = attention(&q.index0(1), &k.index0(1), &v.index0(1), None,
                           &AttnOpts::default());
        assert!(out.index0(1).allclose(&h1, 1e-5, 1e-5));
    }

    #[test]
    fn alibi_bias_attention_via_exact_factors() {
        use crate::bias::{Alibi, ExactBias};
        let (q, k, v) = data(12, 12, 8, 13);
        let alibi = Alibi::new(12, 12, 0.25);
        let dense = attention(&q, &k, &v, Some(&alibi.dense()),
                              &AttnOpts { causal: true });
        let (pq, pk) = alibi.factors();
        let fact = attention_factored(&q, &k, &v, &pq, &pk,
                                      &AttnOpts { causal: true });
        assert!(fact.allclose(&dense, 1e-4, 1e-4));
    }
}
