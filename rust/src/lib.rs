//! FlashBias: fast computation of attention with bias.
//!
//! Rust/JAX/Pallas three-layer reproduction of "FlashBias: Fast Computation
//! of Attention with Bias" (Wu et al., NeurIPS 2025).
//!
//! * [`tensor`] / [`linalg`] — host-side numeric substrate (dense f32
//!   tensors, Jacobi SVD, energy spectra).
//! * [`bias`] — the paper's bias zoo: generators plus exact factorizations.
//! * [`decompose`] — decomposition strategies (exact / SVD / neural / dense).
//! * [`attention`] — reference attention implementations for cross-checking.
//! * [`iomodel`] — analytic HBM-access model (Thm 3.1/3.2, Cor 3.3/3.7).
//! * [`simulator`] — tiled-execution HBM/SRAM simulator (Figures 3/4).
//! * [`runtime`] — PJRT artifact loading + execution.
//! * [`coordinator`] — serving layer: router, dynamic batcher, strategy
//!   selection, metrics.
//! * [`server`] — CLI + config + run loop.
pub mod util;
pub mod tensor;
pub mod linalg;
pub mod bias;
pub mod decompose;
pub mod attention;
pub mod iomodel;
pub mod simulator;
pub mod jsonlite;
pub mod proplite;
pub mod runtime;
pub mod coordinator;
pub mod server;
pub mod benchkit;
