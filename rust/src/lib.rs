// `forbid` is impossible here: runtime/ needs two `unsafe impl Send/Sync`
// for the PJRT handle types (documented at the impls). `deny` + local,
// justified `#[allow(unsafe_code)]` keeps every other module unsafe-free.
#![deny(unsafe_code)]
// Explicit SIMD microkernels (kernels::microkernel) opt into nightly
// portable_simd; the default build stays stable with a bit-identical
// scalar fallback.
#![cfg_attr(feature = "simd", feature(portable_simd))]

//! FlashBias: fast computation of attention with bias.
//!
//! Rust/JAX/Pallas three-layer reproduction of "FlashBias: Fast
//! Computation of Attention with Bias" (Wu et al., NeurIPS 2025).
//!
//! # The pipeline: bias → plan → execute
//!
//! The single public entry point is [`plan`]: declare any bias from the
//! paper's zoo as a [`plan::BiasSpec`], let the [`plan::Planner`] run the
//! Table 1 decision procedure (exact / SVD / neural / dense fallback)
//! fused with the analytic IO cost model, and hand the resulting
//! [`plan::AttentionPlan`] to any [`plan::Executor`] backend:
//!
//! ```no_run
//! # use flashbias::{iomodel::Geometry, plan::{self, BiasSpec, PlanOptions, Planner}};
//! # use flashbias::{tensor::Tensor, util::Xoshiro256};
//! # let mut rng = Xoshiro256::new(0);
//! # let q = Tensor::randn(&[256, 64], 1.0, &mut rng);
//! # let k = Tensor::randn(&[256, 64], 1.0, &mut rng);
//! # let v = Tensor::randn(&[256, 64], 1.0, &mut rng);
//! let spec = BiasSpec::alibi(256, 256, 0.25);
//! let plan = Planner::default().plan(
//!     &spec, &Geometry::square(256, 64, 0, 51200),
//!     &PlanOptions::default())?;
//! let out = plan::execute(&plan, &q, &k, &v)?;
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! # Layers
//!
//! * [`tensor`] / [`linalg`] — host-side numeric substrate (dense f32
//!   tensors, zero-copy [`tensor::View2`] tile views, Jacobi SVD,
//!   energy spectra). [`tensor::Strip`] stores factor strips at
//!   reduced precision ([`tensor::StripDType`]: f32 / bf16 / f16 /
//!   experimental int8 with per-column scales) — the planner's
//!   `strip_policy` gates quantization on a measured error bound.
//! * [`bias`] — the paper's bias zoo: generators plus exact
//!   factorizations (the raw material [`plan::BiasSpec`] wraps).
//! * [`decompose`] — decomposition mechanisms (SVD / neural / low-rank +
//!   sparse) the planner drives; returns typed errors, never panics.
//!   Large tables at small rank take the randomized range-finder SVD
//!   (Halko et al.) with the Jacobi kept as the reference oracle.
//! * [`factorstore`] — **the amortization layer**: a thread-safe,
//!   content-addressed *tiered* factor store (resident byte-budget LRU
//!   → spill-to-disk eviction → cross-node sharing over TCP →
//!   decompose; per-tier counters; jsonlite persistence).
//!   `Planner::plan_with_store` keys SVD/neural outcomes by
//!   `BiasSpec::fingerprint()` + policy, so repeated plans share
//!   factors with zero decomposition work; the coordinator shares one
//!   store across its serving loop (and can export it to the fleet via
//!   `Coordinator::serve_store`), and the CLI (`--store*`, `warm`)
//!   persists it across processes.
//! * [`kernels`] — **the compute spine**: the block-tiled,
//!   multi-threaded streaming-softmax engine with per-tile
//!   [`kernels::BiasTile`] providers (dense view / tile-local factor
//!   contraction — dequantizing reduced-precision strips on the fly —
//!   / JIT generation) and causal tile classification. The inner
//!   loops are the fixed-width register microkernels of
//!   [`kernels::microkernel`] (scalar by default; bit-identical
//!   `std::simd` under the nightly `simd` feature), and
//!   [`kernels::KernelConfig::for_geometry_dtype`] fits tile sizes to
//!   SRAM at the strips' stored width. Host executor, simulator
//!   numerics, the `attention` wrappers and the coordinator's batched
//!   serving path all drive this one engine; `make bench-check` gates
//!   its speed against a checked-in baseline.
//! * [`attention`] — dense reference oracle ([`attention::attention`])
//!   plus thin engine wrappers ([`attention::mha`],
//!   [`attention::online_softmax_attention`]).
//! * [`iomodel`] — analytic HBM-access model (Thm 3.1/3.2, Cor 3.3/3.7);
//!   the planner's cost gate.
//! * [`plan`] — **the API**: `BiasSpec` → `Planner` → `AttentionPlan` →
//!   `Executor` (host / simulator / PJRT); [`plan::plan_bias_tile`]
//!   maps a plan's mode onto an engine bias provider. For
//!   autoregressive serving, [`plan::SessionState`] is the prefill/
//!   decode split in miniature: an append-only [`tensor::KvCache`],
//!   the plan, and the last [`kernels::DecodeCarry`]; each `step` is
//!   the engine's [`kernels::run_decode_step`] — a 1×M pass that is
//!   bit-identical to the matching prefill row, with the bias row
//!   supplied as an O(rank·M) strip instead of an O(M) table read.
//! * [`simulator`] — tiled-execution HBM/SRAM simulator (Figures 3/4)
//!   behind [`plan::SimExecutor`]; its block-size model also sizes the
//!   engine's tiles, so accounting and numerics share one schedule.
//! * [`runtime`] — PJRT artifact loading + execution (stubbed outside
//!   the accelerator image, see [`xla_stub`]).
//! * [`coordinator`] — serving layer: router, dynamic batcher, metrics,
//!   worker pool; host-plan batches execute as one batched
//!   `(B, H, N, C)` kernel-engine call. Decode sessions
//!   ([`coordinator::SessionHandle`], `open_session` / `prefill` /
//!   `step` / `close_session`) append K/V at submit time and ride the
//!   same batcher, so one flush carries a mixed prefill+decode batch
//!   and step outputs are bitwise stable across flush orderings.
//! * [`server`] — CLI + config + run loop (including the `plan`
//!   subcommand), and the network front-end: a TGI-style TCP router
//!   ([`server::NetServer`]) with bounded admission
//!   ([`server::queue`]), a single dispatch thread owning the
//!   coordinator with a waiting/served flush policy, typed error
//!   frames over the shared [`util::frame`] codec, and the
//!   [`server::loadgen`] wave driver behind the `loadgen` binary and
//!   the `serving_load` bench.
//! * [`lint`] — flashlint, the in-repo static-analysis pass enforcing
//!   the serving core's concurrency and panic-safety invariants
//!   (tokenizer, rules R1–R5, hot-path call-graph); paired with the
//!   [`util::sync`] runtime lock-order audit.
pub mod util;
pub mod tensor;
pub mod linalg;
pub mod bias;
pub mod decompose;
pub mod factorstore;
pub mod attention;
pub mod kernels;
pub mod iomodel;
pub mod plan;
pub mod simulator;
pub mod jsonlite;
pub mod proplite;
pub mod xla_stub;
pub mod runtime;
pub mod coordinator;
pub mod server;
pub mod benchkit;
pub mod lint;
