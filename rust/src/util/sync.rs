//! Instrumented, poison-recovering lock wrappers for the serving core.
//!
//! Every lock in `factorstore/`, `coordinator/`, and `runtime/` goes
//! through this shim instead of `std::sync` directly (enforced by the
//! `raw-sync` flashlint rule). The wrappers add two things on top of the
//! std primitives:
//!
//! 1. **Poison recovery.** `lock_recover()` / `read_recover()` /
//!    `write_recover()` never return `Err`: if another thread panicked
//!    while holding the lock, the wrapper logs the event once (per lock)
//!    and takes the inner data anyway. A single panicked worker must not
//!    wedge the whole coordinator — every shared structure here is
//!    either idempotently rebuildable (caches, metrics) or protected by
//!    its own content checks (the factor store verifies finiteness and
//!    shape on read), so continuing past a poisoned lock is safe.
//!
//! 2. **Lock-order auditing.** Under `cfg(debug_assertions)` or the
//!    `sync-audit` feature, each named lock records *held → attempted*
//!    edges into a process-global lock-order graph, and
//!    [`check_blocking`] records any lock held while entering a blocking
//!    region (file or socket I/O). Tests (`rust/tests/sync_audit.rs`)
//!    hammer the serving paths concurrently and assert the graph stays
//!    acyclic and the blocking-violation list stays empty. In release
//!    builds without the feature all audit hooks compile to nothing.
//!
//! The audit is name-based: locks constructed with the same `&'static
//! str` name are one node in the graph, which is exactly what we want —
//! the ordering invariant is between *roles* ("factorstore.inner" before
//! "factorstore.spill"), not between instances.

#![allow(clippy::new_without_default)]

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicBool, Ordering};

// This module *is* the shim the raw-sync lint rule points everyone at,
// so it is the one place allowed to touch std::sync lock types directly.

/// Named, poison-recovering `std::sync::Mutex` wrapper.
pub struct Mutex<T> {
    name: &'static str,
    inner: std::sync::Mutex<T>,
    poison_logged: AtomicBool,
}

impl<T> Mutex<T> {
    /// `name` identifies this lock in the audit graph and in poison
    /// logs; use a stable `module.role` form, e.g. `"factorstore.inner"`.
    pub fn new(name: &'static str, value: T) -> Self {
        Self {
            name,
            inner: std::sync::Mutex::new(value),
            poison_logged: AtomicBool::new(false),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Acquire the lock, recovering (and logging once) if it is poisoned.
    pub fn lock_recover(&self) -> MutexGuard<'_, T> {
        audit::on_attempt(self.name);
        let guard = match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                self.log_poison();
                poisoned.into_inner()
            }
        };
        audit::on_acquire(self.name);
        MutexGuard {
            name: self.name,
            inner: guard,
        }
    }

    /// Non-blocking acquire; `None` if the lock is currently held.
    /// Poison still recovers rather than erroring.
    pub fn try_lock_recover(&self) -> Option<MutexGuard<'_, T>> {
        let guard = match self.inner.try_lock() {
            Ok(g) => g,
            Err(std::sync::TryLockError::Poisoned(poisoned)) => {
                self.log_poison();
                poisoned.into_inner()
            }
            Err(std::sync::TryLockError::WouldBlock) => return None,
        };
        audit::on_acquire(self.name);
        Some(MutexGuard {
            name: self.name,
            inner: guard,
        })
    }

    fn log_poison(&self) {
        if !self.poison_logged.swap(true, Ordering::Relaxed) {
            eprintln!(
                "[util::sync] lock `{}` was poisoned by a panicked \
                 thread; recovering with the inner data",
                self.name
            );
        }
    }
}

impl<T: fmt::Debug> fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut d = f.debug_struct("Mutex");
        d.field("name", &self.name);
        match self.inner.try_lock() {
            Ok(g) => d.field("data", &&*g).finish(),
            Err(_) => d.field("data", &"<locked>").finish(),
        }
    }
}

/// Guard returned by [`Mutex::lock_recover`]; pops the audit stack on drop.
pub struct MutexGuard<'a, T> {
    name: &'static str,
    inner: std::sync::MutexGuard<'a, T>,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        audit::on_release(self.name);
    }
}

/// Named, poison-recovering `std::sync::RwLock` wrapper.
pub struct RwLock<T> {
    name: &'static str,
    inner: std::sync::RwLock<T>,
    poison_logged: AtomicBool,
}

impl<T> RwLock<T> {
    pub fn new(name: &'static str, value: T) -> Self {
        Self {
            name,
            inner: std::sync::RwLock::new(value),
            poison_logged: AtomicBool::new(false),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn read_recover(&self) -> RwLockReadGuard<'_, T> {
        audit::on_attempt(self.name);
        let guard = match self.inner.read() {
            Ok(g) => g,
            Err(poisoned) => {
                self.log_poison();
                poisoned.into_inner()
            }
        };
        audit::on_acquire(self.name);
        RwLockReadGuard {
            name: self.name,
            inner: guard,
        }
    }

    pub fn write_recover(&self) -> RwLockWriteGuard<'_, T> {
        audit::on_attempt(self.name);
        let guard = match self.inner.write() {
            Ok(g) => g,
            Err(poisoned) => {
                self.log_poison();
                poisoned.into_inner()
            }
        };
        audit::on_acquire(self.name);
        RwLockWriteGuard {
            name: self.name,
            inner: guard,
        }
    }

    fn log_poison(&self) {
        if !self.poison_logged.swap(true, Ordering::Relaxed) {
            eprintln!(
                "[util::sync] rwlock `{}` was poisoned by a panicked \
                 thread; recovering with the inner data",
                self.name
            );
        }
    }
}

impl<T: fmt::Debug> fmt::Debug for RwLock<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut d = f.debug_struct("RwLock");
        d.field("name", &self.name);
        match self.inner.try_read() {
            Ok(g) => d.field("data", &&*g).finish(),
            Err(_) => d.field("data", &"<locked>").finish(),
        }
    }
}

pub struct RwLockReadGuard<'a, T> {
    name: &'static str,
    inner: std::sync::RwLockReadGuard<'a, T>,
}

impl<T> Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> Drop for RwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        audit::on_release(self.name);
    }
}

pub struct RwLockWriteGuard<'a, T> {
    name: &'static str,
    inner: std::sync::RwLockWriteGuard<'a, T>,
}

impl<T> Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

impl<T> Drop for RwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        audit::on_release(self.name);
    }
}

// ---------------------------------------------------------------------------
// audit surface (no-ops unless debug_assertions or feature = "sync-audit")
// ---------------------------------------------------------------------------

/// True when the lock-order/blocking audit is compiled in.
pub const fn audit_enabled() -> bool {
    cfg!(any(debug_assertions, feature = "sync-audit"))
}

/// Declare that the caller is about to enter a blocking region (file or
/// socket I/O, long sleep). Any lock currently held by this thread that
/// is not in `allowed` is recorded as a blocking violation. The `allowed`
/// list is for locks whose *purpose* is to serialize that I/O (e.g. the
/// spill-file lock).
#[inline]
pub fn check_blocking(site: &str, allowed: &[&str]) {
    audit::check_blocking(site, allowed);
}

/// All distinct `held → attempted` lock-order edges observed so far.
pub fn order_edges() -> Vec<(String, String)> {
    audit::edges()
}

/// Search the observed lock-order graph for a cycle; returns the node
/// sequence (first node repeated at the end) if one exists.
pub fn find_order_cycle() -> Option<Vec<String>> {
    let edges = order_edges();
    let mut adj: std::collections::BTreeMap<&str, Vec<&str>> =
        std::collections::BTreeMap::new();
    for (a, b) in &edges {
        adj.entry(a.as_str()).or_default().push(b.as_str());
    }
    // Iterative DFS with tri-color marking; a back edge closes a cycle.
    let mut color: std::collections::BTreeMap<&str, u8> =
        std::collections::BTreeMap::new();
    let nodes: Vec<&str> = adj.keys().copied().collect();
    for &start in &nodes {
        if color.get(start).copied().unwrap_or(0) != 0 {
            continue;
        }
        let mut path: Vec<&str> = Vec::new();
        let mut stack: Vec<(&str, usize)> = vec![(start, 0)];
        loop {
            let (node, idx) = match stack.last() {
                Some(&(n, i)) => (n, i),
                None => break,
            };
            if idx == 0 {
                color.insert(node, 1);
                path.push(node);
            }
            let succs: &[&str] =
                adj.get(node).map(|v| v.as_slice()).unwrap_or(&[]);
            if idx < succs.len() {
                if let Some(top) = stack.last_mut() {
                    top.1 += 1;
                }
                let succ = succs[idx];
                match color.get(succ).copied().unwrap_or(0) {
                    0 => stack.push((succ, 0)),
                    1 => {
                        // Back edge: slice the cycle out of the path.
                        let from = path
                            .iter()
                            .position(|&n| n == succ)
                            .unwrap_or(0);
                        let mut cycle: Vec<String> = path[from..]
                            .iter()
                            .map(|s| s.to_string())
                            .collect();
                        cycle.push(succ.to_string());
                        return Some(cycle);
                    }
                    _ => {}
                }
            } else {
                color.insert(node, 2);
                path.pop();
                stack.pop();
            }
        }
    }
    None
}

/// All recorded lock-held-across-blocking-call violations.
pub fn blocking_violations() -> Vec<String> {
    audit::blocking_violations()
}

/// Clear the audit state (edges + violations). Test-scoped helper.
pub fn reset_audit() {
    audit::reset()
}

#[cfg(any(debug_assertions, feature = "sync-audit"))]
mod audit {
    use std::cell::RefCell;
    use std::collections::BTreeSet;
    use std::sync::{Mutex, OnceLock};

    thread_local! {
        /// Names of locks this thread currently holds, in acquire order.
        static HELD: RefCell<Vec<&'static str>> =
            const { RefCell::new(Vec::new()) };
    }

    #[derive(Default)]
    struct State {
        edges: BTreeSet<(&'static str, &'static str)>,
        blocking: Vec<String>,
    }

    fn state() -> &'static Mutex<State> {
        static STATE: OnceLock<Mutex<State>> = OnceLock::new();
        STATE.get_or_init(|| Mutex::new(State::default()))
    }

    fn with_state<R>(f: impl FnOnce(&mut State) -> R) -> R {
        // The audit's own mutex is a leaf: it is only taken inside these
        // short helpers, which never call back into wrapper locks, so it
        // cannot participate in an ordering cycle. Recover from poison
        // so an audit assertion failure cannot cascade.
        let mut st = state().lock().unwrap_or_else(|p| p.into_inner());
        f(&mut st)
    }

    pub(super) fn on_attempt(name: &'static str) {
        let new_edges: Vec<(&'static str, &'static str)> = HELD.with(|h| {
            h.borrow()
                .iter()
                .filter(|&&held| held != name)
                .map(|&held| (held, name))
                .collect()
        });
        if new_edges.is_empty() {
            return;
        }
        with_state(|st| {
            for e in new_edges {
                st.edges.insert(e);
            }
        });
    }

    pub(super) fn on_acquire(name: &'static str) {
        HELD.with(|h| h.borrow_mut().push(name));
    }

    pub(super) fn on_release(name: &'static str) {
        HELD.with(|h| {
            let mut v = h.borrow_mut();
            if let Some(pos) = v.iter().rposition(|&n| n == name) {
                v.remove(pos);
            }
        });
    }

    pub(super) fn check_blocking(site: &str, allowed: &[&str]) {
        let offending: Vec<&'static str> = HELD.with(|h| {
            h.borrow()
                .iter()
                .copied()
                .filter(|n| !allowed.contains(n))
                .collect()
        });
        if offending.is_empty() {
            return;
        }
        with_state(|st| {
            for name in offending {
                st.blocking
                    .push(format!("{site} entered while holding `{name}`"));
            }
        });
    }

    pub(super) fn edges() -> Vec<(String, String)> {
        with_state(|st| {
            st.edges
                .iter()
                .map(|&(a, b)| (a.to_string(), b.to_string()))
                .collect()
        })
    }

    pub(super) fn blocking_violations() -> Vec<String> {
        with_state(|st| st.blocking.clone())
    }

    pub(super) fn reset() {
        with_state(|st| {
            st.edges.clear();
            st.blocking.clear();
        });
    }
}

#[cfg(not(any(debug_assertions, feature = "sync-audit")))]
mod audit {
    #[inline(always)]
    pub(super) fn on_attempt(_name: &'static str) {}
    #[inline(always)]
    pub(super) fn on_acquire(_name: &'static str) {}
    #[inline(always)]
    pub(super) fn on_release(_name: &'static str) {}
    #[inline(always)]
    pub(super) fn check_blocking(_site: &str, _allowed: &[&str]) {}
    pub(super) fn edges() -> Vec<(String, String)> {
        Vec::new()
    }
    pub(super) fn blocking_violations() -> Vec<String> {
        Vec::new()
    }
    pub(super) fn reset() {}
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests that reset or assert on the process-global audit state must
    /// not interleave with each other.
    fn audit_test_guard() -> std::sync::MutexGuard<'static, ()> {
        static GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());
        GATE.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn lock_recover_roundtrip() {
        let m = Mutex::new("test.basic", 41);
        *m.lock_recover() += 1;
        assert_eq!(*m.lock_recover(), 42);
        assert_eq!(m.name(), "test.basic");
    }

    #[test]
    fn rwlock_read_write() {
        let l = RwLock::new("test.rw", vec![1, 2]);
        l.write_recover().push(3);
        assert_eq!(l.read_recover().len(), 3);
    }

    #[test]
    fn poisoned_mutex_recovers() {
        let m = std::sync::Arc::new(Mutex::new("test.poison", 7));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock_recover();
            panic!("poison it");
        })
        .join();
        // The panic above poisons the inner std mutex; recovery must
        // still hand the data back.
        assert_eq!(*m.lock_recover(), 7);
    }

    #[test]
    fn try_lock_sees_contention() {
        let m = Mutex::new("test.try", 0);
        let g = m.lock_recover();
        assert!(m.try_lock_recover().is_none());
        drop(g);
        assert!(m.try_lock_recover().is_some());
    }

    #[test]
    fn debug_formats_without_deadlock() {
        let m = Mutex::new("test.debug", 5);
        let g = m.lock_recover();
        let s = format!("{m:?}");
        assert!(s.contains("test.debug"));
        assert!(s.contains("<locked>"));
        drop(g);
        assert!(format!("{m:?}").contains('5'));
    }

    #[test]
    fn audit_records_edges_and_cycles() {
        if !audit_enabled() {
            return;
        }
        let _gate = audit_test_guard();
        reset_audit();
        let a = Mutex::new("test.edge_a", ());
        let b = Mutex::new("test.edge_b", ());
        {
            let _ga = a.lock_recover();
            let _gb = b.lock_recover();
        }
        let edges = order_edges();
        assert!(edges
            .iter()
            .any(|(x, y)| x == "test.edge_a" && y == "test.edge_b"));
        assert!(find_order_cycle().is_none(), "a->b alone is acyclic");
        // Take them in the opposite order: now the graph has a 2-cycle.
        {
            let _gb = b.lock_recover();
            let _ga = a.lock_recover();
        }
        let cycle = find_order_cycle().expect("inversion forms a cycle");
        assert!(cycle.len() >= 3);
        assert_eq!(cycle.first(), cycle.last());
        reset_audit();
    }

    #[test]
    fn blocking_check_flags_held_locks() {
        if !audit_enabled() {
            return;
        }
        let _gate = audit_test_guard();
        reset_audit();
        let m = Mutex::new("test.blocker", ());
        {
            let _g = m.lock_recover();
            check_blocking("tests::fake_io", &["some.other"]);
        }
        let v = blocking_violations();
        assert!(v.iter().any(|s| s.contains("test.blocker")));
        // Allowed locks are not violations.
        reset_audit();
        {
            let _g = m.lock_recover();
            check_blocking("tests::fake_io", &["test.blocker"]);
        }
        assert!(blocking_violations().is_empty());
        reset_audit();
    }
}
