//! Small substrates shared across the crate: a deterministic PRNG,
//! normal sampling, streaming statistics, timers and human-readable
//! formatting.
//!
//! The vendored-crate universe has no `rand`/`statrs`; everything the
//! benches and the coordinator need is implemented here.

pub mod frame;
pub mod sync;

use std::time::{Duration, Instant};

/// SplitMix64: tiny, fast, full-period seeding PRNG (Steele et al.).
///
/// Used directly for data generation and to seed [`Xoshiro256`].
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse generator (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Standard normal via Box–Muller (second value dropped; generation is
    /// never the hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of standard normals scaled by `scale`.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * scale).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Streaming summary statistics over f64 samples.
///
/// Keeps all samples (bench scale: thousands) so exact percentiles are
/// available; Welford for mean/variance avoids catastrophic cancellation.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Stats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let delta = x - self.mean;
        self.mean += delta / n;
        self.m2 += delta * (x - self.mean);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.samples.len() < 2 {
            0.0
        } else {
            self.m2 / (self.samples.len() as f64 - 1.0)
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact percentile by nearest-rank (q in [0, 1]).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let rank =
            ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
        sorted[rank - 1]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }
}

/// Wall-clock timer for bench loops.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }
}

/// Time `f` over `iters` iterations after `warmup` warmup calls; returns
/// per-iteration seconds as a [`Stats`].
pub fn bench_loop<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    for _ in 0..iters {
        let t = Timer::start();
        f();
        stats.push(t.elapsed_secs());
    }
    stats
}

/// `1536 -> "1.5 KB"`, `3221225472 -> "3.0 GB"`.
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{} {}", bytes, UNITS[0])
    } else {
        format!("{:.1} {}", v, UNITS[unit])
    }
}

/// `0.001234 -> "1.234 ms"`.
pub fn human_secs(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{:.3} s", secs)
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.1} µs", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_differs_by_seed() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn xoshiro_uniform_range() {
        let mut rng = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn xoshiro_normal_moments() {
        let mut rng = Xoshiro256::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn next_below_in_range() {
        let mut rng = Xoshiro256::new(3);
        for _ in 0..1000 {
            assert!(rng.next_below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn stats_mean_var() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn stats_percentiles() {
        let mut s = Stats::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.p99(), 99.0);
        assert_eq!(s.percentile(1.0), 100.0);
    }

    #[test]
    fn human_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(1536), "1.5 KB");
        assert_eq!(human_bytes(3 << 30), "3.0 GB");
        assert_eq!(human_secs(0.001234), "1.234 ms");
        assert_eq!(human_secs(2.5), "2.500 s");
    }

    #[test]
    fn bench_loop_counts() {
        let mut calls = 0;
        let stats = bench_loop(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(stats.len(), 5);
    }
}
