//! Length-prefixed jsonlite frame codec — the one wire format every
//! TCP surface in the crate speaks.
//!
//! A frame is a 4-byte little-endian payload length followed by one
//! JSON document. Both network surfaces — the factor-sharing tier
//! ([`crate::factorstore::remote`]) and the serving front-end
//! ([`crate::server::netserver`]) — use exactly this codec, so there is
//! one implementation and one hostile-input surface: length prefixes
//! are checked against an explicit cap *before* any allocation, torn
//! payloads are typed errors (a clean EOF between frames is `None`),
//! and non-UTF-8 or unparseable payloads never panic.
//!
//! Size caps are the callee's choice per direction: a service reads
//! unauthenticated *requests* under a small cap
//! ([`MAX_REQUEST_BYTES`]-sized) so a hostile 4-byte prefix cannot
//! force a huge allocation, while *responses* from a trusted peer may
//! use the large [`MAX_FRAME_BYTES`] cap.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::jsonlite::{Json, ParseError};

/// Upper bound on one trusted *response* frame — a (16k + 16k) · r=512
/// factor pair prints well under this; anything bigger is a protocol
/// error, not a payload.
pub const MAX_FRAME_BYTES: u32 = 256 * 1024 * 1024;

/// Upper bound on one inbound *request* frame for services whose
/// requests are small (the factor service's are ~60 bytes of JSON).
/// Honoring the response-sized cap for unauthenticated inbound traffic
/// would let any peer make a server allocate 256 MiB per connection
/// from a 4-byte length prefix.
pub const MAX_REQUEST_BYTES: u32 = 64 * 1024;

/// Per-connection read/write timeout: a dead peer costs one timeout,
/// then the caller degrades (falls back, closes the connection).
pub const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Bound on establishing a connection — a black-holed peer (firewalled
/// host, dead route) must cost seconds, not the OS's multi-minute TCP
/// connect timeout.
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// Typed frame-codec failure. Every variant is a protocol-level fact a
/// server can report back (or log) without guessing at an `io::Error`
/// string.
#[derive(Debug)]
pub enum FrameError {
    /// The announced (or outgoing) payload length exceeds the cap.
    TooLarge { len: u64, cap: u32 },
    /// EOF mid-payload: the length prefix promised more bytes than the
    /// stream delivered.
    Torn { wanted: usize },
    /// The payload is not valid UTF-8.
    NonUtf8(std::str::Utf8Error),
    /// The payload is UTF-8 but not a JSON document.
    Parse(ParseError),
    /// Transport failure (including read/write timeouts).
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::TooLarge { len, cap } => {
                write!(f, "frame of {len} bytes exceeds the {cap} limit")
            }
            FrameError::Torn { wanted } => {
                write!(f, "torn frame: EOF with {wanted} bytes missing")
            }
            FrameError::NonUtf8(e) => write!(f, "non-utf8 frame: {e}"),
            FrameError::Parse(e) => write!(f, "bad frame: {e}"),
            FrameError::Io(e) => write!(f, "frame io: {e}"),
        }
    }
}

impl std::error::Error for FrameError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FrameError::NonUtf8(e) => Some(e),
            FrameError::Parse(e) => Some(e),
            FrameError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        FrameError::Io(e)
    }
}

/// Write one length-prefixed jsonlite frame (always bounded by
/// [`MAX_FRAME_BYTES`] — nothing in this crate legitimately emits
/// more).
pub fn write_frame(w: &mut impl Write,
                   json: &Json) -> Result<(), FrameError> {
    let payload = json.dump();
    let bytes = payload.as_bytes();
    if bytes.len() > MAX_FRAME_BYTES as usize {
        return Err(FrameError::TooLarge {
            len: bytes.len() as u64,
            cap: MAX_FRAME_BYTES,
        });
    }
    w.write_all(&(bytes.len() as u32).to_le_bytes())?;
    w.write_all(bytes)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed jsonlite frame under the trusted
/// [`MAX_FRAME_BYTES`] cap. `Ok(None)` is a clean EOF (the peer closed
/// between frames); a torn frame is an error.
pub fn read_frame(r: &mut impl Read)
                  -> Result<Option<Json>, FrameError> {
    read_frame_limited(r, MAX_FRAME_BYTES)
}

/// [`read_frame`] with an explicit size cap — services read *requests*
/// with a small cap ([`MAX_REQUEST_BYTES`]-sized) so a hostile length
/// prefix cannot force a huge allocation. The cap check happens before
/// the payload buffer exists.
pub fn read_frame_limited(r: &mut impl Read,
                          max_bytes: u32)
                          -> Result<Option<Json>, FrameError> {
    let mut len = [0u8; 4];
    match r.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == ErrorKind::UnexpectedEof => {
            // nothing-or-partial-prefix between frames is a clean close
            return Ok(None);
        }
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len);
    if len > max_bytes {
        return Err(FrameError::TooLarge {
            len: len as u64,
            cap: max_bytes,
        });
    }
    let mut buf = vec![0u8; len as usize];
    match r.read_exact(&mut buf) {
        Ok(()) => {}
        Err(e) if e.kind() == ErrorKind::UnexpectedEof => {
            return Err(FrameError::Torn {
                wanted: len as usize,
            });
        }
        Err(e) => return Err(e.into()),
    }
    let text =
        std::str::from_utf8(&buf).map_err(FrameError::NonUtf8)?;
    Ok(Some(Json::parse(text).map_err(FrameError::Parse)?))
}

/// Apply the standard per-connection IO deadline to both directions of
/// a stream — every TCP surface calls this right after accept/connect
/// so a dead peer costs one bounded timeout, never a parked thread.
pub fn set_io_timeouts(stream: &TcpStream,
                       timeout: Duration) -> std::io::Result<()> {
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip() {
        let json = Json::obj(vec![
            ("op", Json::str("get")),
            ("key", Json::str("00000000000000ff")),
        ]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &json).unwrap();
        assert_eq!(&buf[..4], &(buf.len() as u32 - 4).to_le_bytes()[..]);
        let back = read_frame(&mut Cursor::new(&buf)).unwrap().unwrap();
        assert_eq!(back, json);
    }

    #[test]
    fn clean_eof_is_none() {
        let empty: &[u8] = &[];
        assert!(read_frame(&mut Cursor::new(empty)).unwrap().is_none());
    }

    #[test]
    fn oversized_prefix_is_typed() {
        let bytes = u32::MAX.to_le_bytes();
        match read_frame(&mut Cursor::new(&bytes)) {
            Err(FrameError::TooLarge { len, cap }) => {
                assert_eq!(len, u32::MAX as u64);
                assert_eq!(cap, MAX_FRAME_BYTES);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn request_cap_rejects_before_allocating() {
        // a response-sized (256 MiB) length prefix under the small
        // request cap must be refused at the cap check, not allocated
        let bytes = MAX_FRAME_BYTES.to_le_bytes();
        let err =
            read_frame_limited(&mut Cursor::new(&bytes),
                               MAX_REQUEST_BYTES)
                .expect_err("over-cap");
        assert!(err.to_string().contains("limit"), "{err}");
    }

    #[test]
    fn torn_payload_is_typed() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&100u32.to_le_bytes());
        buf.extend_from_slice(b"short");
        match read_frame(&mut Cursor::new(&buf)) {
            Err(FrameError::Torn { wanted }) => assert_eq!(wanted, 100),
            other => panic!("expected Torn, got {other:?}"),
        }
    }

    #[test]
    fn non_utf8_and_bad_json_are_typed() {
        let payload: &[u8] = &[0xFF, 0xFE, 0x80, 0x81];
        let mut wire = (payload.len() as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(payload);
        let err = read_frame(&mut Cursor::new(&wire)).expect_err("utf8");
        assert!(err.to_string().contains("utf8"), "{err}");

        let mut wire = (3u32).to_le_bytes().to_vec();
        wire.extend_from_slice(b"{{{");
        assert!(matches!(read_frame(&mut Cursor::new(&wire)),
                         Err(FrameError::Parse(_))));
    }

    #[test]
    fn outgoing_frames_are_capped() {
        // a payload over the cap is refused client-side, before any
        // bytes hit the wire
        let huge = Json::str(&"x".repeat(MAX_FRAME_BYTES as usize + 8));
        let mut sink = Vec::new();
        match write_frame(&mut sink, &huge) {
            Err(FrameError::TooLarge { .. }) => assert!(sink.is_empty()),
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }
}
