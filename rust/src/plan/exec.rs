//! [`Executor`] — one `execute(&plan, q, k, v)` call, three backends.
//!
//! * [`HostExecutor`] — the block-tiled multi-threaded kernel engine
//!   (`crate::kernels`); always available.
//! * [`SimExecutor`] — the same engine driven with the *simulator's*
//!   block sizes, plus a [`SimReport`] of the schedule's HBM traffic —
//!   numerics and accounting agree on what is loaded per tile, so a
//!   single call yields both the output and the Figure 3/4 instrument.
//! * [`PjrtExecutor`] — routes the plan to a compiled PJRT artifact
//!   through the shape-bucket [`Router`] (requires `make artifacts`).
//!
//! Backends accept any [`AttentionPlan`]; callers never re-inspect the
//! bias class or re-wire factor strips by hand. The mode → provider
//! mapping lives in [`plan_bias_tile`]; no executor re-implements a
//! compute loop of its own (multiplicative plans, which have no tiled
//! schedule, fall back to the `crate::attention` reference math).

use std::cell::Cell;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::attention;
use crate::coordinator::router::{RouteKey, Router};
use crate::iomodel::Geometry;
use crate::kernels::{
    self, AlibiTile, BiasTile, DenseTile, FactoredTile, KernelConfig,
    NoBias,
};
use crate::runtime::{HostValue, Runtime};
use crate::simulator::{simulate_fwd, HwModel, SimReport};
use crate::tensor::Tensor;

use super::planner::{AttentionPlan, ExecMode, JitBias};

/// Execute an [`AttentionPlan`] on `q: (N, C)`, `k`, `v: (M, C)`.
pub trait Executor {
    fn name(&self) -> &'static str;
    fn execute(&self, plan: &AttentionPlan, q: &Tensor, k: &Tensor,
               v: &Tensor) -> Result<Tensor>;
}

fn check_shapes(plan: &AttentionPlan, q: &Tensor, k: &Tensor,
                v: &Tensor) -> Result<()> {
    let g = &plan.geometry;
    if q.shape() != [g.n, g.c] {
        bail!("q shape {:?} != plan (N={}, C={})", q.shape(), g.n, g.c);
    }
    if k.shape() != [g.m, g.c] {
        bail!("k shape {:?} != plan (M={}, C={})", k.shape(), g.m, g.c);
    }
    if v.shape()[0] != g.m {
        bail!("v rows {} != plan M={}", v.shape()[0], g.m);
    }
    Ok(())
}

/// Convenience: execute on the host kernel-engine backend.
pub fn execute(plan: &AttentionPlan, q: &Tensor, k: &Tensor,
               v: &Tensor) -> Result<Tensor> {
    HostExecutor.execute(plan, q, k, v)
}

/// The engine-facing view of an additive plan's bias: maps each
/// [`ExecMode`] to the per-tile provider the kernel engine streams
/// from. Dense plans view their table, factored plans contract strips
/// tile-locally, JIT plans generate values from tile coordinates —
/// nothing is materialized.
pub fn plan_bias_tile(plan: &AttentionPlan) -> Box<dyn BiasTile + '_> {
    match &plan.mode {
        ExecMode::NoBias => Box::new(NoBias),
        ExecMode::Dense { bias } => Box::new(DenseTile::from_tensor(bias)),
        ExecMode::Factored { factors } => {
            Box::new(FactoredTile::from_factors(factors))
        }
        ExecMode::Jit { generator } => match *generator {
            JitBias::Alibi { slope } => Box::new(AlibiTile { slope }),
        },
    }
}

/// Multiplicative plans have no tiled schedule (Appendix I covers the
/// dense math only): execute them on the reference host math.
fn execute_multiplicative(plan: &AttentionPlan, q: &Tensor, k: &Tensor,
                          v: &Tensor) -> Result<Tensor> {
    match &plan.mode {
        ExecMode::Dense { bias } => {
            Ok(attention::attention_multiplicative(q, k, v, bias))
        }
        ExecMode::Factored { factors } => {
            // the reference math is dense f32; dequantize reduced-
            // precision strips up front (multiplicative plans have no
            // tile-local contraction to amortize the decode into)
            let phi_q = factors.phi_q.to_tensor();
            let phi_k = factors.phi_k.to_tensor();
            Ok(attention::attention_multiplicative_factored(
                q, k, v, &phi_q, &phi_k,
            ))
        }
        ExecMode::NoBias | ExecMode::Jit { .. } => bail!(
            "multiplicative plan without a dense/factored bias mode"
        ),
    }
}

// ---------------------------------------------------------------------------
// Host kernel-engine backend
// ---------------------------------------------------------------------------

/// Host backend over the tiled multi-threaded kernel engine.
#[derive(Clone, Copy, Debug, Default)]
pub struct HostExecutor;

impl Executor for HostExecutor {
    fn name(&self) -> &'static str {
        "host"
    }

    fn execute(&self, plan: &AttentionPlan, q: &Tensor, k: &Tensor,
               v: &Tensor) -> Result<Tensor> {
        check_shapes(plan, q, k, v)?;
        if plan.multiplicative {
            return execute_multiplicative(plan, q, k, v);
        }
        let tile = plan_bias_tile(plan);
        let cfg = KernelConfig::for_geometry_dtype(&plan.geometry,
                                                   plan.strip_dtype());
        Ok(kernels::attention_tiled(q, k, v, tile.as_ref(), plan.causal,
                                    &cfg))
    }
}

// ---------------------------------------------------------------------------
// Tiled-simulator backend
// ---------------------------------------------------------------------------

/// Tiled-execution backend: the same kernel engine, driven with block
/// sizes derived from the simulator's SRAM model, plus HBM accounting
/// through [`simulate_fwd`] — the numerics and the report describe the
/// same tile schedule.
#[derive(Debug)]
pub struct SimExecutor {
    pub hw: HwModel,
    last: Cell<Option<SimReport>>,
}

impl Default for SimExecutor {
    fn default() -> Self {
        Self::new(HwModel::default())
    }
}

impl SimExecutor {
    pub fn new(hw: HwModel) -> Self {
        Self {
            hw,
            last: Cell::new(None),
        }
    }

    /// The HBM/FLOP report of the most recent `execute` call.
    pub fn last_report(&self) -> Option<SimReport> {
        self.last.get()
    }
}

impl Executor for SimExecutor {
    fn name(&self) -> &'static str {
        "simulator"
    }

    fn execute(&self, plan: &AttentionPlan, q: &Tensor, k: &Tensor,
               v: &Tensor) -> Result<Tensor> {
        check_shapes(plan, q, k, v)?;
        if plan.multiplicative {
            // no tiled multiplicative schedule to mirror: fall back to
            // the reference and record no report rather than an
            // additive one that contradicts the plan's own cost model
            self.last.set(None);
            return execute_multiplicative(plan, q, k, v);
        }
        self.last.set(Some(simulate_fwd(
            plan.algorithm(),
            &plan.geometry,
            &self.hw,
        )));
        // drive the engine with the block sizes the simulator accounted
        // for (simulate_fwd sizes tiles from hw.sram_elems)
        let g = Geometry {
            sram: self.hw.sram_elems,
            ..plan.geometry
        };
        let cfg = KernelConfig::for_geometry(&g);
        let tile = plan_bias_tile(plan);
        Ok(kernels::attention_tiled(q, k, v, tile.as_ref(), plan.causal,
                                    &cfg))
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// Compiled-artifact backend: maps a plan's mode to an artifact variant
/// (`pure` / `dense` / `factored` / `jit`), routes through the shape
/// buckets, substitutes the plan's activations, and executes on PJRT.
pub struct PjrtExecutor {
    rt: Arc<Runtime>,
    router: Router,
    family: String,
}

impl PjrtExecutor {
    pub fn new(rt: Arc<Runtime>, family: &str) -> Self {
        let router = Router::from_runtime(&rt);
        Self {
            rt,
            router,
            family: family.to_string(),
        }
    }

    /// Artifact variant an exec mode maps to.
    pub fn variant(mode: &ExecMode) -> &'static str {
        match mode {
            ExecMode::NoBias => "pure",
            ExecMode::Dense { .. } => "dense",
            ExecMode::Factored { .. } => "factored",
            ExecMode::Jit { .. } => "jit",
        }
    }
}

impl Executor for PjrtExecutor {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn execute(&self, plan: &AttentionPlan, q: &Tensor, k: &Tensor,
               v: &Tensor) -> Result<Tensor> {
        check_shapes(plan, q, k, v)?;
        // The family encodes attention semantics the artifact was
        // compiled with; executing a plan with different semantics would
        // silently return wrong numbers. The micro-attention families
        // ("attn" = non-causal additive, "causal" = causal additive,
        // "mult" = multiplicative) are checked; model families are the
        // caller's contract.
        if plan.multiplicative != (self.family == "mult") {
            bail!(
                "{} plan routed to family {:?}; multiplicative plans \
                 require the \"mult\" family and vice versa",
                if plan.multiplicative { "multiplicative" } else
                { "additive" },
                self.family
            );
        }
        if matches!(self.family.as_str(), "attn" | "causal")
            && plan.causal != (self.family == "causal")
        {
            bail!(
                "{} plan routed to family {:?}; use {:?}",
                if plan.causal { "causal" } else { "non-causal" },
                self.family,
                if plan.causal { "causal" } else { "attn" }
            );
        }
        let variant = Self::variant(&plan.mode);
        let key = RouteKey::new(&self.family, variant);
        let n = plan.geometry.n;
        let (artifact, bucket) = self
            .router
            .route(&key, n)
            .ok_or_else(|| {
                anyhow!(
                    "no {}/{variant} artifact for N={n} (run `make \
                     artifacts`)",
                    self.family
                )
            })?;
        if bucket != n {
            bail!(
                "nearest {}/{variant} bucket is N={bucket}, plan wants \
                 N={n}; the PJRT backend requires an exact-shape artifact",
                self.family
            );
        }
        let artifact = artifact.to_string();
        let spec = self
            .rt
            .spec(&artifact)
            .ok_or_else(|| anyhow!("artifact {artifact} vanished"))?
            .clone();
        let mut inputs = self.rt.example_inputs(&artifact)?;
        // activation payloads in manifest order: q, k, v, then the
        // bias-carrying inputs of the variant
        let mut payloads = vec![q.clone(), k.clone(), v.clone()];
        match &plan.mode {
            ExecMode::Dense { bias } => payloads.push(bias.clone()),
            ExecMode::Factored { factors } => {
                // PJRT artifacts take dense f32 strip inputs
                payloads.push(factors.phi_q.to_tensor());
                payloads.push(factors.phi_k.to_tensor());
            }
            ExecMode::NoBias | ExecMode::Jit { .. } => {}
        }
        let acts = spec.activation_indices();
        if acts.len() != payloads.len() {
            bail!(
                "{artifact}: {} activation inputs, plan supplies {}",
                acts.len(),
                payloads.len()
            );
        }
        for (&slot, payload) in acts.iter().zip(payloads) {
            let want = &spec.inputs[slot].shape;
            if want.as_slice() != payload.shape() {
                bail!(
                    "{artifact} input {slot}: artifact shape {want:?} != \
                     plan payload {:?}",
                    payload.shape()
                );
            }
            inputs[slot] = HostValue::F32(payload);
        }
        let out = self.rt.load(&artifact)?.run(&inputs)?;
        out.first()
            .and_then(HostValue::as_f32)
            .cloned()
            .ok_or_else(|| anyhow!("{artifact}: no f32 output"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttnOpts;
    use crate::plan::{BiasSpec, PlanOptions, Planner};
    use crate::util::Xoshiro256;

    fn qkv(n: usize, m: usize, c: usize,
           seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = Xoshiro256::new(seed);
        (
            Tensor::randn(&[n, c], 1.0, &mut rng),
            Tensor::randn(&[m, c], 1.0, &mut rng),
            Tensor::randn(&[m, c], 1.0, &mut rng),
        )
    }

    fn geo(n: usize, m: usize, c: usize) -> Geometry {
        Geometry {
            n,
            m,
            c,
            r: 0,
            sram: 100 * 1024 / 2,
        }
    }

    #[test]
    fn host_factored_matches_dense_reference() {
        let (q, k, v) = qkv(24, 24, 8, 0);
        let spec = BiasSpec::alibi(24, 24, 0.25);
        let plan = Planner::default()
            .plan(&spec, &geo(24, 24, 8), &PlanOptions::default())
            .unwrap();
        let out = execute(&plan, &q, &k, &v).unwrap();
        let dense = attention::attention(
            &q,
            &k,
            &v,
            Some(&spec.materialize().unwrap()),
            &AttnOpts::default(),
        );
        assert!(out.allclose(&dense, 1e-4, 1e-4));
    }

    #[test]
    fn jit_equals_factored() {
        let (q, k, v) = qkv(16, 16, 4, 1);
        let planner = Planner::default();
        let spec = BiasSpec::alibi(16, 16, 0.5);
        let g = geo(16, 16, 4);
        let causal = PlanOptions {
            causal: true,
            ..PlanOptions::default()
        };
        let fact = planner.plan(&spec, &g, &causal).unwrap();
        let jit = planner
            .plan(
                &spec,
                &g,
                &PlanOptions {
                    prefer_jit: true,
                    ..causal
                },
            )
            .unwrap();
        let a = execute(&fact, &q, &k, &v).unwrap();
        let b = execute(&jit, &q, &k, &v).unwrap();
        assert!(a.allclose(&b, 1e-5, 1e-5));
    }

    #[test]
    fn simulator_matches_host_and_reports_io() {
        let (q, k, v) = qkv(32, 48, 8, 2);
        let spec = BiasSpec::alibi(32, 48, 0.125);
        let plan = Planner::default()
            .plan(&spec, &geo(32, 48, 8), &PlanOptions::default())
            .unwrap();
        let sim = SimExecutor::default();
        let out = sim.execute(&plan, &q, &k, &v).unwrap();
        let host = HostExecutor.execute(&plan, &q, &k, &v).unwrap();
        assert!(out.allclose(&host, 1e-4, 1e-4));
        let rep = sim.last_report().expect("report recorded");
        assert!(rep.hbm_total() > 0);
    }

    #[test]
    fn simulator_causal_matches_host() {
        let (q, k, v) = qkv(20, 20, 8, 3);
        let plan = Planner::default()
            .plan(
                &BiasSpec::alibi(20, 20, 0.25),
                &geo(20, 20, 8),
                &PlanOptions {
                    causal: true,
                    ..PlanOptions::default()
                },
            )
            .unwrap();
        let sim = SimExecutor::default();
        let out = sim.execute(&plan, &q, &k, &v).unwrap();
        let host = HostExecutor.execute(&plan, &q, &k, &v).unwrap();
        assert!(out.allclose(&host, 1e-4, 1e-4));
    }

    #[test]
    fn multiplicative_plan_executes() {
        let (q, k, v) = qkv(12, 12, 4, 4);
        let spec = BiasSpec::cos_multiplicative(12, 12);
        let plan = Planner::default()
            .plan(&spec, &geo(12, 12, 4), &PlanOptions::default())
            .unwrap();
        let out = execute(&plan, &q, &k, &v).unwrap();
        let dense = attention::attention_multiplicative(
            &q,
            &k,
            &v,
            &spec.materialize().unwrap(),
        );
        assert!(out.allclose(&dense, 1e-4, 1e-4));
    }

    #[test]
    fn shape_mismatch_errors() {
        let (q, k, v) = qkv(8, 8, 4, 5);
        let plan = Planner::default()
            .plan(&BiasSpec::alibi(16, 16, 0.5), &geo(16, 16, 4),
                  &PlanOptions::default())
            .unwrap();
        assert!(execute(&plan, &q, &k, &v).is_err());
    }
}
