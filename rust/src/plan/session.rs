//! Session state for the prefill/decode split.
//!
//! A [`SessionState`] is one live decode stream: the plan it was opened
//! against, the append-only [`KvCache`] of every position seen so far,
//! and the last step's streaming-softmax carry. The lifecycle is
//!
//! 1. `prefill(q, k, v)` — exactly once, on a fresh session: seeds the
//!    cache with the prompt's K/V rows and runs the ordinary one-shot
//!    tiled pass over them (a one-shot request *is* "prefill with N > 1
//!    and no session" — same engine code).
//! 2. `step(q_row, k_row, v_row)` — once per generated position:
//!    appends the new K/V row, then attends the single query row
//!    against the whole cache via
//!    [`crate::kernels::run_decode_step`]. Each step is exact (the
//!    online `(m, l)` recurrence runs to completion over the 1×M strip
//!    before normalizing), so a step at position `t` reproduces row `t`
//!    of a full prefill recompute over `[0..t]`.
//!
//! The bias side costs O(rank·M) per step for factored plans (one φ_q
//! row contracted against φ_k) and O(M) for dense plans (a table row
//! that never amortizes) — the [`AttentionPlan::predicted_step_io`] /
//! [`AttentionPlan::dense_step_io`] entries of the cost model.
//!
//! `SessionState` is deliberately lock-free: the coordinator wraps it
//! in a named `util::sync` lock and serializes appends; workers read
//! immutable row snapshots (see `coordinator::session`).

use std::sync::Arc;

use crate::kernels::{self, DecodeCarry, KernelConfig};
use crate::tensor::{KvCache, Tensor};

use super::exec::plan_bias_tile;
use super::AttentionPlan;

/// Typed session state-machine failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionError {
    /// The plan cannot drive the decode path (multiplicative bias).
    DecodeUnsupported { mode: String },
    /// Prefill on a session that already holds positions.
    NotFresh { pos: usize },
    /// The plan's bias providers only cover `n`/`m` positions.
    ContextExhausted { pos: usize, limit: usize },
    /// A row or tensor had the wrong width/shape.
    ShapeMismatch {
        what: &'static str,
        got: usize,
        want: usize,
    },
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::DecodeUnsupported { mode } => {
                write!(f, "plan mode `{mode}` cannot drive decode \
                           (no additive 1×M strip form)")
            }
            SessionError::NotFresh { pos } => {
                write!(f, "prefill on a session already at position {pos}")
            }
            SessionError::ContextExhausted { pos, limit } => {
                write!(f, "position {pos} exceeds the plan's bias \
                           coverage ({limit})")
            }
            SessionError::ShapeMismatch { what, got, want } => {
                write!(f, "{what}: got {got}, want {want}")
            }
        }
    }
}

impl std::error::Error for SessionError {}

/// Snapshot handed to whoever executes a step that was admitted by
/// [`SessionState::begin_step`]: the step's absolute position `i` and
/// the cache length `m` it may read (rows `[0, m)` are immutable).
#[derive(Clone, Copy, Debug)]
pub struct StepTicket {
    pub i: usize,
    pub m: usize,
}

/// One live decode stream: plan handle, KV cache, softmax carry.
#[derive(Debug, Clone)]
pub struct SessionState {
    plan: Arc<AttentionPlan>,
    cache: KvCache,
    cfg: KernelConfig,
    scale: f32,
    /// Next query position (== number of query rows seen).
    pos: usize,
    /// Carry of the newest recorded step (diagnostic; `l == 0` means
    /// that step was fully masked).
    carry: DecodeCarry,
    /// Number of steps whose carry has been recorded — write-backs from
    /// out-of-order batch execution only advance, never regress, so the
    /// stored carry is deterministic across flush orderings.
    carry_steps: usize,
}

impl SessionState {
    /// Open session state against a plan. Fails for plans without an
    /// additive strip form (multiplicative bias).
    pub fn new(plan: Arc<AttentionPlan>) -> Result<Self, SessionError> {
        if !plan.decode_capable {
            return Err(SessionError::DecodeUnsupported {
                mode: plan.mode_name().to_string(),
            });
        }
        let g = plan.geometry;
        let cfg =
            KernelConfig::for_geometry_dtype(&g, plan.strip_dtype());
        let scale = 1.0 / (g.c as f32).sqrt();
        Ok(Self {
            plan,
            cache: KvCache::new(g.c, g.c),
            cfg,
            scale,
            pos: 0,
            carry: DecodeCarry::fresh(),
            carry_steps: 0,
        })
    }

    pub fn plan(&self) -> &Arc<AttentionPlan> {
        &self.plan
    }

    pub fn cache(&self) -> &KvCache {
        &self.cache
    }

    pub fn kernel_config(&self) -> &KernelConfig {
        &self.cfg
    }

    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Next query position (number of query rows seen so far).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Newest recorded streaming-softmax carry.
    pub fn carry(&self) -> DecodeCarry {
        self.carry
    }

    /// Number of steps whose carry has been recorded.
    pub fn carry_steps(&self) -> usize {
        self.carry_steps
    }

    /// Query positions left before the plan's bias coverage runs out.
    pub fn remaining(&self) -> usize {
        self.plan.geometry.n.saturating_sub(self.pos)
    }

    fn check_width(what: &'static str, got: usize,
                   want: usize) -> Result<(), SessionError> {
        if got != want {
            return Err(SessionError::ShapeMismatch { what, got, want });
        }
        Ok(())
    }

    /// Admit a prefill: validates shapes against the plan, appends the
    /// prompt's `k`/`v` rows to the cache, and advances `pos` — without
    /// running the attention pass. Split out from [`Self::prefill`] so
    /// the coordinator can append-at-submit and run the compute as part
    /// of a later mixed batch (continuous batching), with identical
    /// state transitions.
    pub fn begin_prefill(&mut self, q: &Tensor, k: &Tensor,
                         v: &Tensor) -> Result<(), SessionError> {
        if self.pos != 0 || !self.cache.is_empty() {
            return Err(SessionError::NotFresh { pos: self.pos });
        }
        let g = self.plan.geometry;
        Self::check_width("q rank", q.rank(), 2)?;
        Self::check_width("k rank", k.rank(), 2)?;
        Self::check_width("v rank", v.rank(), 2)?;
        Self::check_width("q cols", q.shape()[1], g.c)?;
        Self::check_width("k cols", k.shape()[1], g.c)?;
        Self::check_width("v cols", v.shape()[1], self.cache.cv())?;
        Self::check_width("v rows", v.shape()[0], k.shape()[0])?;
        let n_p = q.shape()[0];
        let m_p = k.shape()[0];
        if n_p == 0 || n_p > g.n {
            return Err(SessionError::ContextExhausted {
                pos: n_p,
                limit: g.n,
            });
        }
        if m_p > g.m {
            return Err(SessionError::ContextExhausted {
                pos: m_p,
                limit: g.m,
            });
        }
        self.cache.append_rows(k.view2(), v.view2());
        self.pos = n_p;
        Ok(())
    }

    /// Seed a fresh session with the prompt: appends `k`/`v` rows to
    /// the cache and runs the one-shot tiled pass over them. `q` is
    /// `(n_p, C)`; `k`/`v` are `(m_p, C)` with `m_p ≥ n_p` allowed
    /// (ragged cross-attention prefix). Returns the `(n_p, C)` output.
    pub fn prefill(&mut self, q: &Tensor, k: &Tensor,
                   v: &Tensor) -> Result<Tensor, SessionError> {
        self.begin_prefill(q, k, v)?;
        // fresh session ⇒ the cache holds exactly k/v: the one-shot
        // engine path serves the prefill unchanged
        let tile = plan_bias_tile(&self.plan);
        Ok(kernels::attention_tiled(q, k, v, tile.as_ref(),
                                    self.plan.causal, &self.cfg))
    }

    /// Admit one decode step: validates coverage, appends the new K/V
    /// row, advances `pos`, and returns the `(i, m)` snapshot the
    /// executor must use. Split out from [`Self::step`] so the
    /// coordinator can append-at-submit and run the compute later
    /// (continuous batching) while keeping the same state transitions.
    pub fn begin_step(&mut self, k_row: &[f32],
                      v_row: &[f32]) -> Result<StepTicket, SessionError> {
        let g = self.plan.geometry;
        if self.pos >= g.n {
            return Err(SessionError::ContextExhausted {
                pos: self.pos,
                limit: g.n,
            });
        }
        if self.cache.len() >= g.m {
            return Err(SessionError::ContextExhausted {
                pos: self.cache.len(),
                limit: g.m,
            });
        }
        Self::check_width("k row", k_row.len(), self.cache.c())?;
        Self::check_width("v row", v_row.len(), self.cache.cv())?;
        let i = self.pos;
        self.cache.append(k_row, v_row);
        self.pos += 1;
        Ok(StepTicket {
            i,
            m: self.cache.len(),
        })
    }

    /// One inline decode step (no coordinator): append, attend the new
    /// query row against the whole cache, record the carry, and return
    /// the output row. Exact — see the module docs.
    pub fn step(&mut self, q_row: &[f32], k_row: &[f32],
                v_row: &[f32]) -> Result<Vec<f32>, SessionError> {
        Self::check_width("q row", q_row.len(), self.cache.c())?;
        let ticket = self.begin_step(k_row, v_row)?;
        let mut out = vec![0.0f32; self.cache.cv()];
        let tile = plan_bias_tile(&self.plan);
        // n = i + 1: the new position sees the whole cache, ragged
        // prefixes included
        let carry = kernels::run_decode_step(
            q_row,
            self.cache.k_view(),
            self.cache.v_view(),
            tile.as_ref(),
            ticket.i,
            ticket.i + 1,
            self.plan.causal,
            self.scale,
            &self.cfg,
            &mut out,
        );
        drop(tile);
        self.record_carry(carry, ticket.i + 1);
        Ok(out)
    }

    /// Record a step's carry. `steps_done` is the step count the carry
    /// belongs to (`ticket.i + 1`); stale write-backs from out-of-order
    /// batch execution are ignored so the stored carry is the newest
    /// step's regardless of flush ordering.
    pub fn record_carry(&mut self, carry: DecodeCarry,
                        steps_done: usize) {
        if steps_done > self.carry_steps {
            self.carry = carry;
            self.carry_steps = steps_done;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iomodel::Geometry;
    use crate::plan::{BiasSpec, PlanOptions, Planner};
    use crate::util::Xoshiro256;

    fn alibi_plan(n: usize, causal: bool) -> Arc<AttentionPlan> {
        let opts = PlanOptions {
            causal,
            ..PlanOptions::default()
        };
        Arc::new(
            Planner::default()
                .plan(&BiasSpec::alibi(n, n, 0.25),
                      &Geometry::square(n, 8, 0, 100 * 1024 / 2), &opts)
                .unwrap(),
        )
    }

    #[test]
    fn lifecycle_prefill_then_steps_matches_recompute() {
        let n = 24;
        let plan = alibi_plan(n, true);
        let mut sess = SessionState::new(Arc::clone(&plan)).unwrap();
        let mut rng = Xoshiro256::new(40);
        let q = Tensor::randn(&[n, 8], 1.0, &mut rng);
        let k = Tensor::randn(&[n, 8], 1.0, &mut rng);
        let v = Tensor::randn(&[n, 8], 1.0, &mut rng);
        let n0 = 10;
        let pre = sess
            .prefill(&q.slice_rows(0, n0), &k.slice_rows(0, n0),
                     &v.slice_rows(0, n0))
            .unwrap();
        assert_eq!(pre.shape(), &[n0, 8]);
        assert_eq!(sess.pos(), n0);
        for t in n0..n {
            let out = sess
                .step(q.view2().row(t), k.view2().row(t),
                      v.view2().row(t))
                .unwrap();
            // reference: full recompute over [0..t]
            let full = crate::plan::execute(
                &plan_at(&plan, t + 1),
                &q.slice_rows(0, t + 1),
                &k.slice_rows(0, t + 1),
                &v.slice_rows(0, t + 1),
            )
            .unwrap();
            let want = full.view2().row(t);
            for (a, b) in out.iter().zip(want) {
                assert!((a - b).abs() < 1e-5, "t={t}: {a} vs {b}");
            }
            assert_eq!(sess.carry_steps(), t + 1);
        }
    }

    /// Re-plan the same bias at a truncated length for the reference
    /// recompute (executors check exact shapes).
    fn plan_at(plan: &AttentionPlan, n: usize) -> AttentionPlan {
        let opts = PlanOptions {
            causal: plan.causal,
            ..PlanOptions::default()
        };
        Planner::default()
            .plan(&BiasSpec::alibi(n, n, 0.25),
                  &Geometry::square(n, 8, 0, 100 * 1024 / 2), &opts)
            .unwrap()
    }

    #[test]
    fn prefill_twice_rejected() {
        let plan = alibi_plan(8, false);
        let mut sess = SessionState::new(plan).unwrap();
        let mut rng = Xoshiro256::new(41);
        let t = Tensor::randn(&[4, 8], 1.0, &mut rng);
        sess.prefill(&t, &t, &t).unwrap();
        assert!(matches!(sess.prefill(&t, &t, &t),
                         Err(SessionError::NotFresh { pos: 4 })));
    }

    #[test]
    fn context_exhaustion_is_typed() {
        let plan = alibi_plan(4, false);
        let mut sess = SessionState::new(plan).unwrap();
        let row = [0.0f32; 8];
        for _ in 0..4 {
            sess.step(&row, &row, &row).unwrap();
        }
        assert!(matches!(
            sess.step(&row, &row, &row),
            Err(SessionError::ContextExhausted { pos: 4, limit: 4 })
        ));
    }

    #[test]
    fn multiplicative_plan_rejected() {
        let plan = Arc::new(
            Planner::default()
                .plan(&BiasSpec::cos_multiplicative(16, 16),
                      &Geometry::square(16, 8, 0, 100 * 1024 / 2),
                      &PlanOptions::default())
                .unwrap(),
        );
        assert!(matches!(SessionState::new(plan),
                         Err(SessionError::DecodeUnsupported { .. })));
    }

    #[test]
    fn stale_carry_writeback_ignored() {
        let plan = alibi_plan(8, false);
        let mut sess = SessionState::new(plan).unwrap();
        let row = [1.0f32; 8];
        sess.step(&row, &row, &row).unwrap();
        sess.step(&row, &row, &row).unwrap();
        let newest = sess.carry();
        assert_eq!(sess.carry_steps(), 2);
        sess.record_carry(DecodeCarry { m: 123.0, l: 9.0 }, 1);
        assert_eq!(sess.carry(), newest);
        assert_eq!(sess.carry_steps(), 2);
    }

    #[test]
    fn dense_bias_session_uses_table_rows() {
        // full-rank random table forces the dense fallback; session
        // decode must match the dense one-shot at the final position
        let n = 12;
        let bias = Tensor::randn(&[n, n], 1.0, &mut Xoshiro256::new(42));
        let plan = Arc::new(
            Planner::default()
                .plan(&BiasSpec::dense(bias),
                      &Geometry::square(n, 8, 0, 100 * 1024 / 2),
                      &PlanOptions::default())
                .unwrap(),
        );
        let mut sess = SessionState::new(Arc::clone(&plan)).unwrap();
        let mut rng = Xoshiro256::new(43);
        let q = Tensor::randn(&[n, 8], 1.0, &mut rng);
        let k = Tensor::randn(&[n, 8], 1.0, &mut rng);
        let v = Tensor::randn(&[n, 8], 1.0, &mut rng);
        let full = crate::plan::execute(&plan, &q, &k, &v).unwrap();
        for t in 0..n {
            let out = sess
                .step(q.view2().row(t), k.view2().row(t),
                      v.view2().row(t))
                .unwrap();
            // causal=false one-shot row t attends all n keys; the
            // session at step t has only t+1 — compare against the
            // causal-aligned prefix recompute instead for t < n−1
            if t == n - 1 {
                let want = full.view2().row(t);
                for (a, b) in out.iter().zip(want) {
                    assert!((a - b).abs() < 1e-5, "t={t}: {a} vs {b}");
                }
            }
        }
    }
}
