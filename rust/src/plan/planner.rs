//! [`Planner`] — the Table 1 decision procedure plus the Thm 3.1/Cor 3.7
//! cost model, emitting an executable [`AttentionPlan`].
//!
//! The planner is the single place that decides *how* a bias is carried
//! through attention:
//!
//! * closed form               → Exact factors (ALiBi, spatial, cos) —
//!   optionally generated in-kernel ([`ExecMode::Jit`], Table 8);
//! * static learned, low-rank  → truncated SVD at the energy target
//!   (Swin §4.3, Pangu Appendix B);
//! * dynamic / data-dependent  → neural factor functions fitted on the
//!   token sources (AlphaFold pair bias, Eq. 5);
//! * rank test fails           → dense fallback (Appendix J limitation).
//!
//! On top of the class split, every factored candidate is checked against
//! the analytic IO model: if `Θ(NM(C²+R²)/S)` does not beat the dense
//! stream `Θ(NMC²/S + NM)` (Remark 3.8), or a multiplicative rank exceeds
//! the Corollary I.2 threshold, the planner keeps the dense matrix. The
//! emitted plan records the decision, the effective geometry, predicted
//! HBM traffic for plan-vs-dense, and the factor storage bill (Thm 3.2).
//!
//! Decomposition work (SVD of a static table, a neural fit on token
//! sources — the expensive Table 1b/1c rows) can be amortized through a
//! [`FactorStore`]: [`Planner::plan_with_store`] keys the outcome by the
//! spec's content fingerprint plus the decomposition policy, so a
//! repeated plan for the same bias is a cache hit that shares the stored
//! strips (`Arc`-shared, zero copies) and performs **no** SVD/neural
//! work — the paper's "decompose offline once" cost model (Table 4).
//! The store itself is tiered (resident → spill file → remote peer →
//! decompose), so a planner behind a byte-budgeted or fleet-shared
//! store still never repeats a decomposition it can reload from disk
//! or fetch from a peer's [`crate::factorstore::FactorService`]; the
//! decomposition closure the planner hands over runs only when every
//! tier misses.

use std::sync::Arc;

use crate::bias::ExactBias;
use crate::decompose::{
    decompose, quantize_factors, uses_randomized_svd, DecomposeError,
    Factors, NeuralConfig, NeuralDecomposition, RankSelect, Strategy,
};
use crate::factorstore::{Cached, FactorStore, Fingerprint, Fnv64};
use crate::iomodel::{self, Geometry};
use crate::linalg;
use crate::simulator::Algorithm;
use crate::tensor::{StripDType, Tensor};
use crate::util::Xoshiro256;

use super::spec::BiasSpec;

/// End-to-end relative bias error the default f32 strips keep (the
/// repo-wide "factored ≈ dense" property tolerance).
pub const F32_STRIP_TOL: f32 = 1e-5;

/// Documented end-to-end relative bias error budget for bf16 strips:
/// truncation error + the measured quantization bound must stay below
/// this for [`StripPolicy::Auto`] to engage reduced precision. bf16's
/// half-ulp is 2⁻⁹ ≈ 2e-3, so the triangle-inequality bound of
/// [`quantize_factors`] lands well inside 1e-2 for well-conditioned
/// strips and the gate rejects the rest.
pub const BF16_STRIP_TOL: f32 = 1e-2;

/// How SVD/neural factor strips are stored (dtype policy).
///
/// Quantization is gated by the *measured* Eckart–Young-style bound of
/// [`quantize_factors`] — reduced precision only engages when the
/// singular-value truncation error plus the quantization bound stays
/// within the advertised tolerance. Exact closed-form factors (ALiBi,
/// spatial, cos) are never quantized: they are O((N+M)·R) to
/// regenerate and exactness is their contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StripPolicy {
    /// Always keep f32 strips — the exact legacy behavior
    /// ([`F32_STRIP_TOL`] end to end). The default.
    F32Only,
    /// Quantize measured/neural strips to bf16 when the spectrum says
    /// total error stays within [`BF16_STRIP_TOL`]; keep f32 otherwise.
    /// Halves store/spill/remote bytes where it engages.
    Auto,
    /// Pin a dtype regardless of the spectrum (f16 and the experimental
    /// i8 are only reachable this way). Non-finite quantizations still
    /// fall back to f32.
    Force(StripDType),
}

/// Policy knobs for the Table 1 decision procedure.
#[derive(Clone, Copy, Debug)]
pub struct SelectorConfig {
    /// Energy target for SVD truncation (paper: 0.99–0.995).
    pub energy_target: f64,
    /// A static bias is "low-rank enough" if rank_at_energy ≤
    /// `max_rank_fraction` · min(N, M) (the paper applies FlashBias only
    /// to the low-rank layers of SwinV2, §4.3 / Figure 8).
    pub max_rank_fraction: f64,
    /// Neural decomposition defaults for dynamic biases.
    pub neural: crate::decompose::NeuralConfig,
    /// Storage dtype policy for SVD/neural factor strips.
    pub strip_policy: StripPolicy,
}

impl Default for SelectorConfig {
    fn default() -> Self {
        Self {
            energy_target: 0.99,
            max_rank_fraction: 0.35,
            neural: crate::decompose::NeuralConfig::default(),
            strip_policy: StripPolicy::F32Only,
        }
    }
}

/// Per-plan options (orthogonal to the policy in [`SelectorConfig`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanOptions {
    /// Apply the decoder-aligned causal mask.
    pub causal: bool,
    /// For biases whose factor strips are cheap closed forms of the block
    /// coordinates (ALiBi), generate them in-kernel instead of streaming
    /// them from HBM (Table 8 / Appendix C).
    pub prefer_jit: bool,
    /// Force the SVD/neural rank instead of measuring it at the energy
    /// target (the paper pins R = 56 for Pangu, R = 16 for Swin). An
    /// override also bypasses the `max_rank_fraction` test.
    pub rank_override: Option<usize>,
    /// Verify exact factorizations against the materialized dense matrix
    /// (O(NM); off by default so exact plans stay O((N+M)·R)).
    pub verify_exact: bool,
}

/// Which Table 1 row fired, with the evidence.
#[derive(Clone, Debug)]
pub enum Decision {
    /// No bias declared.
    NoBias,
    /// Closed-form factorization (Table 1a).
    Exact { rank: usize },
    /// Truncated SVD of a static learned table (Table 1b).
    Svd { rank: usize, rel_err: f32 },
    /// Neural factor functions fitted on token sources (Table 1c).
    Neural { rank: usize, rel_err: f32 },
    /// Rank or cost test failed — keep the dense matrix (Appendix J).
    DenseFallback { measured_rank: usize, reason: String },
}

/// How the executor carries the bias.
#[derive(Clone, Debug)]
pub enum ExecMode {
    /// Pure FlashAttention.
    NoBias,
    /// Stream the dense `(N, M)` matrix.
    Dense { bias: Tensor },
    /// Stream factor strips and fold them into the dot product (Eq. 3).
    /// The strips sit behind an `Arc` so plans cloned across the serving
    /// stack — and plans minted from a warm [`FactorStore`] — share one
    /// copy of the factor data.
    Factored { factors: Arc<Factors> },
    /// Generate the factor strips in-kernel from block coordinates —
    /// zero bias IO (Table 8).
    Jit { generator: JitBias },
}

/// Closed forms cheap enough to generate inside the kernel.
#[derive(Clone, Copy, Debug)]
pub enum JitBias {
    Alibi { slope: f32 },
}

impl JitBias {
    pub fn rank(&self) -> usize {
        match self {
            JitBias::Alibi { .. } => 2,
        }
    }

    /// Materialize the strips (what the kernel would compute from its
    /// block coordinates).
    pub fn factors(&self, n: usize, m: usize) -> (Tensor, Tensor) {
        match *self {
            JitBias::Alibi { slope } => {
                crate::bias::Alibi::new(n, m, slope).factors()
            }
        }
    }
}

/// An executable plan: everything an [`super::Executor`] backend needs,
/// plus the predicted costs that justified the decision.
#[derive(Clone, Debug)]
pub struct AttentionPlan {
    pub mode: ExecMode,
    /// Problem geometry with `r` set to the plan's effective rank.
    pub geometry: Geometry,
    pub causal: bool,
    /// Hadamard-combined bias (Appendix I) instead of additive.
    pub multiplicative: bool,
    pub decision: Decision,
    /// Predicted HBM accesses (elements) of this plan.
    pub predicted_io: f64,
    /// Predicted HBM accesses of the dense-bias baseline.
    pub dense_io: f64,
    /// Bias-carrying HBM residency in bytes (factor strips, dense table,
    /// or zero for JIT/no-bias) — the Thm 3.2 storage column.
    pub bias_storage_bytes: usize,
    /// Whether this plan can drive the incremental-decode path
    /// (session KV cache + 1×M bias strips). False only for
    /// multiplicative plans, whose Hadamard combine has no additive
    /// strip form.
    pub decode_capable: bool,
    /// Predicted HBM accesses (elements) of *one* decode step under
    /// this plan's mode: O(rank·M) factored strip vs O(M) dense row —
    /// the per-step entry of the cost model.
    pub predicted_step_io: f64,
    /// Per-step cost of the dense-row baseline, for comparison.
    pub dense_step_io: f64,
}

impl AttentionPlan {
    /// Effective bias rank (0 for dense / no-bias plans).
    pub fn rank(&self) -> usize {
        self.geometry.r
    }

    /// The spectral-rank evidence behind the decision: the planned rank
    /// for exact/SVD/neural plans, the measured rank for dense
    /// fallbacks, 0 for no-bias. Unlike [`Self::rank`], this survives a
    /// fallback — it is what rank profiles (Figure 8) report.
    pub fn measured_rank(&self) -> usize {
        match &self.decision {
            Decision::NoBias => 0,
            Decision::Exact { rank }
            | Decision::Svd { rank, .. }
            | Decision::Neural { rank, .. } => *rank,
            Decision::DenseFallback { measured_rank, .. } => *measured_rank,
        }
    }

    /// Predicted IO saving over the dense-bias baseline.
    pub fn io_saving(&self) -> f64 {
        self.dense_io / self.predicted_io.max(1e-12)
    }

    /// Predicted per-decode-step IO saving over the dense-row baseline.
    pub fn step_io_saving(&self) -> f64 {
        self.dense_step_io / self.predicted_step_io.max(1e-12)
    }

    /// The tiled-simulator algorithm this plan maps to.
    pub fn algorithm(&self) -> Algorithm {
        match &self.mode {
            ExecMode::NoBias => Algorithm::Flash,
            ExecMode::Dense { .. } => Algorithm::FlashDenseBias,
            ExecMode::Factored { factors } => {
                Algorithm::FlashBias(factors.rank)
            }
            ExecMode::Jit { generator } => {
                Algorithm::FlashBias(generator.rank())
            }
        }
    }

    /// Short human label of the execution mode.
    pub fn mode_name(&self) -> &'static str {
        match &self.mode {
            ExecMode::NoBias => "no-bias",
            ExecMode::Dense { .. } => "dense",
            ExecMode::Factored { .. } => "factored",
            ExecMode::Jit { .. } => "jit",
        }
    }

    /// Reconstruct the dense bias this plan represents (`None` for
    /// no-bias plans). Test/inspection path — O(NM).
    pub fn materialized_bias(&self) -> Option<Tensor> {
        match &self.mode {
            ExecMode::NoBias => None,
            ExecMode::Dense { bias } => Some(bias.clone()),
            ExecMode::Factored { factors } => Some(factors.reconstruct()),
            ExecMode::Jit { generator } => {
                let (pq, pk) =
                    generator.factors(self.geometry.n, self.geometry.m);
                Some(pq.matmul_t(&pk))
            }
        }
    }

    /// Stored dtype of the plan's factor strips (f32 for every other
    /// mode) — what [`crate::kernels::KernelConfig::for_geometry_dtype`]
    /// fits tiles against.
    pub fn strip_dtype(&self) -> StripDType {
        match &self.mode {
            ExecMode::Factored { factors } => factors.dtype(),
            _ => StripDType::F32,
        }
    }

    /// One-line report for CLIs and benches.
    pub fn summary(&self) -> String {
        format!(
            "mode={} rank={} io={:.3e} ({}x vs dense) step-io={:.3e} \
             ({}x vs dense row) bias-bytes={} {:?}",
            self.mode_name(),
            self.rank(),
            self.predicted_io,
            (self.io_saving() * 10.0).round() / 10.0,
            self.predicted_step_io,
            (self.step_io_saving() * 10.0).round() / 10.0,
            self.bias_storage_bytes,
            self.decision
        )
    }
}

/// Planning failure.
#[derive(Debug)]
pub enum PlanError {
    /// Bias shape disagrees with the declared geometry.
    ShapeMismatch {
        spec: (usize, usize),
        geometry: (usize, usize),
    },
    /// No reference semantics for causal multiplicative bias.
    CausalMultiplicative,
    /// Decomposition-layer failure.
    Decompose(DecomposeError),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::ShapeMismatch { spec, geometry } => write!(
                f,
                "bias shape {spec:?} does not match geometry {geometry:?}"
            ),
            PlanError::CausalMultiplicative => write!(
                f,
                "causal masking of a multiplicative bias is undefined \
                 (Appendix I covers the non-causal case)"
            ),
            PlanError::Decompose(e) => write!(f, "decompose: {e}"),
        }
    }
}

impl std::error::Error for PlanError {}

impl From<DecomposeError> for PlanError {
    fn from(e: DecomposeError) -> Self {
        PlanError::Decompose(e)
    }
}

/// The planner: [`SelectorConfig`] policy + Table 1 procedure + IO model.
#[derive(Clone, Debug, Default)]
pub struct Planner {
    pub config: SelectorConfig,
}

impl Planner {
    pub fn new(config: SelectorConfig) -> Self {
        Self { config }
    }

    /// Run the decision procedure for one bias and emit the plan.
    ///
    /// `geo.r` is ignored on input; the planner sets it to the effective
    /// rank of whatever mode it picks.
    pub fn plan(&self, spec: &BiasSpec, geo: &Geometry,
                opts: &PlanOptions) -> Result<AttentionPlan, PlanError> {
        self.plan_impl(spec, geo, opts, None)
    }

    /// [`Self::plan`], with SVD/neural decomposition amortized through a
    /// [`FactorStore`]. Repeated plans for the same
    /// [`BiasSpec::StaticLearned`] / [`BiasSpec::Dynamic`] /
    /// [`BiasSpec::Dense`] content under the same policy are store hits:
    /// they share the cached strips (`Arc`-pointer-equal across plans)
    /// and perform no SVD, spectrum scan, or neural fit. Closed-form
    /// biases are never stored — their factors cost O((N+M)·R) to
    /// regenerate, cheaper than a lookup of the same size.
    pub fn plan_with_store(&self, spec: &BiasSpec, geo: &Geometry,
                           opts: &PlanOptions, store: &FactorStore)
                           -> Result<AttentionPlan, PlanError> {
        self.plan_impl(spec, geo, opts, Some(store))
    }

    fn plan_impl(&self, spec: &BiasSpec, geo: &Geometry,
                 opts: &PlanOptions, store: Option<&FactorStore>)
                 -> Result<AttentionPlan, PlanError> {
        // the expects below sit in match arms for closed-form biases
        // (Alibi/Spatial/CosMultiplicative), which materialize by
        // construction
        if let Some((n, m)) = spec.shape() {
            if (n, m) != (geo.n, geo.m) {
                return Err(PlanError::ShapeMismatch {
                    spec: (n, m),
                    geometry: (geo.n, geo.m),
                });
            }
        }
        let multiplicative = spec.is_multiplicative();
        if multiplicative && opts.causal {
            return Err(PlanError::CausalMultiplicative);
        }

        match spec {
            BiasSpec::None => {
                let geometry = Geometry { r: 0, ..*geo };
                let io = iomodel::flash_attention_io(&geometry);
                let step_io = iomodel::decode_step_io(&geometry);
                Ok(AttentionPlan {
                    mode: ExecMode::NoBias,
                    geometry,
                    causal: opts.causal,
                    multiplicative: false,
                    decision: Decision::NoBias,
                    predicted_io: io,
                    dense_io: io,
                    bias_storage_bytes: 0,
                    decode_capable: true,
                    predicted_step_io: step_io,
                    dense_step_io: step_io,
                })
            }
            BiasSpec::Alibi { slope, .. } if opts.prefer_jit => {
                let generator = JitBias::Alibi { slope: *slope };
                let rank = generator.rank();
                self.emit(
                    ExecMode::Jit { generator },
                    Decision::Exact { rank },
                    spec,
                    geo,
                    opts,
                    rank,
                )
            }
            BiasSpec::Alibi { .. }
            | BiasSpec::Spatial(_)
            | BiasSpec::CosMultiplicative { .. } => {
                let rank = spec.exact_rank().expect("closed form has rank");
                let (phi_q, phi_k) =
                    spec.exact_factors().expect("closed form has factors");
                let rel_err = if opts.verify_exact {
                    linalg::reconstruction_error(
                        &spec.materialize().expect("dense"),
                        &phi_q,
                        &phi_k,
                    )
                } else {
                    0.0
                };
                // exact closed forms stay f32 — never quantized
                let factors = Arc::new(Factors::from_tensors(
                    phi_q, phi_k, rel_err, rank,
                ));
                self.emit(
                    ExecMode::Factored { factors },
                    Decision::Exact { rank },
                    spec,
                    geo,
                    opts,
                    rank,
                )
            }
            BiasSpec::StaticLearned { table }
            | BiasSpec::Dense { table } => {
                self.plan_measured(spec, table, geo, opts, store)
            }
            BiasSpec::Dynamic {
                sources_q,
                sources_k,
                bias,
            } => {
                let mut cfg = self.config.neural;
                if let Some(r) = opts.rank_override {
                    cfg.rank = r;
                }
                let fit = || {
                    let mut rng = Xoshiro256::new(cfg.seed);
                    let nd = NeuralDecomposition::fit(
                        sources_q, sources_k, bias, &cfg, &mut rng,
                    );
                    let phi_q = nd.phi_q(sources_q);
                    let phi_k = nd.phi_k(sources_k);
                    let rel_err =
                        linalg::reconstruction_error(bias, &phi_q, &phi_k);
                    self.apply_strip_policy(Arc::new(
                        Factors::from_tensors(phi_q, phi_k, rel_err,
                                              cfg.rank),
                    ))
                };
                let factors = match store {
                    Some(s) => {
                        let key = neural_key(
                            spec, &cfg, self.config.strip_policy,
                        );
                        let cached = s.get_or_insert_with(key, || {
                            Cached::Factors(fit())
                        });
                        match cached.factors() {
                            Some(f) => f.clone(),
                            // a neural key never stores a rejection;
                            // refit defensively rather than panic
                            None => fit(),
                        }
                    }
                    None => fit(),
                };
                let (rank, rel_err) = (factors.rank, factors.rel_err);
                self.emit(
                    ExecMode::Factored { factors },
                    Decision::Neural { rank, rel_err },
                    spec,
                    geo,
                    opts,
                    rank,
                )
            }
        }
    }

    /// Static-learned / opaque path: measure the spectral rank, apply the
    /// §4.3 low-rank test, SVD or fall back to dense. With a store, the
    /// whole measure→decide→decompose step is keyed on the table's
    /// content fingerprint + the SVD policy: a hit re-emits the cached
    /// outcome (shared factors *or* the remembered rejection) without
    /// touching the spectrum.
    fn plan_measured(&self, spec: &BiasSpec, table: &Tensor, geo: &Geometry,
                     opts: &PlanOptions, store: Option<&FactorStore>)
                     -> Result<AttentionPlan, PlanError> {
        let full_rank = geo.n.min(geo.m);
        let limit = (full_rank as f64 * self.config.max_rank_fraction)
            .ceil() as usize;
        // Strategy::Svd with a fixed rank is infallible (decompose
        // returns Ok(Some(..)) for it by contract, covered by
        // decompose unit tests)
        let decompose_now = || {
            let svd_at = |rank: usize| {
                let mut rng = Xoshiro256::new(self.config.neural.seed);
                Arc::new(
                    decompose(table,
                              &Strategy::Svd(RankSelect::Fixed(rank)),
                              &mut rng)
                        .expect("SVD strategy never errors")
                        .expect("SVD always yields factors"),
                )
            };
            match opts.rank_override {
                // a pinned rank bypasses the fraction test, so skip the
                // spectrum scan (itself a full SVD) entirely — and for
                // large tables `decompose` takes the randomized path
                Some(rank) => Cached::Factors(
                    self.apply_strip_policy(svd_at(rank)),
                ),
                None => {
                    // one Jacobi SVD serves both the spectrum scan and
                    // the truncation (the cold path used to pay it
                    // twice: rank_for_energy + svd_factors)
                    let full = linalg::svd(table);
                    let measured = linalg::rank_for_energy_in(
                        &full.s,
                        self.config.energy_target,
                    );
                    if measured <= limit {
                        let (phi_q, phi_k) =
                            linalg::factors_from_svd(&full, measured);
                        let rel_err = linalg::reconstruction_error(
                            table, &phi_q, &phi_k,
                        );
                        Cached::Factors(self.apply_strip_policy(
                            Arc::new(Factors::from_tensors(
                                phi_q, phi_k, rel_err, measured,
                            )),
                        ))
                    } else {
                        Cached::Rejected {
                            measured_rank: measured,
                        }
                    }
                }
            }
        };
        let cached = match store {
            Some(s) => {
                s.get_or_insert_with(svd_key(spec, &self.config, opts),
                                     decompose_now)
            }
            None => decompose_now(),
        };
        match cached {
            Cached::Factors(factors) => {
                let (rank, rel_err) = (factors.rank, factors.rel_err);
                self.emit(
                    ExecMode::Factored { factors },
                    Decision::Svd { rank, rel_err },
                    spec,
                    geo,
                    opts,
                    rank,
                )
            }
            Cached::Rejected { measured_rank } => self.emit(
                ExecMode::Dense {
                    bias: table.clone(),
                },
                Decision::DenseFallback {
                    measured_rank,
                    reason: format!(
                        "rank@{:.3} = {measured_rank} > limit {limit}",
                        self.config.energy_target
                    ),
                },
                spec,
                geo,
                opts,
                0,
            ),
        }
    }

    /// Final cost-model gate + plan assembly. A factored/JIT candidate
    /// that the IO model says loses to the dense stream is demoted to
    /// dense (Remark 3.8 / Corollary I.2).
    fn emit(&self, mode: ExecMode, decision: Decision, spec: &BiasSpec,
            geo: &Geometry, opts: &PlanOptions, rank: usize)
            -> Result<AttentionPlan, PlanError> {
        // emit is only reached with biased specs (plan_impl handles
        // BiasSpec::None before any emit call), and every biased spec
        // materializes
        let geometry = Geometry { r: rank, ..*geo };
        let multiplicative = spec.is_multiplicative();
        let dense_io = iomodel::flash_dense_bias_io(&geometry);
        let (mode, decision, predicted_io) = match mode {
            ExecMode::Dense { bias } => {
                (ExecMode::Dense { bias }, decision, dense_io)
            }
            ExecMode::NoBias => (
                ExecMode::NoBias,
                decision,
                iomodel::flash_attention_io(&geometry),
            ),
            candidate @ (ExecMode::Factored { .. }
            | ExecMode::Jit { .. }) => {
                let io = if multiplicative {
                    iomodel::mult_factored_io(&geometry)
                } else {
                    iomodel::flashbias_io(&geometry)
                };
                let mult_ok = !multiplicative
                    || (rank as f64)
                        <= iomodel::mult_bias_rank_threshold(
                            geometry.c, geometry.sram,
                        );
                if io >= dense_io || !mult_ok {
                    let bias = spec
                        .materialize()
                        .expect("biased spec materializes");
                    let reason = if mult_ok {
                        format!(
                            "factored IO {io:.3e} >= dense {dense_io:.3e} \
                             (Remark 3.8)"
                        )
                    } else {
                        format!(
                            "multiplicative rank {rank} above the \
                             Corollary I.2 threshold"
                        )
                    };
                    (
                        ExecMode::Dense { bias },
                        Decision::DenseFallback {
                            measured_rank: rank,
                            reason,
                        },
                        dense_io,
                    )
                } else {
                    (candidate, decision, io)
                }
            }
        };
        let bias_storage_bytes = match &mode {
            ExecMode::NoBias | ExecMode::Jit { .. } => 0,
            ExecMode::Dense { bias } => bias.size_bytes(),
            ExecMode::Factored { factors } => factors.size_bytes(),
        };
        let geometry = Geometry {
            r: match &mode {
                ExecMode::Dense { .. } | ExecMode::NoBias => 0,
                _ => rank,
            },
            ..geometry
        };
        // per-step entry of the cost model: what one decode step of
        // this plan streams (O(rank·M) strip contraction vs O(M) dense
        // row; JIT pays zero bias traffic)
        let dense_step_io = iomodel::decode_step_dense_io(&geometry);
        let predicted_step_io = match &mode {
            ExecMode::NoBias | ExecMode::Jit { .. } => {
                iomodel::decode_step_io(&geometry)
            }
            ExecMode::Dense { .. } => dense_step_io,
            ExecMode::Factored { .. } => {
                iomodel::decode_step_factored_io(&geometry)
            }
        };
        Ok(AttentionPlan {
            mode,
            geometry,
            causal: opts.causal,
            multiplicative,
            decision,
            predicted_io,
            dense_io,
            bias_storage_bytes,
            decode_capable: !multiplicative,
            predicted_step_io,
            dense_step_io,
        })
    }

    /// Apply [`SelectorConfig::strip_policy`] to freshly decomposed
    /// (always-f32) SVD/neural strips. The Eckart–Young-style gate:
    /// quantization engages only when the truncation error plus the
    /// measured quantization bound ([`quantize_factors`]) stays within
    /// the advertised tolerance, and any non-finite quantization
    /// (f16 overflow, degenerate scales) falls back to f32.
    fn apply_strip_policy(&self, factors: Arc<Factors>) -> Arc<Factors> {
        let quantized_ok = |f: &Factors, tol: f32| {
            f.rel_err.is_finite()
                && f.rel_err <= tol
                && f.phi_q.is_finite()
                && f.phi_k.is_finite()
        };
        match self.config.strip_policy {
            StripPolicy::F32Only => factors,
            StripPolicy::Auto => {
                let (qf, _bound) =
                    quantize_factors(&factors, StripDType::Bf16);
                if quantized_ok(&qf, BF16_STRIP_TOL) {
                    Arc::new(qf)
                } else {
                    factors
                }
            }
            StripPolicy::Force(dtype) => {
                if dtype == StripDType::F32 {
                    return factors;
                }
                let (qf, _bound) = quantize_factors(&factors, dtype);
                if quantized_ok(&qf, f32::INFINITY) {
                    Arc::new(qf)
                } else {
                    factors
                }
            }
        }
    }

    /// Layer-policy helper (§4.3): given per-layer rank measurements,
    /// return the first layer index from which FlashBias applies — the
    /// paper's "last 8 layers of SwinV2" rule generalized.
    pub fn factored_from(&self, ranks_at_energy: &[usize],
                         full_rank: usize) -> usize {
        let limit = (full_rank as f64 * self.config.max_rank_fraction)
            .ceil() as usize;
        // longest low-rank suffix
        let mut from = ranks_at_energy.len();
        for (i, &r) in ranks_at_energy.iter().enumerate().rev() {
            if r <= limit {
                from = i;
            } else {
                break;
            }
        }
        from
    }
}

/// Mix the strip dtype policy into a store key. [`StripPolicy::F32Only`]
/// writes nothing — legacy (pre-dtype) store files stay addressable —
/// while any quantizing policy gets its own key space, so strips
/// quantized under one policy never alias a plan minted under another.
fn write_strip_policy(h: &mut Fnv64, policy: StripPolicy) {
    match policy {
        StripPolicy::F32Only => {}
        StripPolicy::Auto => h.write_str("strip:auto"),
        StripPolicy::Force(dtype) => {
            h.write_str("strip:force");
            h.write_str(dtype.name());
        }
    }
}

/// Store key for the measured/SVD path: the spec's content fingerprint
/// mixed with every policy knob that changes the outcome (energy target,
/// rank fraction, rank override, strip dtype policy — and, when the
/// randomized range finder can fire, the sketch seed). Distinct
/// policies never alias.
fn svd_key(spec: &BiasSpec, config: &SelectorConfig,
           opts: &PlanOptions) -> Fingerprint {
    let mut h = Fnv64::new();
    h.write_str("svd");
    h.write_u64(spec.fingerprint().as_u64());
    write_strip_policy(&mut h, config.strip_policy);
    match opts.rank_override {
        Some(r) => {
            // a pinned rank makes the energy/fraction knobs irrelevant
            // — keying on them would split identical cached work
            h.write_str("rank");
            h.write_u64(r as u64);
            // large tables at a pinned small rank decompose through the
            // seeded randomized sketch: different seeds yield
            // bit-different factors, so they must not share an entry
            if let Some((n, m)) = spec.shape() {
                if uses_randomized_svd(n, m, r) {
                    h.write_u64(config.neural.seed);
                }
            }
        }
        None => {
            h.write_str("energy");
            h.write_u64(config.energy_target.to_bits());
            h.write_u64(config.max_rank_fraction.to_bits());
        }
    }
    h.finish()
}

/// Store key for the neural path: content fingerprint + the full fit
/// configuration (a different seed or step budget is a different fit)
/// + the strip dtype policy.
fn neural_key(spec: &BiasSpec, cfg: &NeuralConfig,
              policy: StripPolicy) -> Fingerprint {
    let mut h = Fnv64::new();
    h.write_str("neural");
    h.write_u64(spec.fingerprint().as_u64());
    write_strip_policy(&mut h, policy);
    h.write_u64(cfg.rank as u64);
    h.write_u64(cfg.hidden as u64);
    h.write_u64(cfg.steps as u64);
    h.write_u32(cfg.lr.to_bits());
    h.write_u32(cfg.lr_decay.to_bits());
    h.write_u64(cfg.lr_decay_every as u64);
    h.write_u64(cfg.seed);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo(n: usize, m: usize) -> Geometry {
        Geometry {
            n,
            m,
            c: 64,
            r: 0,
            sram: 100 * 1024 / 2,
        }
    }

    #[test]
    fn alibi_plans_exact_factored() {
        let plan = Planner::default()
            .plan(&BiasSpec::alibi(64, 64, 0.25), &geo(64, 64),
                  &PlanOptions::default())
            .unwrap();
        assert!(matches!(plan.decision, Decision::Exact { rank: 2 }));
        assert!(matches!(plan.mode, ExecMode::Factored { .. }));
        assert_eq!(plan.rank(), 2);
        assert!(plan.predicted_io < plan.dense_io);
    }

    #[test]
    fn alibi_jit_has_zero_bias_storage() {
        let opts = PlanOptions {
            prefer_jit: true,
            ..PlanOptions::default()
        };
        let plan = Planner::default()
            .plan(&BiasSpec::alibi(64, 64, 0.25), &geo(64, 64), &opts)
            .unwrap();
        assert!(matches!(plan.mode, ExecMode::Jit { .. }));
        assert_eq!(plan.bias_storage_bytes, 0);
        assert_eq!(plan.algorithm(), Algorithm::FlashBias(2));
    }

    #[test]
    fn decode_fields_follow_mode() {
        // factored plan: decode-capable, per-step IO beats the dense row
        let fact = Planner::default()
            .plan(&BiasSpec::alibi(4096, 4096, 0.25), &geo(4096, 4096),
                  &PlanOptions::default())
            .unwrap();
        assert!(fact.decode_capable);
        assert!(fact.predicted_step_io < fact.dense_step_io);
        assert!(fact.step_io_saving() > 1.0);
        // jit plan: zero bias traffic per step
        let opts = PlanOptions {
            prefer_jit: true,
            ..PlanOptions::default()
        };
        let jit = Planner::default()
            .plan(&BiasSpec::alibi(4096, 4096, 0.25), &geo(4096, 4096),
                  &opts)
            .unwrap();
        assert!(jit.decode_capable);
        assert!(jit.predicted_step_io < fact.dense_step_io);
        // multiplicative plan: no additive strip form → not capable
        let mult = Planner::default()
            .plan(&BiasSpec::cos_multiplicative(16, 16), &geo(16, 16),
                  &PlanOptions::default())
            .unwrap();
        assert!(!mult.decode_capable);
        // no-bias plan: capable, both step costs equal
        let none = Planner::default()
            .plan(&BiasSpec::None, &geo(128, 128),
                  &PlanOptions::default())
            .unwrap();
        assert!(none.decode_capable);
        assert_eq!(none.predicted_step_io, none.dense_step_io);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let err = Planner::default()
            .plan(&BiasSpec::alibi(64, 64, 0.25), &geo(64, 32),
                  &PlanOptions::default())
            .unwrap_err();
        assert!(matches!(err, PlanError::ShapeMismatch { .. }));
    }

    #[test]
    fn causal_multiplicative_rejected() {
        let opts = PlanOptions {
            causal: true,
            ..PlanOptions::default()
        };
        let err = Planner::default()
            .plan(&BiasSpec::cos_multiplicative(16, 16), &geo(16, 16),
                  &opts)
            .unwrap_err();
        assert!(matches!(err, PlanError::CausalMultiplicative));
    }

    #[test]
    fn no_bias_plan_is_pure_flash() {
        let plan = Planner::default()
            .plan(&BiasSpec::None, &geo(128, 128), &PlanOptions::default())
            .unwrap();
        assert!(matches!(plan.mode, ExecMode::NoBias));
        assert_eq!(plan.algorithm(), Algorithm::Flash);
        assert_eq!(plan.rank(), 0);
    }

    #[test]
    fn store_hit_shares_factors_pointer_equal() {
        use crate::factorstore::FactorStore;
        let mut rng = Xoshiro256::new(5);
        let a = Tensor::randn(&[32, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[32, 4], 1.0, &mut rng);
        let spec = BiasSpec::static_learned(a.matmul_t(&b));
        let store = FactorStore::unbounded();
        let planner = Planner::default();
        let opts = PlanOptions {
            rank_override: Some(4),
            ..PlanOptions::default()
        };
        let p1 = planner
            .plan_with_store(&spec, &geo(32, 32), &opts, &store)
            .unwrap();
        let p2 = planner
            .plan_with_store(&spec, &geo(32, 32), &opts, &store)
            .unwrap();
        assert_eq!(store.misses(), 1);
        assert_eq!(store.hits(), 1);
        match (&p1.mode, &p2.mode) {
            (
                ExecMode::Factored { factors: f1 },
                ExecMode::Factored { factors: f2 },
            ) => assert!(Arc::ptr_eq(f1, f2), "warm plan must share"),
            other => panic!("expected factored plans, got {other:?}"),
        }
        // a different policy is a different key, not a stale hit
        let p3 = planner
            .plan_with_store(&spec, &geo(32, 32),
                             &PlanOptions::default(), &store)
            .unwrap();
        assert_eq!(store.misses(), 2);
        assert!(matches!(p3.decision, Decision::Svd { .. }));
    }

    #[test]
    fn store_caches_dense_fallback_verdict() {
        use crate::factorstore::FactorStore;
        let mut rng = Xoshiro256::new(1);
        let spec =
            BiasSpec::dense(Tensor::randn(&[48, 48], 1.0, &mut rng));
        let store = FactorStore::unbounded();
        let planner = Planner::default();
        for _ in 0..2 {
            let plan = planner
                .plan_with_store(&spec, &geo(48, 48),
                                 &PlanOptions::default(), &store)
                .unwrap();
            assert!(matches!(plan.decision,
                             Decision::DenseFallback { .. }));
        }
        assert_eq!(store.misses(), 1, "the rank scan must be cached too");
        assert_eq!(store.hits(), 1);
    }

    #[test]
    fn auto_policy_quantizes_within_documented_tolerance() {
        let mut rng = Xoshiro256::new(7);
        let a = Tensor::randn(&[48, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[48, 4], 1.0, &mut rng);
        let table = a.matmul_t(&b);
        let spec = BiasSpec::static_learned(table.clone());
        let f32_planner = Planner::default();
        let bf16_planner = Planner::new(SelectorConfig {
            strip_policy: StripPolicy::Auto,
            ..SelectorConfig::default()
        });
        let opts = PlanOptions::default();
        let g = geo(48, 48);
        let pf = f32_planner.plan(&spec, &g, &opts).unwrap();
        let pb = bf16_planner.plan(&spec, &g, &opts).unwrap();
        let (ff, fb) = match (&pf.mode, &pb.mode) {
            (
                ExecMode::Factored { factors: ff },
                ExecMode::Factored { factors: fb },
            ) => (ff, fb),
            other => panic!("expected factored plans, got {other:?}"),
        };
        assert_eq!(pf.strip_dtype(), StripDType::F32);
        assert_eq!(pb.strip_dtype(), StripDType::Bf16);
        assert!(fb.rel_err <= BF16_STRIP_TOL,
                "total error {} over budget", fb.rel_err);
        // the end-to-end bias error really is within the advertised
        // tolerance, measured against the dense table
        let err = fb.reconstruct().rel_err(&table);
        assert!(err <= BF16_STRIP_TOL, "measured {err}");
        // and the storage bill halves
        assert!(pb.bias_storage_bytes * 2 == pf.bias_storage_bytes,
                "{} vs {}", pb.bias_storage_bytes, pf.bias_storage_bytes);
    }

    #[test]
    fn strip_policies_never_alias_in_the_store() {
        use crate::factorstore::FactorStore;
        let mut rng = Xoshiro256::new(8);
        let a = Tensor::randn(&[40, 4], 1.0, &mut rng);
        let spec = BiasSpec::static_learned(a.matmul_t(&a));
        let store = FactorStore::unbounded();
        let opts = PlanOptions {
            rank_override: Some(4),
            ..PlanOptions::default()
        };
        let g = geo(40, 40);
        let p1 = Planner::default()
            .plan_with_store(&spec, &g, &opts, &store)
            .unwrap();
        let p2 = Planner::new(SelectorConfig {
            strip_policy: StripPolicy::Force(StripDType::Bf16),
            ..SelectorConfig::default()
        })
        .plan_with_store(&spec, &g, &opts, &store)
        .unwrap();
        assert_eq!(store.misses(), 2, "policies must not share a key");
        assert_eq!(p1.strip_dtype(), StripDType::F32);
        assert_eq!(p2.strip_dtype(), StripDType::Bf16);
    }

    #[test]
    fn factored_from_suffix_rule() {
        let p = Planner::default();
        // SwinV2 pattern (Figure 8): early layers high-rank, later low
        let ranks = [300, 280, 250, 120, 60, 40, 30, 20];
        // 576 * 0.35 ≈ 202 → suffix starts where rank ≤ 202: index 3
        assert_eq!(p.factored_from(&ranks, 576), 3);
        assert_eq!(p.factored_from(&[500, 480, 460], 576), 3);
        assert_eq!(p.factored_from(&[10, 12, 8], 576), 0);
    }
}
