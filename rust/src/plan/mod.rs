//! The FlashBias pipeline behind one API: **bias → plan → execute**.
//!
//! The paper's core claim is that a single decision procedure (Table 1,
//! justified by the Thm 3.1 rank bound) covers ALiBi, Swin, Pangu,
//! AlphaFold and PDE biases alike — and that the win comes from keeping
//! that decision fused with execution. This module is that procedure as
//! the crate's single public entry point:
//!
//! ```no_run
//! use flashbias::iomodel::Geometry;
//! use flashbias::plan::{self, BiasSpec, PlanOptions, Planner};
//! # use flashbias::tensor::Tensor;
//! # use flashbias::util::Xoshiro256;
//! # let mut rng = Xoshiro256::new(0);
//! # let (q, k, v) = (
//! #     Tensor::randn(&[256, 64], 1.0, &mut rng),
//! #     Tensor::randn(&[256, 64], 1.0, &mut rng),
//! #     Tensor::randn(&[256, 64], 1.0, &mut rng),
//! # );
//! let spec = BiasSpec::alibi(256, 256, 0.25);
//! let plan = Planner::default()
//!     .plan(&spec, &Geometry::square(256, 64, 0, 51200),
//!           &PlanOptions { causal: true, ..PlanOptions::default() })?;
//! let out = plan::execute(&plan, &q, &k, &v)?;
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! * [`BiasSpec`] — the whole bias zoo (closed-form, static learned,
//!   dynamic, opaque dense) with uniform metadata, plus a content
//!   [`BiasSpec::fingerprint`] for store addressing.
//! * [`Planner`] — Table 1 decision procedure + the `iomodel` cost gate;
//!   emits an [`AttentionPlan`] (mode = dense / factored / JIT, effective
//!   rank, predicted HBM IO, factor storage).
//!   [`Planner::plan_with_store`] amortizes the expensive rows (SVD,
//!   neural fits) through a [`crate::factorstore::FactorStore`]: a
//!   repeated plan for the same bias content is a cache hit sharing the
//!   stored strips, with zero decomposition work.
//! * [`Executor`] — one `execute(&plan, q, k, v)` call over three
//!   backends: host reference, tiled simulator, PJRT runtime.
//! * [`SessionState`] — the prefill/decode split: a long-lived session's
//!   KV cache plus streaming-softmax carry, with `prefill` running the
//!   one-shot engine path and `step` the exact 1×M decode path
//!   ([`crate::kernels::run_decode_step`]). The coordinator wraps it in
//!   a session registry and continuous-batches steps across sessions.
//!
//! Everything downstream (coordinator, server, examples, benches) goes
//! through this module; no caller declares bias classes or decomposition
//! strategies by hand.

mod exec;
mod planner;
mod session;
mod spec;

pub use exec::{
    execute, plan_bias_tile, Executor, HostExecutor, PjrtExecutor,
    SimExecutor,
};
pub use planner::{
    AttentionPlan, Decision, ExecMode, JitBias, PlanError, PlanOptions,
    Planner, SelectorConfig, StripPolicy, BF16_STRIP_TOL, F32_STRIP_TOL,
};
pub use session::{SessionError, SessionState, StepTicket};
pub use spec::BiasSpec;
