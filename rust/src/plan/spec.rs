//! [`BiasSpec`] — the whole bias zoo behind one type.
//!
//! Every bias the paper touches (Table 1 and §4) is declared here with
//! uniform metadata: shape, classification (closed-form / static learned /
//! dynamic / opaque), exact rank when a closed-form factorization exists,
//! and — when the planner needs them — the dense matrix or the exact
//! factor strips. The [`crate::plan::Planner`] consumes a `BiasSpec` and
//! never asks the caller which Table 1 row applies; that decision is the
//! planner's job.

use crate::bias::{Alibi, CosMultiplicative, ExactBias, SpatialDistance};
use crate::factorstore::{Fingerprint, Fnv64};
use crate::tensor::Tensor;

/// One bias from the paper's zoo, in planner-consumable form.
#[derive(Clone, Debug)]
pub enum BiasSpec {
    /// No bias — pure FlashAttention.
    None,
    /// ALiBi `b[i,j] = slope·(j − i)` (Example 3.4). Closed form, R = 2.
    Alibi { n: usize, m: usize, slope: f32 },
    /// Weighted spatial squared distance (Example 3.5, PDE solvers).
    /// Closed form, R = 3·dim.
    Spatial(SpatialDistance),
    /// Multiplicative `cos(i − j)` bias (Appendix I Example I.1).
    /// Closed form, R = 2, combined by Hadamard product not addition.
    CosMultiplicative { n: usize, m: usize },
    /// Fixed learned parameter table (Swin / Pangu relative-position
    /// bias): spectral profile measurable offline, SVD candidate.
    StaticLearned { table: Tensor },
    /// Data-dependent bias projected from activations (AlphaFold pair
    /// bias, gravity, spherical): differs per sample, neural candidate.
    /// `sources_q`/`sources_k` are the token-wise inputs the factor
    /// functions φ̂ are fitted on (Eq. 5); `bias` is this sample's dense
    /// matrix (the fitting target).
    Dynamic {
        sources_q: Tensor,
        sources_k: Tensor,
        bias: Tensor,
    },
    /// Opaque dense matrix: nothing declared. The planner still runs the
    /// spectral rank test before falling back to the dense stream.
    Dense { table: Tensor },
}

impl BiasSpec {
    /// ALiBi with the given shape and per-head slope.
    pub fn alibi(n: usize, m: usize, slope: f32) -> Self {
        BiasSpec::Alibi { n, m, slope }
    }

    /// Spatial squared-distance bias from query/key positions
    /// (`xq: (N, dim)`, `xk: (M, dim)`) and optional per-query weights.
    pub fn spatial(xq: Tensor, xk: Tensor, alpha: Option<Vec<f32>>) -> Self {
        BiasSpec::Spatial(SpatialDistance::new(xq, xk, alpha))
    }

    /// Multiplicative `cos(i − j)` bias.
    pub fn cos_multiplicative(n: usize, m: usize) -> Self {
        BiasSpec::CosMultiplicative { n, m }
    }

    /// Static learned table (one head's gathered `(N, M)` bias).
    pub fn static_learned(table: Tensor) -> Self {
        assert_eq!(table.rank(), 2, "bias table must be (N, M)");
        BiasSpec::StaticLearned { table }
    }

    /// Dynamic bias with its token sources (`(N, d)` / `(M, d)`).
    pub fn dynamic(sources_q: Tensor, sources_k: Tensor,
                   bias: Tensor) -> Self {
        assert_eq!(bias.rank(), 2, "bias must be (N, M)");
        assert_eq!(sources_q.shape()[0], bias.shape()[0], "N mismatch");
        assert_eq!(sources_k.shape()[0], bias.shape()[1], "M mismatch");
        BiasSpec::Dynamic {
            sources_q,
            sources_k,
            bias,
        }
    }

    /// Opaque dense bias.
    pub fn dense(table: Tensor) -> Self {
        assert_eq!(table.rank(), 2, "bias table must be (N, M)");
        BiasSpec::Dense { table }
    }

    /// `(N, M)` shape, or `None` for the no-bias spec.
    pub fn shape(&self) -> Option<(usize, usize)> {
        match self {
            BiasSpec::None => None,
            BiasSpec::Alibi { n, m, .. }
            | BiasSpec::CosMultiplicative { n, m } => Some((*n, *m)),
            BiasSpec::Spatial(s) => Some(s.shape()),
            BiasSpec::StaticLearned { table }
            | BiasSpec::Dense { table } => {
                Some((table.shape()[0], table.shape()[1]))
            }
            BiasSpec::Dynamic { bias, .. } => {
                Some((bias.shape()[0], bias.shape()[1]))
            }
        }
    }

    /// Exact factorization rank when a closed form exists (Table 1a).
    pub fn exact_rank(&self) -> Option<usize> {
        match self {
            BiasSpec::Alibi { .. } => Some(2),
            BiasSpec::Spatial(s) => Some(s.rank()),
            BiasSpec::CosMultiplicative { .. } => Some(2),
            _ => None,
        }
    }

    /// Whether this bias multiplies the scores instead of adding
    /// (Appendix I Eq. 15).
    pub fn is_multiplicative(&self) -> bool {
        matches!(self, BiasSpec::CosMultiplicative { .. })
    }

    /// Whether the bias differs per sample (blocks offline SVD).
    pub fn is_dynamic(&self) -> bool {
        matches!(self, BiasSpec::Dynamic { .. })
    }

    /// Short label for plan summaries and routing.
    pub fn kind(&self) -> &'static str {
        match self {
            BiasSpec::None => "none",
            BiasSpec::Alibi { .. } => "alibi",
            BiasSpec::Spatial(_) => "spatial",
            BiasSpec::CosMultiplicative { .. } => "cos-mult",
            BiasSpec::StaticLearned { .. } => "static-learned",
            BiasSpec::Dynamic { .. } => "dynamic",
            BiasSpec::Dense { .. } => "dense",
        }
    }

    /// Exact closed-form factor strips (Table 1a), when they exist.
    pub fn exact_factors(&self) -> Option<(Tensor, Tensor)> {
        match self {
            BiasSpec::Alibi { n, m, slope } => {
                Some(Alibi::new(*n, *m, *slope).factors())
            }
            BiasSpec::Spatial(s) => Some(s.factors()),
            BiasSpec::CosMultiplicative { n, m } => {
                Some(CosMultiplicative { n: *n, m: *m }.factors())
            }
            _ => None,
        }
    }

    /// Content fingerprint: kind + geometry + the exact bit patterns of
    /// whatever data defines this bias (tables, token sources, slopes).
    /// Two specs with the same fingerprint produce identical factors, so
    /// the [`crate::factorstore::FactorStore`] can share one
    /// decomposition between them; perturbing a single table entry by
    /// one ulp changes the fingerprint.
    ///
    /// The fingerprint deliberately excludes planning *policy* (energy
    /// target, rank override, neural config) — the planner mixes those
    /// into its store keys itself, so one bias can coexist in the store
    /// under several decomposition policies.
    pub fn fingerprint(&self) -> Fingerprint {
        let mut h = Fnv64::new();
        h.write_str(self.kind());
        if let Some((n, m)) = self.shape() {
            h.write_u64(n as u64);
            h.write_u64(m as u64);
        }
        match self {
            BiasSpec::None | BiasSpec::CosMultiplicative { .. } => {}
            BiasSpec::Alibi { slope, .. } => h.write_u32(slope.to_bits()),
            BiasSpec::Spatial(s) => {
                h.write_f32s(s.xq.data());
                h.write_f32s(s.xk.data());
                match &s.alpha {
                    Some(a) => {
                        h.write_str("alpha");
                        h.write_f32s(a);
                    }
                    None => h.write_str("unweighted"),
                }
            }
            BiasSpec::StaticLearned { table }
            | BiasSpec::Dense { table } => h.write_f32s(table.data()),
            BiasSpec::Dynamic {
                sources_q,
                sources_k,
                bias,
            } => {
                h.write_f32s(sources_q.data());
                h.write_f32s(sources_k.data());
                h.write_f32s(bias.data());
            }
        }
        h.finish()
    }

    /// Materialize the dense `(N, M)` matrix. `None` only for
    /// [`BiasSpec::None`]. For closed-form biases this is O(NM) — the
    /// planner avoids calling it unless it must fall back to dense.
    pub fn materialize(&self) -> Option<Tensor> {
        match self {
            BiasSpec::None => None,
            BiasSpec::Alibi { n, m, slope } => {
                Some(Alibi::new(*n, *m, *slope).dense())
            }
            BiasSpec::Spatial(s) => Some(s.dense()),
            BiasSpec::CosMultiplicative { n, m } => {
                Some(CosMultiplicative { n: *n, m: *m }.dense())
            }
            BiasSpec::StaticLearned { table }
            | BiasSpec::Dense { table } => Some(table.clone()),
            BiasSpec::Dynamic { bias, .. } => Some(bias.clone()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    #[test]
    fn alibi_spec_metadata() {
        let s = BiasSpec::alibi(16, 24, 0.5);
        assert_eq!(s.shape(), Some((16, 24)));
        assert_eq!(s.exact_rank(), Some(2));
        assert!(!s.is_multiplicative());
        assert!(!s.is_dynamic());
        assert_eq!(s.kind(), "alibi");
        let (pq, pk) = s.exact_factors().unwrap();
        let dense = s.materialize().unwrap();
        assert!(pq.matmul_t(&pk).allclose(&dense, 1e-4, 1e-4));
    }

    #[test]
    fn spatial_spec_rank_tracks_dim() {
        let mut rng = Xoshiro256::new(0);
        let x = Tensor::randn(&[10, 3], 1.0, &mut rng);
        let s = BiasSpec::spatial(x.clone(), x, None);
        assert_eq!(s.exact_rank(), Some(9));
        assert_eq!(s.shape(), Some((10, 10)));
    }

    #[test]
    fn cos_mult_is_multiplicative() {
        let s = BiasSpec::cos_multiplicative(8, 8);
        assert!(s.is_multiplicative());
        assert_eq!(s.exact_rank(), Some(2));
    }

    #[test]
    fn static_and_dense_have_no_exact_rank() {
        let t = Tensor::ones(&[4, 4]);
        assert_eq!(BiasSpec::static_learned(t.clone()).exact_rank(), None);
        assert_eq!(BiasSpec::dense(t).exact_rank(), None);
    }

    #[test]
    fn dynamic_spec_shapes() {
        let mut rng = Xoshiro256::new(1);
        let xq = Tensor::randn(&[6, 2], 1.0, &mut rng);
        let xk = Tensor::randn(&[9, 2], 1.0, &mut rng);
        let b = Tensor::randn(&[6, 9], 1.0, &mut rng);
        let s = BiasSpec::dynamic(xq, xk, b);
        assert_eq!(s.shape(), Some((6, 9)));
        assert!(s.is_dynamic());
        assert!(s.exact_factors().is_none());
    }

    #[test]
    fn none_spec_is_shapeless() {
        assert_eq!(BiasSpec::None.shape(), None);
        assert!(BiasSpec::None.materialize().is_none());
    }

    #[test]
    fn fingerprint_is_content_addressed() {
        let mut rng = Xoshiro256::new(11);
        let t = Tensor::randn(&[12, 12], 1.0, &mut rng);
        // same content → same key
        assert_eq!(
            BiasSpec::static_learned(t.clone()).fingerprint(),
            BiasSpec::static_learned(t.clone()).fingerprint()
        );
        // same table, different kind → different key
        assert_ne!(
            BiasSpec::static_learned(t.clone()).fingerprint(),
            BiasSpec::dense(t.clone()).fingerprint()
        );
        // one-element perturbation → different key
        let mut t2 = t.clone();
        t2.set2(3, 5, t2.at2(3, 5) + 1e-6);
        assert_ne!(
            BiasSpec::static_learned(t).fingerprint(),
            BiasSpec::static_learned(t2).fingerprint()
        );
    }

    #[test]
    fn fingerprint_covers_geometry_and_params() {
        assert_ne!(
            BiasSpec::alibi(64, 64, 0.25).fingerprint(),
            BiasSpec::alibi(64, 64, 0.5).fingerprint()
        );
        assert_ne!(
            BiasSpec::alibi(64, 64, 0.25).fingerprint(),
            BiasSpec::alibi(64, 128, 0.25).fingerprint()
        );
        assert_eq!(
            BiasSpec::alibi(64, 64, 0.25).fingerprint(),
            BiasSpec::alibi(64, 64, 0.25).fingerprint()
        );
    }
}
