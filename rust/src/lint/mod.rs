//! flashlint: a dependency-free static-analysis pass for the serving
//! core's concurrency and panic-safety invariants.
//!
//! The rules encode bug classes found by hand in past reviews:
//!
//! | rule | checks |
//! |------|--------|
//! | `lock-unwrap` | `.lock()/.read()/.write()` result unwrapped in `coordinator/`, `server/`, `factorstore/`, `runtime/` (poison cascade) |
//! | `raw-sync` | raw `std::sync::{Mutex,RwLock}` use outside the `util::sync` shim, or a lock constructed without an audit name |
//! | `io-under-lock` | file/socket I/O lexically inside a lock-guard live range in `factorstore/` |
//! | `nonfinite-persist` | factor-serializing calls in `factorstore/` whose enclosing function never checks finiteness |
//! | `hot-path-panic` | `panic!`/`unwrap`/`expect`/`todo!`/`unimplemented!` reachable from the hot-path manifest |
//!
//! Findings can be suppressed in place with an annotation comment that
//! must carry a reason (see [`rules::AllowForm`]): `allow` covers the
//! next line, `allow-fn` the enclosing function, `allow-file` the file.
//! A malformed or reasonless annotation is itself reported (`bad-allow`)
//! and cannot be suppressed.
//!
//! Run it via `make lint` or directly:
//!
//! ```text
//! cargo run --release --bin flashlint -- rust/src
//! cargo run --release --bin flashlint -- --json rust/src
//! ```
//!
//! Exit code 0 = clean, 1 = unsuppressed findings, 2 = usage/IO error.

pub mod callgraph;
pub mod rules;
pub mod tokenizer;

use crate::jsonlite::Json;
use std::path::{Path, PathBuf};

/// Rule registry: (name, one-line summary, fix hint).
pub const RULES: &[(&str, &str, &str)] = &[
    (
        "lock-unwrap",
        "lock result unwrapped in the serving core",
        "use util::sync wrappers: lock_recover()/read_recover()/write_recover()",
    ),
    (
        "raw-sync",
        "raw std::sync lock outside the util::sync shim",
        "construct locks via util::sync::{Mutex,RwLock}::new(\"module.role\", value)",
    ),
    (
        "io-under-lock",
        "file/socket I/O inside a lock-guard live range",
        "copy the data out, drop the guard, then do the I/O (or scope the guard in a block)",
    ),
    (
        "nonfinite-persist",
        "factor floats persisted without a finiteness guard",
        "call entry_is_finite()/is_finite() before serializing, and skip or reject non-finite factors",
    ),
    (
        "hot-path-panic",
        "panic site reachable from the serving hot path",
        "return a typed error (or prove the invariant and add a flashlint allow annotation with the proof)",
    ),
    (
        "bad-allow",
        "malformed flashlint allow annotation",
        "use `// flashlint: allow(rule) reason`, allow-fn(...) or allow-file(...); the reason is mandatory",
    ),
];

#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
    pub hint: &'static str,
}

#[derive(Debug, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
    pub files_scanned: usize,
    pub suppressed: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }
}

#[derive(Clone, Debug)]
pub struct LintConfig {
    /// Hot-path root function names for R5.
    pub hotpath_roots: Vec<String>,
}

impl Default for LintConfig {
    fn default() -> Self {
        Self {
            hotpath_roots: parse_hotpath(default_hotpath_manifest()),
        }
    }
}

/// The checked-in hot-path manifest (`src/lint/hotpath.txt`).
pub fn default_hotpath_manifest() -> &'static str {
    include_str!("hotpath.txt")
}

/// Parse a manifest: one fn name per line, `#` comments, blanks ignored.
pub fn parse_hotpath(text: &str) -> Vec<String> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect()
}

fn hint_for(rule: &str) -> &'static str {
    RULES
        .iter()
        .find(|(name, _, _)| *name == rule)
        .map(|(_, _, hint)| *hint)
        .unwrap_or("")
}

/// Lint a set of `(path, contents)` pairs. R1–R4 run per file; R5 runs
/// over the whole set so cross-file reachability works.
pub fn lint_sources(files: &[(String, String)], cfg: &LintConfig) -> Report {
    let analyses: Vec<rules::FileAnalysis> = files
        .iter()
        .map(|(path, src)| rules::analyze(path, src))
        .collect();

    let mut raw: Vec<(usize, rules::Finding)> = Vec::new();
    for (fi, fa) in analyses.iter().enumerate() {
        for f in rules::r1_lock_unwrap(fa) {
            raw.push((fi, f));
        }
        for f in rules::r2_raw_sync(fa) {
            raw.push((fi, f));
        }
        for f in rules::r3_io_under_lock(fa) {
            raw.push((fi, f));
        }
        for f in rules::r4_nonfinite_persist(fa) {
            raw.push((fi, f));
        }
    }
    raw.extend(callgraph::hot_path_findings(&analyses, &cfg.hotpath_roots));

    let mut report = Report {
        files_scanned: files.len(),
        ..Report::default()
    };
    for (fi, f) in raw {
        let fa = &analyses[fi];
        // bad-allow is never suppressible; everything else honors allows.
        if f.rule != "bad-allow" && rules::is_suppressed(fa, f.rule, f.line) {
            report.suppressed += 1;
            continue;
        }
        report.diagnostics.push(Diagnostic {
            file: fa.path.clone(),
            line: f.line,
            rule: f.rule,
            message: f.message,
            hint: hint_for(f.rule),
        });
    }
    // Malformed annotations are diagnostics too.
    for fa in &analyses {
        for f in &fa.bad_allows {
            report.diagnostics.push(Diagnostic {
                file: fa.path.clone(),
                line: f.line,
                rule: f.rule,
                message: f.message.clone(),
                hint: hint_for(f.rule),
            });
        }
    }
    report
        .diagnostics
        .sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    report
}

/// Recursively collect `.rs` files under `root` (or `root` itself if it
/// is a file), skipping `vendor/`, `target/`, and hidden directories.
pub fn collect_rs_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(out);
    }
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .collect();
        entries.sort();
        for path in entries {
            let name = path
                .file_name()
                .and_then(|n| n.to_str())
                .unwrap_or("");
            if path.is_dir() {
                if name == "vendor" || name == "target" || name.starts_with('.')
                {
                    continue;
                }
                stack.push(path);
            } else if name.ends_with(".rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Human-readable rendering, one line per finding plus a summary.
pub fn render_text(report: &Report) -> String {
    let mut out = String::new();
    for d in &report.diagnostics {
        out.push_str(&format!(
            "{}:{}: [{}] {}\n    hint: {}\n",
            d.file, d.line, d.rule, d.message, d.hint
        ));
    }
    out.push_str(&format!(
        "flashlint: {} finding(s), {} suppressed, {} file(s) scanned\n",
        report.diagnostics.len(),
        report.suppressed,
        report.files_scanned
    ));
    out
}

/// Machine-readable rendering (single JSON object).
pub fn render_json(report: &Report) -> String {
    let diags: Vec<Json> = report
        .diagnostics
        .iter()
        .map(|d| {
            Json::obj(vec![
                ("file", Json::str(&d.file)),
                ("line", Json::num(d.line as f64)),
                ("rule", Json::str(d.rule)),
                ("message", Json::str(&d.message)),
                ("hint", Json::str(d.hint)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("files_scanned", Json::num(report.files_scanned as f64)),
        ("suppressed", Json::num(report.suppressed as f64)),
        ("violations", Json::num(report.diagnostics.len() as f64)),
        ("diagnostics", Json::Arr(diags)),
    ])
    .dump()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_one(path: &str, src: &str) -> Report {
        lint_sources(
            &[(path.to_string(), src.to_string())],
            &LintConfig::default(),
        )
    }

    #[test]
    fn manifest_parses_and_has_roots() {
        let roots = parse_hotpath(default_hotpath_manifest());
        assert!(roots.len() >= 10);
        assert!(roots.iter().any(|r| r == "serve_loop"));
        assert!(roots.iter().all(|r| !r.starts_with('#')));
    }

    #[test]
    fn clean_file_is_clean() {
        let r = lint_one(
            "src/coordinator/mod.rs",
            "pub fn quiet() -> usize { 1 + 1 }",
        );
        assert!(r.clean(), "{:?}", r.diagnostics);
    }

    #[test]
    fn json_rendering_is_parseable() {
        let r = lint_one(
            "src/factorstore/x.rs",
            "fn f(m: &M) { m.lock().unwrap(); }",
        );
        assert_eq!(r.diagnostics.len(), 1);
        let j = crate::jsonlite::Json::parse(&render_json(&r))
            .expect("valid json");
        assert_eq!(j.get("violations").as_usize(), Some(1));
        let d = &j.get("diagnostics").as_arr().expect("arr")[0];
        assert_eq!(d.get("rule").as_str(), Some("lock-unwrap"));
    }
}
