//! flashlint: a dependency-free static-analysis pass for the serving
//! core's concurrency, determinism, and performance invariants.
//!
//! The rules encode bug classes found by hand in past reviews. R1–R4
//! and R9 are lexical per-file checks; R5, R7, R8, and R10 run on a
//! whole-crate call graph with impl-aware receiver resolution (see
//! [`callgraph`]), seeded by the checked-in manifests
//! `src/lint/hotpath.txt` (sections `[serving]`, `[inner]`,
//! `[scratch]`) and `src/lint/dispatch.txt` (sections `[roots]`,
//! `[blocking]`, `[leaf-locks]`).
//!
//! | rule | checks |
//! |------|--------|
//! | R1 `lock-unwrap` | `.lock()/.read()/.write()` result unwrapped in `coordinator/`, `server/`, `factorstore/`, `runtime/` (poison cascade) |
//! | R2 `raw-sync` | raw `std::sync::{Mutex,RwLock}` use outside the `util::sync` shim, or a lock constructed without an audit name |
//! | R3 `io-under-lock` | file/socket I/O lexically inside a lock-guard live range, anywhere in the crate |
//! | R4 `nonfinite-persist` | factor-serializing calls in `factorstore/` whose enclosing function never checks finiteness |
//! | R5 `hot-path-panic` | `panic!`/`unwrap`/`expect`/`todo!`/`unimplemented!` reachable from the `[serving]` roots |
//! | R6 `bad-allow` | malformed, reasonless, or unknown-rule suppression annotations |
//! | R7 `alloc-in-hotpath` | heap allocation (`Vec::new`, `clone`, `collect`, `format!`, …) reachable from the `[inner]` decode/kernel roots, minus the `[scratch]` allowlist |
//! | R8 `unordered-iteration` | `HashMap`/`HashSet` iteration in code on the serving path or feeding jsonlite dumps / wire frames (bitwise-stability killer) |
//! | R9 `uncapped-read` | socket/file reads on wire paths not bounded by `util::frame::read_frame_limited` / `set_io_timeouts` |
//! | R10 `dispatch-blocking` | blocking calls (`connect`, `join`, `sleep`, non-`try_` locks off the `[leaf-locks]` list) reachable from the dispatch thread's `[roots]` |
//! | `stale-allow` | a suppression annotation whose scope no longer contains any finding for its rule |
//!
//! Findings can be suppressed in place with an annotation comment that
//! must carry a reason (see [`rules::AllowForm`]): `allow` covers the
//! next line, `allow-fn` the enclosing function, `allow-file` the file.
//! A malformed or reasonless annotation is itself reported
//! (`bad-allow`), an annotation that no longer suppresses anything is
//! reported (`stale-allow`), and neither can be suppressed.
//!
//! ## Baseline workflow
//!
//! `make lint` runs in baseline mode: findings recorded in the
//! checked-in `src/lint/baseline.json` are reported as *known* and do
//! not fail the build, so only regressions block. `make lint-strict`
//! fails on any finding; `make lint-baseline` regenerates the baseline
//! (sorted, deterministic) after an intentional change. The swept tree
//! keeps an empty baseline — new findings must be fixed or suppressed
//! with a reasoned annotation, not baselined, unless a rule rollout
//! needs staging.
//!
//! ```text
//! cargo run --release --bin flashlint -- rust/src
//! cargo run --release --bin flashlint -- --json rust/src
//! cargo run --release --bin flashlint -- --baseline rust/src/lint/baseline.json rust/src
//! cargo run --release --bin flashlint -- --write-baseline rust/src/lint/baseline.json rust/src
//! ```
//!
//! Exit code 0 = clean, 1 = unsuppressed findings, 2 = usage/IO error.

pub mod callgraph;
pub mod rules;
pub mod tokenizer;

use crate::jsonlite::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Rule registry: (name, one-line summary, fix hint).
pub const RULES: &[(&str, &str, &str)] = &[
    (
        "lock-unwrap",
        "lock result unwrapped in the serving core",
        "use util::sync wrappers: lock_recover()/read_recover()/write_recover()",
    ),
    (
        "raw-sync",
        "raw std::sync lock outside the util::sync shim",
        "construct locks via util::sync::{Mutex,RwLock}::new(\"module.role\", value)",
    ),
    (
        "io-under-lock",
        "file/socket I/O inside a lock-guard live range",
        "copy the data out, drop the guard, then do the I/O (or scope the guard in a block)",
    ),
    (
        "nonfinite-persist",
        "factor floats persisted without a finiteness guard",
        "call entry_is_finite()/is_finite() before serializing, and skip or reject non-finite factors",
    ),
    (
        "hot-path-panic",
        "panic site reachable from the serving hot path",
        "return a typed error (or prove the invariant and add a flashlint allow annotation with the proof)",
    ),
    (
        "bad-allow",
        "malformed flashlint allow annotation",
        "use `// flashlint: allow(rule) reason`, allow-fn(...) or allow-file(...); the reason is mandatory",
    ),
    (
        "alloc-in-hotpath",
        "heap allocation reachable from a decode/kernel inner-loop root",
        "reuse a thread-local scratch buffer (see kernels::DECODE_SCRATCH) or hoist the allocation; per-flush setup fns belong in hotpath.txt [scratch]",
    ),
    (
        "unordered-iteration",
        "HashMap/HashSet iteration feeding serving or persisted output",
        "switch the container to BTreeMap/BTreeSet (or collect and sort) so emission order is deterministic",
    ),
    (
        "uncapped-read",
        "socket/file read on a wire path without frame caps or timeouts",
        "route peer input through util::frame::read_frame_limited and call set_io_timeouts (connect_timeout) on every stream",
    ),
    (
        "dispatch-blocking",
        "blocking call reachable from the netserver dispatch thread",
        "use try_/timeout variants or move the work onto a worker; locks safe here must be listed in dispatch.txt [leaf-locks]",
    ),
    (
        "stale-allow",
        "flashlint allow annotation that no longer suppresses anything",
        "delete the annotation — the finding it justified is gone (or its rule/scope no longer matches)",
    ),
];

#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
    pub hint: &'static str,
}

#[derive(Debug, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
    pub files_scanned: usize,
    pub suppressed: usize,
    /// Findings matched by the baseline (only set in baseline mode).
    pub known: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }
}

/// A sectioned root manifest: `[section]` headers group one name per
/// line; `#` starts a comment (whole-line or trailing); lines before
/// the first header land in `default_section`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    sections: BTreeMap<String, Vec<String>>,
}

impl Manifest {
    pub fn parse(text: &str, default_section: &str) -> Self {
        let mut sections: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut cur = default_section.to_string();
        for line in text.lines() {
            let l = line.trim();
            let l = match l.find('#') {
                Some(0) => "",
                Some(p) => l[..p].trim_end(),
                None => l,
            };
            if l.is_empty() {
                continue;
            }
            if l.starts_with('[') && l.ends_with(']') {
                cur = l[1..l.len() - 1].trim().to_string();
                sections.entry(cur.clone()).or_default();
                continue;
            }
            sections.entry(cur.clone()).or_default().push(l.to_string());
        }
        Self { sections }
    }

    pub fn section(&self, name: &str) -> &[String] {
        self.sections
            .get(name)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }
}

#[derive(Clone, Debug)]
pub struct LintConfig {
    /// `[serving]` roots: R5 hot-path reachability, R8 serving scope.
    pub hotpath_roots: Vec<String>,
    /// `[inner]` roots: R7 decode/kernel inner-loop reachability.
    pub inner_roots: Vec<String>,
    /// `[scratch]` allowlist: per-flush setup fns whose own bodies may
    /// allocate (their callees stay in R7 scope).
    pub scratch_allow: Vec<String>,
    /// dispatch.txt `[roots]`: the dispatch thread's entry points.
    pub dispatch_roots: Vec<String>,
    /// dispatch.txt `[blocking]`: call names that block.
    pub blocking_fns: Vec<String>,
    /// dispatch.txt `[leaf-locks]`: receivers safe for non-try locking.
    pub leaf_locks: Vec<String>,
}

impl LintConfig {
    pub fn from_manifests(hotpath: &str, dispatch: &str) -> Self {
        let hp = Manifest::parse(hotpath, "serving");
        let dp = Manifest::parse(dispatch, "roots");
        Self {
            hotpath_roots: hp.section("serving").to_vec(),
            inner_roots: hp.section("inner").to_vec(),
            scratch_allow: hp.section("scratch").to_vec(),
            dispatch_roots: dp.section("roots").to_vec(),
            blocking_fns: dp.section("blocking").to_vec(),
            leaf_locks: dp.section("leaf-locks").to_vec(),
        }
    }
}

impl Default for LintConfig {
    fn default() -> Self {
        Self::from_manifests(
            default_hotpath_manifest(),
            default_dispatch_manifest(),
        )
    }
}

/// The checked-in hot-path manifest (`src/lint/hotpath.txt`).
pub fn default_hotpath_manifest() -> &'static str {
    include_str!("hotpath.txt")
}

/// The checked-in dispatch-thread manifest (`src/lint/dispatch.txt`).
pub fn default_dispatch_manifest() -> &'static str {
    include_str!("dispatch.txt")
}

/// Parse a hot-path manifest's `[serving]` roots (the pre-section
/// default, for backward compatibility with flat name-per-line files).
pub fn parse_hotpath(text: &str) -> Vec<String> {
    Manifest::parse(text, "serving").section("serving").to_vec()
}

fn hint_for(rule: &str) -> &'static str {
    RULES
        .iter()
        .find(|(name, _, _)| *name == rule)
        .map(|(_, _, hint)| *hint)
        .unwrap_or("")
}

/// Lint a set of `(path, contents)` pairs. R1–R4 and R9 run per file;
/// R5/R7/R8/R10 run over the whole set on the resolved call graph.
pub fn lint_sources(files: &[(String, String)], cfg: &LintConfig) -> Report {
    let analyses: Vec<rules::FileAnalysis> = files
        .iter()
        .map(|(path, src)| rules::analyze(path, src))
        .collect();

    let graph = callgraph::Graph::build(&analyses);

    let mut raw: Vec<(usize, rules::Finding)> = Vec::new();
    for (fi, fa) in analyses.iter().enumerate() {
        for f in rules::r1_lock_unwrap(fa) {
            raw.push((fi, f));
        }
        for f in rules::r2_raw_sync(fa) {
            raw.push((fi, f));
        }
        for f in rules::r3_io_under_lock(fa) {
            raw.push((fi, f));
        }
        for f in rules::r4_nonfinite_persist(fa) {
            raw.push((fi, f));
        }
        for f in rules::r9_uncapped_read(fa) {
            raw.push((fi, f));
        }
    }
    raw.extend(callgraph::hot_path_findings(&graph, &cfg.hotpath_roots));
    raw.extend(callgraph::alloc_findings(
        &graph,
        &cfg.inner_roots,
        &cfg.scratch_allow,
    ));
    raw.extend(callgraph::unordered_findings(&graph, &cfg.hotpath_roots));
    raw.extend(callgraph::dispatch_findings(
        &graph,
        &cfg.dispatch_roots,
        &cfg.blocking_fns,
        &cfg.leaf_locks,
    ));

    let mut report = Report {
        files_scanned: files.len(),
        ..Report::default()
    };
    // Which allows actually suppressed something (for stale-allow).
    let mut used: Vec<std::collections::BTreeSet<usize>> =
        analyses.iter().map(|_| Default::default()).collect();
    for (fi, f) in raw {
        let fa = &analyses[fi];
        // bad-allow is never suppressible; everything else honors allows.
        let matches = if f.rule == "bad-allow" {
            Vec::new()
        } else {
            rules::matching_allows(fa, f.rule, f.line)
        };
        if !matches.is_empty() {
            used[fi].extend(matches);
            report.suppressed += 1;
            continue;
        }
        report.diagnostics.push(Diagnostic {
            file: fa.path.clone(),
            line: f.line,
            rule: f.rule,
            message: f.message,
            hint: hint_for(f.rule),
        });
    }
    // Stale allows: annotations that suppressed nothing this run. Like
    // bad-allow, these cannot themselves be suppressed. Annotations in
    // test-masked regions are exempt (findings there are masked too).
    for (fi, fa) in analyses.iter().enumerate() {
        for (ai, a) in fa.allows.iter().enumerate() {
            if used[fi].contains(&ai) || rules::line_in_test(fa, a.line) {
                continue;
            }
            report.diagnostics.push(Diagnostic {
                file: fa.path.clone(),
                line: a.line,
                rule: "stale-allow",
                message: format!(
                    "allow({}) suppresses nothing — its scope contains no \
                     `{}` finding any more",
                    a.rule, a.rule
                ),
                hint: hint_for("stale-allow"),
            });
        }
    }
    // Malformed annotations are diagnostics too.
    for fa in &analyses {
        for f in &fa.bad_allows {
            report.diagnostics.push(Diagnostic {
                file: fa.path.clone(),
                line: f.line,
                rule: f.rule,
                message: f.message.clone(),
                hint: hint_for(f.rule),
            });
        }
    }
    report
        .diagnostics
        .sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    report
}

// ---------------------------------------------------------------------------
// Baseline: a checked-in set of known findings (`make lint` fails only
// on findings not in it; `make lint-strict` ignores it).
// ---------------------------------------------------------------------------

/// A known finding, keyed by (file, rule, message) — line numbers shift
/// too easily to key on.
pub type BaselineEntry = (String, String, String);

/// Serialize the report's findings as a deterministic (sorted) baseline.
pub fn render_baseline(report: &Report) -> String {
    let mut entries: Vec<&Diagnostic> = report.diagnostics.iter().collect();
    entries.sort_by(|a, b| {
        (&a.file, a.rule, &a.message, a.line)
            .cmp(&(&b.file, b.rule, &b.message, b.line))
    });
    let findings: Vec<Json> = entries
        .iter()
        .map(|d| {
            Json::obj(vec![
                ("file", Json::str(&d.file)),
                ("line", Json::num(d.line as f64)),
                ("rule", Json::str(d.rule)),
                ("message", Json::str(&d.message)),
            ])
        })
        .collect();
    Json::obj(vec![("findings", Json::Arr(findings))]).dump()
}

/// Parse a baseline file produced by [`render_baseline`].
pub fn parse_baseline(text: &str) -> Result<Vec<BaselineEntry>, String> {
    let j = Json::parse(text).map_err(|e| format!("invalid baseline: {e}"))?;
    let arr = j
        .get("findings")
        .as_arr()
        .ok_or_else(|| "baseline missing `findings` array".to_string())?;
    let mut out = Vec::new();
    for f in arr {
        let file = f.get("file").as_str().unwrap_or_default().to_string();
        let rule = f.get("rule").as_str().unwrap_or_default().to_string();
        let msg = f.get("message").as_str().unwrap_or_default().to_string();
        if file.is_empty() || rule.is_empty() {
            return Err("baseline entry missing file/rule".to_string());
        }
        out.push((file, rule, msg));
    }
    Ok(out)
}

/// Remove diagnostics matched by the baseline (multiset semantics:
/// each entry absorbs one finding). Returns how many were absorbed and
/// records it in `report.known`.
pub fn apply_baseline(report: &mut Report, base: &[BaselineEntry]) -> usize {
    let mut budget: BTreeMap<&BaselineEntry, usize> = BTreeMap::new();
    for e in base {
        *budget.entry(e).or_insert(0) += 1;
    }
    let mut kept = Vec::with_capacity(report.diagnostics.len());
    let mut absorbed = 0usize;
    for d in report.diagnostics.drain(..) {
        let key = (d.file.clone(), d.rule.to_string(), d.message.clone());
        match budget.iter_mut().find(|(k, n)| ***k == key && **n > 0) {
            Some((_, n)) => {
                *n -= 1;
                absorbed += 1;
            }
            None => kept.push(d),
        }
    }
    report.diagnostics = kept;
    report.known = absorbed;
    absorbed
}

/// Recursively collect `.rs` files under `root` (or `root` itself if it
/// is a file), skipping `vendor/`, `target/`, and hidden directories.
pub fn collect_rs_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(out);
    }
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .collect();
        entries.sort();
        for path in entries {
            let name = path
                .file_name()
                .and_then(|n| n.to_str())
                .unwrap_or("");
            if path.is_dir() {
                if name == "vendor" || name == "target" || name.starts_with('.')
                {
                    continue;
                }
                stack.push(path);
            } else if name.ends_with(".rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Human-readable rendering, one line per finding plus a summary.
pub fn render_text(report: &Report) -> String {
    let mut out = String::new();
    for d in &report.diagnostics {
        out.push_str(&format!(
            "{}:{}: [{}] {}\n    hint: {}\n",
            d.file, d.line, d.rule, d.message, d.hint
        ));
    }
    out.push_str(&format!(
        "flashlint: {} finding(s), {} known from baseline, {} suppressed, \
         {} file(s) scanned\n",
        report.diagnostics.len(),
        report.known,
        report.suppressed,
        report.files_scanned
    ));
    out
}

/// Machine-readable rendering (single JSON object).
pub fn render_json(report: &Report) -> String {
    let diags: Vec<Json> = report
        .diagnostics
        .iter()
        .map(|d| {
            Json::obj(vec![
                ("file", Json::str(&d.file)),
                ("line", Json::num(d.line as f64)),
                ("rule", Json::str(d.rule)),
                ("message", Json::str(&d.message)),
                ("hint", Json::str(d.hint)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("files_scanned", Json::num(report.files_scanned as f64)),
        ("suppressed", Json::num(report.suppressed as f64)),
        ("known_from_baseline", Json::num(report.known as f64)),
        ("violations", Json::num(report.diagnostics.len() as f64)),
        ("diagnostics", Json::Arr(diags)),
    ])
    .dump()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_one(path: &str, src: &str) -> Report {
        lint_sources(
            &[(path.to_string(), src.to_string())],
            &LintConfig::default(),
        )
    }

    #[test]
    fn manifest_parses_and_has_roots() {
        let roots = parse_hotpath(default_hotpath_manifest());
        assert!(roots.len() >= 10);
        assert!(roots.iter().any(|r| r == "serve_loop"));
        assert!(roots.iter().all(|r| !r.starts_with('#')));
    }

    #[test]
    fn manifest_sections_parse() {
        let m = Manifest::parse(
            "a\nb # trailing\n[two]\nc\n# comment\n[three]\n",
            "one",
        );
        assert_eq!(m.section("one"), ["a", "b"]);
        assert_eq!(m.section("two"), ["c"]);
        assert!(m.section("three").is_empty());
        assert!(m.section("missing").is_empty());
    }

    #[test]
    fn default_config_has_all_sections() {
        let cfg = LintConfig::default();
        assert!(cfg.inner_roots.iter().any(|r| r == "run_query_block"));
        assert!(cfg.scratch_allow.iter().any(|r| r == "decode_steps"));
        assert!(cfg
            .dispatch_roots
            .iter()
            .any(|r| r == "net_dispatch_loop"));
        assert!(cfg.blocking_fns.iter().any(|r| r == "sleep"));
        assert!(cfg.leaf_locks.iter().any(|r| r == "state"));
    }

    #[test]
    fn clean_file_is_clean() {
        let r = lint_one(
            "src/coordinator/mod.rs",
            "pub fn quiet() -> usize { 1 + 1 }",
        );
        assert!(r.clean(), "{:?}", r.diagnostics);
    }

    #[test]
    fn json_rendering_is_parseable() {
        let r = lint_one(
            "src/factorstore/x.rs",
            "fn f(m: &M) { m.lock().unwrap(); }",
        );
        assert_eq!(r.diagnostics.len(), 1);
        let j = crate::jsonlite::Json::parse(&render_json(&r))
            .expect("valid json");
        assert_eq!(j.get("violations").as_usize(), Some(1));
        let d = &j.get("diagnostics").as_arr().expect("arr")[0];
        assert_eq!(d.get("rule").as_str(), Some("lock-unwrap"));
    }

    #[test]
    fn baseline_roundtrip_absorbs_known_findings() {
        let mut r = lint_one(
            "src/factorstore/x.rs",
            "fn f(m: &M) { m.lock().unwrap(); }",
        );
        assert_eq!(r.diagnostics.len(), 1);
        let text = render_baseline(&r);
        let base = parse_baseline(&text).expect("baseline parses");
        assert_eq!(base.len(), 1);
        let absorbed = apply_baseline(&mut r, &base);
        assert_eq!(absorbed, 1);
        assert!(r.clean());
        assert_eq!(r.known, 1);
        // A fresh (different) finding is NOT absorbed.
        let mut r2 = lint_one(
            "src/factorstore/y.rs",
            "fn g(m: &M) { m.write().unwrap(); }",
        );
        let absorbed = apply_baseline(&mut r2, &base);
        assert_eq!(absorbed, 0);
        assert_eq!(r2.diagnostics.len(), 1);
    }

    #[test]
    fn empty_baseline_parses() {
        let base = parse_baseline("{\"findings\":[]}").expect("parses");
        assert!(base.is_empty());
    }
}
