//! Per-file analysis and the lexical rules R1–R4.
//!
//! Everything here works on the token stream from
//! [`super::tokenizer`]: brace matching gives block structure, a scan
//! for `fn` gives function spans, `#[cfg(test)]` / `#[test]` regions
//! are masked out, and each rule is a small pattern matcher over token
//! windows. R5 (hot-path reachability) lives in [`super::callgraph`].

use super::tokenizer::{is_ident, is_punct, tokenize, Comment, Tok, TokKind};

/// A raw rule hit, before suppression is applied.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub line: u32,
    pub message: String,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllowForm {
    /// Suppresses on the annotation's line and the following line.
    Line,
    /// Suppresses within the enclosing function span.
    Fn,
    /// Suppresses for the whole file.
    File,
}

#[derive(Clone, Debug)]
pub struct Allow {
    pub form: AllowForm,
    pub rule: String,
    pub line: u32,
}

/// A `fn` item: token span of its body plus source lines.
#[derive(Clone, Debug)]
pub struct FnSpan {
    pub name: String,
    /// Impl target type the fn is a method of (`None` for free fns).
    pub owner: Option<String>,
    /// Token index of the `fn` keyword.
    pub kw: usize,
    pub body_open: usize,
    pub body_close: usize,
    pub start_line: u32,
    pub end_line: u32,
    pub is_test: bool,
}

pub struct FileAnalysis {
    pub path: String,
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
    pub allows: Vec<Allow>,
    pub bad_allows: Vec<Finding>,
    /// Per-token: true if inside a `#[cfg(test)]` mod/fn or `#[test]` fn.
    pub test_mask: Vec<bool>,
    /// Source line ranges covered by the test mask (for comment checks).
    pub test_line_ranges: Vec<(u32, u32)>,
    pub fn_spans: Vec<FnSpan>,
    /// `impl` headers in the file: `(trait name if any, target type)`.
    pub impl_decls: Vec<(Option<String>, String)>,
    /// Per-token: index of the matching `}` of the innermost enclosing
    /// `{` (None at top level).
    pub enclosing_close: Vec<Option<usize>>,
}

const SYNC_TYPES: &[&str] = &[
    "Mutex",
    "RwLock",
    "MutexGuard",
    "RwLockReadGuard",
    "RwLockWriteGuard",
];

const LOCK_ACQUIRE: &[&str] = &[
    "lock",
    "try_lock",
    "read",
    "try_read",
    "write",
    "try_write",
    "lock_recover",
    "try_lock_recover",
    "read_recover",
    "write_recover",
];

const IO_METHODS: &[&str] = &[
    "write_all",
    "read_exact",
    "flush",
    "seek",
    "sync_all",
    "set_len",
    "read_to_string",
    "read_to_end",
];

const IO_TYPES: &[&str] = &["File", "OpenOptions", "TcpStream", "TcpListener"];

const FS_FNS: &[&str] = &[
    "write",
    "read",
    "read_to_string",
    "rename",
    "remove_file",
    "copy",
    "create_dir_all",
    "remove_dir_all",
];

/// Serializer entry points that persist factor floats (R4).
const PERSIST_FNS: &[&str] = &["entry_to_json", "f32s_to_json"];

pub const KEYWORDS: &[&str] = &[
    "as", "box", "break", "const", "continue", "crate", "dyn", "else",
    "enum", "extern", "fn", "for", "if", "impl", "in", "let", "loop",
    "match", "mod", "move", "mut", "pub", "ref", "return", "self", "Self",
    "static", "struct", "super", "trait", "type", "unsafe", "use", "where",
    "while",
];

pub fn is_rule_name(name: &str) -> bool {
    matches!(
        name,
        "lock-unwrap"
            | "raw-sync"
            | "io-under-lock"
            | "nonfinite-persist"
            | "hot-path-panic"
            | "alloc-in-hotpath"
            | "unordered-iteration"
            | "uncapped-read"
            | "dispatch-blocking"
    )
}

/// Normalize a path for scope checks (`\` → `/`).
pub(crate) fn norm(path: &str) -> String {
    path.replace('\\', "/")
}

fn in_scope(path: &str, dirs: &[&str]) -> bool {
    let p = norm(path);
    dirs.iter().any(|d| p.contains(d))
}

pub fn analyze(path: &str, src: &str) -> FileAnalysis {
    let (toks, comments) = tokenize(src);
    let n = toks.len();

    // --- brace matching -----------------------------------------------------
    // open_match[i] = index of the `}` closing the `{` at i.
    let mut open_match: Vec<Option<usize>> = vec![None; n];
    let mut enclosing_open: Vec<Option<usize>> = vec![None; n];
    {
        let mut stack: Vec<usize> = Vec::new();
        for i in 0..n {
            if is_punct(&toks[i], '}') {
                enclosing_open[i] = stack.last().copied();
                if let Some(open) = stack.pop() {
                    open_match[open] = Some(i);
                }
            } else {
                enclosing_open[i] = stack.last().copied();
                if is_punct(&toks[i], '{') {
                    stack.push(i);
                }
            }
        }
    }
    let enclosing_close: Vec<Option<usize>> = (0..n)
        .map(|i| enclosing_open[i].and_then(|o| open_match[o]))
        .collect();

    // --- test regions -------------------------------------------------------
    let mut test_mask = vec![false; n];
    let mut test_line_ranges: Vec<(u32, u32)> = Vec::new();
    let mut i = 0usize;
    while i + 2 < n {
        // #[cfg(test)] or #[test]
        if is_punct(&toks[i], '#') && is_punct(&toks[i + 1], '[') {
            let is_cfg_test = i + 6 < n
                && is_ident(&toks[i + 2], "cfg")
                && is_punct(&toks[i + 3], '(')
                && is_ident(&toks[i + 4], "test")
                && is_punct(&toks[i + 5], ')')
                && is_punct(&toks[i + 6], ']');
            let is_test_attr = i + 3 < n
                && is_ident(&toks[i + 2], "test")
                && is_punct(&toks[i + 3], ']');
            if is_cfg_test || is_test_attr {
                // Find the end of this attribute, then skip any further
                // attributes, then mask the following mod/fn body.
                let mut j = skip_attr(&toks, i);
                while j + 1 < n
                    && is_punct(&toks[j], '#')
                    && is_punct(&toks[j + 1], '[')
                {
                    j = skip_attr(&toks, j);
                }
                // Scan to the item's opening brace (mod/fn/impl...).
                let mut k = j;
                while k < n
                    && !is_punct(&toks[k], '{')
                    && !is_punct(&toks[k], ';')
                {
                    k += 1;
                }
                if k < n && is_punct(&toks[k], '{') {
                    if let Some(close) = open_match[k] {
                        for t in test_mask.iter_mut().take(close + 1).skip(i) {
                            *t = true;
                        }
                        test_line_ranges
                            .push((toks[i].line, toks[close].line));
                        i = close + 1;
                        continue;
                    }
                }
            }
        }
        i += 1;
    }

    // --- impl regions -------------------------------------------------------
    // impl_owner[tok] = target type of the innermost enclosing `impl`
    // block, so fn spans carry their receiver type and the callgraph can
    // distinguish same-named methods on different impls.
    let mut impl_owner: Vec<Option<String>> = vec![None; n];
    let mut impl_decls: Vec<(Option<String>, String)> = Vec::new();
    let mut i = 0usize;
    while i < n {
        if !is_ident(&toks[i], "impl") {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        // Skip the generic parameter list `impl<...>`.
        if j < n && is_punct(&toks[j], '<') {
            let mut depth = 0i32;
            while j < n {
                if is_punct(&toks[j], '<') {
                    depth += 1;
                } else if is_punct(&toks[j], '>') {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        // Read path segments up to `{`; in `impl Trait for Type` the
        // segments before `for` name the trait, after it the target.
        let mut ty: Option<String> = None;
        let mut trait_name: Option<String> = None;
        while j < n && !is_punct(&toks[j], '{') && !is_punct(&toks[j], ';') {
            if is_ident(&toks[j], "where") {
                while j < n && !is_punct(&toks[j], '{') {
                    j += 1;
                }
                break;
            }
            if is_ident(&toks[j], "for") {
                trait_name = ty.take();
                j += 1;
                continue;
            }
            if toks[j].kind == TokKind::Ident
                && !matches!(toks[j].text.as_str(), "dyn" | "mut" | "const")
            {
                ty = Some(toks[j].text.clone());
            }
            if is_punct(&toks[j], '<') {
                let mut depth = 0i32;
                while j < n {
                    if is_punct(&toks[j], '<') {
                        depth += 1;
                    } else if is_punct(&toks[j], '>') {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    j += 1;
                }
            }
            j += 1;
        }
        if j < n && is_punct(&toks[j], '{') {
            if let (Some(ty), Some(close)) = (ty, open_match[j]) {
                for slot in impl_owner.iter_mut().take(close + 1).skip(j) {
                    *slot = Some(ty.clone());
                }
                impl_decls.push((trait_name, ty));
            }
        }
        i = j.max(i + 1);
    }

    // --- fn spans -----------------------------------------------------------
    let mut fn_spans: Vec<FnSpan> = Vec::new();
    for i in 0..n {
        if !is_ident(&toks[i], "fn") {
            continue;
        }
        let Some(name_tok) = toks.get(i + 1) else { continue };
        if name_tok.kind != TokKind::Ident {
            continue;
        }
        // Walk to the body `{` (or `;` for bodiless decls).
        let mut k = i + 2;
        let mut body_open = None;
        while k < n {
            if is_punct(&toks[k], '{') {
                body_open = Some(k);
                break;
            }
            if is_punct(&toks[k], ';') {
                break;
            }
            k += 1;
        }
        let Some(open) = body_open else { continue };
        let Some(close) = open_match[open] else { continue };
        fn_spans.push(FnSpan {
            name: name_tok.text.clone(),
            owner: impl_owner[i].clone(),
            kw: i,
            body_open: open,
            body_close: close,
            start_line: toks[i].line,
            end_line: toks[close].line,
            is_test: test_mask[i],
        });
    }

    // --- allow annotations --------------------------------------------------
    let mut allows = Vec::new();
    let mut bad_allows = Vec::new();
    for c in &comments {
        parse_allow(c, &mut allows, &mut bad_allows);
    }

    FileAnalysis {
        path: path.to_string(),
        toks,
        comments,
        allows,
        bad_allows,
        test_mask,
        test_line_ranges,
        fn_spans,
        impl_decls,
        enclosing_close,
    }
}

/// Is `line` inside a `#[cfg(test)]`/`#[test]` region? Used to exempt
/// annotations that only cover test code from the stale-allow check.
pub fn line_in_test(fa: &FileAnalysis, line: u32) -> bool {
    fa.test_line_ranges
        .iter()
        .any(|&(lo, hi)| lo <= line && line <= hi)
}

/// Skip one `#[...]` attribute starting at the `#`; returns the index
/// just past its closing `]`.
fn skip_attr(toks: &[Tok], at: usize) -> usize {
    let mut depth = 0usize;
    let mut j = at + 1;
    while j < toks.len() {
        if is_punct(&toks[j], '[') {
            depth += 1;
        } else if is_punct(&toks[j], ']') {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
        j += 1;
    }
    toks.len()
}

/// Parse a `// flashlint: allow*(rule) reason` annotation. Doc comments
/// (`///`, `//!`) are prose and never parsed.
fn parse_allow(c: &Comment, allows: &mut Vec<Allow>, bad: &mut Vec<Finding>) {
    let body = match c.text.strip_prefix("//") {
        Some(rest) => rest,
        None => return, // block comment: not an annotation carrier
    };
    if body.starts_with('/') || body.starts_with('!') {
        return; // doc comment
    }
    let body = body.trim_start();
    let Some(rest) = body.strip_prefix("flashlint:") else {
        return;
    };
    let rest = rest.trim_start();
    let (form, rest) = if let Some(r) = rest.strip_prefix("allow-fn") {
        (AllowForm::Fn, r)
    } else if let Some(r) = rest.strip_prefix("allow-file") {
        (AllowForm::File, r)
    } else if let Some(r) = rest.strip_prefix("allow") {
        (AllowForm::Line, r)
    } else {
        bad.push(Finding {
            rule: "bad-allow",
            line: c.line,
            message: format!(
                "malformed flashlint annotation (expected \
                 allow/allow-fn/allow-file): `{}`",
                c.text.trim()
            ),
        });
        return;
    };
    let rest = rest.trim_start();
    let ok = rest.strip_prefix('(').and_then(|r| {
        r.split_once(')')
            .map(|(rule, reason)| (rule.trim().to_string(), reason.trim()))
    });
    let Some((rule, reason)) = ok else {
        bad.push(Finding {
            rule: "bad-allow",
            line: c.line,
            message: format!(
                "malformed flashlint annotation (missing `(rule)`): `{}`",
                c.text.trim()
            ),
        });
        return;
    };
    if !is_rule_name(&rule) {
        bad.push(Finding {
            rule: "bad-allow",
            line: c.line,
            message: format!("unknown flashlint rule `{rule}` in annotation"),
        });
        return;
    }
    if reason.is_empty() {
        bad.push(Finding {
            rule: "bad-allow",
            line: c.line,
            message: format!(
                "flashlint allow({rule}) requires a reason after the \
                 closing paren"
            ),
        });
        return;
    }
    allows.push(Allow {
        form,
        rule,
        line: c.line,
    });
}

/// Indices of the file's allows that suppress a finding of `rule` at
/// `line`. Every matching allow is returned so stale-allow accounting
/// can credit each one.
pub fn matching_allows(fa: &FileAnalysis, rule: &str, line: u32) -> Vec<usize> {
    fa.allows
        .iter()
        .enumerate()
        .filter(|(_, a)| {
            if a.rule != rule {
                return false;
            }
            match a.form {
                AllowForm::Line => a.line == line || a.line + 1 == line,
                AllowForm::File => true,
                AllowForm::Fn => fa.fn_spans.iter().any(|s| {
                    s.start_line <= a.line
                        && a.line <= s.end_line
                        && s.start_line <= line
                        && line <= s.end_line
                }),
            }
        })
        .map(|(i, _)| i)
        .collect()
}

/// Is the finding at `line` suppressed by one of the file's allows?
pub fn is_suppressed(fa: &FileAnalysis, rule: &str, line: u32) -> bool {
    !matching_allows(fa, rule, line).is_empty()
}

// ---------------------------------------------------------------------------
// R1: lock().unwrap() — poison cascade
// ---------------------------------------------------------------------------

pub fn r1_lock_unwrap(fa: &FileAnalysis) -> Vec<Finding> {
    let mut out = Vec::new();
    if !in_scope(
        &fa.path,
        &["coordinator/", "server/", "factorstore/", "runtime/"],
    ) {
        return out;
    }
    let t = &fa.toks;
    for i in 1..t.len() {
        if fa.test_mask[i] {
            continue;
        }
        if t[i].kind == TokKind::Ident
            && LOCK_ACQUIRE.contains(&t[i].text.as_str())
            && is_punct(&t[i - 1], '.')
            && i + 5 < t.len()
            && is_punct(&t[i + 1], '(')
            && is_punct(&t[i + 2], ')')
            && is_punct(&t[i + 3], '.')
            && (is_ident(&t[i + 4], "unwrap") || is_ident(&t[i + 4], "expect"))
            && is_punct(&t[i + 5], '(')
        {
            out.push(Finding {
                rule: "lock-unwrap",
                line: t[i].line,
                message: format!(
                    "`.{}().{}()` on a lock result: one panicked holder \
                     poisons the lock and cascades through the serving loop",
                    t[i].text,
                    t[i + 4].text
                ),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// R2: raw std::sync lock usage outside util::sync
// ---------------------------------------------------------------------------

pub fn r2_raw_sync(fa: &FileAnalysis) -> Vec<Finding> {
    let mut out = Vec::new();
    if norm(&fa.path).ends_with("util/sync.rs") {
        return out;
    }
    let t = &fa.toks;
    let n = t.len();
    let mut i = 0usize;
    while i < n {
        if fa.test_mask[i] {
            i += 1;
            continue;
        }
        // `use ... ;` statements naming std::sync lock types.
        if is_ident(&t[i], "use") {
            let mut j = i + 1;
            let (mut has_std, mut has_sync, mut sync_ty) =
                (false, false, None::<&str>);
            while j < n && !is_punct(&t[j], ';') {
                if is_ident(&t[j], "std") {
                    has_std = true;
                } else if is_ident(&t[j], "sync") {
                    has_sync = true;
                } else if t[j].kind == TokKind::Ident {
                    if let Some(ty) =
                        SYNC_TYPES.iter().find(|ty| t[j].text == **ty)
                    {
                        sync_ty = Some(ty);
                    }
                }
                j += 1;
            }
            if has_std && has_sync {
                if let Some(ty) = sync_ty {
                    out.push(Finding {
                        rule: "raw-sync",
                        line: t[i].line,
                        message: format!(
                            "import of raw `std::sync::{ty}` — serving-core \
                             locks must go through the util::sync shim"
                        ),
                    });
                }
            }
            i = j;
            continue;
        }
        // Inline qualified path std::sync::Mutex etc.
        if i + 5 < n
            && is_ident(&t[i], "std")
            && is_punct(&t[i + 1], ':')
            && is_punct(&t[i + 2], ':')
            && is_ident(&t[i + 3], "sync")
            && is_punct(&t[i + 4], ':')
            && is_punct(&t[i + 5], ':')
            && i + 6 < n
            && SYNC_TYPES.contains(&t[i + 6].text.as_str())
        {
            out.push(Finding {
                rule: "raw-sync",
                line: t[i].line,
                message: format!(
                    "raw `std::sync::{}` path — serving-core locks must go \
                     through the util::sync shim",
                    t[i + 6].text
                ),
            });
            i += 7;
            continue;
        }
        // Mutex::new(<non-literal>): either a raw std lock brought in by
        // a `use`, or a util::sync wrapper missing its audit name.
        if i + 3 < n
            && (is_ident(&t[i], "Mutex") || is_ident(&t[i], "RwLock"))
            && is_punct(&t[i + 1], ':')
            && is_punct(&t[i + 2], ':')
            && is_ident(&t[i + 3], "new")
            && i + 4 < n
            && is_punct(&t[i + 4], '(')
            && t.get(i + 5).map(|tk| tk.kind != TokKind::Str).unwrap_or(true)
        {
            out.push(Finding {
                rule: "raw-sync",
                line: t[i].line,
                message: format!(
                    "`{}::new` without a name literal — use \
                     util::sync::{}::new(\"module.role\", value)",
                    t[i].text, t[i].text
                ),
            });
            i += 5;
            continue;
        }
        i += 1;
    }
    out
}

// ---------------------------------------------------------------------------
// R3: I/O lexically inside a lock-guard live range (whole crate)
// ---------------------------------------------------------------------------

pub fn r3_io_under_lock(fa: &FileAnalysis) -> Vec<Finding> {
    let mut out = Vec::new();
    if norm(&fa.path).ends_with("util/sync.rs") {
        // The shim itself wraps acquire calls; it performs no I/O.
        return out;
    }
    let t = &fa.toks;
    let n = t.len();
    let mut flagged: std::collections::BTreeSet<usize> =
        std::collections::BTreeSet::new();
    for i in 1..n {
        if fa.test_mask[i] {
            continue;
        }
        // A guard acquisition: `.lock_recover()`, `.read()`, ... (no-arg).
        let acquire = t[i].kind == TokKind::Ident
            && LOCK_ACQUIRE.contains(&t[i].text.as_str())
            && is_punct(&t[i - 1], '.')
            && i + 2 < n
            && is_punct(&t[i + 1], '(')
            && is_punct(&t[i + 2], ')');
        if !acquire {
            continue;
        }
        // Statement start: token after the previous `;`/`{`/`}`.
        let mut stmt_start = i;
        while stmt_start > 0 {
            let p = &t[stmt_start - 1];
            if is_punct(p, ';') || is_punct(p, '{') || is_punct(p, '}') {
                break;
            }
            stmt_start -= 1;
        }
        let let_bound =
            (stmt_start..i).any(|k| is_ident(&t[k], "let"));
        let mut range_end = if let_bound {
            // Guard lives to the end of the enclosing block...
            fa.enclosing_close[i].unwrap_or(n - 1)
        } else {
            // ...or, for a temporary, to the end of the statement.
            let mut depth = 0i32;
            let mut k = i + 3;
            loop {
                if k >= n {
                    break n - 1;
                }
                if is_punct(&t[k], '{') {
                    depth += 1;
                } else if is_punct(&t[k], '}') {
                    depth -= 1;
                    if depth < 0 {
                        break k;
                    }
                } else if is_punct(&t[k], ';') && depth == 0 {
                    break k;
                }
                k += 1;
            }
        };
        // ...unless it is dropped early.
        if let_bound {
            let name = (stmt_start..i)
                .find(|&k| is_ident(&t[k], "let"))
                .and_then(|k| {
                    (k + 1..i).find(|&m| {
                        t[m].kind == TokKind::Ident && t[m].text != "mut"
                    })
                })
                .map(|m| t[m].text.clone());
            if let Some(name) = name {
                for k in i..range_end.min(n.saturating_sub(3)) {
                    if is_ident(&t[k], "drop")
                        && is_punct(&t[k + 1], '(')
                        && is_ident(&t[k + 2], &name)
                        && is_punct(&t[k + 3], ')')
                    {
                        range_end = k;
                        break;
                    }
                }
            }
        }
        // Scan the live range for I/O markers.
        for k in (i + 3)..range_end.min(n) {
            if fa.test_mask[k] || t[k].kind != TokKind::Ident {
                continue;
            }
            let txt = t[k].text.as_str();
            let io_method = IO_METHODS.contains(&txt)
                && k > 0
                && is_punct(&t[k - 1], '.');
            let io_type = IO_TYPES.contains(&txt)
                && k + 2 < n
                && is_punct(&t[k + 1], ':')
                && is_punct(&t[k + 2], ':');
            let fs_call = txt == "fs"
                && k + 3 < n
                && is_punct(&t[k + 1], ':')
                && is_punct(&t[k + 2], ':')
                && FS_FNS.contains(&t[k + 3].text.as_str());
            if io_method || io_type || fs_call {
                if flagged.insert(k) {
                    out.push(Finding {
                        rule: "io-under-lock",
                        line: t[k].line,
                        message: format!(
                            "`{}` inside the live range of the lock guard \
                             acquired via `.{}()` on line {} — file/socket \
                             I/O under a lock stalls every other holder",
                            txt, t[i].text, t[i].line
                        ),
                    });
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// R4: persisting factor floats without a finiteness guard (factorstore/)
// ---------------------------------------------------------------------------

pub fn r4_nonfinite_persist(fa: &FileAnalysis) -> Vec<Finding> {
    let mut out = Vec::new();
    if !in_scope(&fa.path, &["factorstore/"]) {
        return out;
    }
    let t = &fa.toks;
    for i in 0..t.len() {
        if fa.test_mask[i] {
            continue;
        }
        let is_call = t[i].kind == TokKind::Ident
            && PERSIST_FNS.contains(&t[i].text.as_str())
            && i + 1 < t.len()
            && is_punct(&t[i + 1], '(')
            && !(i > 0 && is_ident(&t[i - 1], "fn"));
        if !is_call {
            continue;
        }
        let span = innermost_fn(fa, i);
        let guarded = span
            .map(|s| {
                (s.body_open..=s.body_close).any(|k| {
                    is_ident(&t[k], "entry_is_finite")
                        || is_ident(&t[k], "is_finite")
                })
            })
            .unwrap_or(false);
        if !guarded {
            out.push(Finding {
                rule: "nonfinite-persist",
                line: t[i].line,
                message: format!(
                    "`{}` serializes factor floats but the enclosing \
                     function never checks finiteness — NaN/Inf factors \
                     must not reach the persisted store",
                    t[i].text
                ),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// R9: socket/file reads on wire paths outside the frame codec's caps
// ---------------------------------------------------------------------------

/// Raw byte-read methods that bypass the frame codec's length cap.
const RAW_READS: &[&str] = &["read_exact", "read_to_end", "read_to_string"];

pub fn r9_uncapped_read(fa: &FileAnalysis) -> Vec<Finding> {
    let mut out = Vec::new();
    let p = norm(&fa.path);
    if p.ends_with("util/frame.rs") {
        // The codec itself is the one place raw reads are allowed: it
        // enforces MAX_FRAME_BYTES / read_frame_limited caps.
        return out;
    }
    let t = &fa.toks;
    let n = t.len();
    // Only files on a wire path are in scope: anything touching the
    // shared frame codec.
    let wire = t.iter().enumerate().any(|(i, tk)| {
        !fa.test_mask[i]
            && tk.kind == TokKind::Ident
            && matches!(
                tk.text.as_str(),
                "read_frame" | "read_frame_limited" | "write_frame"
            )
    });
    if !wire {
        return out;
    }
    for i in 0..n {
        if fa.test_mask[i] || t[i].kind != TokKind::Ident {
            continue;
        }
        // (a) raw byte reads on a wire path.
        if RAW_READS.contains(&t[i].text.as_str())
            && i > 0
            && is_punct(&t[i - 1], '.')
            && i + 1 < n
            && is_punct(&t[i + 1], '(')
        {
            out.push(Finding {
                rule: "uncapped-read",
                line: t[i].line,
                message: format!(
                    "`.{}()` on a wire path reads without a length cap — \
                     route peer input through util::frame::\
                     read_frame_limited",
                    t[i].text
                ),
            });
        }
        // (b) `TcpStream::connect` without a timeout.
        if is_ident(&t[i], "TcpStream")
            && i + 4 < n
            && is_punct(&t[i + 1], ':')
            && is_punct(&t[i + 2], ':')
            && is_ident(&t[i + 3], "connect")
            && is_punct(&t[i + 4], '(')
        {
            out.push(Finding {
                rule: "uncapped-read",
                line: t[i].line,
                message: "`TcpStream::connect` on a wire path can hang \
                          forever — use connect_timeout and then \
                          set_io_timeouts"
                    .to_string(),
            });
        }
    }
    // (c) a fn that obtains a stream and does frame/byte I/O on it must
    // bound that I/O with set_io_timeouts.
    for s in &fa.fn_spans {
        if s.is_test {
            continue;
        }
        let (mut obtains, mut io, mut timeouts) = (false, false, false);
        for k in s.body_open..=s.body_close {
            if fa.test_mask[k] || t[k].kind != TokKind::Ident {
                continue;
            }
            let followed_by_call =
                k + 1 < n && is_punct(&t[k + 1], '(');
            match t[k].text.as_str() {
                "accept" | "connect_timeout" | "incoming"
                    if followed_by_call
                        && k > 0
                        && (is_punct(&t[k - 1], '.')
                            || is_punct(&t[k - 1], ':')) =>
                {
                    obtains = true
                }
                "read_frame" | "read_frame_limited" | "write_frame"
                    if followed_by_call =>
                {
                    io = true
                }
                "read_exact" | "read_to_end" | "write_all"
                    if followed_by_call && k > 0 && is_punct(&t[k - 1], '.') =>
                {
                    io = true
                }
                "set_io_timeouts" => timeouts = true,
                _ => {}
            }
        }
        if obtains && io && !timeouts {
            out.push(Finding {
                rule: "uncapped-read",
                line: s.start_line,
                message: format!(
                    "fn `{}` obtains a socket and does wire I/O on it \
                     without `set_io_timeouts` — a stalled peer pins this \
                     thread forever",
                    s.name
                ),
            });
        }
    }
    out
}

/// Innermost `fn` span whose body contains token `i`.
pub fn innermost_fn(fa: &FileAnalysis, i: usize) -> Option<&FnSpan> {
    fa.fn_spans
        .iter()
        .filter(|s| s.body_open < i && i < s.body_close)
        .min_by_key(|s| s.body_close - s.body_open)
}

/// Bare call sites in a token range: identifiers immediately followed by
/// `(` that are neither definitions, keywords, nor macro invocations.
pub fn calls_in_range(
    fa: &FileAnalysis,
    from: usize,
    to: usize,
) -> Vec<String> {
    let t = &fa.toks;
    let mut out = Vec::new();
    for i in from..to.min(t.len().saturating_sub(1)) {
        if t[i].kind != TokKind::Ident {
            continue;
        }
        if KEYWORDS.contains(&t[i].text.as_str()) {
            continue;
        }
        if i > 0 && is_ident(&t[i - 1], "fn") {
            continue;
        }
        if is_punct(&t[i + 1], '(') {
            out.push(t[i].text.clone());
        }
    }
    out
}
