//! R5: panic sites reachable from the serving hot path.
//!
//! flashlint has no type information, so the call graph is name-level:
//! an identifier followed by `(` inside a function body is an edge from
//! that function's *name* to the callee's *name*. Reachability is then
//! a BFS over names, seeded by the checked-in hot-path manifest
//! (`src/lint/hotpath.txt`). This over-approximates — a call to
//! `x.get(…)` reaches every repo function named `get` — which is the
//! right bias for a safety net: everything the serving loop *could*
//! reach must be panic-free or carry an annotated justification.

use super::rules::{calls_in_range, FileAnalysis, Finding};
use super::tokenizer::{is_ident, is_punct, TokKind};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Macros that are always a panic at runtime.
const PANIC_MACROS: &[&str] = &["panic", "todo", "unimplemented"];

/// Compute R5 findings across all files. Returns `(file_index, finding)`
/// pairs so the caller can route them through per-file suppression.
pub fn hot_path_findings(
    files: &[FileAnalysis],
    roots: &[String],
) -> Vec<(usize, Finding)> {
    // name -> [(file idx, span idx)] over non-test fns.
    let mut by_name: BTreeMap<&str, Vec<(usize, usize)>> = BTreeMap::new();
    for (fi, fa) in files.iter().enumerate() {
        for (si, span) in fa.fn_spans.iter().enumerate() {
            if !span.is_test {
                by_name.entry(span.name.as_str()).or_default().push((fi, si));
            }
        }
    }

    // BFS over fn names; remember which caller first reached each name.
    let mut reached_via: BTreeMap<String, String> = BTreeMap::new();
    let mut queue: VecDeque<String> = VecDeque::new();
    for r in roots {
        if by_name.contains_key(r.as_str())
            && !reached_via.contains_key(r.as_str())
        {
            reached_via.insert(r.clone(), "<hot-path manifest>".to_string());
            queue.push_back(r.clone());
        }
    }
    let mut visited_spans: BTreeSet<(usize, usize)> = BTreeSet::new();
    while let Some(name) = queue.pop_front() {
        let Some(sites) = by_name.get(name.as_str()) else { continue };
        for &(fi, si) in sites {
            if !visited_spans.insert((fi, si)) {
                continue;
            }
            let fa = &files[fi];
            let span = &fa.fn_spans[si];
            for callee in span_calls(fa, si) {
                if by_name.contains_key(callee.as_str())
                    && !reached_via.contains_key(&callee)
                {
                    reached_via.insert(callee.clone(), name.clone());
                    queue.push_back(callee);
                }
            }
        }
    }

    // Scan every reached span for panic sites.
    let mut out = Vec::new();
    for &(fi, si) in &visited_spans {
        let fa = &files[fi];
        let span = &fa.fn_spans[si];
        let t = &fa.toks;
        for i in span.body_open..=span.body_close {
            if fa.test_mask[i] || t[i].kind != TokKind::Ident {
                continue;
            }
            // Only sites attributed to this span, not a nested fn.
            if let Some(inner) = super::rules::innermost_fn(fa, i) {
                if inner.kw != span.kw {
                    continue;
                }
            }
            let site = if (is_ident(&t[i], "unwrap")
                || is_ident(&t[i], "expect"))
                && i > 0
                && is_punct(&t[i - 1], '.')
                && i + 1 < t.len()
                && is_punct(&t[i + 1], '(')
            {
                Some(format!(".{}()", t[i].text))
            } else if PANIC_MACROS.contains(&t[i].text.as_str())
                && i + 1 < t.len()
                && is_punct(&t[i + 1], '!')
            {
                Some(format!("{}!", t[i].text))
            } else {
                None
            };
            if let Some(site) = site {
                let via = chain(&reached_via, &span.name);
                out.push((
                    fi,
                    Finding {
                        rule: "hot-path-panic",
                        line: t[i].line,
                        message: format!(
                            "`{site}` in fn `{}`, reachable from the \
                             serving hot path ({via})",
                            span.name
                        ),
                    },
                ));
            }
        }
    }
    out
}

/// Call sites attributed to span `si` (excluding nested fn bodies).
fn span_calls(fa: &FileAnalysis, si: usize) -> Vec<String> {
    let span = &fa.fn_spans[si];
    let mut calls =
        calls_in_range(fa, span.body_open + 1, span.body_close);
    // Remove calls that actually live in a nested fn defined inside us.
    let nested: Vec<(usize, usize)> = fa
        .fn_spans
        .iter()
        .filter(|s| s.kw != span.kw && s.kw > span.body_open && s.body_close < span.body_close)
        .map(|s| (s.kw, s.body_close))
        .collect();
    if !nested.is_empty() {
        calls = calls_outside_nested(fa, span, &nested);
    }
    calls.sort();
    calls.dedup();
    calls
}

fn calls_outside_nested(
    fa: &FileAnalysis,
    span: &super::rules::FnSpan,
    nested: &[(usize, usize)],
) -> Vec<String> {
    let mut out = Vec::new();
    let mut i = span.body_open + 1;
    while i < span.body_close {
        if let Some(&(_, close)) =
            nested.iter().find(|&&(kw, _)| kw == i)
        {
            i = close + 1;
            continue;
        }
        out.extend(calls_in_range(fa, i, i + 1));
        i += 1;
    }
    out
}

/// Render a short `root <- … <- name` provenance chain for diagnostics.
fn chain(reached_via: &BTreeMap<String, String>, name: &str) -> String {
    let mut parts = vec![name.to_string()];
    let mut cur = name.to_string();
    for _ in 0..6 {
        match reached_via.get(&cur) {
            Some(prev) if prev != "<hot-path manifest>" => {
                parts.push(prev.clone());
                cur = prev.clone();
            }
            _ => break,
        }
    }
    parts.reverse();
    if parts.len() == 1 {
        format!("root `{}`", parts[0])
    } else {
        format!("via `{}`", parts.join(" -> "))
    }
}
