//! Whole-crate call graph with module/impl-aware symbol resolution,
//! plus the reachability rules built on it: R5 (hot-path-panic), R7
//! (alloc-in-hotpath), R8 (unordered-iteration), and R10
//! (dispatch-blocking).
//!
//! flashlint has no type checker, so resolution is heuristic but
//! receiver-aware: every `fn` carries its impl target (`FnSpan::owner`),
//! and call sites resolve through a small type environment (params,
//! `let` bindings, `self`) plus a crate-wide field-type map. The
//! resolution discipline, in decreasing confidence:
//!
//! - **Typed receiver** (`b.go()` where `b` is known to be a `B`):
//!   edges only to `B::go` (trait names expand to their impls). If the
//!   resolved type has no such method, the call leaves the crate — no
//!   edge, no fallback.
//! - **Untyped ident receiver** (`sess.step()` with `sess` untypable):
//!   edges to every crate *method* of that name (`.m()` can never be a
//!   free fn) — except for [`UBIQUITOUS_METHODS`], std-prelude names
//!   (`len`, `get`, `map`, …) whose std reading dominates so completely
//!   that a crate edge would be noise.
//! - **Expression receiver** (`(0..n).map(…)`, `queues[i].push(…)`):
//!   no edge. These are iterator/slice/`Option` adaptors essentially
//!   always, and name fallbacks here were the analyzer's main source
//!   of phantom reachability.
//! - **Qualified path** (`Q::m(…)`): uppercase `Q` resolves strictly
//!   like a typed receiver; lowercase `q` in [`STD_MODULES`]
//!   (`thread::spawn`, `mem::take`) leaves the crate; any other
//!   lowercase module edges to crate free fns of that name only.
//! - **Bare call** (`helper(…)`): crate free fns of that name only —
//!   Rust's own resolution cannot make a bare call land on a method.
//!
//! Reachability is a BFS over resolved fn ids seeded by manifest root
//! sets (`hotpath.txt` sections, `dispatch.txt`), with per-fn
//! provenance chains for diagnostics.

use super::rules::{FileAnalysis, Finding, KEYWORDS};
use super::tokenizer::{is_ident, is_punct, Tok, TokKind};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Macros that are always a panic at runtime.
const PANIC_MACROS: &[&str] = &["panic", "todo", "unimplemented"];

/// Std module segments: `seg::f(…)` through one of these leaves the
/// crate (`thread::spawn` must not edge to a crate fn named `spawn`).
const STD_MODULES: &[&str] = &[
    "std", "thread", "fs", "io", "mem", "env", "process", "time", "cmp",
    "iter", "ptr", "slice", "str", "net", "fmt", "hash", "convert",
    "borrow", "array", "char", "f32", "f64", "u8", "u16", "u32", "u64",
    "usize", "i8", "i16", "i32", "i64", "isize",
];

/// Method names so pervasive on std types (slices, iterators, `Option`,
/// collections, `f32`) that an *untyped* receiver calling one is
/// essentially never a crate call. Typed receivers still resolve these
/// exactly — `batcher.push(…)` with `batcher: DynamicBatcher` edges to
/// `DynamicBatcher::push` — only the name-level fallback is cut.
const UBIQUITOUS_METHODS: &[&str] = &[
    "all", "any", "append", "as_mut", "as_ref", "clear", "clone",
    "collect", "contains", "contains_key", "count", "drain", "entry",
    "enumerate", "expect", "extend", "fill", "filter", "find", "first",
    "fold", "get", "get_mut", "insert", "into_iter", "is_empty",
    "is_none", "is_some", "iter", "iter_mut", "join", "keys", "last",
    "len", "map", "max", "min", "next", "parse", "pop", "position",
    "push", "read", "remove", "replace", "resize", "retain", "rev",
    "send", "sort", "split", "sum", "take", "to_owned", "to_string",
    "to_vec", "unwrap", "unwrap_or", "values", "write", "zip",
];

/// Containers skipped when extracting the interesting type from a
/// declaration (`Arc<FactorStore>` types its binding as `FactorStore`).
fn resolve_type_name(
    idents: &[String],
    crate_known: &BTreeSet<String>,
) -> Option<String> {
    idents
        .iter()
        .find(|t| crate_known.contains(*t))
        .or_else(|| idents.first())
        .cloned()
}

/// One `fn` in the crate: `(file index, span index)`.
#[derive(Clone, Copy, Debug)]
struct FnInfo {
    fi: usize,
    si: usize,
}

/// Result of a reachability BFS: visited fn ids plus, for provenance,
/// the fn each was first reached from (`None` = manifest root).
pub struct Reach {
    parent: BTreeMap<usize, Option<usize>>,
}

impl Reach {
    pub fn visited(&self) -> impl Iterator<Item = usize> + '_ {
        self.parent.keys().copied()
    }
    pub fn contains(&self, id: usize) -> bool {
        self.parent.contains_key(&id)
    }
}

pub struct Graph<'a> {
    files: &'a [FileAnalysis],
    fns: Vec<FnInfo>,
    /// Bare fn name -> fn ids (methods and free fns alike).
    by_name: BTreeMap<String, Vec<usize>>,
    /// Free (non-impl) fn name -> fn ids.
    free_by_name: BTreeMap<String, Vec<usize>>,
    /// (impl target, method name) -> fn ids.
    methods: BTreeMap<(String, String), Vec<usize>>,
    /// Method name -> fn ids (impl fns only): the untyped-receiver
    /// fallback pool.
    methods_by_name: BTreeMap<String, Vec<usize>>,
    /// Trait name -> implementing types (for `dyn Trait` receivers).
    trait_impls: BTreeMap<String, BTreeSet<String>>,
    /// Impl targets and trait names defined in the crate.
    crate_known: BTreeSet<String>,
    /// Per file: binding/field names declared with a HashMap/HashSet
    /// type *in that file*. Kept per-file so a `pending` HashMap in one
    /// module does not taint every other binding named `pending`.
    hash_named: Vec<BTreeSet<String>>,
    /// Crate-wide `name: Type` declarations (fields, params, lets).
    field_types: BTreeMap<String, BTreeSet<String>>,
    /// Per file: token index -> owning fn id (innermost non-test fn).
    token_owner: Vec<Vec<Option<usize>>>,
    /// Resolved call edges per fn id.
    edges: Vec<Vec<usize>>,
}

impl<'a> Graph<'a> {
    pub fn build(files: &'a [FileAnalysis]) -> Graph<'a> {
        let mut fns = Vec::new();
        let mut by_name: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let mut free_by_name: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let mut methods: BTreeMap<(String, String), Vec<usize>> =
            BTreeMap::new();
        let mut methods_by_name: BTreeMap<String, Vec<usize>> =
            BTreeMap::new();
        let mut trait_impls: BTreeMap<String, BTreeSet<String>> =
            BTreeMap::new();
        let mut crate_known: BTreeSet<String> = BTreeSet::new();

        for (fi, fa) in files.iter().enumerate() {
            for (trait_name, ty) in &fa.impl_decls {
                crate_known.insert(ty.clone());
                if let Some(tr) = trait_name {
                    crate_known.insert(tr.clone());
                    trait_impls
                        .entry(tr.clone())
                        .or_default()
                        .insert(ty.clone());
                }
            }
            for (si, span) in fa.fn_spans.iter().enumerate() {
                if span.is_test {
                    continue;
                }
                let id = fns.len();
                fns.push(FnInfo { fi, si });
                by_name.entry(span.name.clone()).or_default().push(id);
                match &span.owner {
                    Some(owner) => {
                        methods
                            .entry((owner.clone(), span.name.clone()))
                            .or_default()
                            .push(id);
                        methods_by_name
                            .entry(span.name.clone())
                            .or_default()
                            .push(id);
                    }
                    None => {
                        free_by_name
                            .entry(span.name.clone())
                            .or_default()
                            .push(id);
                    }
                }
            }
        }

        // Innermost-fn ownership per token: assign wider spans first so
        // nested fns overwrite their enclosing span.
        let mut token_owner: Vec<Vec<Option<usize>>> =
            files.iter().map(|fa| vec![None; fa.toks.len()]).collect();
        let mut order: Vec<usize> = (0..fns.len()).collect();
        order.sort_by_key(|&id| {
            let f = fns[id];
            let s = &files[f.fi].fn_spans[f.si];
            std::cmp::Reverse(s.body_close - s.body_open)
        });
        for id in order {
            let f = fns[id];
            let s = &files[f.fi].fn_spans[f.si];
            for slot in token_owner[f.fi]
                .iter_mut()
                .take(s.body_close + 1)
                .skip(s.body_open)
            {
                *slot = Some(id);
            }
        }

        // Crate-wide declaration scan: field/param/let types (for
        // receiver resolution) and hash-typed binding names (for R8).
        let mut field_types: BTreeMap<String, BTreeSet<String>> =
            BTreeMap::new();
        let mut hash_named: Vec<BTreeSet<String>> =
            vec![BTreeSet::new(); files.len()];
        for (fi, fa) in files.iter().enumerate() {
            let t = &fa.toks;
            for i in 0..t.len() {
                if fa.test_mask[i] {
                    continue;
                }
                if let Some((name, tys)) = decl_type(t, i) {
                    if tys.iter().any(|x| x == "HashMap" || x == "HashSet") {
                        hash_named[fi].insert(name.clone());
                    }
                    if let Some(ty) = resolve_type_name(&tys, &crate_known) {
                        field_types.entry(name).or_default().insert(ty);
                    }
                }
                // `let [mut] name = HashMap::new()` / `HashSet::…`.
                if is_ident(&t[i], "let") {
                    let mut j = i + 1;
                    if j < t.len() && is_ident(&t[j], "mut") {
                        j += 1;
                    }
                    if j + 2 < t.len()
                        && t[j].kind == TokKind::Ident
                        && is_punct(&t[j + 1], '=')
                        && (is_ident(&t[j + 2], "HashMap")
                            || is_ident(&t[j + 2], "HashSet"))
                    {
                        hash_named[fi].insert(t[j].text.clone());
                    }
                }
            }
        }

        let mut g = Graph {
            files,
            fns,
            by_name,
            free_by_name,
            methods,
            methods_by_name,
            trait_impls,
            crate_known,
            hash_named,
            field_types,
            token_owner,
            edges: Vec::new(),
        };
        g.edges = (0..g.fns.len()).map(|id| g.resolve_edges(id)).collect();
        g
    }

    fn span(&self, id: usize) -> &super::rules::FnSpan {
        let f = self.fns[id];
        &self.files[f.fi].fn_spans[f.si]
    }

    fn file_of(&self, id: usize) -> usize {
        self.fns[id].fi
    }

    pub fn name_of(&self, id: usize) -> &str {
        &self.span(id).name
    }

    /// Token indices of fn `id`'s own body (nested fns excluded).
    fn own_tokens(&self, id: usize) -> Vec<usize> {
        let f = self.fns[id];
        let s = &self.files[f.fi].fn_spans[f.si];
        (s.body_open + 1..s.body_close)
            .filter(|&i| self.token_owner[f.fi][i] == Some(id))
            .collect()
    }

    /// Method targets for a receiver type (or trait) name.
    fn method_targets(&self, tys: &[String], m: &str) -> Vec<usize> {
        let mut expanded: BTreeSet<String> = BTreeSet::new();
        for ty in tys {
            match self.trait_impls.get(ty) {
                Some(impls) => expanded.extend(impls.iter().cloned()),
                None => {
                    expanded.insert(ty.clone());
                }
            }
        }
        let mut hit: Vec<usize> = Vec::new();
        for ty in &expanded {
            if let Some(ids) =
                self.methods.get(&(ty.clone(), m.to_string()))
            {
                hit.extend(ids.iter().copied());
            }
        }
        // Strict: no (type, method) hit means the call leaves the
        // crate (`Vec::push`, `Instant::now`, `opt.map(…)`). A typed
        // receiver never falls back to name-level matching.
        hit.sort_unstable();
        hit.dedup();
        hit
    }

    /// Resolve the call edges out of fn `id`.
    fn resolve_edges(&self, id: usize) -> Vec<usize> {
        let f = self.fns[id];
        let fa = &self.files[f.fi];
        let t = &fa.toks;
        let span = &fa.fn_spans[f.si];
        let env = self.type_env(id);
        let mut out: BTreeSet<usize> = BTreeSet::new();

        for i in self.own_tokens(id) {
            if t[i].kind != TokKind::Ident
                || KEYWORDS.contains(&t[i].text.as_str())
            {
                continue;
            }
            if i + 1 >= t.len() || !is_punct(&t[i + 1], '(') {
                continue;
            }
            if i > 0 && is_ident(&t[i - 1], "fn") {
                continue;
            }
            let m = t[i].text.as_str();
            let targets: Vec<usize> = if i > 0 && is_punct(&t[i - 1], '.') {
                // Method call: type the receiver via env, then the
                // crate-wide field map.
                if !(i >= 2 && t[i - 2].kind == TokKind::Ident) {
                    // Expression receiver (`)`, `]`, literal): an
                    // iterator/slice/Option adaptor essentially always;
                    // a name fallback here invents crate edges.
                    continue;
                }
                let recv = t[i - 2].text.as_str();
                let tys: Option<Vec<String>> = if recv == "self" {
                    span.owner.clone().map(|o| vec![o])
                } else {
                    env.get(recv).map(|ty| vec![ty.clone()]).or_else(|| {
                        self.field_types
                            .get(recv)
                            .map(|s| s.iter().cloned().collect())
                    })
                };
                match tys {
                    Some(tys) => self.method_targets(&tys, m),
                    None if UBIQUITOUS_METHODS.contains(&m) => {
                        // std-prelude name on an untyped receiver: the
                        // std reading dominates; no crate edge.
                        Vec::new()
                    }
                    None => {
                        // Untyped receiver: over-approximate across
                        // crate methods only (`.m()` is never a free fn).
                        self.methods_by_name
                            .get(m)
                            .cloned()
                            .unwrap_or_default()
                    }
                }
            } else if i >= 3
                && is_punct(&t[i - 1], ':')
                && is_punct(&t[i - 2], ':')
                && t[i - 3].kind == TokKind::Ident
            {
                let q = t[i - 3].text.as_str();
                if q == "Self" {
                    match &span.owner {
                        Some(o) => {
                            self.method_targets(&[o.clone()], m)
                        }
                        None => self
                            .methods_by_name
                            .get(m)
                            .cloned()
                            .unwrap_or_default(),
                    }
                } else if q.starts_with(|c: char| c.is_ascii_uppercase()) {
                    self.method_targets(&[q.to_string()], m)
                } else if STD_MODULES.contains(&q) {
                    // `thread::spawn`, `mem::take`, …: leaves the crate.
                    Vec::new()
                } else {
                    // Module-qualified path: a crate free fn elsewhere.
                    self.free_by_name.get(m).cloned().unwrap_or_default()
                }
            } else {
                // Bare call: crate free fns only — Rust's resolution
                // cannot make a bare call land on a method.
                self.free_by_name.get(m).cloned().unwrap_or_default()
            };
            out.extend(targets);
        }
        out.remove(&id);
        out.into_iter().collect()
    }

    /// Local type environment for fn `id`: param and `let` bindings.
    fn type_env(&self, id: usize) -> BTreeMap<String, String> {
        let f = self.fns[id];
        let fa = &self.files[f.fi];
        let t = &fa.toks;
        let span = &fa.fn_spans[f.si];
        let mut env = BTreeMap::new();
        // Params: `name: Type` between the fn name and the body `{`.
        for i in span.kw..span.body_open {
            if let Some((name, tys)) = decl_type(t, i) {
                if let Some(ty) = resolve_type_name(&tys, &self.crate_known)
                {
                    env.insert(name, ty);
                }
            }
        }
        // Lets: `let [mut] name: Type` / `let [mut] name = Type::…`.
        for i in self.own_tokens(id) {
            if !is_ident(&t[i], "let") {
                continue;
            }
            let mut j = i + 1;
            if j < t.len() && is_ident(&t[j], "mut") {
                j += 1;
            }
            if j >= t.len() || t[j].kind != TokKind::Ident {
                continue;
            }
            let name = t[j].text.clone();
            if j + 1 < t.len()
                && is_punct(&t[j + 1], ':')
                && !(j + 2 < t.len() && is_punct(&t[j + 2], ':'))
            {
                if let Some((n, tys)) = decl_type(t, j) {
                    if let Some(ty) =
                        resolve_type_name(&tys, &self.crate_known)
                    {
                        env.insert(n, ty);
                    }
                }
            } else if j + 2 < t.len()
                && is_punct(&t[j + 1], '=')
                && t[j + 2].kind == TokKind::Ident
                && t[j + 2]
                    .text
                    .starts_with(|c: char| c.is_ascii_uppercase())
                && j + 3 < t.len()
                && (is_punct(&t[j + 3], ':') || is_punct(&t[j + 3], '{'))
            {
                // `let x = Type::ctor(…)` or `let x = Type { … }`.
                env.insert(name, t[j + 2].text.clone());
            }
        }
        env
    }

    /// BFS from manifest roots. A root is a bare fn name (matches every
    /// fn with that name) or `Type::method`.
    pub fn reach(&self, roots: &[String]) -> Reach {
        let mut parent: BTreeMap<usize, Option<usize>> = BTreeMap::new();
        let mut queue: VecDeque<usize> = VecDeque::new();
        for spec in roots {
            let ids: Vec<usize> = match spec.split_once("::") {
                Some((ty, m)) => self
                    .methods
                    .get(&(ty.to_string(), m.to_string()))
                    .cloned()
                    .unwrap_or_default(),
                None => {
                    self.by_name.get(spec).cloned().unwrap_or_default()
                }
            };
            for id in ids {
                if let std::collections::btree_map::Entry::Vacant(e) =
                    parent.entry(id)
                {
                    e.insert(None);
                    queue.push_back(id);
                }
            }
        }
        while let Some(id) = queue.pop_front() {
            for &tgt in &self.edges[id] {
                if let std::collections::btree_map::Entry::Vacant(e) =
                    parent.entry(tgt)
                {
                    e.insert(Some(id));
                    queue.push_back(tgt);
                }
            }
        }
        Reach { parent }
    }

    /// Fns that can reach (transitively call) any fn named in `sinks`.
    fn reaches_any(&self, sinks: &[&str]) -> BTreeSet<usize> {
        let mut rev: Vec<Vec<usize>> = vec![Vec::new(); self.fns.len()];
        for (src, tgts) in self.edges.iter().enumerate() {
            for &t in tgts {
                rev[t].push(src);
            }
        }
        let mut seen: BTreeSet<usize> = BTreeSet::new();
        let mut queue: VecDeque<usize> = VecDeque::new();
        for s in sinks {
            for &id in
                self.by_name.get(*s).map(|v| v.as_slice()).unwrap_or(&[])
            {
                if seen.insert(id) {
                    queue.push_back(id);
                }
            }
        }
        while let Some(id) = queue.pop_front() {
            for &caller in &rev[id] {
                if seen.insert(caller) {
                    queue.push_back(caller);
                }
            }
        }
        seen
    }

    /// Render a short `root -> … -> name` provenance chain.
    fn chain(&self, reach: &Reach, id: usize) -> String {
        let mut parts = vec![self.name_of(id).to_string()];
        let mut cur = id;
        for _ in 0..6 {
            match reach.parent.get(&cur) {
                Some(Some(p)) => {
                    parts.push(self.name_of(*p).to_string());
                    cur = *p;
                }
                _ => break,
            }
        }
        parts.reverse();
        if parts.len() == 1 {
            format!("root `{}`", parts[0])
        } else {
            format!("via `{}`", parts.join(" -> "))
        }
    }
}

/// Parse a `name: Type` declaration at ident token `i` (field, param,
/// or typed `let`). Returns the binding name and the type path's
/// identifiers (generics included, `dyn`/`mut`/`impl`/`ref` skipped).
fn decl_type(t: &[Tok], i: usize) -> Option<(String, Vec<String>)> {
    if t[i].kind != TokKind::Ident
        || KEYWORDS.contains(&t[i].text.as_str())
    {
        return None;
    }
    if i + 2 >= t.len()
        || !is_punct(&t[i + 1], ':')
        || is_punct(&t[i + 2], ':')
        || (i > 0 && is_punct(&t[i - 1], ':'))
    {
        return None;
    }
    let mut tys = Vec::new();
    let mut depth = 0i32;
    let mut j = i + 2;
    let limit = (i + 42).min(t.len());
    while j < limit {
        let tk = &t[j];
        if is_punct(tk, '<') {
            depth += 1;
        } else if is_punct(tk, '>') {
            if depth == 0 {
                break;
            }
            depth -= 1;
        } else if depth == 0
            && (is_punct(tk, ',')
                || is_punct(tk, ')')
                || is_punct(tk, ';')
                || is_punct(tk, '=')
                || is_punct(tk, '{')
                || is_punct(tk, '}')
                || is_punct(tk, '('))
        {
            break;
        } else if tk.kind == TokKind::Ident
            && !matches!(tk.text.as_str(), "dyn" | "mut" | "impl" | "ref")
        {
            tys.push(tk.text.clone());
        }
        j += 1;
    }
    if tys.is_empty() {
        None
    } else {
        Some((t[i].text.clone(), tys))
    }
}

/// Receiver-chain identifiers for the method call whose `.` is at
/// `dot`: `self.store.lookup(…)` yields `["store", "self"]`
/// (nearest-first), skipping balanced `(...)`/`[...]` groups.
fn chain_idents(t: &[Tok], dot: usize) -> Vec<String> {
    let mut ids = Vec::new();
    let mut k = dot;
    loop {
        if k == 0 {
            break;
        }
        let p = k - 1;
        match t[p].kind {
            TokKind::Ident => {
                ids.push(t[p].text.clone());
                if p > 0 && is_punct(&t[p - 1], '.') {
                    k = p - 1;
                    continue;
                }
                break;
            }
            TokKind::Punct
                if is_punct(&t[p], ')') || is_punct(&t[p], ']') =>
            {
                let close_ch = if is_punct(&t[p], ')') { ')' } else { ']' };
                let open_ch = if close_ch == ')' { '(' } else { '[' };
                let mut depth = 0i32;
                let mut o = p;
                loop {
                    if is_punct(&t[o], close_ch) {
                        depth += 1;
                    } else if is_punct(&t[o], open_ch) {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    if o == 0 {
                        break;
                    }
                    o -= 1;
                }
                if o > 0 && t[o - 1].kind == TokKind::Ident {
                    ids.push(t[o - 1].text.clone());
                    if o >= 2 && is_punct(&t[o - 2], '.') {
                        k = o - 2;
                        continue;
                    }
                }
                break;
            }
            _ => break,
        }
    }
    ids
}

// ---------------------------------------------------------------------------
// R5: panic sites reachable from the serving hot path
// ---------------------------------------------------------------------------

/// Compute R5 findings. Returns `(file_index, finding)` pairs so the
/// caller can route them through per-file suppression.
pub fn hot_path_findings(
    g: &Graph,
    roots: &[String],
) -> Vec<(usize, Finding)> {
    let reach = g.reach(roots);
    let mut out = Vec::new();
    for id in reach.visited() {
        let fa = &g.files[g.file_of(id)];
        let t = &fa.toks;
        let span_name = g.name_of(id).to_string();
        for i in g.own_tokens(id) {
            if fa.test_mask[i] || t[i].kind != TokKind::Ident {
                continue;
            }
            let site = if (is_ident(&t[i], "unwrap")
                || is_ident(&t[i], "expect"))
                && i > 0
                && is_punct(&t[i - 1], '.')
                && i + 1 < t.len()
                && is_punct(&t[i + 1], '(')
            {
                Some(format!(".{}()", t[i].text))
            } else if PANIC_MACROS.contains(&t[i].text.as_str())
                && i + 1 < t.len()
                && is_punct(&t[i + 1], '!')
            {
                Some(format!("{}!", t[i].text))
            } else {
                None
            };
            if let Some(site) = site {
                let via = g.chain(&reach, id);
                out.push((
                    g.file_of(id),
                    Finding {
                        rule: "hot-path-panic",
                        line: t[i].line,
                        message: format!(
                            "`{site}` in fn `{span_name}`, reachable from \
                             the serving hot path ({via})"
                        ),
                    },
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// R7: heap allocation reachable from decode/kernel inner-loop roots
// ---------------------------------------------------------------------------

const ALLOC_CTOR_TYPES: &[&str] = &[
    "Vec", "VecDeque", "String", "Box", "HashMap", "HashSet", "BTreeMap",
    "BTreeSet",
];
const ALLOC_CTOR_FNS: &[&str] = &["new", "with_capacity", "from"];
const ALLOC_METHODS: &[&str] =
    &["clone", "to_vec", "to_owned", "to_string", "collect"];
const ALLOC_MACROS: &[&str] = &["format", "vec"];

pub fn alloc_findings(
    g: &Graph,
    inner_roots: &[String],
    scratch_allow: &[String],
) -> Vec<(usize, Finding)> {
    let reach = g.reach(inner_roots);
    let mut out = Vec::new();
    for id in reach.visited() {
        let span = g.span(id);
        let exempt = scratch_allow.iter().any(|s| {
            s == &span.name
                || match (&span.owner, s.split_once("::")) {
                    (Some(o), Some((ty, m))) => {
                        o == ty && m == span.name
                    }
                    _ => false,
                }
        });
        if exempt {
            // Per-flush setup fns: their own allocations are amortized
            // over the whole batch, but their callees stay in scope.
            continue;
        }
        let fa = &g.files[g.file_of(id)];
        let t = &fa.toks;
        let span_name = g.name_of(id).to_string();
        for i in g.own_tokens(id) {
            if fa.test_mask[i] || t[i].kind != TokKind::Ident {
                continue;
            }
            let txt = t[i].text.as_str();
            let next_open = i + 1 < t.len() && is_punct(&t[i + 1], '(');
            let next_turbofish = i + 3 < t.len()
                && is_punct(&t[i + 1], ':')
                && is_punct(&t[i + 2], ':')
                && is_punct(&t[i + 3], '<');
            let what = if ALLOC_METHODS.contains(&txt)
                && i > 0
                && is_punct(&t[i - 1], '.')
                && (next_open || next_turbofish)
            {
                Some(format!(".{txt}()"))
            } else if ALLOC_CTOR_TYPES.contains(&txt)
                && i + 3 < t.len()
                && is_punct(&t[i + 1], ':')
                && is_punct(&t[i + 2], ':')
                && ALLOC_CTOR_FNS.contains(&t[i + 3].text.as_str())
                && i + 4 < t.len()
                && is_punct(&t[i + 4], '(')
            {
                Some(format!("{txt}::{}", t[i + 3].text))
            } else if ALLOC_MACROS.contains(&txt)
                && i + 1 < t.len()
                && is_punct(&t[i + 1], '!')
            {
                Some(format!("{txt}!"))
            } else {
                None
            };
            if let Some(what) = what {
                let via = g.chain(&reach, id);
                out.push((
                    g.file_of(id),
                    Finding {
                        rule: "alloc-in-hotpath",
                        line: t[i].line,
                        message: format!(
                            "`{what}` heap-allocates in fn `{span_name}`, \
                             on the decode/kernel inner loop ({via}) — \
                             reuse a scratch buffer"
                        ),
                    },
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// R8: HashMap/HashSet iteration feeding serving or persisted output
// ---------------------------------------------------------------------------

const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];

/// Fns whose output must be deterministic: jsonlite dumps, wire
/// frames, and store persistence.
const ORDER_SINKS: &[&str] = &[
    "dump",
    "dumps",
    "write_frame",
    "entry_to_json",
    "f32s_to_json",
    "to_json",
    "save",
];

pub fn unordered_findings(
    g: &Graph,
    serving_roots: &[String],
) -> Vec<(usize, Finding)> {
    let fwd = g.reach(serving_roots);
    let to_sink = g.reaches_any(ORDER_SINKS);
    let mut out = Vec::new();
    for id in 0..g.fns.len() {
        let on_serving = fwd.contains(id);
        let feeds_sink = to_sink.contains(&id);
        if !on_serving && !feeds_sink {
            continue;
        }
        let scope = if on_serving {
            "on the serving path"
        } else {
            "feeding persisted/wire output"
        };
        let fa = &g.files[g.file_of(id)];
        let t = &fa.toks;
        let span_name = g.name_of(id).to_string();
        for i in g.own_tokens(id) {
            if fa.test_mask[i] || t[i].kind != TokKind::Ident {
                continue;
            }
            // `.iter()`-family calls on a hash-typed receiver chain.
            if ITER_METHODS.contains(&t[i].text.as_str())
                && i > 0
                && is_punct(&t[i - 1], '.')
                && i + 1 < t.len()
                && is_punct(&t[i + 1], '(')
            {
                let ids = chain_idents(t, i - 1);
                if let Some(hit) = ids
                    .iter()
                    .find(|x| g.hash_named[g.file_of(id)].contains(*x))
                {
                    out.push((
                        g.file_of(id),
                        Finding {
                            rule: "unordered-iteration",
                            line: t[i].line,
                            message: format!(
                                "`.{}()` iterates hash-ordered `{hit}` in \
                                 fn `{span_name}` ({scope}) — iteration \
                                 order is nondeterministic across runs",
                                t[i].text
                            ),
                        },
                    ));
                }
                continue;
            }
            // `for pat in &hash_map { … }` without a method call.
            if is_ident(&t[i], "in") {
                let mut j = i + 1;
                let mut names: Vec<String> = Vec::new();
                let mut stopped_at_brace = false;
                let limit = (i + 24).min(t.len());
                while j < limit {
                    if is_punct(&t[j], '{') {
                        stopped_at_brace = true;
                        break;
                    }
                    if is_punct(&t[j], '(')
                        || is_punct(&t[j], ';')
                        || is_ident(&t[j], "in")
                    {
                        break;
                    }
                    if t[j].kind == TokKind::Ident
                        && !KEYWORDS.contains(&t[j].text.as_str())
                    {
                        names.push(t[j].text.clone());
                    }
                    j += 1;
                }
                if stopped_at_brace {
                    if let Some(hit) = names
                        .iter()
                        .find(|x| g.hash_named[g.file_of(id)].contains(*x))
                    {
                        out.push((
                            g.file_of(id),
                            Finding {
                                rule: "unordered-iteration",
                                line: t[i].line,
                                message: format!(
                                    "`for … in` over hash-ordered `{hit}` \
                                     in fn `{span_name}` ({scope}) — \
                                     iteration order is nondeterministic \
                                     across runs"
                                ),
                            },
                        ));
                    }
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// R10: blocking calls reachable from the netserver dispatch thread
// ---------------------------------------------------------------------------

/// Non-`try_` lock acquisitions (block until granted).
const LOCK_NONTRY: &[&str] = &[
    "lock",
    "read",
    "write",
    "lock_recover",
    "read_recover",
    "write_recover",
];

pub fn dispatch_findings(
    g: &Graph,
    dispatch_roots: &[String],
    blocking: &[String],
    leaf_locks: &[String],
) -> Vec<(usize, Finding)> {
    let reach = g.reach(dispatch_roots);
    let mut out = Vec::new();
    for id in reach.visited() {
        let fa = &g.files[g.file_of(id)];
        if super::rules::norm(&fa.path).ends_with("util/sync.rs") {
            // The audited sync shim: its recover wrappers *are* the
            // sanctioned lock acquisitions, and its watchdog closures
            // park deliberately.
            continue;
        }
        let t = &fa.toks;
        let span_name = g.name_of(id).to_string();
        for i in g.own_tokens(id) {
            if fa.test_mask[i] || t[i].kind != TokKind::Ident {
                continue;
            }
            if i + 1 >= t.len() || !is_punct(&t[i + 1], '(') {
                continue;
            }
            if i > 0 && is_ident(&t[i - 1], "fn") {
                continue;
            }
            let txt = t[i].text.as_str();
            // Known-blocking calls from the dispatch manifest.
            if blocking.iter().any(|b| b == txt) {
                let via = g.chain(&reach, id);
                out.push((
                    g.file_of(id),
                    Finding {
                        rule: "dispatch-blocking",
                        line: t[i].line,
                        message: format!(
                            "`{txt}(…)` blocks the dispatch thread in fn \
                             `{span_name}` ({via}) — a stalled call here \
                             stops admission for every connection"
                        ),
                    },
                ));
                continue;
            }
            // Non-try lock acquisition outside the leaf-lock set.
            // Lock acquisitions take no arguments — the `()` check
            // keeps `v.write(out)`-style fmt/io writes out of scope.
            if LOCK_NONTRY.contains(&txt)
                && i > 0
                && is_punct(&t[i - 1], '.')
                && i + 2 < t.len()
                && is_punct(&t[i + 2], ')')
            {
                let ids = chain_idents(t, i - 1);
                let leaf = ids
                    .iter()
                    .any(|x| leaf_locks.iter().any(|l| l == x));
                if !leaf {
                    let recv = ids
                        .first()
                        .cloned()
                        .unwrap_or_else(|| "<expr>".to_string());
                    let via = g.chain(&reach, id);
                    out.push((
                        g.file_of(id),
                        Finding {
                            rule: "dispatch-blocking",
                            line: t[i].line,
                            message: format!(
                                "non-try `.{txt}()` on `{recv}` in fn \
                                 `{span_name}` ({via}) — only [leaf-locks] \
                                 from dispatch.txt may be taken on the \
                                 dispatch thread"
                            ),
                        },
                    ));
                }
            }
        }
    }
    out
}
