//! A lightweight, lossy Rust tokenizer for flashlint.
//!
//! No `syn` in the vendored universe, and the rules only need
//! line/token-level structure: identifiers, single-char punctuation,
//! literals, and comments (kept separately so allow-annotations can be
//! parsed). Multi-char operators arrive as adjacent single-char `Punct`
//! tokens (`::` is `:` `:`), which the rule matchers account for.
//!
//! The scanner understands the constructs that would otherwise corrupt
//! a naive token stream: nested block comments, string/char literals
//! with escapes, raw and byte strings (`r#"…"#`, `b"…"`), lifetimes vs
//! char literals, and numeric literals with exponents.

/// Token kind. `Punct` carries exactly one character.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    Str,
    Char,
    Num,
    Lifetime,
}

#[derive(Clone, Debug)]
pub struct Tok {
    /// 1-based source line of the token's first character.
    pub line: u32,
    pub kind: TokKind,
    pub text: String,
}

#[derive(Clone, Debug)]
pub struct Comment {
    /// 1-based line the comment starts on.
    pub line: u32,
    pub text: String,
}

/// Tokenize `src`, returning code tokens and comments separately.
pub fn tokenize(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        // Line comments (incl. /// and //!).
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let start = i;
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            comments.push(Comment {
                line,
                text: src[start..i].to_string(),
            });
            continue;
        }
        // Block comments, nested.
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start = i;
            let start_line = line;
            i += 2;
            let mut depth = 1usize;
            while i < n && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            comments.push(Comment {
                line: start_line,
                text: src[start..i].to_string(),
            });
            continue;
        }
        // Plain string literal.
        if c == b'"' {
            let start_line = line;
            let start = i;
            i += 1;
            while i < n {
                match b[i] {
                    b'\\' => {
                        if i + 1 < n && b[i + 1] == b'\n' {
                            line += 1;
                        }
                        i += 2;
                    }
                    b'"' => {
                        i += 1;
                        break;
                    }
                    b'\n' => {
                        line += 1;
                        i += 1;
                    }
                    _ => i += 1,
                }
            }
            toks.push(Tok {
                line: start_line,
                kind: TokKind::Str,
                text: src[start..i.min(n)].to_string(),
            });
            continue;
        }
        // Identifier (or raw/byte-string prefix).
        if c.is_ascii_alphabetic() || c == b'_' || c >= 0x80 {
            let start = i;
            while i < n
                && (b[i].is_ascii_alphanumeric() || b[i] == b'_' || b[i] >= 0x80)
            {
                i += 1;
            }
            let text = &src[start..i];
            // Raw strings (`r"…"`, `r#"…"#`, `br#"…"#`) have no escape
            // processing; `b"…"` is an *escaped* byte string and is
            // handled below; `r#ident` is a raw identifier, not a
            // string. Only commit to the raw-string branch once the
            // lookahead confirms hashes are followed by a quote.
            let raw_candidate = matches!(text, "r" | "br")
                && i < n
                && (b[i] == b'"' || b[i] == b'#');
            if raw_candidate {
                let mut j = i;
                let mut hashes = 0usize;
                while j < n && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && b[j] == b'"' {
                    // Raw string: find `"` followed by `hashes` hashes.
                    let start_line = line;
                    i = j + 1;
                    'scan: while i < n {
                        if b[i] == b'\n' {
                            line += 1;
                            i += 1;
                            continue;
                        }
                        if b[i] == b'"' {
                            let mut k = i + 1;
                            let mut seen = 0usize;
                            while k < n && b[k] == b'#' && seen < hashes {
                                seen += 1;
                                k += 1;
                            }
                            if seen == hashes {
                                i = k;
                                break 'scan;
                            }
                        }
                        i += 1;
                    }
                    toks.push(Tok {
                        line: start_line,
                        kind: TokKind::Str,
                        text: src[start..i.min(n)].to_string(),
                    });
                    continue;
                }
                if text == "r"
                    && hashes == 1
                    && j < n
                    && (b[j].is_ascii_alphabetic()
                        || b[j] == b'_'
                        || b[j] >= 0x80)
                {
                    // Raw identifier `r#ident`: emit the bare ident so
                    // rules see `r#match` and `match` identically.
                    let id_start = j;
                    i = j;
                    while i < n
                        && (b[i].is_ascii_alphanumeric()
                            || b[i] == b'_'
                            || b[i] >= 0x80)
                    {
                        i += 1;
                    }
                    toks.push(Tok {
                        line,
                        kind: TokKind::Ident,
                        text: src[id_start..i].to_string(),
                    });
                    continue;
                }
                // Fall through: `r`/`br` used as a plain identifier
                // followed by `#` punctuation.
            }
            if text == "b" && i < n && b[i] == b'"' {
                // Byte string: escape-processed like a plain string,
                // so `b"\""` does not terminate at the escaped quote.
                let start_line = line;
                i += 1;
                while i < n {
                    match b[i] {
                        b'\\' => {
                            if i + 1 < n && b[i + 1] == b'\n' {
                                line += 1;
                            }
                            i += 2;
                        }
                        b'"' => {
                            i += 1;
                            break;
                        }
                        b'\n' => {
                            line += 1;
                            i += 1;
                        }
                        _ => i += 1,
                    }
                }
                toks.push(Tok {
                    line: start_line,
                    kind: TokKind::Str,
                    text: src[start..i.min(n)].to_string(),
                });
                continue;
            }
            toks.push(Tok {
                line,
                kind: TokKind::Ident,
                text: text.to_string(),
            });
            continue;
        }
        // Numeric literal (handles hex, floats, exponents, suffixes).
        if c.is_ascii_digit() {
            let start = i;
            while i < n {
                let d = b[i];
                if d.is_ascii_alphanumeric() || d == b'_' {
                    i += 1;
                } else if d == b'.'
                    && i + 1 < n
                    && b[i + 1].is_ascii_digit()
                    && !src[start..i].contains('.')
                {
                    i += 1;
                } else if (d == b'+' || d == b'-')
                    && i > start
                    && (b[i - 1] == b'e' || b[i - 1] == b'E')
                    && !src[start..i].starts_with("0x")
                {
                    i += 1;
                } else {
                    break;
                }
            }
            toks.push(Tok {
                line,
                kind: TokKind::Num,
                text: src[start..i].to_string(),
            });
            continue;
        }
        // Char literal vs lifetime.
        if c == b'\'' {
            let is_lifetime = i + 1 < n
                && (b[i + 1].is_ascii_alphabetic() || b[i + 1] == b'_')
                && (i + 2 >= n || b[i + 2] != b'\'');
            if is_lifetime {
                let start = i;
                i += 1;
                while i < n
                    && (b[i].is_ascii_alphanumeric() || b[i] == b'_')
                {
                    i += 1;
                }
                toks.push(Tok {
                    line,
                    kind: TokKind::Lifetime,
                    text: src[start..i].to_string(),
                });
            } else {
                let start = i;
                i += 1;
                while i < n {
                    match b[i] {
                        b'\\' => i += 2,
                        b'\'' => {
                            i += 1;
                            break;
                        }
                        b'\n' => break, // malformed; bail on the line
                        _ => i += 1,
                    }
                }
                toks.push(Tok {
                    line,
                    kind: TokKind::Char,
                    text: src[start..i.min(n)].to_string(),
                });
            }
            continue;
        }
        // Everything else: single-char punctuation.
        toks.push(Tok {
            line,
            kind: TokKind::Punct,
            text: (c as char).to_string(),
        });
        i += 1;
    }
    (toks, comments)
}

/// True if `t` is the identifier `name`.
pub fn is_ident(t: &Tok, name: &str) -> bool {
    t.kind == TokKind::Ident && t.text == name
}

/// True if `t` is the punctuation character `ch`.
pub fn is_punct(t: &Tok, ch: char) -> bool {
    t.kind == TokKind::Punct && t.text.len() == 1 && t.text.starts_with(ch)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        tokenize(src)
            .0
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn basic_tokens_and_lines() {
        let (toks, comments) = tokenize("let x = 1;\n// hi\nlet y = x;");
        assert!(toks.iter().any(|t| is_ident(t, "x") && t.line == 1));
        assert!(toks.iter().any(|t| is_ident(t, "y") && t.line == 3));
        assert_eq!(comments.len(), 1);
        assert_eq!(comments[0].line, 2);
        assert_eq!(comments[0].text, "// hi");
    }

    #[test]
    fn strings_hide_their_contents() {
        let ids = idents(r#"let s = "let fake = unwrap";"#);
        assert_eq!(ids, vec!["let", "s"]);
    }

    #[test]
    fn raw_strings_and_byte_strings() {
        let (toks, _) = tokenize("let s = r#\"has \"quotes\" inside\"#; x");
        assert!(toks.iter().any(|t| is_ident(t, "x")));
        let (toks, _) = tokenize("let b = b\"bytes\"; y");
        assert!(toks.iter().any(|t| is_ident(t, "y")));
        // `r` alone as an identifier must not eat a following `#`.
        let ids = idents("let r = 1; rank");
        assert!(ids.contains(&"r".to_string()));
        assert!(ids.contains(&"rank".to_string()));
    }

    #[test]
    fn nested_block_comments() {
        let (toks, comments) = tokenize("/* a /* b */ c */ real");
        assert_eq!(toks.len(), 1);
        assert!(is_ident(&toks[0], "real"));
        assert_eq!(comments.len(), 1);
    }

    #[test]
    fn lifetimes_vs_chars() {
        let (toks, _) = tokenize("fn f<'a>(x: &'a str, c: char) { let y = 'z'; }");
        assert!(toks
            .iter()
            .any(|t| t.kind == TokKind::Lifetime && t.text == "'a"));
        assert!(toks
            .iter()
            .any(|t| t.kind == TokKind::Char && t.text == "'z'"));
    }

    #[test]
    fn numbers_with_exponents() {
        let (toks, _) = tokenize("let x = 1.5e-3 + 0xFF + 2_000usize;");
        let nums: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(nums, vec!["1.5e-3", "0xFF", "2_000usize"]);
    }

    #[test]
    fn range_does_not_glue_numbers() {
        let (toks, _) = tokenize("for i in 0..5 {}");
        let nums: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(nums, vec!["0", "5"]);
    }

    #[test]
    fn escaped_quotes_in_strings() {
        let ids = idents("let s = \"a \\\" b\"; tail");
        assert!(ids.contains(&"tail".to_string()));
    }

    #[test]
    fn byte_strings_process_escapes() {
        // An escaped quote inside a byte string must not terminate the
        // literal — otherwise its contents leak into the token stream
        // and can spoof rule matches.
        let ids = idents("let s = b\"\\\" m.lock().unwrap() \\\"\"; tail");
        assert_eq!(ids, vec!["let", "s", "tail"]);
    }

    #[test]
    fn raw_strings_hide_their_contents() {
        let ids = idents("let s = r#\"m.lock().unwrap()\"#; tail");
        assert_eq!(ids, vec!["let", "s", "tail"]);
    }

    #[test]
    fn raw_identifiers_are_idents() {
        let (toks, _) = tokenize("let r#type = 1; r#match(x);");
        let ids: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(ids, vec!["let", "type", "match", "x"]);
    }

    #[test]
    fn nested_block_comments_hide_violations() {
        let (toks, comments) =
            tokenize("/* outer /* m.lock().unwrap() */ still comment */ ok");
        assert_eq!(toks.len(), 1);
        assert!(is_ident(&toks[0], "ok"));
        assert_eq!(comments.len(), 1);
    }
}
