//! Decomposition strategies — the heart of FlashBias (§3.2, Table 1).
//!
//! * [`Strategy::Exact`] — closed-form factors from a [`crate::bias::ExactBias`].
//! * [`Strategy::Svd`] — truncated SVD at a fixed rank or an energy target
//!   (Remark 3.8), for learned-parameter biases (Swin, Pangu). Large
//!   tables at small fixed rank take the randomized range-finder path
//!   ([`crate::linalg::randomized_svd_factors`], Halko et al.); the
//!   one-sided Jacobi stays the exact reference oracle everywhere else
//!   (see [`uses_randomized_svd`]).
//! * [`Strategy::Neural`] — token-wise MLP factor functions fitted with
//!   hand-rolled backprop + Adam against Eq. (5), for dynamic biases
//!   (AlphaFold pair bias, gravity, spherical).
//! * [`Strategy::Dense`] — keep the dense matrix (the baseline).
//!
//! Plus the Appendix J extension: a low-rank + sparse split for biases
//! with a full-rank tail (e.g. diagonal-heavy matrices).
//!
//! Amortization of these mechanisms — reuse across repeated plans,
//! serving workers and process restarts — lives one layer up in
//! [`crate::factorstore`]; this module stays the pure math.

use crate::linalg;
use crate::tensor::{Strip, StripDType, Tensor};
use crate::util::Xoshiro256;

pub mod neural;

pub use neural::{Mlp, NeuralConfig, NeuralDecomposition};

/// How to pick the SVD truncation rank.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RankSelect {
    /// Fixed rank R.
    Fixed(usize),
    /// Smallest R keeping ≥ this squared-singular-value energy fraction.
    Energy(f64),
}

/// A decomposition strategy for one bias matrix.
#[derive(Clone, Debug)]
pub enum Strategy {
    /// Use caller-provided exact factors (Table 1a).
    Exact,
    /// Truncated SVD (Table 1b).
    Svd(RankSelect),
    /// Token-wise neural factor functions (Table 1c).
    Neural(NeuralConfig),
    /// No decomposition — dense baseline.
    Dense,
}

/// The result of decomposing a bias: factor strips + bookkeeping.
///
/// The strips are stored as [`Strip`]s so they can carry a
/// reduced-precision [`StripDType`] (bf16/f16/i8) end to end — through
/// the `FactorStore`, jsonlite persistence, and the kernel's tile-local
/// contraction — while every arithmetic consumer still sees f32.
#[derive(Clone, Debug)]
pub struct Factors {
    pub phi_q: Strip,
    pub phi_k: Strip,
    /// Relative Frobenius reconstruction error against the dense bias
    /// (for quantized strips: including the measured quantization
    /// bound, see [`quantize_factors`]).
    pub rel_err: f32,
    /// Rank actually used.
    pub rank: usize,
}

impl Factors {
    /// Wrap exact f32 factor strips (the decomposition mechanisms all
    /// produce f32; quantization is a separate, policy-gated step).
    pub fn from_tensors(phi_q: Tensor, phi_k: Tensor, rel_err: f32,
                        rank: usize) -> Self {
        Self {
            phi_q: Strip::from_f32(phi_q),
            phi_k: Strip::from_f32(phi_k),
            rel_err,
            rank,
        }
    }

    /// Stored dtype of the strips (both strips always share one).
    pub fn dtype(&self) -> StripDType {
        debug_assert_eq!(self.phi_q.dtype(), self.phi_k.dtype());
        self.phi_q.dtype()
    }

    /// Storage in bytes of the factor pair (Thm 3.2: Θ((N+M)·R)), at
    /// the strips' *stored* width — bf16 factors report half the f32
    /// bytes, and this is what the `FactorStore` byte budget charges.
    pub fn size_bytes(&self) -> usize {
        self.phi_q.size_bytes() + self.phi_k.size_bytes()
    }

    /// Reconstruct the dense bias (test/inspection path only).
    pub fn reconstruct(&self) -> Tensor {
        self.phi_q.to_tensor().matmul_t(&self.phi_k.to_tensor())
    }
}

/// Re-encode a decomposition's strips at `dtype`, returning the
/// quantized factors and the *measured* relative error the quantization
/// adds to the reconstructed bias:
///
/// `‖Δφ_q φ_kᵀ‖_F + ‖φ_q Δφ_kᵀ‖_F + ‖Δφ_q Δφ_kᵀ‖_F` over
/// `‖φ_q φ_kᵀ‖_F` — an upper bound on `‖b̂_quant − b̂‖_F / ‖b̂‖_F` by
/// the triangle inequality, computed exactly via Gram matrices
/// ([`linalg::factored_frob_norm`]) in O((N+M)R² + R³) without ever
/// materializing an N×M matrix.
///
/// The returned `rel_err` is the input's `rel_err` plus this bound, so
/// downstream accuracy accounting (planner gates, property tests) sees
/// the end-to-end figure. Quantizing to [`StripDType::F32`] is a no-op
/// with a zero bound.
pub fn quantize_factors(f: &Factors, dtype: StripDType)
                        -> (Factors, f32) {
    if dtype == StripDType::F32 && f.dtype() == StripDType::F32 {
        return (f.clone(), 0.0);
    }
    let pq = f.phi_q.to_tensor();
    let pk = f.phi_k.to_tensor();
    let (sq, sk) = (Strip::quantize(&pq, dtype),
                    Strip::quantize(&pk, dtype));
    let dq = sq.to_tensor().sub(&pq);
    let dk = sk.to_tensor().sub(&pk);
    let den = linalg::factored_frob_norm(&pq, &pk);
    let num = linalg::factored_frob_norm(&dq, &pk)
        + linalg::factored_frob_norm(&pq, &dk)
        + linalg::factored_frob_norm(&dq, &dk);
    let bound = if den > 0.0 {
        (num / den) as f32
    } else if num > 0.0 {
        f32::INFINITY
    } else {
        0.0
    };
    let out = Factors {
        phi_q: sq,
        phi_k: sk,
        rel_err: f.rel_err + bound,
        rank: f.rank,
    };
    (out, bound)
}

/// Typed failure from [`decompose`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecomposeError {
    /// `Strategy::Exact` has no dense matrix to approximate — the closed
    /// form lives with the caller. Route exact biases through
    /// [`from_exact`] (or `plan::BiasSpec`, which carries the closed
    /// form itself).
    ExactNeedsClosedForm,
}

impl std::fmt::Display for DecomposeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecomposeError::ExactNeedsClosedForm => write!(
                f,
                "Strategy::Exact needs closed-form factors; use \
                 from_exact() or a plan::BiasSpec"
            ),
        }
    }
}

impl std::error::Error for DecomposeError {}

/// Smallest `min(N, M)` at which [`Strategy::Svd`] switches from the
/// exact one-sided Jacobi to the randomized range finder. Below this
/// the Jacobi is fast and bit-reproducible; above it the sketch's
/// O(N·M·(R+p)) beats the Jacobi's O(N·M²) decisively.
pub const RANDOMIZED_SVD_MIN_DIM: usize = 256;
/// Sketch oversampling `p` (Halko et al. recommend 5–10).
pub const RANDOMIZED_OVERSAMPLE: usize = 8;
/// Subspace power iterations (sharpens slowly decaying spectra).
pub const RANDOMIZED_POWER_ITERS: usize = 2;

/// Whether [`Strategy::Svd`] at this geometry takes the randomized
/// range-finder path: the table is large AND the target rank is small
/// enough that the sketch stays thin relative to the matrix.
pub fn uses_randomized_svd(n: usize, m: usize, rank: usize) -> bool {
    let k = n.min(m);
    k >= RANDOMIZED_SVD_MIN_DIM && rank + RANDOMIZED_OVERSAMPLE <= k / 4
}

/// Decompose a dense bias with the requested strategy.
///
/// For [`Strategy::Exact`] pass the closed-form factors through
/// [`from_exact`] instead (there is no dense matrix to approximate);
/// requesting it here is a typed error, not a panic, so policy layers
/// can route it. [`Strategy::Dense`] returns `Ok(None)` (no factors —
/// keep the matrix).
pub fn decompose(bias: &Tensor, strategy: &Strategy, rng: &mut Xoshiro256)
                 -> Result<Option<Factors>, DecomposeError> {
    match strategy {
        Strategy::Exact => Err(DecomposeError::ExactNeedsClosedForm),
        Strategy::Dense => Ok(None),
        Strategy::Svd(sel) => {
            let (n, m) = (bias.shape()[0], bias.shape()[1]);
            let (pq, pk) = match *sel {
                RankSelect::Energy(target) => {
                    // one Jacobi SVD serves both the energy scan and
                    // the truncation — never decompose twice
                    let full = linalg::svd(bias);
                    let rank =
                        linalg::rank_for_energy_in(&full.s, target);
                    linalg::factors_from_svd(&full, rank)
                }
                RankSelect::Fixed(rank)
                    if uses_randomized_svd(n, m, rank) =>
                {
                    linalg::randomized_svd_factors(
                        bias,
                        rank,
                        RANDOMIZED_OVERSAMPLE,
                        RANDOMIZED_POWER_ITERS,
                        rng,
                    )
                }
                RankSelect::Fixed(rank) => {
                    linalg::svd_factors(bias, rank)
                }
            };
            let rel_err = linalg::reconstruction_error(bias, &pq, &pk);
            // record the rank actually factored: a requested rank
            // above min(N, M) is clamped by the SVD, and `rank` must
            // always equal the strips' column count (persistence
            // validates entries against it)
            let rank = pq.shape()[1];
            Ok(Some(Factors::from_tensors(pq, pk, rel_err, rank)))
        }
        Strategy::Neural(cfg) => {
            // Without token sources, use normalized row/col indices as the
            // source coordinates (positional biases); callers with real
            // sources should use neural::NeuralDecomposition directly.
            let (n, m) = (bias.shape()[0], bias.shape()[1]);
            let xq = Tensor::from_fn(&[n, 1], |ix| ix[0] as f32 / n as f32);
            let xk = Tensor::from_fn(&[m, 1], |ix| ix[0] as f32 / m as f32);
            let nd = NeuralDecomposition::fit(&xq, &xk, bias, cfg, rng);
            let pq = nd.phi_q(&xq);
            let pk = nd.phi_k(&xk);
            let rel_err = linalg::reconstruction_error(bias, &pq, &pk);
            Ok(Some(Factors::from_tensors(pq, pk, rel_err, cfg.rank)))
        }
    }
}

/// Wrap the closed-form factors of an exact bias (rel_err is checked, and
/// should be ~0 up to f32 rounding).
pub fn from_exact<B: crate::bias::ExactBias>(bias: &B) -> Factors {
    let (pq, pk) = bias.factors();
    let dense = bias.dense();
    let rel_err = linalg::reconstruction_error(&dense, &pq, &pk);
    Factors::from_tensors(pq, pk, rel_err, bias.rank())
}

// ---------------------------------------------------------------------------
// Appendix J: low-rank + sparse split
// ---------------------------------------------------------------------------

/// Low-rank + sparse decomposition `b ≈ φ_q φ_kᵀ + t` where `t` keeps the
/// largest-magnitude residual entries (a practical proxy for the convex
/// program in Appendix J Eq. (20)).
#[derive(Clone, Debug)]
pub struct LowRankSparse {
    pub factors: Factors,
    /// Sparse residual as (row, col, value) triplets.
    pub sparse: Vec<(usize, usize, f32)>,
    pub rel_err: f32,
}

impl LowRankSparse {
    /// Alternate: truncated SVD of (b − sparse), then re-pick the sparse
    /// support from the residual. `sparse_frac` bounds the kept entries.
    pub fn fit(bias: &Tensor, rank: usize, sparse_frac: f64,
               iters: usize) -> Self {
        let (n, m) = (bias.shape()[0], bias.shape()[1]);
        let keep = ((n * m) as f64 * sparse_frac).ceil() as usize;
        let mut sparse: Vec<(usize, usize, f32)> = Vec::new();
        let mut factors = None;
        for _ in 0..iters.max(1) {
            // low-rank pass on b − t
            let mut work = bias.clone();
            for &(i, j, v) in &sparse {
                work.set2(i, j, work.at2(i, j) - v);
            }
            let (pq, pk) = linalg::svd_factors(&work, rank);
            let recon = pq.matmul_t(&pk);
            // sparse pass on b − r: keep the top-|keep| magnitudes via
            // O(NM) selection, not an O(NM log NM) full sort
            let resid = bias.sub(&recon);
            let mut entries: Vec<(usize, usize, f32)> = (0..n)
                .flat_map(|i| {
                    let r = &resid;
                    (0..m).map(move |j| (i, j, r.at2(i, j)))
                })
                .collect();
            if keep > 0 && keep < entries.len() {
                entries.select_nth_unstable_by(keep - 1, |a, b| {
                    b.2.abs().total_cmp(&a.2.abs())
                });
            }
            entries.truncate(keep);
            sparse = entries;
            let rel_err = linalg::reconstruction_error(bias, &pq, &pk);
            let rank = pq.shape()[1];
            factors = Some(Factors::from_tensors(pq, pk, rel_err, rank));
        }
        // the loop above runs iters.max(1) >= 1 passes, so factors
        // is always Some here
        let factors = factors.unwrap();
        let mut approx = factors.reconstruct();
        for &(i, j, v) in &sparse {
            approx.set2(i, j, approx.at2(i, j) + v);
        }
        let rel_err = approx.rel_err(bias);
        Self {
            factors,
            sparse,
            rel_err,
        }
    }

    /// Reconstruct the dense approximation.
    pub fn reconstruct(&self) -> Tensor {
        let mut out = self.factors.reconstruct();
        for &(i, j, v) in &self.sparse {
            out.set2(i, j, out.at2(i, j) + v);
        }
        out
    }

    pub fn size_bytes(&self) -> usize {
        self.factors.size_bytes() + self.sparse.len() * 12
    }
}

// Factor reuse (offline SVD happens once; Table 4 notes 4.79 s for
// SwinV2) is the job of `crate::factorstore::FactorStore` — thread-safe,
// content-addressed, byte-budgeted, persistent — which replaced the
// string-keyed `FactorCache` that used to sit here unwired.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bias::{Alibi, ExactBias, SpatialDistance};

    #[test]
    fn exact_strategy_zero_error() {
        let f = from_exact(&Alibi::new(32, 32, 0.25));
        assert!(f.rel_err < 1e-5);
        assert_eq!(f.rank, 2);
        assert_eq!(f.size_bytes(), (32 + 32) * 2 * 4);
    }

    #[test]
    fn svd_fixed_rank() {
        let mut rng = Xoshiro256::new(0);
        let a = Tensor::randn(&[24, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[20, 4], 1.0, &mut rng);
        let bias = a.matmul_t(&b);
        let f = decompose(&bias, &Strategy::Svd(RankSelect::Fixed(4)),
                          &mut rng)
            .unwrap()
            .unwrap();
        assert!(f.rel_err < 1e-3, "rel_err {}", f.rel_err);
        assert_eq!(f.rank, 4);
    }

    #[test]
    fn exact_strategy_is_typed_error() {
        let mut rng = Xoshiro256::new(9);
        let bias = Tensor::randn(&[8, 8], 1.0, &mut rng);
        assert!(matches!(
            decompose(&bias, &Strategy::Exact, &mut rng),
            Err(DecomposeError::ExactNeedsClosedForm)
        ));
    }

    #[test]
    fn svd_energy_target_meets_error_bound() {
        let biases = crate::bias::swin_relative_bias((8, 8), 1, 3, 6, 0.02);
        let mut rng = Xoshiro256::new(1);
        let f = decompose(&biases[0],
                          &Strategy::Svd(RankSelect::Energy(0.99)), &mut rng)
            .unwrap()
            .unwrap();
        // 99% energy → ≤ 10% Frobenius error (Eckart–Young: sqrt(1−0.99))
        assert!(f.rel_err <= 0.11, "rel_err {}", f.rel_err);
        assert!(f.rank < 64);
    }

    #[test]
    fn dense_strategy_returns_none() {
        let mut rng = Xoshiro256::new(2);
        let bias = Tensor::randn(&[8, 8], 1.0, &mut rng);
        assert!(decompose(&bias, &Strategy::Dense, &mut rng)
            .unwrap()
            .is_none());
    }

    #[test]
    fn neural_strategy_fits_positional_bias() {
        // ALiBi-like positional bias from index sources
        let alibi = Alibi::new(24, 24, 0.5).dense();
        let mut rng = Xoshiro256::new(3);
        let cfg = NeuralConfig {
            rank: 8,
            hidden: 32,
            steps: 800,
            lr: 5e-3,
            ..NeuralConfig::default()
        };
        let f = decompose(&alibi, &Strategy::Neural(cfg), &mut rng)
            .unwrap()
            .unwrap();
        assert!(f.rel_err < 0.2, "rel_err {}", f.rel_err);
    }

    #[test]
    fn storage_matches_thm_3_2() {
        // Thm 3.2: factored storage is Θ((N+M)·R) vs dense N·M
        let mut rng = Xoshiro256::new(4);
        let spatial = {
            let x = Tensor::randn(&[64, 3], 1.0, &mut rng);
            SpatialDistance::new(x.clone(), x, None)
        };
        let f = from_exact(&spatial);
        assert_eq!(f.size_bytes(), (64 + 64) * 9 * 4);
        let dense_bytes = spatial.dense().size_bytes();
        assert!(f.size_bytes() < dense_bytes / 3);
    }

    #[test]
    fn lowrank_sparse_beats_pure_svd_on_diagonal_heavy() {
        // Appendix J: a low-rank matrix plus a strong diagonal (the
        // gravity-style failure mode of pure truncation)
        let mut rng = Xoshiro256::new(5);
        let a = Tensor::randn(&[32, 3], 1.0, &mut rng);
        let mut bias = a.matmul_t(&a);
        for i in 0..32 {
            bias.set2(i, i, bias.at2(i, i) + 10.0);
        }
        let pure = decompose(&bias, &Strategy::Svd(RankSelect::Fixed(3)),
                             &mut rng)
            .unwrap()
            .unwrap();
        let split = LowRankSparse::fit(&bias, 3, 32.0 / (32.0 * 32.0), 2);
        assert!(
            split.rel_err < pure.rel_err * 0.8,
            "split {} vs pure {}",
            split.rel_err,
            pure.rel_err
        );
    }

    #[test]
    fn lowrank_sparse_reconstruct_consistent() {
        let mut rng = Xoshiro256::new(6);
        let bias = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let split = LowRankSparse::fit(&bias, 4, 0.05, 2);
        let recon = split.reconstruct();
        assert!((recon.rel_err(&bias) - split.rel_err).abs() < 1e-5);
        assert!(split.size_bytes() > 0);
    }

    #[test]
    fn quantize_factors_bound_is_a_real_upper_bound() {
        let mut rng = Xoshiro256::new(31);
        let a = Tensor::randn(&[40, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[36, 5], 1.0, &mut rng);
        let bias = a.matmul_t(&b);
        let f = decompose(&bias, &Strategy::Svd(RankSelect::Fixed(5)),
                          &mut rng)
            .unwrap()
            .unwrap();
        for dtype in [StripDType::Bf16, StripDType::F16, StripDType::I8] {
            let (qf, bound) = quantize_factors(&f, dtype);
            assert_eq!(qf.dtype(), dtype);
            assert_eq!(qf.rank, f.rank);
            // the Gram-matrix bound must dominate the true quantization
            // error of the materialized bias
            let actual =
                qf.reconstruct().rel_err(&f.reconstruct()) as f64;
            assert!(actual <= bound as f64 + 1e-6,
                    "{dtype}: actual {actual} > bound {bound}");
            assert!(bound > 0.0 && bound < 0.05, "{dtype}: {bound}");
            assert!(qf.rel_err >= f.rel_err);
            // bytes shrink by the dtype width
            assert!(qf.size_bytes() < f.size_bytes());
        }
    }

    #[test]
    fn quantize_factors_f32_is_noop() {
        let f = from_exact(&Alibi::new(16, 16, 0.5));
        let (qf, bound) = quantize_factors(&f, StripDType::F32);
        assert_eq!(bound, 0.0);
        assert_eq!(qf.size_bytes(), f.size_bytes());
        assert_eq!(qf.phi_q, f.phi_q);
        assert_eq!(qf.rel_err, f.rel_err);
    }

    #[test]
    fn randomized_gate_targets_large_thin_decompositions() {
        assert!(!uses_randomized_svd(144, 144, 16), "Swin stays exact");
        assert!(uses_randomized_svd(512, 512, 16));
        assert!(uses_randomized_svd(2048, 1024, 32));
        // sketch as wide as the table buys nothing
        assert!(!uses_randomized_svd(512, 512, 200));
    }

    #[test]
    fn svd_strategy_randomized_path_stays_accurate() {
        // large exactly-low-rank table: the randomized path must fire
        // (gate test above) and still recover the factorization
        let mut rng = Xoshiro256::new(7);
        let a = Tensor::randn(&[320, 6], 1.0, &mut rng);
        let b = Tensor::randn(&[300, 6], 1.0, &mut rng);
        let bias = a.matmul_t(&b);
        assert!(uses_randomized_svd(320, 300, 6));
        let f = decompose(&bias, &Strategy::Svd(RankSelect::Fixed(6)),
                          &mut rng)
            .unwrap()
            .unwrap();
        assert_eq!(f.rank, 6);
        assert!(f.rel_err < 1e-3, "rel_err {}", f.rel_err);
    }
}
