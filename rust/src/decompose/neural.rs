//! Neural decomposition (Table 1c): token-wise MLP factor functions
//! `φ̂_q, φ̂_k : R^{C'} → R^R` fitted against Eq. (5),
//! `min ‖φ̂_q(x_q) φ̂_k(x_k)ᵀ − f(x_q, x_k)‖²`,
//! with hand-rolled backprop + Adam (no autodiff crates in the vendored
//! universe). Architecture follows Appendix H Table 12: three linear
//! layers with tanh in between.

use crate::tensor::Tensor;
use crate::util::Xoshiro256;

/// Three-layer tanh MLP with Adam state per parameter.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub w1: Tensor,
    pub b1: Vec<f32>,
    pub w2: Tensor,
    pub b2: Vec<f32>,
    pub w3: Tensor,
    pub b3: Vec<f32>,
}

/// Forward-pass activations kept for backprop.
struct Acts {
    x: Tensor,
    h1: Tensor,
    h2: Tensor,
}

/// Gradients in the same layout as [`Mlp`].
struct Grads {
    w1: Tensor,
    b1: Vec<f32>,
    w2: Tensor,
    b2: Vec<f32>,
    w3: Tensor,
    b3: Vec<f32>,
}

impl Mlp {
    pub fn init(c_in: usize, hidden: usize, c_out: usize,
                rng: &mut Xoshiro256) -> Self {
        let lin = |fan_in: usize, fan_out: usize, rng: &mut Xoshiro256| {
            let scale = 1.0 / (fan_in as f32).sqrt();
            Tensor::new(
                &[fan_in, fan_out],
                (0..fan_in * fan_out)
                    .map(|_| (rng.uniform(-1.0, 1.0) as f32) * scale)
                    .collect(),
            )
        };
        Self {
            w1: lin(c_in, hidden, rng),
            b1: vec![0.0; hidden],
            w2: lin(hidden, hidden, rng),
            b2: vec![0.0; hidden],
            w3: lin(hidden, c_out, rng),
            b3: vec![0.0; c_out],
        }
    }

    fn add_bias(x: &Tensor, b: &[f32]) -> Tensor {
        let (n, m) = (x.shape()[0], x.shape()[1]);
        Tensor::from_fn(&[n, m], |ix| x.at2(ix[0], ix[1]) + b[ix[1]])
    }

    /// Forward pass returning (output, activations-for-backprop).
    fn forward_acts(&self, x: &Tensor) -> (Tensor, Acts) {
        let h1 = Self::add_bias(&x.matmul(&self.w1), &self.b1)
            .map(f32::tanh);
        let h2 = Self::add_bias(&h1.matmul(&self.w2), &self.b2)
            .map(f32::tanh);
        let y = Self::add_bias(&h2.matmul(&self.w3), &self.b3);
        (
            y,
            Acts {
                x: x.clone(),
                h1,
                h2,
            },
        )
    }

    /// Plain forward pass.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_acts(x).0
    }

    /// Backprop `d_out (N × c_out)` through the net, returning gradients.
    fn backward(&self, acts: &Acts, d_out: &Tensor) -> Grads {
        let col_sum = |t: &Tensor| -> Vec<f32> {
            let (n, m) = (t.shape()[0], t.shape()[1]);
            let mut out = vec![0.0f32; m];
            for i in 0..n {
                for (o, &v) in out.iter_mut().zip(t.row(i)) {
                    *o += v;
                }
            }
            out
        };
        // layer 3
        let gw3 = acts.h2.t().matmul(d_out);
        let gb3 = col_sum(d_out);
        let dh2 = d_out.matmul(&self.w3.t());
        // tanh'
        let dz2 = dh2.zip(&acts.h2, |d, h| d * (1.0 - h * h));
        let gw2 = acts.h1.t().matmul(&dz2);
        let gb2 = col_sum(&dz2);
        let dh1 = dz2.matmul(&self.w2.t());
        let dz1 = dh1.zip(&acts.h1, |d, h| d * (1.0 - h * h));
        let gw1 = acts.x.t().matmul(&dz1);
        let gb1 = col_sum(&dz1);
        Grads {
            w1: gw1,
            b1: gb1,
            w2: gw2,
            b2: gb2,
            w3: gw3,
            b3: gb3,
        }
    }
}

/// Adam moment buffers mirroring an [`Mlp`].
struct AdamState {
    m: Mlp,
    v: Mlp,
    step: usize,
}

impl AdamState {
    fn zeros_like(mlp: &Mlp) -> Self {
        let z = |t: &Tensor| Tensor::zeros(t.shape());
        let zb = |b: &[f32]| vec![0.0; b.len()];
        let zero = Mlp {
            w1: z(&mlp.w1),
            b1: zb(&mlp.b1),
            w2: z(&mlp.w2),
            b2: zb(&mlp.b2),
            w3: z(&mlp.w3),
            b3: zb(&mlp.b3),
        };
        Self {
            m: zero.clone(),
            v: zero,
            step: 0,
        }
    }

    fn update(&mut self, params: &mut Mlp, grads: &Grads, lr: f32) {
        self.step += 1;
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        let bc1 = 1.0 - b1.powi(self.step as i32);
        let bc2 = 1.0 - b2.powi(self.step as i32);
        let upd = |p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32]| {
            for i in 0..p.len() {
                m[i] = b1 * m[i] + (1.0 - b1) * g[i];
                v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
                let mh = m[i] / bc1;
                let vh = v[i] / bc2;
                p[i] -= lr * mh / (vh.sqrt() + eps);
            }
        };
        upd(params.w1.data_mut(), grads.w1.data(), self.m.w1.data_mut(),
            self.v.w1.data_mut());
        upd(&mut params.b1, &grads.b1, &mut self.m.b1, &mut self.v.b1);
        upd(params.w2.data_mut(), grads.w2.data(), self.m.w2.data_mut(),
            self.v.w2.data_mut());
        upd(&mut params.b2, &grads.b2, &mut self.m.b2, &mut self.v.b2);
        upd(params.w3.data_mut(), grads.w3.data(), self.m.w3.data_mut(),
            self.v.w3.data_mut());
        upd(&mut params.b3, &grads.b3, &mut self.m.b3, &mut self.v.b3);
    }
}

/// Hyperparameters for the neural fit (Appendix H Table 12 defaults,
/// scaled down).
#[derive(Clone, Copy, Debug)]
pub struct NeuralConfig {
    pub rank: usize,
    pub hidden: usize,
    pub steps: usize,
    pub lr: f32,
    /// Multiply lr by `lr_decay` every `lr_decay_every` steps.
    pub lr_decay: f32,
    pub lr_decay_every: usize,
    pub seed: u64,
}

impl Default for NeuralConfig {
    fn default() -> Self {
        Self {
            rank: 16,
            hidden: 64,
            steps: 1000,
            lr: 1e-3,
            lr_decay: 0.95,
            lr_decay_every: 50,
            seed: 0,
        }
    }
}

/// A fitted factor-function pair.
#[derive(Clone, Debug)]
pub struct NeuralDecomposition {
    pub mlp_q: Mlp,
    pub mlp_k: Mlp,
    pub loss_history: Vec<f32>,
}

impl NeuralDecomposition {
    /// Fit `φ̂_q(x_q) φ̂_k(x_k)ᵀ ≈ target` by full-batch Adam on Eq. (5).
    pub fn fit(
        xq: &Tensor,
        xk: &Tensor,
        target: &Tensor,
        cfg: &NeuralConfig,
        rng: &mut Xoshiro256,
    ) -> Self {
        let _ = rng; // seeding comes from cfg for reproducibility
        let mut seed_rng = Xoshiro256::new(cfg.seed);
        let mut mlp_q = Mlp::init(xq.shape()[1], cfg.hidden, cfg.rank,
                                  &mut seed_rng);
        let mut mlp_k = Mlp::init(xk.shape()[1], cfg.hidden, cfg.rank,
                                  &mut seed_rng);
        let mut adam_q = AdamState::zeros_like(&mlp_q);
        let mut adam_k = AdamState::zeros_like(&mlp_k);
        let (n, m) = (target.shape()[0], target.shape()[1]);
        let inv_nm = 1.0 / (n * m) as f32;
        let mut lr = cfg.lr;
        let mut losses = Vec::with_capacity(cfg.steps);
        for step in 1..=cfg.steps {
            let (fq, acts_q) = mlp_q.forward_acts(xq);
            let (fk, acts_k) = mlp_k.forward_acts(xk);
            let approx = fq.matmul_t(&fk);
            let diff = approx.sub(target);
            let loss =
                diff.data().iter().map(|&d| d * d).sum::<f32>() * inv_nm;
            losses.push(loss);
            // dL/dA = 2(A − T)/NM; dFq = dA·Fk; dFk = dAᵀ·Fq
            let d_a = diff.scale(2.0 * inv_nm);
            let d_fq = d_a.matmul(&fk);
            let d_fk = d_a.t().matmul(&fq);
            let gq = mlp_q.backward(&acts_q, &d_fq);
            let gk = mlp_k.backward(&acts_k, &d_fk);
            adam_q.update(&mut mlp_q, &gq, lr);
            adam_k.update(&mut mlp_k, &gk, lr);
            if step % cfg.lr_decay_every == 0 {
                lr *= cfg.lr_decay;
            }
        }
        Self {
            mlp_q,
            mlp_k,
            loss_history: losses,
        }
    }

    /// Factor strip for query sources: (N, R).
    pub fn phi_q(&self, xq: &Tensor) -> Tensor {
        self.mlp_q.forward(xq)
    }

    /// Factor strip for key sources: (M, R).
    pub fn phi_k(&self, xk: &Tensor) -> Tensor {
        self.mlp_k.forward(xk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finite_diff_check(mlp: &Mlp, x: &Tensor) {
        // numeric gradient of L = sum(y²)/2 wrt w3[0,0]
        let (y, acts) = mlp.forward_acts(x);
        let grads = mlp.backward(&acts, &y);
        let eps = 1e-3f32;
        let mut plus = mlp.clone();
        plus.w3.data_mut()[0] += eps;
        let mut minus = mlp.clone();
        minus.w3.data_mut()[0] -= eps;
        let loss = |m: &Mlp| -> f32 {
            let out = m.forward(x);
            out.data().iter().map(|&v| v * v).sum::<f32>() / 2.0
        };
        let numeric = (loss(&plus) - loss(&minus)) / (2.0 * eps);
        let analytic = grads.w3.data()[0];
        assert!(
            (numeric - analytic).abs() < 2e-2 * numeric.abs().max(1.0),
            "numeric {numeric} vs analytic {analytic}"
        );
    }

    #[test]
    fn backprop_matches_finite_differences() {
        let mut rng = Xoshiro256::new(0);
        let mlp = Mlp::init(3, 8, 4, &mut rng);
        let x = Tensor::randn(&[5, 3], 1.0, &mut rng);
        finite_diff_check(&mlp, &x);
    }

    #[test]
    fn fits_exact_lowrank_target() {
        // target that IS rank-2 from smooth sources: must fit well
        let mut rng = Xoshiro256::new(1);
        let n = 24;
        let x = Tensor::from_fn(&[n, 1], |ix| ix[0] as f32 / n as f32);
        let pq = x.map(|v| (2.0 * v).sin());
        let pk = x.map(|v| (2.0 * v).cos());
        let target = Tensor::from_fn(&[n, n], |ix| {
            pq.data()[ix[0]] * pk.data()[ix[1]] + 0.5
        });
        let cfg = NeuralConfig {
            rank: 4,
            hidden: 24,
            steps: 1200,
            lr: 1e-2,
            ..NeuralConfig::default()
        };
        let nd = NeuralDecomposition::fit(&x, &x, &target, &cfg, &mut rng);
        let approx = nd.phi_q(&x).matmul_t(&nd.phi_k(&x));
        assert!(
            approx.rel_err(&target) < 0.1,
            "rel_err {}",
            approx.rel_err(&target)
        );
    }

    #[test]
    fn loss_decreases_monotonically_in_trend() {
        let mut rng = Xoshiro256::new(2);
        let x = Tensor::randn(&[16, 2], 1.0, &mut rng);
        let target = crate::bias::spherical_bias(&x, &x);
        let cfg = NeuralConfig {
            rank: 8,
            hidden: 24,
            steps: 400,
            lr: 3e-3,
            ..NeuralConfig::default()
        };
        let nd = NeuralDecomposition::fit(&x, &x, &target, &cfg, &mut rng);
        let first = nd.loss_history[..10].iter().sum::<f32>() / 10.0;
        let last = nd.loss_history[nd.loss_history.len() - 10..]
            .iter()
            .sum::<f32>()
            / 10.0;
        assert!(last < first * 0.5, "first {first} last {last}");
    }

    #[test]
    fn tokenwise_property() {
        // Remark 3.6: permuting input rows permutes outputs identically
        let mut rng = Xoshiro256::new(3);
        let mlp = Mlp::init(2, 8, 4, &mut rng);
        let x = Tensor::randn(&[10, 2], 1.0, &mut rng);
        let out = mlp.forward(&x);
        // reverse rows
        let rev = Tensor::from_fn(&[10, 2], |ix| x.at2(9 - ix[0], ix[1]));
        let out_rev = mlp.forward(&rev);
        for i in 0..10 {
            for j in 0..4 {
                assert!((out.at2(9 - i, j) - out_rev.at2(i, j)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let mut rng = Xoshiro256::new(4);
        let x = Tensor::randn(&[8, 1], 1.0, &mut rng);
        let target = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let cfg = NeuralConfig {
            steps: 50,
            ..NeuralConfig::default()
        };
        let a = NeuralDecomposition::fit(&x, &x, &target, &cfg, &mut rng);
        let b = NeuralDecomposition::fit(&x, &x, &target, &cfg, &mut rng);
        assert_eq!(a.loss_history, b.loss_history);
    }
}
