//! CLI + config + run loop — the `flashbias` binary's brain.
//!
//! Subcommands:
//!
//! * `list`                — artifacts in the manifest.
//! * `verify [--only RE]`  — replay every artifact against its recorded
//!   expected outputs (the cross-layer integrity check).
//! * `run <artifact> [-n ITERS]` — execute one artifact, print timing.
//! * `serve [--requests N] [--workers W]` — synthetic serving loop through
//!   the full coordinator (router → batcher → workers), print metrics.
//! * `serve --listen ADDR` — the TCP serving front-end instead: a
//!   [`netserver::NetServer`] on a synthetic demo plan (no artifacts
//!   needed), driven by the `loadgen` binary.
//! * `plan --bias KIND [...]` — run the Table 1 planner on a synthetic
//!   bias and print the emitted plan (no artifacts needed).
//! * `warm --store PATH`    — pre-decompose a bias zoo into an on-disk
//!   factor store (the paper's offline SVD, Table 4, as a command).
//! * `info`                — platform + manifest summary.
//!
//! `plan`, `serve` and `warm` share the tiered-store flags: `--store
//! PATH` amortizes SVD/neural decomposition through a persistent
//! [`crate::factorstore::FactorStore`] (loaded if present, saved back on
//! exit), `--store-budget BYTES` bounds resident factor bytes with
//! evictions spilling to a process-private scratch file instead of
//! being dropped, and
//! `--store-remote ADDR` warms from a peer's
//! [`crate::factorstore::FactorService`] (started by `serve
//! --store-serve ADDR`) before decomposing locally.

pub mod loadgen;
pub mod netserver;
pub mod queue;

pub use loadgen::{
    fetch_stats, run_wave, wait_ready, WaveConfig, WaveOutcome,
};
pub use netserver::{
    demo_plan_name, register_demo_plan, synthetic_qkv, synthetic_rows,
    NetServer,
};
pub use queue::{FlushPolicy, ServeConfig};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::bias;
use crate::coordinator::{
    Coordinator, CoordinatorConfig, RouteKey, Router,
};
use crate::factorstore::{FactorStore, RemoteStore};
use crate::iomodel::Geometry;
use crate::plan::{BiasSpec, PjrtExecutor, PlanOptions, Planner};
use crate::runtime::{HostValue, Runtime};
use crate::tensor::Tensor;
use crate::util::{bench_loop, human_bytes, human_secs, Timer, Xoshiro256};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

/// Flags that never take a value: `--verbose x` must not swallow the
/// positional `x` (a boolean flag used to eat the following artifact
/// name). `--flag=value` remains available to force any value.
const BOOL_FLAGS: &[&str] =
    &["causal", "jit", "verbose", "spawn", "check", "json"];

impl Cli {
    /// Hand-rolled parser: `cmd pos1 --flag value --flag=value
    /// --bool-flag`.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Cli> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut cli = Cli {
            command,
            ..Cli::default()
        };
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    cli.flags.insert(k.to_string(), v.to_string());
                    continue;
                }
                let value = if BOOL_FLAGS.contains(&name) {
                    "true".to_string()
                } else {
                    it.next_if(|v| !v.starts_with("--"))
                        .unwrap_or_else(|| "true".to_string())
                };
                cli.flags.insert(name.to_string(), value);
            } else {
                cli.positional.push(arg);
            }
        }
        Ok(cli)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    /// Boolean flag semantics: absent = false, present = true, and an
    /// explicit `--flag=false` / `--flag=0` turns it back off.
    pub fn flag_bool(&self, name: &str) -> bool {
        match self.flag(name) {
            None => false,
            Some("false") | Some("0") => false,
            Some(_) => true,
        }
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v}")),
        }
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got {v}")),
        }
    }
}

/// Config file: `key = value` lines, `#` comments (mini-TOML subset).
pub fn parse_config(text: &str) -> HashMap<String, String> {
    let mut out = HashMap::new();
    for line in text.lines() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some((k, v)) = line.split_once('=') {
            out.insert(
                k.trim().to_string(),
                v.trim().trim_matches('"').to_string(),
            );
        }
    }
    out
}

pub const USAGE: &str = "\
flashbias — FlashBias serving runtime (rust/JAX/Pallas reproduction)

USAGE: flashbias <COMMAND> [ARGS]

COMMANDS:
  info                         platform + manifest summary
  list                         list artifacts
  verify [--only REGEX-ISH]    replay artifacts vs recorded outputs
  run <ARTIFACT> [--iters N]   execute one artifact, print timing
  serve [--requests N] [--workers W] [--max-batch B] [--store PATH]
        [--store-budget BYTES] [--store-remote ADDR] [--store-serve ADDR]
                               synthetic serving loop, print metrics
                               (--store loads/saves a persistent factor
                               store; the coordinator's host-plan
                               registrations decompose through it, so a
                               warmed file plans with zero SVD work;
                               --store-serve exports the store to the
                               fleet over TCP)
  serve --listen ADDR [--n N] [--for SECS] [--workers W] [--max-batch B]
        [--queue-depth Q] [--max-batch-total-tokens T]
        [--waiting-served-ratio R] [--max-sessions S]
                               TCP serving front-end instead: admission
                               queue + continuous-batching dispatch over
                               length-prefixed JSON frames, serving a
                               synthetic causal-ALiBi demo plan at
                               context N (no artifacts needed); --for 0
                               (the default) serves until killed; drive
                               it with the `loadgen` binary
  plan --bias KIND [--n N] [--m M] [--c C] [--sram ELEMS] [--rank R]
       [--causal] [--jit] [--store PATH] [--store-budget BYTES]
       [--store-remote ADDR]
                               run the Table 1 planner on a synthetic bias
                               (KIND: none|alibi|spatial|cos-mult|swin|
                               pangu|dynamic|dense) and print the plan;
                               --store amortizes SVD/neural work through
                               an on-disk factor store
  warm --store PATH [--zoo swin,pangu] [--layers L] [--heads H] [--rank R]
       [--store-budget BYTES] [--store-remote ADDR]
                               pre-decompose a bias zoo into the factor
                               store (the Table 4 offline SVD, once);
                               --store-remote fetches from a peer's
                               factor service instead of re-running SVDs
  help                         this text

STORE TIERS: lookups fall resident -> spill file -> remote peer ->
  decompose. --store-budget caps resident bytes; evictions append to a
  process-private spill scratch file (PATH.spill.<pid>_<seq>, cleaned
  up on exit) and reload on demand (one disk read, never a repeated
  SVD).
";

/// Entry point used by main.rs (and tested directly).
pub fn run(cli: &Cli) -> Result<String> {
    match cli.command.as_str() {
        "help" | "" => Ok(USAGE.to_string()),
        "info" => cmd_info(),
        "list" => cmd_list(),
        "verify" => cmd_verify(cli),
        "run" => cmd_run(cli),
        "serve" => cmd_serve(cli),
        "plan" => cmd_plan(cli),
        "warm" => cmd_warm(cli),
        other => bail!("unknown command {other}\n{USAGE}"),
    }
}

fn cmd_info() -> Result<String> {
    let rt = Runtime::open_default()?;
    Ok(format!(
        "platform: {}\nartifacts: {}\n",
        rt.platform(),
        rt.names().len()
    ))
}

fn cmd_list() -> Result<String> {
    let rt = Runtime::open_default()?;
    let mut out = String::new();
    for name in rt.names() {
        let Some(spec) = rt.spec(name) else { continue };
        out.push_str(&format!(
            "{name:32} family={:12} variant={:10} n={}\n",
            spec.family(),
            spec.variant(),
            spec.seq_len()
        ));
    }
    Ok(out)
}

/// Max |a−b| across all f32 outputs.
fn max_abs_diff(a: &[HostValue], b: &[HostValue]) -> f32 {
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        if let (Some(tx), Some(ty)) = (x.as_f32(), y.as_f32()) {
            worst = worst.max(tx.sub(ty).max_abs());
        }
    }
    worst
}

fn cmd_verify(cli: &Cli) -> Result<String> {
    let rt = Runtime::open_default()?;
    let filter = cli.flag("only").unwrap_or("");
    let mut out = String::new();
    let mut failures = 0;
    for name in rt.names() {
        if !filter.is_empty() && !name.contains(filter) {
            continue;
        }
        let Some(spec) = rt.spec(name) else { continue };
        if spec.outputs.is_empty() {
            continue;
        }
        let exe = rt.load(name)?;
        let inputs = rt.example_inputs(name)?;
        let expected = rt.expected_outputs(name)?;
        let got = exe.run(&inputs)?;
        let diff = max_abs_diff(&got, &expected);
        let ok = diff < 2e-3;
        if !ok {
            failures += 1;
        }
        out.push_str(&format!(
            "{name:32} max|Δ|={diff:.2e} {}\n",
            if ok { "OK" } else { "FAIL" }
        ));
    }
    if failures > 0 {
        bail!("{failures} artifacts FAILED\n{out}");
    }
    Ok(out)
}

fn cmd_run(cli: &Cli) -> Result<String> {
    let artifact = cli
        .positional
        .first()
        .ok_or_else(|| anyhow!("run needs an artifact name"))?;
    let iters = cli.flag_usize("iters", 10)?;
    let rt = Runtime::open_default()?;
    let exe = rt.load_warm(artifact)?;
    let inputs = rt.example_inputs(artifact)?;
    // load_warm already executed these exact inputs once; a repeat
    // failing mid-bench is unrecoverable and aborting beats reporting
    // fake timings
    let stats = bench_loop(1, iters, || {
        exe.run(&inputs).expect("execute");
    });
    Ok(format!(
        "{artifact}: mean={} p50={} p99={} over {iters} iters\n",
        human_secs(stats.mean()),
        human_secs(stats.p50()),
        human_secs(stats.p99()),
    ))
}

/// A factor store assembled from the shared CLI flags.
struct CliStore {
    store: Arc<FactorStore>,
    /// `--store PATH`, when given (saves go here).
    path: Option<String>,
    /// Process-private scratch spill file (any `--store-budget` run):
    /// removed when the command finishes, so repeated CLI runs don't
    /// litter the disk — the in-memory spill index dies with the
    /// process, making the file useless afterwards anyway.
    scratch_spill: Option<String>,
}

impl Drop for CliStore {
    fn drop(&mut self) {
        if let Some(p) = &self.scratch_spill {
            // unlink-while-open is fine on unix; best-effort elsewhere
            let _ = std::fs::remove_file(p);
        }
    }
}

impl CliStore {
    /// Whether this run added content worth persisting: a local
    /// decomposition or a factor fetched from a peer.
    fn dirty(&self) -> bool {
        let s = self.store.stats();
        s.misses > 0 || s.remote_hits > 0
    }

    /// Save back to `--store PATH` when content arrived; returns the
    /// human-readable disposition for the command output.
    fn save_if_dirty(&self) -> Result<String> {
        match &self.path {
            Some(path) if self.dirty() => {
                self.store.save(path)?;
                Ok(format!(" (saved to {path})"))
            }
            Some(path) => Ok(format!(" ({path} unchanged)")),
            None => Ok(String::new()),
        }
    }
}

/// Assemble the tiered factor store the `--store`, `--store-budget`
/// and `--store-remote` flags describe; `None` when no store flag was
/// given. A budget enables the spill tier in a **process-private**
/// scratch file (`PATH.spill.<pid>_<seq>` next to the store, or in the
/// temp dir without a path) — the spill index lives in memory, so the
/// file is meaningless to any other process, and a shared name would
/// let a second serving process truncate the first one's live spill.
fn store_from_flags(cli: &Cli) -> Result<Option<CliStore>> {
    let path = cli.flag("store").map(str::to_string);
    let remote = cli.flag("store-remote").map(str::to_string);
    let budgeted = cli.flag("store-budget").is_some();
    if path.is_none() && remote.is_none() && !budgeted {
        return Ok(None);
    }
    let budget = cli.flag_usize("store-budget", usize::MAX)?;
    let mut store = FactorStore::new(budget);
    let mut scratch_spill = None;
    if budget != usize::MAX {
        // pid + per-process sequence: concurrent stores (a second
        // serving process on the same --store, parallel tests, library
        // use) must never share — and truncate — a live spill file
        static SCRATCH_SEQ: AtomicU64 = AtomicU64::new(0);
        let seq = SCRATCH_SEQ.fetch_add(1, Ordering::Relaxed);
        let pid = std::process::id();
        // attach the spill tier BEFORE absorbing the file, so a store
        // file larger than the budget spills its overflow instead of
        // dropping it
        let spill = match &path {
            Some(p) => format!("{p}.spill.{pid}_{seq}"),
            None => std::env::temp_dir()
                .join(format!("flashbias_spill_{pid}_{seq}.jsonl"))
                .to_string_lossy()
                .into_owned(),
        };
        scratch_spill = Some(spill.clone());
        store = store.spill_to(&spill)?;
    }
    if let Some(p) = &path {
        if std::path::Path::new(p).exists() {
            if let Err(e) = store.absorb(p) {
                // the CliStore that would clean the scratch spill up
                // on Drop is not built yet — don't leak the file
                if let Some(s) = &scratch_spill {
                    let _ = std::fs::remove_file(s);
                }
                return Err(e);
            }
        }
    }
    if let Some(addr) = remote {
        store.attach_remote(RemoteStore::new(addr));
    }
    Ok(Some(CliStore {
        store: Arc::new(store),
        path,
        scratch_spill,
    }))
}

/// Run the Table 1 planner on a synthetic bias and print the emitted
/// plan — the `BiasSpec → Planner → AttentionPlan` pipeline as a CLI.
fn cmd_plan(cli: &Cli) -> Result<String> {
    let kind = cli.flag("bias").unwrap_or("alibi");
    let n = cli.flag_usize("n", 256)?;
    let m = cli.flag_usize("m", n)?;
    let c = cli.flag_usize("c", 64)?;
    let sram = cli.flag_usize("sram", 100 * 1024 / 2)?;
    let causal = cli.flag_bool("causal");
    let jit = cli.flag_bool("jit");
    let rank_override = match cli.flag("rank") {
        Some(_) => Some(cli.flag_usize("rank", 0)?),
        None => None,
    };
    let mut rng = Xoshiro256::new(0);
    let (spec, n, m) = match kind {
        "none" => (BiasSpec::None, n, m),
        "alibi" => (BiasSpec::alibi(n, m, 0.25), n, m),
        "spatial" => {
            let xq = bias::synthetic_car_cloud(n, 0);
            let xk = if m == n {
                xq.clone()
            } else {
                bias::synthetic_car_cloud(m, 1)
            };
            (BiasSpec::spatial(xq, xk, None), n, m)
        }
        "cos-mult" => (BiasSpec::cos_multiplicative(n, m), n, m),
        "swin" => {
            let mut tables =
                bias::swin_relative_bias((12, 12), 1, 0, 6, 0.02);
            (BiasSpec::static_learned(tables.remove(0)), 144, 144)
        }
        "pangu" => {
            let mut tables =
                bias::pangu_relative_bias((2, 6, 12), 1, 0, 5, 0.02);
            (BiasSpec::static_learned(tables.remove(0)), 144, 144)
        }
        "dynamic" => {
            // neural fit is O(steps·N·hidden): keep the CLI snappy
            let nn = n.min(64);
            let x = Tensor::from_fn(&[nn, 2], |ix| {
                let t = ix[0] as f32 / nn as f32;
                if ix[1] == 0 { (6.28 * t).sin() } else { t }
            });
            let w = Tensor::randn(&[2, 2], 0.8, &mut rng);
            let proj = x.matmul(&w);
            let target = proj.matmul_t(&proj).map(|vv| (0.5 * vv).tanh());
            (BiasSpec::dynamic(x.clone(), x, target), nn, nn)
        }
        "dense" => {
            let table = Tensor::randn(&[n, m], 1.0, &mut rng);
            (BiasSpec::dense(table), n, m)
        }
        other => bail!("unknown bias kind {other}\n{USAGE}"),
    };
    let geo = Geometry { n, m, c, r: 0, sram };
    let opts = PlanOptions {
        causal,
        prefer_jit: jit,
        rank_override,
        verify_exact: false,
    };
    let planner = Planner::default();
    let (plan, store_note) = match store_from_flags(cli)? {
        Some(cs) => {
            let plan = planner.plan_with_store(&spec, &geo, &opts,
                                               &cs.store)?;
            // rewrite the file only when new content arrived (a local
            // decomposition or a remote fetch) — a pure-hit plan
            // leaves a warmed store untouched
            let disposition = cs.save_if_dirty()?;
            (plan,
             format!("{}{disposition}\n", cs.store.stats().summary()))
        }
        None => (planner.plan(&spec, &geo, &opts)?, String::new()),
    };
    Ok(format!(
        "bias: {kind} (N={n}, M={m}, C={c}, SRAM={sram} elems)\n\
         plan: {}\n\
         predicted HBM IO: {:.3e} elems vs dense-bias {:.3e} ({:.1}x)\n\
         bias storage: {}\n{store_note}",
        plan.summary(),
        plan.predicted_io,
        plan.dense_io,
        plan.io_saving(),
        human_bytes(plan.bias_storage_bytes as u64),
    ))
}

/// Pre-decompose a bias zoo into an on-disk factor store so later
/// `plan --store` / `serve --store` runs (and any process loading the
/// file) start warm — Table 4's "4.79 s of offline SVD, once" as a
/// command. Re-running is idempotent: already-stored biases are hits.
fn cmd_warm(cli: &Cli) -> Result<String> {
    let cs = match store_from_flags(cli)? {
        Some(cs) if cs.path.is_some() => cs,
        _ => bail!("warm needs --store PATH\n{USAGE}"),
    };
    let layers = cli.flag_usize("layers", 4)?;
    let heads = cli.flag_usize("heads", 4)?;
    let zoo = cli.flag("zoo").unwrap_or("swin,pangu");
    let rank_override = match cli.flag("rank") {
        Some(_) => Some(cli.flag_usize("rank", 0)?),
        None => None,
    };
    let store = &cs.store;
    let planner = Planner::default();
    let opts = PlanOptions {
        rank_override,
        ..PlanOptions::default()
    };
    // both zoos gather into (144, 144) windows
    let geo = Geometry::square(144, 64, 0, 100 * 1024 / 2);
    let timer = Timer::start();
    let mut planned = 0usize;
    for kind in zoo.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let tables_per_layer = |li: usize| match kind {
            "swin" => {
                Ok(bias::swin_relative_bias((12, 12), heads, li as u64,
                                            6, 0.02))
            }
            "pangu" => {
                Ok(bias::pangu_relative_bias((2, 6, 12), heads,
                                             li as u64, 5, 0.02))
            }
            other => Err(anyhow!("unknown zoo member {other} \
                                  (expected swin|pangu)")),
        };
        for li in 0..layers {
            for table in tables_per_layer(li)? {
                planner.plan_with_store(
                    &BiasSpec::static_learned(table),
                    &geo,
                    &opts,
                    store,
                )?;
                planned += 1;
            }
        }
    }
    // idempotent re-warm: a pure-hit pass leaves the file untouched;
    // remote fetches count as new content and are persisted
    let disposition = cs.save_if_dirty()?;
    Ok(format!(
        "warmed {planned} biases ({zoo}) in {}\n{}{disposition}\n",
        human_secs(timer.elapsed_secs()),
        store.stats().summary(),
    ))
}

// The one submit-with-backpressure policy, re-exported so the CLI
// loop, the network dispatch thread, and tests share it (this module
// used to carry its own copy, which had already drifted once).
pub use crate::coordinator::submit_with_retry;

/// What [`serve_loop`] observed; failures are reported after cleanup.
struct ServeOutcome {
    submitted: usize,
    completed: usize,
    failures: Vec<String>,
    wall_secs: f64,
}

/// The serving loop proper, separated from `cmd_serve` so every exit —
/// success, submit error, failed response, timeout — flows back
/// through the same shutdown/save cleanup in the caller.
fn serve_loop(
    coord: &mut Coordinator,
    rt: &Runtime,
    router: &Router,
    key: &RouteKey,
    n_requests: usize,
) -> Result<ServeOutcome> {
    let mut rng = Xoshiro256::new(42);
    let t0 = std::time::Instant::now();
    let max_n = router
        .max_bucket(key)
        .ok_or_else(|| anyhow!("no artifacts routable for {key:?}"))?;
    let mut submitted = 0usize;
    let mut completed = 0usize;
    let mut failures: Vec<String> = Vec::new();
    for _ in 0..n_requests {
        let want_n = 1 + rng.next_below(max_n as u64) as usize;
        let (artifact, _bucket) = router
            .route(key, want_n)
            .ok_or_else(|| anyhow!("no bucket for n={want_n}"))?;
        let inputs = rt.example_inputs(artifact)?;
        // responses drained while absorbing backpressure still count:
        // dropping them used to leave the completion loop short
        submit_with_retry(coord, artifact, inputs, |resp| {
            if let Err(e) = &resp.outputs {
                failures.push(format!("request {}: {e}", resp.id));
            }
            completed += 1;
        })?;
        submitted += 1;
    }
    coord.flush_all()?;
    while completed < submitted {
        match coord.recv_timeout(Duration::from_secs(60)) {
            Some(resp) => {
                // a failed response is recorded, not returned early —
                // the remaining in-flight work still gets drained
                if let Err(e) = &resp.outputs {
                    failures.push(format!("request {}: {e}", resp.id));
                }
                completed += 1;
            }
            None => bail!(
                "serve loop timed out ({completed}/{submitted} done)"
            ),
        }
    }
    Ok(ServeOutcome {
        submitted,
        completed,
        failures,
        wall_secs: t0.elapsed().as_secs_f64(),
    })
}

/// `serve --listen ADDR`: the TCP serving front-end. Serves the
/// synthetic demo plan from an empty runtime — admission control,
/// continuous batching and the session protocol all run without any
/// PJRT artifacts, so this is also what CI's load smoke drives.
fn cmd_serve_net(cli: &Cli, addr: &str) -> Result<String> {
    let n = cli.flag_usize("n", 256)?;
    let secs = cli.flag_usize("for", 0)?;
    let d = ServeConfig::default();
    let cfg = ServeConfig {
        workers: cli.flag_usize("workers", d.workers)?,
        max_batch: cli.flag_usize("max-batch", d.max_batch)?,
        queue_depth: cli.flag_usize("queue-depth", d.queue_depth)?,
        max_batch_total_tokens: cli.flag_usize(
            "max-batch-total-tokens",
            d.max_batch_total_tokens,
        )?,
        waiting_served_ratio: cli.flag_f64(
            "waiting-served-ratio",
            d.waiting_served_ratio,
        )?,
        max_sessions: cli.flag_usize("max-sessions", d.max_sessions)?,
        ..d
    };
    let coord = Coordinator::new(
        Arc::new(Runtime::empty()),
        cfg.coordinator_config(),
    );
    netserver::register_demo_plan(&coord, n)?;
    let server = NetServer::serve(coord, cfg, addr)?;
    // stdout, flushed: a spawning harness (CI's load smoke) waits for
    // this line to learn the bound port
    println!(
        "flashbias netserver listening on {} (plan {})",
        server.addr(),
        demo_plan_name(n)
    );
    if secs == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(secs as u64));
    let summary = server.metrics().summary();
    server.shutdown();
    Ok(format!("{summary}\n"))
}

/// Synthetic serving workload: route random-length attention requests
/// through the full stack; the planner picks the artifact variant.
fn cmd_serve(cli: &Cli) -> Result<String> {
    if let Some(addr) = cli.flag("listen") {
        return cmd_serve_net(cli, addr);
    }
    let n_requests = cli.flag_usize("requests", 64)?;
    let workers = cli.flag_usize("workers", 2)?;
    let max_batch = cli.flag_usize("max-batch", 8)?;
    let rt = Arc::new(Runtime::open_default()?);
    let router = Router::from_runtime(&rt);
    // one tiered factor store shared by the probe plan and the whole
    // serving loop; --store makes it persistent across processes,
    // --store-budget/--store-remote add the spill/sharing tiers
    let cli_store = store_from_flags(cli)?;
    let store = cli_store
        .as_ref()
        .map(|cs| cs.store.clone())
        .unwrap_or_else(|| Arc::new(FactorStore::unbounded()));
    // the serving bias is exact-closed-form ALiBi: let the planner decide
    // how it is carried and route to the matching artifact variant
    let probe = Planner::default().plan_with_store(
        &BiasSpec::alibi(512, 512, 0.25),
        &Geometry::square(512, 64, 0, 100 * 1024 / 2),
        &PlanOptions::default(),
        &store,
    )?;
    let variant = PjrtExecutor::variant(&probe.mode);
    let key = RouteKey::new("attn", variant);
    if router.route(&key, 1).is_none() {
        bail!("no attn/{variant} artifacts in manifest; \
               run `make artifacts`");
    }
    let mut config = CoordinatorConfig::default();
    config.workers = workers;
    config.batcher.max_batch = max_batch;
    let mut coord = Coordinator::with_store(rt.clone(), config,
                                            store.clone());
    // with a store that outlives this process (a file or a peer), the
    // serving loop's decomposition work is amortized across the fleet:
    // register a Swin host plan through the shared store — a cold run
    // pays its SVD once, a run booted from a warmed file or a peer's
    // factor service plans it with zero SVD work (see the store
    // counters in the metrics line)
    if cli_store.is_some() {
        let table =
            bias::swin_relative_bias((12, 12), 1, 0, 6, 0.02).remove(0);
        coord.plan_and_register(
            "swin_host_n144",
            &Planner::default(),
            &BiasSpec::static_learned(table),
            &Geometry::square(144, 64, 0, 100 * 1024 / 2),
            &PlanOptions::default(),
        )?;
    }
    // export the store to the fleet when asked; a bind failure flows
    // through the same cleanup as every other error below — an early
    // `?` here would skip shutdown and discard a dirty store's SVD work
    let mut service = None;
    let outcome = match cli
        .flag("store-serve")
        .map(|addr| coord.serve_store(addr))
        .transpose()
    {
        Ok(svc) => {
            service = svc;
            serve_loop(&mut coord, &rt, &router, &key, n_requests)
        }
        Err(e) => Err(e),
    };
    // cleanup runs on EVERY path — an early error used to leak worker
    // threads and discard a warmed store's decomposition work
    let summary = coord.metrics().summary();
    let json = coord.metrics().to_json().dump();
    coord.shutdown();
    let service_note = match service {
        Some(svc) => {
            let note = format!(
                "factor service {} served {} lookups\n",
                svc.addr(),
                svc.served()
            );
            svc.shutdown();
            note
        }
        None => String::new(),
    };
    // the save is attempted on every path, but a save failure must not
    // mask the serve loop's own error or the recorded request failures
    // — those are the diagnostics this cleanup exists to preserve
    let saved = cli_store.as_ref().map(|cs| cs.save_if_dirty());
    let outcome = outcome?;
    if !outcome.failures.is_empty() {
        bail!(
            "{} of {} requests failed (first: {})\n{summary}",
            outcome.failures.len(),
            outcome.submitted,
            outcome.failures[0]
        );
    }
    if let Some(s) = saved {
        s?;
    }
    Ok(format!(
        "served {}/{} requests in {:.2}s ({:.1} req/s)\n\
         {service_note}{summary}\nmetrics: {json}\n",
        outcome.completed,
        outcome.submitted,
        outcome.wall_secs,
        outcome.completed as f64 / outcome.wall_secs
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cli_parses_flags_and_positionals() {
        let cli = Cli::parse(
            ["run", "attn_pure_n256", "--iters", "5", "--verbose"]
                .into_iter()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(cli.command, "run");
        assert_eq!(cli.positional, vec!["attn_pure_n256"]);
        assert_eq!(cli.flag("iters"), Some("5"));
        assert_eq!(cli.flag("verbose"), Some("true"));
        assert_eq!(cli.flag_usize("iters", 1).unwrap(), 5);
        assert_eq!(cli.flag_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn cli_bool_flags_do_not_swallow_positionals() {
        // `--verbose` used to consume the artifact name as its value
        let cli = Cli::parse(
            ["run", "--verbose", "attn_pure_n256"]
                .into_iter()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(cli.positional, vec!["attn_pure_n256"]);
        assert_eq!(cli.flag("verbose"), Some("true"));
        let cli = Cli::parse(
            ["plan", "--causal", "swin", "--jit", "x"]
                .into_iter()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(cli.positional, vec!["swin", "x"]);
        assert_eq!(cli.flag("causal"), Some("true"));
        assert_eq!(cli.flag("jit"), Some("true"));
    }

    #[test]
    fn cli_equals_form_flags() {
        let cli = Cli::parse(
            [
                "serve",
                "--requests=9",
                "--store=factors.json",
                "--store-budget=4096",
                "--causal=false",
            ]
            .into_iter()
            .map(String::from),
        )
        .unwrap();
        assert_eq!(cli.flag_usize("requests", 0).unwrap(), 9);
        assert_eq!(cli.flag("store"), Some("factors.json"));
        assert_eq!(cli.flag_usize("store-budget", 0).unwrap(), 4096);
        // `=` overrides even a boolean flag's implicit value, and the
        // boolean accessor honors it
        assert_eq!(cli.flag("causal"), Some("false"));
        assert!(!cli.flag_bool("causal"));
        assert!(!cli.flag_bool("missing"));
        let cli = Cli::parse(
            ["plan", "--causal", "--jit=true"]
                .into_iter()
                .map(String::from),
        )
        .unwrap();
        assert!(cli.flag_bool("causal"));
        assert!(cli.flag_bool("jit"));
    }

    #[test]
    fn cli_bad_int_flag_errors() {
        let cli = Cli::parse(
            ["run", "--iters", "abc"].into_iter().map(String::from),
        )
        .unwrap();
        assert!(cli.flag_usize("iters", 1).is_err());
    }

    #[test]
    fn config_parser() {
        let cfg = parse_config(
            "# comment\nworkers = 4\nname = \"prod\" # inline\n\nbad line\n",
        );
        assert_eq!(cfg.get("workers").map(String::as_str), Some("4"));
        assert_eq!(cfg.get("name").map(String::as_str), Some("prod"));
        assert_eq!(cfg.len(), 2);
    }

    #[test]
    fn unknown_command_errors() {
        let cli =
            Cli::parse(["wat"].into_iter().map(String::from)).unwrap();
        assert!(run(&cli).is_err());
    }

    #[test]
    fn help_prints_usage() {
        let cli = Cli::parse(std::iter::empty()).unwrap();
        assert!(run(&cli).unwrap().contains("USAGE"));
    }

    #[test]
    fn plan_subcommand_needs_no_artifacts() {
        let cli = Cli::parse(
            ["plan", "--bias", "alibi", "--n", "128", "--causal"]
                .into_iter()
                .map(String::from),
        )
        .unwrap();
        let out = run(&cli).unwrap();
        assert!(out.contains("mode=factored"), "{out}");
        assert!(out.contains("rank=2"), "{out}");
    }

    #[test]
    fn plan_subcommand_jit_mode() {
        let cli = Cli::parse(
            ["plan", "--bias", "alibi", "--jit"]
                .into_iter()
                .map(String::from),
        )
        .unwrap();
        let out = run(&cli).unwrap();
        assert!(out.contains("mode=jit"), "{out}");
    }

    #[test]
    fn plan_subcommand_rejects_unknown_kind() {
        let cli = Cli::parse(
            ["plan", "--bias", "wat"].into_iter().map(String::from),
        )
        .unwrap();
        assert!(run(&cli).is_err());
    }

    #[test]
    fn warm_then_plan_hits_the_store() {
        let path = std::env::temp_dir().join(format!(
            "fb_cli_store_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let path = path.to_str().unwrap().to_string();
        // warm one swin head at the pinned rank (same table the `plan`
        // subcommand's swin kind generates: seed 0, head 0)
        let warm = Cli::parse(
            [
                "warm", "--store", path.as_str(), "--zoo", "swin",
                "--layers", "1", "--heads", "1", "--rank", "16",
            ]
            .into_iter()
            .map(String::from),
        )
        .unwrap();
        let out = run(&warm).unwrap();
        assert!(out.contains("warmed 1 biases"), "{out}");
        assert!(out.contains("misses=1"), "{out}");
        // the same bias content + policy through `plan --store` is a hit
        let plan = Cli::parse(
            [
                "plan", "--bias", "swin", "--rank", "16", "--store",
                path.as_str(),
            ]
            .into_iter()
            .map(String::from),
        )
        .unwrap();
        let out = run(&plan).unwrap();
        assert!(out.contains("mode=factored"), "{out}");
        assert!(out.contains("hits=1"), "{out}");
        assert!(out.contains("misses=0"), "{out}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn plan_with_budget_but_no_path_uses_scratch_spill() {
        // a budget without --store still plans: the spill tier lands
        // in a temp scratch file, and the oversized single entry stays
        // resident instead of self-evicting into an SVD loop
        let cli = Cli::parse(
            [
                "plan", "--bias", "swin", "--rank", "16",
                "--store-budget", "1024",
            ]
            .into_iter()
            .map(String::from),
        )
        .unwrap();
        let out = run(&cli).unwrap();
        assert!(out.contains("mode=factored"), "{out}");
        assert!(out.contains("misses=1"), "{out}");
        assert!(out.contains("spilled=0"), "{out}");
    }

    #[test]
    fn warm_without_store_errors() {
        let cli =
            Cli::parse(["warm"].into_iter().map(String::from)).unwrap();
        assert!(run(&cli).is_err());
    }
}
