//! CLI + config + run loop — the `flashbias` binary's brain.
//!
//! Subcommands:
//!
//! * `list`                — artifacts in the manifest.
//! * `verify [--only RE]`  — replay every artifact against its recorded
//!   expected outputs (the cross-layer integrity check).
//! * `run <artifact> [-n ITERS]` — execute one artifact, print timing.
//! * `serve [--requests N] [--workers W]` — synthetic serving loop through
//!   the full coordinator (router → batcher → workers), print metrics.
//! * `plan --bias KIND [...]` — run the Table 1 planner on a synthetic
//!   bias and print the emitted plan (no artifacts needed).
//! * `warm --store PATH`    — pre-decompose a bias zoo into an on-disk
//!   factor store (the paper's offline SVD, Table 4, as a command).
//! * `info`                — platform + manifest summary.
//!
//! `plan` and `serve` take `--store PATH` to amortize SVD/neural
//! decomposition through a persistent [`crate::factorstore::FactorStore`]
//! (loaded if present, saved back on exit).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::bias;
use crate::coordinator::{Coordinator, CoordinatorConfig, RouteKey, Router};
use crate::factorstore::FactorStore;
use crate::iomodel::Geometry;
use crate::plan::{BiasSpec, PjrtExecutor, PlanOptions, Planner};
use crate::runtime::{HostValue, Runtime};
use crate::tensor::Tensor;
use crate::util::{bench_loop, human_bytes, human_secs, Timer, Xoshiro256};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Cli {
    /// Hand-rolled parser: `cmd pos1 --flag value --bool-flag`.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Cli> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut cli = Cli {
            command,
            ..Cli::default()
        };
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                cli.flags.insert(name.to_string(), value);
            } else {
                cli.positional.push(arg);
            }
        }
        Ok(cli)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v}")),
        }
    }
}

/// Config file: `key = value` lines, `#` comments (mini-TOML subset).
pub fn parse_config(text: &str) -> HashMap<String, String> {
    let mut out = HashMap::new();
    for line in text.lines() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some((k, v)) = line.split_once('=') {
            out.insert(
                k.trim().to_string(),
                v.trim().trim_matches('"').to_string(),
            );
        }
    }
    out
}

pub const USAGE: &str = "\
flashbias — FlashBias serving runtime (rust/JAX/Pallas reproduction)

USAGE: flashbias <COMMAND> [ARGS]

COMMANDS:
  info                         platform + manifest summary
  list                         list artifacts
  verify [--only REGEX-ISH]    replay artifacts vs recorded outputs
  run <ARTIFACT> [--iters N]   execute one artifact, print timing
  serve [--requests N] [--workers W] [--max-batch B] [--store PATH]
                               synthetic serving loop, print metrics
                               (--store loads/saves a persistent factor
                               store; the coordinator's host-plan
                               registrations decompose through it, so a
                               warmed file plans with zero SVD work)
  plan --bias KIND [--n N] [--m M] [--c C] [--sram ELEMS] [--rank R]
       [--causal] [--jit] [--store PATH]
                               run the Table 1 planner on a synthetic bias
                               (KIND: none|alibi|spatial|cos-mult|swin|
                               pangu|dynamic|dense) and print the plan;
                               --store amortizes SVD/neural work through
                               an on-disk factor store
  warm --store PATH [--zoo swin,pangu] [--layers L] [--heads H] [--rank R]
                               pre-decompose a bias zoo into the factor
                               store (the Table 4 offline SVD, once)
  help                         this text
";

/// Entry point used by main.rs (and tested directly).
pub fn run(cli: &Cli) -> Result<String> {
    match cli.command.as_str() {
        "help" | "" => Ok(USAGE.to_string()),
        "info" => cmd_info(),
        "list" => cmd_list(),
        "verify" => cmd_verify(cli),
        "run" => cmd_run(cli),
        "serve" => cmd_serve(cli),
        "plan" => cmd_plan(cli),
        "warm" => cmd_warm(cli),
        other => bail!("unknown command {other}\n{USAGE}"),
    }
}

fn cmd_info() -> Result<String> {
    let rt = Runtime::open_default()?;
    Ok(format!(
        "platform: {}\nartifacts: {}\n",
        rt.platform(),
        rt.names().len()
    ))
}

fn cmd_list() -> Result<String> {
    let rt = Runtime::open_default()?;
    let mut out = String::new();
    for name in rt.names() {
        let spec = rt.spec(name).unwrap();
        out.push_str(&format!(
            "{name:32} family={:12} variant={:10} n={}\n",
            spec.family(),
            spec.variant(),
            spec.seq_len()
        ));
    }
    Ok(out)
}

/// Max |a−b| across all f32 outputs.
fn max_abs_diff(a: &[HostValue], b: &[HostValue]) -> f32 {
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        if let (Some(tx), Some(ty)) = (x.as_f32(), y.as_f32()) {
            worst = worst.max(tx.sub(ty).max_abs());
        }
    }
    worst
}

fn cmd_verify(cli: &Cli) -> Result<String> {
    let rt = Runtime::open_default()?;
    let filter = cli.flag("only").unwrap_or("");
    let mut out = String::new();
    let mut failures = 0;
    for name in rt.names() {
        if !filter.is_empty() && !name.contains(filter) {
            continue;
        }
        let spec = rt.spec(name).unwrap();
        if spec.outputs.is_empty() {
            continue;
        }
        let exe = rt.load(name)?;
        let inputs = rt.example_inputs(name)?;
        let expected = rt.expected_outputs(name)?;
        let got = exe.run(&inputs)?;
        let diff = max_abs_diff(&got, &expected);
        let ok = diff < 2e-3;
        if !ok {
            failures += 1;
        }
        out.push_str(&format!(
            "{name:32} max|Δ|={diff:.2e} {}\n",
            if ok { "OK" } else { "FAIL" }
        ));
    }
    if failures > 0 {
        bail!("{failures} artifacts FAILED\n{out}");
    }
    Ok(out)
}

fn cmd_run(cli: &Cli) -> Result<String> {
    let artifact = cli
        .positional
        .first()
        .ok_or_else(|| anyhow!("run needs an artifact name"))?;
    let iters = cli.flag_usize("iters", 10)?;
    let rt = Runtime::open_default()?;
    let exe = rt.load_warm(artifact)?;
    let inputs = rt.example_inputs(artifact)?;
    let stats = bench_loop(1, iters, || {
        exe.run(&inputs).expect("execute");
    });
    Ok(format!(
        "{artifact}: mean={} p50={} p99={} over {iters} iters\n",
        human_secs(stats.mean()),
        human_secs(stats.p50()),
        human_secs(stats.p99()),
    ))
}

/// Run the Table 1 planner on a synthetic bias and print the emitted
/// plan — the `BiasSpec → Planner → AttentionPlan` pipeline as a CLI.
fn cmd_plan(cli: &Cli) -> Result<String> {
    let kind = cli.flag("bias").unwrap_or("alibi");
    let n = cli.flag_usize("n", 256)?;
    let m = cli.flag_usize("m", n)?;
    let c = cli.flag_usize("c", 64)?;
    let sram = cli.flag_usize("sram", 100 * 1024 / 2)?;
    let causal = cli.flag("causal").is_some();
    let jit = cli.flag("jit").is_some();
    let rank_override = match cli.flag("rank") {
        Some(_) => Some(cli.flag_usize("rank", 0)?),
        None => None,
    };
    let mut rng = Xoshiro256::new(0);
    let (spec, n, m) = match kind {
        "none" => (BiasSpec::None, n, m),
        "alibi" => (BiasSpec::alibi(n, m, 0.25), n, m),
        "spatial" => {
            let xq = bias::synthetic_car_cloud(n, 0);
            let xk = if m == n {
                xq.clone()
            } else {
                bias::synthetic_car_cloud(m, 1)
            };
            (BiasSpec::spatial(xq, xk, None), n, m)
        }
        "cos-mult" => (BiasSpec::cos_multiplicative(n, m), n, m),
        "swin" => {
            let mut tables =
                bias::swin_relative_bias((12, 12), 1, 0, 6, 0.02);
            (BiasSpec::static_learned(tables.remove(0)), 144, 144)
        }
        "pangu" => {
            let mut tables =
                bias::pangu_relative_bias((2, 6, 12), 1, 0, 5, 0.02);
            (BiasSpec::static_learned(tables.remove(0)), 144, 144)
        }
        "dynamic" => {
            // neural fit is O(steps·N·hidden): keep the CLI snappy
            let nn = n.min(64);
            let x = Tensor::from_fn(&[nn, 2], |ix| {
                let t = ix[0] as f32 / nn as f32;
                if ix[1] == 0 { (6.28 * t).sin() } else { t }
            });
            let w = Tensor::randn(&[2, 2], 0.8, &mut rng);
            let proj = x.matmul(&w);
            let target = proj.matmul_t(&proj).map(|vv| (0.5 * vv).tanh());
            (BiasSpec::dynamic(x.clone(), x, target), nn, nn)
        }
        "dense" => {
            let table = Tensor::randn(&[n, m], 1.0, &mut rng);
            (BiasSpec::dense(table), n, m)
        }
        other => bail!("unknown bias kind {other}\n{USAGE}"),
    };
    let geo = Geometry { n, m, c, r: 0, sram };
    let opts = PlanOptions {
        causal,
        prefer_jit: jit,
        rank_override,
        verify_exact: false,
    };
    let planner = Planner::default();
    let (plan, store_note) = match cli.flag("store") {
        Some(path) => {
            let store = FactorStore::open(path, usize::MAX)?;
            let plan = planner.plan_with_store(&spec, &geo, &opts,
                                               &store)?;
            let stats = store.stats();
            // rewrite the file only when something new was decomposed —
            // a pure-hit plan leaves a warmed store untouched
            let disposition = if stats.misses > 0 {
                store.save(path)?;
                format!(" (saved to {path})")
            } else {
                format!(" ({path} unchanged)")
            };
            (plan, format!("{}{disposition}\n", stats.summary()))
        }
        None => (planner.plan(&spec, &geo, &opts)?, String::new()),
    };
    Ok(format!(
        "bias: {kind} (N={n}, M={m}, C={c}, SRAM={sram} elems)\n\
         plan: {}\n\
         predicted HBM IO: {:.3e} elems vs dense-bias {:.3e} ({:.1}x)\n\
         bias storage: {}\n{store_note}",
        plan.summary(),
        plan.predicted_io,
        plan.dense_io,
        plan.io_saving(),
        human_bytes(plan.bias_storage_bytes as u64),
    ))
}

/// Pre-decompose a bias zoo into an on-disk factor store so later
/// `plan --store` / `serve --store` runs (and any process loading the
/// file) start warm — Table 4's "4.79 s of offline SVD, once" as a
/// command. Re-running is idempotent: already-stored biases are hits.
fn cmd_warm(cli: &Cli) -> Result<String> {
    let path = cli
        .flag("store")
        .ok_or_else(|| anyhow!("warm needs --store PATH\n{USAGE}"))?
        .to_string();
    let layers = cli.flag_usize("layers", 4)?;
    let heads = cli.flag_usize("heads", 4)?;
    let zoo = cli.flag("zoo").unwrap_or("swin,pangu");
    let rank_override = match cli.flag("rank") {
        Some(_) => Some(cli.flag_usize("rank", 0)?),
        None => None,
    };
    let store = FactorStore::open(&path, usize::MAX)?;
    let planner = Planner::default();
    let opts = PlanOptions {
        rank_override,
        ..PlanOptions::default()
    };
    // both zoos gather into (144, 144) windows
    let geo = Geometry::square(144, 64, 0, 100 * 1024 / 2);
    let timer = Timer::start();
    let mut planned = 0usize;
    for kind in zoo.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let tables_per_layer = |li: usize| match kind {
            "swin" => {
                Ok(bias::swin_relative_bias((12, 12), heads, li as u64,
                                            6, 0.02))
            }
            "pangu" => {
                Ok(bias::pangu_relative_bias((2, 6, 12), heads,
                                             li as u64, 5, 0.02))
            }
            other => Err(anyhow!("unknown zoo member {other} \
                                  (expected swin|pangu)")),
        };
        for li in 0..layers {
            for table in tables_per_layer(li)? {
                planner.plan_with_store(
                    &BiasSpec::static_learned(table),
                    &geo,
                    &opts,
                    &store,
                )?;
                planned += 1;
            }
        }
    }
    let stats = store.stats();
    let disposition = if stats.misses > 0 {
        store.save(&path)?;
        format!("(saved to {path})")
    } else {
        // idempotent re-warm: everything was already on disk
        format!("({path} unchanged — all hits)")
    };
    Ok(format!(
        "warmed {planned} biases ({zoo}) in {}\n{} {disposition}\n",
        human_secs(timer.elapsed_secs()),
        stats.summary(),
    ))
}

/// Synthetic serving workload: route random-length attention requests
/// through the full stack; the planner picks the artifact variant.
fn cmd_serve(cli: &Cli) -> Result<String> {
    let n_requests = cli.flag_usize("requests", 64)?;
    let workers = cli.flag_usize("workers", 2)?;
    let max_batch = cli.flag_usize("max-batch", 8)?;
    let rt = Arc::new(Runtime::open_default()?);
    let router = Router::from_runtime(&rt);
    // one factor store shared by the probe plan and the whole serving
    // loop; --store makes it persistent across processes
    let store_path = cli.flag("store").map(str::to_string);
    let store = Arc::new(match &store_path {
        Some(p) => FactorStore::open(p, usize::MAX)?,
        None => FactorStore::unbounded(),
    });
    // the serving bias is exact-closed-form ALiBi: let the planner decide
    // how it is carried and route to the matching artifact variant
    let probe = Planner::default().plan_with_store(
        &BiasSpec::alibi(512, 512, 0.25),
        &Geometry::square(512, 64, 0, 100 * 1024 / 2),
        &PlanOptions::default(),
        &store,
    )?;
    let variant = PjrtExecutor::variant(&probe.mode);
    let key = RouteKey::new("attn", variant);
    if router.route(&key, 1).is_none() {
        bail!("no attn/{variant} artifacts in manifest; \
               run `make artifacts`");
    }
    let mut config = CoordinatorConfig::default();
    config.workers = workers;
    config.batcher.max_batch = max_batch;
    let mut coord = Coordinator::with_store(rt.clone(), config,
                                            store.clone());
    // with a persistent store, the serving loop's decomposition work is
    // amortized across processes: register a Swin host plan through the
    // shared store — a cold run pays its SVD once, a run booted from a
    // warmed file plans it with zero SVD work (see the store counters
    // in the metrics line)
    if store_path.is_some() {
        let table =
            bias::swin_relative_bias((12, 12), 1, 0, 6, 0.02).remove(0);
        coord.plan_and_register(
            "swin_host_n144",
            &Planner::default(),
            &BiasSpec::static_learned(table),
            &Geometry::square(144, 64, 0, 100 * 1024 / 2),
            &PlanOptions::default(),
        )?;
    }
    let mut rng = Xoshiro256::new(42);
    let t0 = std::time::Instant::now();
    let max_n = router.max_bucket(&key).unwrap();
    let mut submitted = 0usize;
    for _ in 0..n_requests {
        let want_n = 1 + rng.next_below(max_n as u64) as usize;
        let (artifact, _bucket) = router.route(&key, want_n).unwrap();
        let inputs = rt.example_inputs(artifact)?;
        // retry on backpressure: drain a few responses then resubmit
        loop {
            match coord.submit(artifact, inputs.clone()) {
                Ok(_) => break,
                Err(_) => {
                    let _ = coord.recv_timeout(Duration::from_millis(50));
                }
            }
        }
        submitted += 1;
    }
    coord.flush_all()?;
    let mut completed = 0usize;
    while completed < submitted {
        match coord.recv_timeout(Duration::from_secs(60)) {
            Some(resp) => {
                resp.outputs?;
                completed += 1;
            }
            None => bail!("serve loop timed out"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let summary = coord.metrics().summary();
    let json = coord.metrics().to_json().dump();
    coord.shutdown();
    if let Some(p) = &store_path {
        if store.stats().misses > 0 {
            store.save(p)?;
        }
    }
    Ok(format!(
        "served {completed}/{submitted} requests in {:.2}s \
         ({:.1} req/s)\n{summary}\nmetrics: {json}\n",
        wall,
        completed as f64 / wall
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cli_parses_flags_and_positionals() {
        let cli = Cli::parse(
            ["run", "attn_pure_n256", "--iters", "5", "--verbose"]
                .into_iter()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(cli.command, "run");
        assert_eq!(cli.positional, vec!["attn_pure_n256"]);
        assert_eq!(cli.flag("iters"), Some("5"));
        assert_eq!(cli.flag("verbose"), Some("true"));
        assert_eq!(cli.flag_usize("iters", 1).unwrap(), 5);
        assert_eq!(cli.flag_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn cli_bad_int_flag_errors() {
        let cli = Cli::parse(
            ["run", "--iters", "abc"].into_iter().map(String::from),
        )
        .unwrap();
        assert!(cli.flag_usize("iters", 1).is_err());
    }

    #[test]
    fn config_parser() {
        let cfg = parse_config(
            "# comment\nworkers = 4\nname = \"prod\" # inline\n\nbad line\n",
        );
        assert_eq!(cfg.get("workers").map(String::as_str), Some("4"));
        assert_eq!(cfg.get("name").map(String::as_str), Some("prod"));
        assert_eq!(cfg.len(), 2);
    }

    #[test]
    fn unknown_command_errors() {
        let cli =
            Cli::parse(["wat"].into_iter().map(String::from)).unwrap();
        assert!(run(&cli).is_err());
    }

    #[test]
    fn help_prints_usage() {
        let cli = Cli::parse(std::iter::empty()).unwrap();
        assert!(run(&cli).unwrap().contains("USAGE"));
    }

    #[test]
    fn plan_subcommand_needs_no_artifacts() {
        let cli = Cli::parse(
            ["plan", "--bias", "alibi", "--n", "128", "--causal"]
                .into_iter()
                .map(String::from),
        )
        .unwrap();
        let out = run(&cli).unwrap();
        assert!(out.contains("mode=factored"), "{out}");
        assert!(out.contains("rank=2"), "{out}");
    }

    #[test]
    fn plan_subcommand_jit_mode() {
        let cli = Cli::parse(
            ["plan", "--bias", "alibi", "--jit"]
                .into_iter()
                .map(String::from),
        )
        .unwrap();
        let out = run(&cli).unwrap();
        assert!(out.contains("mode=jit"), "{out}");
    }

    #[test]
    fn plan_subcommand_rejects_unknown_kind() {
        let cli = Cli::parse(
            ["plan", "--bias", "wat"].into_iter().map(String::from),
        )
        .unwrap();
        assert!(run(&cli).is_err());
    }

    #[test]
    fn warm_then_plan_hits_the_store() {
        let path = std::env::temp_dir().join(format!(
            "fb_cli_store_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let path = path.to_str().unwrap().to_string();
        // warm one swin head at the pinned rank (same table the `plan`
        // subcommand's swin kind generates: seed 0, head 0)
        let warm = Cli::parse(
            [
                "warm", "--store", path.as_str(), "--zoo", "swin",
                "--layers", "1", "--heads", "1", "--rank", "16",
            ]
            .into_iter()
            .map(String::from),
        )
        .unwrap();
        let out = run(&warm).unwrap();
        assert!(out.contains("warmed 1 biases"), "{out}");
        assert!(out.contains("misses=1"), "{out}");
        // the same bias content + policy through `plan --store` is a hit
        let plan = Cli::parse(
            [
                "plan", "--bias", "swin", "--rank", "16", "--store",
                path.as_str(),
            ]
            .into_iter()
            .map(String::from),
        )
        .unwrap();
        let out = run(&plan).unwrap();
        assert!(out.contains("mode=factored"), "{out}");
        assert!(out.contains("hits=1"), "{out}");
        assert!(out.contains("misses=0"), "{out}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn warm_without_store_errors() {
        let cli =
            Cli::parse(["warm"].into_iter().map(String::from)).unwrap();
        assert!(run(&cli).is_err());
    }
}
