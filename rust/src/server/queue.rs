//! Serving-policy substrate for the TCP front-end: the [`ServeConfig`]
//! knob set, a bounded [`AdmissionQueue`] with semaphore-style
//! admission control, and the waiting/served [`FlushPolicy`].
//!
//! The shape follows the TGI/vLLM router split: connection threads do
//! *admission* (cheap, rejecting, never blocking the socket on model
//! work) and one dispatch thread does *scheduling* (when to flush the
//! coordinator's pending bucket into the worker pool). The policy is a
//! pure function over four observables — waiting requests, in-flight
//! requests, pending token count, oldest waiting age — so it is
//! unit-testable without a socket or a coordinator, and every decision
//! it makes is counted per [`FlushReason`] in
//! [`crate::coordinator::Metrics`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError,
};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::{BatcherConfig, CoordinatorConfig, FlushReason};
use crate::util::frame::IO_TIMEOUT;

/// Knobs for the network serving front-end. The defaults serve; the
/// load bench sweeps the interesting ones.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads executing flushed batches.
    pub workers: usize,
    /// Coordinator bucket size: a bucket at `max_batch` flushes itself
    /// regardless of policy (the upper bound on batch occupancy).
    pub max_batch: usize,
    /// Bounded depth of the coordinator's worker dispatch queue.
    pub coord_queue_depth: usize,
    /// Bounded depth of the network admission queue; a full queue
    /// rejects with an `overloaded` wire error instead of parking the
    /// connection (semaphore-style admission control).
    pub queue_depth: usize,
    /// Flush when the pending bucket holds at least this many "tokens"
    /// (query rows: a prefill of n rows counts n, a decode step 1).
    pub max_batch_total_tokens: usize,
    /// Flush when `waiting >= ratio * in_flight` — enough queued work
    /// relative to what the workers are chewing to justify a new batch
    /// now instead of letting the pending bucket ripen further.
    pub waiting_served_ratio: f64,
    /// Flush when the oldest waiting request has aged past this (the
    /// latency backstop at low offered load).
    pub max_wait: Duration,
    /// Refuse `open` frames beyond this many live sessions.
    pub max_sessions: usize,
    /// Per-frame inbound request cap (prefill payloads carry whole
    /// prompts, so this is generous next to the factor-service cap).
    pub max_request_bytes: u32,
    /// Per-connection socket read/write timeout.
    pub io_timeout: Duration,
    /// Artificial pause per dispatched request, before it reaches the
    /// coordinator. Zero in production; tests raise it to make
    /// admission-queue overflow deterministic, and it doubles as a
    /// slow-backend emulator for the load bench.
    pub dispatch_delay: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 16,
            coord_queue_depth: 64,
            queue_depth: 256,
            max_batch_total_tokens: 4096,
            waiting_served_ratio: 1.2,
            max_wait: Duration::from_millis(5),
            max_sessions: 1024,
            max_request_bytes: 8 * 1024 * 1024,
            io_timeout: IO_TIMEOUT,
            dispatch_delay: Duration::ZERO,
        }
    }
}

impl ServeConfig {
    /// The no-batching baseline the load bench compares against: every
    /// request flushes alone (`max_batch == 1`), so each one pays the
    /// full dispatch + scoped-pool overhead the batcher exists to
    /// amortize.
    pub fn batch1() -> Self {
        Self {
            max_batch: 1,
            ..Self::default()
        }
    }

    /// The coordinator configuration this serving config implies.
    pub fn coordinator_config(&self) -> CoordinatorConfig {
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: self.max_batch,
                max_wait: self.max_wait,
            },
            workers: self.workers,
            queue_depth: self.coord_queue_depth,
        }
    }

    /// The flush policy this config describes.
    pub fn flush_policy(&self) -> FlushPolicy {
        FlushPolicy {
            max_batch_total_tokens: self.max_batch_total_tokens,
            waiting_served_ratio: self.waiting_served_ratio,
            max_wait: self.max_wait,
        }
    }
}

// ---------------------------------------------------------------------------
// Flush policy
// ---------------------------------------------------------------------------

/// The waiting/served flush decision, as a pure function. The dispatch
/// thread evaluates it once per tick; `Some(reason)` means "flush the
/// coordinator's pending bucket now, and count the decision under
/// `reason`".
#[derive(Clone, Copy, Debug)]
pub struct FlushPolicy {
    pub max_batch_total_tokens: usize,
    pub waiting_served_ratio: f64,
    pub max_wait: Duration,
}

impl FlushPolicy {
    /// Decide whether to flush. `waiting` is the number of requests in
    /// the coordinator's pending bucket, `in_flight` the number already
    /// dispatched but not yet completed, `pending_tokens` the query-row
    /// total of the waiting set, `oldest_age` how long the oldest
    /// waiting request has been pending.
    pub fn decide(
        &self,
        waiting: usize,
        in_flight: usize,
        pending_tokens: usize,
        oldest_age: Duration,
    ) -> Option<FlushReason> {
        if waiting == 0 {
            return None;
        }
        if pending_tokens >= self.max_batch_total_tokens {
            return Some(FlushReason::Tokens);
        }
        if oldest_age >= self.max_wait {
            return Some(FlushReason::Deadline);
        }
        // idle workers never wait on a ripening batch; with work in
        // flight, flush once the queue outweighs it by the ratio
        if in_flight == 0
            || waiting as f64 >= self.waiting_served_ratio * in_flight as f64
        {
            return Some(FlushReason::Ratio);
        }
        None
    }
}

// ---------------------------------------------------------------------------
// Admission queue
// ---------------------------------------------------------------------------

/// Why [`AdmissionQueue::try_admit`] refused; the item rides back so
/// the connection thread can report without cloning request payloads.
#[derive(Debug)]
pub enum AdmitError<T> {
    /// Queue at capacity — the overload signal.
    Full(T),
    /// The dispatch side is gone (server shutting down).
    Closed(T),
}

/// Producer half of the bounded admission queue. Cloned into every
/// connection thread; `try_admit` never blocks — a full queue is an
/// immediate, reportable rejection, which is the whole point of
/// admission control (a parked connection thread is an invisible,
/// unbounded queue).
pub struct AdmissionQueue<T> {
    tx: SyncSender<Admitted<T>>,
    depth: Arc<AtomicUsize>,
}

// derive(Clone) would demand T: Clone; the sender clones regardless
impl<T> Clone for AdmissionQueue<T> {
    fn clone(&self) -> Self {
        Self {
            tx: self.tx.clone(),
            depth: Arc::clone(&self.depth),
        }
    }
}

struct Admitted<T> {
    item: T,
    enqueued: Instant,
}

/// Consumer half: owned by the single dispatch thread.
pub struct AdmissionReceiver<T> {
    rx: Receiver<Admitted<T>>,
    depth: Arc<AtomicUsize>,
}

/// One dequeued item plus its admission observables.
pub struct Dequeued<T> {
    pub item: T,
    /// Time spent in the admission queue.
    pub wait: Duration,
    /// Queue depth sampled at dequeue (items still behind this one).
    pub depth: usize,
}

/// Build the bounded queue: up to `capacity` admitted-but-undispatched
/// requests; the `capacity + 1`-th is refused.
pub fn admission_queue<T>(
    capacity: usize,
) -> (AdmissionQueue<T>, AdmissionReceiver<T>) {
    let (tx, rx) = sync_channel(capacity);
    let depth = Arc::new(AtomicUsize::new(0));
    (
        AdmissionQueue {
            tx,
            depth: Arc::clone(&depth),
        },
        AdmissionReceiver { rx, depth },
    )
}

impl<T> AdmissionQueue<T> {
    /// Admit `item` or refuse immediately (never blocks).
    pub fn try_admit(&self, item: T) -> Result<(), AdmitError<T>> {
        // count up BEFORE the send: the receiver decrements on recv,
        // which can only follow a successful send, so the counter never
        // underflows; on refusal the speculative increment is undone
        self.depth.fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send(Admitted {
            item,
            enqueued: Instant::now(),
        }) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                match e {
                    TrySendError::Full(a) => Err(AdmitError::Full(a.item)),
                    TrySendError::Disconnected(a) => {
                        Err(AdmitError::Closed(a.item))
                    }
                }
            }
        }
    }

    /// Items admitted but not yet dequeued (approximate under
    /// concurrency; exact once the dispatch thread quiesces).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }
}

impl<T> AdmissionReceiver<T> {
    /// Dequeue the next admitted item, waiting up to `timeout`. `None`
    /// on timeout or when every producer is gone.
    pub fn recv_admitted(&self, timeout: Duration) -> Option<Dequeued<T>> {
        match self.rx.recv_timeout(timeout) {
            Ok(a) => {
                let depth = self
                    .depth
                    .fetch_sub(1, Ordering::Relaxed)
                    .saturating_sub(1);
                Some(Dequeued {
                    item: a.item,
                    wait: a.enqueued.elapsed(),
                    depth,
                })
            }
            Err(RecvTimeoutError::Timeout)
            | Err(RecvTimeoutError::Disconnected) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> FlushPolicy {
        FlushPolicy {
            max_batch_total_tokens: 100,
            waiting_served_ratio: 1.5,
            max_wait: Duration::from_millis(10),
        }
    }

    #[test]
    fn policy_never_flushes_an_empty_bucket() {
        assert_eq!(
            policy().decide(0, 0, 0, Duration::from_secs(9)),
            None
        );
    }

    #[test]
    fn policy_token_budget_flushes_first() {
        // over budget wins even when ratio/deadline would also fire
        assert_eq!(
            policy().decide(8, 0, 100, Duration::from_secs(1)),
            Some(FlushReason::Tokens)
        );
        assert_eq!(
            policy().decide(1, 99, 99, Duration::ZERO),
            None,
            "under budget, under ratio, under deadline: ripen"
        );
    }

    #[test]
    fn policy_deadline_is_the_latency_backstop() {
        assert_eq!(
            policy().decide(1, 99, 1, Duration::from_millis(10)),
            Some(FlushReason::Deadline)
        );
    }

    #[test]
    fn policy_waiting_served_ratio() {
        // idle workers: anything waiting flushes at once
        assert_eq!(
            policy().decide(1, 0, 1, Duration::ZERO),
            Some(FlushReason::Ratio)
        );
        // 3 waiting vs 2 in flight = 1.5 ratio exactly: flush
        assert_eq!(
            policy().decide(3, 2, 3, Duration::ZERO),
            Some(FlushReason::Ratio)
        );
        // 2 waiting vs 2 in flight: below the ratio, keep ripening
        assert_eq!(policy().decide(2, 2, 2, Duration::ZERO), None);
    }

    #[test]
    fn admission_queue_bounds_and_rejects() {
        let (q, rx) = admission_queue::<u32>(2);
        q.try_admit(1).expect("fits");
        q.try_admit(2).expect("fits");
        assert_eq!(q.depth(), 2);
        match q.try_admit(3) {
            Err(AdmitError::Full(item)) => assert_eq!(item, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        // draining one slot readmits
        let got = rx.recv_admitted(Duration::from_secs(1)).expect("one");
        assert_eq!(got.item, 1);
        assert_eq!(got.depth, 1);
        q.try_admit(3).expect("slot freed");
    }

    #[test]
    fn admission_queue_reports_closed() {
        let (q, rx) = admission_queue::<u32>(4);
        drop(rx);
        match q.try_admit(7) {
            Err(AdmitError::Closed(item)) => assert_eq!(item, 7),
            other => panic!("expected Closed, got {other:?}"),
        }
    }

    #[test]
    fn admission_wait_is_measured() {
        let (q, rx) = admission_queue::<&str>(1);
        q.try_admit("x").expect("fits");
        std::thread::sleep(Duration::from_millis(5));
        let got = rx.recv_admitted(Duration::from_secs(1)).expect("x");
        assert!(got.wait >= Duration::from_millis(5));
        assert_eq!(got.depth, 0);
        // empty queue: timeout is a clean None
        assert!(rx.recv_admitted(Duration::from_millis(1)).is_none());
    }

    #[test]
    fn batch1_preset_disables_batching_only() {
        let b1 = ServeConfig::batch1();
        assert_eq!(b1.max_batch, 1);
        assert_eq!(b1.workers, ServeConfig::default().workers);
        assert_eq!(b1.coordinator_config().batcher.max_batch, 1);
    }
}
