//! TCP serving front-end: the network router in front of the
//! [`Coordinator`].
//!
//! The shape is the TGI/vLLM router split, sized down to this crate:
//!
//! * **Connection threads** (one per accepted socket) parse and
//!   validate request frames, then *admit* them into a bounded
//!   [`super::queue::AdmissionQueue`]. A full queue is an immediate
//!   `overloaded` error frame — admission control, not an invisible
//!   parked connection. Validation failures never reach the
//!   coordinator.
//! * **One dispatch thread** owns the [`Coordinator`] (which is `Send`
//!   but deliberately not `Sync`): it drains admitted work, submits
//!   prefills/decode steps/one-shots, correlates [`Response`]s back to
//!   per-request reply channels, and runs the waiting/served
//!   [`super::queue::FlushPolicy`] every tick, counting each decision
//!   per [`FlushReason`] in [`Metrics`].
//!
//! Wire protocol: the shared length-prefixed jsonlite framing from
//! [`crate::util::frame`] (same codec as the factor service). One
//! request frame yields exactly one response frame, in order, per
//! connection. Ops:
//!
//! | op        | request fields                          | ok-response |
//! |-----------|-----------------------------------------|-------------|
//! | `ping`    | —                                       | `{"ok":true,"pong":true}` |
//! | `stats`   | —                                       | `{"ok":true,"metrics":{...},"queue_depth":D}` |
//! | `open`    | `plan`                                  | `{"ok":true,"session":ID}` |
//! | `prefill` | `session`, payload, `echo?`             | `{"ok":true,"id":R,"shape":[n,Cv],"out":[...]?}` |
//! | `step`    | `session`, row payload, `echo?`         | `{"ok":true,"id":R,"shape":[Cv],"out":[...]?}` |
//! | `oneshot` | `artifact`, payload, `echo?`            | like `prefill` |
//! | `close`   | `session`                               | `{"ok":true,"closed":ID}` |
//!
//! A payload is either explicit flat arrays `q`/`k`/`v` (row-major,
//! lengths multiples of the plan's head width C) or the *seed form*
//! `{"n":N,"seed":S}` (`{"t":T,"seed":S}` for steps): the server
//! generates the tensors with [`synthetic_qkv`] / [`synthetic_rows`],
//! so a load generator streams kilobyte frames instead of megabyte
//! prompts and a test can replay the exact same inputs through an
//! in-process [`crate::plan::SessionState`] for bitwise comparison.
//! `"echo":false` suppresses the output array (latency benches don't
//! pay for float printing).
//!
//! Errors are typed frames `{"ok":false,"kind":K,"error":MSG}` with
//! `K` ∈ `validation` (malformed request, bad shapes, unknown plan),
//! `session` (unknown/foreign session id, session state machine
//! refusal), `overloaded` (admission queue full, session cap,
//! coordinator backpressure), `unavailable` (server shutting down),
//! `exec` (the batch ran and failed), `frame` (protocol damage; the
//! connection closes after reporting). Sessions are connection-owned:
//! a session opened on one connection is invisible to every other, and
//! sessions still open when the peer disconnects are closed
//! best-effort.

use std::collections::{BTreeMap, VecDeque};
use std::net::{
    IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream,
    ToSocketAddrs,
};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::{
    Coordinator, FlushReason, HostPlanRegistry, Metrics, Response,
    SessionApiError, SubmitError,
};
use crate::iomodel::Geometry;
use crate::jsonlite::Json;
use crate::plan::{AttentionPlan, BiasSpec, PlanOptions, Planner};
use crate::runtime::HostValue;
use crate::tensor::Tensor;
use crate::util::frame::{
    read_frame_limited, set_io_timeouts, write_frame, CONNECT_TIMEOUT,
};
use crate::util::Xoshiro256;

use super::queue::{
    admission_queue, AdmissionQueue, AdmissionReceiver, AdmitError,
    ServeConfig,
};

/// How long a connection thread waits for the dispatch side to answer
/// one admitted request before declaring the server gone. Generous:
/// an admitted prefill legitimately waits out the whole queue ahead of
/// it plus its batch's execution.
const REPLY_TIMEOUT: Duration = Duration::from_secs(60);

/// Dispatch-thread tick: the poll interval for admitted work, response
/// draining, the flush policy, and the stop flag.
const TICK: Duration = Duration::from_millis(1);

// ---------------------------------------------------------------------------
// Wire command (parsed, validated request)
// ---------------------------------------------------------------------------

/// A validated request, tensors already built — nothing in here can
/// make the dispatch thread panic.
enum WireCmd {
    Open { plan: String },
    Prefill { session: u64, q: Tensor, k: Tensor, v: Tensor, echo: bool },
    Step { session: u64, q: Vec<f32>, k: Vec<f32>, v: Vec<f32>, echo: bool },
    Oneshot { artifact: String, q: Tensor, k: Tensor, v: Tensor, echo: bool },
    Close { session: u64 },
}

/// One admitted unit of work: the command plus the channel its single
/// response frame must be sent on.
struct Work {
    cmd: WireCmd,
    reply: Sender<Json>,
}

/// Per-session geometry a connection caches at `open` so later frames
/// validate (and bound allocations) without a dispatch round trip.
#[derive(Clone, Copy)]
struct SessInfo {
    /// Head width C — every row the wire carries must be a multiple.
    c: usize,
    /// Context limit (the plan's N): caps seed-form `n` before any
    /// allocation happens.
    n_max: usize,
}

/// A typed validation refusal: (wire error kind, message).
type WireFault = (&'static str, String);

fn err_json(kind: &str, msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("kind", Json::str(kind)),
        ("error", Json::str(msg)),
    ])
}

// ---------------------------------------------------------------------------
// Deterministic synthetic payloads (seed form)
// ---------------------------------------------------------------------------

/// The seed-form prefill/one-shot payload: `(q, k, v)`, each `(n, c)`
/// standard normal, fully determined by `seed`. Server and test
/// generate identical tensors from the same seed.
pub fn synthetic_qkv(seed: u64, n: usize, c: usize) -> (Tensor, Tensor, Tensor) {
    let mut rng = Xoshiro256::new(seed);
    let q = Tensor::randn(&[n, c], 1.0, &mut rng);
    let k = Tensor::randn(&[n, c], 1.0, &mut rng);
    let v = Tensor::randn(&[n, c], 1.0, &mut rng);
    (q, k, v)
}

/// The seed-form decode-step payload: `(q_row, k_row, v_row)` of width
/// `c` for step position `t`, determined by `(seed, t)`.
pub fn synthetic_rows(seed: u64, t: usize,
                      c: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256::new(
        seed ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    let q = rng.normal_vec(c, 1.0);
    let k = rng.normal_vec(c, 1.0);
    let v = rng.normal_vec(c, 1.0);
    (q, k, v)
}

/// Name of the demo plan [`register_demo_plan`] installs for context
/// length `n`.
pub fn demo_plan_name(n: usize) -> String {
    format!("net_alibi_n{n}")
}

/// Register the synthetic serving plan the network tooling shares (CLI
/// `serve --listen`, `loadgen --spawn`, the load bench, the loopback
/// tests): causal ALiBi at context `n`, head width 64 — exact,
/// factored, decode-capable, so both one-shots and sessions run
/// against it. Returns the registered plan (callers replay it inline
/// for bitwise comparisons).
pub fn register_demo_plan(coord: &Coordinator,
                          n: usize) -> Result<AttentionPlan> {
    coord.plan_and_register(
        &demo_plan_name(n),
        &Planner::default(),
        &BiasSpec::alibi(n, n, 0.25),
        &Geometry::square(n, 64, 0, 100 * 1024 / 2),
        &PlanOptions {
            causal: true,
            ..PlanOptions::default()
        },
    )
}

// ---------------------------------------------------------------------------
// Request parsing / validation (connection side, pure)
// ---------------------------------------------------------------------------

/// Parse one request frame into a [`WireCmd`], validating everything
/// that can be validated without the coordinator: op shape, session
/// ownership, array widths against the plan's C, seed-form bounds
/// against the plan's N. Array lengths are proven consistent *here*,
/// before any [`Tensor::new`] runs — its shape assertion can never
/// fire on wire data.
fn parse_wire_op(
    req: &Json,
    my_sessions: &BTreeMap<u64, SessInfo>,
    plans: &HostPlanRegistry,
) -> Result<WireCmd, WireFault> {
    let echo = req.get("echo").as_bool().unwrap_or(true);
    match req.get("op").as_str() {
        Some("open") => {
            let plan = req.get("plan").as_str().ok_or_else(|| {
                fault("validation", "open needs a \"plan\" name")
            })?;
            if plans.get(plan).is_none() {
                return Err(fault(
                    "validation",
                    &format!("unknown plan {plan}"),
                ));
            }
            Ok(WireCmd::Open {
                plan: plan.to_string(),
            })
        }
        Some("prefill") => {
            let (session, info) = session_of(req, my_sessions)?;
            let (q, k, v) = parse_qkv(req, info)?;
            Ok(WireCmd::Prefill { session, q, k, v, echo })
        }
        Some("step") => {
            let (session, info) = session_of(req, my_sessions)?;
            let (q, k, v) = parse_rows(req, info.c)?;
            Ok(WireCmd::Step { session, q, k, v, echo })
        }
        Some("oneshot") => {
            let name = req.get("artifact").as_str().ok_or_else(|| {
                fault("validation", "oneshot needs an \"artifact\" name")
            })?;
            let plan = plans.get(name).ok_or_else(|| {
                fault(
                    "validation",
                    &format!(
                        "unknown plan {name} (oneshot serves host plans)"
                    ),
                )
            })?;
            let info = SessInfo {
                c: plan.geometry.c,
                n_max: plan.geometry.n,
            };
            let (q, k, v) = parse_qkv(req, info)?;
            Ok(WireCmd::Oneshot {
                artifact: name.to_string(),
                q,
                k,
                v,
                echo,
            })
        }
        Some("close") => {
            let (session, _) = session_of(req, my_sessions)?;
            Ok(WireCmd::Close { session })
        }
        Some(other) => {
            Err(fault("validation", &format!("unknown op {other:?}")))
        }
        None => Err(fault("validation", "missing \"op\" string")),
    }
}

fn fault(kind: &'static str, msg: &str) -> WireFault {
    (kind, msg.to_string())
}

/// Resolve the frame's `session` id against this connection's own
/// sessions — ids from other connections are indistinguishable from
/// unknown ones (connection-owned sessions).
fn session_of(
    req: &Json,
    my_sessions: &BTreeMap<u64, SessInfo>,
) -> Result<(u64, SessInfo), WireFault> {
    let id = req.get("session").as_usize().ok_or_else(|| {
        fault("validation", "this op needs a \"session\" id")
    })? as u64;
    match my_sessions.get(&id) {
        Some(info) => Ok((id, *info)),
        None => Err((
            "session",
            format!("session {id} is not open on this connection"),
        )),
    }
}

/// Prefill/one-shot payload: seed form or explicit arrays, validated
/// against `info` so the tensors below are shape-consistent by
/// construction.
fn parse_qkv(
    req: &Json,
    info: SessInfo,
) -> Result<(Tensor, Tensor, Tensor), WireFault> {
    let c = info.c;
    if !req.get("seed").is_null() {
        let seed = seed_of(req)?;
        let n = req.get("n").as_usize().ok_or_else(|| {
            fault("validation", "seed-form payload needs \"n\" rows")
        })?;
        if n == 0 || n > info.n_max {
            return Err(fault(
                "validation",
                &format!("n={n} outside [1, {}]", info.n_max),
            ));
        }
        return Ok(synthetic_qkv(seed, n, c));
    }
    let q = f32_field(req, "q")?;
    let k = f32_field(req, "k")?;
    let v = f32_field(req, "v")?;
    let n = rows_of(q.len(), c, "q", info.n_max)?;
    let m = rows_of(k.len(), c, "k", info.n_max)?;
    if v.len() != k.len() {
        return Err(fault(
            "validation",
            &format!("v has {} values, want {} (same as k)",
                     v.len(), k.len()),
        ));
    }
    Ok((
        Tensor::new(&[n, c], q),
        Tensor::new(&[m, c], k),
        Tensor::new(&[m, c], v),
    ))
}

/// Decode-step payload: seed form (`seed` + `t`) or explicit arrays of
/// exactly `c` values each.
fn parse_rows(
    req: &Json,
    c: usize,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>), WireFault> {
    if !req.get("seed").is_null() {
        let seed = seed_of(req)?;
        let t = req.get("t").as_usize().ok_or_else(|| {
            fault("validation", "seed-form step needs \"t\" (position)")
        })?;
        return Ok(synthetic_rows(seed, t, c));
    }
    let q = f32_field(req, "q")?;
    let k = f32_field(req, "k")?;
    let v = f32_field(req, "v")?;
    for (name, row) in [("q", &q), ("k", &k), ("v", &v)] {
        if row.len() != c {
            return Err(fault(
                "validation",
                &format!("step {name} row has {} values, want {c}",
                         row.len()),
            ));
        }
    }
    Ok((q, k, v))
}

fn seed_of(req: &Json) -> Result<u64, WireFault> {
    req.get("seed")
        .as_usize()
        .map(|s| s as u64)
        .ok_or_else(|| {
            fault("validation",
                  "\"seed\" must be a non-negative integer")
        })
}

/// `len` must be a positive multiple of `c`, at most `n_max` rows.
fn rows_of(
    len: usize,
    c: usize,
    what: &str,
    n_max: usize,
) -> Result<usize, WireFault> {
    if len == 0 || len % c != 0 {
        return Err(fault(
            "validation",
            &format!("{what} has {len} values, want a positive \
                      multiple of C={c}"),
        ));
    }
    let rows = len / c;
    if rows > n_max {
        return Err(fault(
            "validation",
            &format!("{what} has {rows} rows, plan limit is {n_max}"),
        ));
    }
    Ok(rows)
}

/// Extract a flat f32 array field. Non-numeric elements (including
/// `null`, JSON's only spelling of non-finite) are validation errors.
fn f32_field(req: &Json, key: &str) -> Result<Vec<f32>, WireFault> {
    let arr = req.get(key).as_arr().ok_or_else(|| {
        fault(
            "validation",
            &format!("payload needs \"{key}\" as a number array \
                      (or the seed form)"),
        )
    })?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, x) in arr.iter().enumerate() {
        match x.as_f64() {
            Some(f) => out.push(f as f32),
            None => {
                return Err(fault(
                    "validation",
                    &format!("{key}[{i}] is not a number"),
                ));
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Server lifecycle
// ---------------------------------------------------------------------------

/// The TCP serving front-end. [`Self::serve`] binds, spawns the accept
/// and dispatch threads, and returns; dropping (or [`Self::shutdown`])
/// stops both, drains admitted work, and shuts the coordinator down.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
    accept: Option<JoinHandle<()>>,
    dispatch: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (use `"127.0.0.1:0"` for an ephemeral port) and
    /// serve `coord` under `cfg`. The coordinator moves into the
    /// dispatch thread — register host plans before calling.
    pub fn serve(coord: Coordinator, cfg: ServeConfig,
                 addr: impl ToSocketAddrs) -> Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("netserver bind: {e}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = coord.metrics_handle();
        let plans = Arc::clone(coord.host_plans());
        let (queue, admitted) = admission_queue::<Work>(cfg.queue_depth);
        let dispatch = {
            let (cfg, stop, metrics) =
                (cfg.clone(), stop.clone(), metrics.clone());
            std::thread::spawn(move || {
                net_dispatch_loop(coord, &admitted, &cfg, &stop,
                                  &metrics)
            })
        };
        let accept = {
            let (stop, metrics) = (stop.clone(), metrics.clone());
            std::thread::spawn(move || {
                net_accept_loop(listener, queue, plans, cfg, stop,
                                metrics)
            })
        };
        Ok(Self {
            addr,
            stop,
            metrics,
            accept: Some(accept),
            dispatch: Some(dispatch),
        })
    }

    /// The bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The coordinator's metrics sink (admission + flush counters
    /// included).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Stop accepting, drain admitted work, shut the coordinator down.
    pub fn shutdown(self) {
        // Drop does the work
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake the blocking accept with a throwaway connection; an
        // unspecified bind address (0.0.0.0 / ::) is not connectable
        // everywhere, so aim the wake at loopback on the same port
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let woke =
            TcpStream::connect_timeout(&wake, CONNECT_TIMEOUT).is_ok();
        if let Some(h) = self.accept.take() {
            if woke {
                let _ = h.join();
            }
            // wake failed: the accept thread stays parked in accept()
            // with the stop flag set — it exits on the next connection
            // or with the process; joining would hang forever
        }
        // the dispatch thread polls the stop flag every TICK, drains
        // what was admitted, and shuts the coordinator down; joining
        // it also drops the admission receiver, so any remaining
        // connection threads fail fast with `unavailable`
        if let Some(h) = self.dispatch.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Accept + connection threads
// ---------------------------------------------------------------------------

fn net_accept_loop(
    listener: TcpListener,
    queue: AdmissionQueue<Work>,
    plans: Arc<HostPlanRegistry>,
    cfg: ServeConfig,
    stop: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => {
                // a persistent accept error (fd exhaustion, EMFILE)
                // fails instantly — back off instead of busy-spinning
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        let queue = queue.clone();
        let plans = plans.clone();
        let metrics = metrics.clone();
        let cfg = cfg.clone();
        std::thread::spawn(move || {
            net_handle_conn(stream, &queue, &plans, &metrics, &cfg);
        });
    }
}

/// One connection: read a frame, answer it, repeat until the peer
/// closes or the protocol breaks. Exactly one response frame per
/// request frame, in order.
fn net_handle_conn(
    mut stream: TcpStream,
    queue: &AdmissionQueue<Work>,
    plans: &HostPlanRegistry,
    metrics: &Metrics,
    cfg: &ServeConfig,
) {
    if set_io_timeouts(&stream, cfg.io_timeout).is_err() {
        return;
    }
    let mut my_sessions: BTreeMap<u64, SessInfo> = BTreeMap::new();
    loop {
        let req = match read_frame_limited(&mut stream,
                                           cfg.max_request_bytes) {
            Ok(Some(r)) => r,
            Ok(None) => break, // clean close
            Err(e) => {
                // protocol damage is not recoverable mid-stream:
                // report once (best effort) and drop the connection
                let _ = write_frame(
                    &mut stream,
                    &err_json("frame", &e.to_string()),
                );
                break;
            }
        };
        // ops answerable without the dispatch thread: never queued,
        // never rejected
        match req.get("op").as_str() {
            Some("ping") => {
                let pong = Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("pong", Json::Bool(true)),
                ]);
                if write_frame(&mut stream, &pong).is_err() {
                    break;
                }
                continue;
            }
            Some("stats") => {
                let resp = Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("queue_depth", Json::num(queue.depth() as f64)),
                    ("metrics", metrics.to_json()),
                ]);
                if write_frame(&mut stream, &resp).is_err() {
                    break;
                }
                continue;
            }
            _ => {}
        }
        let cmd = match parse_wire_op(&req, &my_sessions, plans) {
            Ok(c) => c,
            Err((kind, msg)) => {
                if write_frame(&mut stream, &err_json(kind, &msg))
                    .is_err()
                {
                    break;
                }
                continue;
            }
        };
        // session bookkeeping material, captured before `cmd` moves
        let opened = match &cmd {
            WireCmd::Open { plan } => plans.get(plan).map(|p| SessInfo {
                c: p.geometry.c,
                n_max: p.geometry.n,
            }),
            _ => None,
        };
        let closing = match &cmd {
            WireCmd::Close { session } => Some(*session),
            _ => None,
        };
        let (tx, rx) = mpsc::channel();
        match queue.try_admit(Work { cmd, reply: tx }) {
            Ok(()) => {}
            Err(AdmitError::Full(_)) => {
                metrics.on_net_rejected();
                let refusal =
                    err_json("overloaded", "admission queue full");
                if write_frame(&mut stream, &refusal).is_err() {
                    break;
                }
                continue;
            }
            Err(AdmitError::Closed(_)) => {
                let _ = write_frame(
                    &mut stream,
                    &err_json("unavailable", "server shutting down"),
                );
                break;
            }
        }
        let resp = match rx.recv_timeout(REPLY_TIMEOUT) {
            Ok(r) => r,
            Err(_) => {
                // dispatch gone (shutdown) or wedged: either way this
                // connection can't be answered in order anymore
                let _ = write_frame(
                    &mut stream,
                    &err_json("unavailable",
                              "server dropped the request"),
                );
                break;
            }
        };
        if resp.get("ok").as_bool() == Some(true) {
            if let (Some(info), Some(id)) =
                (opened, resp.get("session").as_usize())
            {
                my_sessions.insert(id as u64, info);
            }
            if let Some(id) = closing {
                my_sessions.remove(&id);
            }
        }
        if write_frame(&mut stream, &resp).is_err() {
            break;
        }
    }
    // close any sessions the peer abandoned, best-effort: the reply
    // channel is dropped immediately, and a full queue just leaks the
    // session until shutdown
    for &id in my_sessions.keys() {
        let (tx, _rx) = mpsc::channel();
        let _ = queue.try_admit(Work {
            cmd: WireCmd::Close { session: id },
            reply: tx,
        });
    }
}

// ---------------------------------------------------------------------------
// Dispatch thread (owns the Coordinator)
// ---------------------------------------------------------------------------

/// In-flight request bookkeeping: where its response frame goes, and
/// whether to carry the output array.
struct PendingReply {
    reply: Sender<Json>,
    echo: bool,
}

fn net_dispatch_loop(
    mut coord: Coordinator,
    admitted: &AdmissionReceiver<Work>,
    cfg: &ServeConfig,
    stop: &AtomicBool,
    metrics: &Metrics,
) {
    let policy = cfg.flush_policy();
    let mut pending: BTreeMap<u64, PendingReply> = BTreeMap::new();
    // (tokens, submitted-at) of requests believed still in the
    // batcher's pending bucket, oldest first; reconciled against
    // `coord.pending_len()` each tick because the batcher also
    // self-flushes at max_batch
    let mut waiting: VecDeque<(usize, Instant)> = VecDeque::new();
    'outer: loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // drain a bounded burst of admitted work per tick: the first
        // recv waits, the rest are opportunistic
        let mut budget = 64usize;
        let mut next = admitted.recv_admitted(TICK);
        while let Some(dq) = next {
            metrics.on_net_admit(dq.wait, dq.depth);
            if !cfg.dispatch_delay.is_zero() {
                // flashlint: allow(dispatch-blocking) load-test pacing hook, zero in every production config
                std::thread::sleep(cfg.dispatch_delay);
            }
            if !handle_work(&mut coord, cfg, metrics, dq.item,
                            &mut pending, &mut waiting) {
                break 'outer; // worker pool stopped
            }
            budget -= 1;
            next = if budget > 0 {
                admitted.recv_admitted(Duration::ZERO)
            } else {
                None
            };
        }
        while let Some(resp) = coord.recv_timeout(Duration::ZERO) {
            finish(resp, &mut pending);
        }
        // waiting/served flush policy over this tick's observables
        let waiting_n = coord.pending_len();
        while waiting.len() > waiting_n {
            waiting.pop_front(); // batcher self-flushed these
        }
        if waiting_n > 0 {
            let in_flight =
                pending.len().saturating_sub(waiting.len());
            let tokens: usize = waiting.iter().map(|(t, _)| *t).sum();
            let oldest = waiting
                .front()
                .map(|(_, at)| at.elapsed())
                .unwrap_or(Duration::ZERO);
            if let Some(reason) =
                policy.decide(waiting_n, in_flight, tokens, oldest)
            {
                if coord.flush_all().is_err() {
                    break; // worker pool stopped
                }
                metrics.on_flush(reason);
                waiting.clear();
            }
        }
    }
    // shutdown: flush and drain what was admitted so no connection is
    // left waiting on a reply that will never come
    if !pending.is_empty() {
        if coord.flush_all().is_ok() {
            metrics.on_flush(FlushReason::Drain);
        }
        let deadline = Instant::now() + Duration::from_secs(5);
        while !pending.is_empty() && Instant::now() < deadline {
            if let Some(resp) =
                coord.recv_timeout(Duration::from_millis(100))
            {
                finish(resp, &mut pending);
            }
        }
    }
    for (_, p) in std::mem::take(&mut pending) {
        let _ = p
            .reply
            .send(err_json("unavailable", "server shutting down"));
    }
    coord.shutdown();
}

/// Apply one admitted command to the coordinator. Immediate ops reply
/// in place; submitted ops register in `pending` and reply when their
/// [`Response`] drains. Returns `false` only when the worker pool is
/// gone and the loop must wind down.
fn handle_work(
    coord: &mut Coordinator,
    cfg: &ServeConfig,
    metrics: &Metrics,
    work: Work,
    pending: &mut BTreeMap<u64, PendingReply>,
    waiting: &mut VecDeque<(usize, Instant)>,
) -> bool {
    let Work { cmd, reply } = work;
    match cmd {
        WireCmd::Open { plan } => {
            let resp = if coord.open_sessions() >= cfg.max_sessions {
                metrics.on_net_rejected();
                err_json(
                    "overloaded",
                    &format!("session cap {} reached",
                             cfg.max_sessions),
                )
            } else {
                match coord.open_session(&plan) {
                    Ok(id) => Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("session", Json::num(id as f64)),
                    ]),
                    Err(e) => session_err_json(&e),
                }
            };
            let _ = reply.send(resp);
        }
        WireCmd::Close { session } => {
            let resp = match coord.close_session(session) {
                Some(_) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("closed", Json::num(session as f64)),
                ]),
                None => err_json(
                    "session",
                    &format!("no open session {session}"),
                ),
            };
            let _ = reply.send(resp);
        }
        WireCmd::Prefill { session, q, k, v, echo } => {
            let tokens = q.shape().first().copied().unwrap_or(1);
            match coord.prefill(session, q, k, v) {
                Ok(rid) => {
                    pending.insert(rid, PendingReply { reply, echo });
                    waiting.push_back((tokens, Instant::now()));
                }
                Err(SessionApiError::Stopped) => {
                    let _ = reply.send(session_err_json(
                        &SessionApiError::Stopped,
                    ));
                    return false;
                }
                Err(e) => {
                    let _ = reply.send(session_err_json(&e));
                }
            }
        }
        WireCmd::Step { session, q, k, v, echo } => {
            match coord.step(session, &q, &k, &v) {
                Ok(rid) => {
                    pending.insert(rid, PendingReply { reply, echo });
                    waiting.push_back((1, Instant::now()));
                }
                Err(SessionApiError::Stopped) => {
                    let _ = reply.send(session_err_json(
                        &SessionApiError::Stopped,
                    ));
                    return false;
                }
                Err(e) => {
                    let _ = reply.send(session_err_json(&e));
                }
            }
        }
        WireCmd::Oneshot { artifact, q, k, v, echo } => {
            let tokens = q.shape().first().copied().unwrap_or(1);
            let inputs = vec![
                HostValue::F32(q),
                HostValue::F32(k),
                HostValue::F32(v),
            ];
            match coord.try_submit(&artifact, inputs) {
                Ok(rid) => {
                    pending.insert(rid, PendingReply { reply, echo });
                    waiting.push_back((tokens, Instant::now()));
                }
                Err(SubmitError::Backpressure { .. }) => {
                    metrics.on_net_rejected();
                    let _ = reply.send(err_json(
                        "overloaded",
                        "dispatch queue full",
                    ));
                }
                Err(e @ SubmitError::UnknownArtifact(_)) => {
                    let _ = reply.send(err_json(
                        "validation",
                        &e.to_string(),
                    ));
                }
                Err(SubmitError::Stopped) => {
                    let _ = reply.send(err_json(
                        "unavailable",
                        "worker pool stopped",
                    ));
                    return false;
                }
            }
        }
    }
    true
}

/// Map a session-API refusal to its wire error kind.
fn session_err_json(e: &SessionApiError) -> Json {
    let kind = match e {
        SessionApiError::UnknownPlan(_) => "validation",
        SessionApiError::UnknownSession(_) => "session",
        SessionApiError::State(_) => "session",
        SessionApiError::Stopped => "unavailable",
    };
    err_json(kind, &e.to_string())
}

/// Correlate one coordinator [`Response`] back to its connection.
fn finish(resp: Response, pending: &mut BTreeMap<u64, PendingReply>) {
    let Some(p) = pending.remove(&resp.id) else {
        // a best-effort close for an abandoned connection, or a reply
        // channel whose connection died: nothing to do
        return;
    };
    let msg = match &resp.outputs {
        Ok(outs) => output_json(&resp, outs, p.echo),
        Err(e) => err_json("exec", &format!("{e:#}")),
    };
    let _ = p.reply.send(msg);
}

/// The ok-response frame for a completed prefill/step/one-shot.
fn output_json(resp: &Response, outs: &[HostValue],
               echo: bool) -> Json {
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("id", Json::num(resp.id as f64)),
        ("queue_s", Json::num(resp.queue_time.as_secs_f64())),
        ("exec_s", Json::num(resp.exec_time.as_secs_f64())),
    ];
    if let Some(t) = outs.first().and_then(|h| h.as_f32()) {
        fields.push((
            "shape",
            Json::Arr(
                t.shape().iter().map(|&d| Json::num(d as f64)).collect(),
            ),
        ));
        if echo {
            fields.push((
                "out",
                Json::Arr(
                    t.data()
                        .iter()
                        .map(|&x| Json::num(x as f64))
                        .collect(),
                ),
            ));
        }
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_plans() -> (Arc<HostPlanRegistry>, String) {
        let plans = Arc::new(HostPlanRegistry::new());
        let plan = Planner::default()
            .plan(
                &BiasSpec::alibi(64, 64, 0.25),
                &Geometry::square(64, 16, 0, 100 * 1024 / 2),
                &PlanOptions {
                    causal: true,
                    ..PlanOptions::default()
                },
            )
            .expect("plan");
        plans.register("p", plan);
        (plans, "p".to_string())
    }

    #[test]
    fn synthetic_payloads_are_deterministic() {
        let (q1, k1, v1) = synthetic_qkv(7, 4, 16);
        let (q2, k2, v2) = synthetic_qkv(7, 4, 16);
        assert_eq!(q1.data(), q2.data());
        assert_eq!(k1.data(), k2.data());
        assert_eq!(v1.data(), v2.data());
        let (a, _, _) = synthetic_rows(7, 3, 16);
        let (b, _, _) = synthetic_rows(7, 3, 16);
        assert_eq!(a, b);
        let (c, _, _) = synthetic_rows(7, 4, 16);
        assert_ne!(a, c, "position must vary the row");
    }

    #[test]
    fn parse_validates_ops_and_shapes() {
        let (plans, name) = demo_plans();
        let mut sessions = BTreeMap::new();
        let parse = |req: &Json, s: &BTreeMap<u64, SessInfo>| {
            parse_wire_op(req, s, &plans)
        };

        // unknown op and missing op are validation faults
        let bad = Json::obj(vec![("op", Json::str("put"))]);
        assert_eq!(parse(&bad, &sessions).err().map(|f| f.0),
                   Some("validation"));
        let none = Json::obj(vec![]);
        assert_eq!(parse(&none, &sessions).err().map(|f| f.0),
                   Some("validation"));

        // open: unknown plan refused, known plan parses
        let open_bad = Json::obj(vec![
            ("op", Json::str("open")),
            ("plan", Json::str("nope")),
        ]);
        assert_eq!(parse(&open_bad, &sessions).err().map(|f| f.0),
                   Some("validation"));
        let open = Json::obj(vec![
            ("op", Json::str("open")),
            ("plan", Json::str(&name)),
        ]);
        assert!(parse(&open, &sessions).is_ok());

        // prefill against a session this connection never opened
        let foreign = Json::obj(vec![
            ("op", Json::str("prefill")),
            ("session", Json::num(9.0)),
            ("n", Json::num(2.0)),
            ("seed", Json::num(1.0)),
        ]);
        assert_eq!(parse(&foreign, &sessions).err().map(|f| f.0),
                   Some("session"));

        sessions.insert(9, SessInfo { c: 16, n_max: 64 });
        assert!(parse(&foreign, &sessions).is_ok());

        // seed-form n beyond the plan's context cap
        let huge = Json::obj(vec![
            ("op", Json::str("prefill")),
            ("session", Json::num(9.0)),
            ("n", Json::num(65.0)),
            ("seed", Json::num(1.0)),
        ]);
        assert_eq!(parse(&huge, &sessions).err().map(|f| f.0),
                   Some("validation"));

        // explicit arrays must be multiples of C with matching k/v
        let ragged = Json::obj(vec![
            ("op", Json::str("prefill")),
            ("session", Json::num(9.0)),
            ("q", Json::Arr(vec![Json::num(1.0); 17])),
            ("k", Json::Arr(vec![Json::num(1.0); 16])),
            ("v", Json::Arr(vec![Json::num(1.0); 16])),
        ]);
        assert_eq!(parse(&ragged, &sessions).err().map(|f| f.0),
                   Some("validation"));

        // a step row of the wrong width
        let narrow = Json::obj(vec![
            ("op", Json::str("step")),
            ("session", Json::num(9.0)),
            ("q", Json::Arr(vec![Json::num(1.0); 15])),
            ("k", Json::Arr(vec![Json::num(1.0); 16])),
            ("v", Json::Arr(vec![Json::num(1.0); 16])),
        ]);
        assert_eq!(parse(&narrow, &sessions).err().map(|f| f.0),
                   Some("validation"));

        // non-numeric array elements are refused, not NaN-coerced
        let poison = Json::obj(vec![
            ("op", Json::str("step")),
            ("session", Json::num(9.0)),
            ("q", Json::Arr(vec![Json::Null; 16])),
            ("k", Json::Arr(vec![Json::num(1.0); 16])),
            ("v", Json::Arr(vec![Json::num(1.0); 16])),
        ]);
        assert_eq!(parse(&poison, &sessions).err().map(|f| f.0),
                   Some("validation"));
    }

    #[test]
    fn error_frames_are_typed() {
        let e = err_json("overloaded", "queue full");
        assert_eq!(e.get("ok").as_bool(), Some(false));
        assert_eq!(e.get("kind").as_str(), Some("overloaded"));
        assert_eq!(e.get("error").as_str(), Some("queue full"));
    }
}
