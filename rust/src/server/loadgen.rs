//! Load generation against a live [`super::netserver::NetServer`].
//!
//! [`run_wave`] opens `connections` concurrent TCP clients and drives
//! `requests_per_conn` interactions down each: one-shot prefills when
//! `decode_steps == 0`, otherwise the full session lifecycle (`open` →
//! `prefill` → `decode_steps` × `step` → `close`). All payloads use
//! the wire's *seed form* — a few dozen bytes per frame, expanded to
//! tensors server-side — so the generator measures serving behavior
//! (admission, batching, flush policy), not JSON float printing.
//!
//! The merged [`WaveOutcome`] separates the three ways a request can
//! not complete: `overloaded` (the server's admission control said no
//! — the load test working as designed), `errors` (any other typed
//! error frame), and `protocol_errors` (transport/framing damage —
//! always a bug somewhere). The CI smoke gate asserts the last bucket
//! is zero while throughput is nonzero.

use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::jsonlite::Json;
use crate::util::frame::{
    read_frame, set_io_timeouts, write_frame, CONNECT_TIMEOUT,
};
use crate::util::Stats;

/// Client-side IO timeout. Matches the server's reply timeout: an
/// admitted request may legitimately wait out a deep queue before its
/// batch runs.
const CLIENT_IO_TIMEOUT: Duration = Duration::from_secs(60);

/// One load wave: `connections` clients, `requests_per_conn`
/// interactions each.
#[derive(Clone, Debug)]
pub struct WaveConfig {
    /// Server address, e.g. `"127.0.0.1:4891"`.
    pub addr: String,
    /// Host plan to serve against (see
    /// [`super::netserver::register_demo_plan`]).
    pub plan: String,
    pub connections: usize,
    pub requests_per_conn: usize,
    /// Rows per prefill/one-shot (seed-form `n`).
    pub prefill_rows: usize,
    /// Decode steps per interaction; `0` switches to one-shot mode.
    pub decode_steps: usize,
    /// Base seed; each connection and request derives its own.
    pub seed: u64,
}

impl Default for WaveConfig {
    fn default() -> Self {
        Self {
            addr: String::new(),
            plan: String::new(),
            connections: 8,
            requests_per_conn: 4,
            prefill_rows: 32,
            decode_steps: 4,
            seed: 0x10ad,
        }
    }
}

/// Merged result of one wave.
#[derive(Debug)]
pub struct WaveOutcome {
    /// Per-operation round-trip latency (seconds): prefill, step and
    /// one-shot exchanges; open/close bookkeeping is excluded.
    pub latency: Stats,
    /// Ok-frames for prefill/step/one-shot operations.
    pub completed: u64,
    /// Typed error frames other than `overloaded`.
    pub errors: u64,
    /// `overloaded` refusals (admission control at work).
    pub overloaded: u64,
    /// Transport or framing failures — protocol bugs.
    pub protocol_errors: u64,
    /// Wall-clock for the whole wave.
    pub wall_secs: f64,
}

impl WaveOutcome {
    /// Completed operations per wall-clock second.
    pub fn throughput(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.completed as f64 / self.wall_secs
        } else {
            0.0
        }
    }
}

/// Tallies one connection thread reports back for merging.
struct ConnTally {
    latency: Vec<f64>,
    completed: u64,
    errors: u64,
    overloaded: u64,
    protocol_errors: u64,
}

impl ConnTally {
    fn new() -> Self {
        Self {
            latency: Vec::new(),
            completed: 0,
            errors: 0,
            overloaded: 0,
            protocol_errors: 0,
        }
    }

    /// Classify one response frame (`None` = transport failure).
    fn observe(&mut self, resp: Option<&Json>, rtt: f64) {
        match resp {
            None => self.protocol_errors += 1,
            Some(r) if r.get("ok").as_bool() == Some(true) => {
                self.completed += 1;
                self.latency.push(rtt);
            }
            Some(r) if r.get("kind").as_str() == Some("overloaded") => {
                self.overloaded += 1;
            }
            Some(_) => self.errors += 1,
        }
    }
}

/// Run one wave and merge the per-connection tallies.
pub fn run_wave(cfg: &WaveConfig) -> WaveOutcome {
    let started = Instant::now();
    let (tx, rx) = mpsc::channel::<ConnTally>();
    let mut spawned = 0usize;
    for ci in 0..cfg.connections {
        let cfg = cfg.clone();
        let tx = tx.clone();
        // seeds stay below 2^53 so the wire's f64 numbers carry them
        // exactly
        let seed = cfg.seed ^ ((ci as u64) << 32);
        if std::thread::Builder::new()
            .spawn(move || {
                let _ = tx.send(conn_worker(&cfg, seed));
            })
            .is_ok()
        {
            spawned += 1;
        }
    }
    drop(tx);
    let mut out = WaveOutcome {
        latency: Stats::new(),
        completed: 0,
        errors: 0,
        overloaded: 0,
        protocol_errors: 0,
        wall_secs: 0.0,
    };
    if spawned < cfg.connections {
        // thread exhaustion: count the connections that never ran
        out.protocol_errors += (cfg.connections - spawned) as u64;
    }
    for tally in rx {
        for l in tally.latency {
            out.latency.push(l);
        }
        out.completed += tally.completed;
        out.errors += tally.errors;
        out.overloaded += tally.overloaded;
        out.protocol_errors += tally.protocol_errors;
    }
    out.wall_secs = started.elapsed().as_secs_f64();
    out
}

/// One client connection's work for the wave.
fn conn_worker(cfg: &WaveConfig, seed: u64) -> ConnTally {
    let mut tally = ConnTally::new();
    let Some(mut stream) = connect(&cfg.addr) else {
        // the whole connection's worth of requests failed transport
        tally.protocol_errors += cfg.requests_per_conn.max(1) as u64;
        return tally;
    };
    for ri in 0..cfg.requests_per_conn {
        let seed = seed ^ (ri as u64);
        let ok = if cfg.decode_steps == 0 {
            run_oneshot(&mut stream, cfg, seed, &mut tally)
        } else {
            run_session(&mut stream, cfg, seed, &mut tally)
        };
        if !ok {
            break; // transport gone; observe() already counted it
        }
    }
    tally
}

/// One one-shot interaction. Returns `false` when the transport died.
fn run_oneshot(stream: &mut TcpStream, cfg: &WaveConfig, seed: u64,
               tally: &mut ConnTally) -> bool {
    let req = Json::obj(vec![
        ("op", Json::str("oneshot")),
        ("artifact", Json::str(&cfg.plan)),
        ("n", Json::num(cfg.prefill_rows as f64)),
        ("seed", Json::num(seed as f64)),
        ("echo", Json::Bool(false)),
    ]);
    let at = Instant::now();
    let resp = exchange(stream, &req);
    tally.observe(resp.as_ref(), at.elapsed().as_secs_f64());
    resp.is_some()
}

/// One full session lifecycle. Returns `false` when the transport
/// died.
fn run_session(stream: &mut TcpStream, cfg: &WaveConfig, seed: u64,
               tally: &mut ConnTally) -> bool {
    let open = Json::obj(vec![
        ("op", Json::str("open")),
        ("plan", Json::str(&cfg.plan)),
    ]);
    let Some(resp) = exchange(stream, &open) else {
        tally.protocol_errors += 1;
        return false;
    };
    let Some(session) = resp.get("session").as_usize() else {
        // open refused (session cap, unknown plan): classify the
        // refusal and move on to the next interaction
        if resp.get("kind").as_str() == Some("overloaded") {
            tally.overloaded += 1;
        } else {
            tally.errors += 1;
        }
        return true;
    };
    let sid = Json::num(session as f64);

    let prefill = Json::obj(vec![
        ("op", Json::str("prefill")),
        ("session", sid.clone()),
        ("n", Json::num(cfg.prefill_rows as f64)),
        ("seed", Json::num(seed as f64)),
        ("echo", Json::Bool(false)),
    ]);
    let at = Instant::now();
    let resp = exchange(stream, &prefill);
    tally.observe(resp.as_ref(), at.elapsed().as_secs_f64());
    if resp.is_none() {
        return false;
    }

    for t in 0..cfg.decode_steps {
        let step = Json::obj(vec![
            ("op", Json::str("step")),
            ("session", sid.clone()),
            ("t", Json::num((cfg.prefill_rows + t) as f64)),
            ("seed", Json::num(seed as f64)),
            ("echo", Json::Bool(false)),
        ]);
        let at = Instant::now();
        let resp = exchange(stream, &step);
        tally.observe(resp.as_ref(), at.elapsed().as_secs_f64());
        if resp.is_none() {
            return false;
        }
    }

    let close = Json::obj(vec![
        ("op", Json::str("close")),
        ("session", sid),
    ]);
    if exchange(stream, &close).is_none() {
        tally.protocol_errors += 1;
        return false;
    }
    true
}

/// One request/response round trip. `None` only on transport failure —
/// typed error frames come back as `Some`.
fn exchange(stream: &mut TcpStream, req: &Json) -> Option<Json> {
    if write_frame(stream, req).is_err() {
        return None;
    }
    match read_frame(stream) {
        Ok(Some(resp)) => Some(resp),
        _ => None,
    }
}

fn connect(addr: &str) -> Option<TcpStream> {
    let resolved = addr.to_socket_addrs().ok()?.next()?;
    let stream =
        TcpStream::connect_timeout(&resolved, CONNECT_TIMEOUT).ok()?;
    set_io_timeouts(&stream, CLIENT_IO_TIMEOUT).ok()?;
    Some(stream)
}

/// Poll `ping` until the server answers or `deadline` passes. Spawning
/// callers (CI smoke, tests) use this instead of sleeping.
pub fn wait_ready(addr: &str, deadline: Duration) -> bool {
    let until = Instant::now() + deadline;
    while Instant::now() < until {
        if let Some(mut stream) = connect(addr) {
            let ping = Json::obj(vec![("op", Json::str("ping"))]);
            if let Some(resp) = exchange(&mut stream, &ping) {
                if resp.get("pong").as_bool() == Some(true) {
                    return true;
                }
            }
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    false
}

/// Fetch the server's `stats` frame (queue depth + full metrics JSON).
pub fn fetch_stats(addr: &str) -> Result<Json> {
    let mut stream = connect(addr)
        .ok_or_else(|| anyhow!("connect {addr} for stats"))?;
    let req = Json::obj(vec![("op", Json::str("stats"))]);
    exchange(&mut stream, &req)
        .ok_or_else(|| anyhow!("stats exchange with {addr} failed"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_classifies_frames() {
        let mut t = ConnTally::new();
        t.observe(None, 0.0);
        let ok = Json::obj(vec![("ok", Json::Bool(true))]);
        t.observe(Some(&ok), 0.01);
        let busy = Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("kind", Json::str("overloaded")),
        ]);
        t.observe(Some(&busy), 0.0);
        let bad = Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("kind", Json::str("validation")),
        ]);
        t.observe(Some(&bad), 0.0);
        assert_eq!(t.protocol_errors, 1);
        assert_eq!(t.completed, 1);
        assert_eq!(t.overloaded, 1);
        assert_eq!(t.errors, 1);
        assert_eq!(t.latency.len(), 1);
    }

    #[test]
    fn throughput_is_zero_without_wall_time() {
        let out = WaveOutcome {
            latency: Stats::new(),
            completed: 10,
            errors: 0,
            overloaded: 0,
            protocol_errors: 0,
            wall_secs: 0.0,
        };
        assert_eq!(out.throughput(), 0.0);
    }
}
