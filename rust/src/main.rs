//! `flashbias` — leader entrypoint. See `server::USAGE`.

use flashbias::server::{run, Cli};

fn main() {
    let cli = match Cli::parse(std::env::args().skip(1)) {
        Ok(cli) => cli,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    match run(&cli) {
        Ok(out) => print!("{out}"),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
