//! Analytic HBM-access model — the paper's theory section in executable
//! form (Theorems 3.1/3.2, Corollaries 3.3/3.7/I.2, Example 3.9).
//!
//! All quantities are in *elements* unless a function says bytes; callers
//! multiply by `dtype_bytes` where the paper does (Example 3.9 uses fp16 =
//! 2 B). The tiled-execution simulator (`crate::simulator`) must agree
//! with these asymptotics up to block-rounding — that agreement is tested
//! in `tests/sim_vs_model.rs`.

/// Problem geometry for an attention-with-bias computation.
#[derive(Clone, Copy, Debug)]
pub struct Geometry {
    /// Query sequence length.
    pub n: usize,
    /// Key/value sequence length.
    pub m: usize,
    /// Head channel dimension.
    pub c: usize,
    /// Bias rank (0 = no bias).
    pub r: usize,
    /// SRAM size in elements.
    pub sram: usize,
}

impl Geometry {
    pub fn square(n: usize, c: usize, r: usize, sram: usize) -> Self {
        Self { n, m: n, c, r, sram }
    }
}

// ---------------------------------------------------------------------------
// FlashAttention baseline costs (Appendix A Eq. 6)
// ---------------------------------------------------------------------------

/// HBM accesses of standard (materializing) attention: Θ(NC + N²).
pub fn standard_attention_io(g: &Geometry) -> f64 {
    (g.n * g.c + g.m * g.c + g.n * g.m) as f64
}

/// HBM accesses of FlashAttention (no bias): Θ(N²C²/S).
pub fn flash_attention_io(g: &Geometry) -> f64 {
    (g.n as f64 * g.m as f64 * (g.c * g.c) as f64) / g.sram as f64
}

/// HBM accesses of FlashAttention reading a dense bias:
/// Θ(NMC²/S + NM) (Example 3.9).
pub fn flash_dense_bias_io(g: &Geometry) -> f64 {
    flash_attention_io(g) + (g.n * g.m) as f64
}

/// Corollary 3.7: HBM accesses of FlashBias — Θ(NM(C² + R²)/S).
pub fn flashbias_io(g: &Geometry) -> f64 {
    let cr = (g.c * g.c + g.r * g.r) as f64;
    g.n as f64 * g.m as f64 * cr / g.sram as f64
}

/// Corollary 3.3: the lower bound — no algorithm computes exact attention
/// with a rank-R bias in o(NM(C²+R²)/S) accesses. Returned as the bound
/// value itself (same form as [`flashbias_io`]; FlashBias is optimal).
pub fn lower_bound_io(g: &Geometry) -> f64 {
    flashbias_io(g)
}

/// FlexAttention-like baseline: recomputes the bias element-wise in-graph.
/// No dense HBM bias stream, but O(NM) element-wise *work* and the same
/// q/k/v streaming as FlashAttention. We model its IO as FlashAttention's
/// (its weakness is compute + recompilation, not IO) — see simulator for
/// the recompilation penalty.
pub fn flexlike_io(g: &Geometry) -> f64 {
    flash_attention_io(g)
}

// ---------------------------------------------------------------------------
// Theorem 3.1
// ---------------------------------------------------------------------------

/// Theorem 3.1 part 1: the IO ratio standard/Flash = Θ(β(1 + 1/α))
/// where C = αN and S = βNC. Returns the Θ-constant-free value.
pub fn flash_speedup_ratio(alpha: f64, beta: f64) -> f64 {
    beta * (1.0 + 1.0 / alpha)
}

/// Theorem 3.1 part 2: α ≥ R/N — the channel dimension cannot be reduced
/// below the rank of the attention weight. Returns the optimal α.
pub fn optimal_alpha(rank: usize, n: usize) -> f64 {
    rank as f64 / n as f64
}

// ---------------------------------------------------------------------------
// Theorem 3.2
// ---------------------------------------------------------------------------

/// Theorem 3.2: optimal storage of an N×N rank-R dense matrix is Θ(NR);
/// the exact minimum is 2NR − R² elements.
pub fn optimal_storage_elems(n: usize, r: usize) -> usize {
    2 * n * r - r * r
}

/// Storage of the FlashBias factor pair: (N + M)·R elements.
pub fn factored_storage_elems(n: usize, m: usize, r: usize) -> usize {
    (n + m) * r
}

/// Dense storage: N·M elements.
pub fn dense_storage_elems(n: usize, m: usize) -> usize {
    n * m
}

// ---------------------------------------------------------------------------
// Example 3.9 + Corollary I.2
// ---------------------------------------------------------------------------

/// Example 3.9: the ratio FlashAttention-with-bias / FlashBias at the
/// paper's reference point (C = 64, S = 100 KB fp16, R = 64, N,M ≫ C,R).
///
/// `sram_bytes` and `dtype_bytes` let callers reproduce the paper's ≈6×.
pub fn example_3_9_ratio(c: usize, r: usize, sram_bytes: usize,
                         dtype_bytes: usize) -> f64 {
    let s = (sram_bytes / dtype_bytes) as f64;
    let c2 = (c * c) as f64;
    let r2 = (r * r) as f64;
    // (NMC²/S + NM) / (NM(C²+R²)/S)  =  (C² + S) / (C² + R²)
    (c2 + s) / (c2 + r2)
}

/// Corollary I.2: multiplicative-bias FlashBias reduces HBM access iff
/// R ≤ √(S/C² + 1). Returns the threshold rank.
pub fn mult_bias_rank_threshold(c: usize, sram_elems: usize) -> f64 {
    ((sram_elems as f64) / ((c * c) as f64) + 1.0).sqrt()
}

/// HBM accesses of the multiplicative channel-repeat trick (Eq. 17):
/// Θ(NMC²R²/S).
pub fn mult_factored_io(g: &Geometry) -> f64 {
    let c2r2 = ((g.c * g.c) as f64) * ((g.r * g.r) as f64);
    g.n as f64 * g.m as f64 * c2r2 / g.sram as f64
}

// ---------------------------------------------------------------------------
// Per-decode-step costs (the prefill/decode split)
// ---------------------------------------------------------------------------

/// HBM accesses of one incremental-decode step *without* bias, in
/// elements: the new query row streams the whole cached K/V slab once
/// (2·M·C) plus reads its own row and writes the output row (2·C).
/// The N×M framing collapses to 1×M — there is no C²/S tiling term
/// because a single query row's accumulator state always fits SRAM.
pub fn decode_step_io(g: &Geometry) -> f64 {
    (2 * g.m * g.c + 2 * g.c) as f64
}

/// Decode step reading a dense bias table: adds the O(M) bias row,
/// *every* step — table rows are distinct per position, so they never
/// amortize across steps the way factor strips do.
pub fn decode_step_dense_io(g: &Geometry) -> f64 {
    decode_step_io(g) + g.m as f64
}

/// Decode step with the Eq.-3 factored strips: the 1×M bias row is an
/// O(R·M) contraction of φ_q's row against φ_k. When the `(N + M)·R`
/// strips fit SRAM they stay resident across steps and the step pays
/// zero bias HBM traffic; otherwise it streams `R·(M + 1)` strip
/// elements (φ_k block + φ_q row). JIT biases (ALiBi) are the R = 0
/// degenerate case of the resident branch.
pub fn decode_step_factored_io(g: &Geometry) -> f64 {
    if factored_storage_elems(g.n, g.m, g.r) <= g.sram {
        decode_step_io(g)
    } else {
        decode_step_io(g) + (g.r * (g.m + 1)) as f64
    }
}

// ---------------------------------------------------------------------------
// Memory footprint model (Figure 3 a-b)
// ---------------------------------------------------------------------------

/// Peak activation+bias memory for one attention layer at inference, in
/// elements. `dense_bias`: whether the N×M bias is materialized.
pub fn inference_memory_elems(g: &Geometry, dense_bias: bool) -> usize {
    let qkv = g.n * g.c + 2 * g.m * g.c;
    let bias = if dense_bias {
        g.n * g.m
    } else {
        factored_storage_elems(g.n, g.m, g.r)
    };
    qkv + bias + g.n * g.c // + output
}

/// Training adds the saved bias (or factor) gradients (§4.4: dense
/// methods must store an N×M gradient per head).
pub fn training_memory_elems(g: &Geometry, dense_bias: bool) -> usize {
    let base = inference_memory_elems(g, dense_bias);
    let grad = if dense_bias {
        g.n * g.m
    } else {
        factored_storage_elems(g.n, g.m, g.r)
    };
    base + grad
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo(n: usize) -> Geometry {
        Geometry::square(n, 64, 64, 100 * 1024 / 2)
    }

    #[test]
    fn example_3_9_reproduces_paper_6x() {
        // paper: C=64, S=100KB fp16, R=64 → ≈6×
        let ratio = example_3_9_ratio(64, 64, 100 * 1024, 2);
        assert!((ratio - 6.0).abs() < 1.0, "ratio {ratio}");
    }

    #[test]
    fn flashbias_beats_dense_bias_at_scale() {
        for n in [1024usize, 4096, 16384] {
            let g = geo(n);
            assert!(flashbias_io(&g) < flash_dense_bias_io(&g));
        }
    }

    #[test]
    fn flashbias_io_equals_flash_when_r_zero() {
        let g = Geometry::square(4096, 64, 0, 50 * 1024);
        assert_eq!(flashbias_io(&g), flash_attention_io(&g));
    }

    #[test]
    fn thm_3_1_ratio_behaviour() {
        // speedup grows as α shrinks (lower rank ⇒ smaller channel dim)
        assert!(flash_speedup_ratio(0.01, 0.5) > flash_speedup_ratio(0.1, 0.5));
        // and linearly with β (bigger SRAM)
        let r1 = flash_speedup_ratio(0.05, 0.2);
        let r2 = flash_speedup_ratio(0.05, 0.4);
        assert!((r2 / r1 - 2.0).abs() < 1e-9);
        // α ≥ R/N
        assert_eq!(optimal_alpha(64, 4096), 64.0 / 4096.0);
    }

    #[test]
    fn thm_3_2_storage_bounds() {
        let n = 1024;
        for r in [1usize, 16, 64, 256] {
            let opt = optimal_storage_elems(n, r);
            // NR ≤ 2NR − R² ≤ 2NR (Appendix A Eq. 8)
            assert!(n * r <= opt);
            assert!(opt <= 2 * n * r);
            // the factor pair is within 2× of optimal
            let ours = factored_storage_elems(n, n, r);
            assert!(ours >= opt);
            assert!(ours <= 2 * opt);
        }
    }

    #[test]
    fn factored_storage_beats_dense_when_low_rank() {
        // (N+M)R < NM  ⇔  R < NM/(N+M); at N=M: R < N/2
        assert!(
            factored_storage_elems(1024, 1024, 64)
                < dense_storage_elems(1024, 1024)
        );
        // degenerate: high rank loses
        assert!(
            factored_storage_elems(16, 16, 16) > dense_storage_elems(16, 16)
        );
    }

    #[test]
    fn cor_i2_threshold() {
        // paper Example I.3: C=64, S=100KB (fp16 → 51200 elems) → R ≤ 27...
        // (the paper uses bytes/2 elements; threshold ≈ sqrt(51200/4096+1))
        let thr = mult_bias_rank_threshold(64, 100 * 1024 / 2);
        assert!((thr - 3.67).abs() < 0.1, "thr {thr}");
        // with the paper's S in raw bytes interpretation (their Example I.3
        // computes sqrt(100·1024/64² + 1) ≈ 27... using S in half-words ×16)
        let thr_paper = mult_bias_rank_threshold(64, 100 * 1024 * 16 / 2);
        assert!(thr_paper > 10.0);
    }

    #[test]
    fn mult_factored_io_crossover() {
        // multiplicative trick only helps below the threshold rank
        let s = 100 * 1024 / 2;
        let thr = mult_bias_rank_threshold(64, s);
        let below = Geometry::square(4096, 64, thr as usize, s);
        let above = Geometry::square(4096, 64, thr as usize + 2, s);
        assert!(mult_factored_io(&below) <= flash_dense_bias_io(&below) * 1.1);
        assert!(mult_factored_io(&above) > flash_dense_bias_io(&above));
    }

    #[test]
    fn memory_model_scaling() {
        let g = geo(16384);
        let dense = inference_memory_elems(&g, true);
        let fact = inference_memory_elems(&g, false);
        // paper Figure 3: ~10× memory reduction at N=16384 inference
        assert!(dense as f64 / fact as f64 > 5.0);
        // training gap is larger than inference gap (gradient storage)
        let dense_t = training_memory_elems(&g, true);
        let fact_t = training_memory_elems(&g, false);
        assert!(dense_t - dense >= g.n * g.m);
        assert!(fact_t - fact < g.n * g.m / 10);
    }

    #[test]
    fn decode_step_costs_order_factored_below_dense() {
        // low rank, long context: strips resident or cheap; dense table
        // rows never amortize
        for m in [2048usize, 8192, 65536] {
            let g = Geometry {
                n: m,
                m,
                c: 64,
                r: 8,
                sram: 100 * 1024 / 2,
            };
            assert!(decode_step_factored_io(&g) < decode_step_dense_io(&g));
            assert!(decode_step_io(&g) <= decode_step_factored_io(&g));
        }
        // resident branch: strips within SRAM pay zero bias traffic
        let small = Geometry {
            n: 128,
            m: 128,
            c: 64,
            r: 8,
            sram: 100 * 1024 / 2,
        };
        assert_eq!(decode_step_factored_io(&small), decode_step_io(&small));
        // spilled branch: huge strips stream R·(M+1)
        let big = Geometry {
            n: 65536,
            m: 65536,
            c: 64,
            r: 64,
            sram: 4 * 1024,
        };
        assert_eq!(
            decode_step_factored_io(&big),
            decode_step_io(&big) + (64 * 65537) as f64
        );
    }

    #[test]
    fn standard_vs_flash_crossover_with_sram() {
        // big SRAM ⇒ Flash wins big; tiny SRAM ⇒ gains shrink (Thm 3.1)
        let big = Geometry::square(4096, 64, 0, 256 * 1024);
        let small = Geometry::square(4096, 64, 0, 4 * 1024);
        let ratio_big = standard_attention_io(&big) / flash_attention_io(&big);
        let ratio_small =
            standard_attention_io(&small) / flash_attention_io(&small);
        assert!(ratio_big > ratio_small);
    }
}
