//! The paper's bias zoo: dense generators plus exact factorizations.
//!
//! Each bias type knows how to (a) materialize its dense `N×M` matrix,
//! (b) emit exact factor strips `φ_q (N×R)` / `φ_k (M×R)` when a
//! closed-form decomposition exists (Table 1a), and (c) report its exact
//! rank. Mirrors `python/compile/decomp.py`; the cross-layer tests pin
//! both against each other through the AOT artifacts.

use crate::tensor::Tensor;
use crate::util::Xoshiro256;

/// A bias with an exact closed-form factorization (Table 1a).
pub trait ExactBias {
    /// Dense `N×M` bias matrix.
    fn dense(&self) -> Tensor;
    /// Exact factor strips such that `φ_q φ_kᵀ == dense()`.
    fn factors(&self) -> (Tensor, Tensor);
    /// Exact rank R of the factorization.
    fn rank(&self) -> usize;
    fn shape(&self) -> (usize, usize);
}

// ---------------------------------------------------------------------------
// ALiBi (Example 3.4)
// ---------------------------------------------------------------------------

/// ALiBi bias `b[i,j] = slope · (j − i)` (pre-causal-mask). R = 2.
#[derive(Clone, Debug)]
pub struct Alibi {
    pub n: usize,
    pub m: usize,
    pub slope: f32,
}

impl Alibi {
    pub fn new(n: usize, m: usize, slope: f32) -> Self {
        Self { n, m, slope }
    }

    /// Geometric per-head slopes 2^(−8h/H) from the ALiBi paper.
    pub fn head_slopes(num_heads: usize) -> Vec<f32> {
        (1..=num_heads)
            .map(|h| 2f32.powf(-8.0 * h as f32 / num_heads as f32))
            .collect()
    }
}

impl ExactBias for Alibi {
    fn dense(&self) -> Tensor {
        Tensor::from_fn(&[self.n, self.m], |ix| {
            self.slope * (ix[1] as f32 - ix[0] as f32)
        })
    }

    fn factors(&self) -> (Tensor, Tensor) {
        // φ_q(i) = [−slope·i, slope], φ_k(j) = [1, j]
        let pq = Tensor::from_fn(&[self.n, 2], |ix| match ix[1] {
            0 => -self.slope * ix[0] as f32,
            _ => self.slope,
        });
        let pk = Tensor::from_fn(&[self.m, 2], |ix| match ix[1] {
            0 => 1.0,
            _ => ix[0] as f32,
        });
        (pq, pk)
    }

    fn rank(&self) -> usize {
        2
    }

    fn shape(&self) -> (usize, usize) {
        (self.n, self.m)
    }
}

// ---------------------------------------------------------------------------
// Spatial squared distance (Example 3.5 / §4.4 PDE solver)
// ---------------------------------------------------------------------------

/// Weighted spatial distance bias `b[i,j] = −α_i · ‖x_i − x_j‖²`.
/// Exact rank 3·dim (9 for 3-D). `alpha = None` → unweighted.
#[derive(Clone, Debug)]
pub struct SpatialDistance {
    /// (N, dim) query positions.
    pub xq: Tensor,
    /// (M, dim) key positions.
    pub xk: Tensor,
    /// Optional per-query weights (N,).
    pub alpha: Option<Vec<f32>>,
}

impl SpatialDistance {
    pub fn new(xq: Tensor, xk: Tensor, alpha: Option<Vec<f32>>) -> Self {
        assert_eq!(xq.shape()[1], xk.shape()[1], "dim mismatch");
        if let Some(a) = &alpha {
            assert_eq!(a.len(), xq.shape()[0], "alpha length mismatch");
        }
        Self { xq, xk, alpha }
    }

    fn weight(&self, i: usize) -> f32 {
        self.alpha.as_ref().map_or(1.0, |a| a[i])
    }

    fn dim(&self) -> usize {
        self.xq.shape()[1]
    }
}

impl ExactBias for SpatialDistance {
    fn dense(&self) -> Tensor {
        let (n, m) = self.shape();
        let dim = self.dim();
        Tensor::from_fn(&[n, m], |ix| {
            let (i, j) = (ix[0], ix[1]);
            let mut d2 = 0.0f32;
            for d in 0..dim {
                let diff = self.xq.at2(i, d) - self.xk.at2(j, d);
                d2 += diff * diff;
            }
            -self.weight(i) * d2
        })
    }

    fn factors(&self) -> (Tensor, Tensor) {
        // per-dim triple: φ_q = [−α·x², −α, 2α·x], φ_k = [1, x², x]
        let (n, m) = self.shape();
        let dim = self.dim();
        let r = 3 * dim;
        let pq = Tensor::from_fn(&[n, r], |ix| {
            let (i, c) = (ix[0], ix[1]);
            let (d, slot) = (c / 3, c % 3);
            let x = self.xq.at2(i, d);
            let a = self.weight(i);
            match slot {
                0 => -a * x * x,
                1 => -a,
                _ => 2.0 * a * x,
            }
        });
        let pk = Tensor::from_fn(&[m, r], |ix| {
            let (j, c) = (ix[0], ix[1]);
            let (d, slot) = (c / 3, c % 3);
            let x = self.xk.at2(j, d);
            match slot {
                0 => 1.0,
                1 => x * x,
                _ => x,
            }
        });
        (pq, pk)
    }

    fn rank(&self) -> usize {
        3 * self.dim()
    }

    fn shape(&self) -> (usize, usize) {
        (self.xq.shape()[0], self.xk.shape()[0])
    }
}

// ---------------------------------------------------------------------------
// Multiplicative cos bias (Example I.1)
// ---------------------------------------------------------------------------

/// Multiplicative bias `b[i,j] = cos(i − j)`; exact rank 2 via the
/// angle-difference identity.
#[derive(Clone, Debug)]
pub struct CosMultiplicative {
    pub n: usize,
    pub m: usize,
}

impl ExactBias for CosMultiplicative {
    fn dense(&self) -> Tensor {
        Tensor::from_fn(&[self.n, self.m], |ix| {
            (ix[0] as f32 - ix[1] as f32).cos()
        })
    }

    fn factors(&self) -> (Tensor, Tensor) {
        let pq = Tensor::from_fn(&[self.n, 2], |ix| {
            let i = ix[0] as f32;
            if ix[1] == 0 { i.cos() } else { i.sin() }
        });
        let pk = Tensor::from_fn(&[self.m, 2], |ix| {
            let j = ix[0] as f32;
            if ix[1] == 0 { j.cos() } else { j.sin() }
        });
        (pq, pk)
    }

    fn rank(&self) -> usize {
        2
    }

    fn shape(&self) -> (usize, usize) {
        (self.n, self.m)
    }
}

// ---------------------------------------------------------------------------
// Dense-only generators (neural-decomposition targets, Appendix G)
// ---------------------------------------------------------------------------

/// Gravity bias `1/(‖x_i − x_j‖² + eps)` (Appendix G Eq. 13). Not exactly
/// low-rank; used as a neural-decomposition target.
pub fn gravity_bias(xq: &Tensor, xk: &Tensor, eps: f32) -> Tensor {
    let (n, m) = (xq.shape()[0], xk.shape()[0]);
    let dim = xq.shape()[1];
    Tensor::from_fn(&[n, m], |ix| {
        let mut d2 = 0.0f32;
        for d in 0..dim {
            let diff = xq.at2(ix[0], d) - xk.at2(ix[1], d);
            d2 += diff * diff;
        }
        1.0 / (d2 + eps)
    })
}

/// Haversine great-circle distance bias (Appendix G Eq. 14).
/// Columns of `x` are (latitude, longitude) in radians.
pub fn spherical_bias(xq: &Tensor, xk: &Tensor) -> Tensor {
    let (n, m) = (xq.shape()[0], xk.shape()[0]);
    Tensor::from_fn(&[n, m], |ix| {
        let (lat1, lon1) = (xq.at2(ix[0], 0), xq.at2(ix[0], 1));
        let (lat2, lon2) = (xk.at2(ix[1], 0), xk.at2(ix[1], 1));
        let s1 = ((lat1 - lat2) / 2.0).sin().powi(2);
        let s2 = lat1.cos() * lat2.cos() * ((lon1 - lon2) / 2.0).sin().powi(2);
        2.0 * (s1 + s2).clamp(0.0, 1.0).sqrt().asin()
    })
}

// ---------------------------------------------------------------------------
// Synthetic "trained" relative-position tables (Swin / Pangu substitution)
// ---------------------------------------------------------------------------

/// Synthetic learned 2-D relative-position bias with realistic spectra:
/// a sum of separable Gaussians over the offset table (smooth, low-rank)
/// plus white noise (the full-rank tail), gathered into (N, N), N = wy·wx.
/// Mirrors `decomp.swin_relative_bias` on the python side.
pub fn swin_relative_bias(
    window: (usize, usize),
    num_heads: usize,
    seed: u64,
    smooth_terms: usize,
    noise: f32,
) -> Vec<Tensor> {
    let (wy, wx) = window;
    let n = wy * wx;
    let (ty, tx) = (2 * wy - 1, 2 * wx - 1);
    let mut rng = Xoshiro256::new(seed);
    let mut out = Vec::with_capacity(num_heads);
    for _ in 0..num_heads {
        // build the (2wy−1, 2wx−1) offset table
        let mut table = vec![0.0f32; ty * tx];
        for _ in 0..smooth_terms {
            let cy = rng.normal() * wy as f64 / 2.0;
            let cx = rng.normal() * wx as f64 / 2.0;
            let sy = rng.uniform(wy as f64 / 4.0, wy as f64);
            let sx = rng.uniform(wx as f64 / 4.0, wx as f64);
            let amp = rng.normal();
            for (idx, t) in table.iter_mut().enumerate() {
                let dy = (idx / tx) as f64 - (wy as f64 - 1.0);
                let dx = (idx % tx) as f64 - (wx as f64 - 1.0);
                let g = (-((dy - cy) / sy).powi(2)).exp()
                    * (-((dx - cx) / sx).powi(2)).exp();
                *t += (amp * g) as f32;
            }
        }
        for t in table.iter_mut() {
            *t += noise * rng.normal_f32();
        }
        // gather into (n, n) by relative offset
        let bias = Tensor::from_fn(&[n, n], |ix| {
            let (iy, ixx) = (ix[0] / wx, ix[0] % wx);
            let (jy, jx) = (ix[1] / wx, ix[1] % wx);
            let dy = iy as isize - jy as isize + (wy as isize - 1);
            let dx = ixx as isize - jx as isize + (wx as isize - 1);
            table[dy as usize * tx + dx as usize]
        });
        out.push(bias);
    }
    out
}

/// Synthetic learned 3-D relative-position bias (Pangu-Weather window
/// 2×6×12 = 144). Same construction as the 2-D version, in 3-D.
pub fn pangu_relative_bias(
    window: (usize, usize, usize),
    num_heads: usize,
    seed: u64,
    smooth_terms: usize,
    noise: f32,
) -> Vec<Tensor> {
    let (wz, wy, wx) = window;
    let n = wz * wy * wx;
    let (tz, ty, tx) = (2 * wz - 1, 2 * wy - 1, 2 * wx - 1);
    let mut rng = Xoshiro256::new(seed);
    let mut out = Vec::with_capacity(num_heads);
    for _ in 0..num_heads {
        let mut table = vec![0.0f32; tz * ty * tx];
        for _ in 0..smooth_terms {
            let cz = rng.normal() * wz as f64 / 2.0;
            let cy = rng.normal() * wy as f64 / 2.0;
            let cx = rng.normal() * wx as f64 / 2.0;
            let sz = rng.uniform(wz as f64 / 3.0, wz as f64);
            let sy = rng.uniform(wy as f64 / 3.0, wy as f64);
            let sx = rng.uniform(wx as f64 / 3.0, wx as f64);
            let amp = rng.normal();
            for (idx, t) in table.iter_mut().enumerate() {
                let dz = (idx / (ty * tx)) as f64 - (wz as f64 - 1.0);
                let dy = ((idx / tx) % ty) as f64 - (wy as f64 - 1.0);
                let dx = (idx % tx) as f64 - (wx as f64 - 1.0);
                let g = (-((dz - cz) / sz).powi(2)).exp()
                    * (-((dy - cy) / sy).powi(2)).exp()
                    * (-((dx - cx) / sx).powi(2)).exp();
                *t += (amp * g) as f32;
            }
        }
        for t in table.iter_mut() {
            *t += noise * rng.normal_f32();
        }
        let coord = |flat: usize| -> (usize, usize, usize) {
            (flat / (wy * wx), (flat / wx) % wy, flat % wx)
        };
        let bias = Tensor::from_fn(&[n, n], |ix| {
            let (iz, iy, ixx) = coord(ix[0]);
            let (jz, jy, jx) = coord(ix[1]);
            let dz = (iz as isize - jz as isize + tz as isize / 2) as usize;
            let dy = (iy as isize - jy as isize + ty as isize / 2) as usize;
            let dx = (ixx as isize - jx as isize + tx as isize / 2) as usize;
            table[dz * ty * tx + dy * tx + dx]
        });
        out.push(bias);
    }
    out
}

/// Synthetic car-like hull point cloud (DrivAer stand-in for the PDE
/// solver, §4.4): elongated ellipsoid body + cabin bump + wheel clusters.
pub fn synthetic_car_cloud(n: usize, seed: u64) -> Tensor {
    let mut rng = Xoshiro256::new(seed);
    let mut data = Vec::with_capacity(n * 3);
    for _ in 0..n {
        let u = rng.next_f64();
        let t = rng.uniform(0.0, 2.0 * std::f64::consts::PI);
        let x = 4.0 * (u - 0.5);
        let ry = 0.8 * (1.0 - (2.0 * u - 1.0).powi(2)).max(0.0).sqrt() + 0.05;
        let y = ry * t.cos();
        let mut z = 0.5 * ry * t.sin().abs();
        let cabin = (-(x - 0.2) * (x - 0.2) / 0.5).exp();
        z += 0.35 * cabin * t.sin().max(0.0);
        for wx in [-1.2, 1.2] {
            for wy in [-0.6, 0.6] {
                let d = (x - wx).powi(2) + (y - wy).powi(2);
                if d < 0.08 {
                    z = -0.2 + 0.1 * rng.next_f64();
                }
            }
        }
        data.push((x + 0.01 * rng.normal()) as f32);
        data.push((y + 0.01 * rng.normal()) as f32);
        data.push((z + 0.01 * rng.normal()) as f32);
    }
    Tensor::new(&[n, 3], data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;

    fn assert_exact<B: ExactBias>(b: &B, tol: f32) {
        let dense = b.dense();
        let (pq, pk) = b.factors();
        assert_eq!(pq.shape()[1], b.rank());
        assert_eq!(pk.shape()[1], b.rank());
        let recon = pq.matmul_t(&pk);
        assert!(
            recon.allclose(&dense, tol, tol),
            "max err {}",
            recon.sub(&dense).max_abs()
        );
    }

    #[test]
    fn alibi_factorization_exact() {
        for (n, m, slope) in [(16, 16, 0.5), (7, 23, 0.0625), (64, 32, 1.0)] {
            assert_exact(&Alibi::new(n, m, slope), 1e-4);
        }
    }

    #[test]
    fn alibi_head_slopes_geometric() {
        let s = Alibi::head_slopes(8);
        assert_eq!(s.len(), 8);
        assert!((s[7] - 2f32.powi(-8)).abs() < 1e-9);
        for w in s.windows(2) {
            assert!((w[1] / w[0] - s[0]).abs() < 1e-6);
        }
    }

    #[test]
    fn spatial_factorization_exact_unweighted() {
        let mut rng = Xoshiro256::new(0);
        let xq = Tensor::randn(&[20, 3], 1.0, &mut rng);
        let xk = Tensor::randn(&[15, 3], 1.0, &mut rng);
        let b = SpatialDistance::new(xq, xk, None);
        assert_eq!(b.rank(), 9);
        assert_exact(&b, 1e-4);
    }

    #[test]
    fn spatial_factorization_exact_weighted() {
        let mut rng = Xoshiro256::new(1);
        let xq = Tensor::randn(&[12, 3], 1.0, &mut rng);
        let alpha: Vec<f32> =
            (0..12).map(|_| rng.uniform(0.5, 2.0) as f32).collect();
        let b = SpatialDistance::new(xq.clone(), xq, Some(alpha));
        assert_exact(&b, 1e-4);
    }

    #[test]
    fn spatial_2d_has_rank_6() {
        let mut rng = Xoshiro256::new(2);
        let x = Tensor::randn(&[10, 2], 1.0, &mut rng);
        let b = SpatialDistance::new(x.clone(), x, None);
        assert_eq!(b.rank(), 6);
        assert_exact(&b, 1e-4);
    }

    #[test]
    fn spatial_diagonal_zero_when_self() {
        let mut rng = Xoshiro256::new(3);
        let x = Tensor::randn(&[8, 3], 1.0, &mut rng);
        let b = SpatialDistance::new(x.clone(), x, None).dense();
        for i in 0..8 {
            assert!(b.at2(i, i).abs() < 1e-6);
        }
        // distances are non-positive with our sign convention
        assert!(b.data().iter().all(|&v| v <= 1e-6));
    }

    #[test]
    fn cos_mult_factorization_exact() {
        assert_exact(&CosMultiplicative { n: 37, m: 53 }, 1e-4);
    }

    #[test]
    fn gravity_bias_diagonal_dominant() {
        let mut rng = Xoshiro256::new(4);
        let x = Tensor::randn(&[10, 2], 1.0, &mut rng);
        let g = gravity_bias(&x, &x, 0.01);
        for i in 0..10 {
            assert!((g.at2(i, i) - 100.0).abs() < 1e-3);
            for j in 0..10 {
                assert!(g.at2(i, j) <= 100.0 + 1e-3);
                assert!(g.at2(i, j) > 0.0);
            }
        }
    }

    #[test]
    fn spherical_bias_properties() {
        // antipodal points: distance π; self-distance 0; symmetric
        let x = Tensor::new(&[2, 2], vec![0.0, 0.0, 0.0, std::f32::consts::PI]);
        let s = spherical_bias(&x, &x);
        assert!((s.at2(0, 1) - std::f32::consts::PI).abs() < 1e-4);
        assert!(s.at2(0, 0).abs() < 1e-6);
        assert!((s.at2(0, 1) - s.at2(1, 0)).abs() < 1e-6);
    }

    #[test]
    fn swin_bias_is_lowrank_and_relative() {
        let biases = swin_relative_bias((8, 8), 2, 0, 6, 0.02);
        assert_eq!(biases.len(), 2);
        for b in &biases {
            assert_eq!(b.shape(), &[64, 64]);
            // diagonal entries all equal (offset 0,0)
            let d0 = b.at2(0, 0);
            for i in 0..64 {
                assert!((b.at2(i, i) - d0).abs() < 1e-6);
            }
            // spectral decay: 99% energy well below full rank
            let r = linalg::rank_for_energy(b, 0.99);
            assert!(r <= 32, "rank@99% = {r}");
        }
    }

    #[test]
    fn pangu_bias_shape_and_rank() {
        let biases = pangu_relative_bias((2, 6, 12), 2, 0, 5, 0.02);
        for b in &biases {
            assert_eq!(b.shape(), &[144, 144]);
            let r = linalg::rank_for_energy(b, 0.99);
            assert!(r <= 80, "rank@99% = {r}");
        }
    }

    #[test]
    fn car_cloud_bounds() {
        let pts = synthetic_car_cloud(500, 0);
        assert_eq!(pts.shape(), &[500, 3]);
        for i in 0..500 {
            assert!(pts.at2(i, 0).abs() < 2.5);
            assert!(pts.at2(i, 1).abs() < 1.5);
            assert!(pts.at2(i, 2) > -0.5 && pts.at2(i, 2) < 1.5);
        }
    }

    #[test]
    fn car_cloud_deterministic_by_seed() {
        let a = synthetic_car_cloud(50, 7);
        let b = synthetic_car_cloud(50, 7);
        let c = synthetic_car_cloud(50, 8);
        assert!(a.allclose(&b, 0.0, 0.0));
        assert!(!a.allclose(&c, 1e-6, 1e-6));
    }
}
