//! Tiled-execution memory-hierarchy simulator.
//!
//! Executes the exact FlashAttention-2 block schedule (grid over query
//! blocks, inner loop over key/value blocks, online-softmax accumulators
//! in SRAM) and counts every HBM read/write, matmul FLOP, element-wise
//! FLOP and the SRAM high-water mark, for each algorithm the paper
//! compares:
//!
//! * [`Algorithm::Standard`]       — materializing attention (scores to HBM).
//! * [`Algorithm::Flash`]          — FlashAttention, no bias (upper bound).
//! * [`Algorithm::FlashDenseBias`] — FlashAttention + dense N×M bias stream.
//! * [`Algorithm::FlexLike`]       — FlexAttention stand-in: bias recomputed
//!   element-wise in-kernel (no bias IO, element-wise work, recompile
//!   penalty per new shape).
//! * [`Algorithm::FlashBias`]      — factor strips streamed, bias tile
//!   reconstructed with one extra MXU matmul.
//!
//! Counts must match `crate::iomodel`'s Θ-asymptotics up to block
//! rounding — `tests/sim_vs_model.rs` enforces this. This is the
//! instrument that regenerates the *shape* of Figures 3/4 independently
//! of host-CPU quirks (DESIGN.md §Hardware-Adaptation).

use crate::iomodel::Geometry;

/// Which attention algorithm to simulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Standard,
    Flash,
    FlashDenseBias,
    FlexLike,
    /// FlashBias with factor rank R.
    FlashBias(usize),
}

impl Algorithm {
    pub fn name(&self) -> String {
        match self {
            Algorithm::Standard => "standard".into(),
            Algorithm::Flash => "flash".into(),
            Algorithm::FlashDenseBias => "flash+dense-bias".into(),
            Algorithm::FlexLike => "flex-like".into(),
            Algorithm::FlashBias(r) => format!("flashbias(R={r})"),
        }
    }

    fn bias_rank(&self) -> usize {
        match self {
            Algorithm::FlashBias(r) => *r,
            _ => 0,
        }
    }
}

/// Hardware model: SRAM capacity and relative cost weights used by
/// [`SimReport::cost`]. Defaults approximate an A100-class accelerator
/// normalized to HBM-element = 1.
#[derive(Clone, Copy, Debug)]
pub struct HwModel {
    /// SRAM capacity in elements.
    pub sram_elems: usize,
    /// Cost of one matmul FLOP relative to one HBM element access.
    /// MXU/tensor-core matmuls are effectively free next to HBM traffic.
    pub matmul_flop_cost: f64,
    /// Cost of one element-wise FLOP (VPU, not MXU) — the FlexAttention
    /// weakness: "element-wise operations are less optimized than matrix
    /// multiplications".
    pub elemwise_flop_cost: f64,
    /// One-time cost (in HBM-element units) charged per *new shape/value
    /// configuration* for compiler-based approaches (FlexAttention
    /// recompilation, §4.3).
    pub recompile_penalty: f64,
}

impl Default for HwModel {
    fn default() -> Self {
        Self {
            // 100 KB fp16 working set — the paper's Example 3.9 setting
            sram_elems: 100 * 1024 / 2,
            // MXU matmul throughput vs HBM bandwidth: ~1000 flops per
            // element access on an A100-class part.
            matmul_flop_cost: 0.001,
            // VPU element-wise ops are ~50× more expensive per flop than
            // MXU matmul flops — FlexAttention's documented weakness.
            elemwise_flop_cost: 0.05,
            recompile_penalty: 5e6,
        }
    }
}

/// What one simulated pass did.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimReport {
    /// HBM elements read.
    pub hbm_read: u64,
    /// HBM elements written.
    pub hbm_write: u64,
    /// Matmul FLOPs (MXU-eligible: 2·m·n·k per m×k·k×n product).
    pub matmul_flops: u64,
    /// Element-wise FLOPs (softmax, masks, in-kernel bias recompute).
    pub elemwise_flops: u64,
    /// SRAM high-water mark in elements.
    pub sram_peak: u64,
    /// Peak HBM allocation in elements (activations + bias (+ grads)).
    pub hbm_peak: u64,
    /// Recompilations charged (FlexLike only).
    pub recompiles: u64,
}

impl SimReport {
    pub fn hbm_total(&self) -> u64 {
        self.hbm_read + self.hbm_write
    }

    /// Scalar cost under a hardware model — the simulator's "runtime".
    pub fn cost(&self, hw: &HwModel) -> f64 {
        self.hbm_total() as f64
            + self.matmul_flops as f64 * hw.matmul_flop_cost
            + self.elemwise_flops as f64 * hw.elemwise_flop_cost
            + self.recompiles as f64 * hw.recompile_penalty
    }

    fn add(&mut self, other: &SimReport) {
        self.hbm_read += other.hbm_read;
        self.hbm_write += other.hbm_write;
        self.matmul_flops += other.matmul_flops;
        self.elemwise_flops += other.elemwise_flops;
        self.sram_peak = self.sram_peak.max(other.sram_peak);
        self.hbm_peak = self.hbm_peak.max(other.hbm_peak);
        self.recompiles += other.recompiles;
    }
}

/// FlashAttention-2 block sizes (Appendix A Eq. 10): `B_q = Θ(S/w)`,
/// `B_kv = Θ(min(S/w, w))` for strip width `w`.
///
/// `strip_w` is the per-query-token SRAM residency (q strip + output
/// accumulator + m/l scalars); `kv_w` the per-key-token stream width
/// (k (+φ_k) + v). The query strip gets half of SRAM (it is resident for
/// the whole inner loop — the lean allocation is what makes
/// FlashAttention's T = Θ(N·w/S) pass count achievable); k/v tiles are
/// small since total k/v traffic does not depend on `B_kv`.
pub fn block_sizes(sram: usize, strip_w: usize, kv_w: usize,
                   n: usize, m: usize) -> (usize, usize) {
    let bq = (sram / (2 * strip_w)).clamp(1, n.max(1));
    let bkv = (sram / (8 * kv_w)).min(kv_w).clamp(1, m.max(1));
    (bq, bkv)
}

fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Forward pass of one attention head.
pub fn simulate_fwd(alg: Algorithm, g: &Geometry, hw: &HwModel) -> SimReport {
    let mut rep = SimReport::default();
    let (n, m, c) = (g.n, g.m, g.c);
    let r = alg.bias_rank();
    match alg {
        Algorithm::Standard => {
            // s = q kᵀ: read q, k; write s
            rep.hbm_read += (n * c + m * c) as u64;
            rep.matmul_flops += 2 * (n * m * c) as u64;
            rep.hbm_write += (n * m) as u64;
            // softmax(s + b): read s (+ bias), write p
            rep.hbm_read += (n * m) as u64;
            if g.r > 0 {
                rep.hbm_read += (n * m) as u64; // dense bias
            }
            rep.elemwise_flops += 4 * (n * m) as u64;
            rep.hbm_write += (n * m) as u64;
            // o = p v: read p, v; write o
            rep.hbm_read += (n * m + m * c) as u64;
            rep.matmul_flops += 2 * (n * m * c) as u64;
            rep.hbm_write += (n * c) as u64;
            rep.sram_peak = (2 * c) as u64; // row-streamed
            rep.hbm_peak = (n * c + 2 * m * c + 2 * n * m
                + if g.r > 0 { n * m } else { 0 }
                + n * c) as u64;
        }
        Algorithm::Flash
        | Algorithm::FlashDenseBias
        | Algorithm::FlexLike
        | Algorithm::FlashBias(_) => {
            let dense_bias = alg == Algorithm::FlashDenseBias;
            let flexlike = alg == Algorithm::FlexLike;
            let w = c + r; // channel width streamed per query token
            // strip: q (+φ_q) + o accumulator + (m, l) scalars
            let strip_w = w + c + 2;
            // kv stream: k (+φ_k) + v per key token
            let kv_w = w + c;
            let (bq, bkv) = block_sizes(hw.sram_elems, strip_w, kv_w, n, m);
            let t_q = ceil_div(n, bq);
            let t_kv = ceil_div(m, bkv);
            // simulate the actual grid
            for qi in 0..t_q {
                let bq_cur = if qi == t_q - 1 { n - qi * bq } else { bq };
                // load query strip (+ φ_q strip) and init accumulators
                rep.hbm_read += (bq_cur * w) as u64;
                let mut sram = bq_cur * strip_w;
                for ki in 0..t_kv {
                    let bk_cur =
                        if ki == t_kv - 1 { m - ki * bkv } else { bkv };
                    // stream k/v (+ φ_k) tiles
                    rep.hbm_read += (bk_cur * kv_w) as u64;
                    let tile = bk_cur * kv_w + bq_cur * bk_cur;
                    sram = sram.max(bq_cur * strip_w + tile);
                    // s = q kᵀ tile
                    rep.matmul_flops += 2 * (bq_cur * bk_cur * c) as u64;
                    if dense_bias {
                        // the quadratic stream the paper eliminates
                        rep.hbm_read += (bq_cur * bk_cur) as u64;
                        rep.elemwise_flops += (bq_cur * bk_cur) as u64;
                    }
                    if flexlike {
                        // score_mod: element-wise bias recompute per tile
                        // (index arithmetic + gather + arithmetic chain —
                        // all VPU work, never a matmul)
                        rep.elemwise_flops += 10 * (bq_cur * bk_cur) as u64;
                    }
                    if r > 0 && !dense_bias && !flexlike {
                        // FlashBias: tile reconstruction on the MXU
                        rep.matmul_flops +=
                            2 * (bq_cur * bk_cur * r) as u64;
                        rep.elemwise_flops += (bq_cur * bk_cur) as u64;
                    }
                    // online softmax update + p·v
                    rep.elemwise_flops += 5 * (bq_cur * bk_cur) as u64;
                    rep.matmul_flops += 2 * (bq_cur * bk_cur * c) as u64;
                }
                // write output strip
                rep.hbm_write += (bq_cur * c) as u64;
                rep.sram_peak = rep.sram_peak.max(sram as u64);
            }
            let bias_resident = if dense_bias {
                n * m
            } else if flexlike {
                0
            } else {
                (n + m) * r
            };
            rep.hbm_peak =
                (n * c + 2 * m * c + bias_resident + n * c) as u64
                + (n + m) as u64 * r as u64; // factor strips if any
            if flexlike {
                rep.recompiles = 1;
            }
        }
    }
    rep
}

/// Backward pass (training). Follows FlashAttention-2's recompute
/// strategy: one extra forward-shaped pass for dq and one for dk/dv, plus
/// the *bias gradient traffic* — the §4.4 pain point: dense learnable
/// biases write and re-read an N×M gradient; factored biases only touch
/// (N+M)·R.
pub fn simulate_bwd(alg: Algorithm, g: &Geometry, hw: &HwModel) -> SimReport {
    let mut rep = SimReport::default();
    // dq pass + dkv pass ≈ 2 forward-shaped sweeps
    let fwd = simulate_fwd(alg, g, hw);
    rep.add(&fwd);
    rep.add(&fwd);
    rep.recompiles = fwd.recompiles; // recompile once, not thrice
    let (n, m) = (g.n, g.m);
    match alg {
        Algorithm::FlashDenseBias | Algorithm::Standard => {
            // learnable dense bias: db = dS must be materialized
            rep.hbm_write += (n * m) as u64;
            rep.hbm_read += (n * m) as u64; // optimizer read
            rep.hbm_peak += (n * m) as u64;
        }
        Algorithm::FlashBias(r) => {
            let strip = ((n + m) * r) as u64;
            rep.hbm_write += strip;
            rep.hbm_read += strip;
            rep.hbm_peak += strip;
        }
        Algorithm::FlexLike => {
            // FlexAttention "fails in speeding up dynamic bias": grads of a
            // data-dependent bias must materialize dS too
            rep.hbm_write += (n * m) as u64;
            rep.hbm_read += (n * m) as u64;
            rep.hbm_peak += (n * m) as u64;
        }
        Algorithm::Flash => {}
    }
    rep
}

/// One training step = forward + backward.
pub fn simulate_train_step(alg: Algorithm, g: &Geometry,
                           hw: &HwModel) -> SimReport {
    let mut rep = simulate_fwd(alg, g, hw);
    let bwd = simulate_bwd(alg, g, hw);
    rep.add(&bwd);
    rep.recompiles = bwd.recompiles;
    rep
}

/// Multi-head, multi-layer sweep helper: per-head geometry scaled out.
pub fn simulate_model_fwd(alg: Algorithm, g: &Geometry, heads: usize,
                          layers: usize, hw: &HwModel) -> SimReport {
    let one = simulate_fwd(alg, g, hw);
    let mut rep = SimReport::default();
    for _ in 0..heads * layers {
        rep.add(&one);
    }
    // Flex-like recompiles once per distinct shape, not per head/layer —
    // unless bias values differ per layer (Swin case, handled by caller).
    rep.recompiles = one.recompiles;
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iomodel;

    fn hw() -> HwModel {
        HwModel::default()
    }

    fn geo(n: usize, r: usize) -> Geometry {
        Geometry {
            n,
            m: n,
            c: 64,
            r,
            sram: hw().sram_elems,
        }
    }

    #[test]
    fn sram_never_exceeded() {
        for n in [256usize, 1024, 4096, 16384] {
            for alg in [
                Algorithm::Flash,
                Algorithm::FlashDenseBias,
                Algorithm::FlexLike,
                Algorithm::FlashBias(64),
            ] {
                let rep = simulate_fwd(alg, &geo(n, 64), &hw());
                assert!(
                    rep.sram_peak <= hw().sram_elems as u64,
                    "{} n={n}: sram {} > {}",
                    alg.name(),
                    rep.sram_peak,
                    hw().sram_elems
                );
            }
        }
    }

    #[test]
    fn flash_beats_standard_io() {
        let rep_std = simulate_fwd(Algorithm::Standard, &geo(4096, 0), &hw());
        let rep_fla = simulate_fwd(Algorithm::Flash, &geo(4096, 0), &hw());
        assert!(rep_fla.hbm_total() < rep_std.hbm_total());
    }

    #[test]
    fn flashbias_eliminates_quadratic_bias_stream() {
        let n = 8192;
        let r = 16; // typical FlashBias rank (paper uses R = 8..16 here)
        let dense =
            simulate_fwd(Algorithm::FlashDenseBias, &geo(n, r), &hw());
        let fact = simulate_fwd(Algorithm::FlashBias(r), &geo(n, r), &hw());
        let pure = simulate_fwd(Algorithm::Flash, &geo(n, 0), &hw());
        // dense pays ≥ N² extra reads over pure
        assert!(dense.hbm_read >= pure.hbm_read + (n * n) as u64);
        // FlashBias pays only the strips
        assert!(fact.hbm_read < dense.hbm_read);
        assert!(fact.hbm_total() < pure.hbm_total() * 2);
    }

    #[test]
    fn flashbias_advantage_shrinks_as_rank_grows() {
        // Remark 3.8 trade-off: at R ≈ C the widened q/k streams eat the
        // bias-stream saving (the block-level constant-factor reality the
        // Θ analysis hides); at small R the win is large.
        let n = 8192;
        let ratio = |r: usize| {
            let dense =
                simulate_fwd(Algorithm::FlashDenseBias, &geo(n, r), &hw());
            let fact =
                simulate_fwd(Algorithm::FlashBias(r), &geo(n, r), &hw());
            dense.hbm_total() as f64 / fact.hbm_total() as f64
        };
        let r8 = ratio(8);
        let r64 = ratio(64);
        assert!(r8 > r64, "r8 {r8} !> r64 {r64}");
        assert!(r8 > 1.5, "small-rank win too small: {r8}");
    }

    #[test]
    fn flashbias_io_matches_corollary_3_7_asymptotics() {
        // simulated HBM ≈ Θ(NM(C²+R²)/S): ratio to the model stays
        // bounded across a 16× N sweep
        let mut ratios = Vec::new();
        for n in [1024usize, 4096, 16384] {
            let g = geo(n, 64);
            let sim =
                simulate_fwd(Algorithm::FlashBias(64), &g, &hw()).hbm_total();
            let model = iomodel::flashbias_io(&g);
            ratios.push(sim as f64 / model);
        }
        let (lo, hi) = ratios
            .iter()
            .fold((f64::MAX, f64::MIN), |(l, h), &r| (l.min(r), h.max(r)));
        assert!(hi / lo < 1.6, "ratios {ratios:?} not Θ-stable");
    }

    #[test]
    fn dense_bias_io_matches_model_asymptotics() {
        let mut ratios = Vec::new();
        for n in [1024usize, 4096, 16384] {
            let g = geo(n, 64);
            let sim = simulate_fwd(Algorithm::FlashDenseBias, &g, &hw())
                .hbm_total();
            let model = iomodel::flash_dense_bias_io(&g);
            ratios.push(sim as f64 / model);
        }
        let (lo, hi) = ratios
            .iter()
            .fold((f64::MAX, f64::MIN), |(l, h), &r| (l.min(r), h.max(r)));
        assert!(hi / lo < 1.6, "ratios {ratios:?} not Θ-stable");
    }

    #[test]
    fn flexlike_pays_elementwise_not_io() {
        let n = 4096;
        let flex = simulate_fwd(Algorithm::FlexLike, &geo(n, 64), &hw());
        let dense =
            simulate_fwd(Algorithm::FlashDenseBias, &geo(n, 64), &hw());
        assert!(flex.hbm_read < dense.hbm_read);
        assert!(flex.elemwise_flops > dense.elemwise_flops);
        assert_eq!(flex.recompiles, 1);
        assert_eq!(dense.recompiles, 0);
    }

    #[test]
    fn figure3_ordering_under_cost_model() {
        // Figure 3(c-d) long-sequence ordering:
        //   pure flash < flashbias < flexlike < flash+dense-bias
        let n = 16384;
        let r = 16;
        let hwm = hw();
        let pure = simulate_fwd(Algorithm::Flash, &geo(n, 0), &hwm).cost(&hwm);
        let fb =
            simulate_fwd(Algorithm::FlashBias(r), &geo(n, r), &hwm)
                .cost(&hwm);
        let flex =
            simulate_fwd(Algorithm::FlexLike, &geo(n, r), &hwm).cost(&hwm);
        let dense = simulate_fwd(Algorithm::FlashDenseBias, &geo(n, r), &hwm)
            .cost(&hwm);
        assert!(pure < fb, "pure {pure} !< fb {fb}");
        assert!(fb < flex, "fb {fb} !< flex {flex}");
        assert!(flex < dense, "flex {flex} !< dense {dense}");
    }

    #[test]
    fn training_memory_gap_matches_table5_shape() {
        // Table 5: dense learnable-bias training OOMs (quadratic grads);
        // FlashBias stays near-linear
        let n = 16384;
        let dense =
            simulate_train_step(Algorithm::FlashDenseBias, &geo(n, 9), &hw());
        let fact =
            simulate_train_step(Algorithm::FlashBias(9), &geo(n, 9), &hw());
        assert!(dense.hbm_peak as f64 / fact.hbm_peak as f64 > 20.0);
    }

    #[test]
    fn bwd_is_roughly_two_fwd() {
        let g = geo(2048, 16);
        let fwd = simulate_fwd(Algorithm::FlashBias(16), &g, &hw());
        let bwd = simulate_bwd(Algorithm::FlashBias(16), &g, &hw());
        let ratio = bwd.hbm_total() as f64 / fwd.hbm_total() as f64;
        assert!((1.8..=2.6).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn rectangular_cross_attention() {
        let g = Geometry {
            n: 512,
            m: 4096,
            c: 64,
            r: 16,
            sram: hw().sram_elems,
        };
        let rep = simulate_fwd(Algorithm::FlashBias(16), &g, &hw());
        assert!(rep.hbm_total() > 0);
        assert!(rep.sram_peak <= hw().sram_elems as u64);
    }

    #[test]
    fn model_sweep_scales_linearly() {
        let g = geo(1024, 16);
        let one = simulate_fwd(Algorithm::FlashBias(16), &g, &hw());
        let many =
            simulate_model_fwd(Algorithm::FlashBias(16), &g, 8, 4, &hw());
        assert_eq!(many.hbm_total(), one.hbm_total() * 32);
        assert_eq!(many.sram_peak, one.sram_peak);
    }

    #[test]
    fn block_sizes_respect_sram() {
        for (sram, sw, kw) in [
            (1024usize, 130usize, 128usize),
            (51200, 146, 144),
            (51200, 194, 192),
            (51200, 700, 680),
        ] {
            let (bq, bkv) = block_sizes(sram, sw, kw, 10_000, 10_000);
            assert!(bq >= 1 && bkv >= 1);
            // resident strip + kv tile + score tile must fit
            assert!(
                bq * sw + bkv * kw + bq * bkv <= sram
                    || bq == 1
                    || bkv == 1,
                "sram={sram} sw={sw}: {} used",
                bq * sw + bkv * kw + bq * bkv
            );
        }
    }
}
