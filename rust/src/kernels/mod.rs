//! The block-tiled, multi-threaded attention kernel engine — the one
//! compute spine shared by the host executor, the simulator's numeric
//! mirror, the `attention` reference wrappers, and the coordinator's
//! batched serving path.
//!
//! Design (FlashAttention-2 schedule + the FlashBias bias treatment):
//!
//! * **Streaming softmax.** The N×M score matrix is never materialized.
//!   Each query block holds `(m, l, o)` accumulators and streams key/value
//!   tiles, exactly the Milakov–Gimelshein recurrence the L1 Pallas
//!   kernels implement.
//! * **[`BiasTile`] providers.** The bias enters per tile: a dense view
//!   ([`DenseTile`]), factor strips contracted tile-locally
//!   ([`FactoredTile`] — the Eq. (3) concat trick evaluated as the extra
//!   rank-R tile matmul of Corollary 3.7), or a JIT closed form computed
//!   from tile coordinates with zero bias IO ([`AlibiTile`], Table 8).
//!   No provider ever materializes the N×M bias.
//! * **Causal tile classification** (the tile-skipping idea of Sharma &
//!   Geiping 2024): tiles entirely in the masked future are skipped (and
//!   every later tile with them), tiles entirely in the past take the
//!   unmasked fast path, and only the diagonal band pays the per-element
//!   mask.
//! * **Data parallelism.** Work is split into (program × query-block)
//!   jobs executed on a scoped thread pool ([`KernelConfig::threads`],
//!   `FLASHBIAS_THREADS` to override). Each job owns a disjoint slice of
//!   the output, so results are bit-identical for any thread count.
//! * **Masked-row guard.** A query row that never sees a live key (fully
//!   masked, e.g. decoder alignment with N > M) yields an exactly-zero
//!   output row, not a uniform average over masked keys.
//!
//! * **Microkernels.** Every hot inner loop bottoms out in
//!   [`microkernel`]: register-tiled fused-multiply-add dot blocks with
//!   bounds checks hoisted, compiled either as an
//!   autovectorization-friendly scalar fallback (default, stable) or as
//!   an explicit `std::simd` path (`--features simd`, nightly) —
//!   bit-identical by construction.
//!
//! Block sizes default to [`KernelConfig::for_geometry`], which derives
//! them from [`crate::simulator::block_sizes`] — so the simulator's HBM
//! accounting and the engine's numerics agree on what is loaded per
//! tile. Quantized factor strips get [`KernelConfig::for_geometry_dtype`],
//! which fits tiles at the strips' stored width.

use crate::attention::NEG_INF;
use crate::iomodel::Geometry;
use crate::simulator;
use crate::tensor::{Strip, StripDType, Tensor, View2};

pub mod microkernel;

/// Scores at or below this threshold count as masked when deciding
/// whether a row saw any live key (½·|NEG_INF| head-room keeps genuine
/// large-negative biases distinguishable from the mask sentinel).
pub const MASKED: f32 = -5e29;

// ---------------------------------------------------------------------------
// Bias providers
// ---------------------------------------------------------------------------

/// Per-tile bias provider: accumulates a bias tile into a score tile.
///
/// Implementations must be cheap to call per tile and must never
/// materialize the full N×M matrix (the dense provider *views* an
/// existing one, it does not build it).
pub trait BiasTile: Sync {
    /// Add this bias's tile `[q0, q0+bq) × [k0, k0+bk)` into `scores`
    /// (row-major `bq × bk`, stride `bk`).
    fn add_tile(&self, q0: usize, k0: usize, bq: usize, bk: usize,
                scores: &mut [f32]);

    /// Accumulate the 1×`bk` bias strip for the single query position
    /// `qi` against keys `[k0, k0 + scores.len())` into `scores` — the
    /// decode-step analogue of [`Self::add_tile`]. The default
    /// delegates to `add_tile` with `bq = 1`; providers override it to
    /// drop the row loop (dense: one row `add_assign`; factored: one
    /// O(rank·bk) contraction; ALiBi: closed form). Overrides must
    /// produce bit-identical values to the `bq = 1` tile path — the
    /// decode/prefill exactness contract depends on it.
    fn add_row(&self, qi: usize, k0: usize, scores: &mut [f32]) {
        self.add_tile(qi, k0, 1, scores.len(), scores);
    }

    /// Overwrite `out` with the bias row for query position `qi`
    /// against keys `[0, out.len())` — the materialized 1×M strip, for
    /// callers that want the row itself rather than a score update.
    fn bias_row_into(&self, qi: usize, out: &mut [f32]) {
        out.fill(0.0);
        self.add_row(qi, 0, out);
    }

    /// Elements of HBM-resident bias state this provider streams
    /// (dense table or factor strips; 0 for JIT/no-bias) — the Thm 3.2
    /// storage column, used by benches for the bytes column.
    fn resident_elems(&self) -> usize {
        0
    }

    /// Bytes of HBM-resident bias state. Defaults to f32 elements;
    /// quantized factor strips override with their stored width.
    fn resident_bytes(&self) -> usize {
        self.resident_elems() * 4
    }
}

/// No bias: pure FlashAttention.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoBias;

impl BiasTile for NoBias {
    fn add_tile(&self, _q0: usize, _k0: usize, _bq: usize, _bk: usize,
                _scores: &mut [f32]) {
    }
}

/// Dense `(N, M)` bias streamed tile-by-tile from an existing table.
#[derive(Clone, Copy, Debug)]
pub struct DenseTile<'a> {
    bias: View2<'a>,
}

impl<'a> DenseTile<'a> {
    pub fn new(bias: View2<'a>) -> Self {
        Self { bias }
    }

    pub fn from_tensor(bias: &'a Tensor) -> Self {
        Self { bias: bias.view2() }
    }
}

impl BiasTile for DenseTile<'_> {
    fn add_tile(&self, q0: usize, k0: usize, bq: usize, bk: usize,
                scores: &mut [f32]) {
        for ii in 0..bq {
            let brow = &self.bias.row(q0 + ii)[k0..k0 + bk];
            let srow = &mut scores[ii * bk..(ii + 1) * bk];
            microkernel::add_assign(brow, srow);
        }
    }

    fn add_row(&self, qi: usize, k0: usize, scores: &mut [f32]) {
        let bk = scores.len();
        microkernel::add_assign(&self.bias.row(qi)[k0..k0 + bk], scores);
    }

    fn bias_row_into(&self, qi: usize, out: &mut [f32]) {
        // the table may be wider than the current cache; copy the
        // visible prefix of the row
        out.copy_from_slice(&self.bias.row(qi)[..out.len()]);
    }

    fn resident_elems(&self) -> usize {
        self.bias.rows * self.bias.cols
    }
}

/// One factor strip as the tile contraction consumes it: a zero-copy
/// f32 view (fast path) or a reduced-precision [`Strip`] dequantized
/// tile-locally on the fly.
#[derive(Clone, Copy, Debug)]
enum StripSrc<'a> {
    F32(View2<'a>),
    Quant(&'a Strip),
}

impl<'a> StripSrc<'a> {
    fn rows(&self) -> usize {
        match self {
            StripSrc::F32(v) => v.rows,
            StripSrc::Quant(s) => s.rows(),
        }
    }

    fn cols(&self) -> usize {
        match self {
            StripSrc::F32(v) => v.cols,
            StripSrc::Quant(s) => s.cols(),
        }
    }

    fn stored_bytes(&self) -> usize {
        match self {
            StripSrc::F32(v) => v.rows * v.cols * 4,
            StripSrc::Quant(s) => s.size_bytes(),
        }
    }

    /// Decode rows `[r0, r0 + n)` into `out[..n·cols]`.
    fn decode_rows(&self, r0: usize, n: usize, out: &mut [f32]) {
        let c = self.cols();
        match self {
            StripSrc::F32(v) => {
                out[..n * c].copy_from_slice(
                    v.rows_view(r0, r0 + n).data(),
                );
            }
            StripSrc::Quant(s) => {
                for (i, row) in
                    out[..n * c].chunks_exact_mut(c).enumerate()
                {
                    s.row_into(r0 + i, row);
                }
            }
        }
    }
}

// Tile-local dequantization scratch: one (φ_q block, φ_k block) pair
// per worker thread, grown on demand and reused across tiles, so the
// quantized path stays allocation-free in steady state.
thread_local! {
    static DEQ_SCRATCH: std::cell::RefCell<(Vec<f32>, Vec<f32>)> =
        std::cell::RefCell::new((Vec::new(), Vec::new()));
}

/// Factored bias `φ_q φ_kᵀ` contracted tile-locally: the Eq. (3) concat
/// trick, realized as the extra rank-R tile matmul of Corollary 3.7.
/// Streams only the `(N + M)·R` strips — at their stored width when the
/// strips are quantized ([`StripDType`]): reduced-precision strips are
/// decoded into a thread-local f32 tile right before the contraction,
/// so the accumulator numerics stay f32.
#[derive(Clone, Copy, Debug)]
pub struct FactoredTile<'a> {
    phi_q: StripSrc<'a>,
    phi_k: StripSrc<'a>,
}

impl<'a> FactoredTile<'a> {
    pub fn new(phi_q: &'a Tensor, phi_k: &'a Tensor) -> Self {
        assert_eq!(phi_q.shape()[1], phi_k.shape()[1],
                   "factor rank mismatch");
        Self::from_views(phi_q.view2(), phi_k.view2())
    }

    pub fn from_views(phi_q: View2<'a>, phi_k: View2<'a>) -> Self {
        assert_eq!(phi_q.cols, phi_k.cols, "factor rank mismatch");
        Self {
            phi_q: StripSrc::F32(phi_q),
            phi_k: StripSrc::F32(phi_k),
        }
    }

    /// Contract stored strips directly — f32 strips take the zero-copy
    /// view path, reduced-precision strips the tile-local dequantize
    /// path.
    pub fn from_strips(phi_q: &'a Strip, phi_k: &'a Strip) -> Self {
        assert_eq!(phi_q.cols(), phi_k.cols(), "factor rank mismatch");
        let src = |s: &'a Strip| match s.as_view2() {
            Some(v) => StripSrc::F32(v),
            None => StripSrc::Quant(s),
        };
        Self {
            phi_q: src(phi_q),
            phi_k: src(phi_k),
        }
    }

    /// Contract a decomposition result's strips.
    pub fn from_factors(f: &'a crate::decompose::Factors) -> Self {
        Self::from_strips(&f.phi_q, &f.phi_k)
    }

    pub fn rank(&self) -> usize {
        self.phi_q.cols()
    }

    /// The f32 register-tiled Eq. (3) contraction both paths bottom
    /// out in.
    fn contract(phi_q: View2<'_>, phi_k: View2<'_>, q0: usize,
                k0: usize, bq: usize, bk: usize, scores: &mut [f32]) {
        for ii in 0..bq {
            let prow = phi_q.row(q0 + ii);
            let srow = &mut scores[ii * bk..(ii + 1) * bk];
            microkernel::row_accum(prow, phi_k, k0, srow);
        }
    }
}

impl BiasTile for FactoredTile<'_> {
    fn add_tile(&self, q0: usize, k0: usize, bq: usize, bk: usize,
                scores: &mut [f32]) {
        if let (StripSrc::F32(pq), StripSrc::F32(pk)) =
            (self.phi_q, self.phi_k)
        {
            Self::contract(pq, pk, q0, k0, bq, bk, scores);
            return;
        }
        let r = self.rank();
        DEQ_SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            let (qbuf, kbuf) = &mut *scratch;
            qbuf.resize((bq * r).max(qbuf.len()), 0.0);
            kbuf.resize((bk * r).max(kbuf.len()), 0.0);
            self.phi_q.decode_rows(q0, bq, qbuf);
            self.phi_k.decode_rows(k0, bk, kbuf);
            Self::contract(
                View2::new(bq, r, &qbuf[..bq * r]),
                View2::new(bk, r, &kbuf[..bk * r]),
                0,
                0,
                bq,
                bk,
                scores,
            );
        });
    }

    fn add_row(&self, qi: usize, k0: usize, scores: &mut [f32]) {
        let bk = scores.len();
        if let (StripSrc::F32(pq), StripSrc::F32(pk)) =
            (self.phi_q, self.phi_k)
        {
            // the O(rank·bk) Eq. (3) strip contraction: one φ_q row
            // against the φ_k block — no N×M row is ever materialized
            microkernel::row_accum(pq.row(qi), pk, k0, scores);
            return;
        }
        let r = self.rank();
        DEQ_SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            let (qbuf, kbuf) = &mut *scratch;
            qbuf.resize(r.max(qbuf.len()), 0.0);
            kbuf.resize((bk * r).max(kbuf.len()), 0.0);
            self.phi_q.decode_rows(qi, 1, qbuf);
            self.phi_k.decode_rows(k0, bk, kbuf);
            microkernel::row_accum(
                &qbuf[..r],
                View2::new(bk, r, &kbuf[..bk * r]),
                0,
                scores,
            );
        });
    }

    fn resident_elems(&self) -> usize {
        (self.phi_q.rows() + self.phi_k.rows()) * self.phi_q.cols()
    }

    fn resident_bytes(&self) -> usize {
        self.phi_q.stored_bytes() + self.phi_k.stored_bytes()
    }
}

/// ALiBi generated in-kernel from tile coordinates — zero bias IO
/// (Table 8): `b[i, j] = slope · (j − i)`.
#[derive(Clone, Copy, Debug)]
pub struct AlibiTile {
    pub slope: f32,
}

impl BiasTile for AlibiTile {
    fn add_tile(&self, q0: usize, k0: usize, bq: usize, bk: usize,
                scores: &mut [f32]) {
        // hoist the per-row invariants: the row's bias at jj = 0 is
        // fixed, and each step right adds exactly `slope` — the k-inner
        // loop does one fused multiply-add per element instead of
        // recomputing slope · (base + jj) from scratch
        let slope = self.slope;
        for ii in 0..bq {
            let row_bias = slope * (k0 as f32 - (q0 + ii) as f32);
            let srow = &mut scores[ii * bk..(ii + 1) * bk];
            for (jj, s) in srow.iter_mut().enumerate() {
                *s += slope.mul_add(jj as f32, row_bias);
            }
        }
    }

    fn add_row(&self, qi: usize, k0: usize, scores: &mut [f32]) {
        // same hoisted-fma form as the tile path, bq = 1: bit-identical
        // values, zero bias IO per step
        let slope = self.slope;
        let row_bias = slope * (k0 as f32 - qi as f32);
        for (jj, s) in scores.iter_mut().enumerate() {
            *s += slope.mul_add(jj as f32, row_bias);
        }
    }
}

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Tile and parallelism knobs for the engine.
#[derive(Clone, Copy, Debug)]
pub struct KernelConfig {
    /// Query rows per block (one job per block).
    pub block_q: usize,
    /// Key/value rows streamed per tile.
    pub block_k: usize,
    /// Worker threads (results are identical for any value).
    pub threads: usize,
}

impl Default for KernelConfig {
    fn default() -> Self {
        Self {
            block_q: 64,
            block_k: 128,
            threads: default_threads(),
        }
    }
}

/// `FLASHBIAS_THREADS` override, else the machine's parallelism.
pub fn default_threads() -> usize {
    std::env::var("FLASHBIAS_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t > 0)
        .or_else(|| {
            std::thread::available_parallelism().ok().map(|n| n.get())
        })
        .unwrap_or(1)
}

impl KernelConfig {
    /// Block sizes from the simulator's SRAM model (Appendix A Eq. 10),
    /// so `simulate_fwd`'s HBM accounting and the engine's schedule
    /// agree on what is loaded per tile. Assumes f32 factor strips; use
    /// [`Self::for_geometry_dtype`] when the strips are quantized.
    pub fn for_geometry(g: &Geometry) -> Self {
        Self::for_geometry_dtype(g, StripDType::F32)
    }

    /// Block sizes with the factor strips' *stored* element width
    /// plumbed into the SRAM fit. q/k/v/o and the softmax accumulators
    /// stay f32, but the rank-R φ columns stream at
    /// `strip.size_bytes()` per element — bf16 strips let bigger tiles
    /// fit the same SRAM (the old fit assumed 4 bytes for everything).
    pub fn for_geometry_dtype(g: &Geometry, strip: StripDType) -> Self {
        // strip contribution in f32-equivalent elements (ceil), since
        // the SRAM model counts 4-byte elements
        let r_eq = (g.r * strip.size_bytes() + 3) / 4;
        let w = g.c + r_eq; // channel width streamed per query token
        let strip_w = w + g.c + 2; // q (+φ_q) + o accumulator + (m, l)
        let kv_w = w + g.c; // k (+φ_k) + v per key token
        let (bq, bk) =
            simulator::block_sizes(g.sram, strip_w, kv_w, g.n, g.m);
        Self {
            block_q: bq,
            block_k: bk,
            ..Self::default()
        }
    }

    pub fn with_blocks(mut self, block_q: usize, block_k: usize) -> Self {
        self.block_q = block_q.max(1);
        self.block_k = block_k.max(1);
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }
}

// ---------------------------------------------------------------------------
// Core schedule
// ---------------------------------------------------------------------------

/// One independent attention problem: `q: (N, C)`, `k: (M, C)`,
/// `v: (M, Cv)` plus its bias provider. Heads and batch entries become
/// separate programs sharing one job pool.
#[derive(Clone, Copy)]
struct Program<'a> {
    q: View2<'a>,
    k: View2<'a>,
    v: View2<'a>,
    bias: &'a dyn BiasTile,
    causal: bool,
    scale: f32,
}

/// A (program, query-block) work item owning its output rows.
struct Job<'a> {
    prog: Program<'a>,
    /// First query row of this block.
    i0: usize,
    /// Output rows `[i0, i0 + bq) × Cv`.
    out: &'a mut [f32],
}

/// Split programs into query-block jobs and run them on a scoped
/// thread pool. Each job owns a disjoint output slice, so the result is
/// independent of the thread count.
fn execute_programs<'a>(programs: Vec<(Program<'a>, &'a mut [f32])>,
                        cfg: &KernelConfig) {
    let bq = cfg.block_q.max(1);
    let mut jobs: Vec<Job<'a>> = Vec::new();
    for (prog, out) in programs {
        if out.is_empty() {
            continue;
        }
        let chunk = (bq * prog.v.cols).max(1);
        for (bi, block) in out.chunks_mut(chunk).enumerate() {
            jobs.push(Job {
                prog,
                i0: bi * bq,
                out: block,
            });
        }
    }
    let threads = cfg.threads.max(1).min(jobs.len().max(1));
    if threads <= 1 {
        for job in jobs {
            run_query_block(job, cfg);
        }
        return;
    }
    let mut queues: Vec<Vec<Job<'a>>> =
        (0..threads).map(|_| Vec::new()).collect();
    for (i, job) in jobs.into_iter().enumerate() {
        queues[i % threads].push(job);
    }
    std::thread::scope(|s| {
        for queue in queues {
            s.spawn(move || {
                for job in queue {
                    run_query_block(job, cfg);
                }
            });
        }
    });
}

// Per-thread (m_acc, l_acc, score_buf) buffers, reused across query
// blocks so the steady-state tile loop never touches the allocator:
// one worker runs many blocks per flush, and a fresh vec! per block
// multiplies by batch × blocks. Every buffer is resized and refilled
// at block entry, so reuse cannot change a single output bit.
thread_local! {
    static QBLOCK_SCRATCH: std::cell::RefCell<(Vec<f32>, Vec<f32>, Vec<f32>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new(), Vec::new())) };
}

/// The streaming-softmax inner loop for one query block.
fn run_query_block(job: Job<'_>, cfg: &KernelConfig) {
    QBLOCK_SCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        let (m_acc, l_acc, score_buf) = &mut *scratch;
        run_query_block_in(job, cfg, m_acc, l_acc, score_buf);
    });
}

fn run_query_block_in(job: Job<'_>, cfg: &KernelConfig,
                      m_acc: &mut Vec<f32>, l_acc: &mut Vec<f32>,
                      score_buf: &mut Vec<f32>) {
    let Job { prog, i0, out } = job;
    let (n, m) = (prog.q.rows, prog.k.rows);
    let cv = prog.v.cols;
    let bq = out.len() / cv.max(1);
    let block_k = cfg.block_k.max(1);
    // decoder alignment: key j is visible to query i iff j − (m − n) ≤ i
    let off = m as isize - n as isize;
    m_acc.clear();
    m_acc.resize(bq, NEG_INF);
    l_acc.clear();
    l_acc.resize(bq, 0.0f32);
    out.fill(0.0);
    score_buf.clear();
    score_buf.resize(bq * block_k, 0.0f32);
    let mut j0 = 0usize;
    while j0 < m {
        let bk = block_k.min(m - j0);
        if prog.causal && j0 as isize > (i0 + bq - 1) as isize + off {
            // tile (and every later tile) entirely in the masked future
            break;
        }
        // only the diagonal band pays the per-element mask
        let diag = prog.causal
            && (j0 + bk - 1) as isize > i0 as isize + off;
        let scores = &mut score_buf[..bq * bk];
        // s = q kᵀ · scale for this tile — register-tiled microkernel
        // (one q row × NR key rows per block, LANES-wide fma inside)
        for ii in 0..bq {
            let qrow = prog.q.row(i0 + ii);
            let srow = &mut scores[ii * bk..(ii + 1) * bk];
            microkernel::row_scores(qrow, prog.k, j0, prog.scale, srow);
        }
        prog.bias.add_tile(i0, j0, bq, bk, scores);
        if diag {
            // per-row mask boundary hoisted out of the inner loop: keys
            // (j0 + jj) > limit are masked, i.e. the row suffix from
            // `first` on — one clamp, then a branch-free fill
            for ii in 0..bq {
                let limit = i0 as isize + ii as isize + off;
                let first = (limit - j0 as isize + 1)
                    .clamp(0, bk as isize) as usize;
                let srow = &mut scores[ii * bk..(ii + 1) * bk];
                for s in &mut srow[first..] {
                    *s = NEG_INF;
                }
            }
        }
        // online-softmax accumulator update
        for ii in 0..bq {
            let srow = &scores[ii * bk..(ii + 1) * bk];
            let blk_max = microkernel::row_max(srow);
            if blk_max <= MASKED {
                // every key in this tile is masked for this row
                continue;
            }
            let m_new = m_acc[ii].max(blk_max);
            let alpha = (m_acc[ii] - m_new).exp();
            let orow = &mut out[ii * cv..(ii + 1) * cv];
            if alpha != 1.0 {
                l_acc[ii] *= alpha;
                microkernel::scale_in_place(alpha, orow);
            }
            let mut l = l_acc[ii];
            for (jj, &sv) in srow.iter().enumerate() {
                let p = (sv - m_new).exp();
                if p == 0.0 {
                    continue;
                }
                l += p;
                microkernel::axpy(p, prog.v.row(j0 + jj), orow);
            }
            m_acc[ii] = m_new;
            l_acc[ii] = l;
        }
        j0 += bk;
    }
    // normalize; fully-masked rows stay exactly zero
    for ii in 0..bq {
        if l_acc[ii] > 0.0 {
            let inv = 1.0 / l_acc[ii];
            let orow = &mut out[ii * cv..(ii + 1) * cv];
            microkernel::scale_in_place(inv, orow);
        }
    }
}

// ---------------------------------------------------------------------------
// Decode path: single-query attention against a cached K/V slab
// ---------------------------------------------------------------------------

/// Streaming-softmax state a decode step finishes with: the running
/// max and denominator of the online recurrence over all visible keys.
/// The step itself is *exact* — `(m, l)` ran to completion over the 1×M
/// strip before the output was normalized — so the carry is a session
/// diagnostic (and the fully-masked signal: `l == 0.0`), not an
/// approximation to be corrected later.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecodeCarry {
    /// Running max over all visible (bias-added, scaled) scores.
    pub m: f32,
    /// Softmax denominator; `0.0` iff every key was masked.
    pub l: f32,
}

impl DecodeCarry {
    /// Carry before any key has been seen.
    pub fn fresh() -> Self {
        Self {
            m: NEG_INF,
            l: 0.0,
        }
    }
}

impl Default for DecodeCarry {
    fn default() -> Self {
        Self::fresh()
    }
}

// Per-thread 1×block_k score strip, reused across decode steps so the
// per-step hot path is allocation-free in steady state.
thread_local! {
    static DECODE_SCRATCH: std::cell::RefCell<Vec<f32>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// One decode step: attend query row `q` (length C) at absolute
/// position `i` of a logical `n`-query problem against cached keys
/// `k: (M, C)` / values `v: (M, Cv)`, writing the normalized output
/// row into `out` (length Cv).
///
/// This is `run_query_block` specialized to `bq = 1`: identical key
/// tiling (`cfg.block_k`), identical microkernel calls
/// (`row_scores` → [`BiasTile::add_row`] → mask → online update), and
/// the same decoder alignment `off = M − n`, so at equal `block_k` a
/// decode step is *bit-identical* to row `i` of the one-shot prefill —
/// the one-shot path is simply "prefill with N > 1 and no session".
/// For a live session the caller passes `n = i + 1` (the new position
/// sees the whole cache, ragged cross-attention prefixes included).
#[allow(clippy::too_many_arguments)]
pub fn run_decode_step(q: &[f32], k: View2<'_>, v: View2<'_>,
                       bias: &dyn BiasTile, i: usize, n: usize,
                       causal: bool, scale: f32, cfg: &KernelConfig,
                       out: &mut [f32]) -> DecodeCarry {
    let m = k.rows;
    let block_k = cfg.block_k.max(1);
    // decoder alignment: key j is visible iff j ≤ i + (m − n)
    let off = m as isize - n as isize;
    let limit = i as isize + off;
    let mut carry = DecodeCarry::fresh();
    out.fill(0.0);
    DECODE_SCRATCH.with(|cell| {
        let mut buf = cell.borrow_mut();
        if buf.len() < block_k {
            buf.resize(block_k, 0.0);
        }
        let mut j0 = 0usize;
        while j0 < m {
            let bk = block_k.min(m - j0);
            if causal && j0 as isize > limit {
                // this tile (and every later one) is masked future
                break;
            }
            let diag = causal && (j0 + bk - 1) as isize > limit;
            let scores = &mut buf[..bk];
            microkernel::row_scores(q, k, j0, scale, scores);
            bias.add_row(i, j0, scores);
            if diag {
                let first = (limit - j0 as isize + 1)
                    .clamp(0, bk as isize) as usize;
                for s in &mut scores[first..] {
                    *s = NEG_INF;
                }
            }
            let blk_max = microkernel::row_max(scores);
            if blk_max > MASKED {
                let m_new = carry.m.max(blk_max);
                let alpha = (carry.m - m_new).exp();
                if alpha != 1.0 {
                    carry.l *= alpha;
                    microkernel::scale_in_place(alpha, out);
                }
                let mut l = carry.l;
                for (jj, &sv) in scores.iter().enumerate() {
                    let p = (sv - m_new).exp();
                    if p == 0.0 {
                        continue;
                    }
                    l += p;
                    microkernel::axpy(p, v.row(j0 + jj), out);
                }
                carry.m = m_new;
                carry.l = l;
            }
            j0 += bk;
        }
    });
    // normalize; a fully-masked step stays exactly zero
    if carry.l > 0.0 {
        microkernel::scale_in_place(1.0 / carry.l, out);
    }
    carry
}

/// One decode step in a batched flush: borrowed query row, cached K/V
/// views, the session plan's bias provider, and the step's position
/// snapshot. See [`run_decode_step`] for the semantics of `i`/`n`.
pub struct DecodeProgram<'a> {
    pub q: &'a [f32],
    pub k: View2<'a>,
    pub v: View2<'a>,
    pub bias: &'a dyn BiasTile,
    pub i: usize,
    pub n: usize,
    pub causal: bool,
    pub scale: f32,
}

/// Execute a batch of decode steps data-parallel on a scoped thread
/// pool — the continuous-batching engine call that advances many
/// sessions at once. Each step owns a disjoint output slice and carry
/// slot, so the results (and the returned carries, in input order) are
/// independent of the thread count and of how the batcher interleaved
/// the steps.
pub fn decode_steps<'a>(progs: Vec<(DecodeProgram<'a>, &'a mut [f32])>,
                        cfg: &KernelConfig) -> Vec<DecodeCarry> {
    let mut carries = vec![DecodeCarry::fresh(); progs.len()];
    let threads = cfg.threads.max(1).min(progs.len().max(1));
    if threads <= 1 {
        for ((prog, out), c) in progs.into_iter().zip(carries.iter_mut())
        {
            *c = run_decode_step(prog.q, prog.k, prog.v, prog.bias,
                                 prog.i, prog.n, prog.causal,
                                 prog.scale, cfg, out);
        }
        return carries;
    }
    let mut queues: Vec<
        Vec<((DecodeProgram<'a>, &'a mut [f32]), &mut DecodeCarry)>,
    > = (0..threads).map(|_| Vec::new()).collect();
    for (idx, item) in
        progs.into_iter().zip(carries.iter_mut()).enumerate()
    {
        queues[idx % threads].push(item);
    }
    std::thread::scope(|s| {
        for queue in queues {
            s.spawn(move || {
                for ((prog, out), c) in queue {
                    *c = run_decode_step(prog.q, prog.k, prog.v,
                                         prog.bias, prog.i, prog.n,
                                         prog.causal, prog.scale, cfg,
                                         out);
                }
            });
        }
    });
    carries
}

// ---------------------------------------------------------------------------
// Public entry points
// ---------------------------------------------------------------------------

/// Single-head tiled attention: `q: (N, C)`, `k: (M, C)`, `v: (M, Cv)`.
pub fn attention_tiled(q: &Tensor, k: &Tensor, v: &Tensor,
                       bias: &dyn BiasTile, causal: bool,
                       cfg: &KernelConfig) -> Tensor {
    let (n, c) = (q.shape()[0], q.shape()[1]);
    let m = k.shape()[0];
    assert_eq!(k.shape()[1], c, "k channels");
    assert_eq!(v.shape()[0], m, "v rows");
    let cv = v.shape()[1];
    let scale = 1.0 / (c as f32).sqrt();
    let mut out = vec![0.0f32; n * cv];
    let prog = Program {
        q: q.view2(),
        k: k.view2(),
        v: v.view2(),
        bias,
        causal,
        scale,
    };
    execute_programs(vec![(prog, out.as_mut_slice())], cfg);
    Tensor::new(&[n, cv], out)
}

/// Multi-head tiled attention: `q: (H, N, C)`, `k`/`v: (H, M, C[v])`,
/// optional per-head dense `bias: (H, N, M)`. Heads and query blocks
/// run data-parallel on one job pool.
pub fn mha_tiled(q: &Tensor, k: &Tensor, v: &Tensor,
                 bias: Option<&Tensor>, causal: bool,
                 cfg: &KernelConfig) -> Tensor {
    assert_eq!(q.rank(), 3, "q must be (H, N, C)");
    let (h, n, c) = (q.shape()[0], q.shape()[1], q.shape()[2]);
    let m = k.shape()[1];
    assert_eq!(k.shape()[0], h, "k heads");
    assert_eq!(k.shape()[2], c, "k channels");
    assert_eq!(v.shape()[0], h, "v heads");
    assert_eq!(v.shape()[1], m, "v rows");
    let cv = v.shape()[2];
    if let Some(b) = bias {
        assert_eq!(b.shape(), &[h, n, m], "bias shape");
    }
    let scale = 1.0 / (c as f32).sqrt();
    let nobias = NoBias;
    let tiles: Vec<DenseTile<'_>> = match bias {
        Some(b) => (0..h).map(|i| DenseTile::new(b.view_slab(i))).collect(),
        None => Vec::new(),
    };
    let mut out = vec![0.0f32; h * n * cv];
    let mut programs = Vec::with_capacity(h);
    for (hi, block) in out.chunks_mut((n * cv).max(1)).enumerate() {
        let provider: &dyn BiasTile = if tiles.is_empty() {
            &nobias
        } else {
            &tiles[hi]
        };
        programs.push((
            Program {
                q: q.view_slab(hi),
                k: k.view_slab(hi),
                v: v.view_slab(hi),
                bias: provider,
                causal,
                scale,
            },
            block,
        ));
    }
    execute_programs(programs, cfg);
    Tensor::new(&[h, n, cv], out)
}

/// Batched entry point: `q: (..., N, C)` with all leading dims (batch,
/// heads, …) flattened into independent programs sharing one bias
/// provider — one engine call executes a whole flushed serving batch.
pub fn attention_batched(q: &Tensor, k: &Tensor, v: &Tensor,
                         bias: &dyn BiasTile, causal: bool,
                         cfg: &KernelConfig) -> Tensor {
    let rank = q.rank();
    assert!(rank >= 2, "q must be at least rank 2");
    assert_eq!(k.rank(), rank, "k rank");
    assert_eq!(v.rank(), rank, "v rank");
    let n = q.shape()[rank - 2];
    let c = q.shape()[rank - 1];
    let m = k.shape()[rank - 2];
    assert_eq!(k.shape()[rank - 1], c, "k channels");
    assert_eq!(v.shape()[rank - 2], m, "v rows");
    let cv = v.shape()[rank - 1];
    assert_eq!(&q.shape()[..rank - 2], &k.shape()[..rank - 2],
               "leading dims");
    assert_eq!(&q.shape()[..rank - 2], &v.shape()[..rank - 2],
               "leading dims");
    let slabs: usize = q.shape()[..rank - 2].iter().product();
    let scale = 1.0 / (c as f32).sqrt();
    let mut out_shape = q.shape()[..rank - 2].to_vec();
    out_shape.push(n);
    out_shape.push(cv);
    let mut out = vec![0.0f32; slabs * n * cv];
    let mut programs = Vec::with_capacity(slabs);
    for (pi, block) in out.chunks_mut((n * cv).max(1)).enumerate() {
        programs.push((
            Program {
                q: q.view_slab(pi),
                k: k.view_slab(pi),
                v: v.view_slab(pi),
                bias,
                causal,
                scale,
            },
            block,
        ));
    }
    execute_programs(programs, cfg);
    Tensor::new(&out_shape, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{attention, AttnOpts};
    use crate::bias::{Alibi, ExactBias};
    use crate::util::Xoshiro256;

    fn qkv(n: usize, m: usize, c: usize,
           seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = Xoshiro256::new(seed);
        (
            Tensor::randn(&[n, c], 1.0, &mut rng),
            Tensor::randn(&[m, c], 1.0, &mut rng),
            Tensor::randn(&[m, c], 1.0, &mut rng),
        )
    }

    fn cfg(bq: usize, bk: usize) -> KernelConfig {
        KernelConfig::default().with_blocks(bq, bk).with_threads(2)
    }

    #[test]
    fn no_bias_matches_reference() {
        let (q, k, v) = qkv(17, 23, 8, 0);
        let reference = attention(&q, &k, &v, None, &AttnOpts::default());
        for (bq, bk) in [(1, 1), (5, 7), (17, 23), (64, 64)] {
            let tiled = attention_tiled(&q, &k, &v, &NoBias, false,
                                        &cfg(bq, bk));
            assert!(tiled.allclose(&reference, 1e-5, 1e-5),
                    "bq={bq} bk={bk}");
        }
    }

    #[test]
    fn dense_tile_matches_reference_causal() {
        let (q, k, v) = qkv(13, 19, 4, 1);
        let mut rng = Xoshiro256::new(2);
        let bias = Tensor::randn(&[13, 19], 1.0, &mut rng);
        let reference =
            attention(&q, &k, &v, Some(&bias), &AttnOpts { causal: true });
        let tiled = attention_tiled(&q, &k, &v,
                                    &DenseTile::from_tensor(&bias), true,
                                    &cfg(4, 6));
        assert!(tiled.allclose(&reference, 1e-5, 1e-5));
    }

    #[test]
    fn factored_tile_equals_dense_tile() {
        let (q, k, v) = qkv(11, 14, 8, 3);
        let mut rng = Xoshiro256::new(4);
        let pq = Tensor::randn(&[11, 3], 0.4, &mut rng);
        let pk = Tensor::randn(&[14, 3], 0.4, &mut rng);
        let dense = pq.matmul_t(&pk);
        let a = attention_tiled(&q, &k, &v,
                                &DenseTile::from_tensor(&dense), false,
                                &cfg(3, 5));
        let b = attention_tiled(&q, &k, &v, &FactoredTile::new(&pq, &pk),
                                false, &cfg(3, 5));
        assert!(a.allclose(&b, 1e-5, 1e-5));
    }

    #[test]
    fn alibi_tile_matches_dense_alibi() {
        let (q, k, v) = qkv(16, 16, 8, 5);
        let alibi = Alibi::new(16, 16, 0.25);
        let reference = attention(&q, &k, &v, Some(&alibi.dense()),
                                  &AttnOpts { causal: true });
        let tiled = attention_tiled(&q, &k, &v,
                                    &AlibiTile { slope: 0.25 }, true,
                                    &cfg(5, 3));
        assert!(tiled.allclose(&reference, 1e-5, 1e-5));
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let (q, k, v) = qkv(29, 31, 8, 6);
        let mut rng = Xoshiro256::new(7);
        let bias = Tensor::randn(&[29, 31], 1.0, &mut rng);
        let tile = DenseTile::from_tensor(&bias);
        let base = attention_tiled(&q, &k, &v, &tile, true,
                                   &cfg(4, 8).with_threads(1));
        for threads in [2, 3, 8] {
            let multi = attention_tiled(&q, &k, &v, &tile, true,
                                        &cfg(4, 8).with_threads(threads));
            assert!(multi.allclose(&base, 0.0, 0.0), "threads={threads}");
        }
    }

    #[test]
    fn fully_masked_rows_are_exactly_zero() {
        // N > M decoder alignment: rows 0..N−M see no key at all
        let (q, k, v) = qkv(8, 5, 4, 8);
        let out = attention_tiled(&q, &k, &v, &NoBias, true, &cfg(3, 2));
        for i in 0..3 {
            assert!(out.row(i).iter().all(|&x| x == 0.0), "row {i}");
        }
        // row N−M sees exactly key 0 → equals v[0]
        for j in 0..4 {
            assert!((out.at2(3, j) - v.at2(0, j)).abs() < 1e-5);
        }
    }

    #[test]
    fn batched_matches_per_slab() {
        let mut rng = Xoshiro256::new(9);
        let (b, h, n, m, c) = (3, 2, 10, 12, 4);
        let q = Tensor::randn(&[b, h, n, c], 1.0, &mut rng);
        let k = Tensor::randn(&[b, h, m, c], 1.0, &mut rng);
        let v = Tensor::randn(&[b, h, m, c], 1.0, &mut rng);
        let tile = AlibiTile { slope: 0.125 };
        let out = attention_batched(&q, &k, &v, &tile, true, &cfg(4, 5));
        assert_eq!(out.shape(), &[b, h, n, c]);
        let alibi = Alibi::new(n, m, 0.125).dense();
        for bi in 0..b {
            for hi in 0..h {
                let pi = bi * h + hi;
                let reference = attention(
                    &q.view_slab(pi).to_tensor(),
                    &k.view_slab(pi).to_tensor(),
                    &v.view_slab(pi).to_tensor(),
                    Some(&alibi),
                    &AttnOpts { causal: true },
                );
                assert!(out
                    .view_slab(pi)
                    .to_tensor()
                    .allclose(&reference, 1e-4, 1e-4));
            }
        }
    }

    #[test]
    fn mha_tiled_matches_per_head_reference() {
        let mut rng = Xoshiro256::new(10);
        let q = Tensor::randn(&[3, 6, 4], 1.0, &mut rng);
        let k = Tensor::randn(&[3, 8, 4], 1.0, &mut rng);
        let v = Tensor::randn(&[3, 8, 4], 1.0, &mut rng);
        let bias = Tensor::randn(&[3, 6, 8], 0.5, &mut rng);
        let out = mha_tiled(&q, &k, &v, Some(&bias), false, &cfg(2, 3));
        assert_eq!(out.shape(), &[3, 6, 4]);
        for hi in 0..3 {
            let reference = attention(
                &q.index0(hi),
                &k.index0(hi),
                &v.index0(hi),
                Some(&bias.index0(hi)),
                &AttnOpts::default(),
            );
            assert!(out.index0(hi).allclose(&reference, 1e-5, 1e-5));
        }
    }

    #[test]
    fn extreme_bias_stays_finite() {
        let (q, k, v) = qkv(5, 8, 4, 11);
        let bias = Tensor::full(&[5, 8], 200.0);
        let out = attention_tiled(&q, &k, &v,
                                  &DenseTile::from_tensor(&bias), false,
                                  &cfg(2, 3));
        assert!(out.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn for_geometry_blocks_fit_sram() {
        let g = Geometry {
            n: 4096,
            m: 4096,
            c: 64,
            r: 16,
            sram: 100 * 1024 / 2,
        };
        let cfg = KernelConfig::for_geometry(&g);
        assert!(cfg.block_q >= 1 && cfg.block_k >= 1);
        assert!(cfg.block_q <= g.n && cfg.block_k <= g.m);
    }

    #[test]
    fn alibi_tile_exact_on_non_dividing_blocks() {
        // the per-row hoist (row_bias + jj·slope as one fma) must stay
        // exact for tail tiles whose origin/extent don't divide N, M —
        // compare add_tile against the closed form at odd offsets
        let slope = 0.3;
        let tile = AlibiTile { slope };
        for (q0, k0, bq, bk) in
            [(0, 0, 3, 5), (7, 11, 4, 3), (13, 2, 1, 7), (5, 9, 6, 1)]
        {
            let mut scores = vec![0.0f32; bq * bk];
            tile.add_tile(q0, k0, bq, bk, &mut scores);
            for ii in 0..bq {
                for jj in 0..bk {
                    let want =
                        slope * ((k0 + jj) as f32 - (q0 + ii) as f32);
                    let got = scores[ii * bk + jj];
                    assert!(
                        (got - want).abs() <= 1e-5 * want.abs().max(1.0),
                        "q0={q0} k0={k0} ii={ii} jj={jj}: {got} vs {want}"
                    );
                }
            }
        }
        // and through the full schedule, with blocks that leave tails
        let (q, k, v) = qkv(19, 21, 8, 20);
        let alibi = Alibi::new(19, 21, 0.25);
        let reference = attention(&q, &k, &v, Some(&alibi.dense()),
                                  &AttnOpts::default());
        let tiled = attention_tiled(&q, &k, &v,
                                    &AlibiTile { slope: 0.25 }, false,
                                    &cfg(7, 6));
        assert!(tiled.allclose(&reference, 1e-5, 1e-5));
    }

    #[test]
    fn quantized_factored_tile_tracks_f32_within_tolerance() {
        use crate::tensor::Strip;
        let (q, k, v) = qkv(18, 22, 8, 21);
        let mut rng = Xoshiro256::new(22);
        let pq = Tensor::randn(&[18, 4], 0.4, &mut rng);
        let pk = Tensor::randn(&[22, 4], 0.4, &mut rng);
        let exact = attention_tiled(&q, &k, &v,
                                    &FactoredTile::new(&pq, &pk), false,
                                    &cfg(5, 7));
        // f32 strips take the zero-copy path: bit-identical to tensors
        let (sq, sk) = (Strip::from_f32(pq.clone()),
                        Strip::from_f32(pk.clone()));
        let via_strip = attention_tiled(
            &q, &k, &v, &FactoredTile::from_strips(&sq, &sk), false,
            &cfg(5, 7),
        );
        assert!(via_strip.allclose(&exact, 0.0, 0.0));
        // reduced precision dequantizes on the fly; the output error is
        // bounded by the representation error of the strips
        for (dtype, tol) in [(StripDType::Bf16, 2e-2),
                             (StripDType::F16, 2e-3)] {
            let (bq, bk) = (Strip::quantize(&pq, dtype),
                            Strip::quantize(&pk, dtype));
            let tile = FactoredTile::from_strips(&bq, &bk);
            let out =
                attention_tiled(&q, &k, &v, &tile, false, &cfg(5, 7));
            assert!(out.allclose(&exact, tol, tol), "{dtype}");
            // stored bytes halve; the bias-state accounting must see it
            assert_eq!(tile.resident_bytes() * 2,
                       FactoredTile::new(&pq, &pk).resident_bytes(),
                       "{dtype}");
        }
    }

    #[test]
    fn quantized_tiles_are_tile_boundary_invariant() {
        // tile-local dequantization must not depend on where tile
        // boundaries fall: each strip row decodes to the same f32s in
        // any block, so assembling the bias from small add_tile calls
        // is bit-identical to one whole-matrix call
        use crate::tensor::Strip;
        let (n, m) = (15, 17);
        let mut rng = Xoshiro256::new(24);
        let pq = Tensor::randn(&[n, 3], 0.5, &mut rng);
        let pk = Tensor::randn(&[m, 3], 0.5, &mut rng);
        let (sq, sk) = (Strip::quantize(&pq, StripDType::Bf16),
                        Strip::quantize(&pk, StripDType::Bf16));
        let tile = FactoredTile::from_strips(&sq, &sk);
        let mut whole = vec![0.0f32; n * m];
        tile.add_tile(0, 0, n, m, &mut whole);
        for (bq, bk) in [(1, 1), (4, 6), (7, 5)] {
            let mut assembled = vec![0.0f32; n * m];
            let mut q0 = 0;
            while q0 < n {
                let h = bq.min(n - q0);
                let mut k0 = 0;
                while k0 < m {
                    let w = bk.min(m - k0);
                    let mut block = vec![0.0f32; h * w];
                    tile.add_tile(q0, k0, h, w, &mut block);
                    for ii in 0..h {
                        for jj in 0..w {
                            assembled[(q0 + ii) * m + k0 + jj] =
                                block[ii * w + jj];
                        }
                    }
                    k0 += w;
                }
                q0 += h;
            }
            assert_eq!(whole, assembled, "bq={bq} bk={bk}");
        }
    }

    #[test]
    fn for_geometry_dtype_fits_more_rows_at_reduced_width() {
        let g = Geometry {
            n: 4096,
            m: 4096,
            c: 64,
            r: 64,
            sram: 100 * 1024 / 2,
        };
        let f32_cfg = KernelConfig::for_geometry_dtype(&g, StripDType::F32);
        assert_eq!(f32_cfg.block_q,
                   KernelConfig::for_geometry(&g).block_q,
                   "f32 dtype fit must equal the legacy fit");
        for dtype in [StripDType::Bf16, StripDType::F16, StripDType::I8] {
            let c = KernelConfig::for_geometry_dtype(&g, dtype);
            assert!(c.block_q >= f32_cfg.block_q,
                    "{dtype}: narrower strips can't shrink tiles");
            assert!(c.block_q <= g.n && c.block_k <= g.m);
        }
        // at rank 0 the dtype is irrelevant
        let g0 = Geometry { r: 0, ..g };
        assert_eq!(
            KernelConfig::for_geometry_dtype(&g0, StripDType::I8).block_q,
            KernelConfig::for_geometry(&g0).block_q
        );
    }

    #[test]
    fn resident_elems_reporting() {
        let mut rng = Xoshiro256::new(12);
        let bias = Tensor::randn(&[6, 7], 1.0, &mut rng);
        let pq = Tensor::randn(&[6, 2], 1.0, &mut rng);
        let pk = Tensor::randn(&[7, 2], 1.0, &mut rng);
        assert_eq!(DenseTile::from_tensor(&bias).resident_elems(), 42);
        assert_eq!(FactoredTile::new(&pq, &pk).resident_elems(), 26);
        assert_eq!(AlibiTile { slope: 0.5 }.resident_elems(), 0);
        assert_eq!(NoBias.resident_elems(), 0);
    }

    /// Every provider's `add_row` override must agree bit-for-bit with
    /// the default `bq = 1` `add_tile` path — the decode/prefill
    /// exactness contract.
    #[test]
    fn add_row_matches_single_row_add_tile() {
        let mut rng = Xoshiro256::new(13);
        let n = 9;
        let m = 21;
        let bias = Tensor::randn(&[n, m], 1.0, &mut rng);
        let pq = Tensor::randn(&[n, 3], 0.5, &mut rng);
        let pk = Tensor::randn(&[m, 3], 0.5, &mut rng);
        let (sq, sk) = (Strip::quantize(&pq, StripDType::Bf16),
                        Strip::quantize(&pk, StripDType::Bf16));
        let dense = DenseTile::from_tensor(&bias);
        let fact = FactoredTile::new(&pq, &pk);
        let quant = FactoredTile::from_strips(&sq, &sk);
        let alibi = AlibiTile { slope: 0.3 };
        let providers: [&dyn BiasTile; 5] =
            [&NoBias, &dense, &fact, &quant, &alibi];
        for tile in providers {
            for qi in 0..n {
                for (k0, bk) in [(0, m), (0, 5), (4, 7), (m - 1, 1)] {
                    let mut via_row = vec![0.5f32; bk];
                    let mut via_tile = via_row.clone();
                    tile.add_row(qi, k0, &mut via_row);
                    tile.add_tile(qi, k0, 1, bk, &mut via_tile);
                    assert_eq!(via_row, via_tile,
                               "qi={qi} k0={k0} bk={bk}");
                }
            }
        }
    }

    #[test]
    fn bias_row_into_overwrites_with_the_strip() {
        let mut rng = Xoshiro256::new(14);
        let bias = Tensor::randn(&[4, 12], 1.0, &mut rng);
        let dense = DenseTile::from_tensor(&bias);
        // shorter than the table: visible prefix only (growing cache)
        let mut row = vec![7.0f32; 8];
        dense.bias_row_into(2, &mut row);
        assert_eq!(row, bias.view2().row(2)[..8].to_vec());
        let mut none = vec![7.0f32; 8];
        NoBias.bias_row_into(0, &mut none);
        assert!(none.iter().all(|&x| x == 0.0));
    }

    /// A decode step at position i must be bit-identical to row i of
    /// the one-shot tiled pass at the same block_k (single thread so
    /// the prefill row is computed with the same tile partition).
    #[test]
    fn decode_step_is_bitwise_row_of_prefill() {
        let (q, k, v) = qkv(12, 18, 8, 15);
        let mut rng = Xoshiro256::new(16);
        let bias = Tensor::randn(&[12, 18], 1.0, &mut rng);
        let tile = DenseTile::from_tensor(&bias);
        let scale = 1.0 / (8.0f32).sqrt();
        for causal in [false, true] {
            for bk in [1, 5, 18, 64] {
                let c = cfg(4, bk).with_threads(1);
                let full = attention_tiled(&q, &k, &v, &tile, causal, &c);
                for i in 0..12 {
                    let mut out = vec![0.0f32; 8];
                    run_decode_step(q.view2().row(i), k.view2(),
                                    v.view2(), &tile, i, 12, causal,
                                    scale, &c, &mut out);
                    assert_eq!(out.as_slice(), full.view2().row(i),
                               "i={i} causal={causal} bk={bk}");
                }
            }
        }
    }

    /// n > m with causal puts the new position entirely in the masked
    /// future: the 1×M path must return exact zeros and a zero
    /// denominator.
    #[test]
    fn fully_masked_decode_step_is_exact_zero() {
        let (q, k, v) = qkv(6, 3, 4, 17);
        let scale = 0.5;
        let mut out = vec![1.0f32; 4];
        // n = 6, m = 3 → off = −3; position 0 sees keys j ≤ −3: none
        let carry = run_decode_step(q.view2().row(0), k.view2(),
                                    v.view2(), &NoBias, 0, 6, true,
                                    scale, &cfg(1, 2), &mut out);
        assert_eq!(carry.l, 0.0);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    /// decode_steps must return the same outputs and carries for any
    /// thread count (disjoint out slices + carry slots).
    #[test]
    fn decode_steps_thread_count_does_not_change_bits() {
        let (q, k, v) = qkv(8, 26, 8, 18);
        let pq = Tensor::randn(&[8, 3], 0.4, &mut Xoshiro256::new(19));
        let pk = Tensor::randn(&[26, 3], 0.4, &mut Xoshiro256::new(20));
        let tile = FactoredTile::new(&pq, &pk);
        let scale = 1.0 / (8.0f32).sqrt();
        let run = |threads: usize| {
            let mut outs = vec![0.0f32; 8 * 8];
            let progs = outs
                .chunks_mut(8)
                .enumerate()
                .map(|(i, block)| {
                    (
                        DecodeProgram {
                            q: q.view2().row(i),
                            k: k.view2(),
                            v: v.view2(),
                            bias: &tile,
                            i,
                            n: 8,
                            causal: true,
                            scale,
                        },
                        block,
                    )
                })
                .collect();
            let carries =
                decode_steps(progs, &cfg(4, 7).with_threads(threads));
            (outs, carries)
        };
        let (base_out, base_carry) = run(1);
        for threads in [2, 3, 8] {
            let (out, carry) = run(threads);
            assert_eq!(out, base_out, "threads={threads}");
            assert_eq!(carry, base_carry, "threads={threads}");
        }
    }
}
