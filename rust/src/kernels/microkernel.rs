//! Fixed-width microkernels for the tile engine's hot loops.
//!
//! Every inner loop of [`super::run_query_block`] and the
//! [`super::BiasTile`] providers bottoms out here: register-tiled
//! dot-product kernels (one query row × [`NR`] key rows per block,
//! [`LANES`]-wide lane blocks inside each), fused multiply-add
//! throughout, and bounds checks hoisted out of the loop body by
//! re-slicing every operand to a common length up front.
//!
//! Two implementations share this file:
//!
//! * the **scalar fallback** (default, stable Rust) — `chunks_exact`
//!   lane blocks accumulated into a `[f32; LANES]` register file with
//!   `f32::mul_add`, written so LLVM can autovectorize it;
//! * the **explicit SIMD path** (`--features simd`, nightly
//!   `portable_simd`) — the same algorithm on `std::simd::f32x8`.
//!
//! **Bit-identity contract.** Both paths perform the identical
//! per-lane operation sequence — lane `l` of the accumulator sees the
//! same fused `a[i·LANES+l] * b[i·LANES+l] + acc[l]` chain, the tail is
//! a scalar `mul_add` chain in both, and the final reduction is the
//! shared [`reduce`] tree — so scalar and SIMD builds produce
//! bit-identical results, and the engine's "same bits for any thread
//! count" guarantee extends to "same bits for any build". The property
//! tests in `tests/microkernel_props.rs` pin every kernel to a
//! portable lane-model reference; running them with and without
//! `--features simd` proves both paths agree with it bit-for-bit.

/// Lane width of one register block (f32 lanes per SIMD vector).
pub const LANES: usize = 8;

/// Key rows processed per register-tiled dot block.
pub const NR: usize = 4;

/// The fixed reduction tree both paths share: pairwise over lane
/// distance 4, then 2, then 1. Never `reduce_sum` (its order is
/// implementation-defined); this tree is the contract.
#[inline(always)]
pub fn reduce(acc: [f32; LANES]) -> f32 {
    let a = [
        acc[0] + acc[4],
        acc[1] + acc[5],
        acc[2] + acc[6],
        acc[3] + acc[7],
    ];
    (a[0] + a[2]) + (a[1] + a[3])
}

// ---------------------------------------------------------------------------
// dot: one query row × one key row
// ---------------------------------------------------------------------------

/// Fused dot product `Σ a[i]·b[i]` over `min(|a|, |b|)` elements.
///
/// Lane-blocked: full [`LANES`]-wide blocks accumulate into a lane
/// register file, the tail accumulates into a scalar chain, and the
/// lane file collapses through [`reduce`].
#[cfg(not(feature = "simd"))]
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    let mut acc = [0.0f32; LANES];
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for (ca, cb) in (&mut ac).zip(&mut bc) {
        for l in 0..LANES {
            acc[l] = ca[l].mul_add(cb[l], acc[l]);
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
        tail = x.mul_add(y, tail);
    }
    reduce(acc) + tail
}

/// Fused dot product (explicit `std::simd` path — same lane algorithm,
/// bit-identical to the scalar fallback).
#[cfg(feature = "simd")]
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    use std::simd::{f32x8, StdFloat};
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    let mut acc = f32x8::splat(0.0);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for (ca, cb) in (&mut ac).zip(&mut bc) {
        acc = f32x8::from_slice(ca).mul_add(f32x8::from_slice(cb), acc);
    }
    let mut tail = 0.0f32;
    for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
        tail = x.mul_add(y, tail);
    }
    reduce(acc.to_array()) + tail
}

// ---------------------------------------------------------------------------
// dot4: one query row × NR key rows (the register tile)
// ---------------------------------------------------------------------------

/// Register-tiled dot block: `[dot(a, b0), dot(a, b1), dot(a, b2),
/// dot(a, b3)]` with each `a` lane block loaded once and reused across
/// all four key rows. Each output is bit-identical to the
/// corresponding [`dot`] call.
#[cfg(not(feature = "simd"))]
#[inline]
pub fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32],
            b3: &[f32]) -> [f32; NR] {
    let n = a
        .len()
        .min(b0.len())
        .min(b1.len())
        .min(b2.len())
        .min(b3.len());
    let (a, b0, b1, b2, b3) =
        (&a[..n], &b0[..n], &b1[..n], &b2[..n], &b3[..n]);
    let mut acc = [[0.0f32; LANES]; NR];
    let blocks = n / LANES;
    for i in 0..blocks {
        let o = i * LANES;
        let ca = &a[o..o + LANES];
        let cb = [
            &b0[o..o + LANES],
            &b1[o..o + LANES],
            &b2[o..o + LANES],
            &b3[o..o + LANES],
        ];
        for r in 0..NR {
            for l in 0..LANES {
                acc[r][l] = ca[l].mul_add(cb[r][l], acc[r][l]);
            }
        }
    }
    let mut tails = [0.0f32; NR];
    for i in blocks * LANES..n {
        let x = a[i];
        tails[0] = x.mul_add(b0[i], tails[0]);
        tails[1] = x.mul_add(b1[i], tails[1]);
        tails[2] = x.mul_add(b2[i], tails[2]);
        tails[3] = x.mul_add(b3[i], tails[3]);
    }
    [
        reduce(acc[0]) + tails[0],
        reduce(acc[1]) + tails[1],
        reduce(acc[2]) + tails[2],
        reduce(acc[3]) + tails[3],
    ]
}

/// Register-tiled dot block (explicit `std::simd` path — bit-identical
/// to the scalar fallback and to four [`dot`] calls).
#[cfg(feature = "simd")]
#[inline]
pub fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32],
            b3: &[f32]) -> [f32; NR] {
    use std::simd::{f32x8, StdFloat};
    let n = a
        .len()
        .min(b0.len())
        .min(b1.len())
        .min(b2.len())
        .min(b3.len());
    let (a, b0, b1, b2, b3) =
        (&a[..n], &b0[..n], &b1[..n], &b2[..n], &b3[..n]);
    let mut acc = [f32x8::splat(0.0); NR];
    let blocks = n / LANES;
    for i in 0..blocks {
        let o = i * LANES;
        let va = f32x8::from_slice(&a[o..o + LANES]);
        acc[0] = va.mul_add(f32x8::from_slice(&b0[o..o + LANES]), acc[0]);
        acc[1] = va.mul_add(f32x8::from_slice(&b1[o..o + LANES]), acc[1]);
        acc[2] = va.mul_add(f32x8::from_slice(&b2[o..o + LANES]), acc[2]);
        acc[3] = va.mul_add(f32x8::from_slice(&b3[o..o + LANES]), acc[3]);
    }
    let mut tails = [0.0f32; NR];
    for i in blocks * LANES..n {
        let x = a[i];
        tails[0] = x.mul_add(b0[i], tails[0]);
        tails[1] = x.mul_add(b1[i], tails[1]);
        tails[2] = x.mul_add(b2[i], tails[2]);
        tails[3] = x.mul_add(b3[i], tails[3]);
    }
    [
        reduce(acc[0].to_array()) + tails[0],
        reduce(acc[1].to_array()) + tails[1],
        reduce(acc[2].to_array()) + tails[2],
        reduce(acc[3].to_array()) + tails[3],
    ]
}

// ---------------------------------------------------------------------------
// Elementwise kernels (trivially bit-identical across paths: no
// cross-lane reduction, one fused op per element)
// ---------------------------------------------------------------------------

/// `y[i] += a · x[i]` over `min(|x|, |y|)` elements.
#[cfg(not(feature = "simd"))]
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len().min(y.len());
    let (x, y) = (&x[..n], &mut y[..n]);
    for (o, &v) in y.iter_mut().zip(x) {
        *o = a.mul_add(v, *o);
    }
}

/// `y[i] += a · x[i]` (explicit `std::simd` path).
#[cfg(feature = "simd")]
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    use std::simd::{f32x8, StdFloat};
    let n = x.len().min(y.len());
    let (x, y) = (&x[..n], &mut y[..n]);
    let va = f32x8::splat(a);
    let mut xc = x.chunks_exact(LANES);
    let mut yc = y.chunks_exact_mut(LANES);
    for (cx, cy) in (&mut xc).zip(&mut yc) {
        let r = va.mul_add(f32x8::from_slice(cx), f32x8::from_slice(cy));
        cy.copy_from_slice(&r.to_array());
    }
    for (o, &v) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o = a.mul_add(v, *o);
    }
}

/// `y[i] *= a`.
#[cfg(not(feature = "simd"))]
#[inline]
pub fn scale_in_place(a: f32, y: &mut [f32]) {
    for o in y.iter_mut() {
        *o *= a;
    }
}

/// `y[i] *= a` (explicit `std::simd` path).
#[cfg(feature = "simd")]
#[inline]
pub fn scale_in_place(a: f32, y: &mut [f32]) {
    use std::simd::f32x8;
    let va = f32x8::splat(a);
    let mut yc = y.chunks_exact_mut(LANES);
    for cy in &mut yc {
        let r = f32x8::from_slice(cy) * va;
        cy.copy_from_slice(&r.to_array());
    }
    for o in yc.into_remainder() {
        *o *= a;
    }
}

/// `y[i] += x[i]` over `min(|x|, |y|)` elements.
#[cfg(not(feature = "simd"))]
#[inline]
pub fn add_assign(x: &[f32], y: &mut [f32]) {
    let n = x.len().min(y.len());
    let (x, y) = (&x[..n], &mut y[..n]);
    for (o, &v) in y.iter_mut().zip(x) {
        *o += v;
    }
}

/// `y[i] += x[i]` (explicit `std::simd` path).
#[cfg(feature = "simd")]
#[inline]
pub fn add_assign(x: &[f32], y: &mut [f32]) {
    use std::simd::f32x8;
    let n = x.len().min(y.len());
    let (x, y) = (&x[..n], &mut y[..n]);
    let mut xc = x.chunks_exact(LANES);
    let mut yc = y.chunks_exact_mut(LANES);
    for (cx, cy) in (&mut xc).zip(&mut yc) {
        let r = f32x8::from_slice(cx) + f32x8::from_slice(cy);
        cy.copy_from_slice(&r.to_array());
    }
    for (o, &v) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o += v;
    }
}

/// Maximum of a slice (`-inf` when empty). Scalar in both builds:
/// `max` is order-insensitive for the non-NaN inputs the engine feeds
/// it, and it is nowhere near the dot-product bottleneck.
#[inline]
pub fn row_max(xs: &[f32]) -> f32 {
    let mut m = f32::NEG_INFINITY;
    for &x in xs {
        m = m.max(x);
    }
    m
}

// ---------------------------------------------------------------------------
// Row-level drivers: score-tile contraction shared by q·kᵀ and the
// factored-bias Eq. (3) contraction
// ---------------------------------------------------------------------------

/// For one query row `a`, overwrite `out[j] = dot(a, rows.row(j0 + j))
/// · scale` for `j ∈ [0, out.len())` — the register-tiled q·kᵀ inner
/// loop of the score tile.
#[inline]
pub fn row_scores(a: &[f32], rows: crate::tensor::View2<'_>, j0: usize,
                  scale: f32, out: &mut [f32]) {
    let bk = out.len();
    let mut jj = 0usize;
    while jj + NR <= bk {
        let d = dot4(
            a,
            rows.row(j0 + jj),
            rows.row(j0 + jj + 1),
            rows.row(j0 + jj + 2),
            rows.row(j0 + jj + 3),
        );
        for r in 0..NR {
            out[jj + r] = d[r] * scale;
        }
        jj += NR;
    }
    while jj < bk {
        out[jj] = dot(a, rows.row(j0 + jj)) * scale;
        jj += 1;
    }
}

/// For one query row `a`, accumulate `out[j] += dot(a, rows.row(j0 +
/// j))` — the register-tiled Eq. (3) factored-bias contraction for one
/// score row (rows = φ_k, a = the query's φ_q row).
#[inline]
pub fn row_accum(a: &[f32], rows: crate::tensor::View2<'_>, j0: usize,
                 out: &mut [f32]) {
    let bk = out.len();
    let mut jj = 0usize;
    while jj + NR <= bk {
        let d = dot4(
            a,
            rows.row(j0 + jj),
            rows.row(j0 + jj + 1),
            rows.row(j0 + jj + 2),
            rows.row(j0 + jj + 3),
        );
        for r in 0..NR {
            out[jj + r] += d[r];
        }
        jj += NR;
    }
    while jj < bk {
        out[jj] += dot(a, rows.row(j0 + jj));
        jj += 1;
    }
}
