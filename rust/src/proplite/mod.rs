//! Tiny property-testing harness (no proptest in the vendored universe).
//!
//! Generators are closures over [`crate::util::Xoshiro256`]; a property is
//! run over `cases` random inputs and on failure the input is shrunk with
//! a caller-provided shrinker (halving-style candidates) before panicking
//! with the minimal counterexample.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla_extension rpath)
//! use flashbias::proplite::{forall, shrink_usize, Config};
//! forall(
//!     Config::default().cases(64),
//!     |rng| rng.next_below(1000) as usize,
//!     |n| shrink_usize(n),
//!     |&n| n < 1000,
//! );
//! ```

use crate::util::Xoshiro256;

#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 100,
            seed: 0x5EED,
            max_shrink_steps: 200,
        }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `prop` over `cfg.cases` values drawn from `gen`. On failure, shrink
/// with `shrink` (must return *smaller* candidates) and panic with the
/// minimal failing input's Debug representation.
pub fn forall<T, G, S, P>(cfg: Config, mut gen: G, shrink: S, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Xoshiro256) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> bool,
{
    let mut rng = Xoshiro256::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(input, &shrink, &prop,
                                      cfg.max_shrink_steps);
            panic!(
                "property failed on case {case}; minimal counterexample: \
                 {minimal:?}"
            );
        }
    }
}

fn shrink_loop<T, S, P>(mut failing: T, shrink: &S, prop: &P,
                        max_steps: usize) -> T
where
    T: Clone,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> bool,
{
    let mut steps = 0;
    'outer: while steps < max_steps {
        for cand in shrink(&failing) {
            steps += 1;
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
            if steps >= max_steps {
                break 'outer;
            }
        }
        break;
    }
    failing
}

// ---------------------------------------------------------------------------
// stock shrinkers
// ---------------------------------------------------------------------------

/// Binary-search-style shrinker for usize: candidates approach `n` from
/// below geometrically (0, n/2, 3n/4, 7n/8, …, n−1), so repeated passes
/// converge on the smallest failing value like bisection.
pub fn shrink_usize(n: &usize) -> Vec<usize> {
    let n = *n;
    let mut out = Vec::new();
    if n > 0 {
        out.push(0);
        let mut gap = n / 2;
        while gap > 0 {
            out.push(n - gap);
            gap /= 2;
        }
    }
    out.sort_unstable();
    out.dedup();
    out.retain(|&x| x != n);
    out
}

/// Shrinker for f32 toward 0 and simpler magnitudes.
pub fn shrink_f32(x: &f32) -> Vec<f32> {
    let x = *x;
    let mut out = Vec::new();
    if x != 0.0 {
        out.push(0.0);
        out.push(x / 2.0);
        out.push(x.trunc());
    }
    out.retain(|&y| y != x);
    out.dedup_by(|a, b| a == b);
    out
}

/// Shrinker for Vec<T>: drop halves, drop single elements, shrink elements.
pub fn shrink_vec<T: Clone>(xs: &[T],
                            elem: impl Fn(&T) -> Vec<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = xs.len();
    if n == 0 {
        return out;
    }
    out.push(xs[..n / 2].to_vec());
    out.push(xs[n / 2..].to_vec());
    if n <= 16 {
        for i in 0..n {
            let mut v = xs.to_vec();
            v.remove(i);
            out.push(v);
        }
        for i in 0..n {
            for e in elem(&xs[i]) {
                let mut v = xs.to_vec();
                v[i] = e;
                out.push(v);
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// stock generators
// ---------------------------------------------------------------------------

/// Random dims in [lo, hi] (inclusive).
pub fn gen_dim(rng: &mut Xoshiro256, lo: usize, hi: usize) -> usize {
    lo + rng.next_below((hi - lo + 1) as u64) as usize
}

/// Random f32 vector with entries ~ N(0, scale).
pub fn gen_vec(rng: &mut Xoshiro256, n: usize, scale: f32) -> Vec<f32> {
    rng.normal_vec(n, scale)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(
            Config::default().cases(50),
            |rng| gen_dim(rng, 1, 64),
            |n| shrink_usize(n),
            |&n| (1..=64).contains(&n),
        );
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_panics() {
        forall(
            Config::default().cases(50),
            |rng| gen_dim(rng, 0, 1000),
            |n| shrink_usize(n),
            |&n| n < 500,
        );
    }

    #[test]
    fn shrinker_reaches_small_counterexample() {
        // Property: n < 500. Failing inputs are >= 500; the halving
        // shrinker must land on a value well below the initial failure.
        let minimal = super::shrink_loop(987usize, &shrink_usize,
                                         &|&n: &usize| n < 500, 200);
        assert_eq!(minimal, 500);
    }

    #[test]
    fn shrink_vec_produces_smaller() {
        let xs = vec![1, 2, 3, 4];
        let cands = shrink_vec(&xs, |_| vec![]);
        assert!(cands.iter().any(|c| c.len() < xs.len()));
    }
}
