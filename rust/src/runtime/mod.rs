//! PJRT runtime: load AOT artifacts (HLO text + input binaries produced by
//! `python/compile/aot.py`) and execute them on the CPU PJRT client.
//!
//! This is the only module that touches the `xla` bindings. The flow
//! follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`. HLO
//! *text* is the interchange format (xla_extension 0.5.1 rejects jax≥0.5
//! serialized protos with 64-bit ids).
//!
//! Outside the vendored accelerator image the real bindings do not
//! exist, so this module builds against [`crate::xla_stub`] (imported
//! under the name `xla`): every `Runtime::open*` then fails with a clear
//! "PJRT backend unavailable" error while the rest of the crate — the
//! `plan` pipeline, host/simulator executors, coordinator — keeps
//! working. To wire the real backend, swap the `use` below for the real
//! crate and add it to `rust/Cargo.toml`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonlite::Json;
use crate::tensor::Tensor;
use crate::util::sync::{Mutex, MutexGuard};
use crate::xla_stub as xla;

/// Element type of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// Shape + dtype + backing file of one artifact input/output.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub file: Option<String>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(
            j.get("dtype").as_str().ok_or_else(|| anyhow!("missing dtype"))?,
        )?;
        let file = j.get("file").as_str().map(str::to_string);
        Ok(Self { shape, dtype, file })
    }
}

/// Manifest entry for one AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

impl ArtifactSpec {
    /// Indices of inputs the bench harness may randomize per request
    /// (activations); the rest are weights.
    pub fn activation_indices(&self) -> Vec<usize> {
        self.meta
            .get("activations")
            .as_arr()
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default()
    }

    pub fn family(&self) -> &str {
        self.meta.get("family").as_str().unwrap_or("")
    }

    pub fn variant(&self) -> &str {
        self.meta.get("variant").as_str().unwrap_or("")
    }

    pub fn seq_len(&self) -> usize {
        self.meta.get("n").as_usize().unwrap_or(0)
    }
}

/// Host value fed to / returned from an executable.
#[derive(Clone, Debug)]
pub enum HostValue {
    F32(Tensor),
    I32(Vec<i32>, Vec<usize>),
}

impl HostValue {
    pub fn as_f32(&self) -> Option<&Tensor> {
        match self {
            HostValue::F32(t) => Some(t),
            _ => None,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => t.shape(),
            HostValue::I32(_, s) => s,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64>;
        let lit = match self {
            HostValue::F32(t) => {
                dims = t.shape().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(t.data()).reshape(&dims)?
            }
            HostValue::I32(v, shape) => {
                dims = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(v).reshape(&dims)?
            }
        };
        Ok(lit)
    }
}

/// A compiled executable plus its spec.
///
/// # Safety of the `Send + Sync` impls
/// `PjRtLoadedExecutable::execute` and buffer transfers go through the
/// PJRT C API, which guarantees thread-safe execution of a loaded
/// executable (PJRT is designed for concurrent dispatch). The wrapper
/// types only lack the auto-traits because they hold raw pointers.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
}

// The crate is `deny(unsafe_code)`; these impls are the documented
// exception (see the safety note above).
#[allow(unsafe_code)]
unsafe impl Send for Executable {}
#[allow(unsafe_code)]
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with host values; returns host values (tuple flattened).
    pub fn run(&self, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let literals = inputs
            .iter()
            .map(HostValue::to_literal)
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for lit in parts {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> =
                shape.dims().iter().map(|&d| d as usize).collect();
            match shape.ty() {
                xla::ElementType::F32 => {
                    let data = lit.to_vec::<f32>()?;
                    out.push(HostValue::F32(Tensor::new(&dims, data)));
                }
                xla::ElementType::S32 => {
                    let data = lit.to_vec::<i32>()?;
                    out.push(HostValue::I32(data, dims));
                }
                other => bail!("unsupported output type {other:?}"),
            }
        }
        Ok(out)
    }
}

/// The artifact registry + PJRT client + executable cache.
///
/// The client is created lazily on the first compile: opening a manifest
/// and reading input dumps are pure host operations and must keep
/// working where no PJRT backend exists (e.g. the stub build).
pub struct Runtime {
    client: Mutex<Option<xla::PjRtClient>>,
    root: PathBuf,
    artifacts: HashMap<String, ArtifactSpec>,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

// Same exception as [`Executable`]: the PJRT client handle is a raw
// pointer behind a thread-safe C API; all mutation goes through the
// `runtime.client` lock.
#[allow(unsafe_code)]
unsafe impl Send for Runtime {}
#[allow(unsafe_code)]
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let root = dir.as_ref().to_path_buf();
        let manifest_path = root.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(
            || format!("reading {} (run `make artifacts`)",
                       manifest_path.display()),
        )?;
        let json = Json::parse(&text)
            .map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut artifacts = HashMap::new();
        for entry in json
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let name = entry
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let hlo = entry
                .get("hlo")
                .as_str()
                .ok_or_else(|| anyhow!("artifact missing hlo"))?
                .to_string();
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                entry
                    .get(key)
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name,
                    hlo,
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                    meta: entry.get("meta").clone(),
                },
            );
        }
        Ok(Self {
            client: Mutex::new("runtime.client", None),
            root,
            artifacts,
            cache: Mutex::new("runtime.cache", HashMap::new()),
        })
    }

    /// An artifact-less runtime: every `spec()` lookup misses and
    /// `load()` fails. Lets the coordinator serve host-plan traffic
    /// (kernel-engine batches) where no compiled artifacts or PJRT
    /// backend exist.
    pub fn empty() -> Self {
        Self {
            client: Mutex::new("runtime.client", None),
            root: PathBuf::from("."),
            artifacts: HashMap::new(),
            cache: Mutex::new("runtime.cache", HashMap::new()),
        }
    }

    /// Default artifact directory: `$FLASHBIAS_ARTIFACTS` or `artifacts/`
    /// relative to the workspace root.
    pub fn open_default() -> Result<Self> {
        if let Ok(dir) = std::env::var("FLASHBIAS_ARTIFACTS") {
            return Self::open(dir);
        }
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            if Path::new(cand).join("manifest.json").exists() {
                return Self::open(cand);
            }
        }
        // fall back to the crate-root-relative path
        Self::open(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    /// Create (or reuse) the PJRT client. On `Ok`, the guard is
    /// guaranteed to hold `Some`.
    fn client(&self)
              -> Result<MutexGuard<'_, Option<xla::PjRtClient>>>
    {
        let mut guard = self.client.lock_recover();
        if guard.is_none() {
            *guard = Some(xla::PjRtClient::cpu()?);
        }
        Ok(guard)
    }

    pub fn platform(&self) -> String {
        match self.client() {
            Ok(guard) => guard
                .as_ref()
                .map(|c| c.platform_name())
                .unwrap_or_else(|| "unavailable".to_string()),
            Err(_) => "unavailable".to_string(),
        }
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> =
            self.artifacts.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name)
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock_recover().get(name) {
            return Ok(exe.clone());
        }
        let spec = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?
            .clone();
        let hlo_path = self.root.join(&spec.hlo);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow!("bad path {hlo_path:?}"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = {
            let guard = self.client()?;
            guard
                .as_ref()
                .ok_or_else(|| anyhow!("PJRT client unavailable"))?
                .compile(&comp)?
        };
        let exe = Arc::new(Executable { exe, spec });
        self.cache
            .lock_recover()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    fn read_bin(&self, spec: &TensorSpec) -> Result<HostValue> {
        let file = spec
            .file
            .as_ref()
            .ok_or_else(|| anyhow!("spec has no backing file"))?;
        let bytes = std::fs::read(self.root.join(file))
            .with_context(|| format!("reading {file}"))?;
        let expect = spec.numel() * spec.dtype.size_bytes();
        if bytes.len() != expect {
            bail!("{file}: {} bytes, expected {expect}", bytes.len());
        }
        // chunks_exact(4) guarantees 4-byte windows, so indexing here
        // cannot go out of bounds (and needs no unwrap).
        Ok(match spec.dtype {
            Dtype::F32 => {
                let data: Vec<f32> = bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                HostValue::F32(Tensor::new(&spec.shape, data))
            }
            Dtype::I32 => {
                let data: Vec<i32> = bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                HostValue::I32(data, spec.shape.clone())
            }
        })
    }

    /// The example inputs the artifact was lowered with.
    pub fn example_inputs(&self, name: &str) -> Result<Vec<HostValue>> {
        let spec = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        spec.inputs.iter().map(|s| self.read_bin(s)).collect()
    }

    /// The expected outputs recorded at AOT time (XLA:CPU python run).
    pub fn expected_outputs(&self, name: &str) -> Result<Vec<HostValue>> {
        let spec = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        spec.outputs.iter().map(|s| self.read_bin(s)).collect()
    }

    /// Load + warm up (one execution with example inputs).
    pub fn load_warm(&self, name: &str) -> Result<Arc<Executable>> {
        let exe = self.load(name)?;
        let inputs = self.example_inputs(name)?;
        exe.run(&inputs)?;
        Ok(exe)
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests that need real artifacts live in
    // rust/tests/runtime_artifacts.rs (integration, requires
    // `make artifacts`). Unit tests here cover the manifest parsing and
    // HostValue plumbing without a PJRT client.
    use super::*;

    #[test]
    fn tensor_spec_from_json() {
        let j = Json::parse(
            r#"{"shape": [2, 3], "dtype": "f32", "file": "x.bin"}"#,
        )
        .unwrap();
        let spec = TensorSpec::from_json(&j).unwrap();
        assert_eq!(spec.shape, vec![2, 3]);
        assert_eq!(spec.dtype, Dtype::F32);
        assert_eq!(spec.numel(), 6);
        assert_eq!(spec.file.as_deref(), Some("x.bin"));
    }

    #[test]
    fn tensor_spec_rejects_bad_dtype() {
        let j = Json::parse(r#"{"shape": [1], "dtype": "f64"}"#).unwrap();
        assert!(TensorSpec::from_json(&j).is_err());
    }

    #[test]
    fn host_value_shapes() {
        let t = HostValue::F32(Tensor::zeros(&[2, 5]));
        assert_eq!(t.shape(), &[2, 5]);
        let i = HostValue::I32(vec![1, 2, 3], vec![3]);
        assert_eq!(i.shape(), &[3]);
        assert!(i.as_f32().is_none());
        assert!(t.as_f32().is_some());
    }

    #[test]
    fn artifact_spec_meta_accessors() {
        let meta = Json::parse(
            r#"{"family": "attn", "variant": "factored", "n": 256,
                "activations": [0, 1, 2]}"#,
        )
        .unwrap();
        let spec = ArtifactSpec {
            name: "x".into(),
            hlo: "hlo/x.hlo.txt".into(),
            inputs: vec![],
            outputs: vec![],
            meta,
        };
        assert_eq!(spec.family(), "attn");
        assert_eq!(spec.variant(), "factored");
        assert_eq!(spec.seq_len(), 256);
        assert_eq!(spec.activation_indices(), vec![0, 1, 2]);
    }
}
