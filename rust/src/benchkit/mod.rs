//! Shared harness for the paper-table/figure benches (no criterion in the
//! vendored universe; each bench binary is `harness = false` and drives
//! this module).
//!
//! Conventions: every bench prints (a) the paper's reference numbers for
//! the row it regenerates, (b) the measured/simulated numbers, so
//! `cargo bench | tee bench_output.txt` is the EXPERIMENTS.md source.

use crate::runtime::{HostValue, Runtime};
use crate::util::{human_bytes, human_secs, Stats, Timer};

/// Measured row: label + per-iteration seconds + optional bytes.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub stats: Stats,
    pub bytes: Option<u64>,
    pub note: String,
}

/// Pretty table printer.
pub struct Table {
    title: String,
    rows: Vec<Row>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        println!("\n=== {title} ===");
        Self {
            title: title.to_string(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, row: Row) {
        let mem = row
            .bytes
            .map(|b| human_bytes(b))
            .unwrap_or_else(|| "-".into());
        println!(
            "  {:38} mean={:>12} p50={:>12} mem={:>10} {}",
            row.label,
            human_secs(row.stats.mean()),
            human_secs(row.stats.p50()),
            mem,
            row.note
        );
        self.rows.push(row);
    }

    /// Δ against a baseline row (the paper's Table 3 presentation).
    pub fn delta(&self, label: &str, baseline: &str) -> Option<f64> {
        let get = |l: &str| {
            self.rows
                .iter()
                .find(|r| r.label == l)
                .map(|r| r.stats.mean())
        };
        Some(get(label)? - get(baseline)?)
    }

    pub fn title(&self) -> &str {
        &self.title
    }

    pub fn rows(&self) -> &[Row] {
        &self.rows
    }
}

/// Bench one artifact: load, warm up, time `iters` executions of its
/// example inputs. Returns per-iteration stats.
pub fn bench_artifact(rt: &Runtime, name: &str, warmup: usize,
                      iters: usize) -> Row {
    let exe = rt.load(name).expect("load artifact");
    let inputs = rt.example_inputs(name).expect("example inputs");
    let stats = crate::util::bench_loop(warmup, iters, || {
        exe.run(&inputs).expect("execute");
    });
    let bytes = input_bytes(&inputs);
    Row {
        label: name.to_string(),
        stats,
        bytes: Some(bytes),
        note: String::new(),
    }
}

/// Total bytes of a host input set (the HBM-resident request payload).
pub fn input_bytes(inputs: &[HostValue]) -> u64 {
    inputs
        .iter()
        .map(|v| match v {
            HostValue::F32(t) => t.size_bytes() as u64,
            HostValue::I32(d, _) => (d.len() * 4) as u64,
        })
        .sum()
}

/// Bytes of only the *bias-carrying* inputs (indices beyond activations'
/// q/k/v), used to report the paper's bias-storage columns.
pub fn bias_input_bytes(rt: &Runtime, name: &str) -> u64 {
    let spec = rt.spec(name).expect("spec");
    let acts = spec.activation_indices();
    spec.inputs
        .iter()
        .enumerate()
        .filter(|(i, _)| acts.contains(i))
        .map(|(_, s)| (s.numel() * s.dtype.size_bytes()) as u64)
        .sum()
}

/// Time a closure `iters` times (for simulator/host-math benches).
pub fn bench_fn<F: FnMut()>(label: &str, warmup: usize, iters: usize,
                            f: F) -> Row {
    let stats = crate::util::bench_loop(warmup, iters, f);
    Row {
        label: label.to_string(),
        stats,
        bytes: None,
        note: String::new(),
    }
}

/// Print a paper-reference block so bench output is self-describing.
pub fn paper_reference(lines: &[&str]) {
    println!("  paper reference:");
    for l in lines {
        println!("    | {l}");
    }
}

/// Quick single-shot timing (for expensive one-off steps like SVD).
pub fn time_once<T>(label: &str, f: impl FnOnce() -> T) -> T {
    let t = Timer::start();
    let out = f();
    println!("  {label}: {}", human_secs(t.elapsed_secs()));
    out
}

/// Standard iteration counts, overridable via FLASHBIAS_BENCH_ITERS for
/// quick smoke runs.
pub fn iters(default: usize) -> usize {
    std::env::var("FLASHBIAS_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_delta() {
        let mut t = Table::new("test");
        let mut s1 = Stats::new();
        s1.push(1.0);
        let mut s2 = Stats::new();
        s2.push(3.0);
        t.row(Row {
            label: "base".into(),
            stats: s1,
            bytes: None,
            note: String::new(),
        });
        t.row(Row {
            label: "x".into(),
            stats: s2,
            bytes: Some(1024),
            note: "n".into(),
        });
        assert_eq!(t.delta("x", "base"), Some(2.0));
        assert_eq!(t.delta("missing", "base"), None);
        assert_eq!(t.rows().len(), 2);
        assert_eq!(t.title(), "test");
    }

    #[test]
    fn iters_env_override() {
        assert_eq!(iters(7), 7);
    }
}
