//! Shared harness for the paper-table/figure benches (no criterion in the
//! vendored universe; each bench binary is `harness = false` and drives
//! this module).
//!
//! Conventions: every bench prints (a) the paper's reference numbers for
//! the row it regenerates, (b) the measured/simulated numbers, so
//! `cargo bench | tee bench_output.txt` is the EXPERIMENTS.md source.

use std::path::PathBuf;

use crate::jsonlite::Json;
use crate::runtime::{HostValue, Runtime};
use crate::util::{human_bytes, human_secs, Stats, Timer};

/// Measured row: label + per-iteration seconds + optional bytes.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub stats: Stats,
    pub bytes: Option<u64>,
    pub note: String,
}

/// Pretty table printer.
pub struct Table {
    title: String,
    rows: Vec<Row>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        println!("\n=== {title} ===");
        Self {
            title: title.to_string(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, row: Row) {
        let mem = row
            .bytes
            .map(|b| human_bytes(b))
            .unwrap_or_else(|| "-".into());
        println!(
            "  {:38} mean={:>12} p50={:>12} mem={:>10} {}",
            row.label,
            human_secs(row.stats.mean()),
            human_secs(row.stats.p50()),
            mem,
            row.note
        );
        self.rows.push(row);
    }

    /// Δ against a baseline row (the paper's Table 3 presentation).
    pub fn delta(&self, label: &str, baseline: &str) -> Option<f64> {
        let get = |l: &str| {
            self.rows
                .iter()
                .find(|r| r.label == l)
                .map(|r| r.stats.mean())
        };
        Some(get(label)? - get(baseline)?)
    }

    pub fn title(&self) -> &str {
        &self.title
    }

    pub fn rows(&self) -> &[Row] {
        &self.rows
    }

    /// Machine-readable rows as JSON: `{title, rows: [{label, mean,
    /// p50, bytes}]}` (bytes is `null` when a row has none).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("label", Json::str(&r.label)),
                    ("mean", Json::num(r.stats.mean())),
                    ("p50", Json::num(r.stats.p50())),
                    (
                        "bytes",
                        r.bytes
                            .map(|b| Json::num(b as f64))
                            .unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("title", Json::str(&self.title)),
            ("rows", Json::Arr(rows)),
        ])
    }

    /// Write the table as `BENCH_<stem>.json` into `dir` (the CI/tooling
    /// interchange format next to the pretty print).
    pub fn write_json_to(&self, dir: impl Into<PathBuf>,
                         stem: &str) -> std::io::Result<PathBuf> {
        let path = dir.into().join(format!("BENCH_{stem}.json"));
        std::fs::write(&path, self.to_json().dump())?;
        println!("  wrote {}", path.display());
        Ok(path)
    }

    /// Write `BENCH_<stem>.json` into `$FLASHBIAS_BENCH_JSON_DIR`
    /// (default: the current directory — `make bench-json` sets it to
    /// the workspace root).
    pub fn write_json(&self, stem: &str) -> std::io::Result<PathBuf> {
        let dir = std::env::var("FLASHBIAS_BENCH_JSON_DIR")
            .unwrap_or_else(|_| ".".into());
        self.write_json_to(dir, stem)
    }
}

/// Bench one artifact: load, warm up, time `iters` executions of its
/// example inputs. Returns per-iteration stats.
pub fn bench_artifact(rt: &Runtime, name: &str, warmup: usize,
                      iters: usize) -> Row {
    let exe = rt.load(name).expect("load artifact");
    let inputs = rt.example_inputs(name).expect("example inputs");
    let stats = crate::util::bench_loop(warmup, iters, || {
        exe.run(&inputs).expect("execute");
    });
    let bytes = input_bytes(&inputs);
    Row {
        label: name.to_string(),
        stats,
        bytes: Some(bytes),
        note: String::new(),
    }
}

/// Total bytes of a host input set (the HBM-resident request payload).
pub fn input_bytes(inputs: &[HostValue]) -> u64 {
    inputs
        .iter()
        .map(|v| match v {
            HostValue::F32(t) => t.size_bytes() as u64,
            HostValue::I32(d, _) => (d.len() * 4) as u64,
        })
        .sum()
}

/// Bytes of only the *bias-carrying* inputs (indices beyond activations'
/// q/k/v), used to report the paper's bias-storage columns.
pub fn bias_input_bytes(rt: &Runtime, name: &str) -> u64 {
    let spec = rt.spec(name).expect("spec");
    let acts = spec.activation_indices();
    spec.inputs
        .iter()
        .enumerate()
        .filter(|(i, _)| acts.contains(i))
        .map(|(_, s)| (s.numel() * s.dtype.size_bytes()) as u64)
        .sum()
}

/// Time a closure `iters` times (for simulator/host-math benches).
pub fn bench_fn<F: FnMut()>(label: &str, warmup: usize, iters: usize,
                            f: F) -> Row {
    let stats = crate::util::bench_loop(warmup, iters, f);
    Row {
        label: label.to_string(),
        stats,
        bytes: None,
        note: String::new(),
    }
}

/// Print a paper-reference block so bench output is self-describing.
pub fn paper_reference(lines: &[&str]) {
    println!("  paper reference:");
    for l in lines {
        println!("    | {l}");
    }
}

/// Quick single-shot timing (for expensive one-off steps like SVD).
pub fn time_once<T>(label: &str, f: impl FnOnce() -> T) -> T {
    let t = Timer::start();
    let out = f();
    println!("  {label}: {}", human_secs(t.elapsed_secs()));
    out
}

/// Standard iteration counts, overridable via FLASHBIAS_BENCH_ITERS for
/// quick smoke runs.
pub fn iters(default: usize) -> usize {
    std::env::var("FLASHBIAS_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_delta() {
        let mut t = Table::new("test");
        let mut s1 = Stats::new();
        s1.push(1.0);
        let mut s2 = Stats::new();
        s2.push(3.0);
        t.row(Row {
            label: "base".into(),
            stats: s1,
            bytes: None,
            note: String::new(),
        });
        t.row(Row {
            label: "x".into(),
            stats: s2,
            bytes: Some(1024),
            note: "n".into(),
        });
        assert_eq!(t.delta("x", "base"), Some(2.0));
        assert_eq!(t.delta("missing", "base"), None);
        assert_eq!(t.rows().len(), 2);
        assert_eq!(t.title(), "test");
    }

    #[test]
    fn iters_env_override() {
        assert_eq!(iters(7), 7);
    }

    #[test]
    fn json_roundtrip_and_file_dump() {
        let mut t = Table::new("kernels-test");
        let mut s = Stats::new();
        s.push(0.25);
        s.push(0.75);
        t.row(Row {
            label: "tiled".into(),
            stats: s,
            bytes: Some(2048),
            note: String::new(),
        });
        let j = t.to_json();
        assert_eq!(j.get("title").as_str(), Some("kernels-test"));
        let rows = j.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("label").as_str(), Some("tiled"));
        assert_eq!(rows[0].get("mean").as_f64(), Some(0.5));
        assert_eq!(rows[0].get("bytes").as_f64(), Some(2048.0));
        // dump → parse roundtrip through a real file
        let path = t
            .write_json_to(std::env::temp_dir(), "kernels_test")
            .expect("write");
        let text = std::fs::read_to_string(&path).expect("read back");
        let parsed = crate::jsonlite::Json::parse(&text).expect("parse");
        assert_eq!(parsed.get("title").as_str(), Some("kernels-test"));
        let _ = std::fs::remove_file(path);
    }
}
