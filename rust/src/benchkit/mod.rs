//! Shared harness for the paper-table/figure benches (no criterion in the
//! vendored universe; each bench binary is `harness = false` and drives
//! this module).
//!
//! Conventions: every bench prints (a) the paper's reference numbers for
//! the row it regenerates, (b) the measured/simulated numbers, so
//! `cargo bench | tee bench_output.txt` is the EXPERIMENTS.md source.

use std::path::PathBuf;

use crate::jsonlite::Json;
use crate::runtime::{HostValue, Runtime};
use crate::util::{human_bytes, human_secs, Stats, Timer};

/// Measured row: label + per-iteration seconds + optional bytes.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub stats: Stats,
    pub bytes: Option<u64>,
    pub note: String,
}

/// Pretty table printer.
pub struct Table {
    title: String,
    rows: Vec<Row>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        println!("\n=== {title} ===");
        Self {
            title: title.to_string(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, row: Row) {
        let mem = row
            .bytes
            .map(|b| human_bytes(b))
            .unwrap_or_else(|| "-".into());
        println!(
            "  {:38} mean={:>12} p50={:>12} mem={:>10} {}",
            row.label,
            human_secs(row.stats.mean()),
            human_secs(row.stats.p50()),
            mem,
            row.note
        );
        self.rows.push(row);
    }

    /// Δ against a baseline row (the paper's Table 3 presentation).
    pub fn delta(&self, label: &str, baseline: &str) -> Option<f64> {
        let get = |l: &str| {
            self.rows
                .iter()
                .find(|r| r.label == l)
                .map(|r| r.stats.mean())
        };
        Some(get(label)? - get(baseline)?)
    }

    pub fn title(&self) -> &str {
        &self.title
    }

    pub fn rows(&self) -> &[Row] {
        &self.rows
    }

    /// Machine-readable rows as JSON: `{title, rows: [{label, mean,
    /// p50, p99, bytes, note}]}` (bytes is `null` when a row has
    /// none). Consumers key on `label`/`mean`; the tail percentile and
    /// note ride along for serving benches.
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("label", Json::str(&r.label)),
                    ("mean", Json::num(r.stats.mean())),
                    ("p50", Json::num(r.stats.p50())),
                    ("p99", Json::num(r.stats.p99())),
                    (
                        "bytes",
                        r.bytes
                            .map(|b| Json::num(b as f64))
                            .unwrap_or(Json::Null),
                    ),
                    ("note", Json::str(&r.note)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("title", Json::str(&self.title)),
            ("rows", Json::Arr(rows)),
        ])
    }

    /// Write the table as `BENCH_<stem>.json` into `dir` (the CI/tooling
    /// interchange format next to the pretty print).
    pub fn write_json_to(&self, dir: impl Into<PathBuf>,
                         stem: &str) -> std::io::Result<PathBuf> {
        let path = dir.into().join(format!("BENCH_{stem}.json"));
        std::fs::write(&path, self.to_json().dump())?;
        println!("  wrote {}", path.display());
        Ok(path)
    }

    /// Write `BENCH_<stem>.json` into `$FLASHBIAS_BENCH_JSON_DIR`
    /// (default: the current directory — `make bench-json` sets it to
    /// the workspace root).
    pub fn write_json(&self, stem: &str) -> std::io::Result<PathBuf> {
        let dir = std::env::var("FLASHBIAS_BENCH_JSON_DIR")
            .unwrap_or_else(|_| ".".into());
        self.write_json_to(dir, stem)
    }
}

/// Bench one artifact: load, warm up, time `iters` executions of its
/// example inputs. Returns per-iteration stats.
pub fn bench_artifact(rt: &Runtime, name: &str, warmup: usize,
                      iters: usize) -> Row {
    let exe = rt.load(name).expect("load artifact");
    let inputs = rt.example_inputs(name).expect("example inputs");
    let stats = crate::util::bench_loop(warmup, iters, || {
        exe.run(&inputs).expect("execute");
    });
    let bytes = input_bytes(&inputs);
    Row {
        label: name.to_string(),
        stats,
        bytes: Some(bytes),
        note: String::new(),
    }
}

/// Total bytes of a host input set (the HBM-resident request payload).
pub fn input_bytes(inputs: &[HostValue]) -> u64 {
    inputs
        .iter()
        .map(|v| match v {
            HostValue::F32(t) => t.size_bytes() as u64,
            HostValue::I32(d, _) => (d.len() * 4) as u64,
        })
        .sum()
}

/// Bytes of only the *bias-carrying* inputs (indices beyond activations'
/// q/k/v), used to report the paper's bias-storage columns.
pub fn bias_input_bytes(rt: &Runtime, name: &str) -> u64 {
    let spec = rt.spec(name).expect("spec");
    let acts = spec.activation_indices();
    spec.inputs
        .iter()
        .enumerate()
        .filter(|(i, _)| acts.contains(i))
        .map(|(_, s)| (s.numel() * s.dtype.size_bytes()) as u64)
        .sum()
}

/// Time a closure `iters` times (for simulator/host-math benches).
pub fn bench_fn<F: FnMut()>(label: &str, warmup: usize, iters: usize,
                            f: F) -> Row {
    let stats = crate::util::bench_loop(warmup, iters, f);
    Row {
        label: label.to_string(),
        stats,
        bytes: None,
        note: String::new(),
    }
}

/// Print a paper-reference block so bench output is self-describing.
pub fn paper_reference(lines: &[&str]) {
    println!("  paper reference:");
    for l in lines {
        println!("    | {l}");
    }
}

/// Quick single-shot timing (for expensive one-off steps like SVD).
pub fn time_once<T>(label: &str, f: impl FnOnce() -> T) -> T {
    let t = Timer::start();
    let out = f();
    println!("  {label}: {}", human_secs(t.elapsed_secs()));
    out
}

/// Standard iteration counts, overridable via FLASHBIAS_BENCH_ITERS for
/// quick smoke runs.
pub fn iters(default: usize) -> usize {
    std::env::var("FLASHBIAS_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

// ---------------------------------------------------------------------------
// CI perf-regression gate (`make bench-check` / the bench_check bin)
//
// The gated quantity is a *ratio*: each single-thread engine row's mean
// divided by the same-n single-thread dense oracle's mean, both from
// one BENCH_kernels.json run. Ratios cancel the host's absolute speed,
// so one checked-in baseline (BENCH_kernels.baseline.json) gates every
// machine; only the relative cost of the microkernel paths is pinned.
// ---------------------------------------------------------------------------

/// Default regression slack: a gated ratio may drift up to 15% above
/// its checked-in baseline before the gate fails.
pub const GATE_SLACK: f64 = 0.15;

/// One gated quantity: a `…-1t n<N>` row's mean over the
/// `reference-dense n<N>` mean (< 1.0 ⇒ faster than the dense oracle).
#[derive(Clone, Debug, PartialEq)]
pub struct SpeedRatio {
    pub label: String,
    pub ratio: f64,
}

/// Verdict for one baseline entry after comparing against a fresh run.
#[derive(Clone, Debug)]
pub struct GateOutcome {
    pub label: String,
    pub baseline: f64,
    pub current: f64,
    /// `current ≤ baseline · (1 + slack)`.
    pub ok: bool,
}

/// Extract the gated ratios from a `Table::to_json` document: every
/// row whose label carries the single-thread marker `-1t ` is paired
/// with the `reference-dense n<N>` row of the same `n<N>` suffix.
pub fn speed_ratios(table: &Json) -> Result<Vec<SpeedRatio>, String> {
    let rows = table
        .get("rows")
        .as_arr()
        .ok_or("bench json has no `rows` array")?;
    let mut means: Vec<(String, f64)> = Vec::new();
    for r in rows {
        let label = r
            .get("label")
            .as_str()
            .ok_or("bench row without a `label`")?;
        let mean = r
            .get("mean")
            .as_f64()
            .ok_or_else(|| format!("row `{label}` has no `mean`"))?;
        means.push((label.to_string(), mean));
    }
    let mean_of = |l: &str| {
        means.iter().find(|(ml, _)| ml == l).map(|&(_, m)| m)
    };
    let mut out = Vec::new();
    for (label, mean) in &means {
        let Some(pos) = label.find("-1t ") else { continue };
        let suffix = &label[pos + 4..]; // "n512", "n2048", …
        let reference = format!("reference-dense {suffix}");
        let ref_mean = mean_of(&reference).ok_or_else(|| {
            format!("row `{label}` has no `{reference}` to normalize by")
        })?;
        if !(ref_mean > 0.0) || !mean.is_finite() {
            return Err(format!(
                "degenerate means for `{label}`: {mean} / {ref_mean}"
            ));
        }
        out.push(SpeedRatio {
            label: label.clone(),
            ratio: mean / ref_mean,
        });
    }
    if out.is_empty() {
        return Err("no single-thread (`-1t`) rows to gate".into());
    }
    Ok(out)
}

/// Serialize a baseline document: `{title, slack, ratios: [{label,
/// ratio}]}`.
pub fn ratios_to_json(title: &str, slack: f64,
                      ratios: &[SpeedRatio]) -> Json {
    let rows = ratios
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("label", Json::str(&r.label)),
                ("ratio", Json::num(r.ratio)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("title", Json::str(title)),
        ("slack", Json::num(slack)),
        ("ratios", Json::Arr(rows)),
    ])
}

/// Parse a baseline document; returns `(slack, ratios)`.
pub fn ratios_from_json(doc: &Json)
                        -> Result<(f64, Vec<SpeedRatio>), String> {
    let slack = doc.get("slack").as_f64().unwrap_or(GATE_SLACK);
    let rows = doc
        .get("ratios")
        .as_arr()
        .ok_or("baseline json has no `ratios` array")?;
    let mut out = Vec::new();
    for r in rows {
        let label = r
            .get("label")
            .as_str()
            .ok_or("baseline entry without a `label`")?;
        let ratio = r
            .get("ratio")
            .as_f64()
            .ok_or_else(|| format!("baseline `{label}` has no ratio"))?;
        out.push(SpeedRatio { label: label.to_string(), ratio });
    }
    if out.is_empty() {
        return Err("baseline has no gated entries".into());
    }
    Ok((slack, out))
}

/// Compare a fresh run's ratios against the baseline. Every baseline
/// entry must be present in the run (a silently dropped bench row must
/// fail the gate, not pass it); extra rows in the run are ignored so
/// new benches can land before their baseline does.
pub fn gate(current: &[SpeedRatio], baseline: &[SpeedRatio],
            slack: f64) -> Result<Vec<GateOutcome>, String> {
    let mut out = Vec::new();
    for b in baseline {
        let cur = current
            .iter()
            .find(|c| c.label == b.label)
            .ok_or_else(|| {
                format!("gated row `{}` missing from this run", b.label)
            })?;
        out.push(GateOutcome {
            label: b.label.clone(),
            baseline: b.ratio,
            current: cur.ratio,
            ok: cur.ratio <= b.ratio * (1.0 + slack),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_delta() {
        let mut t = Table::new("test");
        let mut s1 = Stats::new();
        s1.push(1.0);
        let mut s2 = Stats::new();
        s2.push(3.0);
        t.row(Row {
            label: "base".into(),
            stats: s1,
            bytes: None,
            note: String::new(),
        });
        t.row(Row {
            label: "x".into(),
            stats: s2,
            bytes: Some(1024),
            note: "n".into(),
        });
        assert_eq!(t.delta("x", "base"), Some(2.0));
        assert_eq!(t.delta("missing", "base"), None);
        assert_eq!(t.rows().len(), 2);
        assert_eq!(t.title(), "test");
    }

    #[test]
    fn iters_env_override() {
        assert_eq!(iters(7), 7);
    }

    fn bench_doc(rows: &[(&str, f64)]) -> Json {
        Json::obj(vec![
            ("title", Json::str("t")),
            (
                "rows",
                Json::Arr(
                    rows.iter()
                        .map(|(l, m)| {
                            Json::obj(vec![
                                ("label", Json::str(l)),
                                ("mean", Json::num(*m)),
                                ("p50", Json::num(*m)),
                                ("bytes", Json::Null),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    #[test]
    fn speed_ratios_normalize_by_the_same_n_reference() {
        let doc = bench_doc(&[
            ("reference-dense n512", 2.0),
            ("tiled-dense n512", 0.9),       // multi-thread: not gated
            ("tiled-factored-1t n512", 1.0),
            ("reference-dense n2048", 10.0),
            ("tiled-factored-1t n2048", 4.0),
        ]);
        let r = speed_ratios(&doc).expect("ratios");
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].label, "tiled-factored-1t n512");
        assert_eq!(r[0].ratio, 0.5);
        assert_eq!(r[1].ratio, 0.4);
        // a -1t row without its oracle is an error, not a silent skip
        let orphan = bench_doc(&[("tiled-jit-1t n999", 1.0)]);
        assert!(speed_ratios(&orphan).is_err());
        // and a run with nothing to gate is an error too
        let empty = bench_doc(&[("reference-dense n512", 1.0)]);
        assert!(speed_ratios(&empty).is_err());
    }

    #[test]
    fn gate_fails_only_beyond_slack_and_on_missing_rows() {
        let base = vec![SpeedRatio { label: "a-1t n1".into(), ratio: 1.0 }];
        let run = |r: f64| {
            vec![SpeedRatio { label: "a-1t n1".into(), ratio: r }]
        };
        // 10% slower than baseline: inside the 15% slack
        let out = gate(&run(1.10), &base, GATE_SLACK).expect("gate");
        assert!(out[0].ok);
        // 20% slower: regression
        let out = gate(&run(1.20), &base, GATE_SLACK).expect("gate");
        assert!(!out[0].ok);
        // faster than baseline always passes
        assert!(gate(&run(0.5), &base, GATE_SLACK).unwrap()[0].ok);
        // a baseline row the run no longer produces must hard-fail
        assert!(gate(&[], &base, GATE_SLACK).is_err());
        // extra rows in the run are fine (bench landed before baseline)
        let mut cur = run(1.0);
        cur.push(SpeedRatio { label: "new-1t n2".into(), ratio: 9.0 });
        assert_eq!(gate(&cur, &base, GATE_SLACK).unwrap().len(), 1);
    }

    #[test]
    fn baseline_document_round_trips() {
        let ratios = vec![
            SpeedRatio { label: "tiled-factored-1t n2048".into(),
                         ratio: 0.55 },
            SpeedRatio { label: "tiled-jit-1t n2048".into(),
                         ratio: 0.6 },
        ];
        let doc = ratios_to_json("kernels", 0.15, &ratios);
        let text = doc.dump();
        let parsed = crate::jsonlite::Json::parse(&text).expect("parse");
        let (slack, back) = ratios_from_json(&parsed).expect("decode");
        assert_eq!(slack, 0.15);
        assert_eq!(back, ratios);
        // slack defaults when the field is absent
        let bare = Json::obj(vec![(
            "ratios",
            doc.get("ratios").clone(),
        )]);
        let (slack, _) = ratios_from_json(&bare).expect("decode");
        assert_eq!(slack, GATE_SLACK);
    }

    #[test]
    fn json_roundtrip_and_file_dump() {
        let mut t = Table::new("kernels-test");
        let mut s = Stats::new();
        s.push(0.25);
        s.push(0.75);
        t.row(Row {
            label: "tiled".into(),
            stats: s,
            bytes: Some(2048),
            note: String::new(),
        });
        let j = t.to_json();
        assert_eq!(j.get("title").as_str(), Some("kernels-test"));
        let rows = j.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("label").as_str(), Some("tiled"));
        assert_eq!(rows[0].get("mean").as_f64(), Some(0.5));
        assert_eq!(rows[0].get("bytes").as_f64(), Some(2048.0));
        // dump → parse roundtrip through a real file
        let path = t
            .write_json_to(std::env::temp_dir(), "kernels_test")
            .expect("write");
        let text = std::fs::read_to_string(&path).expect("read back");
        let parsed = crate::jsonlite::Json::parse(&text).expect("parse");
        assert_eq!(parsed.get("title").as_str(), Some("kernels-test"));
        let _ = std::fs::remove_file(path);
    }
}
