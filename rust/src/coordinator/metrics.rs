//! Serving metrics: counters + latency distributions for each pipeline
//! stage, safe to share across worker threads. When a
//! [`FactorStore`] is attached (every coordinator does this), its
//! tier counters — hits, misses, evictions, spill hits, remote hits —
//! ride along in [`Metrics::summary`] and [`Metrics::to_json`], so
//! plan-time amortization (and which tier supplied it) is observable
//! next to the latency distributions it buys.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::factorstore::FactorStore;
use crate::util::sync::Mutex;
use crate::util::Stats;

/// Why the serving front-end's batching thread flushed the batcher —
/// the policy observable the load harness tunes against. Lives here
/// (not in `server`) because `Metrics` owns the per-reason counters
/// and `server` depends on `coordinator`, never the reverse.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    /// Pending work hit the `max_batch_total_tokens` budget.
    Tokens = 0,
    /// Waiting/served ratio crossed — enough queued work relative to
    /// in-flight work to justify interrupting the served batch cadence.
    Ratio = 1,
    /// Oldest waiting request aged past the deadline.
    Deadline = 2,
    /// Shutdown/idle drain of whatever was pending.
    Drain = 3,
}

impl FlushReason {
    pub const ALL: [FlushReason; 4] = [
        FlushReason::Tokens,
        FlushReason::Ratio,
        FlushReason::Deadline,
        FlushReason::Drain,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FlushReason::Tokens => "tokens",
            FlushReason::Ratio => "ratio",
            FlushReason::Deadline => "deadline",
            FlushReason::Drain => "drain",
        }
    }
}

#[derive(Debug)]
pub struct Metrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    batch_sizes: Mutex<Stats>,
    queue_secs: Mutex<Stats>,
    exec_secs: Mutex<Stats>,
    store: Mutex<Option<Arc<FactorStore>>>,
    // network front-end admission + flush-policy observables; zero
    // everywhere until a netserver records into them
    net_wait_secs: Mutex<Stats>,
    net_depth: Mutex<Stats>,
    net_rejected: AtomicU64,
    flush_reasons: [AtomicU64; 4],
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_sizes: Mutex::new("metrics.batch_sizes", Stats::default()),
            queue_secs: Mutex::new("metrics.queue_secs", Stats::default()),
            exec_secs: Mutex::new("metrics.exec_secs", Stats::default()),
            store: Mutex::new("metrics.store", None),
            net_wait_secs: Mutex::new("metrics.net_wait_secs",
                                      Stats::default()),
            net_depth: Mutex::new("metrics.net_depth", Stats::default()),
            net_rejected: AtomicU64::new(0),
            flush_reasons: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Surface `store`'s counters in summaries and JSON dumps.
    pub fn attach_store(&self, store: Arc<FactorStore>) {
        *self.store.lock_recover() = Some(store);
    }

    /// Snapshot of the attached store's counters, if any. Holds
    /// `metrics.store` across the store's own counter reads — the one
    /// legitimate cross-module lock-order edge the audit records
    /// (`metrics.store` → `factorstore.inner`).
    pub fn store_stats(&self) -> Option<crate::factorstore::StoreStats> {
        self.store.lock_recover().as_ref().map(|s| s.stats())
    }

    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_sizes.lock_recover().push(size as f64);
    }

    pub fn on_complete(&self, queue: Duration, exec: Duration, ok: bool) {
        if ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        self.queue_secs.lock_recover().push(queue.as_secs_f64());
        self.exec_secs.lock_recover().push(exec.as_secs_f64());
    }

    /// A network request cleared admission and reached the dispatch
    /// thread after `wait` in the admission queue, which then held
    /// `depth` requests (a queue-depth sample at dequeue time).
    pub fn on_net_admit(&self, wait: Duration, depth: usize) {
        self.net_wait_secs.lock_recover().push(wait.as_secs_f64());
        self.net_depth.lock_recover().push(depth as f64);
    }

    /// A network request was refused at admission (queue full or
    /// session cap).
    pub fn on_net_rejected(&self) {
        self.net_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// The batching thread flushed pending work for `reason`.
    pub fn on_flush(&self, reason: FlushReason) {
        self.flush_reasons[reason as usize]
            .fetch_add(1, Ordering::Relaxed);
    }

    pub fn net_wait_stats(&self) -> Stats {
        self.net_wait_secs.lock_recover().clone()
    }

    pub fn net_depth_stats(&self) -> Stats {
        self.net_depth.lock_recover().clone()
    }

    pub fn net_rejected(&self) -> u64 {
        self.net_rejected.load(Ordering::Relaxed)
    }

    pub fn flush_count(&self, reason: FlushReason) -> u64 {
        self.flush_reasons[reason as usize].load(Ordering::Relaxed)
    }

    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn mean_batch_size(&self) -> f64 {
        self.batch_sizes.lock_recover().mean()
    }

    pub fn queue_stats(&self) -> Stats {
        self.queue_secs.lock_recover().clone()
    }

    pub fn exec_stats(&self) -> Stats {
        self.exec_secs.lock_recover().clone()
    }

    /// One-line human summary (two lines once a store is attached).
    pub fn summary(&self) -> String {
        let q = self.queue_stats();
        let e = self.exec_stats();
        let mut out = format!(
            "submitted={} completed={} failed={} batches={} \
             mean_batch={:.2} queue_p50={} exec_p50={} exec_p99={}",
            self.submitted(),
            self.completed(),
            self.failed(),
            self.batches(),
            self.mean_batch_size(),
            crate::util::human_secs(q.p50()),
            crate::util::human_secs(e.p50()),
            crate::util::human_secs(e.p99()),
        );
        if let Some(s) = self.store_stats() {
            out.push('\n');
            out.push_str(&s.summary());
        }
        let w = self.net_wait_stats();
        if !w.is_empty() || self.net_rejected() > 0 {
            let d = self.net_depth_stats();
            out.push('\n');
            out.push_str(&format!(
                "net: admitted={} rejected={} wait_p50={} wait_p99={} \
                 depth_mean={:.1}",
                w.len(),
                self.net_rejected(),
                crate::util::human_secs(w.p50()),
                crate::util::human_secs(w.p99()),
                d.mean(),
            ));
            for r in FlushReason::ALL {
                out.push_str(&format!(" flush_{}={}",
                                      r.name(),
                                      self.flush_count(r)));
            }
        }
        out
    }

    /// Metrics as JSON (for the CLI's --metrics-out).
    pub fn to_json(&self) -> crate::jsonlite::Json {
        use crate::jsonlite::Json;
        let q = self.queue_stats();
        let e = self.exec_stats();
        Json::obj(vec![
            ("submitted", Json::num(self.submitted() as f64)),
            ("completed", Json::num(self.completed() as f64)),
            ("failed", Json::num(self.failed() as f64)),
            ("batches", Json::num(self.batches() as f64)),
            ("mean_batch_size", Json::num(self.mean_batch_size())),
            ("queue_p50_s", Json::num(q.p50())),
            ("queue_p99_s", Json::num(q.p99())),
            ("exec_p50_s", Json::num(e.p50())),
            ("exec_p99_s", Json::num(e.p99())),
            (
                "store",
                self.store_stats()
                    .map(|s| s.to_json())
                    .unwrap_or(Json::Null),
            ),
            ("net", self.net_json()),
        ])
    }

    /// Network-admission and flush-policy counters as JSON (the "net"
    /// section of [`Self::to_json`]).
    fn net_json(&self) -> crate::jsonlite::Json {
        use crate::jsonlite::Json;
        let w = self.net_wait_stats();
        let d = self.net_depth_stats();
        Json::obj(vec![
            ("admitted", Json::num(w.len() as f64)),
            ("rejected", Json::num(self.net_rejected() as f64)),
            ("wait_p50_s", Json::num(w.p50())),
            ("wait_p99_s", Json::num(w.p99())),
            ("depth_mean", Json::num(d.mean())),
            ("depth_max", Json::num(d.max())),
            (
                "flush_reasons",
                Json::obj(
                    FlushReason::ALL
                        .iter()
                        .map(|&r| {
                            (r.name(),
                             Json::num(self.flush_count(r) as f64))
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_track() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_batch(2);
        m.on_complete(Duration::from_millis(1), Duration::from_millis(2),
                      true);
        m.on_complete(Duration::from_millis(3), Duration::from_millis(4),
                      false);
        assert_eq!(m.submitted(), 2);
        assert_eq!(m.completed(), 1);
        assert_eq!(m.failed(), 1);
        assert_eq!(m.batches(), 1);
        assert_eq!(m.mean_batch_size(), 2.0);
        assert!(m.exec_stats().mean() > 0.0);
    }

    #[test]
    fn json_and_summary_render() {
        let m = Metrics::new();
        m.on_submit();
        m.on_batch(1);
        m.on_complete(Duration::from_millis(1), Duration::from_millis(1),
                      true);
        let j = m.to_json();
        assert_eq!(j.get("submitted").as_usize(), Some(1));
        assert!(m.summary().contains("completed=1"));
    }

    #[test]
    fn attached_store_counters_surface() {
        use crate::factorstore::{Cached, Fingerprint};
        use std::sync::Arc;
        let m = Metrics::new();
        assert!(m.store_stats().is_none());
        assert!(m.to_json().get("store").is_null());
        let store = Arc::new(FactorStore::unbounded());
        m.attach_store(store.clone());
        store.get_or_insert_with(Fingerprint(1), || Cached::Rejected {
            measured_rank: 9,
        });
        store.get_or_insert_with(Fingerprint(1), || Cached::Rejected {
            measured_rank: 9,
        });
        let s = m.store_stats().expect("attached");
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!(m.summary().contains("store: hits=1"));
        assert!(m.summary().contains("spill_hits=0"));
        let j = m.to_json();
        assert_eq!(j.get("store").get("hits").as_usize(), Some(1));
        // the tier counters ride along for dashboards
        assert_eq!(j.get("store").get("spill_hits").as_usize(), Some(0));
        assert_eq!(j.get("store").get("remote_hits").as_usize(),
                   Some(0));
        assert_eq!(j.get("store").get("spilled").as_usize(), Some(0));
    }

    #[test]
    fn net_counters_surface_in_summary_and_json() {
        let m = Metrics::new();
        // quiet metrics carry an (all-zero) net section in JSON but no
        // net line in the human summary
        assert!(!m.summary().contains("net:"));
        assert_eq!(m.to_json().get("net").get("admitted").as_usize(),
                   Some(0));
        m.on_net_admit(Duration::from_millis(5), 3);
        m.on_net_admit(Duration::from_millis(15), 7);
        m.on_net_rejected();
        m.on_flush(FlushReason::Tokens);
        m.on_flush(FlushReason::Deadline);
        m.on_flush(FlushReason::Deadline);
        assert_eq!(m.net_rejected(), 1);
        assert_eq!(m.flush_count(FlushReason::Deadline), 2);
        assert_eq!(m.flush_count(FlushReason::Ratio), 0);
        let s = m.summary();
        assert!(s.contains("net: admitted=2 rejected=1"), "{s}");
        assert!(s.contains("flush_deadline=2"), "{s}");
        let net = m.to_json().get("net").clone();
        assert_eq!(net.get("admitted").as_usize(), Some(2));
        assert_eq!(net.get("rejected").as_usize(), Some(1));
        assert_eq!(net.get("depth_max").as_usize(), Some(7));
        assert_eq!(
            net.get("flush_reasons").get("deadline").as_usize(),
            Some(2)
        );
        assert!(net.get("wait_p99_s").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.on_submit();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.submitted(), 400);
    }
}
