//! Serving metrics: counters + latency distributions for each pipeline
//! stage, safe to share across worker threads. When a
//! [`FactorStore`] is attached (every coordinator does this), its
//! tier counters — hits, misses, evictions, spill hits, remote hits —
//! ride along in [`Metrics::summary`] and [`Metrics::to_json`], so
//! plan-time amortization (and which tier supplied it) is observable
//! next to the latency distributions it buys.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::factorstore::FactorStore;
use crate::util::sync::Mutex;
use crate::util::Stats;

#[derive(Debug)]
pub struct Metrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    batch_sizes: Mutex<Stats>,
    queue_secs: Mutex<Stats>,
    exec_secs: Mutex<Stats>,
    store: Mutex<Option<Arc<FactorStore>>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_sizes: Mutex::new("metrics.batch_sizes", Stats::default()),
            queue_secs: Mutex::new("metrics.queue_secs", Stats::default()),
            exec_secs: Mutex::new("metrics.exec_secs", Stats::default()),
            store: Mutex::new("metrics.store", None),
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Surface `store`'s counters in summaries and JSON dumps.
    pub fn attach_store(&self, store: Arc<FactorStore>) {
        *self.store.lock_recover() = Some(store);
    }

    /// Snapshot of the attached store's counters, if any. Holds
    /// `metrics.store` across the store's own counter reads — the one
    /// legitimate cross-module lock-order edge the audit records
    /// (`metrics.store` → `factorstore.inner`).
    pub fn store_stats(&self) -> Option<crate::factorstore::StoreStats> {
        self.store.lock_recover().as_ref().map(|s| s.stats())
    }

    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_sizes.lock_recover().push(size as f64);
    }

    pub fn on_complete(&self, queue: Duration, exec: Duration, ok: bool) {
        if ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        self.queue_secs.lock_recover().push(queue.as_secs_f64());
        self.exec_secs.lock_recover().push(exec.as_secs_f64());
    }

    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn mean_batch_size(&self) -> f64 {
        self.batch_sizes.lock_recover().mean()
    }

    pub fn queue_stats(&self) -> Stats {
        self.queue_secs.lock_recover().clone()
    }

    pub fn exec_stats(&self) -> Stats {
        self.exec_secs.lock_recover().clone()
    }

    /// One-line human summary (two lines once a store is attached).
    pub fn summary(&self) -> String {
        let q = self.queue_stats();
        let e = self.exec_stats();
        let mut out = format!(
            "submitted={} completed={} failed={} batches={} \
             mean_batch={:.2} queue_p50={} exec_p50={} exec_p99={}",
            self.submitted(),
            self.completed(),
            self.failed(),
            self.batches(),
            self.mean_batch_size(),
            crate::util::human_secs(q.p50()),
            crate::util::human_secs(e.p50()),
            crate::util::human_secs(e.p99()),
        );
        if let Some(s) = self.store_stats() {
            out.push('\n');
            out.push_str(&s.summary());
        }
        out
    }

    /// Metrics as JSON (for the CLI's --metrics-out).
    pub fn to_json(&self) -> crate::jsonlite::Json {
        use crate::jsonlite::Json;
        let q = self.queue_stats();
        let e = self.exec_stats();
        Json::obj(vec![
            ("submitted", Json::num(self.submitted() as f64)),
            ("completed", Json::num(self.completed() as f64)),
            ("failed", Json::num(self.failed() as f64)),
            ("batches", Json::num(self.batches() as f64)),
            ("mean_batch_size", Json::num(self.mean_batch_size())),
            ("queue_p50_s", Json::num(q.p50())),
            ("queue_p99_s", Json::num(q.p99())),
            ("exec_p50_s", Json::num(e.p50())),
            ("exec_p99_s", Json::num(e.p99())),
            (
                "store",
                self.store_stats()
                    .map(|s| s.to_json())
                    .unwrap_or(Json::Null),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_track() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_batch(2);
        m.on_complete(Duration::from_millis(1), Duration::from_millis(2),
                      true);
        m.on_complete(Duration::from_millis(3), Duration::from_millis(4),
                      false);
        assert_eq!(m.submitted(), 2);
        assert_eq!(m.completed(), 1);
        assert_eq!(m.failed(), 1);
        assert_eq!(m.batches(), 1);
        assert_eq!(m.mean_batch_size(), 2.0);
        assert!(m.exec_stats().mean() > 0.0);
    }

    #[test]
    fn json_and_summary_render() {
        let m = Metrics::new();
        m.on_submit();
        m.on_batch(1);
        m.on_complete(Duration::from_millis(1), Duration::from_millis(1),
                      true);
        let j = m.to_json();
        assert_eq!(j.get("submitted").as_usize(), Some(1));
        assert!(m.summary().contains("completed=1"));
    }

    #[test]
    fn attached_store_counters_surface() {
        use crate::factorstore::{Cached, Fingerprint};
        use std::sync::Arc;
        let m = Metrics::new();
        assert!(m.store_stats().is_none());
        assert!(m.to_json().get("store").is_null());
        let store = Arc::new(FactorStore::unbounded());
        m.attach_store(store.clone());
        store.get_or_insert_with(Fingerprint(1), || Cached::Rejected {
            measured_rank: 9,
        });
        store.get_or_insert_with(Fingerprint(1), || Cached::Rejected {
            measured_rank: 9,
        });
        let s = m.store_stats().expect("attached");
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!(m.summary().contains("store: hits=1"));
        assert!(m.summary().contains("spill_hits=0"));
        let j = m.to_json();
        assert_eq!(j.get("store").get("hits").as_usize(), Some(1));
        // the tier counters ride along for dashboards
        assert_eq!(j.get("store").get("spill_hits").as_usize(), Some(0));
        assert_eq!(j.get("store").get("remote_hits").as_usize(),
                   Some(0));
        assert_eq!(j.get("store").get("spilled").as_usize(), Some(0));
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.on_submit();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.submitted(), 400);
    }
}
