//! L3 coordinator: the serving layer in front of the PJRT runtime.
//!
//! FlashBias itself is a kernel-layer contribution, so the coordinator is
//! the thin-but-real serving runtime a deployment needs around it:
//!
//! * [`router`] — shape-bucket routing: a request for sequence length N is
//!   routed to the smallest compiled artifact bucket ≥ N (with padding),
//!   per (family, variant).
//! * [`batcher`] — dynamic batching: requests accumulate per bucket and
//!   flush on max-batch or deadline, amortizing dispatch overhead.
//! * [`worker`] — a thread pool executing flushed batches: PJRT for
//!   compiled artifacts, or **one batched `(B, H, N, C)` kernel-engine
//!   call** for plans in the [`HostPlanRegistry`]; bounded queues give
//!   backpressure.
//! * [`metrics`] — latency/throughput counters for every stage,
//!   including the shared factor store's tier counters (hits, misses,
//!   evictions, spill hits, remote hits).
//! * [`session`] — the prefill/decode split: [`Coordinator::open_session`]
//!   registers a [`SessionHandle`] (KV cache + softmax carry behind a
//!   named lock); [`Coordinator::prefill`] seeds it through the ordinary
//!   batched engine path, and each [`Coordinator::step`] appends the new
//!   K/V row at submit and enqueues a 1×M decode request. Decode steps
//!   and prefills for the same plan share a batcher bucket, so one flush
//!   carries a **mixed** batch (continuous batching); the workers run
//!   all decode steps of a flush as a single
//!   [`crate::kernels::decode_steps`] call.
//!
//! Decomposition-strategy selection is the [`crate::plan::Planner`]
//! (re-exported here as [`StrategySelector`] for the serving layer);
//! every coordinator owns a [`FactorStore`] shared across its serving
//! loop, so [`Coordinator::plan_and_register`] amortizes SVD/neural
//! decomposition across repeated plans and worker threads. The store
//! can be tiered: a byte budget spills evictions to disk, and
//! [`Coordinator::serve_store`] exports it over TCP so a fleet of
//! coordinators warms from one decomposition.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod session;
pub mod worker;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::factorstore::{FactorService, FactorStore};
use crate::iomodel::Geometry;
use crate::plan::{
    AttentionPlan, BiasSpec, PlanOptions, Planner, SessionError,
    SessionState,
};
use crate::runtime::{HostValue, Runtime};
use crate::tensor::Tensor;
use crate::util::sync::RwLock;

pub use batcher::{Batch, BatcherConfig, DynamicBatcher};
pub use metrics::{FlushReason, Metrics};
pub use router::{RouteKey, Router};
pub use session::SessionHandle;
pub use worker::DispatchError;
// the serving-layer aliases for the Table 1 policy object (the old
// `selector` module shim, folded in here)
pub use crate::plan::{Planner as StrategySelector, SelectorConfig};

/// Registry of attention plans served directly on the host kernel
/// engine — no PJRT artifact needed. Plan names share the artifact
/// namespace; a flushed batch whose name resolves here is stacked into
/// one batched `(B, H, N, C)` engine call by the worker pool.
pub struct HostPlanRegistry {
    plans: RwLock<HashMap<String, Arc<AttentionPlan>>>,
}

impl Default for HostPlanRegistry {
    fn default() -> Self {
        Self {
            plans: RwLock::new("coordinator.host_plans", HashMap::new()),
        }
    }
}

impl HostPlanRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&self, name: &str, plan: AttentionPlan) {
        self.plans
            .write_recover()
            .insert(name.to_string(), Arc::new(plan));
    }

    pub fn get(&self, name: &str) -> Option<Arc<AttentionPlan>> {
        self.plans.read_recover().get(name).cloned()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.plans.read_recover().contains_key(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.plans.read_recover().keys().cloned().collect()
    }
}

/// Why [`Coordinator::try_submit`] refused a request. Only
/// [`SubmitError::Backpressure`] is retryable — drain a response and
/// resubmit; anything else must be propagated, not spun on.
#[derive(Debug)]
pub enum SubmitError {
    /// Not in the PJRT manifest or the host-plan registry.
    UnknownArtifact(String),
    /// The dispatch queue is full; the request was NOT accepted (no
    /// request is ever silently dropped) and its `inputs` ride back so
    /// the caller retries by moving them, not by pre-cloning every
    /// submit on the hot path. Drain a response, retry.
    Backpressure { inputs: Vec<HostValue> },
    /// The worker pool has stopped.
    Stopped,
}

impl SubmitError {
    /// The one refusal worth retrying.
    pub fn is_backpressure(&self) -> bool {
        matches!(self, SubmitError::Backpressure { .. })
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::UnknownArtifact(name) => {
                write!(f, "unknown artifact {name}")
            }
            SubmitError::Backpressure { .. } => {
                write!(f, "dispatch queue full (backpressure)")
            }
            SubmitError::Stopped => write!(f, "worker pool stopped"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Why a session-API call ([`Coordinator::open_session`] /
/// [`Coordinator::prefill`] / [`Coordinator::step`]) was refused.
#[derive(Debug)]
pub enum SessionApiError {
    /// `open_session` names no registered host plan (sessions decode on
    /// the kernel engine; PJRT artifacts have no cache-aware path).
    UnknownPlan(String),
    /// No open session with this id.
    UnknownSession(u64),
    /// The session state machine refused (wrong shape, exhausted
    /// context, double prefill, decode-incapable plan…).
    State(SessionError),
    /// The worker pool has stopped.
    Stopped,
}

impl From<SessionError> for SessionApiError {
    fn from(e: SessionError) -> Self {
        SessionApiError::State(e)
    }
}

impl std::fmt::Display for SessionApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionApiError::UnknownPlan(name) => {
                write!(f, "no host plan named {name}")
            }
            SessionApiError::UnknownSession(id) => {
                write!(f, "no open session {id}")
            }
            SessionApiError::State(e) => write!(f, "session state: {e}"),
            SessionApiError::Stopped => write!(f, "worker pool stopped"),
        }
    }
}

impl std::error::Error for SessionApiError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SessionApiError::State(e) => Some(e),
            _ => None,
        }
    }
}

/// How a request's payload executes on the worker pool.
#[derive(Debug)]
pub enum RequestKind {
    /// One-shot attention or a session prefill: inputs are `[q, k, v]`
    /// tensors, stacked into one batched engine call per flush.
    Prefill,
    /// One decode position of a live session: inputs are `[q_row]`
    /// (shape `(C,)`); the cached K/V, bias provider and softmax carry
    /// live behind the ticket's session handle. All decode steps in a
    /// flushed batch run as **one** [`crate::kernels::decode_steps`]
    /// call.
    Decode(DecodeTicket),
}

/// Admission snapshot for one decode step, minted at submit time by
/// [`SessionState::begin_step`] under the session's write lock: by
/// construction cache rows `[0, m)` are already appended and immutable,
/// so a worker can execute the step from a read lock at any later time,
/// in any batch, and produce bit-identical output.
#[derive(Debug)]
pub struct DecodeTicket {
    pub session: Arc<SessionHandle>,
    /// Absolute query position of this step.
    pub i: usize,
    /// Cache length this step attends (keys `[0, m)`).
    pub m: usize,
}

/// A unit of work: run `artifact` on `inputs`.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub artifact: String,
    pub inputs: Vec<HostValue>,
    pub enqueued: Instant,
    pub kind: RequestKind,
}

/// Execution result for one request.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub artifact: String,
    pub outputs: Result<Vec<HostValue>>,
    /// Time from submit to flush (batching wait).
    pub queue_time: Duration,
    /// Pure execute time.
    pub exec_time: Duration,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    pub workers: usize,
    /// Bounded depth of the dispatch queue (backpressure).
    pub queue_depth: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            workers: 2,
            queue_depth: 64,
        }
    }
}

/// The assembled serving stack.
pub struct Coordinator {
    runtime: Arc<Runtime>,
    host_plans: Arc<HostPlanRegistry>,
    store: Arc<FactorStore>,
    batcher: DynamicBatcher,
    pool: worker::WorkerPool,
    responses: Receiver<Response>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    /// Open decode sessions; in-flight requests hold their own `Arc`,
    /// so closing a session never invalidates queued work.
    sessions: HashMap<u64, Arc<SessionHandle>>,
    next_session: u64,
}

impl Coordinator {
    /// Coordinator with a private, unbounded [`FactorStore`]. Use
    /// [`Self::with_store`] to share a (possibly disk-warmed, byte-
    /// budgeted) store across coordinators or with the CLI.
    pub fn new(runtime: Arc<Runtime>, config: CoordinatorConfig) -> Self {
        Self::with_store(runtime, config,
                         Arc::new(FactorStore::unbounded()))
    }

    /// Coordinator sharing `store` for every decomposition in its
    /// serving loop; the store's counters surface through
    /// [`Metrics::summary`] / [`Metrics::to_json`].
    pub fn with_store(runtime: Arc<Runtime>, config: CoordinatorConfig,
                      store: Arc<FactorStore>) -> Self {
        let metrics = Arc::new(Metrics::new());
        metrics.attach_store(store.clone());
        let host_plans = Arc::new(HostPlanRegistry::new());
        let (pool, responses) = worker::WorkerPool::spawn(
            runtime.clone(),
            host_plans.clone(),
            config.workers,
            config.queue_depth,
            metrics.clone(),
        );
        Self {
            runtime,
            host_plans,
            store,
            batcher: DynamicBatcher::new(config.batcher),
            pool,
            responses,
            metrics,
            next_id: AtomicU64::new(0),
            sessions: HashMap::new(),
            next_session: 0,
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Shared handle to the metrics sink. The network front-end hands
    /// this to its accept/connection threads so admission events
    /// (queue wait, rejections) are recorded off the dispatch thread —
    /// the `Coordinator` itself is not `Sync` and never leaves its
    /// thread.
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Requests admitted to the batcher but not yet flushed to the
    /// worker pool — the "waiting" half of a waiting/served flush
    /// policy.
    pub fn pending_len(&self) -> usize {
        self.batcher.pending_len()
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.runtime
    }

    /// The factor store shared across this coordinator's serving loop.
    pub fn store(&self) -> &Arc<FactorStore> {
        &self.store
    }

    /// Serve this coordinator's factor store to the fleet: peers that
    /// attach a [`crate::factorstore::RemoteStore`] pointed at the
    /// returned service's address plan shared biases with zero SVD
    /// work (`remote_hits` instead of `misses`). Bind `"host:0"` for
    /// an ephemeral port.
    pub fn serve_store(&self, addr: impl std::net::ToSocketAddrs)
                       -> Result<FactorService> {
        FactorService::serve(self.store.clone(), addr)
    }

    /// Plan `spec` through the shared factor store and register the
    /// result as a host plan under `name` — the serving-layer entry to
    /// amortized decomposition: repeated calls for the same bias
    /// content are store hits that share factor strips with every
    /// previously registered plan.
    pub fn plan_and_register(&self, name: &str, planner: &Planner,
                             spec: &BiasSpec, geo: &Geometry,
                             opts: &PlanOptions)
                             -> Result<AttentionPlan> {
        let plan = planner
            .plan_with_store(spec, geo, opts, &self.store)
            .map_err(|e| anyhow!("plan {name}: {e}"))?;
        self.register_plan(name, plan.clone())?;
        Ok(plan)
    }

    /// Register an [`AttentionPlan`] under an artifact-style name so
    /// requests for it are served on the host kernel engine — flushed
    /// batches run as a single batched engine call. Errors if the name
    /// would shadow a compiled PJRT artifact (the worker resolves host
    /// plans first).
    pub fn register_plan(&self, name: &str,
                         plan: AttentionPlan) -> Result<()> {
        if self.runtime.spec(name).is_some() {
            return Err(anyhow!(
                "{name} already names a compiled PJRT artifact; pick a \
                 distinct host-plan name"
            ));
        }
        self.host_plans.register(name, plan);
        Ok(())
    }

    pub fn host_plans(&self) -> &Arc<HostPlanRegistry> {
        &self.host_plans
    }

    // -----------------------------------------------------------------
    // Decode sessions (prefill/decode split)
    // -----------------------------------------------------------------

    /// Open a decode session against a registered host plan. Fails for
    /// unknown names and for plans without an additive 1×M strip form
    /// (multiplicative bias — `decode_capable == false`). Returns the
    /// session id used by [`Self::prefill`] / [`Self::step`] /
    /// [`Self::close_session`].
    pub fn open_session(&mut self, plan_name: &str)
                        -> Result<u64, SessionApiError> {
        let plan = self.host_plans.get(plan_name).ok_or_else(|| {
            SessionApiError::UnknownPlan(plan_name.to_string())
        })?;
        let state = SessionState::new(plan)?;
        let id = self.next_session;
        self.next_session += 1;
        self.sessions.insert(
            id,
            Arc::new(SessionHandle::new(id, plan_name.to_string(),
                                        state)),
        );
        Ok(id)
    }

    /// Handle of an open session (positions, carry, cache size are
    /// readable through it).
    pub fn session(&self, id: u64) -> Option<&Arc<SessionHandle>> {
        self.sessions.get(&id)
    }

    /// Number of currently open sessions.
    pub fn open_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Close a session: new steps for it are refused. The handle rides
    /// back (and any in-flight requests hold their own `Arc`), so
    /// queued work still completes.
    pub fn close_session(&mut self, id: u64)
                         -> Option<Arc<SessionHandle>> {
        self.sessions.remove(&id)
    }

    /// Seed a fresh session with its prompt. The K/V rows are appended
    /// to the session cache *now* (append-at-submit); the attention
    /// pass itself is enqueued as an ordinary `[q, k, v]` request that
    /// batches — and stacks — with one-shot traffic and other prefills.
    /// Returns the request id; the `(n_p, Cv)` output arrives as that
    /// id's [`Response`].
    pub fn prefill(&mut self, session: u64, q: Tensor, k: Tensor,
                   v: Tensor) -> Result<u64, SessionApiError> {
        let handle = Arc::clone(
            self.sessions
                .get(&session)
                .ok_or(SessionApiError::UnknownSession(session))?,
        );
        handle.write().begin_prefill(&q, &k, &v)?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            artifact: handle.artifact().to_string(),
            inputs: vec![
                HostValue::F32(q),
                HostValue::F32(k),
                HostValue::F32(v),
            ],
            enqueued: Instant::now(),
            kind: RequestKind::Prefill,
        };
        self.enqueue_session_request(req)?;
        Ok(id)
    }

    /// Submit one decode step: append the new K/V row under the session
    /// write lock, snapshot the `(i, m)` ticket, and enqueue the query
    /// row. Steps from many sessions (and prefills) accumulate in the
    /// same per-plan bucket and flush as one mixed batch; the workers
    /// execute every decode step of a flush as a single
    /// [`crate::kernels::decode_steps`] call. Returns the request id;
    /// the `(Cv,)` output row arrives as that id's [`Response`].
    pub fn step(&mut self, session: u64, q_row: &[f32], k_row: &[f32],
                v_row: &[f32]) -> Result<u64, SessionApiError> {
        let handle = Arc::clone(
            self.sessions
                .get(&session)
                .ok_or(SessionApiError::UnknownSession(session))?,
        );
        let c = handle.plan().geometry.c;
        if q_row.len() != c {
            return Err(SessionError::ShapeMismatch {
                what: "q row",
                got: q_row.len(),
                want: c,
            }
            .into());
        }
        let ticket = handle.write().begin_step(k_row, v_row)?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            artifact: handle.artifact().to_string(),
            inputs: vec![HostValue::F32(Tensor::new(&[c],
                                                    q_row.to_vec()))],
            enqueued: Instant::now(),
            kind: RequestKind::Decode(DecodeTicket {
                session: handle,
                i: ticket.i,
                m: ticket.m,
            }),
        };
        self.enqueue_session_request(req)?;
        Ok(id)
    }

    /// Enqueue a request whose session state transition already
    /// happened at submit. Unlike [`Self::try_submit`] there is no
    /// backpressure refusal — the append cannot be handed back — so a
    /// full dispatch queue blocks until the workers drain it.
    fn enqueue_session_request(&mut self, req: Request)
                               -> Result<(), SessionApiError> {
        if let Some(batch) = self.batcher.push(req) {
            self.pool
                // flashlint: allow(dispatch-blocking) append already happened, the request cannot be refused; blocking here IS the backpressure
                .dispatch_blocking(batch)
                .map_err(|_| SessionApiError::Stopped)?;
        }
        self.metrics.on_submit();
        Ok(())
    }

    /// Submit one request; may flush a batch to the workers. Returns
    /// the request id. [`anyhow`]-typed wrapper around
    /// [`Self::try_submit`] (the `Display` of a backpressure refusal
    /// contains `"backpressure"`).
    pub fn submit(&mut self, artifact: &str,
                  inputs: Vec<HostValue>) -> Result<u64> {
        self.try_submit(artifact, inputs).map_err(Into::into)
    }

    /// Submit one request with a typed refusal, so callers can tell
    /// retryable backpressure apart from fatal errors. On
    /// [`SubmitError::Backpressure`] the request is handed back whole:
    /// it is not queued, and any previously accepted requests in the
    /// refused batch are returned to the batcher — nothing is dropped.
    pub fn try_submit(&mut self, artifact: &str,
                      inputs: Vec<HostValue>)
                      -> Result<u64, SubmitError> {
        if self.runtime.spec(artifact).is_none()
            && !self.host_plans.contains(artifact)
        {
            return Err(SubmitError::UnknownArtifact(
                artifact.to_string(),
            ));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            artifact: artifact.to_string(),
            inputs,
            enqueued: Instant::now(),
            kind: RequestKind::Prefill,
        };
        if let Some(batch) = self.batcher.push(req) {
            match self.pool.dispatch(batch) {
                Ok(()) => {}
                Err(DispatchError::Backpressure(mut batch)) => {
                    // our request is the one that filled the batch —
                    // pop it (the caller owns the retry, and gets its
                    // inputs back) and requeue the previously accepted
                    // rest
                    let mine = batch.requests.pop();
                    debug_assert_eq!(
                        mine.as_ref().map(|r| r.id),
                        Some(id)
                    );
                    self.batcher.unflush(batch);
                    return Err(SubmitError::Backpressure {
                        inputs: mine
                            .map(|r| r.inputs)
                            .unwrap_or_default(),
                    });
                }
                Err(DispatchError::Stopped(_)) => {
                    return Err(SubmitError::Stopped);
                }
            }
        }
        self.metrics.on_submit();
        Ok(id)
    }

    /// Flush any batches whose deadline has passed (call periodically, or
    /// after the last submit of a burst). Blocks for queue space: these
    /// requests were already accepted, so they must reach the workers.
    pub fn flush_due(&mut self) -> Result<()> {
        for batch in self.batcher.flush_due(Instant::now()) {
            self.pool
                .dispatch_blocking(batch)
                .map_err(|_| anyhow!("worker pool stopped"))?;
        }
        Ok(())
    }

    /// Force-flush everything. Blocks for queue space (see
    /// [`Self::flush_due`]).
    pub fn flush_all(&mut self) -> Result<()> {
        for batch in self.batcher.flush_all() {
            self.pool
                // flashlint: allow(dispatch-blocking) flushed batches were already accepted; they must reach the workers
                .dispatch_blocking(batch)
                .map_err(|_| anyhow!("worker pool stopped"))?;
        }
        Ok(())
    }

    /// Receive the next response, waiting up to `timeout`.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        match self.responses.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    /// Submit with bounded backpressure retries — the one retry policy
    /// every serving caller shares. A refused submit drains one
    /// response for up to `drain_timeout` (handed to `drained`; the
    /// caller must account for it) and retries with the handed-back
    /// inputs (moved, never cloned); any non-backpressure error
    /// propagates immediately instead of spinning, and a wedged worker
    /// pool surfaces as an error after 1000 rounds.
    pub fn submit_with_retry(
        &mut self,
        artifact: &str,
        mut inputs: Vec<HostValue>,
        drain_timeout: Duration,
        mut drained: impl FnMut(Response),
    ) -> Result<u64> {
        const MAX_RETRIES: usize = 1000;
        for _ in 0..MAX_RETRIES {
            match self.try_submit(artifact, inputs) {
                Ok(id) => return Ok(id),
                Err(SubmitError::Backpressure { inputs: back }) => {
                    inputs = back;
                    if let Some(r) = self.recv_timeout(drain_timeout) {
                        drained(r);
                    }
                }
                Err(e) => return Err(e.into()),
            }
        }
        Err(anyhow!(
            "submit {artifact}: backpressure persisted after \
             {MAX_RETRIES} retries"
        ))
    }

    /// Convenience: submit a burst, flush, and collect all responses.
    /// Backpressure inside the burst is absorbed (bounded) by draining
    /// responses early and retrying, so a burst larger than the
    /// dispatch queue still completes.
    pub fn run_burst(&mut self, reqs: Vec<(String, Vec<HostValue>)>)
                     -> Result<Vec<Response>> {
        let n = reqs.len();
        let mut out = Vec::with_capacity(n);
        for (artifact, inputs) in reqs {
            self.submit_with_retry(
                &artifact,
                inputs,
                Duration::from_millis(20),
                |r| out.push(r),
            )?;
        }
        self.flush_all()?;
        let deadline = Instant::now() + Duration::from_secs(600);
        while out.len() < n {
            let remaining = deadline
                .checked_duration_since(Instant::now())
                .ok_or_else(|| anyhow!("burst timed out"))?;
            match self.recv_timeout(remaining.min(Duration::from_secs(5))) {
                Some(r) => out.push(r),
                None if Instant::now() >= deadline => {
                    return Err(anyhow!("burst timed out"));
                }
                None => continue,
            }
        }
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// Shut down workers (drains in-flight batches).
    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}

/// Default per-retry drain window for [`submit_with_retry`]: long
/// enough that a drained response usually frees a dispatch slot, short
/// enough that a stalled pool surfaces within seconds.
pub const DEFAULT_DRAIN: Duration = Duration::from_millis(50);

/// The crate's one submit-with-backpressure policy with its default
/// drain window applied — thin wrapper over
/// [`Coordinator::submit_with_retry`], re-exported by `server` so the
/// CLI loop, the network dispatch thread, and tests cannot drift onto
/// different retry behavior.
pub fn submit_with_retry(
    coord: &mut Coordinator,
    artifact: &str,
    inputs: Vec<HostValue>,
    drained: impl FnMut(Response),
) -> Result<u64> {
    coord.submit_with_retry(artifact, inputs, DEFAULT_DRAIN, drained)
}
