//! L3 coordinator: the serving layer in front of the PJRT runtime.
//!
//! FlashBias itself is a kernel-layer contribution, so the coordinator is
//! the thin-but-real serving runtime a deployment needs around it:
//!
//! * [`router`] — shape-bucket routing: a request for sequence length N is
//!   routed to the smallest compiled artifact bucket ≥ N (with padding),
//!   per (family, variant).
//! * [`batcher`] — dynamic batching: requests accumulate per bucket and
//!   flush on max-batch or deadline, amortizing dispatch overhead.
//! * [`worker`] — a thread pool executing flushed batches: PJRT for
//!   compiled artifacts, or **one batched `(B, H, N, C)` kernel-engine
//!   call** for plans in the [`HostPlanRegistry`]; bounded queues give
//!   backpressure.
//! * [`metrics`] — latency/throughput counters for every stage,
//!   including the shared factor store's hit/miss/eviction counters.
//!
//! Decomposition-strategy selection is the [`crate::plan::Planner`]
//! (re-exported here as [`StrategySelector`] for the serving layer);
//! every coordinator owns a [`FactorStore`] shared across its serving
//! loop, so [`Coordinator::plan_and_register`] amortizes SVD/neural
//! decomposition across repeated plans and worker threads.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod worker;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::factorstore::FactorStore;
use crate::iomodel::Geometry;
use crate::plan::{AttentionPlan, BiasSpec, PlanOptions, Planner};
use crate::runtime::{HostValue, Runtime};

pub use batcher::{Batch, BatcherConfig, DynamicBatcher};
pub use metrics::Metrics;
pub use router::{RouteKey, Router};
// the serving-layer aliases for the Table 1 policy object (the old
// `selector` module shim, folded in here)
pub use crate::plan::{Planner as StrategySelector, SelectorConfig};

/// Registry of attention plans served directly on the host kernel
/// engine — no PJRT artifact needed. Plan names share the artifact
/// namespace; a flushed batch whose name resolves here is stacked into
/// one batched `(B, H, N, C)` engine call by the worker pool.
#[derive(Default)]
pub struct HostPlanRegistry {
    plans: RwLock<HashMap<String, Arc<AttentionPlan>>>,
}

impl HostPlanRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&self, name: &str, plan: AttentionPlan) {
        self.plans
            .write()
            .unwrap()
            .insert(name.to_string(), Arc::new(plan));
    }

    pub fn get(&self, name: &str) -> Option<Arc<AttentionPlan>> {
        self.plans.read().unwrap().get(name).cloned()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.plans.read().unwrap().contains_key(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.plans.read().unwrap().keys().cloned().collect()
    }
}

/// A unit of work: run `artifact` on `inputs`.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub artifact: String,
    pub inputs: Vec<HostValue>,
    pub enqueued: Instant,
}

/// Execution result for one request.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub artifact: String,
    pub outputs: Result<Vec<HostValue>>,
    /// Time from submit to flush (batching wait).
    pub queue_time: Duration,
    /// Pure execute time.
    pub exec_time: Duration,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    pub workers: usize,
    /// Bounded depth of the dispatch queue (backpressure).
    pub queue_depth: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            workers: 2,
            queue_depth: 64,
        }
    }
}

/// The assembled serving stack.
pub struct Coordinator {
    runtime: Arc<Runtime>,
    host_plans: Arc<HostPlanRegistry>,
    store: Arc<FactorStore>,
    batcher: DynamicBatcher,
    pool: worker::WorkerPool,
    responses: Receiver<Response>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Coordinator with a private, unbounded [`FactorStore`]. Use
    /// [`Self::with_store`] to share a (possibly disk-warmed, byte-
    /// budgeted) store across coordinators or with the CLI.
    pub fn new(runtime: Arc<Runtime>, config: CoordinatorConfig) -> Self {
        Self::with_store(runtime, config,
                         Arc::new(FactorStore::unbounded()))
    }

    /// Coordinator sharing `store` for every decomposition in its
    /// serving loop; the store's counters surface through
    /// [`Metrics::summary`] / [`Metrics::to_json`].
    pub fn with_store(runtime: Arc<Runtime>, config: CoordinatorConfig,
                      store: Arc<FactorStore>) -> Self {
        let metrics = Arc::new(Metrics::new());
        metrics.attach_store(store.clone());
        let host_plans = Arc::new(HostPlanRegistry::new());
        let (pool, responses) = worker::WorkerPool::spawn(
            runtime.clone(),
            host_plans.clone(),
            config.workers,
            config.queue_depth,
            metrics.clone(),
        );
        Self {
            runtime,
            host_plans,
            store,
            batcher: DynamicBatcher::new(config.batcher),
            pool,
            responses,
            metrics,
            next_id: AtomicU64::new(0),
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.runtime
    }

    /// The factor store shared across this coordinator's serving loop.
    pub fn store(&self) -> &Arc<FactorStore> {
        &self.store
    }

    /// Plan `spec` through the shared factor store and register the
    /// result as a host plan under `name` — the serving-layer entry to
    /// amortized decomposition: repeated calls for the same bias
    /// content are store hits that share factor strips with every
    /// previously registered plan.
    pub fn plan_and_register(&self, name: &str, planner: &Planner,
                             spec: &BiasSpec, geo: &Geometry,
                             opts: &PlanOptions)
                             -> Result<AttentionPlan> {
        let plan = planner
            .plan_with_store(spec, geo, opts, &self.store)
            .map_err(|e| anyhow!("plan {name}: {e}"))?;
        self.register_plan(name, plan.clone())?;
        Ok(plan)
    }

    /// Register an [`AttentionPlan`] under an artifact-style name so
    /// requests for it are served on the host kernel engine — flushed
    /// batches run as a single batched engine call. Errors if the name
    /// would shadow a compiled PJRT artifact (the worker resolves host
    /// plans first).
    pub fn register_plan(&self, name: &str,
                         plan: AttentionPlan) -> Result<()> {
        if self.runtime.spec(name).is_some() {
            return Err(anyhow!(
                "{name} already names a compiled PJRT artifact; pick a \
                 distinct host-plan name"
            ));
        }
        self.host_plans.register(name, plan);
        Ok(())
    }

    pub fn host_plans(&self) -> &Arc<HostPlanRegistry> {
        &self.host_plans
    }

    /// Submit one request; may flush a batch to the workers. Returns the
    /// request id. Errors if the artifact is unknown or the dispatch
    /// queue is full (backpressure).
    pub fn submit(&mut self, artifact: &str,
                  inputs: Vec<HostValue>) -> Result<u64> {
        if self.runtime.spec(artifact).is_none()
            && !self.host_plans.contains(artifact)
        {
            return Err(anyhow!("unknown artifact {artifact}"));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            artifact: artifact.to_string(),
            inputs,
            enqueued: Instant::now(),
        };
        self.metrics.on_submit();
        if let Some(batch) = self.batcher.push(req) {
            self.pool.dispatch(batch)?;
        }
        Ok(id)
    }

    /// Flush any batches whose deadline has passed (call periodically, or
    /// after the last submit of a burst).
    pub fn flush_due(&mut self) -> Result<()> {
        for batch in self.batcher.flush_due(Instant::now()) {
            self.pool.dispatch(batch)?;
        }
        Ok(())
    }

    /// Force-flush everything.
    pub fn flush_all(&mut self) -> Result<()> {
        for batch in self.batcher.flush_all() {
            self.pool.dispatch(batch)?;
        }
        Ok(())
    }

    /// Receive the next response, waiting up to `timeout`.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        match self.responses.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    /// Convenience: submit a burst, flush, and collect all responses.
    pub fn run_burst(&mut self, reqs: Vec<(String, Vec<HostValue>)>)
                     -> Result<Vec<Response>> {
        let n = reqs.len();
        for (artifact, inputs) in reqs {
            self.submit(&artifact, inputs)?;
        }
        self.flush_all()?;
        let mut out = Vec::with_capacity(n);
        let deadline = Instant::now() + Duration::from_secs(600);
        while out.len() < n {
            let remaining = deadline
                .checked_duration_since(Instant::now())
                .ok_or_else(|| anyhow!("burst timed out"))?;
            match self.recv_timeout(remaining.min(Duration::from_secs(5))) {
                Some(r) => out.push(r),
                None if Instant::now() >= deadline => {
                    return Err(anyhow!("burst timed out"));
                }
                None => continue,
            }
        }
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// Shut down workers (drains in-flight batches).
    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}
