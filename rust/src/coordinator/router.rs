//! Shape-bucket router.
//!
//! Compiled PJRT executables are shape-specialized, so a request for
//! sequence length N must run on an artifact compiled for some bucket
//! N_b ≥ N (padding the inputs). The router indexes the manifest by
//! (family, variant) and picks the smallest adequate bucket — the same
//! discipline serving systems use for bucketed static shapes.

use std::collections::BTreeMap;

use crate::runtime::Runtime;

/// Routing key: artifact family + variant (e.g. ("attn", "factored")).
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RouteKey {
    pub family: String,
    pub variant: String,
}

impl RouteKey {
    pub fn new(family: &str, variant: &str) -> Self {
        Self {
            family: family.to_string(),
            variant: variant.to_string(),
        }
    }
}

/// Maps (family, variant, n) → artifact name.
#[derive(Debug, Default)]
pub struct Router {
    // key → sorted (bucket_n → artifact name)
    buckets: BTreeMap<RouteKey, BTreeMap<usize, String>>,
}

impl Router {
    /// Build from a runtime's manifest.
    pub fn from_runtime(rt: &Runtime) -> Self {
        let mut router = Router::default();
        for name in rt.names() {
            let Some(spec) = rt.spec(name) else { continue };
            if spec.family().is_empty() {
                continue;
            }
            router.insert(
                RouteKey::new(spec.family(), spec.variant()),
                spec.seq_len(),
                name,
            );
        }
        router
    }

    pub fn insert(&mut self, key: RouteKey, n: usize, artifact: &str) {
        self.buckets
            .entry(key)
            .or_default()
            .insert(n, artifact.to_string());
    }

    /// Smallest bucket with capacity ≥ n. Returns (artifact, bucket_n).
    pub fn route(&self, key: &RouteKey, n: usize) -> Option<(&str, usize)> {
        self.buckets
            .get(key)?
            .range(n..)
            .next()
            .map(|(&bn, name)| (name.as_str(), bn))
    }

    /// The largest bucket for a key (capacity probe).
    pub fn max_bucket(&self, key: &RouteKey) -> Option<usize> {
        self.buckets
            .get(key)?
            .keys()
            .next_back()
            .copied()
    }

    pub fn keys(&self) -> impl Iterator<Item = &RouteKey> {
        self.buckets.keys()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }
}

/// Pad a 2-D-or-3-D f32 tensor's sequence axis (second-to-last) with
/// zeros up to `target`. Used when routing pads a request into a bucket.
pub fn pad_seq(t: &crate::tensor::Tensor, target: usize)
               -> crate::tensor::Tensor {
    let shape = t.shape();
    let rank = shape.len();
    assert!(rank >= 2, "pad_seq needs rank ≥ 2");
    let seq_axis = rank - 2;
    let n = shape[seq_axis];
    assert!(target >= n, "target {target} < current {n}");
    if target == n {
        return t.clone();
    }
    let mut new_shape = shape.to_vec();
    new_shape[seq_axis] = target;
    crate::tensor::Tensor::from_fn(&new_shape, |ix| {
        if ix[seq_axis] < n {
            let mut src = ix.to_vec();
            src[seq_axis] = ix[seq_axis];
            // flatten index manually
            let mut flat = 0;
            for (d, &i) in src.iter().enumerate() {
                flat = flat * shape[d] + i;
            }
            t.data()[flat]
        } else {
            0.0
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn router() -> Router {
        let mut r = Router::default();
        let key = RouteKey::new("attn", "factored");
        r.insert(key.clone(), 256, "attn_factored_n256");
        r.insert(key.clone(), 512, "attn_factored_n512");
        r.insert(key, 1024, "attn_factored_n1024");
        r
    }

    #[test]
    fn routes_to_smallest_adequate_bucket() {
        let r = router();
        let key = RouteKey::new("attn", "factored");
        assert_eq!(r.route(&key, 100).unwrap(), ("attn_factored_n256", 256));
        assert_eq!(r.route(&key, 256).unwrap(), ("attn_factored_n256", 256));
        assert_eq!(r.route(&key, 257).unwrap(), ("attn_factored_n512", 512));
        assert_eq!(r.route(&key, 1024).unwrap(),
                   ("attn_factored_n1024", 1024));
    }

    #[test]
    fn oversize_request_rejected() {
        let r = router();
        let key = RouteKey::new("attn", "factored");
        assert!(r.route(&key, 2048).is_none());
        assert_eq!(r.max_bucket(&key), Some(1024));
    }

    #[test]
    fn unknown_key_rejected() {
        let r = router();
        assert!(r.route(&RouteKey::new("attn", "nope"), 100).is_none());
    }

    #[test]
    fn pad_seq_2d() {
        let t = Tensor::from_fn(&[3, 2], |ix| (ix[0] * 2 + ix[1]) as f32);
        let p = pad_seq(&t, 5);
        assert_eq!(p.shape(), &[5, 2]);
        assert_eq!(p.at2(2, 1), 5.0);
        assert_eq!(p.at2(3, 0), 0.0);
        assert_eq!(p.at2(4, 1), 0.0);
    }

    #[test]
    fn pad_seq_3d_heads() {
        let t = Tensor::from_fn(&[2, 3, 4], |ix| {
            (ix[0] * 12 + ix[1] * 4 + ix[2]) as f32
        });
        let p = pad_seq(&t, 4);
        assert_eq!(p.shape(), &[2, 4, 4]);
        // original values preserved
        assert_eq!(p.index0(1).at2(2, 3), 23.0);
        // padding zero
        assert_eq!(p.index0(1).at2(3, 0), 0.0);
    }

    #[test]
    fn pad_seq_noop() {
        let t = Tensor::ones(&[2, 2]);
        assert!(pad_seq(&t, 2).allclose(&t, 0.0, 0.0));
    }
}
