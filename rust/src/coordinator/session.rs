//! Session registry entries for the continuous-batching decode path.
//!
//! A [`SessionHandle`] is one live decode stream as the coordinator
//! sees it: a stable id, the host-plan artifact name it was opened
//! against, an immutable copy of the plan (readable with **no** lock),
//! and the mutable [`SessionState`] (KV cache + softmax carry) behind a
//! named `util::sync` lock.
//!
//! ## Locking discipline
//!
//! Appends happen at submit time, under the coordinator's `&mut self`:
//! [`Coordinator::step`](super::Coordinator::step) write-locks the
//! session, appends the new K/V row, snapshots the `(i, m)` ticket and
//! enqueues — so by the time a worker sees the request, rows `[0, m)`
//! of the cache are immutable. Workers then only ever
//!
//! 1. **read-lock** sessions (one guard per distinct session) to view
//!    cached K/V during the batched `decode_steps` call, and
//! 2. after dropping *every* read guard, **write-lock** sessions one
//!    at a time for the monotone carry write-back.
//!
//! Never holding a read guard while wanting a write guard is what makes
//! two workers with overlapping session sets deadlock-free; the
//! name-based lock audit cannot see this (all sessions share one lock
//! name), so the discipline is load-bearing — keep it.

use std::sync::Arc;

use crate::plan::{AttentionPlan, SessionState};
use crate::util::sync::{RwLock, RwLockReadGuard, RwLockWriteGuard};

/// One registered decode session (see module docs for the locking
/// discipline).
pub struct SessionHandle {
    id: u64,
    artifact: String,
    /// Immutable copy of the session's plan: workers build bias tiles
    /// and kernel configs from it without touching the state lock.
    plan: Arc<AttentionPlan>,
    state: RwLock<SessionState>,
}

impl SessionHandle {
    pub fn new(id: u64, artifact: String, state: SessionState) -> Self {
        let plan = Arc::clone(state.plan());
        Self {
            id,
            artifact,
            plan,
            state: RwLock::new("coordinator.session", state),
        }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Host-plan artifact name this session batches under.
    pub fn artifact(&self) -> &str {
        &self.artifact
    }

    /// The session's plan — lock-free (it never changes after open).
    pub fn plan(&self) -> &AttentionPlan {
        &self.plan
    }

    /// Read-lock the state: cached K/V views, position, carry.
    pub fn read(&self) -> RwLockReadGuard<'_, SessionState> {
        self.state.read_recover()
    }

    /// Write-lock the state: appends and carry write-backs.
    pub fn write(&self) -> RwLockWriteGuard<'_, SessionState> {
        self.state.write_recover()
    }
}

impl std::fmt::Debug for SessionHandle {
    // deliberately does not touch the state lock: Debug-printing a
    // Request mid-dispatch must never contend with workers
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SessionHandle")
            .field("id", &self.id)
            .field("artifact", &self.artifact)
            .finish_non_exhaustive()
    }
}
