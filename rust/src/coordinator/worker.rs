//! Worker pool: threads that pull flushed [`Batch`]es from a bounded
//! channel and execute them — on the shared PJRT runtime for compiled
//! artifacts, or as **one batched kernel-engine call** for names found
//! in the [`HostPlanRegistry`]. The bounded channel is the backpressure
//! boundary — when workers fall behind, `dispatch` errors instead of
//! queueing without bound.

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::session::SessionHandle;
use super::{
    Batch, HostPlanRegistry, Metrics, Request, RequestKind, Response,
};
use crate::kernels::{self, KernelConfig};
use crate::plan::{plan_bias_tile, AttentionPlan, Executor, HostExecutor};
use crate::runtime::{HostValue, Runtime};
use crate::tensor::Tensor;
use crate::util::sync::Mutex;

enum Job {
    Run(Batch),
    Stop,
}

/// Why a dispatch was refused — the batch rides along so the caller can
/// requeue it instead of dropping its requests on the floor.
#[derive(Debug)]
pub enum DispatchError {
    /// The bounded queue is full; retry after the workers drain.
    Backpressure(Batch),
    /// The worker pool has stopped.
    Stopped(Batch),
}

pub struct WorkerPool {
    tx: SyncSender<Job>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads sharing one dispatch queue of depth
    /// `queue_depth`. Returns the pool and the response channel.
    pub fn spawn(
        runtime: Arc<Runtime>,
        host_plans: Arc<HostPlanRegistry>,
        workers: usize,
        queue_depth: usize,
        metrics: Arc<Metrics>,
    ) -> (Self, Receiver<Response>) {
        let (tx, rx) = sync_channel::<Job>(queue_depth.max(1));
        let rx = Arc::new(Mutex::new("coordinator.worker_rx", rx));
        let (resp_tx, resp_rx) = std::sync::mpsc::channel::<Response>();
        let mut handles = Vec::with_capacity(workers.max(1));
        // divide the machine's core budget across workers so concurrent
        // engine batches don't oversubscribe the CPU
        let engine_threads =
            (kernels::default_threads() / workers.max(1)).max(1);
        for _ in 0..workers.max(1) {
            let rx = rx.clone();
            let runtime = runtime.clone();
            let host_plans = host_plans.clone();
            let resp_tx: Sender<Response> = resp_tx.clone();
            let metrics = metrics.clone();
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock_recover();
                    guard.recv()
                };
                match job {
                    Ok(Job::Run(batch)) => {
                        if let Some(plan) = host_plans.get(&batch.artifact) {
                            run_batch_engine(&plan, batch, &resp_tx,
                                             &metrics, engine_threads);
                        } else {
                            run_batch(&runtime, batch, &resp_tx, &metrics);
                        }
                    }
                    Ok(Job::Stop) | Err(_) => break,
                }
            }));
        }
        (Self { tx, handles }, resp_rx)
    }

    /// Enqueue a batch without blocking; a refusal hands the batch back
    /// so its requests are never lost.
    pub fn dispatch(&self, batch: Batch) -> Result<(), DispatchError> {
        match self.tx.try_send(Job::Run(batch)) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(Job::Run(b))) => {
                Err(DispatchError::Backpressure(b))
            }
            Err(TrySendError::Disconnected(Job::Run(b))) => {
                Err(DispatchError::Stopped(b))
            }
            Err(_) => unreachable!("dispatch only sends Job::Run"),
        }
    }

    /// Enqueue a batch, waiting for queue space — the flush paths use
    /// this so an already-accepted request can never be dropped by a
    /// momentarily full queue (workers are draining it concurrently).
    pub fn dispatch_blocking(&self, batch: Batch)
                             -> Result<(), DispatchError> {
        self.tx.send(Job::Run(batch)).map_err(|e| match e.0 {
            Job::Run(b) => DispatchError::Stopped(b),
            Job::Stop => unreachable!("dispatch only sends Job::Run"),
        })
    }

    /// Stop all workers after draining in-flight jobs.
    pub fn shutdown(self) {
        for _ in &self.handles {
            let _ = self.tx.send(Job::Stop);
        }
        for h in self.handles {
            // flashlint: allow(dispatch-blocking) teardown only: runs after the dispatch loop has exited
            let _ = h.join();
        }
    }
}

fn run_batch(
    runtime: &Runtime,
    batch: Batch,
    resp_tx: &Sender<Response>,
    metrics: &Metrics,
) {
    metrics.on_batch(batch.len());
    let exe = runtime.load(&batch.artifact);
    for req in batch.requests {
        let queue_time = batch.formed.duration_since(req.enqueued);
        let t0 = Instant::now();
        let outputs = match &exe {
            Ok(exe) => exe.run(&req.inputs),
            Err(e) => Err(anyhow!("load {}: {e}", batch.artifact)),
        };
        let exec_time = t0.elapsed();
        metrics.on_complete(queue_time, exec_time, outputs.is_ok());
        let _ = resp_tx.send(Response {
            id: req.id,
            artifact: req.artifact,
            outputs,
            queue_time,
            exec_time,
        });
    }
}

// ---------------------------------------------------------------------------
// Host-plan batches: one kernel-engine call per flushed batch
// ---------------------------------------------------------------------------

/// Payload signature a request stacks under:
/// `(heads, rank, cv, n, m)`. Session prefills may be shorter than the
/// plan geometry (`n ≤ g.n`, `m ≤ g.m` — the bias's leading rows and
/// columns still line up with absolute positions `[0, n) × [0, m)`), so
/// the actual lengths are part of the signature.
type StackSig = (usize, usize, usize, usize, usize);

/// Validate one host-plan request's payload (`[q, k, v]` f32 tensors of
/// rank 2 `(N, C)` or rank 3 `(H, N, C)`, with `N`/`M` at most the plan
/// geometry's) and return its stacking signature.
fn check_engine_req(plan: &AttentionPlan,
                    req: &Request) -> Result<StackSig> {
    let g = &plan.geometry;
    if req.inputs.len() != 3 {
        bail!(
            "host-plan request wants [q, k, v], got {} inputs",
            req.inputs.len()
        );
    }
    let f32_at = |i: usize| -> Result<&Tensor> {
        req.inputs[i]
            .as_f32()
            .ok_or_else(|| anyhow!("input {i} must be f32"))
    };
    let (q, k, v) = (f32_at(0)?, f32_at(1)?, f32_at(2)?);
    let rank = q.rank();
    if rank != 2 && rank != 3 {
        bail!("q must be (N, C) or (H, N, C), got {:?}", q.shape());
    }
    if k.rank() != rank || v.rank() != rank {
        bail!("q/k/v rank mismatch");
    }
    let h = if rank == 3 { q.shape()[0] } else { 1 };
    let cv = v.shape()[rank - 1];
    let n = q.shape()[rank - 2];
    let m = k.shape()[rank - 2];
    let q_ok =
        (1..=g.n).contains(&n) && q.shape()[rank - 1] == g.c;
    let k_ok = (1..=g.m).contains(&m)
        && k.shape()[rank - 1] == g.c
        && (rank == 2 || k.shape()[0] == h);
    let v_ok =
        v.shape()[rank - 2] == m && (rank == 2 || v.shape()[0] == h);
    if !q_ok || !k_ok || !v_ok {
        bail!(
            "payload shapes q{:?} k{:?} v{:?} do not fit plan \
             (N≤{}, M≤{}, C={})",
            q.shape(),
            k.shape(),
            v.shape(),
            g.n,
            g.m,
            g.c
        );
    }
    Ok((h, rank, cv, n, m))
}

/// Execute a flushed host-plan batch on the kernel engine. The batch
/// may be **mixed** (continuous batching): decode steps split off and
/// run as one [`kernels::decode_steps`] call; prefills/one-shots are
/// grouped by stacking signature (almost always one group) and each
/// group runs as **one** batched `(B, H, N, C)` engine call instead of
/// request-by-request. The plan's bias is shared by every program
/// (batch entry × head), matching the per-plan bias semantics of the
/// serving API.
fn run_batch_engine(
    plan: &AttentionPlan,
    batch: Batch,
    resp_tx: &Sender<Response>,
    metrics: &Metrics,
    engine_threads: usize,
) {
    metrics.on_batch(batch.len());
    let formed = batch.formed;
    let (prefills, decodes) = batch.split_by_kind();
    if !decodes.is_empty() {
        run_batch_decode(decodes, formed, resp_tx, metrics,
                         engine_threads);
    }
    if prefills.is_empty() {
        return;
    }
    // group by signature so mixed rank-2/rank-3 (or mixed-head, mixed-
    // length) traffic for the same plan still succeeds — each group
    // stacks independently
    let mut groups: Vec<(StackSig, Vec<Request>)> = Vec::new();
    for req in prefills {
        match check_engine_req(plan, &req) {
            Ok(sig) => {
                match groups.iter_mut().find(|(s, _)| *s == sig) {
                    Some((_, reqs)) => reqs.push(req),
                    None => groups.push((sig, vec![req])),
                }
            }
            Err(e) => {
                let queue_time = formed.duration_since(req.enqueued);
                metrics.on_complete(queue_time, Duration::ZERO, false);
                let _ = resp_tx.send(Response {
                    id: req.id,
                    artifact: req.artifact.clone(),
                    outputs: Err(e),
                    queue_time,
                    exec_time: Duration::ZERO,
                });
            }
        }
    }
    if plan.multiplicative {
        // no batched multiplicative tile schedule (Appendix I is dense
        // math): serve these per request on the host executor
        for (_, reqs) in groups {
            for req in reqs {
                run_multiplicative_req(plan, req, formed, resp_tx,
                                       metrics);
            }
        }
        return;
    }
    for (sig, reqs) in groups {
        run_engine_group(plan, sig, reqs, formed, resp_tx, metrics,
                         engine_threads);
    }
}

/// Stack one signature group into `(B, H, N, C)` tensors and run it as
/// a single engine call.
fn run_engine_group(
    plan: &AttentionPlan,
    (h, rank, cv, n, m): StackSig,
    good: Vec<Request>,
    formed: Instant,
    resp_tx: &Sender<Response>,
    metrics: &Metrics,
    engine_threads: usize,
) {
    // flashlint: allow-fn(hot-path-panic) every request in `good` passed check_engine_req, which proved the three inputs exist and are f32
    let g = &plan.geometry;
    let b = good.len();
    let mut qd = Vec::with_capacity(b * h * n * g.c);
    let mut kd = Vec::with_capacity(b * h * m * g.c);
    let mut vd = Vec::with_capacity(b * h * m * cv);
    for req in &good {
        qd.extend_from_slice(req.inputs[0].as_f32().expect("f32 q").data());
        kd.extend_from_slice(req.inputs[1].as_f32().expect("f32 k").data());
        vd.extend_from_slice(req.inputs[2].as_f32().expect("f32 v").data());
    }
    let qt = Tensor::new(&[b, h, n, g.c], qd);
    let kt = Tensor::new(&[b, h, m, g.c], kd);
    let vt = Tensor::new(&[b, h, m, cv], vd);
    let t0 = Instant::now();
    let tile = plan_bias_tile(plan);
    let cfg = KernelConfig::for_geometry(g).with_threads(engine_threads);
    let out = kernels::attention_batched(&qt, &kt, &vt, tile.as_ref(),
                                         plan.causal, &cfg);
    let per_req = t0.elapsed() / b as u32;
    for (bi, req) in good.into_iter().enumerate() {
        let queue_time = formed.duration_since(req.enqueued);
        let slab = out.index0(bi); // (H, N, Cv)
        let result = if rank == 2 { slab.index0(0) } else { slab };
        metrics.on_complete(queue_time, per_req, true);
        let _ = resp_tx.send(Response {
            id: req.id,
            artifact: req.artifact,
            outputs: Ok(vec![HostValue::F32(result)]),
            queue_time,
            exec_time: per_req,
        });
    }
}

/// Execute every decode step of a flushed batch as **one**
/// [`kernels::decode_steps`] call — the continuous-batching hot path.
///
/// Locking discipline (see `coordinator::session`): acquire one read
/// guard per distinct session (cache rows `[0, m)` are immutable by
/// append-at-submit), run the batched kernel, drop **every** read guard,
/// and only then write-lock sessions one at a time for the monotone
/// carry write-back. Interleaving reads and writes across workers with
/// overlapping session sets would deadlock; this ordering cannot.
fn run_batch_decode(
    reqs: Vec<Request>,
    formed: Instant,
    resp_tx: &Sender<Response>,
    metrics: &Metrics,
    engine_threads: usize,
) {
    struct Item {
        id: u64,
        artifact: String,
        enqueued: Instant,
        session: Arc<SessionHandle>,
        i: usize,
        m: usize,
        q: Tensor,
    }
    let reject = |id: u64, artifact: String, enqueued: Instant,
                  err: anyhow::Error| {
        let queue_time = formed.duration_since(enqueued);
        metrics.on_complete(queue_time, Duration::ZERO, false);
        let _ = resp_tx.send(Response {
            id,
            artifact,
            outputs: Err(err),
            queue_time,
            exec_time: Duration::ZERO,
        });
    };
    let mut items: Vec<Item> = Vec::with_capacity(reqs.len());
    for req in reqs {
        let Request { id, artifact, mut inputs, enqueued, kind } = req;
        let RequestKind::Decode(ticket) = kind else {
            // the caller splits by kind; surface a stray prefill as a
            // failed response rather than a worker panic
            reject(id, artifact, enqueued,
                   anyhow!("non-decode request on the decode path"));
            continue;
        };
        let c = ticket.session.plan().geometry.c;
        let q = match (inputs.len(), inputs.pop()) {
            (1, Some(HostValue::F32(t))) if t.data().len() == c => t,
            _ => {
                reject(id, artifact, enqueued,
                       anyhow!("decode step wants one f32 q row of \
                                width {c}"));
                continue;
            }
        };
        if ticket.m > ticket.session.read().cache().len() {
            // impossible via Coordinator::step, which appends the K/V
            // row before minting the ticket
            reject(id, artifact, enqueued,
                   anyhow!("decode ticket m={} beyond cached rows",
                           ticket.m));
            continue;
        }
        items.push(Item {
            id,
            artifact,
            enqueued,
            session: ticket.session,
            i: ticket.i,
            m: ticket.m,
            q,
        });
    }
    if items.is_empty() {
        return;
    }
    // bias tiles and the kernel config come from the sessions' immutable
    // plan copies — no state lock needed, and the config depends only on
    // the plan, so a step's bits never depend on its batch's composition
    let head = items[0].session.plan();
    let cfg = KernelConfig::for_geometry_dtype(&head.geometry,
                                               head.strip_dtype())
        .with_threads(engine_threads);
    let tiles: Vec<_> = items
        .iter()
        .map(|it| plan_bias_tile(it.session.plan()))
        .collect();
    // one read guard per distinct session: re-read-locking a session we
    // already hold could deadlock std's RwLock if a writer is queued
    let mut guards = Vec::new();
    let mut guard_idx = Vec::with_capacity(items.len());
    for it in &items {
        let sid = it.session.id();
        let gi = match guards.iter().position(|(g, _)| *g == sid) {
            Some(gi) => gi,
            None => {
                guards.push((sid, it.session.read()));
                guards.len() - 1
            }
        };
        guard_idx.push(gi);
    }
    let mut outs: Vec<Vec<f32>> = items
        .iter()
        .map(|it| vec![0.0f32; it.session.plan().geometry.c])
        .collect();
    let mut progs = Vec::with_capacity(items.len());
    for (((it, tile), gi), out) in items
        .iter()
        .zip(&tiles)
        .zip(&guard_idx)
        .zip(outs.iter_mut())
    {
        let cache = guards[*gi].1.cache();
        let plan = it.session.plan();
        progs.push((
            kernels::DecodeProgram {
                q: it.q.data(),
                k: cache.k_prefix(it.m),
                v: cache.v_prefix(it.m),
                bias: tile.as_ref(),
                i: it.i,
                n: it.i + 1,
                causal: plan.causal,
                scale: 1.0 / (plan.geometry.c as f32).sqrt(),
            },
            out.as_mut_slice(),
        ));
    }
    let t0 = Instant::now();
    let carries = kernels::decode_steps(progs, &cfg);
    let per_req = t0.elapsed() / items.len() as u32;
    // every read guard must be gone before the first carry write-lock;
    // the tiles borrow the sessions' plans, so they go too before
    // `items` is consumed below
    drop(guards);
    drop(tiles);
    for (it, carry) in items.iter().zip(&carries) {
        it.session.write().record_carry(*carry, it.i + 1);
    }
    for (it, out) in items.into_iter().zip(outs) {
        let queue_time = formed.duration_since(it.enqueued);
        metrics.on_complete(queue_time, per_req, true);
        let cv = out.len();
        let _ = resp_tx.send(Response {
            id: it.id,
            artifact: it.artifact,
            outputs: Ok(vec![HostValue::F32(Tensor::new(&[cv], out))]),
            queue_time,
            exec_time: per_req,
        });
    }
}

fn run_multiplicative_req(
    plan: &AttentionPlan,
    req: Request,
    formed: Instant,
    resp_tx: &Sender<Response>,
    metrics: &Metrics,
) {
    // flashlint: allow-fn(hot-path-panic) callers route here only after check_engine_req validated the [q, k, v] f32 payload
    let queue_time = formed.duration_since(req.enqueued);
    let t0 = Instant::now();
    let outputs = (|| -> Result<Vec<HostValue>> {
        let q = req.inputs[0].as_f32().expect("f32 q");
        let k = req.inputs[1].as_f32().expect("f32 k");
        let v = req.inputs[2].as_f32().expect("f32 v");
        if q.rank() != 2 {
            bail!("multiplicative host plans serve (N, C) payloads only");
        }
        let out = HostExecutor.execute(plan, q, k, v)?;
        Ok(vec![HostValue::F32(out)])
    })();
    let exec_time = t0.elapsed();
    metrics.on_complete(queue_time, exec_time, outputs.is_ok());
    let _ = resp_tx.send(Response {
        id: req.id,
        artifact: req.artifact,
        outputs,
        queue_time,
        exec_time,
    });
}

// Integration tests: the PJRT path is exercised end-to-end in
// rust/tests/coordinator_serving.rs (requires artifacts); the host-plan
// engine path in rust/tests/host_serving.rs (runs everywhere).
