//! Worker pool: threads that pull flushed [`Batch`]es from a bounded
//! channel and execute them on the shared PJRT runtime. The bounded
//! channel is the backpressure boundary — when workers fall behind,
//! `dispatch` errors instead of queueing without bound.

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::{Batch, Metrics, Response};
use crate::runtime::Runtime;

enum Job {
    Run(Batch),
    Stop,
}

pub struct WorkerPool {
    tx: SyncSender<Job>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads sharing one dispatch queue of depth
    /// `queue_depth`. Returns the pool and the response channel.
    pub fn spawn(
        runtime: Arc<Runtime>,
        workers: usize,
        queue_depth: usize,
        metrics: Arc<Metrics>,
    ) -> (Self, Receiver<Response>) {
        let (tx, rx) = sync_channel::<Job>(queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let (resp_tx, resp_rx) = std::sync::mpsc::channel::<Response>();
        let mut handles = Vec::with_capacity(workers.max(1));
        for _ in 0..workers.max(1) {
            let rx = rx.clone();
            let runtime = runtime.clone();
            let resp_tx: Sender<Response> = resp_tx.clone();
            let metrics = metrics.clone();
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(Job::Run(batch)) => {
                        run_batch(&runtime, batch, &resp_tx, &metrics);
                    }
                    Ok(Job::Stop) | Err(_) => break,
                }
            }));
        }
        (Self { tx, handles }, resp_rx)
    }

    /// Enqueue a batch; errors when the queue is full (backpressure).
    pub fn dispatch(&self, batch: Batch) -> Result<()> {
        match self.tx.try_send(Job::Run(batch)) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => {
                Err(anyhow!("dispatch queue full (backpressure)"))
            }
            Err(TrySendError::Disconnected(_)) => {
                Err(anyhow!("worker pool stopped"))
            }
        }
    }

    /// Stop all workers after draining in-flight jobs.
    pub fn shutdown(self) {
        for _ in &self.handles {
            let _ = self.tx.send(Job::Stop);
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn run_batch(
    runtime: &Runtime,
    batch: Batch,
    resp_tx: &Sender<Response>,
    metrics: &Metrics,
) {
    metrics.on_batch(batch.len());
    let exe = runtime.load(&batch.artifact);
    for req in batch.requests {
        let queue_time = batch.formed.duration_since(req.enqueued);
        let t0 = Instant::now();
        let outputs = match &exe {
            Ok(exe) => exe.run(&req.inputs),
            Err(e) => Err(anyhow!("load {}: {e}", batch.artifact)),
        };
        let exec_time = t0.elapsed();
        metrics.on_complete(queue_time, exec_time, outputs.is_ok());
        let _ = resp_tx.send(Response {
            id: req.id,
            artifact: req.artifact,
            outputs,
            queue_time,
            exec_time,
        });
    }
}

// Integration tests that exercise the pool against real artifacts live in
// rust/tests/coordinator_serving.rs; the pool's queue/backpressure logic
// is covered there end-to-end.
