//! Dynamic batcher: requests accumulate per artifact and flush when the
//! batch is full or the oldest request's deadline expires — the standard
//! latency/throughput knob of serving systems (vLLM-style), applied here
//! to amortize PJRT dispatch and queue overhead.
//!
//! Buckets are keyed by artifact only, not by [`RequestKind`]: one-shot
//! requests, session prefills and decode steps for the same plan share
//! a bucket, so a single flush carries a **mixed** batch (continuous
//! batching). The worker splits it with [`Batch::split_by_kind`] and
//! runs each side as one batched engine call.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use super::{Request, RequestKind};

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush when a bucket reaches this many requests.
    pub max_batch: usize,
    /// Flush when the oldest request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// A flushed group of same-artifact requests.
#[derive(Debug)]
pub struct Batch {
    pub artifact: String,
    pub requests: Vec<Request>,
    pub formed: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Split a mixed flush into `(prefills, decode_steps)`, preserving
    /// submission order within each side. The batch's `formed` instant
    /// applies to both.
    pub fn split_by_kind(self) -> (Vec<Request>, Vec<Request>) {
        self.requests
            .into_iter()
            .partition(|r| matches!(r.kind, RequestKind::Prefill))
    }
}

/// Per-artifact accumulation queues. Keyed with a `BTreeMap` so that
/// timer flushes emit batches in artifact order — two runs that submit
/// the same requests flush in the same order, independent of hasher
/// seeds.
#[derive(Debug)]
pub struct DynamicBatcher {
    config: BatcherConfig,
    pending: BTreeMap<String, Vec<Request>>,
}

impl DynamicBatcher {
    pub fn new(config: BatcherConfig) -> Self {
        Self {
            config,
            pending: BTreeMap::new(),
        }
    }

    /// Number of requests currently waiting.
    pub fn pending_len(&self) -> usize {
        self.pending.values().map(Vec::len).sum()
    }

    /// Add a request; returns a full batch if this push filled one.
    pub fn push(&mut self, req: Request) -> Option<Batch> {
        let queue = self.pending.entry(req.artifact.clone()).or_default();
        queue.push(req);
        if queue.len() >= self.config.max_batch {
            let artifact = queue[0].artifact.clone();
            let requests = std::mem::take(queue);
            return Some(Batch {
                artifact,
                requests,
                formed: Instant::now(),
            });
        }
        None
    }

    /// Flush every bucket whose oldest request exceeded `max_wait`.
    pub fn flush_due(&mut self, now: Instant) -> Vec<Batch> {
        let max_wait = self.config.max_wait;
        let due: Vec<String> = self
            .pending
            .iter()
            .filter(|(_, q)| {
                q.first()
                    .map(|r| now.duration_since(r.enqueued) >= max_wait)
                    .unwrap_or(false)
            })
            .map(|(k, _)| k.clone())
            .collect();
        due.into_iter()
            .filter_map(|k| self.take_bucket(&k))
            .collect()
    }

    /// Flush everything regardless of deadlines.
    pub fn flush_all(&mut self) -> Vec<Batch> {
        let keys: Vec<String> = self.pending.keys().cloned().collect();
        keys.into_iter()
            .filter_map(|k| self.take_bucket(&k))
            .collect()
    }

    /// Return a flushed batch's requests to the *front* of their bucket
    /// — dispatch refused it, so the next flush re-emits them first,
    /// preserving submission order. The bucket may transiently exceed
    /// `max_batch`; the oversized flush that follows is legal (workers
    /// take batches of any size).
    pub fn unflush(&mut self, batch: Batch) {
        if batch.requests.is_empty() {
            return;
        }
        let queue = self.pending.entry(batch.artifact).or_default();
        let mut requests = batch.requests;
        requests.append(queue);
        *queue = requests;
    }

    fn take_bucket(&mut self, key: &str) -> Option<Batch> {
        let queue = self.pending.get_mut(key)?;
        if queue.is_empty() {
            return None;
        }
        let requests = std::mem::take(queue);
        Some(Batch {
            artifact: key.to_string(),
            requests,
            formed: Instant::now(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, artifact: &str) -> Request {
        Request {
            id,
            artifact: artifact.to_string(),
            inputs: vec![],
            enqueued: Instant::now(),
            kind: RequestKind::Prefill,
        }
    }

    fn req_at(id: u64, artifact: &str, enqueued: Instant) -> Request {
        Request {
            id,
            artifact: artifact.to_string(),
            inputs: vec![],
            enqueued,
            kind: RequestKind::Prefill,
        }
    }

    /// A decode-kind request against a real (tiny) session handle.
    fn decode_req(id: u64, artifact: &str) -> Request {
        use crate::coordinator::session::SessionHandle;
        use crate::coordinator::DecodeTicket;
        use crate::iomodel::Geometry;
        use crate::plan::{BiasSpec, PlanOptions, Planner, SessionState};
        use std::sync::Arc;

        let opts = PlanOptions {
            causal: true,
            ..PlanOptions::default()
        };
        let plan = Planner::default()
            .plan(&BiasSpec::alibi(8, 8, 0.25),
                  &Geometry::square(8, 4, 0, 100 * 1024 / 2), &opts)
            .expect("plan");
        let state = SessionState::new(Arc::new(plan)).expect("session");
        let handle = Arc::new(SessionHandle::new(
            id,
            artifact.to_string(),
            state,
        ));
        Request {
            id,
            artifact: artifact.to_string(),
            inputs: vec![],
            enqueued: Instant::now(),
            kind: RequestKind::Decode(DecodeTicket {
                session: handle,
                i: 0,
                m: 1,
            }),
        }
    }

    #[test]
    fn flushes_on_max_batch() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
        });
        assert!(b.push(req(0, "a")).is_none());
        assert!(b.push(req(1, "a")).is_none());
        let batch = b.push(req(2, "a")).expect("should flush");
        assert_eq!(batch.len(), 3);
        assert_eq!(batch.artifact, "a");
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn buckets_are_per_artifact() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(10),
        });
        assert!(b.push(req(0, "a")).is_none());
        assert!(b.push(req(1, "b")).is_none());
        assert_eq!(b.pending_len(), 2);
        let batch = b.push(req(2, "a")).expect("a flushes");
        assert_eq!(batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
                   vec![0, 2]);
        assert_eq!(b.pending_len(), 1);
    }

    #[test]
    fn deadline_flush() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
        });
        let old = Instant::now() - Duration::from_millis(50);
        b.push(req_at(0, "a", old));
        b.push(req(1, "b")); // fresh
        let due = b.flush_due(Instant::now());
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].artifact, "a");
        assert_eq!(b.pending_len(), 1);
    }

    #[test]
    fn unflush_requeues_at_the_front() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(10),
        });
        b.push(req(0, "a"));
        let batch = b.push(req(1, "a")).expect("flushes");
        b.push(req(2, "a"));
        // dispatch refused the batch: put it back, order preserved
        b.unflush(batch);
        assert_eq!(b.pending_len(), 3);
        let batch = b.flush_all().pop().expect("one bucket");
        assert_eq!(
            batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
    }

    #[test]
    fn mixed_kinds_share_a_bucket_and_split_in_order() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
        });
        assert!(b.push(req(0, "a")).is_none());
        assert!(b.push(decode_req(1, "a")).is_none());
        assert!(b.push(decode_req(2, "a")).is_none());
        let batch = b.push(req(3, "a")).expect("mixed bucket flushes");
        assert_eq!(batch.len(), 4);
        let (prefills, decodes) = batch.split_by_kind();
        assert_eq!(prefills.iter().map(|r| r.id).collect::<Vec<_>>(),
                   vec![0, 3]);
        assert_eq!(decodes.iter().map(|r| r.id).collect::<Vec<_>>(),
                   vec![1, 2]);
        assert!(decodes.iter().all(|r| matches!(
            r.kind,
            RequestKind::Decode(_)
        )));
    }

    #[test]
    fn flush_all_drains() {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        b.push(req(0, "a"));
        b.push(req(1, "b"));
        b.push(req(2, "b"));
        let batches = b.flush_all();
        assert_eq!(batches.iter().map(Batch::len).sum::<usize>(), 3);
        assert_eq!(b.pending_len(), 0);
        assert!(b.flush_all().is_empty());
    }
}
