//! Decomposition-strategy selector — the Table 1 decision procedure as a
//! policy object.
//!
//! Given what the coordinator knows about a bias (closed-form? static
//! learned parameter? data-dependent? measured spectral rank?), pick the
//! strategy the paper prescribes:
//!
//! * closed form            → Exact (ALiBi, spatial distance, cos)
//! * static learned, low-rank at the energy target → SVD (Swin, Pangu)
//! * dynamic/data-dependent → Neural (AlphaFold pair bias)
//! * rank test fails        → Dense fallback (Appendix J limitation),
//!   optionally LowRankSparse when the residual is sparse.

use crate::decompose::{NeuralConfig, RankSelect, Strategy};

/// What kind of bias a model layer declares.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BiasClass {
    /// Closed-form factorization known (rank R).
    ClosedForm { rank: usize },
    /// Fixed learned parameter; spectral profile measured offline.
    StaticLearned {
        /// Rank needed to keep the energy target.
        rank_at_energy: usize,
        /// Full matrix side (min(N, M)).
        full_rank: usize,
    },
    /// Projected from activations — differs per sample/layer/head.
    Dynamic { source_dim: usize },
    /// Nothing known.
    Unknown,
}

/// Policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct SelectorConfig {
    /// Energy target for SVD truncation (paper: 0.99–0.995).
    pub energy_target: f64,
    /// A static bias is "low-rank enough" if rank_at_energy ≤
    /// `max_rank_fraction` · full_rank (paper applies FlashBias only to
    /// the low-rank layers of SwinV2, §4.3 / Figure 8).
    pub max_rank_fraction: f64,
    /// Neural decomposition defaults for dynamic biases.
    pub neural: NeuralConfig,
}

impl Default for SelectorConfig {
    fn default() -> Self {
        Self {
            energy_target: 0.99,
            max_rank_fraction: 0.35,
            neural: NeuralConfig::default(),
        }
    }
}

/// The selector.
#[derive(Clone, Debug, Default)]
pub struct StrategySelector {
    pub config: SelectorConfig,
}

impl StrategySelector {
    pub fn new(config: SelectorConfig) -> Self {
        Self { config }
    }

    /// Pick a strategy for one bias.
    pub fn select(&self, class: BiasClass) -> Strategy {
        match class {
            BiasClass::ClosedForm { .. } => Strategy::Exact,
            BiasClass::StaticLearned {
                rank_at_energy,
                full_rank,
            } => {
                let limit = (full_rank as f64
                    * self.config.max_rank_fraction)
                    .ceil() as usize;
                if rank_at_energy <= limit {
                    Strategy::Svd(RankSelect::Fixed(rank_at_energy))
                } else {
                    // Appendix J: not low-rank enough — keep dense
                    Strategy::Dense
                }
            }
            BiasClass::Dynamic { .. } => Strategy::Neural(self.config.neural),
            BiasClass::Unknown => Strategy::Dense,
        }
    }

    /// Layer-policy helper (§4.3): given per-layer rank measurements,
    /// return the first layer index from which FlashBias applies — the
    /// paper's "last 8 layers of SwinV2" rule generalized.
    pub fn factored_from(&self, ranks_at_energy: &[usize],
                         full_rank: usize) -> usize {
        let limit =
            (full_rank as f64 * self.config.max_rank_fraction).ceil() as usize;
        // longest low-rank suffix
        let mut from = ranks_at_energy.len();
        for (i, &r) in ranks_at_energy.iter().enumerate().rev() {
            if r <= limit {
                from = i;
            } else {
                break;
            }
        }
        from
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sel() -> StrategySelector {
        StrategySelector::new(SelectorConfig::default())
    }

    #[test]
    fn closed_form_goes_exact() {
        assert!(matches!(
            sel().select(BiasClass::ClosedForm { rank: 2 }),
            Strategy::Exact
        ));
    }

    #[test]
    fn lowrank_static_goes_svd_with_measured_rank() {
        let s = sel().select(BiasClass::StaticLearned {
            rank_at_energy: 16,
            full_rank: 576,
        });
        match s {
            Strategy::Svd(RankSelect::Fixed(16)) => {}
            other => panic!("expected SVD(16), got {other:?}"),
        }
    }

    #[test]
    fn highrank_static_falls_back_dense() {
        // rank@99% = 500 of 576 — the Figure 6 "not all heads are
        // low-rank" case: keep dense (paper's own deployment rule)
        assert!(matches!(
            sel().select(BiasClass::StaticLearned {
                rank_at_energy: 500,
                full_rank: 576,
            }),
            Strategy::Dense
        ));
    }

    #[test]
    fn dynamic_goes_neural() {
        assert!(matches!(
            sel().select(BiasClass::Dynamic { source_dim: 577 }),
            Strategy::Neural(_)
        ));
    }

    #[test]
    fn unknown_goes_dense() {
        assert!(matches!(sel().select(BiasClass::Unknown), Strategy::Dense));
    }

    #[test]
    fn factored_from_suffix_rule() {
        // SwinV2 pattern (Figure 8): early layers high-rank, later low
        let ranks = [300, 280, 250, 120, 60, 40, 30, 20];
        let from = sel().factored_from(&ranks, 576);
        // 576 * 0.35 ≈ 202 → suffix starts where rank ≤ 202: index 3
        assert_eq!(from, 3);
    }

    #[test]
    fn factored_from_none_lowrank() {
        let ranks = [500, 480, 460];
        assert_eq!(sel().factored_from(&ranks, 576), 3); // empty suffix
    }

    #[test]
    fn factored_from_all_lowrank() {
        let ranks = [10, 12, 8];
        assert_eq!(sel().factored_from(&ranks, 576), 0);
    }
}
