//! Decomposition-strategy selection — delegated to [`crate::plan`].
//!
//! The Table 1 decision procedure used to live here as a standalone
//! policy object keyed on a hand-declared `BiasClass`. It is now the
//! [`crate::plan::Planner`]: callers declare a [`crate::plan::BiasSpec`]
//! and receive a full executable plan instead of a bare strategy, so the
//! decision stays fused with execution (the paper's whole point). This
//! module remains as the serving-layer alias for that policy object.

pub use crate::plan::{Planner as StrategySelector, SelectorConfig};
