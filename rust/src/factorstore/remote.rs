//! Cross-node factor sharing — the store's third tier.
//!
//! The paper's amortization argument (Table 4: pay the SVD once, serve
//! forever) extends across a fleet: one coordinator decomposes, every
//! peer fetches the finished strips instead of re-paying the SVD. Two
//! halves:
//!
//! * [`FactorService`] — serves lookup-by-fingerprint from a
//!   [`FactorStore`] (resident *and* spill tiers) over a TCP listener.
//! * [`RemoteStore`] — the client a planner/coordinator store consults
//!   on a local+spill miss ([`FactorStore::attach_remote`]); fetched
//!   entries are cached locally, so each peer pays one network round
//!   trip per bias, ever.
//!
//! The wire protocol is length-prefixed jsonlite: a 4-byte
//! little-endian frame length followed by one JSON document, the same
//! entry encoding [`FactorStore::save`] uses (finite f32 payloads
//! round-trip exactly). Requests are `{"op":"get","key":"<16-hex>"}`;
//! responses are `{"found":true,"entry":{...}}`, `{"found":false}`, or
//! `{"error":"..."}`. Any network or protocol failure on the client
//! degrades to a miss — the caller decomposes locally, never blocks on
//! a dead peer (10 s IO timeouts).

use std::net::{
    IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream,
    ToSocketAddrs,
};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use super::{
    entry_from_json, entry_is_finite, entry_to_json, Cached, FactorStore,
    Fingerprint,
};
use crate::jsonlite::Json;
// The frame codec lives in util::frame (shared with the serving
// front-end); re-exported here because this module introduced it and
// existing callers import it from this path.
pub use crate::util::frame::{
    read_frame, read_frame_limited, set_io_timeouts, write_frame,
    CONNECT_TIMEOUT, IO_TIMEOUT, MAX_FRAME_BYTES, MAX_REQUEST_BYTES,
};

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Serves factor lookups from a [`FactorStore`] over TCP. Bind with
/// `"127.0.0.1:0"` for an ephemeral port ([`Self::addr`] reports the
/// bound address). The accept loop and each connection run on their
/// own threads; dropping (or [`Self::shutdown`]) stops the listener.
pub struct FactorService {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    served: Arc<AtomicU64>,
    handle: Option<JoinHandle<()>>,
}

impl FactorService {
    /// Bind `addr` and start serving lookups from `store`.
    pub fn serve(store: Arc<FactorStore>,
                 addr: impl ToSocketAddrs) -> Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("factor service bind: {e}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let handle = {
            let (stop, served) = (stop.clone(), served.clone());
            std::thread::spawn(move || {
                accept_loop(listener, store, stop, served)
            })
        };
        Ok(Self {
            addr,
            stop,
            served,
            handle: Some(handle),
        })
    }

    /// The bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Lookups answered with a factor entry so far.
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// Stop accepting connections and join the accept thread.
    pub fn shutdown(self) {
        // Drop does the work
    }
}

impl Drop for FactorService {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake the blocking accept with a throwaway connection; an
        // unspecified bind address (0.0.0.0 / ::) is not connectable
        // everywhere, so aim the wake at loopback on the same port
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let woke =
            TcpStream::connect_timeout(&wake, CONNECT_TIMEOUT).is_ok();
        if let Some(h) = self.handle.take() {
            if woke {
                let _ = h.join();
            }
            // wake failed: the accept thread stays parked in accept()
            // with the stop flag set — it exits on the next connection
            // or with the process; joining would hang forever
        }
    }
}

fn accept_loop(listener: TcpListener, store: Arc<FactorStore>,
               stop: Arc<AtomicBool>, served: Arc<AtomicU64>) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => {
                // a persistent accept error (fd exhaustion, EMFILE)
                // fails instantly — back off instead of busy-spinning
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        let store = store.clone();
        let served = served.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(stream, &store, &served);
        });
    }
}

/// One connection: answer request frames until the peer closes.
fn handle_conn(mut stream: TcpStream, store: &FactorStore,
               served: &AtomicU64) -> Result<()> {
    set_io_timeouts(&stream, IO_TIMEOUT)?;
    while let Some(req) =
        read_frame_limited(&mut stream, MAX_REQUEST_BYTES)?
    {
        let resp = answer(&req, store, served);
        write_frame(&mut stream, &resp)?;
    }
    Ok(())
}

fn error_json(msg: &str) -> Json {
    Json::obj(vec![("error", Json::str(msg))])
}

fn answer(req: &Json, store: &FactorStore, served: &AtomicU64) -> Json {
    match req.get("op").as_str() {
        Some("get") => {
            let Some(hex) = req.get("key").as_str() else {
                return error_json("get without key");
            };
            let Ok(key) = u64::from_str_radix(hex, 16) else {
                return error_json("malformed key");
            };
            // peek serves resident AND spill tiers and touches LRU
            // recency (a shared factor is a hot factor) but counts
            // nothing: peer probes must not mark the leader's store
            // dirty or pose as local SVD work in its metrics
            match store.peek(Fingerprint(key)) {
                Some(v) if entry_is_finite(&v) => {
                    served.fetch_add(1, Ordering::Relaxed);
                    Json::obj(vec![
                        ("found", Json::Bool(true)),
                        ("entry", entry_to_json(key, &v)),
                    ])
                }
                _ => Json::obj(vec![("found", Json::Bool(false))]),
            }
        }
        _ => error_json("unknown op (expected \"get\")"),
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Client half of the sharing tier: fetches entries by fingerprint from
/// a peer's [`FactorService`]. One connection per fetch — each bias is
/// fetched at most once per process (the local store caches it), so
/// connection reuse buys nothing.
#[derive(Clone, Debug)]
pub struct RemoteStore {
    addr: String,
}

impl RemoteStore {
    pub fn new(addr: impl Into<String>) -> Self {
        Self { addr: addr.into() }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Fetch `key`, degrading every failure (network, protocol, peer
    /// miss) to `None` so the caller falls back to decomposing locally.
    pub fn fetch(&self, key: Fingerprint) -> Option<Cached> {
        self.try_fetch(key).ok().flatten()
    }

    /// Fetch `key`, surfacing transport/protocol errors.
    pub fn try_fetch(&self, key: Fingerprint)
                     -> Result<Option<Cached>> {
        // connect_timeout needs a resolved SocketAddr; plain connect
        // would wait out the OS's multi-minute TCP timeout on a
        // black-holed peer
        let addr = self
            .addr
            .as_str()
            .to_socket_addrs()
            .map_err(|e| anyhow!("resolve {}: {e}", self.addr))?
            .next()
            .ok_or_else(|| {
                anyhow!("{}: resolved to no address", self.addr)
            })?;
        let mut stream = TcpStream::connect_timeout(&addr,
                                                    CONNECT_TIMEOUT)
            .map_err(|e| anyhow!("connect {}: {e}", self.addr))?;
        set_io_timeouts(&stream, IO_TIMEOUT)?;
        let req = Json::obj(vec![
            ("op", Json::str("get")),
            ("key", Json::str(&format!("{key}"))),
        ]);
        write_frame(&mut stream, &req)?;
        let resp = read_frame(&mut stream)?
            .ok_or_else(|| anyhow!("{}: peer closed mid-request",
                                   self.addr))?;
        if let Some(msg) = resp.get("error").as_str() {
            bail!("factor service {}: {msg}", self.addr);
        }
        if resp.get("found").as_bool() != Some(true) {
            return Ok(None);
        }
        let (got, value) = entry_from_json(resp.get("entry"))?;
        if got != key {
            bail!("factor service {} answered key {got} for {key}",
                  self.addr);
        }
        Ok(Some(value))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // frame-codec robustness lives with the codec (util::frame unit
    // tests + tests/jsonlite_robustness.rs); this module only tests
    // the factor-service request semantics on top of it

    #[test]
    fn answer_handles_malformed_requests() {
        let store = FactorStore::unbounded();
        let served = AtomicU64::new(0);
        let bad_op = Json::obj(vec![("op", Json::str("put"))]);
        assert!(answer(&bad_op, &store, &served)
            .get("error")
            .as_str()
            .is_some());
        let no_key = Json::obj(vec![("op", Json::str("get"))]);
        assert!(answer(&no_key, &store, &served)
            .get("error")
            .as_str()
            .is_some());
        let bad_key = Json::obj(vec![
            ("op", Json::str("get")),
            ("key", Json::str("zz")),
        ]);
        assert!(answer(&bad_key, &store, &served)
            .get("error")
            .as_str()
            .is_some());
        let miss = Json::obj(vec![
            ("op", Json::str("get")),
            ("key", Json::str("0000000000000001")),
        ]);
        assert_eq!(answer(&miss, &store, &served).get("found").as_bool(),
                   Some(false));
        assert_eq!(served.load(Ordering::Relaxed), 0);
    }
}
