//! [`FactorStore`] — the amortization layer the paper assumes (§4.3,
//! Table 4: 4.79 s of offline SVD for SwinV2, ~0.05% once amortized).
//!
//! Decomposition used to be a per-`plan()` tax: every call on a
//! `StaticLearned` table re-ran the full Jacobi SVD, every `Dynamic`
//! spec re-fitted its neural factor functions. The store turns that
//! into a content-addressed cache shared across planner, coordinator
//! and server:
//!
//! * **Content-addressed.** Keys are [`Fingerprint`]s: an FNV-1a hash
//!   of the bias kind + geometry + the exact bytes of its tables /
//!   sources (see [`crate::plan::BiasSpec::fingerprint`]). The planner
//!   mixes in the decomposition policy (energy target, rank override,
//!   neural config) so a different policy never aliases a cached
//!   result.
//! * **Thread-safe, decompose-once.** Concurrent `get_or_insert_with`
//!   calls for the same key run the decomposition exactly once; the
//!   other callers block on the in-flight cell and share the finished
//!   [`Factors`] behind an `Arc` (zero copies on a hit).
//! * **Byte-budget LRU.** Factor strips are Θ((N+M)·R) each (Thm 3.2);
//!   the store evicts least-recently-used entries once the resident
//!   bytes exceed the budget, and counts hits / misses / evictions.
//! * **Persistent.** [`FactorStore::save`] / [`FactorStore::load`]
//!   round-trip the store through a jsonlite file, so offline
//!   decomposition (`flashbias warm`) survives process restarts and a
//!   serving fleet can boot warm.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{anyhow, Result};

use crate::decompose::Factors;
use crate::jsonlite::Json;
use crate::tensor::Tensor;

// ---------------------------------------------------------------------------
// Fingerprints
// ---------------------------------------------------------------------------

/// 64-bit content fingerprint — the store's key currency.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fingerprint(pub u64);

impl Fingerprint {
    pub fn as_u64(self) -> u64 {
        self.0
    }
}

impl std::fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// FNV-1a 64-bit streaming hasher (no `std::hash` — we need a stable,
/// documented digest that survives process restarts and toolchain
/// upgrades, because fingerprints are persisted in store files).
#[derive(Clone, Debug)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;

    pub fn new() -> Self {
        Self(Self::OFFSET)
    }

    #[inline]
    pub fn write_byte(&mut self, b: u8) {
        self.0 = (self.0 ^ b as u64).wrapping_mul(Self::PRIME);
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_byte(b);
        }
    }

    pub fn write_str(&mut self, s: &str) {
        self.write_bytes(s.as_bytes());
        self.write_byte(0xff); // delimiter: "ab","c" != "a","bc"
    }

    pub fn write_u32(&mut self, x: u32) {
        self.write_bytes(&x.to_le_bytes());
    }

    pub fn write_u64(&mut self, x: u64) {
        self.write_bytes(&x.to_le_bytes());
    }

    /// Hash f32 payloads by exact bit pattern, one FNV round per 32-bit
    /// word — 4× fewer multiplies than the byte-wise feed on the hot
    /// table path (fingerprints re-hash the table on every
    /// store-addressed plan). A one-ulp perturbation of any entry still
    /// yields a different fingerprint.
    pub fn write_f32s(&mut self, xs: &[f32]) {
        self.write_u64(xs.len() as u64);
        for &x in xs {
            self.0 = (self.0 ^ x.to_bits() as u64)
                .wrapping_mul(Self::PRIME);
        }
    }

    pub fn finish(&self) -> Fingerprint {
        Fingerprint(self.0)
    }
}

// ---------------------------------------------------------------------------
// Cached values
// ---------------------------------------------------------------------------

/// What one decomposition attempt produced — the store caches *outcomes*,
/// not just factor strips, so a repeated plan skips the spectrum scan
/// even when the verdict was "stay dense".
#[derive(Clone, Debug)]
pub enum Cached {
    /// Shared factor strips (SVD or neural).
    Factors(Arc<Factors>),
    /// The measured spectral rank failed the planner's low-rank test;
    /// remembered so repeated plans skip the (full-SVD) spectrum scan
    /// and fall back to dense immediately.
    Rejected { measured_rank: usize },
}

impl Cached {
    /// Resident bytes this entry charges against the store budget.
    pub fn size_bytes(&self) -> usize {
        match self {
            Cached::Factors(f) => f.size_bytes(),
            Cached::Rejected { .. } => std::mem::size_of::<usize>(),
        }
    }

    /// The shared factors, when this entry holds any.
    pub fn factors(&self) -> Option<&Arc<Factors>> {
        match self {
            Cached::Factors(f) => Some(f),
            Cached::Rejected { .. } => None,
        }
    }
}

// ---------------------------------------------------------------------------
// The store
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct Entry {
    value: Cached,
    bytes: usize,
    /// Monotonic recency stamp — larger = more recently used.
    stamp: u64,
}

#[derive(Debug, Default)]
struct Inner {
    map: HashMap<u64, Entry>,
    /// In-flight decompositions: concurrent callers share one cell so
    /// the closure runs exactly once per key.
    pending: HashMap<u64, Arc<OnceLock<Cached>>>,
    bytes: usize,
    tick: u64,
}

/// Counter snapshot for metrics/CLIs.
#[derive(Clone, Copy, Debug, Default)]
pub struct StoreStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    pub bytes: usize,
    /// `usize::MAX` = unbounded.
    pub budget_bytes: usize,
}

impl StoreStats {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        let budget = if self.budget_bytes == usize::MAX {
            "unbounded".to_string()
        } else {
            crate::util::human_bytes(self.budget_bytes as u64)
        };
        format!(
            "store: hits={} misses={} evictions={} entries={} bytes={} \
             budget={budget}",
            self.hits,
            self.misses,
            self.evictions,
            self.entries,
            crate::util::human_bytes(self.bytes as u64),
        )
    }

    /// Metrics-dump shape (`coordinator::Metrics::to_json` embeds this).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("hits", Json::num(self.hits as f64)),
            ("misses", Json::num(self.misses as f64)),
            ("evictions", Json::num(self.evictions as f64)),
            ("entries", Json::num(self.entries as f64)),
            ("bytes", Json::num(self.bytes as f64)),
            (
                "budget_bytes",
                if self.budget_bytes == usize::MAX {
                    Json::Null
                } else {
                    Json::num(self.budget_bytes as f64)
                },
            ),
        ])
    }
}

/// Thread-safe, content-addressed factor store with a byte-budget LRU.
pub struct FactorStore {
    inner: Mutex<Inner>,
    budget_bytes: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl std::fmt::Debug for FactorStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "FactorStore(entries={}, bytes={}, hits={}, misses={})",
            s.entries, s.bytes, s.hits, s.misses
        )
    }
}

impl FactorStore {
    /// Store bounded to `budget_bytes` of resident factor data.
    pub fn new(budget_bytes: usize) -> Self {
        Self {
            inner: Mutex::new(Inner::default()),
            budget_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Store with no byte budget (nothing is ever evicted).
    pub fn unbounded() -> Self {
        Self::new(usize::MAX)
    }

    /// Look up a finished entry (LRU touch). Counts a hit or a miss.
    pub fn get(&self, key: Fingerprint) -> Option<Cached> {
        let found = {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let stamp = inner.tick;
            inner.map.get_mut(&key.0).map(|e| {
                e.stamp = stamp;
                e.value.clone()
            })
        };
        match found {
            Some(v) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Get the entry for `key`, running `decompose` to fill it on a
    /// miss. Concurrent callers for the same key run `decompose`
    /// exactly once: one caller computes, the rest block on the
    /// in-flight cell and share the result (each such share counts as a
    /// hit — they did no decomposition work).
    pub fn get_or_insert_with(
        &self,
        key: Fingerprint,
        decompose: impl FnOnce() -> Cached,
    ) -> Cached {
        let cell = {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let stamp = inner.tick;
            if let Some(e) = inner.map.get_mut(&key.0) {
                e.stamp = stamp;
                let v = e.value.clone();
                drop(inner);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return v;
            }
            inner
                .pending
                .entry(key.0)
                .or_insert_with(|| Arc::new(OnceLock::new()))
                .clone()
        };
        // The store lock is NOT held while decomposing: only same-key
        // callers wait here, everyone else proceeds.
        let mut ran = false;
        let value = cell
            .get_or_init(|| {
                ran = true;
                decompose()
            })
            .clone();
        if ran {
            self.misses.fetch_add(1, Ordering::Relaxed);
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        let mut inner = self.inner.lock().unwrap();
        // Only the cell we actually waited on may be retired: after an
        // eviction, a *newer* in-flight decomposition for this key can
        // own a fresh pending cell, and a late waiter from the old one
        // must not remove it (that would let a third caller re-run the
        // work) or clobber the map with its stale value.
        let owns_cell = inner
            .pending
            .get(&key.0)
            .is_some_and(|c| Arc::ptr_eq(c, &cell));
        if owns_cell {
            inner.pending.remove(&key.0);
            if !inner.map.contains_key(&key.0) {
                self.insert_locked(&mut inner, key.0, value.clone());
            }
        }
        value
    }

    /// Insert (or replace) an entry directly — the load path.
    pub fn insert(&self, key: Fingerprint, value: Cached) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(old) = inner.map.remove(&key.0) {
            inner.bytes -= old.bytes;
        }
        self.insert_locked(&mut inner, key.0, value);
    }

    fn insert_locked(&self, inner: &mut Inner, key: u64, value: Cached) {
        inner.tick += 1;
        let stamp = inner.tick;
        let bytes = value.size_bytes();
        inner.bytes += bytes;
        inner.map.insert(key, Entry { value, bytes, stamp });
        // strict byte budget: evict LRU-first until back under (the
        // just-inserted entry has the newest stamp, so it goes last)
        while inner.bytes > self.budget_bytes && !inner.map.is_empty() {
            let lru = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| *k);
            match lru {
                Some(k) => {
                    if let Some(e) = inner.map.remove(&k) {
                        inner.bytes -= e.bytes;
                    }
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                None => break,
            }
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().map.is_empty()
    }

    /// Resident factor bytes.
    pub fn total_bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    pub fn stats(&self) -> StoreStats {
        let inner = self.inner.lock().unwrap();
        StoreStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: inner.map.len(),
            bytes: inner.bytes,
            budget_bytes: self.budget_bytes,
        }
    }

    // -- persistence --------------------------------------------------------

    /// Serialize every resident entry to a jsonlite file. Entries are
    /// written oldest-first so a later [`load`](Self::load) re-inserts
    /// them in LRU order. Finite f32 payloads survive the text round
    /// trip exactly (shortest-roundtrip float formatting); entries
    /// holding non-finite values are skipped — NaN/inf have no JSON
    /// representation, and writing them would leave a file every later
    /// `load` rejects. A skipped bias simply decomposes again on demand.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let json = {
            let inner = self.inner.lock().unwrap();
            let mut entries: Vec<(&u64, &Entry)> =
                inner.map.iter().collect();
            entries.sort_by_key(|(_, e)| e.stamp);
            let arr: Vec<Json> = entries
                .iter()
                .filter(|(_, e)| entry_is_finite(&e.value))
                .map(|(k, e)| entry_to_json(**k, &e.value))
                .collect();
            Json::obj(vec![
                ("version", Json::num(1.0)),
                ("entries", Json::Arr(arr)),
            ])
        };
        // atomic replace: a crash mid-write must never leave a
        // truncated file that bricks every later open() on this path
        let path = path.as_ref();
        let tmp = path
            .with_extension(format!("tmp.{}", std::process::id()));
        std::fs::write(&tmp, json.dump())
            .map_err(|e| anyhow!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path).map_err(|e| {
            anyhow!(
                "rename {} -> {}: {e}",
                tmp.display(),
                path.display()
            )
        })
    }

    /// Load a store previously written by [`save`](Self::save).
    pub fn load(path: impl AsRef<Path>,
                budget_bytes: usize) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        let json = Json::parse(&text)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let store = Self::new(budget_bytes);
        for entry in json.get("entries").as_arr().unwrap_or(&[]) {
            let (key, value) = entry_from_json(entry)
                .map_err(|e| anyhow!("{}: {e}", path.display()))?;
            store.insert(key, value);
        }
        Ok(store)
    }

    /// Load `path` if it exists, else start empty — the CLI's
    /// `--store PATH` semantics.
    pub fn open(path: impl AsRef<Path>,
                budget_bytes: usize) -> Result<Self> {
        if path.as_ref().exists() {
            Self::load(path, budget_bytes)
        } else {
            Ok(Self::new(budget_bytes))
        }
    }
}

/// Whether an entry's payload is fully finite (serializable as JSON
/// numbers). Factors from a corrupt table can carry NaN/inf; those are
/// kept in memory but never persisted.
fn entry_is_finite(value: &Cached) -> bool {
    match value {
        Cached::Factors(f) => {
            f.rel_err.is_finite()
                && f.phi_q.data().iter().all(|x| x.is_finite())
                && f.phi_k.data().iter().all(|x| x.is_finite())
        }
        Cached::Rejected { .. } => true,
    }
}

fn f32s_to_json(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::num(x as f64)).collect())
}

fn json_to_f32s(j: &Json) -> Result<Vec<f32>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected a number array"))?
        .iter()
        .map(|v| {
            v.as_f64()
                .map(|x| x as f32)
                .ok_or_else(|| anyhow!("non-numeric array element"))
        })
        .collect()
}

fn entry_to_json(key: u64, value: &Cached) -> Json {
    let key_hex = format!("{:016x}", key);
    match value {
        Cached::Factors(f) => Json::obj(vec![
            ("key", Json::str(&key_hex)),
            ("kind", Json::str("factors")),
            ("n", Json::num(f.phi_q.shape()[0] as f64)),
            ("m", Json::num(f.phi_k.shape()[0] as f64)),
            ("rank", Json::num(f.rank as f64)),
            ("rel_err", Json::num(f.rel_err as f64)),
            ("phi_q", f32s_to_json(f.phi_q.data())),
            ("phi_k", f32s_to_json(f.phi_k.data())),
        ]),
        Cached::Rejected { measured_rank } => Json::obj(vec![
            ("key", Json::str(&key_hex)),
            ("kind", Json::str("rejected")),
            ("measured_rank", Json::num(*measured_rank as f64)),
        ]),
    }
}

fn entry_from_json(j: &Json) -> Result<(Fingerprint, Cached)> {
    let key_hex = j
        .get("key")
        .as_str()
        .ok_or_else(|| anyhow!("entry without key"))?;
    let key = u64::from_str_radix(key_hex, 16)
        .map_err(|_| anyhow!("bad key {key_hex}"))?;
    let value = match j.get("kind").as_str() {
        Some("factors") => {
            let n = j
                .get("n")
                .as_usize()
                .ok_or_else(|| anyhow!("factors entry without n"))?;
            let m = j
                .get("m")
                .as_usize()
                .ok_or_else(|| anyhow!("factors entry without m"))?;
            let rank = j
                .get("rank")
                .as_usize()
                .ok_or_else(|| anyhow!("factors entry without rank"))?;
            let rel_err = j
                .get("rel_err")
                .as_f64()
                .ok_or_else(|| anyhow!("factors entry without rel_err"))?
                as f32;
            let pq = json_to_f32s(j.get("phi_q"))?;
            let pk = json_to_f32s(j.get("phi_k"))?;
            if pq.len() != n * rank || pk.len() != m * rank {
                return Err(anyhow!(
                    "factor payload sizes {}/{} disagree with \
                     (n={n}, m={m}, rank={rank})",
                    pq.len(),
                    pk.len()
                ));
            }
            Cached::Factors(Arc::new(Factors {
                phi_q: Tensor::new(&[n, rank], pq),
                phi_k: Tensor::new(&[m, rank], pk),
                rel_err,
                rank,
            }))
        }
        Some("rejected") => Cached::Rejected {
            measured_rank: j
                .get("measured_rank")
                .as_usize()
                .ok_or_else(|| anyhow!("rejected entry without rank"))?,
        },
        other => return Err(anyhow!("unknown entry kind {other:?}")),
    };
    Ok((Fingerprint(key), value))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bias::{Alibi, ExactBias};
    use crate::decompose::from_exact;

    fn cached_alibi(n: usize) -> Cached {
        Cached::Factors(Arc::new(from_exact(&Alibi::new(n, n, 0.5))))
    }

    #[test]
    fn fnv_is_stable_and_order_sensitive() {
        let mut a = Fnv64::new();
        a.write_str("alibi");
        a.write_u64(64);
        let mut b = Fnv64::new();
        b.write_str("alibi");
        b.write_u64(64);
        assert_eq!(a.finish(), b.finish());
        let mut c = Fnv64::new();
        c.write_u64(64);
        c.write_str("alibi");
        assert_ne!(a.finish(), c.finish());
        // str delimiter: "ab"+"c" != "a"+"bc"
        let mut d = Fnv64::new();
        d.write_str("ab");
        d.write_str("c");
        let mut e = Fnv64::new();
        e.write_str("a");
        e.write_str("bc");
        assert_ne!(d.finish(), e.finish());
    }

    #[test]
    fn get_or_insert_runs_once_then_hits() {
        let store = FactorStore::unbounded();
        let key = Fingerprint(42);
        let mut calls = 0;
        for _ in 0..3 {
            let v = store.get_or_insert_with(key, || {
                calls += 1;
                cached_alibi(8)
            });
            assert!(v.factors().is_some());
        }
        assert_eq!(calls, 1);
        assert_eq!(store.misses(), 1);
        assert_eq!(store.hits(), 2);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn lru_eviction_respects_budget() {
        // each rank-2 alibi(8) factor pair: (8 + 8) * 2 * 4 = 128 bytes
        let store = FactorStore::new(300);
        store.get_or_insert_with(Fingerprint(1), || cached_alibi(8));
        store.get_or_insert_with(Fingerprint(2), || cached_alibi(8));
        assert_eq!(store.len(), 2);
        // touch key 1 so key 2 is the LRU victim
        assert!(store.get(Fingerprint(1)).is_some());
        store.get_or_insert_with(Fingerprint(3), || cached_alibi(8));
        assert_eq!(store.len(), 2);
        assert!(store.total_bytes() <= 300);
        assert_eq!(store.evictions(), 1);
        assert!(store.get(Fingerprint(1)).is_some());
        assert!(store.get(Fingerprint(2)).is_none(), "LRU must go first");
        assert!(store.get(Fingerprint(3)).is_some());
    }

    #[test]
    fn rejected_entries_are_tiny_and_cacheable() {
        let store = FactorStore::new(64);
        store.get_or_insert_with(Fingerprint(9), || Cached::Rejected {
            measured_rank: 57,
        });
        match store.get(Fingerprint(9)) {
            Some(Cached::Rejected { measured_rank }) => {
                assert_eq!(measured_rank, 57)
            }
            other => panic!("expected rejected entry, got {other:?}"),
        }
    }

    #[test]
    fn save_load_roundtrip_exact() {
        let store = FactorStore::unbounded();
        store.get_or_insert_with(Fingerprint(7), || cached_alibi(12));
        store.get_or_insert_with(Fingerprint(8), || Cached::Rejected {
            measured_rank: 33,
        });
        let path = std::env::temp_dir().join(format!(
            "fb_store_unit_{}.json",
            std::process::id()
        ));
        store.save(&path).expect("save");
        let loaded = FactorStore::load(&path, usize::MAX).expect("load");
        assert_eq!(loaded.len(), 2);
        let orig = store.get(Fingerprint(7)).unwrap();
        let back = loaded.get(Fingerprint(7)).unwrap();
        let (of, bf) = (orig.factors().unwrap(), back.factors().unwrap());
        assert_eq!(of.rank, bf.rank);
        assert_eq!(of.phi_q.data(), bf.phi_q.data());
        assert_eq!(of.phi_k.data(), bf.phi_k.data());
        assert!(matches!(
            loaded.get(Fingerprint(8)),
            Some(Cached::Rejected { measured_rank: 33 })
        ));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn save_skips_non_finite_entries_so_load_never_bricks() {
        let store = FactorStore::unbounded();
        store.insert(Fingerprint(1), cached_alibi(8));
        store.insert(
            Fingerprint(2),
            Cached::Factors(Arc::new(Factors {
                phi_q: Tensor::new(&[2, 1], vec![f32::NAN, 1.0]),
                phi_k: Tensor::new(&[2, 1], vec![0.5, 2.0]),
                rel_err: 0.0,
                rank: 1,
            })),
        );
        let path = std::env::temp_dir().join(format!(
            "fb_store_nan_{}.json",
            std::process::id()
        ));
        store.save(&path).expect("save");
        let loaded =
            FactorStore::load(&path, usize::MAX).expect("load succeeds");
        assert_eq!(loaded.len(), 1, "NaN entry must be skipped");
        assert!(loaded.get(Fingerprint(1)).is_some());
        assert!(loaded.get(Fingerprint(2)).is_none());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn open_missing_path_starts_empty() {
        let path = std::env::temp_dir().join(format!(
            "fb_store_missing_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let store = FactorStore::open(&path, usize::MAX).expect("open");
        assert!(store.is_empty());
    }

    #[test]
    fn stats_snapshot_and_summary() {
        let store = FactorStore::new(1 << 20);
        store.get_or_insert_with(Fingerprint(1), || cached_alibi(8));
        store.get_or_insert_with(Fingerprint(1), || cached_alibi(8));
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!(s.bytes > 0);
        assert!(s.summary().contains("hits=1"));
        assert_eq!(s.to_json().get("misses").as_usize(), Some(1));
    }
}
